//! End-to-end driver (the DESIGN.md validation workload): the paper's full
//! evaluation scenario — 5 cameras around a traffic intersection, 60 s
//! offline profile, 120 s online evaluation — run through every layer:
//! world simulation → ReID + tandem filters → RoI optimization → tile
//! grouping → codec → shared-link DES → AOT HLO inference (PJRT) →
//! unique-vehicle query.
//!
//!     make artifacts && cargo run --release --example five_camera_intersection
//!
//! Prints the Fig. 8 ablation rows at full paper scale and writes a JSON
//! report to `target/five_camera_report.json`.  Recorded in EXPERIMENTS.md.

use crossroi::config::Config;
use crossroi::coordinator::{run_ablation, Method, RuntimeInfer};
use crossroi::runtime::Runtime;
use crossroi::sim::Scenario;
use crossroi::util::json::Json;

fn main() -> anyhow::Result<()> {
    let cfg = Config::paper();
    println!(
        "paper-scale scenario: {} cameras, {:.0} s profile + {:.0} s eval @ {} fps",
        cfg.scenario.n_cameras, cfg.scenario.profile_secs, cfg.scenario.eval_secs, cfg.scenario.fps
    );
    let scenario = Scenario::build(&cfg.scenario);
    println!(
        "  {} vehicles, {} ground-truth boxes over {} frames",
        scenario.world.vehicles.len(),
        scenario.total_boxes(),
        scenario.n_frames()
    );

    let rt = Runtime::load(&cfg.system.artifacts_dir)?;
    let infer = RuntimeInfer(&rt);
    let methods = [
        Method::Baseline,
        Method::NoFilters,
        Method::NoMerging,
        Method::NoRoiInf,
        Method::CrossRoi,
    ];
    let reports = run_ablation(&scenario, &cfg.system, &infer, &methods)?;
    println!();
    for r in &reports {
        println!("{}", r.row());
    }

    // machine-readable record for EXPERIMENTS.md
    let items: Vec<Json> = reports
        .iter()
        .map(|r| {
            Json::obj(vec![
                ("method", Json::Str(r.method.clone())),
                ("accuracy", Json::Num(r.accuracy)),
                ("network_mbps", Json::Num(r.network_mbps_total)),
                ("server_hz", Json::Num(r.server_hz)),
                ("camera_fps", Json::Num(r.camera_fps)),
                ("e2e_latency_s", Json::Num(r.latency.total())),
                ("latency_p95_s", Json::Num(r.latency_p95)),
                ("mask_tiles", Json::Num(r.mask_tiles as f64)),
                ("frames_total", Json::Num(r.frames_total as f64)),
            ])
        })
        .collect();
    let out = Json::Arr(items).to_string_pretty(2);
    std::fs::create_dir_all("target")?;
    std::fs::write("target/five_camera_report.json", &out)?;
    println!("\nwrote target/five_camera_report.json");

    let base = &reports[0];
    let cross = reports.iter().find(|r| r.method == "CrossRoI").unwrap();
    println!(
        "CrossRoI vs Baseline: network -{:.0}%, latency -{:.0}%, accuracy {:.4}",
        100.0 * (1.0 - cross.network_mbps_total / base.network_mbps_total),
        100.0 * (1.0 - cross.latency.total() / base.latency.total()),
        cross.accuracy
    );
    Ok(())
}
