//! Offline phase walkthrough (§3, §4.2): shows each stage's intermediate
//! products — raw ReID error structure, what each tandem filter removed,
//! the association table, the optimized masks and the tile groups.
//! Runs entirely without artifacts (no inference involved).
//!
//!     cargo run --release --example offline_profiling

use crossroi::association::table::AssociationTable;
use crossroi::association::tiles::Tiling;
use crossroi::config::Config;
use crossroi::filters::TandemFilters;
use crossroi::reid::error_model::{ErrorModelParams, RawReid};
use crossroi::reid::labels;
use crossroi::roi::masks::RoiMasks;
use crossroi::roi::setcover::{self, SolverParams};
use crossroi::sim::Scenario;
use crossroi::tilegroup;

fn main() {
    let cfg = Config::paper();
    let scenario = Scenario::build(&cfg.scenario);
    println!(
        "① offline ReID over {} profile frames...",
        scenario.profile_range().len()
    );
    let raw =
        RawReid::generate(&scenario, scenario.profile_range(), &ErrorModelParams::default());
    let tot = |m: &[Vec<labels::PairCounts>], f: fn(&labels::PairCounts) -> usize| -> usize {
        m.iter().flat_map(|r| r.iter()).map(f).sum()
    };
    let before = labels::characterize_all(&raw);
    println!(
        "   {} records; pairwise TP={} FP={} FN={} TN={}",
        raw.len(),
        tot(&before, |c| c.tp),
        tot(&before, |c| c.fp),
        tot(&before, |c| c.fn_),
        tot(&before, |c| c.tn)
    );

    println!("② tandem statistical filters...");
    let (clean, report) = TandemFilters::default().apply(&raw);
    let after = labels::characterize_all(&clean);
    println!(
        "   regression filter decoupled {} FP; SVM filter removed {} FN",
        report.fp_rewritten, report.fn_removed
    );
    println!(
        "   pairwise now TP={} FP={} FN={} TN={}",
        tot(&after, |c| c.tp),
        tot(&after, |c| c.fp),
        tot(&after, |c| c.fn_),
        tot(&after, |c| c.tn)
    );

    println!("③ region association lookup table...");
    let tiling = Tiling::new(scenario.cameras.len(), 320, 192, cfg.scenario.tile_px);
    let table = AssociationTable::build(&clean, &tiling);
    println!(
        "   {} occurrences -> {} unique constraints over {} candidate tiles",
        table.total_occurrences,
        table.n_constraints(),
        table.candidate_tiles().len()
    );

    println!("④ RoI mask optimization (greedy + prune set-cover)...");
    let sol = setcover::solve(&table, &SolverParams::default());
    let masks = RoiMasks::from_solution(&tiling, &sol.tiles);
    for cam in 0..scenario.cameras.len() {
        println!(
            "   C{}: {:3} tiles ({:4.1}% of frame)",
            cam + 1,
            masks.camera_size(cam),
            100.0 * masks.coverage(cam)
        );
    }
    println!("   |M| = {} of {} tiles", masks.total_size(), tiling.total());

    println!("⑤ tile grouping for the codec...");
    let groups = tilegroup::group_all(&masks);
    for cam in 0..scenario.cameras.len() {
        println!(
            "   C{}: {} tiles -> {} rectangular regions",
            cam + 1,
            masks.camera_size(cam),
            groups[cam].len()
        );
    }

    // ASCII render of camera 1's mask
    println!("\nC1 RoI mask ('#' = mask tile, '.' = dropped):");
    for ty in 0..tiling.tiles_y {
        let row: String = (0..tiling.tiles_x)
            .map(|tx| if masks.tiles[0].contains(&(tx, ty)) { '#' } else { '.' })
            .collect();
        println!("   {row}");
    }
}
