//! Overlap-sharded planning on a disjoint multi-intersection fleet.
//!
//! The CLI's `--shards auto` on the default scenario exercises only the
//! single-component fall-through (the 5-camera rig is one overlap
//! component), so this example is the release-build smoke for the real
//! fan-out: it builds a synthetic fleet of disjoint 4-camera
//! intersections (`crossroi::testing::fleet`), plans it sharded and
//! unsharded, checks the plans are byte-identical, and prints the shard
//! breakdown.  CI runs it on every push (`cargo run --release --example
//! sharded_fleet`); it needs no PJRT runtime.

use anyhow::Result;

use crossroi::config::Config;
use crossroi::coordinator::Method;
use crossroi::offline::{build_plan_from_stream, OfflineOptions, ShardMode};
use crossroi::testing::fleet::disjoint_intersections;

fn main() -> Result<()> {
    let mut cfg = Config::paper();
    // small windows: this is a smoke, not a bench (eval length only
    // affects how much ground truth the scenario builder generates)
    cfg.scenario.profile_secs = 12.0;
    cfg.scenario.eval_secs = 8.0;
    let n_intersections = 3;
    let (stream, tiling) = disjoint_intersections(&cfg, n_intersections, cfg.scenario.seed);
    println!(
        "fleet: {} cameras as {n_intersections} disjoint intersections, {} profile records",
        tiling.n_cameras,
        stream.len()
    );

    let plan_with = |shards: ShardMode| {
        let opts = OfflineOptions { shards, ..Default::default() };
        build_plan_from_stream(&stream, &tiling, &cfg.system, &Method::CrossRoi, &opts)
    };
    let sharded = plan_with(ShardMode::Auto)?;
    let unsharded = plan_with(ShardMode::Off)?;

    assert!(
        sharded.report.shards.len() >= n_intersections,
        "partition found {} shards, expected >= {n_intersections}",
        sharded.report.shards.len()
    );
    for cam in 0..tiling.n_cameras {
        assert_eq!(
            sharded.masks.tiles[cam], unsharded.masks.tiles[cam],
            "sharded plan diverged from unsharded at camera {cam}"
        );
        assert_eq!(sharded.groups[cam], unsharded.groups[cam], "groups diverged at {cam}");
        assert_eq!(sharded.blocks[cam], unsharded.blocks[cam], "blocks diverged at {cam}");
    }
    assert_eq!(sharded.filter_report, unsharded.filter_report, "filter report diverged");
    assert_eq!(sharded.n_constraints, unsharded.n_constraints, "constraint count diverged");

    println!(
        "plans byte-identical: {} constraints, |M| = {} tiles; sharded {:.2} s vs unsharded {:.2} s",
        sharded.n_constraints,
        sharded.masks.total_size(),
        sharded.seconds(),
        unsharded.seconds()
    );
    for (i, s) in sharded.report.shards.iter().enumerate() {
        println!(
            "  shard {i}: cameras {:?}, {} constraints, {} tiles",
            s.cameras, s.n_constraints, s.mask_tiles
        );
    }
    println!("OK");
    Ok(())
}
