//! Quickstart: build the paper scene, run the offline phase, then run the
//! full CrossRoI method against the Baseline on a short online window.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! Prints the offline mask summary and the two method rows (network,
//! throughput, latency, accuracy).

use crossroi::config::Config;
use crossroi::coordinator::{self, Method, RuntimeInfer};
use crossroi::runtime::Runtime;
use crossroi::sim::Scenario;

fn main() -> anyhow::Result<()> {
    let mut cfg = Config::paper();
    // keep the quickstart quick: 30 s profile + 20 s eval
    cfg.scenario.profile_secs = 30.0;
    cfg.scenario.eval_secs = 20.0;

    println!("building scenario ({} cameras, {:.0} s)...", cfg.scenario.n_cameras, cfg.scenario.total_secs());
    let scenario = Scenario::build(&cfg.scenario);
    println!("  {} ground-truth boxes", scenario.total_boxes());

    println!("loading AOT artifacts from {:?}...", cfg.system.artifacts_dir);
    let rt = Runtime::load(&cfg.system.artifacts_dir)?;
    let infer = RuntimeInfer(&rt);

    let plan =
        coordinator::build_plan(&scenario, &cfg.scenario, &cfg.system, &Method::CrossRoi)?;
    println!(
        "offline: |M| = {} tiles, coverage {:.1}%, {} regions total",
        plan.masks.total_size(),
        100.0 * (0..cfg.scenario.n_cameras).map(|c| plan.masks.coverage(c)).sum::<f64>()
            / cfg.scenario.n_cameras as f64,
        plan.groups.iter().map(|g| g.len()).sum::<usize>()
    );

    for method in [Method::Baseline, Method::CrossRoi] {
        let report = coordinator::run_method(&scenario, &cfg.system, &infer, &method, None)?;
        println!("{}", report.row());
    }
    Ok(())
}
