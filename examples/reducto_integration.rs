//! CrossRoI-Reducto integration (Fig. 12 / Table 4): spatial RoI masks
//! first, then temporal frame filtering, compared against plain Reducto at
//! one accuracy target.
//!
//!     make artifacts && cargo run --release --example reducto_integration [target]

use crossroi::config::Config;
use crossroi::coordinator::{baseline_reference, run_method, Method, RuntimeInfer};
use crossroi::runtime::Runtime;
use crossroi::sim::Scenario;

fn main() -> anyhow::Result<()> {
    let target: f64 = std::env::args().nth(1).map(|s| s.parse()).transpose()?.unwrap_or(0.9);
    let mut cfg = Config::paper();
    cfg.scenario.profile_secs = 40.0;
    cfg.scenario.eval_secs = 40.0;

    println!("accuracy target {target}");
    let scenario = Scenario::build(&cfg.scenario);
    let rt = Runtime::load(&cfg.system.artifacts_dir)?;
    let infer = RuntimeInfer(&rt);

    let (reference, baseline) = baseline_reference(&scenario, &cfg.system, &infer)?;
    println!("{}", baseline.row());
    for method in [Method::Reducto(target), Method::CrossRoiReducto(target)] {
        let r = run_method(&scenario, &cfg.system, &infer, &method, Some(&reference))?;
        println!("{}", r.row());
        println!(
            "  target {:.2} -> achieved {:.3}; frames reduced {}/{}",
            target, r.accuracy, r.frames_reduced, r.frames_total
        );
    }
    Ok(())
}
