"""L1 — Pallas sparse-block convolution kernels (SBNet-style, TPU rethink).

The paper accelerates RoI-restricted CNN inference with SBNet [36], a CUDA
kernel that *gathers* active spatial blocks, runs dense convolution on the
stacked blocks, and *scatters* results back.  On the TPU-shaped Pallas side
the idea maps onto the kernel **grid**: each active block is one grid step,
``BlockSpec`` stages that block (plus conv halo) HBM->VMEM, and the 3x3
convolution is expressed as nine shifted ``dot_general`` contractions so the
MXU systolic array does the arithmetic (the CUDA version leans on WMMA
fragments instead).  Gather / scatter of block indices stays in XLA around
the kernel, mirroring SBNet's gather/scatter modules (see model.py).

All kernels run with ``interpret=True``: the CPU PJRT plugin used by the
rust runtime cannot execute Mosaic custom-calls, and interpret-mode lowers
the kernel body to plain HLO that any backend runs.  Real-TPU VMEM / MXU
estimates live in DESIGN.md §2.

Correctness oracle: ``ref.py`` (pure jnp / lax.conv); checked by pytest +
hypothesis in ``python/tests/``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _conv3x3_body(x, w, b, *, relu: bool):
    """Dense 3x3 VALID conv on one block, unrolled as 9 MXU contractions.

    x: (H+2, W+2, Cin) float32, w: (3, 3, Cin, Cout), b: (Cout,).
    Returns (H, W, Cout).
    """
    h = x.shape[0] - 2
    wd = x.shape[1] - 2
    cout = w.shape[3]
    acc = jnp.zeros((h, wd, cout), dtype=jnp.float32)
    for dy in range(3):
        for dx in range(3):
            patch = x[dy : dy + h, dx : dx + wd, :]
            # (H, W, Cin) @ (Cin, Cout) -> (H, W, Cout): an MXU-friendly
            # contraction over the channel dimension.
            acc = acc + jax.lax.dot_general(
                patch,
                w[dy, dx],
                dimension_numbers=(((2,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            )
    acc = acc + b
    if relu:
        acc = jnp.maximum(acc, 0.0)
    return acc


def _block_conv_kernel(x_ref, w_ref, b_ref, o_ref, *, relu: bool):
    x = x_ref[...][0]  # (H+2, W+2, Cin) — leading block dim is 1
    o_ref[...] = _conv3x3_body(x, w_ref[...], b_ref[...], relu=relu)[None]


def block_conv3x3(x_blocks, w, b, *, relu: bool = True):
    """Sparse-block 3x3 VALID convolution.

    x_blocks: (K, H+2, W+2, Cin) — K gathered active blocks with 1px halo.
    w: (3, 3, Cin, Cout); b: (Cout,).
    Returns (K, H, W, Cout); ReLU applied when ``relu``.

    Grid = (K,): one grid step per active block, i.e. compute scales with
    the number of active blocks — the SBNet property the paper exploits.
    """
    k, hp, wp, cin = x_blocks.shape
    h, wd = hp - 2, wp - 2
    cout = w.shape[3]
    kernel = functools.partial(_block_conv_kernel, relu=relu)
    return pl.pallas_call(
        kernel,
        grid=(k,),
        in_specs=[
            pl.BlockSpec((1, hp, wp, cin), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((3, 3, cin, cout), lambda i: (0, 0, 0, 0)),
            pl.BlockSpec((cout,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((1, h, wd, cout), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((k, h, wd, cout), jnp.float32),
        interpret=True,
    )(x_blocks, w, b)


def _fused_stack_kernel(x_ref, w1_ref, b1_ref, w2_ref, b2_ref, w3_ref, b3_ref,
                        hw_ref, o_ref, *, cell: int):
    """Fused 3-conv + head + cell-pool stack for one block.

    Input block carries a halo of 3 (one per conv layer); each VALID conv
    peels one pixel per side.  After the head (1x1 projection) the block is
    mean-pooled into (H/cell, W/cell) objectness cells.  Fusing the stack
    keeps every intermediate in VMEM — one HBM round-trip per block instead
    of four (the perf-pass optimization recorded in EXPERIMENTS.md §Perf).
    """
    x = x_ref[...][0]
    y = _conv3x3_body(x, w1_ref[...], b1_ref[...], relu=True)
    y = _conv3x3_body(y, w2_ref[...], b2_ref[...], relu=True)
    y = _conv3x3_body(y, w3_ref[...], b3_ref[...], relu=True)
    # head: 1x1 projection to a scalar objectness score per pixel
    score = jax.lax.dot_general(
        y, hw_ref[...],
        dimension_numbers=(((2,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )[..., 0]
    h, wd = score.shape
    pooled = score.reshape(h // cell, cell, wd // cell, cell).mean(axis=(1, 3))
    o_ref[...] = pooled[None]


def detector_block_stack(x_blocks, params, *, cell: int = 16):
    """Fused SBNet block stack: 3x conv3x3+ReLU -> 1x1 head -> cell pooling.

    x_blocks: (K, H+6, W+6, Cin) — gathered blocks with halo 3.
    params: dict with w1,b1,w2,b2,w3,b3 (conv layers) and head (C3, 1).
    Returns (K, H/cell, W/cell) objectness cells.
    """
    k, hp, wp, cin = x_blocks.shape
    h, wd = hp - 6, wp - 6
    assert h % cell == 0 and wd % cell == 0, (h, wd, cell)
    w1, b1 = params["w1"], params["b1"]
    w2, b2 = params["w2"], params["b2"]
    w3, b3 = params["w3"], params["b3"]
    hw = params["head"]
    c1, c2, c3 = w1.shape[3], w2.shape[3], w3.shape[3]
    kernel = functools.partial(_fused_stack_kernel, cell=cell)
    return pl.pallas_call(
        kernel,
        grid=(k,),
        in_specs=[
            pl.BlockSpec((1, hp, wp, cin), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((3, 3, cin, c1), lambda i: (0, 0, 0, 0)),
            pl.BlockSpec((c1,), lambda i: (0,)),
            pl.BlockSpec((3, 3, c1, c2), lambda i: (0, 0, 0, 0)),
            pl.BlockSpec((c2,), lambda i: (0,)),
            pl.BlockSpec((3, 3, c2, c3), lambda i: (0, 0, 0, 0)),
            pl.BlockSpec((c3,), lambda i: (0,)),
            pl.BlockSpec((c3, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, h // cell, wd // cell), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((k, h // cell, wd // cell), jnp.float32),
        interpret=True,
    )(x_blocks, w1, b1, w2, b2, w3, b3, hw)
