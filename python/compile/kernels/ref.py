"""Pure-jnp correctness oracles for the L1 Pallas kernels.

Everything here is built on ``lax.conv_general_dilated`` / plain jnp ops —
no Pallas — and serves as the reference the kernels are allclose-checked
against in ``python/tests/`` (pytest + hypothesis sweeps over shapes).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def conv3x3_valid(x, w, b, *, relu: bool = True):
    """Reference dense 3x3 VALID conv on HWC input.

    x: (H+2, W+2, Cin), w: (3, 3, Cin, Cout), b: (Cout,) -> (H, W, Cout).
    """
    lhs = x[None].transpose(0, 3, 1, 2)          # NCHW
    rhs = w.transpose(3, 2, 0, 1)                # OIHW
    out = jax.lax.conv_general_dilated(
        lhs, rhs, window_strides=(1, 1), padding="VALID"
    )
    out = out[0].transpose(1, 2, 0) + b
    if relu:
        out = jnp.maximum(out, 0.0)
    return out


def block_conv3x3(x_blocks, w, b, *, relu: bool = True):
    """Reference for kernels.sbnet.block_conv3x3 (vmap of dense conv)."""
    return jax.vmap(lambda x: conv3x3_valid(x, w, b, relu=relu))(x_blocks)


def detector_block_stack(x_blocks, params, *, cell: int = 16):
    """Reference for kernels.sbnet.detector_block_stack."""

    def one(x):
        y = conv3x3_valid(x, params["w1"], params["b1"])
        y = conv3x3_valid(y, params["w2"], params["b2"])
        y = conv3x3_valid(y, params["w3"], params["b3"])
        score = (y @ params["head"])[..., 0]
        h, wd = score.shape
        return score.reshape(h // cell, cell, wd // cell, cell).mean(axis=(1, 3))

    return jax.vmap(one)(x_blocks)


def detector_full(frame, params, *, cell: int = 16):
    """Reference full-frame detector: pad 3, 3x conv3x3+ReLU, head, pool.

    frame: (H, W, 3) -> (H/cell, W/cell) objectness cells.
    """
    x = jnp.pad(frame, ((3, 3), (3, 3), (0, 0)))
    y = conv3x3_valid(x, params["w1"], params["b1"])
    y = conv3x3_valid(y, params["w2"], params["b2"])
    y = conv3x3_valid(y, params["w3"], params["b3"])
    score = (y @ params["head"])[..., 0]
    h, wd = score.shape
    return score.reshape(h // cell, cell, wd // cell, cell).mean(axis=(1, 3))
