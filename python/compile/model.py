"""L2 — the JAX detector graph ("TinyDet") lowered AOT for the rust runtime.

The paper's server runs a YOLO detector, optionally RoI-restricted via SBNet
(§4.4).  The substitution (DESIGN.md §3) is a small fixed-weight conv
detector whose cost structure matches the claim under test: the dense
variant convolves the whole frame, the RoI variants gather only the active
blocks (runtime input!) and run the L1 Pallas sparse-block kernel, so
inference cost scales with RoI area.

Weights are *analytic*, derived from the rust renderer's content model:
vehicles are drawn in saturated palette colors while road / lane-marking
pixels are gray-scale, so a color-opponency matched filter (|R-G|, |G-B|,
|B-R| half-differences), spatially smoothed and thresholded, is a faithful
stand-in detector.  Objectness cells above a threshold are decoded into
bounding boxes by the rust post-processor (connected components + NMS).

Geometry contract (mirrored in rust/src/runtime/contract.rs and exported to
artifacts/meta.json — an integration test asserts the two agree):

    frame   192 x 320 x 3 (f32, [0,1])
    block   32 px   -> 6 x 10 = 60 blocks  (SBNet granularity, 2x2 RoI tiles)
    cell    16 px   -> 12 x 20 objectness cells (detector output)
    halo    3 px    (three 3x3 VALID convs)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile.kernels import ref, sbnet

# ---------------------------------------------------------------------------
# Geometry contract
# ---------------------------------------------------------------------------
FRAME_H = 192
FRAME_W = 320
CHANNELS = 3
BLOCK = 32
CELL = 16
HALO = 3
GRID_BH = FRAME_H // BLOCK            # 6
GRID_BW = FRAME_W // BLOCK            # 10
N_BLOCKS = GRID_BH * GRID_BW          # 60
CELLS_H = FRAME_H // CELL             # 12
CELLS_W = FRAME_W // CELL             # 20
CELLS_PER_BLOCK = BLOCK // CELL       # 2

#: Padded-capacity variants compiled AOT; rust picks the smallest >= active.
ROI_CAPACITIES = (8, 16, 32, 60)

#: Objectness threshold used by the rust post-processor (cells with a mean
#: matched-filter response above this contain vehicle pixels).
OBJECTNESS_THRESHOLD = 0.25

C1, C2, C3 = 8, 8, 8


def build_params() -> dict:
    """Analytic TinyDet weights (no training — see module docstring).

    conv1 (3->8, center tap): six color-opponency half-differences
        relu(R-G), relu(G-R), relu(G-B), relu(B-G), relu(B-R), relu(R-B)
      plus brightness-excess and darkness-excess channels (kept as features
      for kernel tests; weighted 0 in the mix so white lane markings and
      dark shadows stay silent).
    conv2 (8->8): per-channel 3x3 box blur (noise suppression).
    conv3 (8->8): channel 0 = relu(1.5 * sum(saturation channels) - 0.15);
      gray road noise (~0.07 expected |diff| sum) lands below the bias and
      is clamped to exactly 0, palette vehicles land ~1.8.
    head (8->1): select channel 0.
    """
    w1 = jnp.zeros((3, 3, CHANNELS, C1))
    b1 = jnp.zeros((C1,))
    pairs = [(0, 1), (1, 0), (1, 2), (2, 1), (2, 0), (0, 2)]
    for c, (pos, neg) in enumerate(pairs):
        w1 = w1.at[1, 1, pos, c].set(1.0)
        w1 = w1.at[1, 1, neg, c].set(-1.0)
    # ch6: brightness excess over 0.55; ch7: darkness below 0.25
    w1 = w1.at[1, 1, :, 6].set(1.0 / 3.0)
    b1 = b1.at[6].set(-0.55)
    w1 = w1.at[1, 1, :, 7].set(-1.0 / 3.0)
    b1 = b1.at[7].set(0.25)

    w2 = jnp.zeros((3, 3, C1, C2))
    for c in range(C1):
        w2 = w2.at[:, :, c, c].set(1.0 / 9.0)
    b2 = jnp.zeros((C2,))

    w3 = jnp.zeros((3, 3, C2, C3))
    for c in range(6):
        w3 = w3.at[1, 1, c, 0].set(1.5)
    b3 = jnp.zeros((C3,)).at[0].set(-0.15)

    head = jnp.zeros((C3, 1)).at[0, 0].set(1.0)
    return {"w1": w1, "b1": b1, "w2": w2, "b2": b2, "w3": w3, "b3": b3,
            "head": head}


# ---------------------------------------------------------------------------
# Variants
# ---------------------------------------------------------------------------
def _conv_im2col(x, w, b):
    """3x3 VALID conv as one im2col matmul.

    §Perf L2 note: on the rust runtime's XLA (xla_extension 0.5.1 CPU)
    this lowers ~1.35x faster than `lax.conv_general_dilated` at our
    shapes (29 ms vs 40 ms per frame, see EXPERIMENTS.md §Perf), so the
    dense serving path uses it.  ref.py keeps the lax.conv formulation as
    the independent oracle.
    """
    h, wd, cin = x.shape
    cout = w.shape[3]
    cols = [x[dy : h - 2 + dy, dx : wd - 2 + dx, :] for dy in range(3) for dx in range(3)]
    patch = jnp.concatenate(cols, axis=-1)
    wm = w.reshape(9 * cin, cout)
    out = patch.reshape(-1, 9 * cin) @ wm + b
    return jnp.maximum(out, 0.0).reshape(h - 2, wd - 2, cout)


def detector_full(frame):
    """Dense full-frame detector ("normal YOLO" path, §4.4).

    frame: (FRAME_H, FRAME_W, 3) -> (CELLS_H, CELLS_W) objectness.
    The unrestricted baseline the RoI variants beat when the RoI area is
    small and lose to near full frame (the SBNet crossover).
    """
    p = build_params()
    x = jnp.pad(frame, ((HALO, HALO), (HALO, HALO), (0, 0)))
    y = _conv_im2col(x, p["w1"], p["b1"])
    y = _conv_im2col(y, p["w2"], p["b2"])
    y = _conv_im2col(y, p["w3"], p["b3"])
    score = (y @ p["head"])[..., 0]
    h, wd = score.shape
    return score.reshape(h // CELL, CELL, wd // CELL, CELL).mean(axis=(1, 3))


def detector_full_ref(frame):
    """Oracle for detector_full (lax.conv formulation from ref.py)."""
    return ref.detector_full(frame, build_params(), cell=CELL)


def gather_blocks(frame, ids):
    """SBNet gather: stack active blocks (with conv halo) from the frame.

    frame: (FRAME_H, FRAME_W, 3); ids: (K,) int32 block ids in [0, N_BLOCKS)
    padded with -1.  Returns (K, BLOCK+2*HALO, BLOCK+2*HALO, 3); padded
    entries gather block 0 and are masked out downstream.
    """
    padded = jnp.pad(frame, ((HALO, HALO), (HALO, HALO), (0, 0)))
    safe = jnp.maximum(ids, 0)
    by = safe // GRID_BW
    bx = safe % GRID_BW
    size = BLOCK + 2 * HALO

    def one(y, x):
        return jax.lax.dynamic_slice(
            padded, (y * BLOCK, x * BLOCK, 0), (size, size, CHANNELS)
        )

    return jax.vmap(one)(by, bx)


def detector_roi(frame, ids):
    """RoI detector: gather -> L1 Pallas block stack -> masked cell scores.

    frame: (FRAME_H, FRAME_W, 3); ids: (K,) int32 (-1 padding).
    Returns (K, CELLS_PER_BLOCK, CELLS_PER_BLOCK) objectness cells; the rust
    runtime scatters them into the (CELLS_H, CELLS_W) grid using the ids it
    supplied.
    """
    blocks = gather_blocks(frame, ids)
    cells = sbnet.detector_block_stack(blocks, build_params(), cell=CELL)
    valid = (ids >= 0)[:, None, None]
    return jnp.where(valid, cells, 0.0)


def detector_roi_ref(frame, ids):
    """Pure-jnp oracle for detector_roi (kernel swapped for ref)."""
    blocks = gather_blocks(frame, ids)
    cells = ref.detector_block_stack(blocks, build_params(), cell=CELL)
    valid = (ids >= 0)[:, None, None]
    return jnp.where(valid, cells, 0.0)
