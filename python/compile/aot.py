"""AOT entry point: lower the L2 detector variants to HLO text artifacts.

Run once at build time (``make artifacts``); the rust runtime loads the
resulting ``artifacts/*.hlo.txt`` via ``HloModuleProto::from_text_file`` and
executes them on the PJRT CPU client.  Python is never on the request path.

HLO **text** (not ``.serialize()``) is the interchange format: jax >= 0.5
emits protos with 64-bit instruction ids that the image's xla_extension
0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Also emits ``meta.json`` describing the geometry contract so the rust side
can assert it matches its compiled-in constants.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple for rust)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_full() -> str:
    spec = jax.ShapeDtypeStruct((model.FRAME_H, model.FRAME_W, 3), jnp.float32)
    fn = lambda f: (model.detector_full(f),)
    return to_hlo_text(jax.jit(fn).lower(spec))


def lower_roi(capacity: int) -> str:
    fspec = jax.ShapeDtypeStruct((model.FRAME_H, model.FRAME_W, 3), jnp.float32)
    ispec = jax.ShapeDtypeStruct((capacity,), jnp.int32)
    fn = lambda f, i: (model.detector_roi(f, i),)
    return to_hlo_text(jax.jit(fn).lower(fspec, ispec))


def meta() -> dict:
    return {
        "frame_h": model.FRAME_H,
        "frame_w": model.FRAME_W,
        "channels": model.CHANNELS,
        "block": model.BLOCK,
        "cell": model.CELL,
        "halo": model.HALO,
        "grid_bh": model.GRID_BH,
        "grid_bw": model.GRID_BW,
        "n_blocks": model.N_BLOCKS,
        "cells_h": model.CELLS_H,
        "cells_w": model.CELLS_W,
        "cells_per_block": model.CELLS_PER_BLOCK,
        "roi_capacities": list(model.ROI_CAPACITIES),
        "objectness_threshold": model.OBJECTNESS_THRESHOLD,
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    path = os.path.join(args.out_dir, "detector_full.hlo.txt")
    text = lower_full()
    with open(path, "w") as f:
        f.write(text)
    print(f"wrote {path} ({len(text)} chars)")

    for k in model.ROI_CAPACITIES:
        path = os.path.join(args.out_dir, f"detector_roi_k{k}.hlo.txt")
        text = lower_roi(k)
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")

    path = os.path.join(args.out_dir, "meta.json")
    with open(path, "w") as f:
        json.dump(meta(), f, indent=2)
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
