"""L1 kernel correctness: Pallas sparse-block kernels vs the pure-jnp oracle.

Hypothesis sweeps block counts / spatial sizes / channel widths; every case
asserts allclose against ref.py.  This is the core correctness signal for
the compute layer the rust runtime executes.
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import ref, sbnet

hypothesis.settings.register_profile(
    "kernels", deadline=None, max_examples=10,
    suppress_health_check=[hypothesis.HealthCheck.too_slow],
)
hypothesis.settings.load_profile("kernels")


def rand(key, shape, lo=-1.0, hi=1.0):
    return jax.random.uniform(key, shape, jnp.float32, lo, hi)


@hypothesis.given(
    k=st.integers(1, 6),
    h=st.sampled_from([4, 8, 16]),
    w=st.sampled_from([4, 8, 16]),
    cin=st.sampled_from([1, 3, 8]),
    cout=st.sampled_from([1, 4, 8]),
    relu=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_block_conv3x3_matches_ref(k, h, w, cin, cout, relu, seed):
    keys = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = rand(keys[0], (k, h + 2, w + 2, cin))
    wgt = rand(keys[1], (3, 3, cin, cout))
    b = rand(keys[2], (cout,))
    got = sbnet.block_conv3x3(x, wgt, b, relu=relu)
    want = ref.block_conv3x3(x, wgt, b, relu=relu)
    assert got.shape == (k, h, w, cout)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@hypothesis.given(
    k=st.integers(1, 4),
    cell=st.sampled_from([4, 8]),
    ncell=st.integers(1, 3),
    c=st.sampled_from([2, 4, 8]),
    seed=st.integers(0, 2**31 - 1),
)
def test_fused_stack_matches_ref(k, cell, ncell, c, seed):
    h = w = cell * ncell
    keys = jax.random.split(jax.random.PRNGKey(seed), 8)
    params = {
        "w1": rand(keys[0], (3, 3, 3, c)),
        "b1": rand(keys[1], (c,)),
        "w2": rand(keys[2], (3, 3, c, c)),
        "b2": rand(keys[3], (c,)),
        "w3": rand(keys[4], (3, 3, c, c)),
        "b3": rand(keys[5], (c,)),
        "head": rand(keys[6], (c, 1)),
    }
    x = rand(keys[7], (k, h + 6, w + 6, 3))
    got = sbnet.detector_block_stack(x, params, cell=cell)
    want = ref.detector_block_stack(x, params, cell=cell)
    assert got.shape == (k, ncell, ncell)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


def test_block_conv_zero_input_zero_output():
    x = jnp.zeros((2, 10, 10, 3))
    w = jnp.ones((3, 3, 3, 4))
    b = jnp.zeros((4,))
    out = sbnet.block_conv3x3(x, w, b)
    assert np.asarray(out).max() == 0.0


def test_block_conv_relu_clamps_negative():
    x = -jnp.ones((1, 6, 6, 2))
    w = jnp.ones((3, 3, 2, 2))
    b = jnp.zeros((2,))
    out = sbnet.block_conv3x3(x, w, b, relu=True)
    assert np.asarray(out).min() == 0.0
    out = sbnet.block_conv3x3(x, w, b, relu=False)
    assert np.asarray(out).max() < 0.0


def test_block_conv_identity_kernel_passthrough():
    """Center-tap identity kernel reproduces the interior of the input."""
    x = jax.random.uniform(jax.random.PRNGKey(0), (3, 9, 9, 2))
    w = jnp.zeros((3, 3, 2, 2)).at[1, 1, 0, 0].set(1.0).at[1, 1, 1, 1].set(1.0)
    b = jnp.zeros((2,))
    out = sbnet.block_conv3x3(x, w, b, relu=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(x[:, 1:-1, 1:-1, :]),
                               rtol=1e-6, atol=1e-6)


def test_block_conv_compute_scales_with_blocks():
    """Each grid step is independent: permuting blocks permutes outputs."""
    key = jax.random.PRNGKey(7)
    x = jax.random.uniform(key, (4, 8, 8, 3))
    w = jax.random.uniform(key, (3, 3, 3, 4))
    b = jnp.zeros((4,))
    out = np.asarray(sbnet.block_conv3x3(x, w, b))
    perm = np.array([2, 0, 3, 1])
    out_p = np.asarray(sbnet.block_conv3x3(x[jnp.asarray(perm)], w, b))
    np.testing.assert_allclose(out_p, out[perm], rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("dtype", [jnp.float32])
def test_block_conv_dtype(dtype):
    x = jnp.ones((1, 4, 4, 1), dtype)
    w = jnp.ones((3, 3, 1, 1), dtype)
    b = jnp.zeros((1,), dtype)
    out = sbnet.block_conv3x3(x, w, b)
    assert out.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(out), 9.0 * np.ones((1, 2, 2, 1)))
