"""L2 model invariants: RoI variant vs dense variant vs oracle.

The key contract for the rust runtime: scattering the RoI variant's per-block
cells into the (CELLS_H, CELLS_W) grid reproduces the dense detector exactly
on the active blocks, for any set of active blocks.
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np

from compile import model

hypothesis.settings.register_profile(
    "model", deadline=None, max_examples=10,
    suppress_health_check=[hypothesis.HealthCheck.too_slow],
)
hypothesis.settings.load_profile("model")


def random_frame(seed: int):
    key = jax.random.PRNGKey(seed)
    return jax.random.uniform(key, (model.FRAME_H, model.FRAME_W, 3))


def synthetic_scene(vehicles):
    """Gray road + saturated colored rectangles (the renderer's content model)."""
    frame = jnp.full((model.FRAME_H, model.FRAME_W, 3), 0.45)
    for (y, x, h, w, color) in vehicles:
        patch = jnp.broadcast_to(jnp.asarray(color), (h, w, 3))
        frame = jax.lax.dynamic_update_slice(frame, patch, (y, x, 0))
    return frame


def pad_ids(ids, capacity):
    ids = list(ids)
    assert len(ids) <= capacity
    return jnp.asarray(ids + [-1] * (capacity - len(ids)), jnp.int32)


def scatter_cells(ids, cells):
    """Rust-side scatter, reimplemented: (K,2,2) -> (CELLS_H, CELLS_W)."""
    grid = np.zeros((model.CELLS_H, model.CELLS_W), np.float32)
    cpb = model.CELLS_PER_BLOCK
    for k, bid in enumerate(np.asarray(ids)):
        if bid < 0:
            continue
        by, bx = divmod(int(bid), model.GRID_BW)
        grid[by * cpb:(by + 1) * cpb, bx * cpb:(bx + 1) * cpb] = cells[k]
    return grid


@hypothesis.given(
    seed=st.integers(0, 2**31 - 1),
    nblocks=st.integers(1, 8),
    cap=st.sampled_from([8, 16]),
)
def test_roi_matches_dense_on_active_blocks(seed, nblocks, cap):
    rng = np.random.RandomState(seed)
    ids = rng.choice(model.N_BLOCKS, size=min(nblocks, cap), replace=False)
    frame = random_frame(seed)
    dense = np.asarray(model.detector_full(frame))
    cells = np.asarray(model.detector_roi(frame, pad_ids(ids, cap)))
    scattered = scatter_cells(pad_ids(ids, cap), cells)
    cpb = model.CELLS_PER_BLOCK
    for bid in ids:
        by, bx = divmod(int(bid), model.GRID_BW)
        np.testing.assert_allclose(
            scattered[by * cpb:(by + 1) * cpb, bx * cpb:(bx + 1) * cpb],
            dense[by * cpb:(by + 1) * cpb, bx * cpb:(bx + 1) * cpb],
            rtol=1e-4, atol=1e-5,
        )


@hypothesis.given(seed=st.integers(0, 2**31 - 1))
def test_roi_kernel_matches_oracle(seed):
    rng = np.random.RandomState(seed)
    ids = pad_ids(rng.choice(model.N_BLOCKS, size=6, replace=False), 8)
    frame = random_frame(seed)
    got = np.asarray(model.detector_roi(frame, ids))
    want = np.asarray(model.detector_roi_ref(frame, ids))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_padded_ids_produce_zero_cells():
    frame = random_frame(3)
    ids = pad_ids([5], 8)
    cells = np.asarray(model.detector_roi(frame, ids))
    assert np.all(cells[1:] == 0.0)


def test_vehicle_lights_up_objectness():
    """A saturated vehicle rectangle drives its cells above the threshold,
    gray road stays at exactly zero (bias clamps sensor noise)."""
    frame = synthetic_scene([(64, 128, 32, 48, (0.85, 0.15, 0.12))])
    obj = np.asarray(model.detector_full(frame))
    cy, cx = 64 // model.CELL + 1, 128 // model.CELL + 1
    assert obj[cy, cx] > model.OBJECTNESS_THRESHOLD
    assert obj[0, 0] == 0.0


def test_gray_content_is_silent():
    """Road, lane markings (white) and shadows (dark gray) score zero."""
    frame = synthetic_scene([
        (32, 32, 16, 64, (1.0, 1.0, 1.0)),   # lane marking
        (96, 96, 24, 24, (0.2, 0.2, 0.2)),   # shadow
    ])
    obj = np.asarray(model.detector_full(frame))
    assert obj.max() == 0.0


def test_black_masked_region_is_silent():
    """Non-RoI regions arrive as black pixels after cropping: no detections."""
    frame = jnp.zeros((model.FRAME_H, model.FRAME_W, 3))
    obj = np.asarray(model.detector_full(frame))
    assert obj.max() == 0.0


def test_noise_robustness():
    """Gaussian sensor noise on gray road stays under the threshold."""
    key = jax.random.PRNGKey(11)
    frame = 0.45 + 0.02 * jax.random.normal(key, (model.FRAME_H, model.FRAME_W, 3))
    obj = np.asarray(model.detector_full(frame))
    assert obj.max() < model.OBJECTNESS_THRESHOLD


def test_geometry_contract():
    assert model.FRAME_H % model.BLOCK == 0
    assert model.FRAME_W % model.BLOCK == 0
    assert model.BLOCK % model.CELL == 0
    assert model.N_BLOCKS == model.GRID_BH * model.GRID_BW
    assert max(model.ROI_CAPACITIES) == model.N_BLOCKS


@hypothesis.given(seed=st.integers(0, 2**31 - 1))
def test_dense_im2col_matches_lax_conv_oracle(seed):
    """The serving dense formulation (im2col, §Perf L2) equals the
    lax.conv oracle."""
    frame = random_frame(seed)
    got = np.asarray(model.detector_full(frame))
    want = np.asarray(model.detector_full_ref(frame))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)
