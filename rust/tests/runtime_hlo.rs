//! Cross-layer validation: the AOT HLO executables (L1 Pallas kernel
//! inside the L2 graph, compiled via PJRT) against the independent
//! pure-rust native detector — closing the loop
//! python-oracle ↔ Pallas ↔ HLO ↔ rust.
//!
//! Requires `make artifacts`; tests are skipped (with a notice) otherwise.

use crossroi::config::Config;
use crossroi::runtime::{decode_objectness, native, Runtime};
use crossroi::sim::Scenario;

fn runtime() -> Option<Runtime> {
    match Runtime::load("artifacts") {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("SKIPPING runtime_hlo tests: {e:#}");
            None
        }
    }
}

fn rendered_frame() -> Vec<f32> {
    let cfg = Config::test_small();
    let sc = Scenario::build(&cfg.scenario);
    let renderer = sc.renderer();
    // pick a frame with vehicles in camera 0 if possible
    let frame = (0..sc.n_frames()).find(|&f| !sc.detections(0, f).is_empty()).unwrap_or(0);
    renderer.render(0, frame).to_f32()
}

#[test]
fn dense_hlo_matches_native_detector() {
    let Some(rt) = runtime() else { return };
    let frame = rendered_frame();
    let hlo = rt.infer_full(&frame).unwrap();
    let nat = native::detect_full(&frame, 192, 320);
    assert_eq!(hlo.len(), nat.len());
    for (i, (a, b)) in hlo.iter().zip(&nat).enumerate() {
        assert!(
            (a - b).abs() < 1e-3,
            "cell {i}: HLO {a} vs native {b}"
        );
    }
}

#[test]
fn roi_hlo_matches_native_on_active_blocks() {
    let Some(rt) = runtime() else { return };
    let frame = rendered_frame();
    for blocks in [vec![0, 7, 23, 42], (0..12).collect::<Vec<i32>>(), vec![59]] {
        let (hlo, k) = rt.infer_roi(&frame, &blocks).unwrap();
        assert!(k >= blocks.len());
        let nat = native::detect_roi(&frame, 192, 320, &blocks, 32, 10);
        for (i, (a, b)) in hlo.iter().zip(&nat).enumerate() {
            assert!(
                (a - b).abs() < 1e-3,
                "blocks {blocks:?} cell {i}: HLO {a} vs native {b}"
            );
        }
    }
}

#[test]
fn roi_capacity_selection() {
    let Some(rt) = runtime() else { return };
    assert_eq!(rt.capacity_for(1), Some(8));
    assert_eq!(rt.capacity_for(8), Some(8));
    assert_eq!(rt.capacity_for(9), Some(16));
    assert_eq!(rt.capacity_for(33), Some(60));
    assert_eq!(rt.capacity_for(60), Some(60));
    assert_eq!(rt.capacity_for(61), None);
}

#[test]
fn empty_roi_is_silent() {
    let Some(rt) = runtime() else { return };
    let frame = rendered_frame();
    let (grid, _) = rt.infer_roi(&frame, &[]).unwrap();
    assert!(grid.iter().all(|&v| v == 0.0));
}

#[test]
fn detector_finds_rendered_vehicles() {
    let Some(rt) = runtime() else { return };
    let cfg = Config::test_small();
    let sc = Scenario::build(&cfg.scenario);
    let renderer = sc.renderer();
    // a frame with at least one big unoccluded vehicle in camera 0
    let mut checked = 0;
    for f in 0..sc.n_frames() {
        let gt: Vec<_> = sc
            .detections(0, f)
            .iter()
            .filter(|d| !d.occluded && d.bbox.area() > 700.0)
            .collect();
        if gt.is_empty() {
            continue;
        }
        let frame = renderer.render(0, f).to_f32();
        let grid = rt.infer_full(&frame).unwrap();
        let dets = decode_objectness(&grid, 12, 20, 16, 0.25);
        for g in &gt {
            let (cx, cy) = g.bbox.center();
            let hit = dets
                .iter()
                .any(|d| d.bbox.iou(&g.bbox) >= 0.1 || d.bbox.contains_point(cx, cy));
            assert!(hit, "frame {f}: vehicle {} at {:?} undetected", g.vehicle_id, g.bbox);
        }
        checked += 1;
        if checked >= 10 {
            break;
        }
    }
    assert!(checked > 0, "no suitable frames found");
}
