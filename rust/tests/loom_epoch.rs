//! Exhaustive model checking of the planner's two shared-state
//! protocols (DESIGN.md §11), gated behind `RUSTFLAGS="--cfg loom"`:
//!
//! ```text
//! RUSTFLAGS="--cfg loom" cargo test --release --test loom_epoch
//! ```
//!
//! Under `--cfg loom`, `util::sync` swaps its `Mutex`/`Condvar` to the
//! in-tree `loom` stub (`third_party/loom-stub`), and `loom::model`
//! explores **every** sequentially-consistent interleaving of the
//! operations below — publish/wait/get races the fixed-seed pipeline
//! tests can only sample.  Without the cfg this file compiles to an
//! empty test binary.
//!
//! The models are deliberately tiny: the explorer is a plain DFS over
//! decision vectors with no partial-order reduction, so each extra
//! visible operation multiplies the interleaving count.

#![cfg(loom)]

use crossroi::util::sync::{EpochTable, StateCell};
use loom::sync::Arc;
use loom::thread;

/// A worker can never observe a torn epoch. An epoch's fields (regions,
/// thresholds, ...) travel inside one `Arc`'d value through one
/// write-once slot, modeled here as a `(usize, usize)` pair whose halves
/// must always match. The model also proves `wait` has no lost-wakeup
/// schedule: a worker arriving at the slot in any order relative to the
/// publisher's check/notify still terminates (a lost wakeup would park
/// the worker forever, which the explorer reports as a deadlock).
#[test]
fn published_epoch_is_never_torn() {
    loom::model(|| {
        let table: Arc<EpochTable<(usize, usize)>> = Arc::new(EpochTable::new(2));
        table.publish(0, Arc::new((0, 0)));
        let t = Arc::clone(&table);
        let publisher = thread::spawn(move || {
            t.publish(1, Arc::new((1, 1)));
        });
        let t = Arc::clone(&table);
        let worker = thread::spawn(move || {
            let p0 = t.wait(0);
            assert_eq!((p0.0, p0.1), (0, 0));
            let p1 = t.wait(1);
            assert_eq!(p1.0, p1.1, "torn epoch: fields from two different plans");
        });
        publisher.join().unwrap();
        worker.join().unwrap();
    });
}

/// First write wins under racing publishers: every observer — both
/// racers and a late reader — resolves epoch 0 to the *same* `Arc`, in
/// every interleaving (the error-path "flood the remaining epochs"
/// publish in `PlanSchedule` relies on exactly this).
#[test]
fn racing_publishers_resolve_to_one_plan() {
    loom::model(|| {
        let table: Arc<EpochTable<usize>> = Arc::new(EpochTable::new(1));
        let t = Arc::clone(&table);
        let a = thread::spawn(move || {
            t.publish(0, Arc::new(7));
            t.wait(0)
        });
        let t = Arc::clone(&table);
        let b = thread::spawn(move || {
            t.publish(0, Arc::new(9));
            t.wait(0)
        });
        let va = a.join().unwrap();
        let vb = b.join().unwrap();
        let vm = table.wait(0);
        assert!(Arc::ptr_eq(&va, &vb), "racing publishers observed different plans");
        assert!(Arc::ptr_eq(&va, &vm), "late reader observed a different plan");
    });
}

/// A commit is never reordered against its baseline update: the
/// `Replanner` pushes each epoch's record in the *same* `commit` closure
/// that advances the drift baseline, modeled here as a version counter
/// committed atomically with the record that depends on it.  An observer
/// snapshotting concurrently (the coordinator's `records()` pull) must
/// never see a record whose baseline update it cannot also see.
#[test]
fn commit_never_observed_without_its_baseline_update() {
    loom::model(|| {
        let cell: Arc<StateCell<(usize, Vec<usize>)>> = Arc::new(StateCell::new((0, Vec::new())));
        let c = Arc::clone(&cell);
        let planner = thread::spawn(move || {
            for k in 1..=2 {
                // snapshot → compute (outside the lock) → commit
                let _baseline = c.snapshot(|st| st.0);
                c.commit(|st| {
                    st.0 = k;
                    st.1.push(k);
                });
            }
        });
        let c = Arc::clone(&cell);
        let observer = thread::spawn(move || {
            let (baseline, records) = c.snapshot(|st| (st.0, st.1.clone()));
            for k in records {
                assert!(baseline >= k, "record {k} observed with baseline {baseline}");
            }
        });
        planner.join().unwrap();
        observer.join().unwrap();
    });
}
