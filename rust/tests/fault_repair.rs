//! Fault injection end-to-end (DESIGN.md §12): a failed camera's worker
//! stops producing segments, the segment-deadline liveness monitor pins
//! when the coordinator can first know, and the next epoch boundary runs
//! a repair re-solve without the dead camera's constraints so surviving
//! peers re-cover the orphaned tiles — within one epoch of detection,
//! byte-identical across planner thread counts, and degrading to a
//! recorded carry-forward (never a planner panic) when a whole component
//! dies.

use std::sync::Arc;

use anyhow::Result;
use crossroi::config::{Config, FaultEvent};
use crossroi::coordinator::{build_plan, run_method_with, Infer, Method, NativeInfer};
use crossroi::offline::{OfflineOptions, Replanner};
use crossroi::pipeline::{
    EncodeCost, EpochPlanner as _, FaultTimeline, Parallelism, PipelineOptions, PlanEpoch,
    ReplanPolicy, ReplanScope,
};
use crossroi::sim::Scenario;
use crossroi::testing::{check, PropConfig};

/// Native reference detector with fixed, deterministic service times.
struct FixedCostInfer;

impl Infer for FixedCostInfer {
    fn infer(&self, frame: &[f32], blocks: Option<&[i32]>) -> Result<(Vec<f32>, f64)> {
        let (grid, _) = NativeInfer.infer(frame, blocks)?;
        let secs = match blocks {
            None => 0.004,
            Some(b) => 0.001 + 0.00004 * b.len() as f64,
        };
        Ok((grid, secs))
    }
}

fn faulted(faults: Vec<FaultEvent>) -> Config {
    let mut cfg = Config::test_small();
    cfg.scenario.faults = faults;
    cfg.scenario.validate().unwrap();
    cfg
}

fn pipe(replan: ReplanPolicy) -> PipelineOptions {
    PipelineOptions {
        parallelism: Parallelism::PerCamera,
        encode_cost: EncodeCost::PerFrame(0.02),
        replan,
        replan_scope: ReplanScope::Component,
        ..PipelineOptions::default()
    }
}

/// The camera owning the most mask tiles in the method's offline plan —
/// the victim whose failure orphans the most coverage — and that count.
fn widest_camera(cfg: &Config, method: &Method) -> (usize, usize) {
    let scenario = Scenario::build(&cfg.scenario);
    let plan = build_plan(&scenario, &cfg.scenario, &cfg.system, method).unwrap();
    (0..scenario.cameras.len())
        .map(|c| (plan.masks.camera_size(c), c))
        .max()
        .map(|(n, c)| (c, n))
        .unwrap()
}

/// Repair-only mode: `--replan never` plus a fault schedule synthesizes
/// the default epoch cadence, so the planner wakes *only* for the repair.
/// `test_small` evaluates 8 one-second segments; with the synthesized
/// cadence of 4 the failure at 1.5 s loses segment 2, detection is that
/// segment's 3.0 s deadline, and the repair lands at the next boundary
/// (epoch 1, segment 4) — one epoch after the boundary current at
/// detection.  The orphaned-tile count is exactly the victim's share of
/// the initial offline plan, because no other epoch ever fired.
#[test]
fn dropout_repair_fires_within_one_epoch_in_repair_only_mode() {
    let base = Config::test_small();
    let (victim, victim_tiles) = widest_camera(&base, &Method::CrossRoi);
    assert!(victim_tiles > 0, "seed plan left every camera without tiles");
    let cfg = faulted(vec![FaultEvent { cam: victim, start_secs: 1.5, end_secs: None }]);
    let scenario = Scenario::build(&cfg.scenario);
    let (r, _) = run_method_with(
        &scenario,
        &cfg.system,
        &FixedCostInfer,
        &Method::CrossRoi,
        None,
        &pipe(ReplanPolicy::Never),
    )
    .unwrap();

    assert_eq!(r.repair_records.len(), 1, "records: {:?}", r.repair_records);
    let rec = &r.repair_records[0];
    assert_eq!(rec.kind, "dropout");
    assert_eq!(rec.cam, victim);
    assert_eq!(rec.epoch, 1, "repair must land at the first boundary after detection");
    assert_eq!(rec.repair_latency_epochs, 1, "repair later than one epoch: {rec:?}");
    assert!((rec.detect_secs - 3.0).abs() < 1e-9, "detect_secs {}", rec.detect_secs);
    assert!((rec.detect_latency - 1.5).abs() < 1e-9, "detect_latency {}", rec.detect_latency);
    assert_eq!(
        rec.orphaned_tiles, victim_tiles,
        "the failure must orphan exactly the victim's initial coverage"
    );

    // repair-only mode computes exactly the event epochs, nothing else
    assert_eq!(r.planner_epochs_computed, 1);
    assert_eq!(r.replan_records.len(), 1);
    assert!(r.replan_records[0].replanned, "the repair epoch must fire");
    assert_eq!(r.replan_records[0].epoch, 1);
}

/// The repair path is a pure function of config + segment grid, so the
/// full serialized report must stay byte-identical across planner pool
/// sizes (`--planner-threads 1|2|8`) under a fault schedule.
#[test]
fn dropout_repair_is_byte_identical_across_planner_threads() {
    let base = Config::test_small();
    let (victim, _) = widest_camera(&base, &Method::CrossRoi);
    let cfg = faulted(vec![FaultEvent { cam: victim, start_secs: 1.5, end_secs: None }]);
    let scenario = Scenario::build(&cfg.scenario);
    let json_of = |threads: usize| -> String {
        let opts = PipelineOptions { planner_threads: threads, ..pipe(ReplanPolicy::Every(2)) };
        let (mut r, _) = run_method_with(
            &scenario,
            &cfg.system,
            &FixedCostInfer,
            &Method::CrossRoi,
            None,
            &opts,
        )
        .unwrap();
        // Every(2) over 8 segments: failure at 1.5 s → segment 2 lost →
        // detection during epoch 1 → repair at epoch 2
        assert_eq!(r.repair_records.len(), 1, "records: {:?}", r.repair_records);
        let rec = &r.repair_records[0];
        assert_eq!((rec.kind, rec.cam, rec.epoch), ("dropout", victim, 2));
        assert_eq!(rec.repair_latency_epochs, 1, "repair later than one epoch: {rec:?}");
        r.zero_wall_clock();
        r.to_json().to_string_pretty(2)
    };
    let reference = json_of(1);
    for threads in [2, 8] {
        assert_eq!(
            reference,
            json_of(threads),
            "--planner-threads {threads} diverged from the single-threaded repair"
        );
    }
}

/// Rejoin is the symmetric path: a camera down for segments 1–3 is
/// re-admitted at the first boundary at/after its return, owns tiles
/// again, and (running Reducto) gets its frame-filter threshold
/// re-derived at the rejoin epoch.
#[test]
fn rejoin_readmits_the_camera_with_a_rederived_threshold() {
    let base = Config::test_small();
    let method = Method::CrossRoiReducto(0.9);
    let (victim, victim_tiles) = widest_camera(&base, &method);
    assert!(victim_tiles > 0, "seed plan left every camera without tiles");
    let cfg = faulted(vec![FaultEvent { cam: victim, start_secs: 1.0, end_secs: Some(4.0) }]);
    let scenario = Scenario::build(&cfg.scenario);
    let (r, _) = run_method_with(
        &scenario,
        &cfg.system,
        &FixedCostInfer,
        &method,
        None,
        &pipe(ReplanPolicy::Every(2)),
    )
    .unwrap();

    let kinds: Vec<&str> = r.repair_records.iter().map(|x| x.kind).collect();
    assert_eq!(kinds, vec!["dropout", "rejoin"], "records: {:?}", r.repair_records);
    let dropout = &r.repair_records[0];
    assert_eq!((dropout.cam, dropout.epoch), (victim, 1));
    assert_eq!(dropout.orphaned_tiles, victim_tiles);
    assert_eq!(dropout.repair_latency_epochs, 1);
    let rejoin = &r.repair_records[1];
    assert_eq!((rejoin.cam, rejoin.epoch), (victim, 2));
    assert_eq!(rejoin.orphaned_tiles, 0, "rejoins orphan nothing");
    assert_eq!(
        rejoin.repair_latency_epochs, 0,
        "re-admission boundary is the rejoin epoch itself"
    );
    assert!(
        rejoin.recovered_tiles > 0,
        "the re-admitted camera must own tiles again: {rejoin:?}"
    );
    // the re-plans around the outage re-derive the victim's Reducto
    // threshold (its regions change at both the repair and rejoin epoch)
    assert!(r.replan_reducto_rederived > 0, "no threshold was re-derived");
}

/// Randomized fault schedules: every materialised dropout/rejoin
/// obligation gets exactly one repair record at the epoch an
/// independently-resolved timeline predicts, every repair lands within
/// one epoch of detection, the planner thread never panics, and the
/// run's detections stay at the level of the fault-free run against the
/// (equally faulted) dense baseline.
#[test]
fn prop_random_fault_schedules_repair_within_one_epoch() {
    let base = Config::test_small();
    let scenario0 = Scenario::build(&base.scenario);
    let plan = build_plan(&scenario0, &base.scenario, &base.system, &Method::CrossRoi).unwrap();
    // mirror the coordinator's peer resolution: offline shard members,
    // falling back to one fleet-wide component for unsharded plans
    let components: Vec<Vec<usize>> = if plan.report.shards.is_empty() {
        vec![(0..scenario0.cameras.len()).collect()]
    } else {
        plan.report.shards.iter().map(|s| s.cameras.clone()).collect()
    };
    let eval_start = scenario0.eval_range().start;
    let n_cams = scenario0.cameras.len();

    // fault-free reference accuracy against the dense baseline
    let opts = pipe(ReplanPolicy::Every(2));
    let (_, truth0) = run_method_with(
        &scenario0,
        &base.system,
        &FixedCostInfer,
        &Method::Baseline,
        None,
        &opts,
    )
    .unwrap();
    let (clean, _) = run_method_with(
        &scenario0,
        &base.system,
        &FixedCostInfer,
        &Method::CrossRoi,
        Some(truth0.as_slice()),
        &opts,
    )
    .unwrap();

    check(&PropConfig { cases: 4, seed: 0xFA17 }, "fault-repair", |rng| {
        // 1–2 events on quarter-second marks: times divide the 1 s
        // segment grid exactly, so the mirror below is float-exact
        let n_faults = 1 + rng.below(2);
        let mut faults = Vec::new();
        for _ in 0..n_faults {
            let start_secs = 0.5 + 0.25 * rng.below(23) as f64; // 0.5 .. 6.0
            let end_secs =
                rng.chance(0.5).then(|| start_secs + 1.0 + 0.5 * rng.below(6) as f64);
            faults.push(FaultEvent { cam: rng.below(n_cams), start_secs, end_secs });
        }
        let mut cfg = base.clone();
        cfg.scenario.faults = faults.clone();
        cfg.scenario.validate().map_err(|e| e.to_string())?;
        let scenario = Scenario::build(&cfg.scenario);
        let (_, truth) = run_method_with(
            &scenario,
            &cfg.system,
            &FixedCostInfer,
            &Method::Baseline,
            None,
            &opts,
        )
        .map_err(|e| format!("baseline failed under {faults:?}: {e}"))?;
        let (r, _) = run_method_with(
            &scenario,
            &cfg.system,
            &FixedCostInfer,
            &Method::CrossRoi,
            Some(truth.as_slice()),
            &opts,
        )
        .map_err(|e| format!("pipeline failed under {faults:?}: {e}"))?;

        // one record per obligation, at the predicted epoch
        let timeline =
            FaultTimeline::new(&faults, n_cams, 8, 5, 5.0, 2, eval_start, &components);
        let mut expected: Vec<(usize, &str, usize)> = Vec::new();
        for s in timeline.schedules() {
            if let Some(k) = s.repair_epoch {
                expected.push((s.cam, "dropout", k));
            }
            if let Some(k) = s.rejoin_epoch {
                expected.push((s.cam, "rejoin", k));
            }
        }
        expected.sort_unstable();
        let mut got: Vec<(usize, &str, usize)> =
            r.repair_records.iter().map(|x| (x.cam, x.kind, x.epoch)).collect();
        got.sort_unstable();
        if got != expected {
            return Err(format!("repair records {got:?} != expected {expected:?} for {faults:?}"));
        }
        for rec in &r.repair_records {
            if rec.kind == "dropout" && rec.repair_latency_epochs > 1 {
                return Err(format!("repair later than one epoch after detection: {rec:?}"));
            }
        }
        // faults degrade the affected cameras to full-frame until repair,
        // so detections on covered tiles never drop below the (equally
        // faulted) dense baseline's — accuracy stays at the fault-free
        // level
        if r.accuracy < clean.accuracy - 0.05 {
            return Err(format!(
                "accuracy {} fell below the fault-free reference {} under {faults:?}",
                r.accuracy, clean.accuracy
            ));
        }
        Ok(())
    });
}

/// Regression for the planner-thread panic path: when *every* camera of
/// a component dies, the repair window holds zero constraints for the
/// fired component.  The epoch must degrade to a recorded carry-forward
/// — dead tiles cleared, survivors untouched, the orphaned coverage
/// recorded as uncovered — instead of panicking the planner thread.
#[test]
fn whole_component_outage_degrades_to_recorded_carry_without_panicking() {
    let mut cfg = Config::test_small();
    cfg.scenario.n_cameras = 4;
    cfg.scenario.n_intersections = 2;
    cfg.scenario.profile_secs = 8.0;
    cfg.scenario.eval_secs = 8.0;
    cfg.scenario.faults =
        (4..8).map(|cam| FaultEvent { cam, start_secs: 0.0, end_secs: None }).collect();
    cfg.scenario.validate().unwrap();
    let scenario = Scenario::build(&cfg.scenario);
    let method = Method::CrossRoi;
    let plan = build_plan(&scenario, &cfg.scenario, &cfg.system, &method).unwrap();
    let components: Vec<Vec<usize>> =
        plan.report.shards.iter().map(|s| s.cameras.clone()).collect();
    assert_eq!(
        components,
        vec![vec![0, 1, 2, 3], vec![4, 5, 6, 7]],
        "the fleet must shard into its intersections"
    );
    let timeline = Arc::new(FaultTimeline::new(
        &cfg.scenario.faults,
        8,
        8,
        5,
        5.0,
        2,
        scenario.eval_range().start,
        &components,
    ));
    let rp = Replanner::new(
        &scenario,
        &cfg.system,
        &method,
        OfflineOptions::default(),
        ReplanPolicy::Never,
        ReplanScope::Component,
        5,
        &plan,
        60,
    )
    .with_faults(Arc::clone(&timeline));
    let epoch0 = Arc::new(PlanEpoch::initial(
        plan.groups.clone(),
        plan.blocks.clone(),
        vec![true; 8],
        None,
        plan.masks.total_size(),
    ));

    // intersection 1 dies at t = 0: segment 0 is lost, detection at its
    // deadline, repair at epoch 1 — whose window holds no constraint the
    // dead component could re-solve against
    let next = rp.plan_epoch(1, 2, &epoch0).expect("repair epoch must not error out");
    for cam in 4..8 {
        assert!(next.groups[cam].is_empty(), "dead cam {cam} kept regions");
    }
    for cam in 0..4 {
        assert_eq!(next.groups[cam], epoch0.groups[cam], "survivor cam {cam} plan changed");
        assert_eq!(next.cam_epoch[cam], 0, "survivor cam {cam} must keep its epoch stamp");
    }
    assert_eq!(
        next.mask_tiles,
        (0..4).map(|c| plan.masks.camera_size(c)).sum::<usize>(),
        "the new plan must be exactly the survivors' carried tiles"
    );

    let repairs = rp.repair_records();
    assert_eq!(repairs.len(), 4, "one dropout record per dead camera: {repairs:?}");
    for (rec, cam) in repairs.iter().zip(4..8) {
        assert_eq!((rec.kind, rec.cam, rec.epoch), ("dropout", cam, 1));
        assert_eq!(rec.repair_latency_epochs, 1);
        assert_eq!(rec.orphaned_tiles, plan.masks.camera_size(cam));
        assert_eq!(rec.recovered_tiles, 0, "no live camera can see the dead intersection");
        assert!(
            rec.uncovered_constraints > 0,
            "the dead intersection's coverage must be recorded as uncovered: {rec:?}"
        );
    }

    // the next boundary owes nothing: repair-only mode carries it by
    // pointer without waking the pool again
    let same = rp.plan_epoch(2, 4, &next).unwrap();
    assert!(Arc::ptr_eq(&same, &next), "quiet boundary must carry by pointer");
    assert_eq!(rp.pool_stats().epochs_computed, 1);
    assert_eq!(rp.records().len(), 1);
}
