//! Integration: the full offline phase over a scenario, plus property
//! tests on the optimizer/grouping invariants (the coordinator-side
//! guarantees CrossRoI's correctness rests on).

use crossroi::association::table::AssociationTable;
use crossroi::association::tiles::Tiling;
use crossroi::config::Config;
use crossroi::coordinator::{build_plan, Method};
use crossroi::reid::error_model::{ErrorModelParams, RawReid};
use crossroi::reid::records::{RawDetection, ReidStream};
use crossroi::roi::setcover::{self, SolverParams};
use crossroi::sim::Scenario;
use crossroi::testing::{check, gen, PropConfig};
use crossroi::util::geometry::Rect;

/// The paper's central guarantee (Eq. 2): after optimization, every
/// object occurrence in the *filtered* stream keeps at least one
/// appearance region fully inside the masks.
#[test]
fn masks_cover_every_filtered_occurrence() {
    let cfg = Config::test_small();
    let scenario = Scenario::build(&cfg.scenario);
    let plan = build_plan(&scenario, &cfg.scenario, &cfg.system, &Method::CrossRoi).unwrap();
    // rebuild the filtered stream exactly as build_plan does
    let raw =
        RawReid::generate(&scenario, scenario.profile_range(), &ErrorModelParams::default());
    let filters = crossroi::filters::TandemFilters::default();
    let (stream, _) = filters.apply(&raw);
    let tiling = Tiling::new(5, 320, 192, cfg.scenario.tile_px);
    let table = AssociationTable::build(&stream, &tiling);
    for c in &table.constraints {
        if c.regions.is_empty() {
            continue;
        }
        let satisfied = c.regions.iter().any(|r| {
            r.iter().all(|&t| {
                let (cam, tx, ty) = tiling.tile_pos(t);
                plan.masks.tiles[cam].contains(&(tx, ty))
            })
        });
        assert!(satisfied, "constraint unsatisfied by the plan masks: {c:?}");
    }
}

/// Property: for random synthetic association tables, the greedy solution
/// is always valid and never better than the exact optimum; on small
/// instances it is within one tile of optimal.
#[test]
fn prop_setcover_valid_and_near_optimal() {
    check(&PropConfig { cases: 40, seed: 0xC0FFEE }, "setcover", |rng| {
        let tiling = Tiling::new(2, 320, 192, 16);
        let n_constraints = 1 + rng.below(5);
        let mut records = Vec::new();
        let mut id = 0u32;
        for frame in 0..n_constraints {
            // each constraint: an object seen in 1-2 cameras
            let n_regions = 1 + rng.below(2);
            for cam in 0..n_regions {
                records.push(RawDetection {
                    cam,
                    frame,
                    bbox: gen::bbox_in_frame(rng, 320.0, 192.0),
                    raw_id: id,
                    true_id: id,
                });
            }
            id += 1;
        }
        let stream = ReidStream::new(2, n_constraints, records);
        let table = AssociationTable::build(&stream, &tiling);
        let greedy = setcover::solve(&table, &SolverParams::default());
        // validity
        for c in &table.constraints {
            let ok = c
                .regions
                .iter()
                .any(|r| r.iter().all(|t| greedy.tiles.contains(t)));
            if !ok {
                return Err(format!("greedy left constraint unsatisfied: {c:?}"));
            }
        }
        if table.n_constraints() <= 6 {
            let exact = setcover::solve_exact(&table, 8);
            if greedy.size() < exact.size() {
                return Err(format!(
                    "greedy {} beat 'exact' {} — exact solver is broken",
                    greedy.size(),
                    exact.size()
                ));
            }
        }
        Ok(())
    });
}

/// Property: tile groups always partition the mask exactly.
#[test]
fn prop_tilegroup_partitions_mask() {
    check(&PropConfig { cases: 60, seed: 0x717E }, "tilegroup", |rng| {
        let tiling = Tiling::new(1, 320, 192, 16);
        let n = 1 + rng.below(60);
        let mut set = std::collections::HashSet::new();
        for _ in 0..n {
            set.insert((rng.below(20) as u32, rng.below(12) as u32));
        }
        let masks = crossroi::roi::masks::RoiMasks { tiling, tiles: vec![set.clone()] };
        let groups = crossroi::tilegroup::group_camera(&masks, 0);
        let mut covered = std::collections::HashSet::new();
        for g in &groups {
            for ty in g.y / 16..(g.y + g.h) / 16 {
                for tx in g.x / 16..(g.x + g.w) / 16 {
                    if !set.contains(&(tx, ty)) {
                        return Err(format!("group {g:?} covers non-mask tile ({tx},{ty})"));
                    }
                    if !covered.insert((tx, ty)) {
                        return Err(format!("tile ({tx},{ty}) covered twice"));
                    }
                }
            }
        }
        if covered != set {
            return Err(format!("{} of {} tiles covered", covered.len(), set.len()));
        }
        Ok(())
    });
}

/// Property: the association table groups same-id same-frame records into
/// single multi-region constraints, regardless of camera order.
#[test]
fn prop_association_is_order_invariant() {
    check(&PropConfig { cases: 40, seed: 0xA550 }, "association", |rng| {
        let tiling = Tiling::new(3, 320, 192, 16);
        let mut records = Vec::new();
        for f in 0..3 {
            for cam in 0..3 {
                if rng.chance(0.7) {
                    records.push(RawDetection {
                        cam,
                        frame: f,
                        bbox: gen::bbox_in_frame(rng, 320.0, 192.0),
                        raw_id: (f % 2) as u32,
                        true_id: (f % 2) as u32,
                    });
                }
            }
        }
        let a = AssociationTable::build(&ReidStream::new(3, 3, records.clone()), &tiling);
        let mut rev = records.clone();
        rev.reverse();
        let b = AssociationTable::build(&ReidStream::new(3, 3, rev), &tiling);
        if a.constraints != b.constraints {
            return Err("constraint set depends on record order".into());
        }
        Ok(())
    });
}

/// Failure injection: a camera whose ReID stream is empty (dead camera
/// during profiling) must yield an empty mask for it, not a crash.
#[test]
fn dead_camera_during_profile() {
    let cfg = Config::test_small();
    let scenario = Scenario::build(&cfg.scenario);
    let raw =
        RawReid::generate(&scenario, scenario.profile_range(), &ErrorModelParams::default());
    // drop every record of camera 2
    let stream = raw.filtered(|d| d.cam != 2);
    let tiling = Tiling::new(5, 320, 192, 16);
    let table = AssociationTable::build(&stream, &tiling);
    let sol = setcover::solve(&table, &SolverParams::default());
    let masks = crossroi::roi::masks::RoiMasks::from_solution(&tiling, &sol.tiles);
    assert_eq!(masks.camera_size(2), 0, "dead camera got mask tiles");
    // other cameras still covered
    assert!(masks.total_size() > 0);
}

/// Mixed per-camera resolutions (`testing::fleet`): a fleet whose odd
/// cameras run a quarter-size active frame plans through
/// `build_plan_from_stream` on a heterogeneous `Tiling`, keeps every
/// mask tile and codec region inside its camera's own frame, still
/// satisfies Eq. 2 on the mixed stream, and replays online — the block
/// codec encodes each camera at its native resolution through the
/// plan's regions.
#[test]
fn heterogeneous_fleet_plans_and_replays_at_native_resolutions() {
    let cfg = Config::test_small();
    let (stream, tiling) = crossroi::testing::fleet::heterogeneous_fleet(&cfg, 7);
    assert_eq!(stream.n_cameras, 4);
    assert_ne!(tiling.cam_frame(0), tiling.cam_frame(1), "fleet must actually be mixed");
    let plan = crossroi::offline::build_plan_from_stream(
        &stream,
        &tiling,
        &cfg.system,
        &Method::CrossRoi,
        &crossroi::offline::OfflineOptions::default(),
    )
    .unwrap();
    assert!(plan.masks.total_size() > 0);

    // every mask tile and codec region stays inside its camera's frame —
    // the downscaled cameras must never be planned against the envelope
    for cam in 0..stream.n_cameras {
        let (w, h) = tiling.cam_frame(cam);
        for &(tx, ty) in &plan.masks.tiles[cam] {
            assert!(
                tx * tiling.tile_px < w && ty * tiling.tile_px < h,
                "cam {cam} tile ({tx},{ty}) outside its {w}x{h} frame"
            );
        }
        for r in &plan.groups[cam] {
            assert!(
                r.x + r.w <= w && r.y + r.h <= h,
                "cam {cam} region {r:?} outside its {w}x{h} frame"
            );
        }
    }

    // Eq. 2 still holds on the mixed-resolution stream (rebuilt exactly
    // as build_plan_from_stream filters it)
    let filters = crossroi::filters::TandemFilters::default();
    let (filtered, _) = filters.apply(&stream);
    let table = AssociationTable::build(&filtered, &tiling);
    assert!(table.n_constraints() > 0);
    for c in &table.constraints {
        if c.regions.is_empty() {
            continue;
        }
        let satisfied = c.regions.iter().any(|r| {
            r.iter().all(|&t| {
                let (cam, tx, ty) = tiling.tile_pos(t);
                plan.masks.tiles[cam].contains(&(tx, ty))
            })
        });
        assert!(satisfied, "constraint unsatisfied by the heterogeneous plan: {c:?}");
    }

    // online replay: a short synthetic segment per camera at its native
    // resolution, encoded through the plan's codec regions (plus the
    // full-frame fallback region every degraded camera streams)
    for cam in 0..stream.n_cameras {
        let (w, h) = tiling.cam_frame(cam);
        let frames: Vec<crossroi::sim::Frame> = (0..3u32)
            .map(|f| {
                let mut frame = crossroi::sim::Frame::new(w, h);
                for (i, px) in frame.data.iter_mut().enumerate() {
                    *px = ((i as u32).wrapping_mul(31).wrapping_add(f * 97)) as u8;
                }
                frame
            })
            .collect();
        let full = crossroi::util::geometry::IRect::new(0, 0, w, h);
        for region in plan.groups[cam].iter().chain(std::iter::once(&full)) {
            let mut rs = crossroi::codec::RegionStream::new(*region, 28.0);
            let bits: u64 = frames.iter().map(|fr| rs.encode_frame(fr).bits).sum();
            assert!(bits > 0, "cam {cam} region {region:?} encoded to nothing");
        }
    }
}

#[test]
fn rebuilding_plan_is_deterministic() {
    let cfg = Config::test_small();
    let scenario = Scenario::build(&cfg.scenario);
    let a = build_plan(&scenario, &cfg.scenario, &cfg.system, &Method::CrossRoi).unwrap();
    let b = build_plan(&scenario, &cfg.scenario, &cfg.system, &Method::CrossRoi).unwrap();
    assert_eq!(a.masks.total_size(), b.masks.total_size());
    for cam in 0..5 {
        assert_eq!(a.masks.tiles[cam], b.masks.tiles[cam]);
        assert_eq!(a.groups[cam], b.groups[cam]);
        assert_eq!(a.blocks[cam], b.blocks[cam]);
    }
}

/// Bboxes in appearance regions round-trip: every record's bbox is fully
/// covered by the union of its appearance-region tiles.
#[test]
fn prop_appearance_region_covers_bbox() {
    check(&PropConfig { cases: 100, seed: 0xBB0C }, "appearance", |rng| {
        let tiling = Tiling::new(1, 320, 192, 16);
        let bbox = gen::bbox_in_frame(rng, 320.0, 192.0);
        let region = tiling.appearance_region(0, &bbox);
        if region.is_empty() {
            return Err(format!("empty region for {bbox:?}"));
        }
        // the union of tile rects must contain the bbox
        let mut cover = Rect::new(0.0, 0.0, 0.0, 0.0);
        for &t in &region {
            cover = cover.union_bounds(&tiling.tile_rect(t).to_rect());
        }
        if bbox.intersect(&cover).area() + 1e-6 < bbox.area() {
            return Err(format!("region does not cover bbox: {bbox:?} vs {cover:?}"));
        }
        Ok(())
    });
}
