//! Integration: the online phase (encode → DES → inference → query) over
//! a small scenario with the native detector, checking the paper's
//! directional claims hold end-to-end, plus DES/queueing properties.

use crossroi::config::Config;
use crossroi::coordinator::{
    baseline_reference, run_ablation, run_method, Method, NativeInfer,
};
use crossroi::sim::Scenario;
use crossroi::testing::{check, PropConfig};

fn small() -> (Scenario, Config) {
    let mut cfg = Config::test_small();
    cfg.scenario.profile_secs = 15.0;
    cfg.scenario.eval_secs = 10.0;
    (Scenario::build(&cfg.scenario), cfg)
}

#[test]
fn ablation_ordering_holds() {
    let (scenario, cfg) = small();
    let methods = [
        Method::Baseline,
        Method::NoFilters,
        Method::NoMerging,
        Method::NoRoiInf,
        Method::CrossRoi,
    ];
    let reports = run_ablation(&scenario, &cfg.system, &NativeInfer, &methods).unwrap();
    let get = |n: &str| reports.iter().find(|r| r.method == n).unwrap();
    let base = get("Baseline");
    let cross = get("CrossRoI");
    // paper's headline directions
    assert!(
        cross.network_mbps_total < base.network_mbps_total,
        "CrossRoI must use less network: {} vs {}",
        cross.network_mbps_total,
        base.network_mbps_total
    );
    assert!(
        cross.network_mbps_total <= get("No-Merging").network_mbps_total,
        "tile grouping must not increase network"
    );
    assert!(
        cross.network_mbps_total <= get("No-Filters").network_mbps_total * 1.05,
        "filters should shrink (or at least not inflate) network"
    );
    assert!(cross.latency.total() < base.latency.total(), "CrossRoI must cut latency");
    assert!(cross.accuracy > 0.9, "CrossRoI accuracy too low: {}", cross.accuracy);
    assert_eq!(base.accuracy, 1.0, "Baseline must be the reference");
    // masks really shrank
    assert!(cross.mask_coverage < 0.8);
}

#[test]
fn reducto_integration_dominates_plain_reducto() {
    let (scenario, cfg) = small();
    let (reference, _) = baseline_reference(&scenario, &cfg.system, &NativeInfer).unwrap();
    let target = 0.85;
    let red = run_method(
        &scenario, &cfg.system, &NativeInfer, &Method::Reducto(target), Some(&reference),
    )
    .unwrap();
    let cr = run_method(
        &scenario, &cfg.system, &NativeInfer, &Method::CrossRoiReducto(target), Some(&reference),
    )
    .unwrap();
    assert!(
        cr.network_mbps_total < red.network_mbps_total,
        "CrossRoI-Reducto must use less network: {} vs {}",
        cr.network_mbps_total,
        red.network_mbps_total
    );
    // both meet a loosened version of the target (short window => noisy)
    assert!(red.accuracy > target - 0.1, "Reducto accuracy {}", red.accuracy);
    assert!(cr.accuracy > target - 0.1, "CrossRoI-Reducto accuracy {}", cr.accuracy);
}

#[test]
fn reducto_reduces_frames_at_lower_targets() {
    let (scenario, cfg) = small();
    let (reference, _) = baseline_reference(&scenario, &cfg.system, &NativeInfer).unwrap();
    let strict = run_method(
        &scenario, &cfg.system, &NativeInfer, &Method::Reducto(1.0), Some(&reference),
    )
    .unwrap();
    let loose = run_method(
        &scenario, &cfg.system, &NativeInfer, &Method::Reducto(0.85), Some(&reference),
    )
    .unwrap();
    assert_eq!(strict.frames_reduced, 0, "target 1.0 must keep every frame");
    assert!(
        loose.frames_reduced >= strict.frames_reduced,
        "lower target should drop at least as many frames"
    );
}

#[test]
fn segment_length_tradeoff() {
    let (scenario, cfg) = small();
    let mut short_sys = cfg.system.clone();
    short_sys.segment_secs = 0.4;
    let mut long_sys = cfg.system.clone();
    long_sys.segment_secs = 4.0;
    let short =
        run_method(&scenario, &short_sys, &NativeInfer, &Method::CrossRoi, None).unwrap();
    let long = run_method(&scenario, &long_sys, &NativeInfer, &Method::CrossRoi, None).unwrap();
    // Fig. 11: longer segments compress better but queue longer at cameras
    assert!(
        long.network_mbps_total < short.network_mbps_total,
        "long segments should compress better: {} vs {}",
        long.network_mbps_total,
        short.network_mbps_total
    );
    assert!(
        long.latency.camera > short.latency.camera,
        "long segments should queue longer: {} vs {}",
        long.latency.camera,
        short.latency.camera
    );
}

#[test]
fn narrower_link_increases_latency_only() {
    let (scenario, cfg) = small();
    let mut narrow = cfg.system.clone();
    narrow.bandwidth_mbps = cfg.system.bandwidth_mbps / 3.0;
    let wide = run_method(&scenario, &cfg.system, &NativeInfer, &Method::CrossRoi, None).unwrap();
    let slow = run_method(&scenario, &narrow, &NativeInfer, &Method::CrossRoi, None).unwrap();
    assert!((wide.bytes_total as i64 - slow.bytes_total as i64).abs() < 16, "bytes must not depend on link");
    assert!(
        slow.latency.network > wide.latency.network,
        "narrow link must raise network latency: {} vs {}",
        slow.latency.network,
        wide.latency.network
    );
}

#[test]
fn sixteen_camera_fleet_contends_on_the_shared_link() {
    // the online phase at 8–16 cameras (the offline side has swept this
    // range since `benches/offline_scaling.rs`): the DES replay must
    // stay consistent at fleet scale, and quadrupling the cameras on the
    // same shared uplink must show up as link contention
    let mut cfg = Config::test_small();
    cfg.scenario.profile_secs = 8.0;
    cfg.scenario.eval_secs = 6.0;
    let run = |n: usize| {
        let mut c = cfg.clone();
        c.scenario.n_cameras = n;
        c.scenario.validate().unwrap();
        let sc = Scenario::build(&c.scenario);
        run_method(&sc, &c.system, &NativeInfer, &Method::CrossRoi, None).unwrap()
    };
    let small = run(4);
    let big = run(16);
    let eval_frames = (cfg.scenario.eval_secs * cfg.scenario.fps).round() as usize;
    assert_eq!(big.network_mbps_per_cam.len(), 16);
    assert_eq!(big.frames_total, 16 * eval_frames);
    assert!(big.bytes_total > small.bytes_total, "more cameras must stream more bytes");
    assert!(
        big.network_mbps_total > small.network_mbps_total,
        "aggregate demand must grow with the fleet: {} vs {}",
        big.network_mbps_total,
        small.network_mbps_total
    );
    // same 1.8 Mbps shared link, ~4x the demand: queueing must push the
    // network share of latency up
    assert!(
        big.latency.network > small.latency.network,
        "16 cameras must queue longer on the shared link: {} vs {}",
        big.latency.network,
        small.latency.network
    );
    // the decomposition stays consistent at fleet scale
    assert!(big.latency.camera >= 0.0 && big.latency.server > 0.0);
    assert!(big.latency_p95 >= 0.0);
    assert!((0.0..=1.0).contains(&big.accuracy), "accuracy out of range: {}", big.accuracy);
}

/// Property: the DES latency decomposition is consistent — every
/// component non-negative and their mean sum equals the mean total.
#[test]
fn prop_latency_decomposition_consistent() {
    check(&PropConfig { cases: 4, seed: 0xDE5 }, "latency", |rng| {
        let mut cfg = Config::test_small();
        cfg.scenario.profile_secs = 8.0;
        cfg.scenario.eval_secs = 6.0;
        cfg.scenario.seed = rng.next_u64();
        cfg.system.segment_secs = [0.4, 1.0, 2.0][rng.below(3)];
        let scenario = Scenario::build(&cfg.scenario);
        let r = run_method(&scenario, &cfg.system, &NativeInfer, &Method::CrossRoi, None)
            .map_err(|e| e.to_string())?;
        if r.latency.camera < 0.0 || r.latency.network < 0.0 || r.latency.server < 0.0 {
            return Err(format!("negative latency component: {:?}", r.latency));
        }
        if r.latency.total() <= 0.0 {
            return Err("zero total latency".into());
        }
        if !(r.latency_p95 + 1e-9 >= 0.0) {
            return Err("bad p95".into());
        }
        Ok(())
    });
}
