//! Byte-identity of the SIMD codec kernels against their scalar
//! references (DESIGN.md §9): on a host with AVX2 the dispatched path and
//! the `*_scalar` reference must agree bit-for-bit on random inputs —
//! including non-lane-multiple widths and odd-offset 25%-RoI rects — and
//! a whole segment encode must be invariant under the forced backend.
//! On hosts without AVX2 the dispatched comparisons are vacuous (both
//! sides run scalar) and the forced-backend test skips.

use crossroi::codec::{dct, entropy, motion, KernelBackend, SegmentEncoder};
use crossroi::codec::{avx2_supported, set_backend};
use crossroi::config::Config;
use crossroi::sim::render::Frame;
use crossroi::sim::Scenario;
use crossroi::util::geometry::IRect;
use crossroi::util::rng::Rng;

fn rand_f32(rng: &mut Rng, amp: f32) -> f32 {
    // uniform in [-amp, amp] with codec-realistic magnitudes
    ((rng.next_u64() >> 40) as f32 / (1u64 << 24) as f32 * 2.0 - 1.0) * amp
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|f| f.to_bits()).collect()
}

#[test]
fn dct_roundtrip_identity_on_random_blocks() {
    let mut rng = Rng::new(0xD07);
    for case in 0..200 {
        let mut src = [0.0f32; 64];
        for v in src.iter_mut() {
            *v = rand_f32(&mut rng, 255.0);
        }
        let mut a = src;
        let mut b = src;
        dct::forward(&mut a);
        dct::forward_scalar(&mut b);
        assert_eq!(bits(&a), bits(&b), "forward diverged on case {case}");
        for qp in [1.0f32, 6.0, 14.5] {
            let qa = dct::quantize(&a, qp);
            let qb = dct::quantize_scalar(&b, qp);
            assert_eq!(qa, qb, "quantize diverged on case {case} qp {qp}");
            let mut da = dct::dequantize(&qa, qp);
            let mut db = dct::dequantize_scalar(&qb, qp);
            assert_eq!(bits(&da), bits(&db), "dequantize diverged on case {case} qp {qp}");
            dct::inverse(&mut da);
            dct::inverse_scalar(&mut db);
            assert_eq!(bits(&da), bits(&db), "inverse diverged on case {case} qp {qp}");
        }
    }
}

#[test]
fn sad_identity_on_random_planes_with_odd_strides() {
    let mut rng = Rng::new(0x5AD);
    // widths deliberately not multiples of the 8-lane width
    for (w, h) in [(37usize, 25usize), (41, 33), (64, 48)] {
        let cur: Vec<f32> = (0..w * h).map(|_| rand_f32(&mut rng, 255.0)).collect();
        let reference: Vec<f32> = (0..w * h).map(|_| rand_f32(&mut rng, 255.0)).collect();
        let pc = motion::Plane { w, h, data: &cur };
        let pr = motion::Plane { w, h, data: &reference };
        for bx in [0usize, 5, w - 16] {
            for by in [0usize, 3, h - 16] {
                for (dx, dy) in [(0i32, 0i32), (2, -1), (-3, 2), (15, 0)] {
                    for early in [f32::INFINITY, 2000.0, 100.0, 0.0] {
                        let a = motion::sad(&pc, &pr, bx, by, dx, dy, early);
                        let b = motion::sad_scalar(&pc, &pr, bx, by, dx, dy, early);
                        match (a, b) {
                            (None, None) => {}
                            (Some(a), Some(b)) => assert_eq!(
                                a.to_bits(),
                                b.to_bits(),
                                "w={w} bx={bx} by={by} d=({dx},{dy}) early={early}"
                            ),
                            _ => panic!("bounds decision diverged"),
                        }
                    }
                }
            }
        }
    }
}

/// Intra-activity scan kernels (the encoder's `mb_mean`/`mb_sad_to`
/// mode-decision inputs): the dispatched path and the scalar reference
/// must agree bit-for-bit over odd strides and odd macroblock offsets.
#[test]
fn intra_scan_identity_on_random_planes() {
    use crossroi::codec::kernels;
    let mut rng = Rng::new(0x1A7);
    for (w, h) in [(37usize, 25usize), (48, 31), (320, 192)] {
        let plane: Vec<f32> = (0..w * h).map(|_| rand_f32(&mut rng, 255.0)).collect();
        for bx in [0usize, 5, w - 16] {
            for by in [0usize, 3, h - 16] {
                let mean = kernels::intra_mean_16x16(&plane, w, bx, by);
                let mean_ref = kernels::intra_mean_16x16_scalar(&plane, w, bx, by);
                assert_eq!(mean.to_bits(), mean_ref.to_bits(), "mean w={w} bx={bx} by={by}");
                for target in [mean, 0.0, -17.25] {
                    let a = kernels::intra_sad_16x16(&plane, w, bx, by, target);
                    let b = kernels::intra_sad_16x16_scalar(&plane, w, bx, by, target);
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "sad w={w} bx={bx} by={by} target={target}"
                    );
                }
            }
        }
    }
}

#[test]
fn block_bits_identity_on_random_levels() {
    let mut rng = Rng::new(0xB17);
    for density in [2u64, 5, 20, 64] {
        for _ in 0..100 {
            let mut levels = [0i32; 64];
            for v in levels.iter_mut() {
                if rng.next_u64() % density == 0 {
                    *v = (rng.next_u64() % 1023) as i32 - 511;
                }
            }
            for prev_dc in [0i32, -100, 511] {
                assert_eq!(
                    entropy::block_bits(&levels, prev_dc),
                    entropy::block_bits_scalar(&levels, prev_dc),
                    "levels {levels:?} prev_dc {prev_dc}"
                );
            }
        }
    }
}

#[test]
fn masked_convert_identity_on_odd_offset_rects() {
    let cfg = Config::test_small();
    let scenario = Scenario::build(&cfg.scenario);
    let frame = scenario.renderer().render(0, 4);
    let scalar_reference = |f: &Frame, keep: &[IRect]| -> Vec<f32> {
        let mut out = vec![0.0f32; f.data.len()];
        for r in keep {
            if r.x >= f.w || r.y >= f.h {
                continue;
            }
            let x1 = (r.x + r.w).min(f.w);
            let y1 = (r.y + r.h).min(f.h);
            for y in r.y..y1 {
                let start = f.idx(r.x, y);
                let len = ((x1 - r.x) * 3) as usize;
                for i in start..start + len {
                    out[i] = f.data[i] as f32 / 255.0;
                }
            }
        }
        out
    };
    let cases: Vec<Vec<IRect>> = vec![
        vec![IRect::new(64, 48, 160, 96)],  // the 25%-RoI bench rect
        vec![IRect::new(63, 47, 161, 97)],  // odd offsets, odd span
        vec![IRect::new(1, 0, 7, 5)],       // narrower than one SIMD lane row
        vec![IRect::new(32, 32, 64, 32), IRect::new(60, 40, 50, 40)], // overlap
        vec![IRect::new(300, 180, 100, 100)], // clamped at the frame edge
    ];
    for keep in cases {
        let got = frame.masked_f32(&keep);
        let want = scalar_reference(&frame, &keep);
        assert_eq!(bits(&got), bits(&want), "{keep:?}");
    }
    assert_eq!(bits(&frame.to_f32()), bits(&scalar_reference(&frame, &[IRect::new(0, 0, 320, 192)])));
}

/// Whole-encoder invariance under the forced backend: every kernel in
/// concert (DCT, quantize, SAD-driven mode decisions, entropy costing)
/// must give the same segment bytes either way.  Skips without AVX2.
#[test]
fn segment_encode_is_backend_invariant() {
    if !avx2_supported() {
        return;
    }
    let cfg = Config::test_small();
    let scenario = Scenario::build(&cfg.scenario);
    let renderer = scenario.renderer();
    let frames: Vec<Frame> = (0..6).map(|i| renderer.render(0, i)).collect();
    // odd-offset 25% RoI plus a second small region (multi-stream path)
    let regions = [IRect::new(63, 47, 161, 97), IRect::new(16, 16, 48, 32)];
    let encode_with = |backend: KernelBackend| {
        set_backend(Some(backend));
        let mut enc = SegmentEncoder::new(&regions, 6.0);
        let out = enc.encode_segment(&frames);
        set_backend(None);
        out
    };
    let scalar = encode_with(KernelBackend::Scalar);
    let simd = encode_with(KernelBackend::Avx2);
    assert_eq!(scalar.bytes, simd.bytes, "segment bytes diverged across backends");
    assert_eq!(scalar.region_bits, simd.region_bits, "per-region bits diverged");
    assert_eq!(scalar.n_frames, simd.n_frames);
}
