//! Shape-exhaustiveness + stable-serialization regression tests
//! (DESIGN.md §11, satellite of the static-analysis PR).
//!
//! The first half pins the *field inventory* of the byte-compared report
//! types: each struct is destructured with **no `..`**, so adding a field
//! to `MethodReport`, `ReplanRecord` or `ComponentRecord` fails to
//! compile here until the author decides whether the new field is
//! wall-clock (→ extend `zero_wall_clock` and the xtask manifest) or
//! deterministic (→ safe to serialize).  That decision is exactly what
//! the `cargo xtask analyze` wall-clock pass enforces textually; this
//! file is its compile-time twin.
//!
//! The second half is the order-determinism regression suite: the mask
//! and query surfaces that *consume* hash collections must produce
//! byte-identical serialized output regardless of set insertion order.

use std::collections::HashSet;

use crossroi::association::Tiling;
use crossroi::coordinator::{LatencyBreakdown, MethodReport};
use crossroi::offline::{ComponentRecord, RepairRecord, ReplanRecord};
use crossroi::query;
use crossroi::roi::RoiMasks;

fn sample_component() -> ComponentRecord {
    ComponentRecord {
        cameras: vec![0, 2],
        drift: 0.25,
        fired: true,
        warm: true,
        migrated: false,
        spill_groups: 2,
        n_constraints: 17,
        solver: "greedy",
        seconds: 0.75,
        queue_wait: 0.05,
    }
}

fn sample_record() -> ReplanRecord {
    ReplanRecord {
        epoch: 1,
        start_seg: 12,
        trigger_time: 12.5,
        seconds: 2.0,
        replanned: true,
        warm: true,
        constraint_drift: 0.3,
        mask_churn: 0.1,
        solver: "greedy",
        n_constraints: 17,
        mask_tiles: 40,
        scope: "component",
        components: vec![sample_component()],
        reducto_rederived: 1,
    }
}

fn sample_repair() -> RepairRecord {
    RepairRecord {
        cam: 1,
        kind: "dropout",
        fail_secs: 4.5,
        detect_secs: 6.0,
        detect_latency: 1.5,
        epoch: 2,
        repair_latency_epochs: 1,
        orphaned_tiles: 12,
        recovered_tiles: 9,
        uncovered_constraints: 2,
        seconds: 0.02,
    }
}

/// Every `MethodReport` field is either zeroed by `zero_wall_clock` or
/// must survive it untouched — the no-`..` destructure makes a new field
/// a compile error here until it is classified.
#[test]
fn method_report_inventory_is_classified() {
    let mut r = MethodReport::default();
    r.method = "CrossRoI".to_string();
    r.accuracy = 0.99;
    r.missed_per_frame = vec![0, 1];
    r.total_appearances = 100;
    r.network_mbps_per_cam = vec![1.0, 2.0];
    r.network_mbps_total = 3.0;
    r.bytes_total = 4096;
    r.server_hz = 120.0;
    r.camera_fps = 30.0;
    r.latency = LatencyBreakdown { camera: 0.5, network: 0.1, server: 0.2 };
    r.latency_p95 = 0.9;
    r.frames_reduced = 5;
    r.frames_total = 300;
    r.mask_tiles = 40;
    r.mask_coverage = 0.33;
    r.regions_per_cam = vec![2, 3];
    r.consolidate_mode = "auto".to_string();
    r.canvas_cams = 2;
    r.offline_seconds = 7.5;
    r.replan_count = 1;
    r.replan_warm_count = 1;
    r.replan_carried_components = 2;
    r.replan_migrations = 0;
    r.replan_reducto_rederived = 1;
    r.replan_mask_churn = 0.1;
    r.replan_seconds = 2.0;
    r.replan_done_at = vec![14.5];
    r.replan_records = vec![sample_record()];
    r.repair_records = vec![sample_repair()];
    r.arena_frame_allocs = 8;
    r.arena_pixel_allocs = 8;
    r.arena_pixel_reuses = 32;
    r.arena_grid_allocs = 2;
    r.arena_grid_reuses = 10;
    r.arena_canvas_allocs = 1;
    r.arena_canvas_reuses = 4;
    r.planner_epochs_computed = 1;
    r.planner_components_solved = 1;
    r.planner_max_concurrent = 1;
    r.planner_queue_wait_secs = 0.05;
    r.canvas_count = 6;
    r.canvas_fill_ratio = 0.4;
    r.canvas_occupancy = 2.0;
    r.zero_wall_clock();

    let MethodReport {
        method,
        accuracy,
        missed_per_frame,
        total_appearances,
        network_mbps_per_cam,
        network_mbps_total,
        bytes_total,
        server_hz,
        camera_fps,
        latency,
        latency_p95,
        frames_reduced,
        frames_total,
        mask_tiles,
        mask_coverage,
        regions_per_cam,
        consolidate_mode,
        canvas_cams,
        offline_seconds,
        replan_count,
        replan_warm_count,
        replan_carried_components,
        replan_migrations,
        replan_reducto_rederived,
        replan_mask_churn,
        replan_seconds,
        replan_done_at,
        replan_records,
        repair_records,
        arena_frame_allocs,
        arena_pixel_allocs,
        arena_pixel_reuses,
        arena_grid_allocs,
        arena_grid_reuses,
        arena_canvas_allocs,
        arena_canvas_reuses,
        planner_epochs_computed,
        planner_components_solved,
        planner_max_concurrent,
        planner_queue_wait_secs,
        canvas_count,
        canvas_fill_ratio,
        canvas_occupancy,
    } = r;

    // wall-clock families: zeroed (the xtask manifest mirrors this list)
    assert_eq!(offline_seconds, 0.0);
    assert_eq!(replan_seconds, 0.0);
    assert_eq!(replan_done_at, vec![0.0], "shape preserved, values zeroed");
    assert_eq!(arena_frame_allocs, 0);
    assert_eq!(arena_pixel_allocs, 0);
    assert_eq!(arena_pixel_reuses, 0);
    assert_eq!(arena_grid_allocs, 0);
    assert_eq!(arena_grid_reuses, 0);
    assert_eq!(arena_canvas_allocs, 0);
    assert_eq!(arena_canvas_reuses, 0);
    assert_eq!(planner_epochs_computed, 0);
    assert_eq!(planner_components_solved, 0);
    assert_eq!(planner_max_concurrent, 0);
    assert_eq!(planner_queue_wait_secs, 0.0);
    assert_eq!(canvas_count, 0);
    assert_eq!(canvas_fill_ratio, 0.0);
    assert_eq!(canvas_occupancy, 0.0);

    // deterministic fields: survive untouched
    assert_eq!(method, "CrossRoI");
    assert_eq!(accuracy, 0.99);
    assert_eq!(missed_per_frame, vec![0, 1]);
    assert_eq!(total_appearances, 100);
    assert_eq!(network_mbps_per_cam, vec![1.0, 2.0]);
    assert_eq!(network_mbps_total, 3.0);
    assert_eq!(bytes_total, 4096);
    assert_eq!(server_hz, 120.0);
    assert_eq!(camera_fps, 30.0);
    assert_eq!(latency.camera, 0.5);
    assert_eq!(latency_p95, 0.9);
    assert_eq!(frames_reduced, 5);
    assert_eq!(frames_total, 300);
    assert_eq!(mask_tiles, 40);
    assert_eq!(mask_coverage, 0.33);
    assert_eq!(regions_per_cam, vec![2, 3]);
    assert_eq!(consolidate_mode, "auto", "routing policy is plan-derived");
    assert_eq!(canvas_cams, 2);
    assert_eq!(replan_count, 1);
    assert_eq!(replan_warm_count, 1);
    assert_eq!(replan_carried_components, 2);
    assert_eq!(replan_migrations, 0);
    assert_eq!(replan_reducto_rederived, 1);
    assert_eq!(replan_mask_churn, 0.1);
    assert_eq!(replan_records.len(), 1);
    assert_eq!(repair_records.len(), 1, "repair outcomes are deterministic payload");
}

/// The per-fault repair record: wall-clock is `seconds`; everything else
/// is resolved from the config + segment grid (detection times are DES
/// deadlines) and must survive zeroing.
#[test]
fn repair_record_inventory_is_classified() {
    let mut report = MethodReport::default();
    report.repair_records = vec![sample_repair()];
    report.zero_wall_clock();
    let rec = report.repair_records.into_iter().next().unwrap();

    let RepairRecord {
        cam,
        kind,
        fail_secs,
        detect_secs,
        detect_latency,
        epoch,
        repair_latency_epochs,
        orphaned_tiles,
        recovered_tiles,
        uncovered_constraints,
        seconds,
    } = rec;

    assert_eq!(seconds, 0.0, "wall-clock");
    assert_eq!(cam, 1);
    assert_eq!(kind, "dropout");
    assert_eq!(fail_secs, 4.5);
    assert_eq!(detect_secs, 6.0, "DES deadline, not wall clock");
    assert_eq!(detect_latency, 1.5);
    assert_eq!(epoch, 2);
    assert_eq!(repair_latency_epochs, 1);
    assert_eq!(orphaned_tiles, 12);
    assert_eq!(recovered_tiles, 9);
    assert_eq!(uncovered_constraints, 2);
}

/// The per-epoch record: wall-clock is `seconds` (and, per component,
/// `seconds` + `queue_wait`); everything else is DES-clock or outcome
/// data and must survive zeroing.
#[test]
fn replan_record_inventory_is_classified() {
    let mut report = MethodReport::default();
    report.replan_records = vec![sample_record()];
    report.zero_wall_clock();
    let rec = report.replan_records.into_iter().next().unwrap();

    let ReplanRecord {
        epoch,
        start_seg,
        trigger_time,
        seconds,
        replanned,
        warm,
        constraint_drift,
        mask_churn,
        solver,
        n_constraints,
        mask_tiles,
        scope,
        components,
        reducto_rederived,
    } = rec;

    assert_eq!(seconds, 0.0, "wall-clock");
    assert_eq!(epoch, 1);
    assert_eq!(start_seg, 12);
    assert_eq!(trigger_time, 12.5, "DES clock, not wall clock");
    assert!(replanned);
    assert!(warm);
    assert_eq!(constraint_drift, 0.3);
    assert_eq!(mask_churn, 0.1);
    assert_eq!(solver, "greedy");
    assert_eq!(n_constraints, 17);
    assert_eq!(mask_tiles, 40);
    assert_eq!(scope, "component");
    assert_eq!(reducto_rederived, 1);

    let comp = components.into_iter().next().unwrap();
    let ComponentRecord {
        cameras,
        drift,
        fired,
        warm,
        migrated,
        spill_groups,
        n_constraints,
        solver,
        seconds,
        queue_wait,
    } = comp;
    assert_eq!(seconds, 0.0, "wall-clock");
    assert_eq!(queue_wait, 0.0, "wall-clock");
    assert_eq!(cameras, vec![0, 2]);
    assert_eq!(drift, 0.25);
    assert!(fired);
    assert!(warm);
    assert!(!migrated);
    assert_eq!(spill_groups, 2);
    assert_eq!(n_constraints, 17);
    assert_eq!(solver, "greedy");
}

// ---------------------------------------------------------------------
// order-determinism regressions: hash-set consumers must serialize
// byte-identically for every insertion order
// ---------------------------------------------------------------------

fn tiling() -> Tiling {
    Tiling::new(2, 320, 192, 16)
}

/// A solution set inserted in two opposite orders must produce identical
/// masks, tile rects and active blocks — `from_solution` iterates the
/// hash set, so this pins that the iteration feeds only order-insensitive
/// sinks (per-camera sets) and that the serializing surfaces sort.
#[test]
fn mask_serialization_is_insertion_order_invariant() {
    let t = tiling();
    let ids: Vec<u32> = vec![
        t.tile_id(0, 3, 2),
        t.tile_id(0, 4, 2),
        t.tile_id(1, 0, 0),
        t.tile_id(1, 19, 11),
        t.tile_id(0, 10, 7),
        t.tile_id(1, 5, 5),
    ];
    let fwd: HashSet<u32> = ids.iter().copied().collect();
    let rev: HashSet<u32> = ids.iter().rev().copied().collect();
    let m1 = RoiMasks::from_solution(&t, &fwd);
    let m2 = RoiMasks::from_solution(&t, &rev);
    for cam in 0..t.n_cameras {
        assert_eq!(
            format!("{:?}", m1.tile_rects(cam)),
            format!("{:?}", m2.tile_rects(cam)),
            "tile_rects must be byte-stable"
        );
        assert_eq!(
            m1.active_blocks(cam, 32, t.frame_w),
            m2.active_blocks(cam, 32, t.frame_w),
            "active_blocks must be byte-stable"
        );
    }
    // and sorted ascending — the runtime's RoI HLO contract
    let blocks = m1.active_blocks(0, 32, t.frame_w);
    let mut sorted = blocks.clone();
    sorted.sort_unstable();
    assert_eq!(blocks, sorted);
}

/// Query accuracy consumes per-frame hash sets; only counts may matter.
/// Rebuilding the same sets with different insertion orders must yield
/// bit-identical accuracy and missed-counts.
#[test]
fn query_accuracy_is_insertion_order_invariant() {
    let frames: Vec<Vec<u32>> = vec![vec![1, 2, 3, 4], vec![5, 6], vec![], vec![7, 8, 9]];
    let build = |rev: bool| -> Vec<HashSet<u32>> {
        frames
            .iter()
            .map(|f| {
                if rev {
                    f.iter().rev().copied().collect()
                } else {
                    f.iter().copied().collect()
                }
            })
            .collect()
    };
    let reference = build(false);
    let reported_fwd: Vec<HashSet<u32>> =
        vec![vec![1, 2, 3], vec![5, 6], vec![], vec![7, 9]]
            .into_iter()
            .map(|f| f.into_iter().collect())
            .collect();
    let reported_rev: Vec<HashSet<u32>> =
        vec![vec![3, 2, 1], vec![6, 5], vec![], vec![9, 7]]
            .into_iter()
            .map(|f| f.into_iter().collect())
            .collect();

    let (acc1, missed1) = query::accuracy(&reference, &reported_fwd);
    let (acc2, missed2) = query::accuracy(&build(true), &reported_rev);
    assert_eq!(acc1.to_bits(), acc2.to_bits(), "accuracy must be bit-identical");
    assert_eq!(missed1, missed2);
    assert_eq!(
        query::total_appearances(&reference),
        query::total_appearances(&build(true))
    );
}
