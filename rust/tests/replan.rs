//! Continuous re-profiling (DESIGN.md §7–§8): sliding-window warm-started
//! re-planning must chase a drifting scene — masks change, coverage stays
//! complete — and the mid-run mask swap must be byte-deterministic across
//! pipeline schedules (no reordered or dropped segments).  Component
//! scope (the default) must agree with fleet scope on everything the
//! masks determine; the fleet-level integration tests live in
//! `rust/tests/component_replan.rs`.

use std::collections::HashSet;

use anyhow::Result;
use crossroi::association::table::AssociationTable;
use crossroi::association::tiles::{GlobalTile, Tiling};
use crossroi::config::Config;
use crossroi::coordinator::{run_method_with, Infer, Method, MethodReport, NativeInfer};
use crossroi::offline::{associate, solve, SolverKind};
use crossroi::pipeline::{
    EncodeCost, Parallelism, PipelineOptions, ReplanPolicy, ReplanScope,
};
use crossroi::reid::error_model::{ErrorModelParams, RawReid};
use crossroi::sim::Scenario;

/// Drifting small scenario: flow flips between the two roads 2 s into the
/// evaluation window, so the masks profiled offline go stale mid-run.
fn drift_config() -> Config {
    let mut cfg = Config::test_small();
    cfg.scenario.profile_secs = 10.0;
    cfg.scenario.eval_secs = 10.0;
    cfg.scenario.drift_at_secs = 12.0;
    cfg.scenario.drift_strength = 0.9;
    cfg
}

fn sim_tiling(cfg: &Config, n_cams: usize) -> Tiling {
    Tiling::new(
        n_cams,
        crossroi::sim::FRAME_W,
        crossroi::sim::FRAME_H,
        cfg.scenario.tile_px,
    )
}

fn covers(table: &AssociationTable, tiles: &HashSet<GlobalTile>) -> bool {
    table.constraints.iter().all(|c| {
        c.regions.is_empty()
            || c.regions.iter().any(|r| r.iter().all(|t| tiles.contains(t)))
    })
}

#[test]
fn run_incremental_tracks_a_drifting_window() {
    let cfg = drift_config();
    let scenario = Scenario::build(&cfg.scenario);
    let tiling = sim_tiling(&cfg, scenario.cameras.len());
    let params = ErrorModelParams::default();
    // window A: pre-drift; window B: post-drift
    let a = RawReid::generate(&scenario, 0..50, &params);
    let b = RawReid::generate(&scenario, 50..100, &params);
    let table_a = associate::run(&a, &tiling).table;
    let table_b = associate::run(&b, &tiling).table;
    assert!(table_a.n_constraints() > 0 && table_b.n_constraints() > 0);

    let solver = SolverKind::Greedy.build();
    let first = solve::run(&table_a, solver.as_ref());
    let warm = solve::run_incremental(&table_b, solver.as_ref(), &first.solution);
    // the drifted window must be fully covered by the warm-started cover
    assert!(covers(&table_b, &warm.solution.tiles), "warm re-solve left constraints open");
    // and the masks must actually move with the flow
    assert_ne!(
        first.solution.tiles, warm.solution.tiles,
        "drifting traffic did not change the masks"
    );
    // warm start must not balloon versus a fresh solve of the same window
    let fresh = solve::run(&table_b, solver.as_ref());
    assert!(covers(&table_b, &fresh.solution.tiles));
    assert!(
        warm.solution.size() <= fresh.solution.size() + fresh.solution.size() / 4,
        "warm cover {} far above fresh cover {}",
        warm.solution.size(),
        fresh.solution.size()
    );
}

/// Native reference detector with fixed, deterministic service times (the
/// same shape as `pipeline_determinism.rs`).
struct FixedCostInfer;

impl Infer for FixedCostInfer {
    fn infer(&self, frame: &[f32], blocks: Option<&[i32]>) -> Result<(Vec<f32>, f64)> {
        let (grid, _) = NativeInfer.infer(frame, blocks)?;
        let secs = match blocks {
            None => 0.004,
            Some(b) => 0.001 + 0.00004 * b.len() as f64,
        };
        Ok((grid, secs))
    }
}

fn replan_opts(par: Parallelism, policy: ReplanPolicy, scope: ReplanScope) -> PipelineOptions {
    PipelineOptions {
        parallelism: par,
        encode_cost: EncodeCost::PerFrame(0.02),
        replan: policy,
        replan_scope: scope,
        ..PipelineOptions::default()
    }
}

#[test]
fn online_drift_run_replans_via_warm_start() {
    let cfg = drift_config();
    let scenario = Scenario::build(&cfg.scenario);
    let (report, reported) = run_method_with(
        &scenario,
        &cfg.system,
        &FixedCostInfer,
        &Method::CrossRoi,
        None,
        &replan_opts(Parallelism::PerCamera, ReplanPolicy::Every(2), ReplanScope::Component),
    )
    .unwrap();
    // 10 s eval at 1 s segments, epoch every 2 segments → 4 boundaries;
    // every boundary fires at least its main component (component scope
    // may additionally fire a momentarily-starved singleton to clear its
    // stale tiles, so the component re-solve count can exceed 4)
    assert!(
        report.replan_count >= 4,
        "every-2 policy must fire at each boundary: {}",
        report.replan_count
    );
    assert_eq!(report.replan_done_at.len(), 4, "each boundary must execute a re-plan");
    assert_eq!(report.replan_records.len(), 4, "one record per epoch boundary");
    assert!(
        report.replan_records.iter().all(|r| !r.components.is_empty()),
        "every record must carry its component dispositions"
    );
    assert!(
        report.replan_warm_count >= 1,
        "no component re-solve warm-started: {} of {}",
        report.replan_warm_count,
        report.replan_count
    );
    assert!(
        report.replan_mask_churn > 0.0,
        "drifting flow must churn the masks"
    );
    // re-plans are timestamped after their epoch boundary on the DES clock
    assert!(report.replan_done_at.iter().all(|&t| t > 0.0));
    assert!(report.replan_seconds > 0.0);
    // no dropped frames or segments: every eval frame was reported
    let eval_frames = (cfg.scenario.eval_secs * cfg.scenario.fps).round() as usize;
    assert_eq!(reported.len(), eval_frames);
    assert_eq!(report.frames_total, eval_frames * cfg.scenario.n_cameras);
}

#[test]
fn drift_policy_fires_only_on_drift() {
    let cfg = drift_config();
    let scenario = Scenario::build(&cfg.scenario);
    // a threshold no window can reach: the plan is carried forward.
    // Fleet scope pins the check to pure drift gating — the fleet
    // pseudo-component never migrates, while component scope could
    // legitimately fire on a mid-run component split.
    let (calm, _) = run_method_with(
        &scenario,
        &cfg.system,
        &FixedCostInfer,
        &Method::CrossRoi,
        None,
        &replan_opts(
            Parallelism::PerCamera,
            ReplanPolicy::Drift { check_every: 2, threshold: 1.1 },
            ReplanScope::Fleet,
        ),
    )
    .unwrap();
    assert_eq!(calm.replan_count, 0, "unreachable threshold must never fire");
    assert!(calm.replan_seconds > 0.0, "drift checks still cost wall time");
    assert!(calm.replan_carried_components >= 4, "each boundary carries the fleet forward");
    // a low threshold on a drifting scene must fire
    let (hot, _) = run_method_with(
        &scenario,
        &cfg.system,
        &FixedCostInfer,
        &Method::CrossRoi,
        None,
        &replan_opts(
            Parallelism::PerCamera,
            ReplanPolicy::Drift { check_every: 2, threshold: 0.05 },
            ReplanScope::Component,
        ),
    )
    .unwrap();
    assert!(hot.replan_count >= 1, "drifting scene never crossed a 0.05 threshold");
}

#[test]
fn mask_swap_is_byte_deterministic_across_schedules() {
    let cfg = drift_config();
    let scenario = Scenario::build(&cfg.scenario);
    let json = |par: Parallelism| {
        let (mut report, _) = run_method_with(
            &scenario,
            &cfg.system,
            &FixedCostInfer,
            &Method::CrossRoi,
            None,
            &replan_opts(par, ReplanPolicy::Every(2), ReplanScope::Component),
        )
        .unwrap();
        assert_eq!(report.replan_done_at.len(), 4, "each boundary must execute");
        // wall-clock fields are the only non-deterministic part; zero the
        // values but keep the shape (a dropped or duplicated re-plan
        // would still change the byte stream)
        report.zero_wall_clock();
        report.to_json().to_string_pretty(2)
    };
    let reference = json(Parallelism::Sequential);
    assert!(reference.contains("\"replan_count\""), "{reference}");
    // the serialized dump carries the full per-component records
    assert!(reference.contains("\"replan_records\""), "{reference}");
    assert!(reference.contains("\"components\""), "{reference}");
    for par in [Parallelism::PerCamera, Parallelism::Workers(1), Parallelism::Workers(3)] {
        let parallel = json(par);
        assert_eq!(
            reference, parallel,
            "{par:?} diverged from the sequential reference under mid-run mask swaps"
        );
    }
}

/// Everything the masks determine must agree between the two scopes on a
/// connected fleet: the 5-camera rig is (mostly) one component, and a
/// per-component decomposition of one component is exactly the fleet
/// path.  Re-plan *diagnostics* (component counts) legitimately differ —
/// component scope may additionally clear a starved singleton — so the
/// comparison covers the pipeline-observable fields.
#[test]
fn component_scope_matches_fleet_scope_on_a_connected_fleet() {
    // stationary traffic: sliding windows stay far under
    // FRESH_SOLVE_DRIFT, so both scopes take the warm path at every
    // boundary and no camera ever migrates between components — the
    // preconditions for byte-identity (asserted below, not assumed)
    let mut cfg = Config::test_small();
    cfg.scenario.profile_secs = 10.0;
    cfg.scenario.eval_secs = 10.0;
    let scenario = Scenario::build(&cfg.scenario);
    let run = |scope: ReplanScope| -> MethodReport {
        run_method_with(
            &scenario,
            &cfg.system,
            &FixedCostInfer,
            &Method::CrossRoi,
            None,
            &replan_opts(Parallelism::PerCamera, ReplanPolicy::Every(2), scope),
        )
        .unwrap()
        .0
    };
    let fleet = run(ReplanScope::Fleet);
    let comp = run(ReplanScope::Component);
    assert_eq!(comp.replan_migrations, 0, "stationary traffic must not migrate cameras");
    assert_eq!(fleet.replan_warm_count, fleet.replan_count, "fleet run must stay warm");
    assert_eq!(comp.replan_warm_count, comp.replan_count, "component run must stay warm");
    assert_eq!(fleet.accuracy, comp.accuracy);
    assert_eq!(fleet.missed_per_frame, comp.missed_per_frame);
    assert_eq!(fleet.bytes_total, comp.bytes_total);
    assert_eq!(fleet.network_mbps_per_cam, comp.network_mbps_per_cam);
    assert_eq!(fleet.mask_tiles, comp.mask_tiles);
    assert_eq!(fleet.mask_coverage, comp.mask_coverage);
    assert_eq!(fleet.regions_per_cam, comp.regions_per_cam);
    assert_eq!(fleet.frames_reduced, comp.frames_reduced);
    assert_eq!(fleet.latency_p95, comp.latency_p95);
    assert_eq!(fleet.latency.camera, comp.latency.camera);
    assert_eq!(fleet.latency.network, comp.latency.network);
    assert_eq!(fleet.latency.server, comp.latency.server);
    assert_eq!(fleet.replan_mask_churn, comp.replan_mask_churn);
}
