//! Determinism: the staged, parallel offline planner must produce plans
//! that are byte-identical across thread counts and repeated runs —
//! masks, groups, blocks and the filter report are pure functions of the
//! scenario seed; only the `PlanReport` timings are wall-clock.
//!
//! Mirrors `pipeline_determinism.rs` on the offline side: the per-pair
//! RANSAC/SVM fitting runs on scoped worker threads, and the merge rule
//! (pair-order rewrites, fresh ids assigned after the merge) must make
//! the schedule unobservable.

use crossroi::association::tiles::Tiling;
use crossroi::config::Config;
use crossroi::coordinator::Method;
use crossroi::offline::{
    build_plan_from_stream, build_plan_with, OfflineOptions, OfflinePlan, ShardMode,
    SolverKind,
};
use crossroi::reid::records::ReidStream;
use crossroi::sim::Scenario;

fn small() -> (Scenario, Config) {
    let cfg = Config::test_small();
    (Scenario::build(&cfg.scenario), cfg)
}

fn plan_at(scenario: &Scenario, cfg: &Config, method: &Method, threads: usize) -> OfflinePlan {
    let opts = OfflineOptions { threads, solver: SolverKind::Greedy, shards: ShardMode::Auto };
    build_plan_with(scenario, &cfg.scenario, &cfg.system, method, &opts)
        .expect("the greedy planner never fails")
}

/// Every deterministic field of the plan must match exactly.
fn assert_plans_identical(a: &OfflinePlan, b: &OfflinePlan, what: &str) {
    assert_eq!(a.filter_report, b.filter_report, "{what}: filter report diverged");
    assert_eq!(a.n_constraints, b.n_constraints, "{what}: constraint count diverged");
    assert_eq!(a.masks.total_size(), b.masks.total_size(), "{what}: |M| diverged");
    let n_cams = a.masks.tiles.len();
    assert_eq!(n_cams, b.masks.tiles.len(), "{what}: camera count diverged");
    for cam in 0..n_cams {
        assert_eq!(a.masks.tiles[cam], b.masks.tiles[cam], "{what}: cam {cam} mask diverged");
        assert_eq!(a.groups[cam], b.groups[cam], "{what}: cam {cam} groups diverged");
        assert_eq!(a.blocks[cam], b.blocks[cam], "{what}: cam {cam} blocks diverged");
    }
}

fn assert_identical_across_threads(method: Method) {
    let (scenario, cfg) = small();
    let reference = plan_at(&scenario, &cfg, &method, 1);
    // repeated run, same thread count
    let again = plan_at(&scenario, &cfg, &method, 1);
    assert_plans_identical(&reference, &again, &format!("{}: rerun", method.name()));
    // the acceptance matrix: 1 vs 2 vs 8 worker threads
    for threads in [2usize, 8] {
        let parallel = plan_at(&scenario, &cfg, &method, threads);
        assert_plans_identical(
            &reference,
            &parallel,
            &format!("{}: {threads} threads vs sequential", method.name()),
        );
        assert_eq!(parallel.report.threads, threads);
    }
    // auto thread count (0 = cores) must agree too
    let auto = plan_at(&scenario, &cfg, &method, 0);
    assert_plans_identical(&reference, &auto, &format!("{}: auto threads", method.name()));
}

#[test]
fn crossroi_plan_is_deterministic_across_threads() {
    assert_identical_across_threads(Method::CrossRoi);
}

#[test]
fn no_filters_plan_is_deterministic_across_threads() {
    // no filter stage: the plan must be schedule-independent trivially,
    // and the fast path must not regress
    assert_identical_across_threads(Method::NoFilters);
}

#[test]
fn no_merging_plan_is_deterministic_across_threads() {
    assert_identical_across_threads(Method::NoMerging);
}

#[test]
fn stage_report_shape_is_stable_across_threads() {
    let (scenario, cfg) = small();
    for threads in [1usize, 2, 8] {
        let plan = plan_at(&scenario, &cfg, &Method::CrossRoi, threads);
        let stages: Vec<&str> = plan.report.stages.iter().map(|s| s.stage).collect();
        assert_eq!(
            stages,
            vec!["profile", "filter", "associate", "solve", "group"],
            "stage graph changed at {threads} threads"
        );
        assert_eq!(plan.report.solver, "greedy");
    }
}

// ---- overlap-sharded planning ----

/// A disjoint multi-intersection fleet over the small test windows — the
/// construction itself (camera offsets, disjoint id spaces) is shared
/// with the bench and example via [`crossroi::testing::fleet`].
fn disjoint_fleet(n_intersections: usize, base_seed: u64) -> (ReidStream, Tiling, Config) {
    let cfg = Config::test_small();
    let (stream, tiling) =
        crossroi::testing::fleet::disjoint_intersections(&cfg, n_intersections, base_seed);
    (stream, tiling, cfg)
}

fn plan_stream_at(
    stream: &ReidStream,
    tiling: &Tiling,
    cfg: &Config,
    shards: ShardMode,
    threads: usize,
) -> OfflinePlan {
    let opts = OfflineOptions { threads, solver: SolverKind::Greedy, shards };
    build_plan_from_stream(stream, tiling, &cfg.system, &Method::CrossRoi, &opts)
        .expect("the greedy planner never fails")
}

#[test]
fn shards_auto_equals_off_byte_identically_on_one_intersection() {
    // the acceptance tie-down: on a fleet the partition does not split
    // (the 5-camera rig overlaps at the crossing), --shards auto must
    // produce exactly the --shards off plan
    let (scenario, cfg) = small();
    let mk = |shards: ShardMode| {
        let opts = OfflineOptions { threads: 2, solver: SolverKind::Greedy, shards };
        build_plan_with(&scenario, &cfg.scenario, &cfg.system, &Method::CrossRoi, &opts)
            .expect("the greedy planner never fails")
    };
    let auto = mk(ShardMode::Auto);
    let off = mk(ShardMode::Off);
    assert_plans_identical(&auto, &off, "shards auto vs off, connected fleet");
    assert!(off.report.shards.is_empty(), "--shards off must not shard");
    // whether or not the partition split this fleet, the sub-reports must
    // cover every camera exactly once
    if !auto.report.shards.is_empty() {
        let mut covered: Vec<usize> =
            auto.report.shards.iter().flat_map(|s| s.cameras.iter().copied()).collect();
        covered.sort_unstable();
        assert_eq!(covered, (0..5).collect::<Vec<_>>());
    }
}

#[test]
fn shards_auto_equals_off_byte_identically_on_a_disjoint_fleet() {
    // shard-count independence: the sharded fan-out must be unobservable
    // in the plan even when it actually splits the fleet
    let (stream, tiling, cfg) = disjoint_fleet(3, 7);
    let auto = plan_stream_at(&stream, &tiling, &cfg, ShardMode::Auto, 2);
    let off = plan_stream_at(&stream, &tiling, &cfg, ShardMode::Off, 2);
    assert!(auto.report.shards.len() >= 3, "expected ≥ 3 components");
    // shards never span intersections, and cover the fleet exactly
    let mut covered = Vec::new();
    for s in &auto.report.shards {
        assert!(
            s.cameras.iter().all(|c| c / 4 == s.cameras[0] / 4),
            "shard spans intersections: {:?}",
            s.cameras
        );
        covered.extend(s.cameras.iter().copied());
    }
    covered.sort_unstable();
    assert_eq!(covered, (0..stream.n_cameras).collect::<Vec<_>>());
    assert_plans_identical(&auto, &off, "shards auto vs off, disjoint fleet");
}

#[test]
fn sharded_plans_are_byte_identical_across_thread_counts() {
    let (stream, tiling, cfg) = disjoint_fleet(2, 41);
    let reference = plan_stream_at(&stream, &tiling, &cfg, ShardMode::Auto, 1);
    for threads in [2usize, 8] {
        let parallel = plan_stream_at(&stream, &tiling, &cfg, ShardMode::Auto, threads);
        assert_plans_identical(
            &reference,
            &parallel,
            &format!("sharded, {threads} threads vs sequential"),
        );
        assert_eq!(parallel.report.shards.len(), reference.report.shards.len());
    }
    let auto_cores = plan_stream_at(&stream, &tiling, &cfg, ShardMode::Auto, 0);
    assert_plans_identical(&reference, &auto_cores, "sharded, auto threads");
}

#[test]
fn disjoint_merged_masks_equal_the_per_fleet_concatenation() {
    // a disjoint fleet planned sharded must byte-match each intersection
    // planned alone (camera indices shifted, ids uniformly offset — both
    // invisible to the plan)
    let n = 2usize;
    let base_seed = 99u64;
    let (stream, tiling, cfg) = disjoint_fleet(n, base_seed);
    let merged = plan_stream_at(&stream, &tiling, &cfg, ShardMode::Auto, 2);
    let mut total_constraints = 0usize;
    for k in 0..n {
        let mut c = cfg.clone();
        // exactly the per-intersection scenario the fleet helper profiled
        c.scenario.n_cameras = 4;
        c.scenario.seed = base_seed + k as u64;
        let sc = Scenario::build(&c.scenario);
        let opts =
            OfflineOptions { threads: 2, solver: SolverKind::Greedy, shards: ShardMode::Off };
        let alone = build_plan_with(&sc, &c.scenario, &c.system, &Method::CrossRoi, &opts)
            .expect("the greedy planner never fails");
        for cam in 0..4 {
            let g = 4 * k + cam;
            assert_eq!(
                merged.masks.tiles[g], alone.masks.tiles[cam],
                "intersection {k} cam {cam}: merged mask diverged from standalone plan"
            );
            assert_eq!(merged.groups[g], alone.groups[cam], "intersection {k} cam {cam} groups");
            assert_eq!(merged.blocks[g], alone.blocks[cam], "intersection {k} cam {cam} blocks");
        }
        total_constraints += alone.n_constraints;
    }
    assert_eq!(merged.n_constraints, total_constraints, "constraint counts must sum");
}

// ---- bridge-camera constraint spill (DESIGN.md §8) ----

#[test]
fn bridged_fleet_plans_byte_identically_sharded_and_not() {
    // two disjoint intersections joined by one bridge camera: the camera
    // partition fuses them into a single component (no shard split), but
    // the solve decomposes along the tile-connectivity spill — and the
    // plan must stay byte-identical to the fused `--shards off` solve at
    // every thread count
    let cfg = Config::test_small();
    let (stream, tiling, bridge) =
        crossroi::testing::fleet::bridged_intersections(&cfg, 7);
    let auto = plan_stream_at(&stream, &tiling, &cfg, ShardMode::Auto, 2);
    let off = plan_stream_at(&stream, &tiling, &cfg, ShardMode::Off, 2);
    assert!(auto.report.shards.is_empty(), "bridge must fuse the camera partition");
    assert!(
        auto.report.spill_groups >= 2,
        "bridge topology must spill: {} groups",
        auto.report.spill_groups
    );
    assert!(
        auto.report.bridge_cameras.contains(&bridge),
        "bridge camera {bridge} not detected: {:?}",
        auto.report.bridge_cameras
    );
    assert_eq!(off.report.spill_groups, 0, "--shards off must not spill");
    assert_plans_identical(&auto, &off, "shards auto vs off, bridged fleet");
    for threads in [1usize, 8] {
        let t = plan_stream_at(&stream, &tiling, &cfg, ShardMode::Auto, threads);
        assert_plans_identical(&auto, &t, &format!("bridged fleet, {threads} threads"));
        assert_eq!(t.report.spill_groups, auto.report.spill_groups);
        assert_eq!(t.report.bridge_cameras, auto.report.bridge_cameras);
    }
}

#[test]
fn spill_partition_and_tile_ownership_are_deterministic() {
    use crossroi::offline::{associate, spill};
    let cfg = Config::test_small();
    let (stream, tiling, bridge) =
        crossroi::testing::fleet::bridged_intersections(&cfg, 11);
    let table = associate::run(&stream, &tiling).table;
    let a = spill(&table);
    let b = spill(&table);
    assert!(a.groups.len() >= 2);
    assert_eq!(a.groups.len(), b.groups.len());
    for (ga, gb) in a.groups.iter().zip(&b.groups) {
        assert_eq!(ga.cameras, gb.cameras);
        assert_eq!(ga.constraints, gb.constraints);
        assert_eq!(ga.n_tiles, gb.n_tiles);
    }
    assert_eq!(a.residual, b.residual);
    // every constraint is owned by exactly one group
    let mut owned = vec![0usize; table.n_constraints()];
    for g in &a.groups {
        for &ci in &g.constraints {
            owned[ci] += 1;
        }
    }
    for &ci in &a.residual {
        owned[ci] += 1;
    }
    assert!(owned.iter().all(|&n| n == 1), "constraint ownership not a partition");
    // the bridge camera spans groups, and its owner is the lowest of them
    let bridging = a.bridge_cameras();
    assert!(bridging.contains(&bridge), "{bridging:?}");
    let owner = a.owner_of(bridge).expect("bridge camera owns tiles");
    for (gi, g) in a.groups.iter().enumerate() {
        if g.cameras.contains(&bridge) {
            assert!(owner <= gi, "ownership must break ties toward the lowest group id");
            break;
        }
    }
}

#[test]
fn greedy_cover_is_certified_by_exact_on_a_small_instance() {
    // the acceptance tie-down: the incremental greedy's cover size is
    // still certified against the branch-and-bound optimum on an instance
    // small enough for it (a trimmed profile window)
    use crossroi::association::table::AssociationTable;
    use crossroi::association::tiles::Tiling;
    use crossroi::reid::error_model::{ErrorModelParams, RawReid};
    use crossroi::roi::setcover::{solve_exact, GreedySolver, Solver};

    let cfg = Config::test_small();
    let scenario = Scenario::build(&cfg.scenario);
    let raw =
        RawReid::generate(&scenario, scenario.profile_range(), &ErrorModelParams::default());
    let tiling = Tiling::new(cfg.scenario.n_cameras, 320, 192, cfg.scenario.tile_px);
    let mut table = AssociationTable::build(&raw, &tiling);
    assert!(table.n_constraints() > 0, "profile window produced no constraints");
    // certify on a real-data sub-instance the exponential solver can take
    let keep = table.n_constraints().min(12);
    table.constraints.truncate(keep);
    table.multiplicity.truncate(keep);
    let greedy = GreedySolver::default().solve(&table);
    let exact = solve_exact(&table, 12);
    assert!(
        greedy.size() >= exact.size(),
        "greedy {} beat 'exact' {} — certifier broken",
        greedy.size(),
        exact.size()
    );
    assert!(
        greedy.size() <= exact.size() + 2,
        "greedy cover {} drifted from optimum {}",
        greedy.size(),
        exact.size()
    );
}
