//! Determinism: the staged, parallel offline planner must produce plans
//! that are byte-identical across thread counts and repeated runs —
//! masks, groups, blocks and the filter report are pure functions of the
//! scenario seed; only the `PlanReport` timings are wall-clock.
//!
//! Mirrors `pipeline_determinism.rs` on the offline side: the per-pair
//! RANSAC/SVM fitting runs on scoped worker threads, and the merge rule
//! (pair-order rewrites, fresh ids assigned after the merge) must make
//! the schedule unobservable.

use crossroi::config::Config;
use crossroi::coordinator::Method;
use crossroi::offline::{build_plan_with, OfflineOptions, OfflinePlan, SolverKind};
use crossroi::sim::Scenario;

fn small() -> (Scenario, Config) {
    let cfg = Config::test_small();
    (Scenario::build(&cfg.scenario), cfg)
}

fn plan_at(scenario: &Scenario, cfg: &Config, method: &Method, threads: usize) -> OfflinePlan {
    let opts = OfflineOptions { threads, solver: SolverKind::Greedy };
    build_plan_with(scenario, &cfg.scenario, &cfg.system, method, &opts)
        .expect("the greedy planner never fails")
}

/// Every deterministic field of the plan must match exactly.
fn assert_plans_identical(a: &OfflinePlan, b: &OfflinePlan, what: &str) {
    assert_eq!(a.filter_report, b.filter_report, "{what}: filter report diverged");
    assert_eq!(a.n_constraints, b.n_constraints, "{what}: constraint count diverged");
    assert_eq!(a.masks.total_size(), b.masks.total_size(), "{what}: |M| diverged");
    let n_cams = a.masks.tiles.len();
    assert_eq!(n_cams, b.masks.tiles.len(), "{what}: camera count diverged");
    for cam in 0..n_cams {
        assert_eq!(a.masks.tiles[cam], b.masks.tiles[cam], "{what}: cam {cam} mask diverged");
        assert_eq!(a.groups[cam], b.groups[cam], "{what}: cam {cam} groups diverged");
        assert_eq!(a.blocks[cam], b.blocks[cam], "{what}: cam {cam} blocks diverged");
    }
}

fn assert_identical_across_threads(method: Method) {
    let (scenario, cfg) = small();
    let reference = plan_at(&scenario, &cfg, &method, 1);
    // repeated run, same thread count
    let again = plan_at(&scenario, &cfg, &method, 1);
    assert_plans_identical(&reference, &again, &format!("{}: rerun", method.name()));
    // the acceptance matrix: 1 vs 2 vs 8 worker threads
    for threads in [2usize, 8] {
        let parallel = plan_at(&scenario, &cfg, &method, threads);
        assert_plans_identical(
            &reference,
            &parallel,
            &format!("{}: {threads} threads vs sequential", method.name()),
        );
        assert_eq!(parallel.report.threads, threads);
    }
    // auto thread count (0 = cores) must agree too
    let auto = plan_at(&scenario, &cfg, &method, 0);
    assert_plans_identical(&reference, &auto, &format!("{}: auto threads", method.name()));
}

#[test]
fn crossroi_plan_is_deterministic_across_threads() {
    assert_identical_across_threads(Method::CrossRoi);
}

#[test]
fn no_filters_plan_is_deterministic_across_threads() {
    // no filter stage: the plan must be schedule-independent trivially,
    // and the fast path must not regress
    assert_identical_across_threads(Method::NoFilters);
}

#[test]
fn no_merging_plan_is_deterministic_across_threads() {
    assert_identical_across_threads(Method::NoMerging);
}

#[test]
fn stage_report_shape_is_stable_across_threads() {
    let (scenario, cfg) = small();
    for threads in [1usize, 2, 8] {
        let plan = plan_at(&scenario, &cfg, &Method::CrossRoi, threads);
        let stages: Vec<&str> = plan.report.stages.iter().map(|s| s.stage).collect();
        assert_eq!(
            stages,
            vec!["profile", "filter", "associate", "solve", "group"],
            "stage graph changed at {threads} threads"
        );
        assert_eq!(plan.report.solver, "greedy");
    }
}

#[test]
fn greedy_cover_is_certified_by_exact_on_a_small_instance() {
    // the acceptance tie-down: the incremental greedy's cover size is
    // still certified against the branch-and-bound optimum on an instance
    // small enough for it (a trimmed profile window)
    use crossroi::association::table::AssociationTable;
    use crossroi::association::tiles::Tiling;
    use crossroi::reid::error_model::{ErrorModelParams, RawReid};
    use crossroi::roi::setcover::{solve_exact, GreedySolver, Solver};

    let cfg = Config::test_small();
    let scenario = Scenario::build(&cfg.scenario);
    let raw =
        RawReid::generate(&scenario, scenario.profile_range(), &ErrorModelParams::default());
    let tiling = Tiling::new(cfg.scenario.n_cameras, 320, 192, cfg.scenario.tile_px);
    let mut table = AssociationTable::build(&raw, &tiling);
    assert!(table.n_constraints() > 0, "profile window produced no constraints");
    // certify on a real-data sub-instance the exponential solver can take
    let keep = table.n_constraints().min(12);
    table.constraints.truncate(keep);
    table.multiplicity.truncate(keep);
    let greedy = GreedySolver::default().solve(&table);
    let exact = solve_exact(&table, 12);
    assert!(
        greedy.size() >= exact.size(),
        "greedy {} beat 'exact' {} — certifier broken",
        greedy.size(),
        exact.size()
    );
    assert!(
        greedy.size() <= exact.size() + 2,
        "greedy cover {} drifted from optimum {}",
        greedy.size(),
        exact.size()
    );
}
