//! Component-incremental re-planning over multi-intersection fleets
//! (DESIGN.md §8): the sim's fleet scenarios partition into
//! per-intersection components (joined only by an explicit bridge
//! camera), re-plan epochs route through that partition so only drifted
//! components re-solve, and component scope stays byte-identical to
//! fleet scope — and across pipeline schedules — on everything the masks
//! determine.

use std::sync::Arc;

use anyhow::Result;
use crossroi::config::Config;
use crossroi::coordinator::{run_method_with, Infer, Method, MethodReport, NativeInfer};
use crossroi::offline::{associate, build_plan, spill, OfflineOptions, Replanner};
use crossroi::pipeline::{
    EncodeCost, EpochPlanner as _, Parallelism, PipelineOptions, PlanEpoch, ReplanPolicy,
    ReplanScope,
};
use crossroi::reid::error_model::{ErrorModelParams, RawReid};
use crossroi::sim::Scenario;

/// Two 4-camera intersections, short windows.  `drift_intersection = 1`
/// flips intersection 1's flow mid-eval while intersection 0 stays
/// stationary.
fn fleet_config(drifted: Option<i64>) -> Config {
    let mut cfg = Config::test_small();
    cfg.scenario.n_cameras = 4;
    cfg.scenario.n_intersections = 2;
    cfg.scenario.profile_secs = 8.0;
    cfg.scenario.eval_secs = 8.0;
    if let Some(k) = drifted {
        cfg.scenario.drift_at_secs = 10.0;
        cfg.scenario.drift_strength = 0.9;
        cfg.scenario.drift_intersection = k;
    }
    cfg.scenario.validate().unwrap();
    cfg
}

fn profile_partition(scenario: &Scenario) -> Vec<Vec<usize>> {
    let stream = RawReid::generate(
        scenario,
        scenario.profile_range(),
        &ErrorModelParams::default(),
    );
    crossroi::offline::shard::partition(&stream)
        .into_iter()
        .map(|s| s.cameras)
        .collect()
}

#[test]
fn disjoint_intersections_partition_into_per_intersection_components() {
    let cfg = fleet_config(None);
    let scenario = Scenario::build(&cfg.scenario);
    assert_eq!(scenario.cameras.len(), 8);
    let comps = profile_partition(&scenario);
    assert_eq!(
        comps,
        vec![vec![0, 1, 2, 3], vec![4, 5, 6, 7]],
        "the fleet must partition into its intersections"
    );
}

#[test]
fn bridge_camera_fuses_the_partition_and_spills_the_solve() {
    // the corridor trio (east-watcher, west-watcher, bridge) chains the
    // two intersections into ONE camera component; the vehicle-free
    // middle stretch of the corridor images into an empty band of the
    // bridge camera's frame, so the constraint spill splits the solve
    // back apart at the bridge
    let mut cfg = fleet_config(None);
    cfg.scenario.bridge_cameras = true;
    cfg.scenario.validate().unwrap();
    let scenario = Scenario::build(&cfg.scenario);
    assert_eq!(scenario.cameras.len(), 11, "2 rigs of 4 + the corridor trio");
    let comps = profile_partition(&scenario);
    assert_eq!(comps.len(), 1, "the bridge must fuse the fleet: {comps:?}");
    assert_eq!(comps[0], (0..11).collect::<Vec<_>>());

    let stream = RawReid::generate(
        &scenario,
        scenario.profile_range(),
        &ErrorModelParams::default(),
    );
    let tiling = crossroi::association::tiles::Tiling::new(
        11,
        crossroi::sim::FRAME_W,
        crossroi::sim::FRAME_H,
        cfg.scenario.tile_px,
    );
    let table = associate::run(&stream, &tiling).table;
    assert!(table.n_constraints() > 0);
    let sp = spill(&table);
    assert!(sp.groups.len() >= 2, "bridge topology must spill: {} groups", sp.groups.len());
    // camera 10 is the bridge: its left half belongs to intersection 0's
    // groups, its right half to intersection 1's
    assert!(
        sp.bridge_cameras().contains(&10),
        "bridge camera not split: bridges {:?}",
        sp.bridge_cameras()
    );
    // no spill group may mix the two rigs — they are joined only through
    // the corridor cameras
    for g in &sp.groups {
        let rig0 = g.cameras.iter().any(|&c| c < 4);
        let rig1 = g.cameras.iter().any(|&c| (4..8).contains(&c));
        assert!(
            !(rig0 && rig1),
            "a spill group mixes both rigs: {:?}",
            g.cameras
        );
    }
}

fn epoch_of_plan(plan: &crossroi::offline::OfflinePlan, n_cams: usize) -> Arc<PlanEpoch> {
    Arc::new(PlanEpoch::initial(
        plan.groups.clone(),
        plan.blocks.clone(),
        vec![true; n_cams],
        None,
        plan.masks.total_size(),
    ))
}

/// The acceptance scenario: drift perturbs only intersection 1, so at a
/// post-drift boundary the drifted component's constraint drift must
/// dominate — and, with a threshold between the two, only that component
/// re-solves while intersection 0 is carried forward.
#[test]
fn only_the_drifted_intersection_resolves() {
    let cfg = fleet_config(Some(1));
    let scenario = Scenario::build(&cfg.scenario);
    let method = Method::CrossRoi;
    let plan = build_plan(&scenario, &cfg.scenario, &cfg.system, &method).unwrap();
    let epoch0 = epoch_of_plan(&plan, 8);
    // boundary at segment 6 (t = 6 s into eval): the sliding window spans
    // the drift point at 2 s into eval
    let measure = Replanner::new(
        &scenario,
        &cfg.system,
        &method,
        OfflineOptions::default(),
        ReplanPolicy::Every(2),
        ReplanScope::Component,
        5,
        &plan,
        60,
    );
    measure.plan_epoch(1, 6, &epoch0).unwrap();
    let records = measure.records();
    let rec = &records[0];
    assert_eq!(rec.components.len(), 2, "fleet must check two components: {rec:?}");
    let calm = rec.components.iter().find(|c| c.cameras == vec![0, 1, 2, 3]).unwrap();
    let hot = rec.components.iter().find(|c| c.cameras == vec![4, 5, 6, 7]).unwrap();
    assert!(!calm.migrated && !hot.migrated, "stable intersections must not migrate");
    assert!(
        hot.drift > calm.drift + 0.02,
        "the drifted intersection must out-drift the stationary one: {} vs {}",
        hot.drift,
        calm.drift
    );

    // self-calibrating threshold between the two measured drifts: exactly
    // the drifted component fires, the stationary one is carried
    let threshold = (hot.drift + calm.drift) / 2.0;
    let gated = Replanner::new(
        &scenario,
        &cfg.system,
        &method,
        OfflineOptions::default(),
        ReplanPolicy::Drift { check_every: 2, threshold },
        ReplanScope::Component,
        5,
        &plan,
        60,
    );
    let next = gated.plan_epoch(1, 6, &epoch0).unwrap();
    let records = gated.records();
    let rec = &records[0];
    assert!(rec.replanned);
    let calm = rec.components.iter().find(|c| c.cameras == vec![0, 1, 2, 3]).unwrap();
    let hot = rec.components.iter().find(|c| c.cameras == vec![4, 5, 6, 7]).unwrap();
    assert!(hot.fired, "the drifted component must re-solve");
    assert!(!calm.fired, "the stationary component must be carried");
    assert_eq!(calm.solver, "carried");
    assert_eq!(rec.fired_components(), 1);
    assert_eq!(rec.carried_components(), 1);
    // the carried intersection's cameras keep their plan: their region
    // lists are byte-equal to epoch 0's and their epoch stamp stays 0
    for cam in 0..4 {
        assert_eq!(next.groups[cam], epoch0.groups[cam], "cam {cam} plan changed");
        assert_eq!(next.cam_epoch[cam], 0, "cam {cam} must keep its epoch stamp");
    }
    // the drifted intersection's masks must actually move
    assert!(
        (4..8).any(|cam| next.groups[cam] != epoch0.groups[cam]),
        "drifted component re-solved to an identical plan"
    );
}

/// Native reference detector with fixed, deterministic service times.
struct FixedCostInfer;

impl Infer for FixedCostInfer {
    fn infer(&self, frame: &[f32], blocks: Option<&[i32]>) -> Result<(Vec<f32>, f64)> {
        let (grid, _) = NativeInfer.infer(frame, blocks)?;
        let secs = match blocks {
            None => 0.004,
            Some(b) => 0.001 + 0.00004 * b.len() as f64,
        };
        Ok((grid, secs))
    }
}

fn opts(par: Parallelism, scope: ReplanScope) -> PipelineOptions {
    PipelineOptions {
        parallelism: par,
        encode_cost: EncodeCost::PerFrame(0.02),
        replan: ReplanPolicy::Every(2),
        replan_scope: scope,
        ..PipelineOptions::default()
    }
}

/// On a disjoint fleet, component scope must agree with fleet scope on
/// everything the masks determine, and the component-scoped run itself
/// must be byte-identical across pipeline schedules.
#[test]
fn component_scope_is_byte_identical_on_a_disjoint_fleet() {
    let cfg = fleet_config(None);
    let scenario = Scenario::build(&cfg.scenario);
    let run = |par: Parallelism, scope: ReplanScope| -> MethodReport {
        run_method_with(
            &scenario,
            &cfg.system,
            &FixedCostInfer,
            &Method::CrossRoi,
            None,
            &opts(par, scope),
        )
        .unwrap()
        .0
    };
    let comp = run(Parallelism::PerCamera, ReplanScope::Component);
    // canaries: stationary traffic keeps every solve warm and no camera
    // migrates — the preconditions for cross-scope identity
    assert_eq!(comp.replan_migrations, 0);
    assert_eq!(comp.replan_warm_count, comp.replan_count);

    let fleet = run(Parallelism::PerCamera, ReplanScope::Fleet);
    assert_eq!(fleet.replan_warm_count, fleet.replan_count);
    assert_eq!(fleet.accuracy, comp.accuracy);
    assert_eq!(fleet.missed_per_frame, comp.missed_per_frame);
    assert_eq!(fleet.bytes_total, comp.bytes_total);
    assert_eq!(fleet.network_mbps_per_cam, comp.network_mbps_per_cam);
    assert_eq!(fleet.mask_tiles, comp.mask_tiles);
    assert_eq!(fleet.regions_per_cam, comp.regions_per_cam);
    assert_eq!(fleet.latency.camera, comp.latency.camera);
    assert_eq!(fleet.latency.network, comp.latency.network);
    assert_eq!(fleet.latency.server, comp.latency.server);
    assert_eq!(fleet.latency_p95, comp.latency_p95);

    // byte-identity across schedules for the component-scoped run
    let json = |par: Parallelism| -> String {
        let mut r = run(par, ReplanScope::Component);
        r.zero_wall_clock();
        r.to_json().to_string_pretty(2)
    };
    let reference = json(Parallelism::Sequential);
    for par in [Parallelism::PerCamera, Parallelism::Workers(3)] {
        assert_eq!(
            reference,
            json(par),
            "{par:?} diverged from the sequential reference under component re-planning"
        );
    }
}

/// The ROADMAP residual: a migration decision fired by a *full* pipeline
/// run.  The corridor gate keeps the bridge trio blind during profiling
/// (every EW arm is silent until `corridor_at_secs`), so the offline
/// plan partitions the fleet into its two intersections; when the
/// corridor comes alive mid-eval, the sliding window fuses the fleet
/// through the trio and the re-planner must record a real component
/// migration — byte-identically across planner pool sizes.
#[test]
fn corridor_activation_fires_a_real_migration_through_the_pipeline() {
    let mut cfg = fleet_config(None);
    cfg.scenario.bridge_cameras = true;
    cfg.scenario.eval_secs = 12.0;
    cfg.scenario.corridor_at_secs = 9.0; // 1 s into the eval window
    cfg.scenario.validate().unwrap();
    let scenario = Scenario::build(&cfg.scenario);
    assert_eq!(scenario.cameras.len(), 11, "2 rigs of 4 + the corridor trio");
    // with the corridor gated, profiling must NOT see the fused fleet:
    // the trio (cameras 8–10) has nothing to co-occur through
    let comps = profile_partition(&scenario);
    assert!(
        comps.iter().all(|c| c.iter().all(|&cam| cam < 8)),
        "corridor must stay silent during profiling: {comps:?}"
    );

    let json_of = |threads: usize| -> String {
        let pipe = PipelineOptions {
            planner_threads: threads,
            ..opts(Parallelism::PerCamera, ReplanScope::Component)
        };
        let (mut r, _) = run_method_with(
            &scenario,
            &cfg.system,
            &FixedCostInfer,
            &Method::CrossRoi,
            None,
            &pipe,
        )
        .unwrap();
        assert!(
            r.replan_migrations > 0,
            "the corridor activation must fire a membership change"
        );
        // the migrated membership must actually involve the corridor trio
        assert!(
            r.replan_records.iter().any(|rec| rec
                .components
                .iter()
                .any(|c| c.migrated && c.cameras.iter().any(|&cam| cam >= 8))),
            "no migrated component includes a corridor camera: {:?}",
            r.replan_records
        );
        r.zero_wall_clock();
        r.to_json().to_string_pretty(2)
    };
    let reference = json_of(1);
    for threads in [2, 8] {
        assert_eq!(
            reference,
            json_of(threads),
            "--planner-threads {threads} diverged on the membership-change scenario"
        );
    }
}

/// Each epoch's compute phase fans fired components out over the shared
/// planner pool; the report must stay byte-identical across pool sizes
/// on both the drifted-intersection fleet and the bridge-fused fleet
/// (whose single giant component exercises the inner-thread split).
#[test]
fn planner_pool_is_byte_identical_across_thread_counts() {
    let mut bridged = fleet_config(None);
    bridged.scenario.bridge_cameras = true;
    bridged.scenario.validate().unwrap();
    for cfg in [fleet_config(Some(1)), bridged] {
        let scenario = Scenario::build(&cfg.scenario);
        let json_of = |threads: usize| -> String {
            let pipe = PipelineOptions {
                planner_threads: threads,
                ..opts(Parallelism::PerCamera, ReplanScope::Component)
            };
            let (mut r, _) = run_method_with(
                &scenario,
                &cfg.system,
                &FixedCostInfer,
                &Method::CrossRoi,
                None,
                &pipe,
            )
            .unwrap();
            // the pool counters and grid recycling are schedule-dependent
            // diagnostics — asserted here before zero_wall_clock strips
            // them from the byte-compared JSON
            assert!(r.planner_epochs_computed > 0, "re-plan epochs must have computed");
            assert!(r.replan_count > 0, "Every(2) must fire component solves");
            assert_eq!(r.planner_components_solved, r.replan_count);
            assert!(r.planner_max_concurrent >= 1);
            assert!(
                r.arena_grid_reuses > 0,
                "server-side grid buffers must recycle: {} allocs, {} reuses",
                r.arena_grid_allocs,
                r.arena_grid_reuses
            );
            r.zero_wall_clock();
            r.to_json().to_string_pretty(2)
        };
        let reference = json_of(1);
        for threads in [2, 8] {
            assert_eq!(
                reference,
                json_of(threads),
                "--planner-threads {threads} diverged from the single-threaded re-plan"
            );
        }
    }
}
