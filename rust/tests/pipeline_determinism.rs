//! Determinism: the stage-parallel pipeline must produce byte-identical
//! `MethodReport` JSON across repeated runs and across thread counts.
//!
//! Wall-clock measurement is replaced by deterministic cost models — a
//! fixed-seconds inference backend and a per-frame encode cost — so every
//! field of the report (bytes, accuracy, the full DES latency breakdown)
//! is a pure function of the scenario seed.  `offline_seconds` is the one
//! inherently wall-clock diagnostic; the comparison zeroes it.

use anyhow::Result;
use crossroi::config::Config;
use crossroi::coordinator::{run_method_with, Infer, Method, MethodReport, NativeInfer};
use crossroi::pipeline::{EncodeCost, Parallelism, PipelineOptions};
use crossroi::sim::Scenario;

/// Native reference detector with a fixed, deterministic service time.
struct FixedCostInfer;

impl Infer for FixedCostInfer {
    fn infer(&self, frame: &[f32], blocks: Option<&[i32]>) -> Result<(Vec<f32>, f64)> {
        let (grid, _) = NativeInfer.infer(frame, blocks)?;
        // dense costs more than RoI, like the real executables
        let secs = match blocks {
            None => 0.004,
            Some(b) => 0.001 + 0.00004 * b.len() as f64,
        };
        Ok((grid, secs))
    }
}

fn small() -> (Scenario, Config) {
    let mut cfg = Config::test_small();
    cfg.scenario.profile_secs = 10.0;
    cfg.scenario.eval_secs = 6.0;
    (Scenario::build(&cfg.scenario), cfg)
}

fn report_json(scenario: &Scenario, cfg: &Config, method: &Method, par: Parallelism) -> String {
    let opts = PipelineOptions {
        parallelism: par,
        encode_cost: EncodeCost::PerFrame(0.02),
        ..PipelineOptions::default()
    };
    let (mut report, _) =
        run_method_with(scenario, &cfg.system, &FixedCostInfer, method, None, &opts).unwrap();
    // the offline phase is profiled with a real clock; everything else in
    // the report is deterministic under the fixed cost models
    report.offline_seconds = 0.0;
    report.to_json().to_string_pretty(2)
}

fn assert_identical_across_schedules(method: Method) {
    let (scenario, cfg) = small();
    let reference = report_json(&scenario, &cfg, &method, Parallelism::Sequential);
    assert!(reference.contains("\"accuracy\""));
    // repeated run, same schedule: byte-identical
    let again = report_json(&scenario, &cfg, &method, Parallelism::Sequential);
    assert_eq!(reference, again, "{}: sequential rerun diverged", method.name());
    // different thread counts: byte-identical
    for par in [Parallelism::PerCamera, Parallelism::Workers(1), Parallelism::Workers(3)] {
        let parallel = report_json(&scenario, &cfg, &method, par);
        assert_eq!(
            reference, parallel,
            "{}: {par:?} diverged from the sequential reference",
            method.name()
        );
    }
}

#[test]
fn baseline_is_deterministic_across_schedules() {
    assert_identical_across_schedules(Method::Baseline);
}

#[test]
fn crossroi_is_deterministic_across_schedules() {
    assert_identical_across_schedules(Method::CrossRoi);
}

#[test]
fn crossroi_reducto_is_deterministic_across_schedules() {
    // exercises the stateful filter stage (kept/dropped frames must not
    // depend on scheduling)
    assert_identical_across_schedules(Method::CrossRoiReducto(0.85));
}

#[test]
fn parallel_run_reports_expected_shape() {
    let (scenario, cfg) = small();
    let opts = PipelineOptions::default();
    let (report, reported) = run_method_with(
        &scenario,
        &cfg.system,
        &FixedCostInfer,
        &Method::Baseline,
        None,
        &opts,
    )
    .unwrap();
    let eval_frames = (cfg.scenario.eval_secs * cfg.scenario.fps).round() as usize;
    assert_eq!(report.frames_total, eval_frames * cfg.scenario.n_cameras);
    assert_eq!(reported.len(), eval_frames);
    assert!(report.network_mbps_total > 0.0);
    assert!(report.server_hz > 0.0);
    assert!(report.latency.total() > 0.0);
    assert!(report.accuracy > 0.5, "baseline accuracy {}", report.accuracy);
}

#[test]
fn measured_mode_still_produces_consistent_structure() {
    // wall-clock mode can't be byte-compared, but the deterministic
    // fields must match the modelled run exactly
    let (scenario, cfg) = small();
    let measured = PipelineOptions {
        parallelism: Parallelism::PerCamera,
        encode_cost: EncodeCost::Measured,
        ..PipelineOptions::default()
    };
    let modelled = PipelineOptions {
        parallelism: Parallelism::Sequential,
        encode_cost: EncodeCost::PerFrame(0.02),
        ..PipelineOptions::default()
    };
    let (a, _) = run_method_with(
        &scenario, &cfg.system, &FixedCostInfer, &Method::CrossRoi, None, &measured,
    )
    .unwrap();
    let (b, _) = run_method_with(
        &scenario, &cfg.system, &FixedCostInfer, &Method::CrossRoi, None, &modelled,
    )
    .unwrap();
    deterministic_fields_match(&a, &b);
}

fn deterministic_fields_match(a: &MethodReport, b: &MethodReport) {
    assert_eq!(a.bytes_total, b.bytes_total);
    assert_eq!(a.accuracy, b.accuracy);
    assert_eq!(a.missed_per_frame, b.missed_per_frame);
    assert_eq!(a.frames_reduced, b.frames_reduced);
    assert_eq!(a.mask_tiles, b.mask_tiles);
    assert_eq!(a.regions_per_cam, b.regions_per_cam);
    assert_eq!(a.network_mbps_per_cam, b.network_mbps_per_cam);
}
