//! Cross-camera canvas consolidation properties (DESIGN.md §13):
//! packing sparse RoI cameras into shared dense canvases must be an
//! *invisible* routing optimization.  On a 16-camera fleet the
//! canvas-routed detections are byte-identical to the per-camera RoI
//! route, the consolidated run's full report is byte-identical across
//! camera schedules and `--offline-threads` counts, and a `--fail`
//! dropout re-packs the surviving cameras' canvases without disturbing
//! their detections.
//!
//! Wall-clock measurement is replaced by the same deterministic cost
//! models as `rust/tests/pipeline_determinism.rs`.

use anyhow::Result;
use crossroi::config::{Config, FaultEvent};
use crossroi::coordinator::{run_method_with, Infer, Method, MethodReport, NativeInfer};
use crossroi::offline::OfflineOptions;
use crossroi::pipeline::{ConsolidateMode, EncodeCost, Parallelism, PipelineOptions};
use crossroi::sim::Scenario;

/// Native reference detector with fixed, deterministic service times.
struct FixedCostInfer;

impl Infer for FixedCostInfer {
    fn infer(&self, frame: &[f32], blocks: Option<&[i32]>) -> Result<(Vec<f32>, f64)> {
        let (grid, _) = NativeInfer.infer(frame, blocks)?;
        let secs = match blocks {
            None => 0.004,
            Some(b) => 0.001 + 0.00004 * b.len() as f64,
        };
        Ok((grid, secs))
    }
}

/// The acceptance fleet: 16 cameras around one intersection, shortened
/// windows so the nine full runs below stay test-suite friendly.
fn fleet16(faults: Vec<FaultEvent>) -> (Scenario, Config) {
    let mut cfg = Config::test_small();
    cfg.scenario.n_cameras = 16;
    cfg.scenario.profile_secs = 10.0;
    cfg.scenario.eval_secs = 4.0;
    cfg.scenario.faults = faults;
    cfg.scenario.validate().unwrap();
    (Scenario::build(&cfg.scenario), cfg)
}

fn run(
    scenario: &Scenario,
    cfg: &Config,
    consolidate: ConsolidateMode,
    par: Parallelism,
    offline_threads: usize,
) -> MethodReport {
    let opts = PipelineOptions {
        parallelism: par,
        encode_cost: EncodeCost::PerFrame(0.02),
        offline: OfflineOptions { threads: offline_threads, ..OfflineOptions::default() },
        consolidate,
        ..PipelineOptions::default()
    };
    let (report, _) =
        run_method_with(scenario, &cfg.system, &FixedCostInfer, &Method::CrossRoi, None, &opts)
            .unwrap();
    report
}

/// Everything detection-derived must match between the canvas route and
/// the per-camera RoI route (service times legitimately differ — the
/// whole point — so latency fields are not compared here).
fn detections_match(on: &MethodReport, off: &MethodReport, what: &str) {
    assert_eq!(on.accuracy, off.accuracy, "{what}: accuracy diverged");
    assert_eq!(on.missed_per_frame, off.missed_per_frame, "{what}: misses diverged");
    assert_eq!(on.frames_total, off.frames_total, "{what}: frame count diverged");
    assert_eq!(on.frames_reduced, off.frames_reduced, "{what}: filter decisions diverged");
    assert_eq!(on.bytes_total, off.bytes_total, "{what}: encoded bytes diverged");
    assert_eq!(on.mask_tiles, off.mask_tiles, "{what}: plan diverged");
    assert_eq!(on.regions_per_cam, off.regions_per_cam, "{what}: groups diverged");
}

/// Canvas route on vs off: byte-identical detections on the 16-camera
/// fleet, with the consolidated run actually exercising canvases.
#[test]
fn canvas_route_matches_roi_route_detections() {
    let (scenario, cfg) = fleet16(Vec::new());
    let on = run(&scenario, &cfg, ConsolidateMode::On, Parallelism::PerCamera, 1);
    let off = run(&scenario, &cfg, ConsolidateMode::Off, Parallelism::PerCamera, 1);
    assert!(
        on.canvas_cams >= 2,
        "fleet too dense to consolidate ({} canvas cams) — the test proves nothing",
        on.canvas_cams
    );
    assert!(on.canvas_count > 0, "no canvases were packed");
    assert!(
        on.canvas_count < on.frames_total,
        "consolidation must fold jobs: {} canvases for {} frames",
        on.canvas_count,
        on.frames_total
    );
    assert_eq!(off.canvas_cams, 0, "the off run must not consolidate");
    assert_eq!(off.canvas_count, 0, "the off run must not pack canvases");
    detections_match(&on, &off, "consolidate on vs off");
}

/// The consolidated run's full serialized report is a pure function of
/// the scenario: byte-identical across camera-side schedules and
/// `--offline-threads 1|2|8` (packing is input-order independent, and
/// per-job service times never depend on batch composition).
#[test]
fn canvas_route_is_byte_identical_across_schedules_and_threads() {
    let (scenario, cfg) = fleet16(Vec::new());
    let json_of = |par: Parallelism, threads: usize| -> String {
        let mut r = run(&scenario, &cfg, ConsolidateMode::On, par, threads);
        assert!(r.canvas_count > 0, "{par:?}/{threads}: no canvases were packed");
        r.zero_wall_clock();
        r.to_json().to_string_pretty(2)
    };
    let reference = json_of(Parallelism::Sequential, 1);
    for (par, threads) in [
        (Parallelism::PerCamera, 1),
        (Parallelism::Workers(3), 1),
        (Parallelism::PerCamera, 2),
        (Parallelism::PerCamera, 8),
    ] {
        assert_eq!(
            reference,
            json_of(par, threads),
            "{par:?} with --offline-threads {threads} diverged from the sequential reference"
        );
    }
}

/// A camera dropout mid-window (`--fail 0@1.5`) removes its jobs from
/// the batches; the survivors' canvases re-pack and their detections
/// still match the per-camera RoI route exactly.
#[test]
fn canvases_repack_around_a_dropout() {
    let faults = vec![FaultEvent { cam: 0, start_secs: 1.5, end_secs: None }];
    let (scenario, cfg) = fleet16(faults);
    let on = run(&scenario, &cfg, ConsolidateMode::On, Parallelism::PerCamera, 1);
    let off = run(&scenario, &cfg, ConsolidateMode::Off, Parallelism::PerCamera, 1);
    assert!(on.canvas_count > 0, "survivors must still consolidate");
    detections_match(&on, &off, "faulted consolidate on vs off");
    // the dead camera's segments after 1.5 s are never produced, so the
    // faulted run streams fewer bytes — the canvas route really saw a
    // different job set and re-packed, not a replayed fault-free batch
    let (clean, _) = fleet16(Vec::new());
    let fault_free = run(&clean, &cfg, ConsolidateMode::On, Parallelism::PerCamera, 1);
    assert!(
        on.bytes_total < fault_free.bytes_total,
        "the dropout must cost streamed bytes: {} vs {}",
        on.bytes_total,
        fault_free.bytes_total
    );
}
