//! Steady-state allocation audit for the per-frame hot path (DESIGN.md
//! §9): after warm-up, render → Reducto filter → masked convert → encode
//! → RoI inference → objectness decode — plus the consolidated canvas
//! route (pack → gather → dense inference → scatter, DESIGN.md §13) —
//! must perform ZERO heap allocations per frame.  A counting global
//! allocator wraps the system allocator; this file holds exactly one
//! test so no concurrent test can pollute the counter.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

use crossroi::codec::RegionStream;
use crossroi::config::Config;
use crossroi::pipeline::canvas::{
    active_cells, gather_into, inflate_clip, scatter_into, GATHER_INFLATE_CELLS, GUTTER_PX,
    SCATTER_INFLATE_CELLS,
};
use crossroi::pipeline::{FilterStage, ReductoFilterStage};
use crossroi::runtime::native::{detect_full_into, detect_roi_into, DetectScratch};
use crossroi::runtime::postproc::{decode_objectness_into, DecodeScratch, Detection};
use crossroi::sim::render::Frame;
use crossroi::sim::{Scenario, FRAME_H, FRAME_W};
use crossroi::tilegroup::pack::{PackItem, Packer, Placement};
use crossroi::util::geometry::IRect;

static ALLOCATIONS: AtomicUsize = AtomicUsize::new(0);

/// Counts every allocation event (alloc, alloc_zeroed, realloc) and
/// delegates to the system allocator.  Deallocation is free and not
/// counted.
struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// The warm-up budget: frame 0 sizes every reused buffer, frame 1 builds
/// the encoder's second reconstruction plane set (the prev/spare
/// rotation), frame 2 proves the rotation closed.  From frame 3 on the
/// loop must not touch the allocator at all.
const WARM_UP_FRAMES: usize = 3;

#[test]
fn steady_state_frame_loop_is_allocation_free() {
    let cfg = Config::test_small();
    let scenario = Scenario::build(&cfg.scenario);
    let renderer = scenario.renderer();

    // the 25%-RoI shape the bench measures, with an odd-offset filter mask
    let mask = [IRect::new(64, 48, 160, 96)];
    let mut stream = RegionStream::new(IRect::new(64, 48, 160, 96), 6.0);
    // negative threshold = the disabled filter: the frame diff still runs
    // in full every frame (the allocation surface under audit) but every
    // frame is kept, so the measured loop deterministically exercises the
    // whole keep path regardless of scene content
    let mut filter = ReductoFilterStage::new(&[IRect::new(65, 49, 150, 90)], -1.0);

    let mut frame = Frame::new(1, 1);
    let mut pixels: Vec<f32> = Vec::new();

    // the server side of the path: RoI-restricted native inference into a
    // reused grid, then objectness decode into reused traversal buffers —
    // the same `_into` surfaces `BatchedInfer` recycles through the arena
    // and its thread-local scratch
    let blocks: [i32; 3] = [0, 11, 25];
    let mut det_scratch = DetectScratch::new();
    let mut grid: Vec<f32> = Vec::new();
    let mut dec_scratch = DecodeScratch::new();
    // 3 active 32px blocks expose at most 12 grid cells, so 16 bounds
    // the detection count whatever the scene does per frame
    let mut dets: Vec<Detection> = Vec::with_capacity(16);

    // the consolidated canvas route (DESIGN.md §13): the kept group's
    // gather rect packed onto a canvas, inferred densely, scattered back
    // — every buffer reused, like `BatchedInfer`'s arena-backed path
    let gather = inflate_clip(mask[0], GATHER_INFLATE_CELLS, FRAME_W, FRAME_H);
    let scatter = inflate_clip(mask[0], SCATTER_INFLATE_CELLS, FRAME_W, FRAME_H);
    let items = [PackItem { id: 0, w: gather.w, h: gather.h }];
    let mut packer = Packer::new(FRAME_W, FRAME_H, GUTTER_PX);
    let mut placements: Vec<Placement> = Vec::new();
    let mut canvas: Vec<f32> = Vec::new();
    let mut canvas_grid: Vec<f32> = Vec::new();
    let mut cam_grid: Vec<f32> = Vec::new();
    let mut active: Vec<bool> = Vec::new();

    let mut step = |i: usize,
                    frame: &mut Frame,
                    pixels: &mut Vec<f32>,
                    det_scratch: &mut DetectScratch,
                    grid: &mut Vec<f32>,
                    dec_scratch: &mut DecodeScratch,
                    dets: &mut Vec<Detection>|
     -> bool {
        renderer.render_into(0, i, frame);
        let kept = filter.keep(frame, i == 0);
        frame.masked_f32_into(&mask, pixels);
        stream.encode_frame(frame);
        detect_roi_into(
            pixels,
            FRAME_H as usize,
            FRAME_W as usize,
            &blocks,
            32,
            10,
            det_scratch,
            grid,
        );
        decode_objectness_into(grid, 12, 20, 16, 0.25, dec_scratch, dets);
        // consolidated route over the same frame: re-pack (idempotent,
        // scratch-reusing), gather into the recycled canvas, dense
        // inference, scatter into the recycled camera grid, decode
        packer.pack(&items, &mut placements);
        let p = placements[0];
        canvas.clear();
        canvas.resize((FRAME_W * FRAME_H * 3) as usize, 0.0);
        gather_into(&mut canvas, FRAME_W as usize, pixels, FRAME_W as usize, gather, p.x, p.y);
        detect_full_into(
            &canvas,
            FRAME_H as usize,
            FRAME_W as usize,
            det_scratch,
            &mut canvas_grid,
        );
        active_cells(&blocks, 20, 12, 2, 10, &mut active);
        cam_grid.clear();
        cam_grid.resize(240, 0.0);
        scatter_into(&mut cam_grid, &canvas_grid, 20, scatter, gather, p.x, p.y, &active);
        decode_objectness_into(&cam_grid, 12, 20, 16, 0.25, dec_scratch, dets);
        kept
    };

    for i in 0..WARM_UP_FRAMES {
        step(
            i,
            &mut frame,
            &mut pixels,
            &mut det_scratch,
            &mut grid,
            &mut dec_scratch,
            &mut dets,
        );
    }

    let before = ALLOCATIONS.load(Ordering::Relaxed);
    let mut kept_frames = 0usize;
    for i in WARM_UP_FRAMES..WARM_UP_FRAMES + 10 {
        if step(
            i,
            &mut frame,
            &mut pixels,
            &mut det_scratch,
            &mut grid,
            &mut dec_scratch,
            &mut dets,
        ) {
            kept_frames += 1;
        }
    }
    let after = ALLOCATIONS.load(Ordering::Relaxed);

    assert_eq!(kept_frames, 10, "the measured loop must take the kept-frame path");
    assert_eq!(
        after - before,
        0,
        "steady-state frame loop allocated {} times over 10 frames",
        after - before
    );
}
