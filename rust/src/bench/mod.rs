//! Bench harness (criterion is unavailable offline — DESIGN.md §3).
//!
//! `cargo bench` runs our `harness = false` bench binaries; each uses
//! [`time_it`] for microbenchmarks and [`Table`] to print the paper-shaped
//! rows (Tables 2–4, Figs 8–11).

use std::time::Instant;

/// Timing summary of a microbenchmark.
#[derive(Debug, Clone, Copy)]
pub struct Timing {
    pub iters: usize,
    pub mean_secs: f64,
    pub min_secs: f64,
    pub max_secs: f64,
}

impl Timing {
    pub fn per_iter_display(&self) -> String {
        let s = self.mean_secs;
        if s >= 1.0 {
            format!("{s:.3} s")
        } else if s >= 1e-3 {
            format!("{:.3} ms", s * 1e3)
        } else {
            format!("{:.1} µs", s * 1e6)
        }
    }
}

/// Time `f` with warmup; `target_secs` bounds total measurement time.
pub fn time_it<F: FnMut()>(warmup: usize, iters: usize, target_secs: f64, mut f: F) -> Timing {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(iters);
    let start = Instant::now();
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
        if start.elapsed().as_secs_f64() > target_secs {
            break;
        }
    }
    let n = times.len().max(1);
    Timing {
        iters: n,
        mean_secs: times.iter().sum::<f64>() / n as f64,
        min_secs: times.iter().cloned().fold(f64::INFINITY, f64::min),
        max_secs: times.iter().cloned().fold(0.0, f64::max),
    }
}

/// A simple aligned-column table printer.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        self.rows.push(cells);
    }

    pub fn print(&self, title: &str) {
        println!("\n=== {title} ===");
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let cols: Vec<String> = cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>width$}", c, width = widths[i]))
                .collect();
            println!("  {}", cols.join("  "));
        };
        line(&self.headers);
        println!("  {}", widths.iter().map(|w| "-".repeat(*w)).collect::<Vec<_>>().join("  "));
        for row in &self.rows {
            line(row);
        }
    }
}

/// Format a float with fixed decimals (bench-table convenience).
pub fn fmt(v: f64, decimals: usize) -> String {
    format!("{v:.decimals$}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_it_measures() {
        let t = time_it(1, 10, 5.0, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(t.iters >= 1);
        assert!(t.mean_secs >= 0.0);
        assert!(t.min_secs <= t.mean_secs);
        assert!(t.mean_secs <= t.max_secs.max(1e-12));
    }

    #[test]
    fn table_rows_must_match_headers() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        t.print("test");
    }

    #[test]
    #[should_panic(expected = "column count mismatch")]
    fn table_rejects_ragged_rows() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn timing_display_units() {
        let t = Timing { iters: 1, mean_secs: 2.0, min_secs: 2.0, max_secs: 2.0 };
        assert!(t.per_iter_display().ends_with(" s"));
        let t = Timing { iters: 1, mean_secs: 2e-3, min_secs: 0.0, max_secs: 0.0 };
        assert!(t.per_iter_display().ends_with(" ms"));
        let t = Timing { iters: 1, mean_secs: 2e-6, min_secs: 0.0, max_secs: 0.0 };
        assert!(t.per_iter_display().ends_with(" µs"));
    }
}
