//! Command-line argument parsing (clap is unavailable offline).
//!
//! Subcommand + `--flag value` / `--flag` conventions, with typed lookups
//! and an auto-generated usage string.

use anyhow::{bail, Context, Result};
use std::collections::HashMap;

/// Parsed command line: a subcommand, flags and positional args.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    flags: HashMap<String, String>,
    switches: Vec<String>,
    pub positional: Vec<String>,
}

/// Which flags take a value (everything else is a boolean switch).
pub const VALUE_FLAGS: &[&str] = &[
    "config", "artifacts", "seed", "segment-secs", "svm-gamma", "ransac-theta",
    "reducto-target", "eval-secs", "profile-secs", "cameras", "method", "out",
    "bandwidth-mbps", "qp", "offline-threads", "solver", "shards",
    "replan-every", "replan-drift", "drift-at", "drift-strength",
    "replan-scope", "planner-threads", "intersections", "spacing",
    "drift-intersection",
];

impl Args {
    /// Parse `std::env::args()`-style input (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(input: I) -> Result<Args> {
        let mut out = Args::default();
        let mut it = input.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                let name = name.to_string();
                if let Some(eq) = name.find('=') {
                    out.flags.insert(name[..eq].to_string(), name[eq + 1..].to_string());
                } else if VALUE_FLAGS.contains(&name.as_str()) {
                    let v = it
                        .next()
                        .with_context(|| format!("flag --{name} expects a value"))?;
                    out.flags.insert(name, v);
                } else {
                    out.switches.push(name);
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(tok);
            } else {
                out.positional.push(tok);
            }
        }
        Ok(out)
    }

    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn f64_flag(&self, name: &str) -> Result<Option<f64>> {
        match self.flag(name) {
            None => Ok(None),
            Some(v) => Ok(Some(
                v.parse::<f64>().with_context(|| format!("--{name} {v:?} is not a number"))?,
            )),
        }
    }

    pub fn u64_flag(&self, name: &str) -> Result<Option<u64>> {
        match self.flag(name) {
            None => Ok(None),
            Some(v) => Ok(Some(
                v.parse::<u64>().with_context(|| format!("--{name} {v:?} is not an integer"))?,
            )),
        }
    }

    pub fn switch(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name)
    }

    /// Error on unknown switches (catch typos).
    pub fn ensure_known_switches(&self, known: &[&str]) -> Result<()> {
        for s in &self.switches {
            if !known.contains(&s.as_str()) {
                bail!("unknown flag --{s}");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|t| t.to_string())).unwrap()
    }

    #[test]
    fn subcommand_flags_positional() {
        let a = parse("offline --seed 42 --verbose extra1 extra2");
        assert_eq!(a.subcommand.as_deref(), Some("offline"));
        assert_eq!(a.flag("seed"), Some("42"));
        assert!(a.switch("verbose"));
        assert_eq!(a.positional, vec!["extra1", "extra2"]);
    }

    #[test]
    fn equals_form() {
        let a = parse("run --svm-gamma=0.5");
        assert_eq!(a.f64_flag("svm-gamma").unwrap(), Some(0.5));
    }

    #[test]
    fn missing_value_errors() {
        let e = Args::parse(vec!["run".to_string(), "--seed".to_string()]);
        assert!(e.is_err());
    }

    #[test]
    fn typed_flag_errors() {
        let a = parse("run --seed abc");
        assert!(a.u64_flag("seed").is_err());
        assert!(a.u64_flag("missing").unwrap().is_none());
    }

    #[test]
    fn unknown_switch_detection() {
        let a = parse("run --bogus");
        assert!(a.ensure_known_switches(&["verbose"]).is_err());
        assert!(a.ensure_known_switches(&["bogus"]).is_ok());
    }
}
