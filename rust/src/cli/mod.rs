//! Command-line argument parsing (clap is unavailable offline).
//!
//! Subcommand + `--flag value` / `--flag` conventions, with typed lookups
//! and an auto-generated usage string.

use anyhow::{bail, Context, Result};
use std::collections::HashMap;

/// Parsed command line: a subcommand, flags and positional args.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    flags: HashMap<String, String>,
    /// Values of repeatable flags ([`MULTI_FLAGS`]), in argv order.
    multi: HashMap<String, Vec<String>>,
    switches: Vec<String>,
    pub positional: Vec<String>,
}

/// Which flags take a value (everything else is a boolean switch).
pub const VALUE_FLAGS: &[&str] = &[
    "config", "artifacts", "seed", "segment-secs", "svm-gamma", "ransac-theta",
    "reducto-target", "eval-secs", "profile-secs", "cameras", "method", "out",
    "bandwidth-mbps", "qp", "offline-threads", "solver", "shards",
    "replan-every", "replan-drift", "drift-at", "drift-strength",
    "replan-scope", "planner-threads", "intersections", "spacing",
    "drift-intersection", "scenario", "fail", "consolidate",
];

/// Value flags that may be given more than once; every occurrence is
/// kept, in order (a plain [`VALUE_FLAGS`] repeat overwrites).
pub const MULTI_FLAGS: &[&str] = &["fail"];

impl Args {
    /// Parse `std::env::args()`-style input (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(input: I) -> Result<Args> {
        let mut out = Args::default();
        let mut it = input.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                let name = name.to_string();
                if let Some(eq) = name.find('=') {
                    let (key, v) = (name[..eq].to_string(), name[eq + 1..].to_string());
                    if MULTI_FLAGS.contains(&key.as_str()) {
                        out.multi.entry(key).or_default().push(v);
                    } else {
                        out.flags.insert(key, v);
                    }
                } else if VALUE_FLAGS.contains(&name.as_str()) {
                    let v = it
                        .next()
                        .with_context(|| format!("flag --{name} expects a value"))?;
                    if MULTI_FLAGS.contains(&name.as_str()) {
                        out.multi.entry(name).or_default().push(v);
                    } else {
                        out.flags.insert(name, v);
                    }
                } else {
                    out.switches.push(name);
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(tok);
            } else {
                out.positional.push(tok);
            }
        }
        Ok(out)
    }

    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn f64_flag(&self, name: &str) -> Result<Option<f64>> {
        match self.flag(name) {
            None => Ok(None),
            Some(v) => Ok(Some(
                v.parse::<f64>().with_context(|| format!("--{name} {v:?} is not a number"))?,
            )),
        }
    }

    pub fn u64_flag(&self, name: &str) -> Result<Option<u64>> {
        match self.flag(name) {
            None => Ok(None),
            Some(v) => Ok(Some(
                v.parse::<u64>().with_context(|| format!("--{name} {v:?} is not an integer"))?,
            )),
        }
    }

    /// Every occurrence of a repeatable flag (see [`MULTI_FLAGS`]), in
    /// the order given; empty when absent.
    pub fn multi(&self, name: &str) -> &[String] {
        self.multi.get(name).map(Vec::as_slice).unwrap_or_default()
    }

    pub fn switch(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name)
    }

    /// Error on unknown switches (catch typos).
    pub fn ensure_known_switches(&self, known: &[&str]) -> Result<()> {
        for s in &self.switches {
            if !known.contains(&s.as_str()) {
                bail!("unknown flag --{s}");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|t| t.to_string())).unwrap()
    }

    #[test]
    fn subcommand_flags_positional() {
        let a = parse("offline --seed 42 --verbose extra1 extra2");
        assert_eq!(a.subcommand.as_deref(), Some("offline"));
        assert_eq!(a.flag("seed"), Some("42"));
        assert!(a.switch("verbose"));
        assert_eq!(a.positional, vec!["extra1", "extra2"]);
    }

    #[test]
    fn equals_form() {
        let a = parse("run --svm-gamma=0.5");
        assert_eq!(a.f64_flag("svm-gamma").unwrap(), Some(0.5));
    }

    #[test]
    fn missing_value_errors() {
        let e = Args::parse(vec!["run".to_string(), "--seed".to_string()]);
        assert!(e.is_err());
    }

    #[test]
    fn typed_flag_errors() {
        let a = parse("run --seed abc");
        assert!(a.u64_flag("seed").is_err());
        assert!(a.u64_flag("missing").unwrap().is_none());
    }

    #[test]
    fn repeated_multi_flag_keeps_all_values() {
        let a = parse("run --fail 1@2 --fail=0@3..5 --seed 7 --seed 9");
        assert_eq!(a.multi("fail"), ["1@2", "0@3..5"]);
        // Plain value flags still overwrite on repeat.
        assert_eq!(a.flag("seed"), Some("9"));
        assert!(a.multi("missing").is_empty());
    }

    #[test]
    fn unknown_switch_detection() {
        let a = parse("run --bogus");
        assert!(a.ensure_known_switches(&["verbose"]).is_err());
        assert!(a.ensure_known_switches(&["bogus"]).is_ok());
    }
}
