//! Reducto-style frame filtering (§5.4) — the SotA temporal filter the
//! paper integrates with (Fig. 12: RoI masks remove *spatial* redundancy,
//! then the frame filter removes *temporal* redundancy).
//!
//! Faithful two-phase structure: offline, per-camera low-level
//! frame-difference features are profiled against an accuracy target to
//! pick a filtering threshold; online, frames whose difference against the
//! last *sent* frame falls below the threshold are discarded and the
//! server reuses the previous result (the standard Reducto behaviour).

use crate::sim::render::Frame;
use crate::sim::Scenario;
use crate::util::geometry::IRect;

/// Luma delta (0..255) for a pixel to count as "changed" (public so the
/// [`frame_diff`] docs can cite it; rustdoc runs with `-D warnings`).
pub const PIXEL_DELTA: f32 = 12.0;

/// Candidate thresholds swept during profiling (fraction of changed
/// pixels within the RoI area).
const CANDIDATES: [f64; 10] =
    [0.0, 0.002, 0.005, 0.01, 0.02, 0.03, 0.05, 0.08, 0.12, 0.2];

/// Per-camera filtering thresholds learned offline.
#[derive(Debug, Clone)]
pub struct ReductoFilter {
    pub thresholds: Vec<f64>,
    /// Accuracy target the thresholds were tuned for.
    pub target: f64,
}

/// The fraction of pixels inside `regions` whose luma changed by more
/// than [`PIXEL_DELTA`] between two frames (the Reducto "area" feature).
pub fn frame_diff(prev: &Frame, cur: &Frame, regions: &[IRect]) -> f64 {
    let mut changed = 0u64;
    let mut total = 0u64;
    for r in regions {
        let x1 = (r.x + r.w).min(cur.w);
        let y1 = (r.y + r.h).min(cur.h);
        for y in r.y.min(cur.h)..y1 {
            for x in r.x.min(cur.w)..x1 {
                total += 1;
                if (cur.luma(x, y) - prev.luma(x, y)).abs() > PIXEL_DELTA {
                    changed += 1;
                }
            }
        }
    }
    if total == 0 {
        0.0
    } else {
        changed as f64 / total as f64
    }
}

/// Simulate keep/drop decisions for a diff sequence: frame 0 of each
/// segment is always kept; a frame is kept when its diff against the last
/// *kept* frame exceeds the threshold.  `diffs[i]` is against frame i-1's
/// pixels, so the filter tracks a running accumulated diff.
pub fn keep_decisions(diffs: &[f64], frames_per_segment: usize, threshold: f64) -> Vec<bool> {
    let mut keep = vec![false; diffs.len()];
    let mut acc = 0.0;
    for i in 0..diffs.len() {
        if i % frames_per_segment == 0 {
            keep[i] = true;
            acc = 0.0;
            continue;
        }
        acc += diffs[i];
        if acc > threshold {
            keep[i] = true;
            acc = 0.0;
        }
    }
    keep
}

/// Offline profiling (one camera): sweep thresholds, return the largest
/// one whose *unique-vehicle* accuracy proxy stays at or above `target`.
///
/// Accuracy proxy: for each profile frame, the vehicles "reported" are the
/// ground-truth detections of the last kept frame; per-frame accuracy is
/// `1 - |error|/|truth|` as in §5.1.2, averaged over the window.
pub fn profile_camera(
    scenario: &Scenario,
    cam: usize,
    diffs: &[f64],
    frames: std::ops::Range<usize>,
    frames_per_segment: usize,
    target: f64,
) -> f64 {
    let frame_ids: Vec<usize> = frames.collect();
    assert_eq!(frame_ids.len(), diffs.len());
    let mut best = 0.0;
    for &cand in CANDIDATES.iter() {
        let keep = keep_decisions(diffs, frames_per_segment, cand);
        let mut acc_sum = 0.0;
        let mut n = 0usize;
        let mut last_kept = 0usize;
        for (i, &f) in frame_ids.iter().enumerate() {
            if keep[i] {
                last_kept = i;
            }
            let truth: Vec<u32> =
                scenario.detections(cam, f).iter().map(|d| d.vehicle_id).collect();
            if truth.is_empty() {
                continue;
            }
            let reported: Vec<u32> = scenario
                .detections(cam, frame_ids[last_kept])
                .iter()
                .map(|d| d.vehicle_id)
                .collect();
            let err = (truth.len() as f64 - reported.len() as f64).abs() / truth.len() as f64;
            acc_sum += (1.0 - err).max(0.0);
            n += 1;
        }
        let acc = if n == 0 { 1.0 } else { acc_sum / n as f64 };
        if acc >= target && cand >= best {
            best = cand;
        }
    }
    best
}

impl ReductoFilter {
    /// Profile all cameras of a scenario over `frames` using rendered
    /// pixels restricted to `regions_per_cam` (full frame for plain
    /// Reducto; the RoI groups for CrossRoI-Reducto, per Fig. 12).
    pub fn profile(
        scenario: &Scenario,
        regions_per_cam: &[Vec<IRect>],
        frames: std::ops::Range<usize>,
        frames_per_segment: usize,
        target: f64,
    ) -> ReductoFilter {
        let renderer = scenario.renderer();
        let thresholds = (0..scenario.cameras.len())
            .map(|cam| {
                ReductoFilter::profile_one(
                    scenario,
                    &renderer,
                    cam,
                    &regions_per_cam[cam],
                    frames.clone(),
                    frames_per_segment,
                    target,
                )
            })
            .collect();
        ReductoFilter { thresholds, target }
    }

    /// Profile a single camera's threshold over `frames` (absolute frame
    /// indices) with the diff feature restricted to `regions` — the
    /// continuous re-profiling hook: when a re-plan changes a camera's
    /// RoI regions, its threshold is re-derived from the sliding window
    /// against exactly those regions (DESIGN.md §8) instead of staying
    /// profiled against the initial plan's.  The caller passes one
    /// [`Renderer`] shared across cameras — constructing a renderer
    /// rasterizes every camera's static background, which must not be
    /// paid per camera.
    #[allow(clippy::too_many_arguments)]
    pub fn profile_one(
        scenario: &Scenario,
        renderer: &crate::sim::Renderer<'_>,
        cam: usize,
        regions: &[IRect],
        frames: std::ops::Range<usize>,
        frames_per_segment: usize,
        target: f64,
    ) -> f64 {
        let ids: Vec<usize> = frames.clone().collect();
        let mut diffs = Vec::with_capacity(ids.len());
        let mut prev: Option<Frame> = None;
        for &f in &ids {
            let cur = renderer.render(cam, f);
            diffs.push(match &prev {
                None => 1.0,
                Some(p) => frame_diff(p, &cur, regions),
            });
            prev = Some(cur);
        }
        profile_camera(scenario, cam, &diffs, frames, frames_per_segment, target)
    }

    /// A disabled filter (keeps every frame) — target 1.0 degenerates to
    /// this, as in Table 4's first row.  The threshold is negative so even
    /// pixel-identical frames (zero diff) are kept.
    pub fn disabled(n_cameras: usize) -> ReductoFilter {
        ReductoFilter { thresholds: vec![-1.0; n_cameras], target: 1.0 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;

    #[test]
    fn diff_zero_for_identical() {
        let f = Frame::new(64, 64);
        assert_eq!(frame_diff(&f, &f, &[IRect::new(0, 0, 64, 64)]), 0.0);
    }

    #[test]
    fn diff_counts_changed_fraction() {
        let a = Frame::new(64, 64);
        let mut b = Frame::new(64, 64);
        for y in 0..32 {
            for x in 0..64 {
                b.set(x, y, [200, 200, 200]);
            }
        }
        let d = frame_diff(&a, &b, &[IRect::new(0, 0, 64, 64)]);
        assert!((d - 0.5).abs() < 1e-9, "{d}");
        // restricted to the unchanged half: zero
        let d2 = frame_diff(&a, &b, &[IRect::new(0, 32, 64, 32)]);
        assert_eq!(d2, 0.0);
    }

    #[test]
    fn zero_threshold_keeps_everything_changing() {
        let diffs = vec![1.0, 0.1, 0.1, 0.1];
        let keep = keep_decisions(&diffs, 10, 0.0);
        assert_eq!(keep, vec![true, true, true, true]);
    }

    #[test]
    fn high_threshold_keeps_segment_heads_only() {
        let diffs = vec![1.0, 0.01, 0.01, 0.01, 0.01, 0.01];
        let keep = keep_decisions(&diffs, 3, 10.0);
        assert_eq!(keep, vec![true, false, false, true, false, false]);
    }

    #[test]
    fn accumulated_small_diffs_eventually_trigger() {
        let diffs = vec![1.0, 0.04, 0.04, 0.04, 0.04];
        let keep = keep_decisions(&diffs, 100, 0.1);
        // 0.04+0.04 = 0.08 < 0.1; +0.04 = 0.12 > 0.1 -> kept, acc resets
        assert_eq!(keep, vec![true, false, false, true, false]);
    }

    #[test]
    fn lower_target_allows_higher_threshold() {
        let cfg = Config::test_small();
        let sc = Scenario::build(&cfg.scenario);
        let full: Vec<Vec<IRect>> =
            (0..5).map(|_| vec![IRect::new(0, 0, 320, 192)]).collect();
        let strict = ReductoFilter::profile(&sc, &full, 0..60, 10, 0.999);
        let loose = ReductoFilter::profile(&sc, &full, 0..60, 10, 0.85);
        for cam in 0..5 {
            assert!(
                loose.thresholds[cam] >= strict.thresholds[cam],
                "cam {cam}: loose {} < strict {}",
                loose.thresholds[cam],
                strict.thresholds[cam]
            );
        }
    }
}
