//! A minimal discrete-event simulation engine.
//!
//! Generic over the user's event type: the engine owns the clock and the
//! pending-event heap; the caller drains events in timestamp order and
//! schedules follow-ups.  Ties break by insertion sequence, which makes
//! runs bit-reproducible.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

struct Scheduled<E> {
    time: f64,
    seq: u64,
    event: E,
}

impl<E> PartialEq for Scheduled<E> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}

impl<E> Eq for Scheduled<E> {}

impl<E> PartialOrd for Scheduled<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl<E> Ord for Scheduled<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap; invert for earliest-first
        other
            .time
            .partial_cmp(&self.time)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// The event queue + virtual clock.
pub struct Des<E> {
    now: f64,
    seq: u64,
    queue: BinaryHeap<Scheduled<E>>,
    processed: u64,
}

impl<E> Des<E> {
    pub fn new() -> Des<E> {
        Des { now: 0.0, seq: 0, queue: BinaryHeap::new(), processed: 0 }
    }

    /// Current virtual time (seconds).
    pub fn now(&self) -> f64 {
        self.now
    }

    /// Number of events processed so far.
    pub fn processed(&self) -> u64 {
        self.processed
    }

    /// Schedule `event` at absolute time `time` (>= now, clamped).
    ///
    /// Panics on non-finite times: `Scheduled::cmp` falls back to
    /// `Ordering::Equal` when `partial_cmp` fails, so a single NaN would
    /// silently corrupt the heap order — and with it the bit-reproducible
    /// insertion-sequence tie-break — instead of failing loudly here.
    pub fn at(&mut self, time: f64, event: E) {
        assert!(
            time.is_finite(),
            "Des::at: event time must be finite, got {time} (now = {})",
            self.now
        );
        let t = time.max(self.now);
        self.queue.push(Scheduled { time: t, seq: self.seq, event });
        self.seq += 1;
    }

    /// Schedule `event` after a delay.
    ///
    /// Panics on non-finite delays (a NaN delay would otherwise be
    /// silently clamped to zero by the `max` below).
    pub fn after(&mut self, delay: f64, event: E) {
        assert!(delay.is_finite(), "Des::after: delay must be finite, got {delay}");
        debug_assert!(delay >= 0.0, "negative delay {delay}");
        self.at(self.now + delay.max(0.0), event);
    }

    /// The next event's time and payload without popping it; the clock
    /// does not advance (liveness monitors use this to check whether a
    /// deadline is due before draining).
    pub fn peek(&self) -> Option<(f64, &E)> {
        self.queue.peek().map(|s| (s.time, &s.event))
    }

    /// Pop the next event, advancing the clock.
    pub fn pop(&mut self) -> Option<(f64, E)> {
        let s = self.queue.pop()?;
        self.now = s.time;
        self.processed += 1;
        Some((s.time, s.event))
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }
}

impl<E> Default for Des<E> {
    fn default() -> Self {
        Des::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn events_fire_in_time_order() {
        let mut des: Des<u32> = Des::new();
        des.at(3.0, 3);
        des.at(1.0, 1);
        des.at(2.0, 2);
        let order: Vec<u32> = std::iter::from_fn(|| des.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, vec![1, 2, 3]);
        assert_eq!(des.now(), 3.0);
        assert_eq!(des.processed(), 3);
    }

    #[test]
    fn ties_break_by_insertion_order() {
        let mut des: Des<u32> = Des::new();
        for i in 0..10 {
            des.at(5.0, i);
        }
        let order: Vec<u32> = std::iter::from_fn(|| des.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn after_is_relative_to_now() {
        let mut des: Des<&str> = Des::new();
        des.at(10.0, "a");
        des.pop();
        des.after(5.0, "b");
        let (t, e) = des.pop().unwrap();
        assert_eq!((t, e), (15.0, "b"));
    }

    #[test]
    fn scheduling_in_the_past_is_clamped() {
        let mut des: Des<&str> = Des::new();
        des.at(10.0, "a");
        des.pop();
        des.at(3.0, "late");
        let (t, _) = des.pop().unwrap();
        assert_eq!(t, 10.0); // clamped to now, clock never goes backward
    }

    #[test]
    #[should_panic(expected = "event time must be finite")]
    fn nan_event_time_is_rejected() {
        let mut des: Des<u32> = Des::new();
        des.at(f64::NAN, 1);
    }

    #[test]
    #[should_panic(expected = "event time must be finite")]
    fn infinite_event_time_is_rejected() {
        let mut des: Des<u32> = Des::new();
        des.at(f64::INFINITY, 1);
    }

    #[test]
    #[should_panic(expected = "delay must be finite")]
    fn nan_delay_is_rejected() {
        let mut des: Des<u32> = Des::new();
        // NaN.max(0.0) is 0.0: without its own guard `after` would
        // silently schedule the event immediately
        des.after(f64::NAN, 1);
    }

    #[test]
    fn finite_ordering_is_unchanged_by_the_guard() {
        // mixed magnitudes, ties, and clamped-past times: the observable
        // order must be exactly what the pre-guard engine produced
        let mut des: Des<u32> = Des::new();
        des.at(1e-12, 0);
        des.at(5.0, 1);
        des.at(5.0, 2); // tie with 1: insertion order
        des.at(1e9, 3);
        des.at(0.0, 4);
        let order: Vec<u32> = std::iter::from_fn(|| des.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, vec![4, 0, 1, 2, 3]);
    }

    #[test]
    fn peek_does_not_advance_the_clock() {
        let mut des: Des<u32> = Des::new();
        des.at(2.0, 7);
        des.at(1.0, 3);
        assert_eq!(des.peek(), Some((1.0, &3)));
        assert_eq!(des.now(), 0.0);
        assert_eq!(des.processed(), 0);
        assert_eq!(des.pop(), Some((1.0, 3)));
        assert_eq!(des.peek(), Some((2.0, &7)));
        des.pop();
        assert_eq!(des.peek(), None);
    }

    #[test]
    fn interleaved_schedule_and_pop() {
        // a chain: each event schedules the next
        let mut des: Des<u32> = Des::new();
        des.at(0.0, 0);
        let mut fired = Vec::new();
        while let Some((_, e)) = des.pop() {
            fired.push(e);
            if e < 5 {
                des.after(1.0, e + 1);
            }
        }
        assert_eq!(fired, vec![0, 1, 2, 3, 4, 5]);
        assert_eq!(des.now(), 5.0);
    }
}
