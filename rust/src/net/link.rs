//! Shared uplink model: all cameras feed one server-side link of fixed
//! bandwidth (paper: 30 Mbps WiFi) with a propagation delay of RTT/2.
//!
//! The link is a FIFO fluid queue: a transfer of `bytes` admitted at time
//! `t` starts when the link is free, occupies it for `bytes·8/rate`, and
//! arrives `rtt/2` after its last bit leaves.  This is exactly the
//! queueing structure that turns lower per-camera bitrates into lower
//! end-to-end latency (Fig. 8f / Fig. 11).

/// A shared FIFO link.
#[derive(Debug, Clone)]
pub struct SharedLink {
    /// Bandwidth in bits per second.
    rate_bps: f64,
    /// One-way propagation delay (seconds).
    one_way: f64,
    /// Time the link becomes free.
    busy_until: f64,
    /// Total bytes admitted (for bandwidth accounting).
    total_bytes: u64,
}

impl SharedLink {
    pub fn new(bandwidth_mbps: f64, rtt_ms: f64) -> SharedLink {
        SharedLink {
            rate_bps: bandwidth_mbps * 1e6,
            one_way: rtt_ms / 1000.0 / 2.0,
            busy_until: 0.0,
            total_bytes: 0,
        }
    }

    /// Admit a transfer at time `now`; returns the arrival (fully
    /// received) time at the server.
    pub fn transfer(&mut self, now: f64, bytes: usize) -> f64 {
        let start = self.busy_until.max(now);
        let tx = bytes as f64 * 8.0 / self.rate_bps;
        self.busy_until = start + tx;
        self.total_bytes += bytes as u64;
        self.busy_until + self.one_way
    }

    /// Queueing delay a transfer admitted at `now` would currently face.
    pub fn backlog_delay(&self, now: f64) -> f64 {
        (self.busy_until - now).max(0.0)
    }

    pub fn total_bytes(&self) -> u64 {
        self.total_bytes
    }

    /// Serialization time for a payload on this link.
    pub fn tx_time(&self, bytes: usize) -> f64 {
        bytes as f64 * 8.0 / self.rate_bps
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_transfer_timing() {
        let mut link = SharedLink::new(30.0, 10.0);
        // 30 Mbps, 375_000 bytes = 3 Mbit -> 0.1 s + 5 ms one-way
        let arrive = link.transfer(0.0, 375_000);
        assert!((arrive - 0.105).abs() < 1e-9, "{arrive}");
    }

    #[test]
    fn fifo_queueing() {
        let mut link = SharedLink::new(30.0, 10.0);
        let a = link.transfer(0.0, 375_000); // busy 0..0.1
        let b = link.transfer(0.0, 375_000); // queued, busy 0.1..0.2
        assert!(b > a);
        assert!((b - 0.205).abs() < 1e-9, "{b}");
        // admitted later when the link is idle again: no queueing
        let c = link.transfer(1.0, 375_000);
        assert!((c - 1.105).abs() < 1e-9, "{c}");
    }

    #[test]
    fn backlog_delay_reports_queue() {
        let mut link = SharedLink::new(30.0, 0.0);
        link.transfer(0.0, 375_000);
        assert!((link.backlog_delay(0.0) - 0.1).abs() < 1e-9);
        assert_eq!(link.backlog_delay(0.2), 0.0);
    }

    #[test]
    fn accounting() {
        let mut link = SharedLink::new(10.0, 0.0);
        link.transfer(0.0, 1000);
        link.transfer(0.0, 2000);
        assert_eq!(link.total_bytes(), 3000);
        assert!((link.tx_time(1_250_000) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn faster_link_lower_latency() {
        let mut slow = SharedLink::new(10.0, 10.0);
        let mut fast = SharedLink::new(100.0, 10.0);
        assert!(fast.transfer(0.0, 100_000) < slow.transfer(0.0, 100_000));
    }
}
