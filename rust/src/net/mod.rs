//! Network substrate: a deterministic discrete-event engine and a
//! shared-link transport model (token-bucket bandwidth + RTT), replacing
//! the paper's emulated 30 Mbps / 10 ms WiFi (§5.1.3, DESIGN.md §3).

pub mod des;
pub mod link;

pub use des::Des;
pub use link::SharedLink;
