//! 8×8 DCT-II / DCT-III (separable, precomputed basis) and quantization.

use super::BLOCK;
use once_cell::sync::Lazy;

/// Precomputed orthonormal DCT-II basis: `BASIS[k][n] = c_k cos(...)`.
static BASIS: Lazy<[[f32; BLOCK]; BLOCK]> = Lazy::new(|| {
    let mut b = [[0.0f32; BLOCK]; BLOCK];
    let n = BLOCK as f32;
    for k in 0..BLOCK {
        let ck = if k == 0 { (1.0 / n).sqrt() } else { (2.0 / n).sqrt() };
        for x in 0..BLOCK {
            b[k][x] =
                ck * ((std::f32::consts::PI / n) * (x as f32 + 0.5) * k as f32).cos();
        }
    }
    b
});

/// Forward 8×8 DCT (rows then columns), in place on a row-major block.
pub fn forward(block: &mut [f32; BLOCK * BLOCK]) {
    let b = &*BASIS;
    let mut tmp = [0.0f32; BLOCK * BLOCK];
    // rows
    for y in 0..BLOCK {
        for k in 0..BLOCK {
            let mut acc = 0.0;
            for x in 0..BLOCK {
                acc += b[k][x] * block[y * BLOCK + x];
            }
            tmp[y * BLOCK + k] = acc;
        }
    }
    // cols
    for k in 0..BLOCK {
        for x in 0..BLOCK {
            let mut acc = 0.0;
            for y in 0..BLOCK {
                acc += b[k][y] * tmp[y * BLOCK + x];
            }
            block[k * BLOCK + x] = acc;
        }
    }
}

/// Inverse 8×8 DCT, in place.
pub fn inverse(block: &mut [f32; BLOCK * BLOCK]) {
    let b = &*BASIS;
    let mut tmp = [0.0f32; BLOCK * BLOCK];
    // cols (transpose of forward)
    for y in 0..BLOCK {
        for x in 0..BLOCK {
            let mut acc = 0.0;
            for k in 0..BLOCK {
                acc += b[k][y] * block[k * BLOCK + x];
            }
            tmp[y * BLOCK + x] = acc;
        }
    }
    // rows
    for y in 0..BLOCK {
        for x in 0..BLOCK {
            let mut acc = 0.0;
            for k in 0..BLOCK {
                acc += b[k][x] * tmp[y * BLOCK + k];
            }
            block[y * BLOCK + x] = acc;
        }
    }
}

/// JPEG-flavoured luma quantization weights (flat-ish, frequency-rising).
const QWEIGHT: [f32; BLOCK * BLOCK] = {
    let mut w = [0.0f32; BLOCK * BLOCK];
    let mut y = 0;
    while y < BLOCK {
        let mut x = 0;
        while x < BLOCK {
            w[y * BLOCK + x] = 1.0 + 0.45 * (x + y) as f32;
            x += 1;
        }
        y += 1;
    }
    w
};

/// Quantize DCT coefficients with quality parameter `qp` (≥ 1; higher ⇒
/// coarser).  Returns integer levels.
pub fn quantize(coeffs: &[f32; BLOCK * BLOCK], qp: f32) -> [i32; BLOCK * BLOCK] {
    let mut out = [0i32; BLOCK * BLOCK];
    for i in 0..BLOCK * BLOCK {
        let step = QWEIGHT[i] * qp;
        out[i] = (coeffs[i] / step).round() as i32;
    }
    out
}

/// Dequantize levels back to coefficient space.
pub fn dequantize(levels: &[i32; BLOCK * BLOCK], qp: f32) -> [f32; BLOCK * BLOCK] {
    let mut out = [0.0f32; BLOCK * BLOCK];
    for i in 0..BLOCK * BLOCK {
        out[i] = levels[i] as f32 * QWEIGHT[i] * qp;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_block() -> [f32; 64] {
        let mut b = [0.0f32; 64];
        for (i, v) in b.iter_mut().enumerate() {
            *v = ((i * 7919) % 255) as f32 - 128.0;
        }
        b
    }

    #[test]
    fn dct_roundtrip_is_identity() {
        let src = sample_block();
        let mut b = src;
        forward(&mut b);
        inverse(&mut b);
        for (a, b) in src.iter().zip(b.iter()) {
            assert!((a - b).abs() < 1e-2, "{a} vs {b}");
        }
    }

    #[test]
    fn dct_preserves_energy() {
        // orthonormal transform: Parseval
        let src = sample_block();
        let mut b = src;
        forward(&mut b);
        let e_in: f32 = src.iter().map(|x| x * x).sum();
        let e_out: f32 = b.iter().map(|x| x * x).sum();
        assert!((e_in - e_out).abs() / e_in < 1e-4);
    }

    #[test]
    fn flat_block_is_dc_only() {
        let mut b = [42.0f32; 64];
        forward(&mut b);
        assert!((b[0] - 42.0 * 8.0).abs() < 1e-3);
        for &c in &b[1..] {
            assert!(c.abs() < 1e-3);
        }
    }

    #[test]
    fn quantization_error_bounded_by_step() {
        let src = sample_block();
        let mut c = src;
        forward(&mut c);
        for qp in [1.0f32, 4.0, 12.0] {
            let q = quantize(&c, qp);
            let d = dequantize(&q, qp);
            for i in 0..64 {
                let step = (1.0 + 0.45 * ((i % 8) + (i / 8)) as f32) * qp;
                assert!((c[i] - d[i]).abs() <= step / 2.0 + 1e-3);
            }
        }
    }

    #[test]
    fn coarser_qp_zeroes_more() {
        let src = sample_block();
        let mut c = src;
        forward(&mut c);
        let nz = |qp: f32| quantize(&c, qp).iter().filter(|&&l| l != 0).count();
        assert!(nz(1.0) >= nz(6.0));
        assert!(nz(6.0) >= nz(20.0));
    }
}
