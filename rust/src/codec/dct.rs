//! 8×8 DCT-II / DCT-III (separable, precomputed basis) and quantization.
//!
//! Each public entry point dispatches between the scalar reference
//! (`*_scalar`) and the AVX2 kernels in [`super::kernels`] (selected once
//! at startup); the two paths are byte-identical — see DESIGN.md §9.

use super::BLOCK;
use once_cell::sync::Lazy;

/// Precomputed orthonormal DCT-II basis: `BASIS[k][n] = c_k cos(...)`.
static BASIS: Lazy<[[f32; BLOCK]; BLOCK]> = Lazy::new(|| {
    let mut b = [[0.0f32; BLOCK]; BLOCK];
    let n = BLOCK as f32;
    for k in 0..BLOCK {
        let ck = if k == 0 { (1.0 / n).sqrt() } else { (2.0 / n).sqrt() };
        for x in 0..BLOCK {
            b[k][x] =
                ck * ((std::f32::consts::PI / n) * (x as f32 + 0.5) * k as f32).cos();
        }
    }
    b
});

/// Transposed basis (`BASIS_T[x][k] = BASIS[k][x]`) — row-major access for
/// the vectorized row pass.
#[cfg(target_arch = "x86_64")]
static BASIS_T: Lazy<[[f32; BLOCK]; BLOCK]> = Lazy::new(|| {
    let mut t = [[0.0f32; BLOCK]; BLOCK];
    for k in 0..BLOCK {
        for x in 0..BLOCK {
            t[x][k] = BASIS[k][x];
        }
    }
    t
});

/// Forward 8×8 DCT (rows then columns), in place on a row-major block.
pub fn forward(block: &mut [f32; BLOCK * BLOCK]) {
    #[cfg(target_arch = "x86_64")]
    if super::kernels::backend() == super::kernels::KernelBackend::Avx2 {
        // SAFETY: AVX2 presence guaranteed by `backend()`
        unsafe { super::kernels::avx2::dct_forward(block, &BASIS, &BASIS_T) };
        return;
    }
    forward_scalar(block);
}

/// Scalar reference for [`forward`].
pub fn forward_scalar(block: &mut [f32; BLOCK * BLOCK]) {
    let b = &*BASIS;
    let mut tmp = [0.0f32; BLOCK * BLOCK];
    // rows
    for y in 0..BLOCK {
        for k in 0..BLOCK {
            let mut acc = 0.0;
            for x in 0..BLOCK {
                acc += b[k][x] * block[y * BLOCK + x];
            }
            tmp[y * BLOCK + k] = acc;
        }
    }
    // cols
    for k in 0..BLOCK {
        for x in 0..BLOCK {
            let mut acc = 0.0;
            for y in 0..BLOCK {
                acc += b[k][y] * tmp[y * BLOCK + x];
            }
            block[k * BLOCK + x] = acc;
        }
    }
}

/// Inverse 8×8 DCT, in place.
pub fn inverse(block: &mut [f32; BLOCK * BLOCK]) {
    #[cfg(target_arch = "x86_64")]
    if super::kernels::backend() == super::kernels::KernelBackend::Avx2 {
        // SAFETY: AVX2 presence guaranteed by `backend()`
        unsafe { super::kernels::avx2::dct_inverse(block, &BASIS) };
        return;
    }
    inverse_scalar(block);
}

/// Scalar reference for [`inverse`].
pub fn inverse_scalar(block: &mut [f32; BLOCK * BLOCK]) {
    let b = &*BASIS;
    let mut tmp = [0.0f32; BLOCK * BLOCK];
    // cols (transpose of forward)
    for y in 0..BLOCK {
        for x in 0..BLOCK {
            let mut acc = 0.0;
            for k in 0..BLOCK {
                acc += b[k][y] * block[k * BLOCK + x];
            }
            tmp[y * BLOCK + x] = acc;
        }
    }
    // rows
    for y in 0..BLOCK {
        for x in 0..BLOCK {
            let mut acc = 0.0;
            for k in 0..BLOCK {
                acc += b[k][x] * tmp[y * BLOCK + k];
            }
            block[y * BLOCK + x] = acc;
        }
    }
}

/// JPEG-flavoured luma quantization weights (flat-ish, frequency-rising).
const QWEIGHT: [f32; BLOCK * BLOCK] = {
    let mut w = [0.0f32; BLOCK * BLOCK];
    let mut y = 0;
    while y < BLOCK {
        let mut x = 0;
        while x < BLOCK {
            w[y * BLOCK + x] = 1.0 + 0.45 * (x + y) as f32;
            x += 1;
        }
        y += 1;
    }
    w
};

/// Quantize DCT coefficients with quality parameter `qp` (≥ 1; higher ⇒
/// coarser).  Returns integer levels.
pub fn quantize(coeffs: &[f32; BLOCK * BLOCK], qp: f32) -> [i32; BLOCK * BLOCK] {
    #[cfg(target_arch = "x86_64")]
    if super::kernels::backend() == super::kernels::KernelBackend::Avx2 {
        let mut out = [0i32; BLOCK * BLOCK];
        // SAFETY: AVX2 presence guaranteed by `backend()`
        unsafe { super::kernels::avx2::quantize(coeffs, &QWEIGHT, qp, &mut out) };
        return out;
    }
    quantize_scalar(coeffs, qp)
}

/// Scalar reference for [`quantize`].
pub fn quantize_scalar(coeffs: &[f32; BLOCK * BLOCK], qp: f32) -> [i32; BLOCK * BLOCK] {
    let mut out = [0i32; BLOCK * BLOCK];
    for i in 0..BLOCK * BLOCK {
        let step = QWEIGHT[i] * qp;
        out[i] = (coeffs[i] / step).round() as i32;
    }
    out
}

/// Dequantize levels back to coefficient space.
pub fn dequantize(levels: &[i32; BLOCK * BLOCK], qp: f32) -> [f32; BLOCK * BLOCK] {
    #[cfg(target_arch = "x86_64")]
    if super::kernels::backend() == super::kernels::KernelBackend::Avx2 {
        let mut out = [0.0f32; BLOCK * BLOCK];
        // SAFETY: AVX2 presence guaranteed by `backend()`
        unsafe { super::kernels::avx2::dequantize(levels, &QWEIGHT, qp, &mut out) };
        return out;
    }
    dequantize_scalar(levels, qp)
}

/// Scalar reference for [`dequantize`].
pub fn dequantize_scalar(levels: &[i32; BLOCK * BLOCK], qp: f32) -> [f32; BLOCK * BLOCK] {
    let mut out = [0.0f32; BLOCK * BLOCK];
    for i in 0..BLOCK * BLOCK {
        out[i] = levels[i] as f32 * QWEIGHT[i] * qp;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_block() -> [f32; 64] {
        let mut b = [0.0f32; 64];
        for (i, v) in b.iter_mut().enumerate() {
            *v = ((i * 7919) % 255) as f32 - 128.0;
        }
        b
    }

    #[test]
    fn dct_roundtrip_is_identity() {
        let src = sample_block();
        let mut b = src;
        forward(&mut b);
        inverse(&mut b);
        for (a, b) in src.iter().zip(b.iter()) {
            assert!((a - b).abs() < 1e-2, "{a} vs {b}");
        }
    }

    #[test]
    fn dct_preserves_energy() {
        // orthonormal transform: Parseval
        let src = sample_block();
        let mut b = src;
        forward(&mut b);
        let e_in: f32 = src.iter().map(|x| x * x).sum();
        let e_out: f32 = b.iter().map(|x| x * x).sum();
        assert!((e_in - e_out).abs() / e_in < 1e-4);
    }

    #[test]
    fn flat_block_is_dc_only() {
        let mut b = [42.0f32; 64];
        forward(&mut b);
        assert!((b[0] - 42.0 * 8.0).abs() < 1e-3);
        for &c in &b[1..] {
            assert!(c.abs() < 1e-3);
        }
    }

    #[test]
    fn quantization_error_bounded_by_step() {
        let src = sample_block();
        let mut c = src;
        forward(&mut c);
        for qp in [1.0f32, 4.0, 12.0] {
            let q = quantize(&c, qp);
            let d = dequantize(&q, qp);
            for i in 0..64 {
                let step = (1.0 + 0.45 * ((i % 8) + (i / 8)) as f32) * qp;
                assert!((c[i] - d[i]).abs() <= step / 2.0 + 1e-3);
            }
        }
    }

    #[test]
    fn coarser_qp_zeroes_more() {
        let src = sample_block();
        let mut c = src;
        forward(&mut c);
        let nz = |qp: f32| quantize(&c, qp).iter().filter(|&&l| l != 0).count();
        assert!(nz(1.0) >= nz(6.0));
        assert!(nz(6.0) >= nz(20.0));
    }

    /// The dispatched path must be byte-identical to the scalar reference
    /// (vacuous when the host resolves to the scalar backend anyway).
    #[test]
    fn dispatched_dct_matches_scalar_bitwise() {
        let src = sample_block();
        let mut a = src;
        let mut b = src;
        forward(&mut a);
        forward_scalar(&mut b);
        assert_eq!(bits(&a), bits(&b), "forward diverged");
        inverse(&mut a);
        inverse_scalar(&mut b);
        assert_eq!(bits(&a), bits(&b), "inverse diverged");
    }

    #[test]
    fn dispatched_quantize_matches_scalar_bitwise() {
        let mut c = sample_block();
        forward(&mut c);
        for qp in [1.0f32, 3.5, 6.0, 20.0] {
            let a = quantize(&c, qp);
            let b = quantize_scalar(&c, qp);
            assert_eq!(a, b, "quantize diverged at qp {qp}");
            let da = dequantize(&a, qp);
            let db = dequantize_scalar(&b, qp);
            assert_eq!(bits(&da), bits(&db), "dequantize diverged at qp {qp}");
        }
    }

    /// Exact-half quotients must round away from zero on both paths (the
    /// AVX2 kernel emulates `f32::round`; `_mm256_round_ps` would give
    /// half-to-even here).  `step * (k + 0.5)` does not always divide back
    /// to the exact tie in f32, so each lane scans ±2 ULP for a
    /// coefficient whose quotient lands exactly on the tie.
    #[test]
    fn quantize_ties_round_away_from_zero() {
        let qp = 2.0f32;
        let mut coeffs = [0.0f32; 64];
        let mut tie = [false; 64];
        for i in 0..64 {
            let step = QWEIGHT[i] * qp;
            let k = (i % 7) as f32 - 3.0;
            let target = k + 0.5; // ties at ±0.5, ±1.5, ±2.5, ±3.5
            let base = (step * target).to_bits() as i64;
            for delta in -2i64..=2 {
                let c = f32::from_bits((base + delta) as u32);
                if c / step == target {
                    coeffs[i] = c;
                    tie[i] = true;
                    break;
                }
            }
        }
        assert!(tie.iter().filter(|&&t| t).count() >= 16, "too few exact ties found");
        let a = quantize(&coeffs, qp);
        let b = quantize_scalar(&coeffs, qp);
        assert_eq!(a, b);
        for i in 0..64 {
            if !tie[i] {
                continue;
            }
            let k = (i % 7) as i32 - 3;
            let expected = if k >= 0 { k + 1 } else { k };
            assert_eq!(b[i], expected, "tie at index {i}");
        }
    }

    fn bits(v: &[f32]) -> Vec<u32> {
        v.iter().map(|f| f.to_bits()).collect()
    }
}
