//! Macroblock motion search (three-step search, SAD cost) confined to a
//! region's reconstructed reference plane.
//!
//! The confinement is the point: independently-coded regions cannot
//! reference pixels outside themselves, so finer tilings find worse
//! predictions for objects crossing boundaries — the compression-efficacy
//! degradation CrossRoI's tile-grouping fights (§2.2, Table 3).

use super::MB;

/// A single luma plane with dimensions (row-major f32).
pub struct Plane<'a> {
    pub w: usize,
    pub h: usize,
    pub data: &'a [f32],
}

impl<'a> Plane<'a> {
    #[inline]
    fn at(&self, x: usize, y: usize) -> f32 {
        self.data[y * self.w + x]
    }
}

/// Sum of absolute differences between the MB at (bx,by) in `cur` and the
/// MB at (bx+dx, by+dy) in `reference`; `None` if displaced outside.
/// `early_exit`: give up once the partial SAD exceeds it.
pub fn sad(
    cur: &Plane,
    reference: &Plane,
    bx: usize,
    by: usize,
    dx: i32,
    dy: i32,
    early_exit: f32,
) -> Option<f32> {
    let rx = bx as i32 + dx;
    let ry = by as i32 + dy;
    if rx < 0 || ry < 0 || rx as usize + MB > reference.w || ry as usize + MB > reference.h {
        return None;
    }
    let (rx, ry) = (rx as usize, ry as usize);
    let mut acc = 0.0f32;
    for y in 0..MB {
        for x in 0..MB {
            acc += (cur.at(bx + x, by + y) - reference.at(rx + x, ry + y)).abs();
        }
        if acc > early_exit {
            return Some(acc);
        }
    }
    Some(acc)
}

/// Rate-distortion λ for MV cost in SAD units per MV grid step: longer
/// vectors cost bits, so ties (and near-ties) resolve to the shorter MV.
const MV_LAMBDA: f32 = 2.0;

/// Three-step search around (0,0); returns (dx, dy, sad).  The selection
/// score is `SAD + λ·(|dx|+|dy|)` (rate-distortion–style), the returned
/// SAD is the raw distortion of the winner.
pub fn three_step_search(
    cur: &Plane,
    reference: &Plane,
    bx: usize,
    by: usize,
) -> (i32, i32, f32) {
    let mv_cost = |dx: i32, dy: i32| MV_LAMBDA * (dx.abs() + dy.abs()) as f32;
    let mut best = (0i32, 0i32);
    let mut best_sad = sad(cur, reference, bx, by, 0, 0, f32::INFINITY)
        .expect("zero MV must be valid");
    let mut best_score = best_sad; // zero MV has zero cost
    let mut step = 4i32;
    while step >= 1 {
        let center = best;
        for dy in [-step, 0, step] {
            for dx in [-step, 0, step] {
                if dx == 0 && dy == 0 {
                    continue;
                }
                let cand = (center.0 + dx, center.1 + dy);
                let cost = mv_cost(cand.0, cand.1);
                let budget = best_score - cost;
                if budget <= 0.0 {
                    continue;
                }
                if let Some(s) = sad(cur, reference, bx, by, cand.0, cand.1, budget) {
                    if s + cost < best_score {
                        best_score = s + cost;
                        best_sad = s;
                        best = cand;
                    }
                }
            }
        }
        step /= 2;
    }
    (best.0, best.1, best_sad)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gradient_plane(w: usize, h: usize, shift: i32) -> Vec<f32> {
        let mut d = vec![0.0f32; w * h];
        for y in 0..h {
            for x in 0..w {
                // a smooth, non-periodic texture translated by `shift`
                // (smooth ⇒ SAD decreases toward the true displacement,
                // so the three-step search can follow the gradient)
                let sx = (x as i32 - shift) as f32;
                let _ = y;
                // x-only texture: SAD is monotone in |dx - true shift| and
                // flat in dy, so the search is exactly analyzable
                d[y * w + x] = 60.0 * (sx * 0.13).sin() + 20.0 * (sx * 0.021).sin();
            }
        }
        d
    }

    #[test]
    fn finds_exact_translation() {
        let w = 64;
        let h = 48;
        let prev = gradient_plane(w, h, 0);
        let cur = gradient_plane(w, h, 3); // content moved right by 3
        let p_prev = Plane { w, h, data: &prev };
        let p_cur = Plane { w, h, data: &cur };
        let (dx, dy, s) = three_step_search(&p_cur, &p_prev, 16, 16);
        assert_eq!((dx, dy), (-3, 0));
        assert!(s < 1e-3, "sad {s}");
    }

    #[test]
    fn static_content_prefers_zero_mv() {
        let w = 64;
        let h = 48;
        let a = gradient_plane(w, h, 0);
        let p = Plane { w, h, data: &a };
        let (dx, dy, s) = three_step_search(&p, &p, 32, 16);
        assert_eq!((dx, dy, s), (0, 0, 0.0));
    }

    #[test]
    fn out_of_bounds_rejected() {
        let w = 32;
        let h = 32;
        let a = gradient_plane(w, h, 0);
        let p = Plane { w, h, data: &a };
        assert!(sad(&p, &p, 0, 0, -1, 0, f32::INFINITY).is_none());
        assert!(sad(&p, &p, 16, 16, 1, 0, f32::INFINITY).is_none());
        assert!(sad(&p, &p, 16, 16, 0, 0, f32::INFINITY).is_some());
    }

    #[test]
    fn confinement_blocks_cross_region_motion() {
        // a narrow region cannot express the 8px shift that a wide one can:
        // emulate by searching in a 16-wide reference (no room to displace)
        let w = 16;
        let h = 32;
        let prev = gradient_plane(w, h, 0);
        let cur = gradient_plane(w, h, 8);
        let pp = Plane { w, h, data: &prev };
        let pc = Plane { w, h, data: &cur };
        let (_, _, s) = three_step_search(&pc, &pp, 0, 0);
        assert!(s > 100.0, "confined search should not find the true motion");
    }
}
