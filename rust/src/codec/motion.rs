//! Macroblock motion search (three-step search, SAD cost) confined to a
//! region's reconstructed reference plane.
//!
//! The confinement is the point: independently-coded regions cannot
//! reference pixels outside themselves, so finer tilings find worse
//! predictions for objects crossing boundaries — the compression-efficacy
//! degradation CrossRoI's tile-grouping fights (§2.2, Table 3).
//!
//! SAD is defined over eight lane accumulators with a fixed reduction
//! tree (not a single sequential sum): both the scalar reference and the
//! AVX2 kernel ([`super::kernels::avx2::sad_16x16`]) accumulate column
//! lanes `j` and `j+8` together and reduce with the same
//! `((l0+l4)+(l2+l6)) + ((l1+l5)+(l3+l7))` tree, so the two paths are
//! byte-identical (f32 addition is not associative — a sequential scalar
//! sum could not be vectorized exactly).  Early exit happens at row
//! granularity on the reduced partial in both paths.

use super::MB;

// the lane split (j, j+8) and the AVX2 kernel both hard-code 16 columns
const _: () = assert!(MB == 16, "SAD lane structure assumes 16x16 macroblocks");

/// A single luma plane with dimensions (row-major f32).
pub struct Plane<'a> {
    pub w: usize,
    pub h: usize,
    pub data: &'a [f32],
}

impl<'a> Plane<'a> {
    #[inline]
    fn at(&self, x: usize, y: usize) -> f32 {
        self.data[y * self.w + x]
    }
}

/// Sum of absolute differences between the MB at (bx,by) in `cur` and the
/// MB at (bx+dx, by+dy) in `reference`; `None` if displaced outside.
/// `early_exit`: give up once the partial SAD exceeds it (checked once
/// per row).
pub fn sad(
    cur: &Plane,
    reference: &Plane,
    bx: usize,
    by: usize,
    dx: i32,
    dy: i32,
    early_exit: f32,
) -> Option<f32> {
    let rx = bx as i32 + dx;
    let ry = by as i32 + dy;
    if rx < 0 || ry < 0 || rx as usize + MB > reference.w || ry as usize + MB > reference.h {
        return None;
    }
    let (rx, ry) = (rx as usize, ry as usize);
    // the current MB must itself be in bounds (callers walk an MB-aligned
    // grid); checked explicitly because the AVX2 path reads raw pointers
    assert!(bx + MB <= cur.w && by + MB <= cur.h, "current MB out of bounds");
    assert!(cur.data.len() >= cur.w * cur.h);
    assert!(reference.data.len() >= reference.w * reference.h);
    #[cfg(target_arch = "x86_64")]
    if super::kernels::backend() == super::kernels::KernelBackend::Avx2 {
        // SAFETY: AVX2 presence guaranteed by `backend()`; both MB
        // windows were bounds-checked above, so every row of 16 f32s the
        // kernel reads is inside the plane slices.
        let s = unsafe {
            super::kernels::avx2::sad_16x16(
                cur.data.as_ptr().add(by * cur.w + bx),
                cur.w,
                reference.data.as_ptr().add(ry * reference.w + rx),
                reference.w,
                early_exit,
            )
        };
        return Some(s);
    }
    Some(sad_lanes(cur, reference, bx, by, rx, ry, early_exit))
}

/// Scalar reference for [`sad`] (same signature, never dispatches).
pub fn sad_scalar(
    cur: &Plane,
    reference: &Plane,
    bx: usize,
    by: usize,
    dx: i32,
    dy: i32,
    early_exit: f32,
) -> Option<f32> {
    let rx = bx as i32 + dx;
    let ry = by as i32 + dy;
    if rx < 0 || ry < 0 || rx as usize + MB > reference.w || ry as usize + MB > reference.h {
        return None;
    }
    let (rx, ry) = (rx as usize, ry as usize);
    assert!(bx + MB <= cur.w && by + MB <= cur.h, "current MB out of bounds");
    Some(sad_lanes(cur, reference, bx, by, rx, ry, early_exit))
}

/// Eight-lane SAD accumulation with per-row early exit — the scalar
/// mirror of the AVX2 kernel's lane and reduction structure.
fn sad_lanes(
    cur: &Plane,
    reference: &Plane,
    bx: usize,
    by: usize,
    rx: usize,
    ry: usize,
    early_exit: f32,
) -> f32 {
    let mut lanes = [0.0f32; 8];
    for y in 0..MB {
        for (j, lane) in lanes.iter_mut().enumerate() {
            let d0 = (cur.at(bx + j, by + y) - reference.at(rx + j, ry + y)).abs();
            let d1 = (cur.at(bx + j + 8, by + y) - reference.at(rx + j + 8, ry + y)).abs();
            *lane += d0 + d1;
        }
        let partial = hsum8(&lanes);
        if partial > early_exit {
            return partial;
        }
    }
    hsum8(&lanes)
}

/// Fixed reduction tree matching the AVX2 `hsum256` exactly.
#[inline]
fn hsum8(l: &[f32; 8]) -> f32 {
    let s = [l[0] + l[4], l[1] + l[5], l[2] + l[6], l[3] + l[7]];
    let t = [s[0] + s[2], s[1] + s[3]];
    t[0] + t[1]
}

/// Rate-distortion λ for MV cost in SAD units per MV grid step: longer
/// vectors cost bits, so ties (and near-ties) resolve to the shorter MV.
const MV_LAMBDA: f32 = 2.0;

/// Three-step search around (0,0); returns (dx, dy, sad).  The selection
/// score is `SAD + λ·(|dx|+|dy|)` (rate-distortion–style), the returned
/// SAD is the raw distortion of the winner.
pub fn three_step_search(
    cur: &Plane,
    reference: &Plane,
    bx: usize,
    by: usize,
) -> (i32, i32, f32) {
    let mv_cost = |dx: i32, dy: i32| MV_LAMBDA * (dx.abs() + dy.abs()) as f32;
    let mut best = (0i32, 0i32);
    let mut best_sad = sad(cur, reference, bx, by, 0, 0, f32::INFINITY)
        .expect("zero MV must be valid");
    let mut best_score = best_sad; // zero MV has zero cost
    let mut step = 4i32;
    while step >= 1 {
        let center = best;
        for dy in [-step, 0, step] {
            for dx in [-step, 0, step] {
                if dx == 0 && dy == 0 {
                    continue;
                }
                let cand = (center.0 + dx, center.1 + dy);
                let cost = mv_cost(cand.0, cand.1);
                let budget = best_score - cost;
                if budget <= 0.0 {
                    continue;
                }
                if let Some(s) = sad(cur, reference, bx, by, cand.0, cand.1, budget) {
                    if s + cost < best_score {
                        best_score = s + cost;
                        best_sad = s;
                        best = cand;
                    }
                }
            }
        }
        step /= 2;
    }
    (best.0, best.1, best_sad)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gradient_plane(w: usize, h: usize, shift: i32) -> Vec<f32> {
        let mut d = vec![0.0f32; w * h];
        for y in 0..h {
            for x in 0..w {
                // a smooth, non-periodic texture translated by `shift`
                // (smooth ⇒ SAD decreases toward the true displacement,
                // so the three-step search can follow the gradient)
                let sx = (x as i32 - shift) as f32;
                let _ = y;
                // x-only texture: SAD is monotone in |dx - true shift| and
                // flat in dy, so the search is exactly analyzable
                d[y * w + x] = 60.0 * (sx * 0.13).sin() + 20.0 * (sx * 0.021).sin();
            }
        }
        d
    }

    #[test]
    fn finds_exact_translation() {
        let w = 64;
        let h = 48;
        let prev = gradient_plane(w, h, 0);
        let cur = gradient_plane(w, h, 3); // content moved right by 3
        let p_prev = Plane { w, h, data: &prev };
        let p_cur = Plane { w, h, data: &cur };
        let (dx, dy, s) = three_step_search(&p_cur, &p_prev, 16, 16);
        assert_eq!((dx, dy), (-3, 0));
        assert!(s < 1e-3, "sad {s}");
    }

    #[test]
    fn static_content_prefers_zero_mv() {
        let w = 64;
        let h = 48;
        let a = gradient_plane(w, h, 0);
        let p = Plane { w, h, data: &a };
        let (dx, dy, s) = three_step_search(&p, &p, 32, 16);
        assert_eq!((dx, dy, s), (0, 0, 0.0));
    }

    #[test]
    fn out_of_bounds_rejected() {
        let w = 32;
        let h = 32;
        let a = gradient_plane(w, h, 0);
        let p = Plane { w, h, data: &a };
        assert!(sad(&p, &p, 0, 0, -1, 0, f32::INFINITY).is_none());
        assert!(sad(&p, &p, 16, 16, 1, 0, f32::INFINITY).is_none());
        assert!(sad(&p, &p, 16, 16, 0, 0, f32::INFINITY).is_some());
    }

    #[test]
    fn confinement_blocks_cross_region_motion() {
        // a narrow region cannot express the 8px shift that a wide one can:
        // emulate by searching in a 16-wide reference (no room to displace)
        let w = 16;
        let h = 32;
        let prev = gradient_plane(w, h, 0);
        let cur = gradient_plane(w, h, 8);
        let pp = Plane { w, h, data: &prev };
        let pc = Plane { w, h, data: &cur };
        let (_, _, s) = three_step_search(&pc, &pp, 0, 0);
        assert!(s > 100.0, "confined search should not find the true motion");
    }

    /// The dispatched SAD must match the scalar reference bit-for-bit,
    /// including on plane widths that are not a multiple of the SIMD
    /// lane width (strides are arbitrary, only the MB is 16-wide).
    #[test]
    fn dispatched_sad_matches_scalar_bitwise() {
        for (w, h, bx, by) in [(64usize, 48usize, 16usize, 16usize), (37, 21, 13, 2), (16, 16, 0, 0)] {
            let prev = gradient_plane(w, h, 0);
            let cur = gradient_plane(w, h, 2);
            let pp = Plane { w, h, data: &prev };
            let pc = Plane { w, h, data: &cur };
            for (dx, dy) in [(0i32, 0i32), (1, 0), (-2, 1), (0, -1)] {
                for early in [f32::INFINITY, 500.0, 10.0] {
                    let a = sad(&pc, &pp, bx, by, dx, dy, early);
                    let b = sad_scalar(&pc, &pp, bx, by, dx, dy, early);
                    match (a, b) {
                        (None, None) => {}
                        (Some(a), Some(b)) => assert_eq!(
                            a.to_bits(),
                            b.to_bits(),
                            "w={w} bx={bx} dx={dx} dy={dy} early={early}: {a} vs {b}"
                        ),
                        _ => panic!("bounds decision diverged"),
                    }
                }
            }
        }
    }
}
