//! Block-transform video codec — the offline-friendly stand-in for ffmpeg's
//! H.264 (DESIGN.md §3).
//!
//! Structure mirrors the standard hybrid codec the paper describes in §2.2:
//! 16×16 macroblocks, 8×8 DCT + quantization + run-length entropy costing,
//! motion-compensated P frames inside a GOP (= one streaming segment),
//! 4:2:0 chroma.  Regions (tile groups) are encoded *independently* — the
//! property CrossRoI's tile-grouping algorithm optimizes against, because
//! motion compensation cannot reference across region boundaries and every
//! region pays per-frame header overhead (Table 3's amplification).
//!
//! The encoder keeps a real reconstruction loop (dequant + IDCT), so PSNR
//! against the source is measurable and sizes respond to quantization the
//! way a real codec's do.

pub mod dct;
pub mod encoder;
pub mod entropy;
pub mod kernels;
pub mod motion;

pub use encoder::{EncodedSegment, RegionStream, SegmentEncoder};
pub use kernels::{avx2_supported, backend, set_backend, KernelBackend};

/// Macroblock size in pixels.
pub const MB: usize = 16;
/// Transform block size.
pub const BLOCK: usize = 8;
/// Per-region per-frame container/header overhead in bytes.
pub const REGION_HEADER_BYTES: usize = 14;
/// Per-segment container overhead in bytes.
pub const SEGMENT_HEADER_BYTES: usize = 48;
