//! Runtime-dispatched SIMD kernels for the per-frame hot path.
//!
//! The codec's three inner loops — 8×8 DCT, motion-search SAD and the
//! entropy zig-zag scan — plus the detector-input u8→f32 conversion are
//! implemented twice: a portable scalar reference and an AVX2 version
//! (stable `std::arch` intrinsics behind `is_x86_feature_detected!`).
//! The backend is picked **once** at startup ([`backend`]) and the two
//! paths are **byte-identical**: every SIMD kernel performs the same
//! f32 operations in the same per-lane order as its scalar reference
//! (multiplies and adds only — no FMA contraction, which would change
//! rounding), so reports, determinism tests and recorded experiments do
//! not depend on the host's ISA.  See DESIGN.md §9.
//!
//! The env var `CROSSROI_KERNELS` overrides detection: `scalar` forces
//! the fallback (CI runs the whole suite this way), `simd`/`avx2`
//! requests the vector path (falling back with a warning when the host
//! lacks AVX2), `auto` (default) detects.  [`set_backend`] is the
//! in-process override used by the scalar-vs-SIMD bench columns.

use std::sync::atomic::{AtomicU8, Ordering};

use once_cell::sync::Lazy;

/// Which kernel implementations [`backend`] dispatches to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelBackend {
    /// Portable reference implementation (always available).
    Scalar,
    /// AVX2 vector implementation (x86-64 hosts with AVX2).
    Avx2,
}

impl KernelBackend {
    pub fn name(self) -> &'static str {
        match self {
            KernelBackend::Scalar => "scalar",
            KernelBackend::Avx2 => "avx2",
        }
    }
}

const OVERRIDE_NONE: u8 = 0;
const OVERRIDE_SCALAR: u8 = 1;
const OVERRIDE_AVX2: u8 = 2;

/// In-process override ([`set_backend`]); beats [`DETECTED`] when set.
static OVERRIDE: AtomicU8 = AtomicU8::new(OVERRIDE_NONE);

/// Does this host support the AVX2 kernels?
pub fn avx2_supported() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        is_x86_feature_detected!("avx2")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

/// Backend resolved once from `CROSSROI_KERNELS` + feature detection.
static DETECTED: Lazy<KernelBackend> = Lazy::new(|| {
    let auto = || if avx2_supported() { KernelBackend::Avx2 } else { KernelBackend::Scalar };
    match std::env::var("CROSSROI_KERNELS").ok().as_deref() {
        Some("scalar") => KernelBackend::Scalar,
        Some("simd") | Some("avx2") => {
            if avx2_supported() {
                KernelBackend::Avx2
            } else {
                eprintln!(
                    "CROSSROI_KERNELS=simd requested but this host lacks AVX2; \
                     using the scalar fallback"
                );
                KernelBackend::Scalar
            }
        }
        Some("auto") | None => auto(),
        Some(other) => {
            eprintln!(
                "unknown CROSSROI_KERNELS={other:?} (expected scalar|simd|auto); detecting"
            );
            auto()
        }
    }
});

/// The kernel backend every dispatching entry point uses.  Resolved once
/// (env override + feature detection); both paths produce byte-identical
/// output, so this only decides speed.
#[inline]
pub fn backend() -> KernelBackend {
    match OVERRIDE.load(Ordering::Relaxed) {
        OVERRIDE_SCALAR => KernelBackend::Scalar,
        OVERRIDE_AVX2 => KernelBackend::Avx2,
        _ => *DETECTED,
    }
}

/// Force a backend in-process (`None` restores detection) — the hook the
/// scalar-vs-SIMD bench columns and identity tests use.  Panics if
/// [`KernelBackend::Avx2`] is forced on a host without AVX2.
pub fn set_backend(forced: Option<KernelBackend>) {
    let v = match forced {
        None => OVERRIDE_NONE,
        Some(KernelBackend::Scalar) => OVERRIDE_SCALAR,
        Some(KernelBackend::Avx2) => {
            assert!(avx2_supported(), "cannot force AVX2 kernels: host lacks AVX2");
            OVERRIDE_AVX2
        }
    };
    OVERRIDE.store(v, Ordering::Relaxed);
}

/// u8 → f32/255 conversion of `src` into `dst` (same length) — the
/// detector-input hot loop ([`crate::sim::render::Frame::masked_f32`]).
#[inline]
pub fn convert_u8_to_f32(src: &[u8], dst: &mut [f32]) {
    assert_eq!(src.len(), dst.len());
    #[cfg(target_arch = "x86_64")]
    if backend() == KernelBackend::Avx2 {
        // SAFETY: AVX2 presence is guaranteed by `backend()`; slice
        // lengths are equal (asserted above).
        unsafe { avx2::convert_u8_to_f32(src, dst) };
        return;
    }
    convert_u8_to_f32_scalar(src, dst);
}

/// Scalar reference for [`convert_u8_to_f32`].
#[inline]
pub fn convert_u8_to_f32_scalar(src: &[u8], dst: &mut [f32]) {
    assert_eq!(src.len(), dst.len());
    for (d, &s) in dst.iter_mut().zip(src) {
        *d = s as f32 / 255.0;
    }
}

/// Mean of one 16×16 macroblock — the encoder's intra-activity scan
/// ([`crate::codec::encoder`] mode decision).
#[inline]
pub fn intra_mean_16x16(plane: &[f32], w: usize, bx: usize, by: usize) -> f32 {
    assert!(bx + 16 <= w && (by + 16) * w <= plane.len(), "macroblock out of bounds");
    #[cfg(target_arch = "x86_64")]
    if backend() == KernelBackend::Avx2 {
        // SAFETY: AVX2 presence is guaranteed by `backend()`; the 16×16
        // window at (bx, by) is inside `plane` (asserted above).
        return unsafe { avx2::intra_mean_16x16(plane.as_ptr().add(by * w + bx), w) };
    }
    intra_mean_16x16_scalar(plane, w, bx, by)
}

/// Scalar reference for [`intra_mean_16x16`]: eight lane accumulators
/// (lane `j` sums columns `j` and `j + 8`) reduced by the fixed
/// [`hsum8`] tree, divided by 256 — the same structure as the motion
/// SAD kernels, so the AVX2 path matches bit-for-bit.
#[inline]
pub fn intra_mean_16x16_scalar(plane: &[f32], w: usize, bx: usize, by: usize) -> f32 {
    assert!(bx + 16 <= w && (by + 16) * w <= plane.len(), "macroblock out of bounds");
    let mut lanes = [0.0f32; 8];
    for y in 0..16 {
        let row = (by + y) * w + bx;
        for (j, lane) in lanes.iter_mut().enumerate() {
            *lane += plane[row + j] + plane[row + j + 8];
        }
    }
    hsum8(&lanes) / 256.0
}

/// Sum of absolute deviations of one 16×16 macroblock from `target`
/// (the MB mean) — the second half of the encoder's intra-activity
/// scan.  No early exit: the full sum always feeds the mode decision.
#[inline]
pub fn intra_sad_16x16(plane: &[f32], w: usize, bx: usize, by: usize, target: f32) -> f32 {
    assert!(bx + 16 <= w && (by + 16) * w <= plane.len(), "macroblock out of bounds");
    #[cfg(target_arch = "x86_64")]
    if backend() == KernelBackend::Avx2 {
        // SAFETY: AVX2 presence is guaranteed by `backend()`; the 16×16
        // window at (bx, by) is inside `plane` (asserted above).
        return unsafe {
            avx2::intra_sad_16x16(plane.as_ptr().add(by * w + bx), w, target)
        };
    }
    intra_sad_16x16_scalar(plane, w, bx, by, target)
}

/// Scalar reference for [`intra_sad_16x16`]: lane `j` accumulates
/// `|p[j] − target| + |p[j + 8] − target|` per row, reduced by
/// [`hsum8`] — same lane/reduction structure as the AVX2 twin.
#[inline]
pub fn intra_sad_16x16_scalar(
    plane: &[f32],
    w: usize,
    bx: usize,
    by: usize,
    target: f32,
) -> f32 {
    assert!(bx + 16 <= w && (by + 16) * w <= plane.len(), "macroblock out of bounds");
    let mut lanes = [0.0f32; 8];
    for y in 0..16 {
        let row = (by + y) * w + bx;
        for (j, lane) in lanes.iter_mut().enumerate() {
            *lane += (plane[row + j] - target).abs() + (plane[row + j + 8] - target).abs();
        }
    }
    hsum8(&lanes)
}

/// Fixed reduction tree `((l0+l4)+(l2+l6)) + ((l1+l5)+(l3+l7))` matching
/// the AVX2 `hsum256` exactly, so lane-structured scalar references
/// reduce in the same order as their vector twins.
#[inline]
fn hsum8(l: &[f32; 8]) -> f32 {
    let s = [l[0] + l[4], l[1] + l[5], l[2] + l[6], l[3] + l[7]];
    let t = [s[0] + s[2], s[1] + s[3]];
    t[0] + t[1]
}

/// AVX2 implementations.  Every function here mirrors its scalar
/// reference operation-for-operation (see the module doc's byte-identity
/// contract); callers must only dispatch here after feature detection.
#[cfg(target_arch = "x86_64")]
pub mod avx2 {
    use std::arch::x86_64::*;

    /// Horizontal sum with the fixed reduction tree
    /// `((l0+l4)+(l2+l6)) + ((l1+l5)+(l3+l7))` — the scalar SAD reference
    /// ([`crate::codec::motion::sad_scalar`]) sums its eight lane
    /// accumulators in exactly this order.
    ///
    /// # Safety
    /// Caller must guarantee the host supports AVX2.
    #[inline]
    unsafe fn hsum256(v: __m256) -> f32 {
        // SAFETY: register-only AVX/SSE intrinsics; the caller's contract
        // (AVX2 host) covers the required CPU features.
        unsafe {
            let lo = _mm256_castps256_ps128(v);
            let hi = _mm256_extractf128_ps::<1>(v);
            let s = _mm_add_ps(lo, hi); // [l0+l4, l1+l5, l2+l6, l3+l7]
            let t = _mm_add_ps(s, _mm_movehl_ps(s, s)); // [s0+s2, s1+s3, ..]
            let u = _mm_add_ss(t, _mm_shuffle_ps::<0x55>(t, t)); // t0 + t1
            _mm_cvtss_f32(u)
        }
    }

    /// Forward 8×8 DCT, rows then columns, one `__m256` per output row.
    /// Per lane this is the scalar triple loop's exact op sequence:
    /// accumulators start at `0.0` and gain `mul` + `add` per tap in
    /// ascending tap order (no FMA).
    ///
    /// # Safety
    /// Caller must guarantee the host supports AVX2.
    #[target_feature(enable = "avx2")]
    pub unsafe fn dct_forward(
        block: &mut [f32; 64],
        basis: &[[f32; 8]; 8],
        basis_t: &[[f32; 8]; 8],
    ) {
        // SAFETY: all loads/stores stay inside the fixed-size `[f32; 64]`
        // / `[[f32; 8]; 8]` borrows (offsets ≤ 56 + 8 lanes); AVX2 is the
        // caller's contract.
        unsafe {
            let mut tmp = [0.0f32; 64];
            // rows: tmp[y][k] = Σ_x basis[k][x] * block[y][x]; lane k reads
            // the transposed basis row basis_t[x][k] = basis[k][x]
            for y in 0..8 {
                let mut acc = _mm256_setzero_ps();
                for x in 0..8 {
                    let v = _mm256_set1_ps(block[y * 8 + x]);
                    let row = _mm256_loadu_ps(basis_t[x].as_ptr());
                    acc = _mm256_add_ps(acc, _mm256_mul_ps(row, v));
                }
                _mm256_storeu_ps(tmp.as_mut_ptr().add(y * 8), acc);
            }
            // cols: block[k][x] = Σ_y basis[k][y] * tmp[y][x]
            for k in 0..8 {
                let mut acc = _mm256_setzero_ps();
                for y in 0..8 {
                    let v = _mm256_set1_ps(basis[k][y]);
                    let row = _mm256_loadu_ps(tmp.as_ptr().add(y * 8));
                    acc = _mm256_add_ps(acc, _mm256_mul_ps(row, v));
                }
                _mm256_storeu_ps(block.as_mut_ptr().add(k * 8), acc);
            }
        }
    }

    /// Inverse 8×8 DCT (transpose of [`dct_forward`]), same per-lane op
    /// order as the scalar reference.
    ///
    /// # Safety
    /// Caller must guarantee the host supports AVX2.
    #[target_feature(enable = "avx2")]
    pub unsafe fn dct_inverse(block: &mut [f32; 64], basis: &[[f32; 8]; 8]) {
        // SAFETY: all loads/stores stay inside the fixed-size `[f32; 64]`
        // / `[[f32; 8]; 8]` borrows; AVX2 is the caller's contract.
        unsafe {
            let mut tmp = [0.0f32; 64];
            // cols: tmp[y][x] = Σ_k basis[k][y] * block[k][x]
            for y in 0..8 {
                let mut acc = _mm256_setzero_ps();
                for k in 0..8 {
                    let v = _mm256_set1_ps(basis[k][y]);
                    let row = _mm256_loadu_ps(block.as_ptr().add(k * 8));
                    acc = _mm256_add_ps(acc, _mm256_mul_ps(row, v));
                }
                _mm256_storeu_ps(tmp.as_mut_ptr().add(y * 8), acc);
            }
            // rows: block[y][x] = Σ_k basis[k][x] * tmp[y][k]; lane x reads
            // basis[k] directly (mul is commutative bit-for-bit)
            for y in 0..8 {
                let mut acc = _mm256_setzero_ps();
                for k in 0..8 {
                    let v = _mm256_set1_ps(tmp[y * 8 + k]);
                    let row = _mm256_loadu_ps(basis[k].as_ptr());
                    acc = _mm256_add_ps(acc, _mm256_mul_ps(row, v));
                }
                _mm256_storeu_ps(block.as_mut_ptr().add(y * 8), acc);
            }
        }
    }

    /// Quantize 64 coefficients: `(c / (w*qp)).round() as i32`, eight
    /// lanes at a time.  `round()` (half-away-from-zero, like
    /// `f32::round`) is emulated as `trunc + (|frac| >= 0.5 ? ±1 : 0)`
    /// because `_mm256_round_ps`'s nearest mode is half-to-even; the
    /// trunc/frac arithmetic is exact for the codec's coefficient range,
    /// so the result matches the scalar reference bit-for-bit.
    ///
    /// # Safety
    /// Caller must guarantee the host supports AVX2.
    #[target_feature(enable = "avx2")]
    pub unsafe fn quantize(
        coeffs: &[f32; 64],
        qweight: &[f32; 64],
        qp: f32,
        out: &mut [i32; 64],
    ) {
        // SAFETY: loads/stores cover exactly the 64-element borrows in
        // eight 8-lane steps; AVX2 is the caller's contract.
        unsafe {
            let qpv = _mm256_set1_ps(qp);
            let sign = _mm256_set1_ps(-0.0);
            let half = _mm256_set1_ps(0.5);
            let one = _mm256_set1_ps(1.0);
            for i in 0..8 {
                let c = _mm256_loadu_ps(coeffs.as_ptr().add(i * 8));
                let w = _mm256_loadu_ps(qweight.as_ptr().add(i * 8));
                let step = _mm256_mul_ps(w, qpv);
                let q = _mm256_div_ps(c, step);
                // trunc via the i32 round trip (exact: |q| << 2^31 here)
                let t = _mm256_cvtepi32_ps(_mm256_cvttps_epi32(q));
                let f = _mm256_sub_ps(q, t); // exact (Sterbenz)
                let af = _mm256_andnot_ps(sign, f);
                let bump = _mm256_cmp_ps::<_CMP_GE_OQ>(af, half);
                let signed_one = _mm256_or_ps(_mm256_and_ps(q, sign), one);
                let r = _mm256_add_ps(t, _mm256_and_ps(bump, signed_one));
                _mm256_storeu_si256(
                    out.as_mut_ptr().add(i * 8) as *mut __m256i,
                    _mm256_cvttps_epi32(r),
                );
            }
        }
    }

    /// Dequantize 64 levels: `(l as f32 * w) * qp`, eight lanes at a time
    /// (same multiply order as the scalar reference).
    ///
    /// # Safety
    /// Caller must guarantee the host supports AVX2.
    #[target_feature(enable = "avx2")]
    pub unsafe fn dequantize(
        levels: &[i32; 64],
        qweight: &[f32; 64],
        qp: f32,
        out: &mut [f32; 64],
    ) {
        // SAFETY: loads/stores cover exactly the 64-element borrows in
        // eight 8-lane steps; AVX2 is the caller's contract.
        unsafe {
            let qpv = _mm256_set1_ps(qp);
            for i in 0..8 {
                let l = _mm256_loadu_si256(levels.as_ptr().add(i * 8) as *const __m256i);
                let w = _mm256_loadu_ps(qweight.as_ptr().add(i * 8));
                let r = _mm256_mul_ps(_mm256_mul_ps(_mm256_cvtepi32_ps(l), w), qpv);
                _mm256_storeu_ps(out.as_mut_ptr().add(i * 8), r);
            }
        }
    }

    /// SAD of one 16×16 macroblock, two `__m256` loads per row, abs-diff
    /// accumulated into eight lane sums, early-exit checked once per row
    /// on the [`hsum256`] partial — exactly the lane/reduction structure
    /// of [`crate::codec::motion::sad_scalar`].
    ///
    /// # Safety
    /// Caller must guarantee AVX2 and that `cur`/`refp` point at 16 rows
    /// of 16 valid f32s under the given strides.
    #[target_feature(enable = "avx2")]
    pub unsafe fn sad_16x16(
        cur: *const f32,
        cur_stride: usize,
        refp: *const f32,
        ref_stride: usize,
        early_exit: f32,
    ) -> f32 {
        // SAFETY: the caller guarantees 16 rows of 16 valid f32s behind
        // `cur`/`refp` under the given strides, so every offset below is
        // in bounds; AVX2 is the caller's contract.
        unsafe {
            let sign = _mm256_set1_ps(-0.0);
            let mut acc = _mm256_setzero_ps();
            for y in 0..16 {
                let a0 = _mm256_loadu_ps(cur.add(y * cur_stride));
                let a1 = _mm256_loadu_ps(cur.add(y * cur_stride + 8));
                let b0 = _mm256_loadu_ps(refp.add(y * ref_stride));
                let b1 = _mm256_loadu_ps(refp.add(y * ref_stride + 8));
                let d0 = _mm256_andnot_ps(sign, _mm256_sub_ps(a0, b0));
                let d1 = _mm256_andnot_ps(sign, _mm256_sub_ps(a1, b1));
                acc = _mm256_add_ps(acc, _mm256_add_ps(d0, d1));
                let partial = hsum256(acc);
                if partial > early_exit {
                    return partial;
                }
            }
            hsum256(acc)
        }
    }

    /// Mean of one 16×16 macroblock (intra-activity scan): two `__m256`
    /// loads per row accumulated into eight lane sums, reduced with
    /// [`hsum256`] and divided by 256 — exactly the lane structure of
    /// [`super::intra_mean_16x16_scalar`].
    ///
    /// # Safety
    /// Caller must guarantee AVX2 and that `mb` points at 16 rows of 16
    /// valid f32s under `stride`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn intra_mean_16x16(mb: *const f32, stride: usize) -> f32 {
        // SAFETY: the caller guarantees 16 rows of 16 valid f32s behind
        // `mb` under `stride`, so every offset below is in bounds; AVX2
        // is the caller's contract.
        unsafe {
            let mut acc = _mm256_setzero_ps();
            for y in 0..16 {
                let a0 = _mm256_loadu_ps(mb.add(y * stride));
                let a1 = _mm256_loadu_ps(mb.add(y * stride + 8));
                acc = _mm256_add_ps(acc, _mm256_add_ps(a0, a1));
            }
            hsum256(acc) / 256.0
        }
    }

    /// Sum of absolute deviations of one 16×16 macroblock from `target`,
    /// abs via sign-bit clear (bit-identical to `f32::abs`), no early
    /// exit — exactly the lane structure of
    /// [`super::intra_sad_16x16_scalar`].
    ///
    /// # Safety
    /// Caller must guarantee AVX2 and that `mb` points at 16 rows of 16
    /// valid f32s under `stride`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn intra_sad_16x16(mb: *const f32, stride: usize, target: f32) -> f32 {
        // SAFETY: the caller guarantees 16 rows of 16 valid f32s behind
        // `mb` under `stride`, so every offset below is in bounds; AVX2
        // is the caller's contract.
        unsafe {
            let sign = _mm256_set1_ps(-0.0);
            let t = _mm256_set1_ps(target);
            let mut acc = _mm256_setzero_ps();
            for y in 0..16 {
                let a0 = _mm256_loadu_ps(mb.add(y * stride));
                let a1 = _mm256_loadu_ps(mb.add(y * stride + 8));
                let d0 = _mm256_andnot_ps(sign, _mm256_sub_ps(a0, t));
                let d1 = _mm256_andnot_ps(sign, _mm256_sub_ps(a1, t));
                acc = _mm256_add_ps(acc, _mm256_add_ps(d0, d1));
            }
            hsum256(acc)
        }
    }

    /// Zig-zag gather + nonzero scan of one quantized block, then the
    /// run-length bit costing on the 64-bit nonzero mask.  Integer ops
    /// only, so identical to the scalar scan by construction.
    ///
    /// # Safety
    /// Caller must guarantee the host supports AVX2.
    #[target_feature(enable = "avx2")]
    pub unsafe fn block_bits(
        levels: &[i32; 64],
        prev_dc: i32,
        zigzag: &[i32; 64],
    ) -> (u32, i32) {
        let mut zz = [0i32; 64];
        let mut nz_mask = 0u64;
        // SAFETY: gathers index `levels` by the zig-zag table, whose 64
        // entries are all in 0..64, so every lane stays inside the
        // borrow; stores cover exactly `zz`; AVX2 is the caller's
        // contract.
        unsafe {
            let zero = _mm256_setzero_si256();
            for i in 0..8 {
                let idx = _mm256_loadu_si256(zigzag.as_ptr().add(i * 8) as *const __m256i);
                let v = _mm256_i32gather_epi32::<4>(levels.as_ptr(), idx);
                _mm256_storeu_si256(zz.as_mut_ptr().add(i * 8) as *mut __m256i, v);
                let is_zero = _mm256_cmpeq_epi32(v, zero);
                let zbits = _mm256_movemask_ps(_mm256_castsi256_ps(is_zero)) as u32;
                nz_mask |= (((!zbits) & 0xff) as u64) << (i * 8);
            }
        }
        let dc = zz[0];
        let mut bits = 4 + crate::codec::entropy::magnitude_bits(dc - prev_dc) + 1;
        // AC: walk the set bits; the zero-run before a nonzero at zig-zag
        // position p is p - prev_nonzero_pos - 1 (prev starts at the DC)
        let mut prev_pos = 0usize;
        let mut m = nz_mask & !1u64;
        while m != 0 {
            let pos = m.trailing_zeros() as usize;
            let run = (pos - prev_pos - 1) as u32;
            bits += 6 + (run / 16) * 7 + crate::codec::entropy::magnitude_bits(zz[pos]) + 1;
            prev_pos = pos;
            m &= m - 1;
        }
        bits += 4; // EOB
        (bits, dc)
    }

    /// u8 → f32/255, eight pixels per step (`_mm256_div_ps` rounds like
    /// scalar division, so this is exact).
    ///
    /// # Safety
    /// Caller must guarantee AVX2 and `src.len() == dst.len()`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn convert_u8_to_f32(src: &[u8], dst: &mut [f32]) {
        // SAFETY: the caller guarantees `src.len() == dst.len()`; the
        // vector loop only touches `i..i + 8 ≤ n` and the scalar tail
        // `i < n`, so all accesses are in bounds; AVX2 is the caller's
        // contract.
        unsafe {
            let n = src.len();
            let denom = _mm256_set1_ps(255.0);
            let mut i = 0;
            while i + 8 <= n {
                let bytes = _mm_loadl_epi64(src.as_ptr().add(i) as *const __m128i);
                let ints = _mm256_cvtepu8_epi32(bytes);
                let f = _mm256_cvtepi32_ps(ints);
                _mm256_storeu_ps(dst.as_mut_ptr().add(i), _mm256_div_ps(f, denom));
                i += 8;
            }
            while i < n {
                *dst.get_unchecked_mut(i) = *src.get_unchecked(i) as f32 / 255.0;
                i += 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn backend_names() {
        assert_eq!(KernelBackend::Scalar.name(), "scalar");
        assert_eq!(KernelBackend::Avx2.name(), "avx2");
    }

    #[test]
    fn convert_dispatch_matches_scalar() {
        let mut rng = Rng::new(7);
        for len in [0usize, 1, 7, 8, 9, 24, 100, 961] {
            let src: Vec<u8> = (0..len).map(|_| (rng.next_u64() & 0xff) as u8).collect();
            let mut a = vec![0.0f32; len];
            let mut b = vec![1.0f32; len];
            convert_u8_to_f32(&src, &mut a);
            convert_u8_to_f32_scalar(&src, &mut b);
            assert_eq!(a, b, "len {len}");
        }
    }

    #[cfg(target_arch = "x86_64")]
    #[cfg_attr(miri, ignore)] // Miri has no AVX2 intrinsics; the scalar path is covered above
    #[test]
    fn avx2_convert_is_bit_identical() {
        if !avx2_supported() {
            return;
        }
        let mut rng = Rng::new(11);
        for len in [1usize, 8, 13, 640] {
            let src: Vec<u8> = (0..len).map(|_| (rng.next_u64() & 0xff) as u8).collect();
            let mut a = vec![0.0f32; len];
            let mut b = vec![0.0f32; len];
            // SAFETY: AVX2 presence checked at the top of the test; the
            // two slices have equal length by construction.
            unsafe { avx2::convert_u8_to_f32(&src, &mut a) };
            convert_u8_to_f32_scalar(&src, &mut b);
            assert_eq!(
                a.iter().map(|f| f.to_bits()).collect::<Vec<_>>(),
                b.iter().map(|f| f.to_bits()).collect::<Vec<_>>(),
            );
        }
    }

    #[test]
    fn intra_dispatch_matches_scalar_bitwise() {
        let mut rng = Rng::new(23);
        let w = 48;
        let plane: Vec<f32> =
            (0..w * 40).map(|_| (rng.next_u64() % 256) as f32).collect();
        for (bx, by) in [(0, 0), (16, 8), (32, 24), (5, 17)] {
            let mean = intra_mean_16x16(&plane, w, bx, by);
            let mean_ref = intra_mean_16x16_scalar(&plane, w, bx, by);
            assert_eq!(mean.to_bits(), mean_ref.to_bits(), "mean at ({bx}, {by})");
            let sad = intra_sad_16x16(&plane, w, bx, by, mean);
            let sad_ref = intra_sad_16x16_scalar(&plane, w, bx, by, mean);
            assert_eq!(sad.to_bits(), sad_ref.to_bits(), "sad at ({bx}, {by})");
        }
    }

    #[cfg(target_arch = "x86_64")]
    #[cfg_attr(miri, ignore)] // Miri has no AVX2 intrinsics; the scalar path is covered above
    #[test]
    fn avx2_intra_is_bit_identical() {
        if !avx2_supported() {
            return;
        }
        let mut rng = Rng::new(29);
        for stride in [16usize, 17, 48, 320] {
            let plane: Vec<f32> =
                (0..stride * 16).map(|_| (rng.next_u64() % 1000) as f32 / 4.0).collect();
            // SAFETY: AVX2 presence checked at the top of the test; the
            // plane holds 16 full rows of `stride` ≥ 16 f32s.
            let mean = unsafe { avx2::intra_mean_16x16(plane.as_ptr(), stride) };
            let mean_ref = intra_mean_16x16_scalar(&plane, stride, 0, 0);
            assert_eq!(mean.to_bits(), mean_ref.to_bits(), "mean, stride {stride}");
            // SAFETY: same bounds as above.
            let sad = unsafe { avx2::intra_sad_16x16(plane.as_ptr(), stride, mean) };
            let sad_ref = intra_sad_16x16_scalar(&plane, stride, 0, 0, mean);
            assert_eq!(sad.to_bits(), sad_ref.to_bits(), "sad, stride {stride}");
        }
    }
}
