//! Entropy-coding bit-cost model: zig-zag scan + (run, level) coding with
//! JPEG-style magnitude categories.  We never emit an actual bitstream —
//! only its exact size matters to the system — but the cost model follows
//! the real coders' structure, so sizes respond to content the right way.

use super::BLOCK;

/// Zig-zag scan order for an 8×8 block.
pub const ZIGZAG: [usize; 64] = {
    let mut order = [0usize; 64];
    let mut idx = 0;
    let mut s = 0; // anti-diagonal index
    while s < 15 {
        if s % 2 == 0 {
            // up-right
            let mut y = if s < 8 { s } else { 7 };
            loop {
                let x = s - y;
                if x > 7 {
                    break;
                }
                order[idx] = y * 8 + x;
                idx += 1;
                if y == 0 {
                    break;
                }
                y -= 1;
            }
        } else {
            // down-left
            let mut x = if s < 8 { s } else { 7 };
            loop {
                let y = s - x;
                if y > 7 {
                    break;
                }
                order[idx] = y * 8 + x;
                idx += 1;
                if x == 0 {
                    break;
                }
                x -= 1;
            }
        }
        s += 1;
    }
    order
};

/// [`ZIGZAG`] as i32 — gather indices for the AVX2 scan kernel.
pub const ZIGZAG_I32: [i32; 64] = {
    let mut order = [0i32; 64];
    let mut i = 0;
    while i < 64 {
        order[i] = ZIGZAG[i] as i32;
        i += 1;
    }
    order
};

/// Bits to encode magnitude `v` (category + sign/value bits).
#[inline]
pub(crate) fn magnitude_bits(v: i32) -> u32 {
    let a = v.unsigned_abs();
    // category = position of highest set bit
    32 - a.leading_zeros()
}

/// Bit cost of one quantized 8×8 block: DC differential + AC (run, level)
/// pairs + end-of-block marker.  Dispatches to the AVX2 gather/scan
/// kernel when selected (integer ops — identical by construction).
pub fn block_bits(levels: &[i32; BLOCK * BLOCK], prev_dc: i32) -> (u32, i32) {
    #[cfg(target_arch = "x86_64")]
    if super::kernels::backend() == super::kernels::KernelBackend::Avx2 {
        // SAFETY: AVX2 presence guaranteed by `backend()`
        return unsafe { super::kernels::avx2::block_bits(levels, prev_dc, &ZIGZAG_I32) };
    }
    block_bits_scalar(levels, prev_dc)
}

/// Scalar reference for [`block_bits`].
pub fn block_bits_scalar(levels: &[i32; BLOCK * BLOCK], prev_dc: i32) -> (u32, i32) {
    let dc = levels[0];
    let diff = dc - prev_dc;
    // DC: ~4-bit category code + magnitude bits
    let mut bits = 4 + magnitude_bits(diff) + 1;
    // AC: run-length of zeros + level
    let mut run = 0u32;
    for &zz in ZIGZAG.iter().skip(1) {
        let v = levels[zz];
        if v == 0 {
            run += 1;
        } else {
            // (run, category) code ≈ 6 bits amortized + magnitude bits
            bits += 6 + (run / 16) * 7 + magnitude_bits(v) + 1;
            run = 0;
        }
    }
    bits += 4; // EOB
    (bits, dc)
}

/// Bit cost of a motion vector differential (signed exp-Golomb-ish).
pub fn mv_bits(dx: i32, dy: i32) -> u32 {
    let one = |v: i32| {
        let m = if v <= 0 { (-2 * v) as u32 } else { (2 * v - 1) as u32 };
        2 * (32 - (m + 1).leading_zeros()) - 1
    };
    one(dx) + one(dy)
}

/// Macroblock mode signalling cost.
pub const MODE_BITS: u32 = 3;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zigzag_is_permutation() {
        let mut seen = [false; 64];
        for &i in &ZIGZAG {
            assert!(!seen[i], "duplicate {i}");
            seen[i] = true;
        }
        assert!(seen.iter().all(|&s| s));
        // canonical prefix
        assert_eq!(&ZIGZAG[..10], &[0, 1, 8, 16, 9, 2, 3, 10, 17, 24]);
    }

    #[test]
    fn zero_block_is_cheap() {
        let z = [0i32; 64];
        let (bits, dc) = block_bits(&z, 0);
        assert_eq!(dc, 0);
        assert!(bits < 16, "zero block cost {bits}");
    }

    #[test]
    fn denser_blocks_cost_more() {
        let mut sparse = [0i32; 64];
        sparse[0] = 10;
        sparse[1] = 3;
        let mut dense = sparse;
        for i in 0..32 {
            dense[i] = 5 - (i as i32 % 10);
        }
        let (b1, _) = block_bits(&sparse, 0);
        let (b2, _) = block_bits(&dense, 0);
        assert!(b2 > b1 * 2, "{b2} vs {b1}");
    }

    #[test]
    fn dc_differential_helps() {
        let mut b = [0i32; 64];
        b[0] = 200;
        let (cold, _) = block_bits(&b, 0);
        let (warm, _) = block_bits(&b, 198);
        assert!(warm < cold);
    }

    #[test]
    fn larger_magnitudes_cost_more_bits() {
        assert!(magnitude_bits(1) < magnitude_bits(100));
        assert_eq!(magnitude_bits(0), 0);
        assert_eq!(magnitude_bits(-1), 1);
    }

    #[test]
    fn mv_bits_grow_with_length() {
        assert!(mv_bits(0, 0) <= mv_bits(1, 0));
        assert!(mv_bits(1, 1) < mv_bits(8, 8));
    }

    /// Dispatched bit costing must agree exactly with the scalar scan on
    /// sparse, dense, negative and long-run blocks.
    #[test]
    fn dispatched_block_bits_matches_scalar() {
        let mut cases: Vec<[i32; 64]> = vec![[0i32; 64]];
        let mut sparse = [0i32; 64];
        sparse[0] = 10;
        sparse[ZIGZAG[5]] = -3;
        sparse[ZIGZAG[40]] = 1; // long zero run (run/16 escape path)
        sparse[ZIGZAG[63]] = -7; // nonzero in the last scan position
        cases.push(sparse);
        let mut dense = [0i32; 64];
        for (i, v) in dense.iter_mut().enumerate() {
            *v = (i as i32 % 11) - 5;
        }
        cases.push(dense);
        let mut rng = 0x9e3779b97f4a7c15u64;
        for _ in 0..50 {
            let mut b = [0i32; 64];
            for v in b.iter_mut() {
                rng = rng.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                // mostly zero, occasionally large — codec-like statistics
                let r = (rng >> 33) as i32;
                *v = if r % 5 == 0 { (r >> 8) % 512 } else { 0 };
            }
            cases.push(b);
        }
        for (n, levels) in cases.iter().enumerate() {
            for prev_dc in [0, -13, 200] {
                assert_eq!(
                    block_bits(levels, prev_dc),
                    block_bits_scalar(levels, prev_dc),
                    "case {n} prev_dc {prev_dc}"
                );
            }
        }
    }
}
