//! Region/segment encoder: YCbCr 4:2:0 planes, per-macroblock intra/inter
//! decision, transform + quantize + entropy cost, reconstruction loop.
//!
//! A [`RegionStream`] encodes one independently-decodable region (a tile
//! group); a [`SegmentEncoder`] drives all regions of a camera over one
//! streaming segment (GOP = segment: the first frame is intra so every
//! segment stands alone, which is what makes segment length the
//! latency/size tradeoff of Fig. 11).

use super::{dct, entropy, kernels, motion, BLOCK, MB, REGION_HEADER_BYTES, SEGMENT_HEADER_BYTES};
use crate::sim::render::Frame;
use crate::util::geometry::IRect;

/// YCbCr 4:2:0 planes (luma at `w × h`, chroma at half resolution).
#[derive(Debug, Clone)]
pub struct Planes {
    pub w: usize,
    pub h: usize,
    pub y: Vec<f32>,
    pub cb: Vec<f32>,
    pub cr: Vec<f32>,
}

impl Planes {
    pub fn new_black(w: usize, h: usize) -> Planes {
        Planes {
            w,
            h,
            y: vec![0.0; w * h],
            cb: vec![128.0; (w / 2) * (h / 2)],
            cr: vec![128.0; (w / 2) * (h / 2)],
        }
    }

    /// Zero-capacity placeholder (reusable target for
    /// [`Planes::from_frame_region_into`]).
    pub fn empty() -> Planes {
        Planes { w: 0, h: 0, y: Vec::new(), cb: Vec::new(), cr: Vec::new() }
    }

    /// Extract a region from an RGB frame, padded (edge-replicated) to a
    /// macroblock multiple, converted to YCbCr with 4:2:0 subsampling.
    pub fn from_frame_region(frame: &Frame, region: IRect) -> Planes {
        let mut out = Planes::empty();
        let (mut cbf, mut crf) = (Vec::new(), Vec::new());
        Planes::from_frame_region_into(frame, region, &mut out, &mut cbf, &mut crf);
        out
    }

    /// [`Planes::from_frame_region`] writing through reusable buffers:
    /// `out`'s planes and the two full-resolution chroma scratch vectors
    /// are cleared and resized in place (allocation-free once warm).
    /// Produces values identical to the allocating constructor.
    pub fn from_frame_region_into(
        frame: &Frame,
        region: IRect,
        out: &mut Planes,
        cbf: &mut Vec<f32>,
        crf: &mut Vec<f32>,
    ) {
        let w = pad_to(region.w as usize, MB);
        let h = pad_to(region.h as usize, MB);
        out.w = w;
        out.h = h;
        let y = &mut out.y;
        y.clear();
        y.resize(w * h, 0.0);
        cbf.clear();
        cbf.resize(w * h, 0.0);
        crf.clear();
        crf.resize(w * h, 0.0);
        for py in 0..h {
            let sy = (region.y as usize + py.min(region.h as usize - 1)).min(frame.h as usize - 1);
            for px in 0..w {
                let sx =
                    (region.x as usize + px.min(region.w as usize - 1)).min(frame.w as usize - 1);
                let [r, g, b] = frame.get(sx as u32, sy as u32);
                let (rf, gf, bf) = (r as f32, g as f32, b as f32);
                y[py * w + px] = 0.299 * rf + 0.587 * gf + 0.114 * bf;
                cbf[py * w + px] = 128.0 - 0.168_736 * rf - 0.331_264 * gf + 0.5 * bf;
                crf[py * w + px] = 128.0 + 0.5 * rf - 0.418_688 * gf - 0.081_312 * bf;
            }
        }
        // 2x2 average subsample
        let cw = w / 2;
        let ch = h / 2;
        let cb = &mut out.cb;
        let cr = &mut out.cr;
        cb.clear();
        cb.resize(cw * ch, 0.0);
        cr.clear();
        cr.resize(cw * ch, 0.0);
        for cy in 0..ch {
            for cx in 0..cw {
                let mut sb = 0.0;
                let mut sr = 0.0;
                for dy in 0..2 {
                    for dx in 0..2 {
                        sb += cbf[(cy * 2 + dy) * w + cx * 2 + dx];
                        sr += crf[(cy * 2 + dy) * w + cx * 2 + dx];
                    }
                }
                cb[cy * cw + cx] = sb / 4.0;
                cr[cy * cw + cx] = sr / 4.0;
            }
        }
    }

    /// Luma PSNR against another plane set (dB).
    pub fn psnr_luma(&self, other: &Planes) -> f64 {
        assert_eq!(self.y.len(), other.y.len());
        let mse: f64 = self
            .y
            .iter()
            .zip(&other.y)
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            / self.y.len() as f64;
        if mse <= 1e-9 {
            99.0
        } else {
            10.0 * (255.0f64 * 255.0 / mse).log10()
        }
    }
}

fn pad_to(v: usize, m: usize) -> usize {
    v.div_ceil(m) * m
}

/// One independently-decodable region stream.
///
/// Holds its working buffers across frames: `cur` (the converted source
/// planes), `spare` (the reconstruction retired two frames ago, recycled
/// as the next frame's target) and the two full-resolution chroma scratch
/// vectors.  After a two-frame warm-up, [`RegionStream::encode_frame`]
/// performs no heap allocation.
pub struct RegionStream {
    pub region: IRect,
    qp: f32,
    prev: Option<Planes>,
    cur: Planes,
    spare: Option<Planes>,
    cbf: Vec<f32>,
    crf: Vec<f32>,
}

/// Outcome of encoding one frame of one region.
#[derive(Debug, Clone, Copy)]
pub struct FrameStats {
    pub bits: u64,
    pub intra_mbs: usize,
    pub inter_mbs: usize,
}

impl RegionStream {
    pub fn new(region: IRect, qp: f32) -> RegionStream {
        assert!(region.w > 0 && region.h > 0, "empty region");
        RegionStream {
            region,
            qp,
            prev: None,
            cur: Planes::empty(),
            spare: None,
            cbf: Vec::new(),
            crf: Vec::new(),
        }
    }

    /// Reset the reference (segment boundary: next frame will be intra).
    /// The retired reference is recycled as the next reconstruction
    /// target instead of being dropped.
    pub fn reset_gop(&mut self) {
        if let Some(p) = self.prev.take() {
            if self.spare.is_none() {
                self.spare = Some(p);
            }
        }
    }

    pub fn last_recon(&self) -> Option<&Planes> {
        self.prev.as_ref()
    }

    /// Encode one frame; updates the reconstruction reference.
    pub fn encode_frame(&mut self, frame: &Frame) -> FrameStats {
        // take the stream-owned buffers so `self.prev` stays borrowable
        // inside `code_block`; put back (rotated) at the end
        let mut cur = std::mem::replace(&mut self.cur, Planes::empty());
        Planes::from_frame_region_into(frame, self.region, &mut cur, &mut self.cbf, &mut self.crf);
        // the reconstruction is fully overwritten below (the MB grid
        // covers every luma and chroma block), so a recycled buffer of
        // the right shape is equivalent to a fresh black one
        let mut recon = match self.spare.take() {
            Some(p) if p.w == cur.w && p.h == cur.h => p,
            _ => Planes::new_black(cur.w, cur.h),
        };
        let mut stats = FrameStats { bits: 0, intra_mbs: 0, inter_mbs: 0 };
        let mut prev_dc = [0i32; 3]; // per-plane DC predictor

        let n_mbx = cur.w / MB;
        let n_mby = cur.h / MB;
        for mby in 0..n_mby {
            for mbx in 0..n_mbx {
                let (bx, by) = (mbx * MB, mby * MB);
                // ---- mode decision on luma ----
                let (mode_inter, mv) = match &self.prev {
                    None => (false, (0, 0)),
                    Some(prev) => {
                        let cur_plane = motion::Plane { w: cur.w, h: cur.h, data: &cur.y };
                        let prev_plane = motion::Plane { w: prev.w, h: prev.h, data: &prev.y };
                        let (dx, dy, sad) =
                            motion::three_step_search(&cur_plane, &prev_plane, bx, by);
                        // intra activity: deviation from the MB mean
                        let mean = mb_mean(&cur.y, cur.w, bx, by);
                        let intra_sad = mb_sad_to(&cur.y, cur.w, bx, by, mean);
                        (sad < 0.9 * intra_sad + 64.0, (dx, dy))
                    }
                };
                stats.bits += entropy::MODE_BITS as u64;
                if mode_inter {
                    stats.inter_mbs += 1;
                    stats.bits += entropy::mv_bits(mv.0, mv.1) as u64;
                } else {
                    stats.intra_mbs += 1;
                }

                // ---- luma: four 8x8 blocks ----
                for sub in 0..4 {
                    let ox = bx + (sub % 2) * BLOCK;
                    let oy = by + (sub / 2) * BLOCK;
                    let bits = self.code_block(
                        &cur.y,
                        cur.w,
                        &mut recon.y,
                        ox,
                        oy,
                        mode_inter,
                        mv,
                        0,
                        &mut prev_dc[0],
                    );
                    stats.bits += bits as u64;
                }
                // ---- chroma: one 8x8 block per plane (4:2:0) ----
                let (cx, cy) = (bx / 2, by / 2);
                let cmv = (mv.0 / 2, mv.1 / 2);
                let cw = cur.w / 2;
                let bits_cb = {
                    let (cur_cb, prev_ref) = (&cur.cb, 1);
                    let b = self.code_block(
                        cur_cb,
                        cw,
                        &mut recon.cb,
                        cx,
                        cy,
                        mode_inter,
                        cmv,
                        prev_ref,
                        &mut prev_dc[1],
                    );
                    b
                };
                let bits_cr = self.code_block(
                    &cur.cr,
                    cw,
                    &mut recon.cr,
                    cx,
                    cy,
                    mode_inter,
                    cmv,
                    2,
                    &mut prev_dc[2],
                );
                stats.bits += (bits_cb + bits_cr) as u64;
            }
        }
        self.spare = self.prev.take();
        self.prev = Some(recon);
        self.cur = cur;
        stats
    }

    /// Transform-code one 8×8 block of `plane_sel` (0=Y,1=Cb,2=Cr) at
    /// (ox, oy); writes the reconstruction and returns the bit cost.
    #[allow(clippy::too_many_arguments)]
    fn code_block(
        &self,
        cur: &[f32],
        w: usize,
        recon_out: &mut [f32],
        ox: usize,
        oy: usize,
        inter: bool,
        mv: (i32, i32),
        plane_sel: usize,
        prev_dc: &mut i32,
    ) -> u32 {
        let mut residual = [0.0f32; BLOCK * BLOCK];
        let mut pred = [0.0f32; BLOCK * BLOCK];
        // build prediction
        if inter {
            let prev = self.prev.as_ref().expect("inter without reference");
            let (pw, pdata) = match plane_sel {
                0 => (prev.w, &prev.y),
                1 => (prev.w / 2, &prev.cb),
                _ => (prev.w / 2, &prev.cr),
            };
            let ph = pdata.len() / pw;
            for y in 0..BLOCK {
                for x in 0..BLOCK {
                    let sx = (ox as i32 + x as i32 + mv.0).clamp(0, pw as i32 - 1) as usize;
                    let sy = (oy as i32 + y as i32 + mv.1).clamp(0, ph as i32 - 1) as usize;
                    pred[y * BLOCK + x] = pdata[sy * pw + sx];
                }
            }
        } else {
            let flat = if plane_sel == 0 { 128.0 } else { 128.0 };
            pred = [flat; BLOCK * BLOCK];
        }
        for y in 0..BLOCK {
            for x in 0..BLOCK {
                residual[y * BLOCK + x] = cur[(oy + y) * w + ox + x] - pred[y * BLOCK + x];
            }
        }
        dct::forward(&mut residual);
        let levels = dct::quantize(&residual, self.qp);
        let (bits, dc) = entropy::block_bits(&levels, *prev_dc);
        *prev_dc = dc;
        // reconstruction
        let mut deq = dct::dequantize(&levels, self.qp);
        dct::inverse(&mut deq);
        for y in 0..BLOCK {
            for x in 0..BLOCK {
                recon_out[(oy + y) * w + ox + x] =
                    (pred[y * BLOCK + x] + deq[y * BLOCK + x]).clamp(0.0, 255.0);
            }
        }
        bits
    }
}

/// 16×16 intra-activity mean, dispatched through the scalar/AVX2
/// kernels (byte-identical either way; see [`super::kernels`]).
fn mb_mean(plane: &[f32], w: usize, bx: usize, by: usize) -> f32 {
    const _: () = assert!(MB == 16, "intra kernels assume 16x16 macroblocks");
    kernels::intra_mean_16x16(plane, w, bx, by)
}

/// 16×16 sum of absolute deviations from `target`, dispatched through
/// the scalar/AVX2 kernels (byte-identical either way).
fn mb_sad_to(plane: &[f32], w: usize, bx: usize, by: usize, target: f32) -> f32 {
    kernels::intra_sad_16x16(plane, w, bx, by, target)
}

/// Encoded output of one camera segment.
#[derive(Debug, Clone)]
pub struct EncodedSegment {
    pub bytes: usize,
    pub n_frames: usize,
    /// Bits per region (diagnostics / Table 3).
    pub region_bits: Vec<u64>,
}

/// Drives all regions of one camera over streaming segments.
pub struct SegmentEncoder {
    streams: Vec<RegionStream>,
}

impl SegmentEncoder {
    pub fn new(regions: &[IRect], qp: f64) -> SegmentEncoder {
        SegmentEncoder {
            streams: regions.iter().map(|&r| RegionStream::new(r, qp as f32)).collect(),
        }
    }

    pub fn n_regions(&self) -> usize {
        self.streams.len()
    }

    /// Encode one segment (GOP) from borrowed frames: resets references
    /// so the segment is independently decodable, then codes every frame
    /// of every region.  The streaming pipeline's entry point — kept
    /// frames stay owned by the camera worker and are never cloned into
    /// the encoder.
    pub fn encode_segment_refs(&mut self, frames: &[&Frame]) -> EncodedSegment {
        for s in self.streams.iter_mut() {
            s.reset_gop();
        }
        let mut region_bits = vec![0u64; self.streams.len()];
        for frame in frames {
            for (ri, s) in self.streams.iter_mut().enumerate() {
                let st = s.encode_frame(frame);
                region_bits[ri] += st.bits;
            }
        }
        let payload: u64 = region_bits.iter().sum();
        let bytes = (payload as usize).div_ceil(8)
            + self.streams.len() * frames.len() * REGION_HEADER_BYTES
            + SEGMENT_HEADER_BYTES;
        EncodedSegment { bytes, n_frames: frames.len(), region_bits }
    }

    /// Encode one segment from owned frames (convenience wrapper around
    /// [`SegmentEncoder::encode_segment_refs`]).
    pub fn encode_segment(&mut self, frames: &[Frame]) -> EncodedSegment {
        let refs: Vec<&Frame> = frames.iter().collect();
        self.encode_segment_refs(&refs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::sim::Scenario;

    fn frames(n: usize) -> Vec<Frame> {
        let sc = Scenario::build(&Config::test_small().scenario);
        let r = sc.renderer();
        (0..n).map(|i| r.render(0, i)).collect()
    }

    #[test]
    fn planes_shape_and_padding() {
        let f = Frame::new(320, 192);
        let p = Planes::from_frame_region(&f, IRect::new(0, 0, 50, 30));
        assert_eq!(p.w, 64); // padded to MB multiple
        assert_eq!(p.h, 32);
        assert_eq!(p.cb.len(), 32 * 16);
    }

    #[test]
    fn gray_conversion() {
        let mut f = Frame::new(32, 32);
        for y in 0..32 {
            for x in 0..32 {
                f.set(x, y, [100, 100, 100]);
            }
        }
        let p = Planes::from_frame_region(&f, IRect::new(0, 0, 32, 32));
        assert!((p.y[0] - 100.0).abs() < 0.5);
        assert!((p.cb[0] - 128.0).abs() < 0.5);
        assert!((p.cr[0] - 128.0).abs() < 0.5);
    }

    #[test]
    fn p_frames_are_smaller_than_i_frames() {
        let fs = frames(5);
        let mut rs = RegionStream::new(IRect::new(0, 0, 320, 192), 6.0);
        let i_bits = rs.encode_frame(&fs[0]).bits;
        let p_bits = rs.encode_frame(&fs[1]).bits;
        assert!(
            (p_bits as f64) < 0.8 * i_bits as f64,
            "P frame {p_bits} not much smaller than I frame {i_bits}"
        );
    }

    #[test]
    fn reconstruction_quality_reasonable() {
        let fs = frames(2);
        let region = IRect::new(0, 0, 320, 192);
        let mut rs = RegionStream::new(region, 4.0);
        rs.encode_frame(&fs[0]);
        let orig = Planes::from_frame_region(&fs[0], region);
        let psnr = orig.psnr_luma(rs.last_recon().unwrap());
        assert!(psnr > 30.0, "PSNR too low: {psnr}");
    }

    #[test]
    fn lower_qp_better_quality_bigger_size() {
        let fs = frames(1);
        let region = IRect::new(0, 0, 320, 192);
        let mut hi = RegionStream::new(region, 2.0);
        let mut lo = RegionStream::new(region, 12.0);
        let bits_hi = hi.encode_frame(&fs[0]).bits;
        let bits_lo = lo.encode_frame(&fs[0]).bits;
        assert!(bits_hi > bits_lo);
        let orig = Planes::from_frame_region(&fs[0], region);
        let p_hi = orig.psnr_luma(hi.last_recon().unwrap());
        let p_lo = orig.psnr_luma(lo.last_recon().unwrap());
        assert!(p_hi > p_lo, "{p_hi} vs {p_lo}");
    }

    #[test]
    fn tiled_encoding_is_larger_than_whole_frame() {
        // Table 3's mechanism: independent tiles degrade compression
        let fs = frames(6);
        let mut whole = SegmentEncoder::new(&[IRect::new(0, 0, 320, 192)], 6.0);
        let tiles: Vec<IRect> = (0..4)
            .flat_map(|ty| (0..4).map(move |tx| IRect::new(tx * 80, ty * 48, 80, 48)))
            .collect();
        let mut tiled = SegmentEncoder::new(&tiles, 6.0);
        let a = whole.encode_segment(&fs);
        let b = tiled.encode_segment(&fs);
        assert!(
            b.bytes > a.bytes,
            "tiled {} should exceed whole-frame {}",
            b.bytes,
            a.bytes
        );
    }

    #[test]
    fn borrowed_and_owned_segment_paths_are_identical() {
        let fs = frames(4);
        let refs: Vec<&Frame> = fs.iter().collect();
        let region = [IRect::new(0, 0, 320, 192)];
        let mut a = SegmentEncoder::new(&region, 6.0);
        let mut b = SegmentEncoder::new(&region, 6.0);
        let ea = a.encode_segment(&fs);
        let eb = b.encode_segment_refs(&refs);
        assert_eq!(ea.bytes, eb.bytes);
        assert_eq!(ea.region_bits, eb.region_bits);
    }

    #[test]
    fn segment_reset_makes_first_frame_intra() {
        let fs = frames(3);
        let mut enc = SegmentEncoder::new(&[IRect::new(0, 0, 160, 96)], 6.0);
        let s1 = enc.encode_segment(&fs);
        let s2 = enc.encode_segment(&fs);
        // identical input segments → identical sizes (reference was reset)
        assert_eq!(s1.bytes, s2.bytes);
    }

    /// Buffer-reusing conversion must equal a fresh conversion bit-for-bit,
    /// including when the reused buffers change shape between regions
    /// (odd offsets exercise edge replication and clamping).
    #[test]
    fn from_frame_region_into_reuses_buffers_exactly() {
        let fs = frames(2);
        let mut out = Planes::empty();
        let (mut cbf, mut crf) = (Vec::new(), Vec::new());
        let bits = |v: &[f32]| v.iter().map(|f| f.to_bits()).collect::<Vec<_>>();
        let regions = [
            IRect::new(0, 0, 320, 192),
            IRect::new(64, 48, 160, 96),
            IRect::new(63, 47, 161, 97),
        ];
        for region in regions {
            for f in &fs {
                Planes::from_frame_region_into(f, region, &mut out, &mut cbf, &mut crf);
                let fresh = Planes::from_frame_region(f, region);
                assert_eq!((out.w, out.h), (fresh.w, fresh.h));
                assert_eq!(bits(&out.y), bits(&fresh.y));
                assert_eq!(bits(&out.cb), bits(&fresh.cb));
                assert_eq!(bits(&out.cr), bits(&fresh.cr));
            }
        }
    }

    #[test]
    fn longer_segments_compress_better_per_frame() {
        let fs = frames(8);
        let region = [IRect::new(0, 0, 320, 192)];
        let mut enc_short = SegmentEncoder::new(&region, 6.0);
        let mut total_short = 0;
        for chunk in fs.chunks(2) {
            total_short += enc_short.encode_segment(chunk).bytes;
        }
        let mut enc_long = SegmentEncoder::new(&region, 6.0);
        let total_long = enc_long.encode_segment(&fs).bytes;
        assert!(
            total_long < total_short,
            "8-frame GOP {total_long} should beat 4x 2-frame GOPs {total_short}"
        );
    }
}
