//! Deterministic 2-D rectangle packing for cross-camera RoI consolidation.
//!
//! Packs the kept tile groups of every camera in a batch window into a
//! minimal set of detector-sized canvases (shelf first-fit over sorted
//! items), so N mostly-empty inferences become a few dense ones — the
//! object-level consolidation idea of arXiv 2111.15451 applied to
//! CrossRoI's tile groups.
//!
//! Determinism contract: the output is a pure function of the item
//! **set** — items are re-sorted internally by `(h desc, w desc, id
//! asc)`, so callers may enumerate jobs in any order (worker count,
//! batch arrival order) and still get byte-identical placements.  The
//! shelf scan itself is first-fit in shelf creation order, which is
//! itself determined by the sorted item sequence.
//!
//! Gutter: adjacent placements are separated by at least `gutter`
//! pixels on both axes (canvas edges need none — the detector pads with
//! zeros anyway).  The consumer relies on this to keep one placement's
//! receptive field from reading another placement's pixels.

/// One rectangle to place (dimensions in pixels, 16-px multiples in the
/// consolidation path).  `id` is the caller's provenance key.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PackItem {
    pub id: usize,
    pub w: u32,
    pub h: u32,
}

/// Where one item landed: canvas index and top-left corner.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Placement {
    pub id: usize,
    pub canvas: usize,
    pub x: u32,
    pub y: u32,
}

/// An open shelf: a horizontal strip of one canvas with a fixed height,
/// filled left to right.
#[derive(Clone, Copy, Debug)]
struct Shelf {
    canvas: usize,
    y: u32,
    height: u32,
    cursor_x: u32,
}

/// Reusable shelf packer.  All scratch lives in the struct so warm
/// `pack` calls allocate nothing (the hot-path contract of
/// `pipeline/arena.rs` extends through consolidation).
pub struct Packer {
    canvas_w: u32,
    canvas_h: u32,
    gutter: u32,
    // scratch, cleared (not shrunk) every call
    order: Vec<usize>,
    shelves: Vec<Shelf>,
    canvas_used_h: Vec<u32>,
}

impl Packer {
    pub fn new(canvas_w: u32, canvas_h: u32, gutter: u32) -> Self {
        assert!(canvas_w > 0 && canvas_h > 0);
        Packer {
            canvas_w,
            canvas_h,
            gutter,
            order: Vec::new(),
            shelves: Vec::new(),
            canvas_used_h: Vec::new(),
        }
    }

    /// Pack `items` into as few canvases as first-fit-decreasing finds;
    /// placements (one per item, any order) are appended to
    /// `placements` after it is cleared.  Returns the canvas count.
    ///
    /// Every item must fit a canvas on its own
    /// (`w <= canvas_w && h <= canvas_h`); the consolidation caller
    /// guarantees this because group rects are clipped to the frame,
    /// whose dimensions are the canvas dimensions.
    pub fn pack(&mut self, items: &[PackItem], placements: &mut Vec<Placement>) -> usize {
        placements.clear();
        self.order.clear();
        self.shelves.clear();
        self.canvas_used_h.clear();
        self.order.extend(0..items.len());
        // sort key makes the result input-order independent: tallest
        // first (classic shelf FFD), ties by width then by caller id
        self.order.sort_unstable_by(|&a, &b| {
            let (ia, ib) = (&items[a], &items[b]);
            ib.h.cmp(&ia.h).then(ib.w.cmp(&ia.w)).then(ia.id.cmp(&ib.id))
        });
        for &idx in &self.order {
            let it = items[idx];
            assert!(it.w > 0 && it.h > 0, "degenerate pack item {it:?}");
            assert!(
                it.w <= self.canvas_w && it.h <= self.canvas_h,
                "item {it:?} exceeds canvas {}x{}",
                self.canvas_w,
                self.canvas_h
            );
            // first shelf (creation order) with enough height and width
            let slot = self
                .shelves
                .iter_mut()
                .find(|s| it.h <= s.height && s.cursor_x + it.w <= self.canvas_w);
            let (canvas, x, y) = if let Some(s) = slot {
                let at = (s.canvas, s.cursor_x, s.y);
                s.cursor_x += it.w + self.gutter;
                at
            } else {
                // first canvas with vertical room for a new shelf
                let cv = self
                    .canvas_used_h
                    .iter()
                    .position(|&used| used + it.h <= self.canvas_h)
                    .unwrap_or_else(|| {
                        self.canvas_used_h.push(0);
                        self.canvas_used_h.len() - 1
                    });
                let y = self.canvas_used_h[cv];
                self.canvas_used_h[cv] = y + it.h + self.gutter;
                self.shelves.push(Shelf { canvas: cv, y, height: it.h, cursor_x: it.w + self.gutter });
                (cv, 0, y)
            };
            placements.push(Placement { id: it.id, canvas, x, y });
        }
        self.canvas_used_h.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn packed(items: &[PackItem]) -> (usize, Vec<Placement>) {
        let mut p = Packer::new(320, 192, 16);
        let mut out = Vec::new();
        let n = p.pack(items, &mut out);
        (n, out)
    }

    /// Expand a placement by the gutter on the trailing edges; disjoint
    /// expanded rects ⇒ at least `gutter` px between original rects.
    fn overlaps(a: &Placement, wa: u32, ha: u32, b: &Placement, wb: u32, hb: u32, g: u32) -> bool {
        a.canvas == b.canvas
            && a.x < b.x + wb + g
            && b.x < a.x + wa + g
            && a.y < b.y + hb + g
            && b.y < a.y + ha + g
    }

    #[test]
    fn single_full_frame_item_fills_one_canvas() {
        let (n, out) = packed(&[PackItem { id: 7, w: 320, h: 192 }]);
        assert_eq!(n, 1);
        assert_eq!(out, vec![Placement { id: 7, canvas: 0, x: 0, y: 0 }]);
    }

    #[test]
    fn small_items_share_a_canvas() {
        let items: Vec<PackItem> =
            (0..6).map(|i| PackItem { id: i, w: 64, h: 48 }).collect();
        let (n, out) = packed(&items);
        assert_eq!(n, 1, "6 small groups must consolidate into one canvas");
        assert_eq!(out.len(), 6);
    }

    #[test]
    fn placements_stay_in_bounds_and_respect_gutter() {
        let items: Vec<PackItem> = vec![
            PackItem { id: 0, w: 320, h: 64 },
            PackItem { id: 1, w: 160, h: 96 },
            PackItem { id: 2, w: 160, h: 96 },
            PackItem { id: 3, w: 48, h: 16 },
            PackItem { id: 4, w: 16, h: 16 },
            PackItem { id: 5, w: 128, h: 176 },
        ];
        let (n, out) = packed(&items);
        assert!(n >= 2);
        let dims = |id: usize| {
            let it = items.iter().find(|i| i.id == id).unwrap();
            (it.w, it.h)
        };
        for p in &out {
            let (w, h) = dims(p.id);
            assert!(p.x + w <= 320 && p.y + h <= 192, "{p:?} out of bounds");
        }
        for (i, a) in out.iter().enumerate() {
            for b in &out[i + 1..] {
                let (wa, ha) = dims(a.id);
                let (wb, hb) = dims(b.id);
                assert!(!overlaps(a, wa, ha, b, wb, hb, 16), "{a:?} too close to {b:?}");
            }
        }
    }

    #[test]
    fn output_is_input_order_independent() {
        let items: Vec<PackItem> = vec![
            PackItem { id: 0, w: 96, h: 64 },
            PackItem { id: 1, w: 64, h: 64 },
            PackItem { id: 2, w: 160, h: 96 },
            PackItem { id: 3, w: 16, h: 16 },
            PackItem { id: 4, w: 240, h: 112 },
        ];
        let (n1, mut a) = packed(&items);
        let mut rev: Vec<PackItem> = items.iter().rev().copied().collect();
        rev.swap(0, 2);
        let (n2, mut b) = packed(&rev);
        a.sort_by_key(|p| p.id);
        b.sort_by_key(|p| p.id);
        assert_eq!(n1, n2);
        assert_eq!(a, b, "packing must not depend on item arrival order");
    }

    #[test]
    fn warm_packer_reuses_scratch() {
        let items: Vec<PackItem> =
            (0..9).map(|i| PackItem { id: i, w: 80, h: 48 }).collect();
        let mut p = Packer::new(320, 192, 16);
        let mut out = Vec::new();
        let n1 = p.pack(&items, &mut out);
        let first = out.clone();
        let n2 = p.pack(&items, &mut out);
        assert_eq!(n1, n2);
        assert_eq!(first, out, "repacking the same items must be idempotent");
    }
}
