//! Tile grouping (§4.3.2, Fig. 5): merge a RoI mask's fine tiles into few
//! large rectangles so the codec's independent regions are as big as
//! possible (better motion reference reuse, fewer per-region headers).
//!
//! Greedy, as in the paper: repeatedly take the **largest inscribed
//! rectangle** of the remaining mask (maximal all-ones rectangle in a
//! binary grid, histogram-stack DP, O(cells) per iteration) until every
//! mask tile is covered.  Groups partition the mask exactly — no non-RoI
//! tile is ever included.

pub mod pack;

use crate::roi::masks::RoiMasks;
use crate::util::geometry::IRect;

/// Largest all-true rectangle in a binary grid (row-major `w × h`).
/// Returns (x, y, w, h) in cells, or None if the grid is all false.
pub fn largest_rectangle(grid: &[bool], w: usize, h: usize) -> Option<(usize, usize, usize, usize)> {
    assert_eq!(grid.len(), w * h);
    let mut heights = vec![0usize; w];
    let mut best: Option<(usize, (usize, usize, usize, usize))> = None;
    for y in 0..h {
        for x in 0..w {
            heights[x] = if grid[y * w + x] { heights[x] + 1 } else { 0 };
        }
        // largest rectangle in histogram via a monotonic stack
        let mut stack: Vec<usize> = Vec::new(); // indices with increasing heights
        for x in 0..=w {
            let cur = if x < w { heights[x] } else { 0 };
            while let Some(&top) = stack.last() {
                if heights[top] <= cur {
                    break;
                }
                stack.pop();
                let hgt = heights[top];
                let left = stack.last().map_or(0, |&l| l + 1);
                let width = x - left;
                let area = hgt * width;
                if best.map_or(true, |(a, _)| area > a) {
                    best = Some((area, (left, y + 1 - hgt, width, hgt)));
                }
            }
            stack.push(x);
        }
    }
    best.map(|(_, r)| r)
}

/// Greedy tile grouping of one camera's mask; returns pixel rectangles.
pub fn group_camera(masks: &RoiMasks, cam: usize) -> Vec<IRect> {
    let w = masks.tiling.tiles_x as usize;
    let h = masks.tiling.tiles_y as usize;
    let t = masks.tiling.tile_px;
    let mut grid = vec![false; w * h];
    for &(tx, ty) in &masks.tiles[cam] {
        grid[ty as usize * w + tx as usize] = true;
    }
    let mut groups = Vec::new();
    while let Some((x, y, rw, rh)) = largest_rectangle(&grid, w, h) {
        groups.push(IRect::new(x as u32 * t, y as u32 * t, rw as u32 * t, rh as u32 * t));
        for yy in y..y + rh {
            for xx in x..x + rw {
                grid[yy * w + xx] = false;
            }
        }
    }
    groups
}

/// Group every camera's mask.
pub fn group_all(masks: &RoiMasks) -> Vec<Vec<IRect>> {
    (0..masks.tiling.n_cameras).map(|c| group_camera(masks, c)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::association::tiles::Tiling;
    use std::collections::HashSet;

    fn masks_from(tiles: &[(u32, u32)]) -> RoiMasks {
        let tiling = Tiling::new(1, 320, 192, 16);
        let mut set = HashSet::new();
        set.extend(tiles.iter().copied());
        RoiMasks { tiling, tiles: vec![set] }
    }

    #[test]
    fn histogram_rectangle_basics() {
        // 4x3 grid with a 3x2 block of ones
        #[rustfmt::skip]
        let grid = [
            false, true,  true,  true,
            false, true,  true,  true,
            true,  false, false, false,
        ];
        let r = largest_rectangle(&grid, 4, 3).unwrap();
        assert_eq!(r, (1, 0, 3, 2));
        assert!(largest_rectangle(&[false; 6], 3, 2).is_none());
        let full = largest_rectangle(&[true; 6], 3, 2).unwrap();
        assert_eq!(full, (0, 0, 3, 2));
    }

    #[test]
    fn groups_partition_the_mask() {
        // the Fig. 5 shape: an L of tiles
        let tiles: Vec<(u32, u32)> = (0..4)
            .flat_map(|x| (0..3).map(move |y| (x, y)))
            .chain((0..2).map(|y| (4, y)))
            .collect();
        let m = masks_from(&tiles);
        let groups = group_camera(&m, 0);
        // exact cover: areas sum to tile count, no overlaps, all inside mask
        let total_area: u64 = groups.iter().map(|g| g.area()).sum();
        assert_eq!(total_area, tiles.len() as u64 * 16 * 16);
        for g in &groups {
            assert_eq!(g.x % 16, 0);
            assert_eq!(g.w % 16, 0);
            for ty in g.y / 16..(g.y + g.h) / 16 {
                for tx in g.x / 16..(g.x + g.w) / 16 {
                    assert!(tiles.contains(&(tx, ty)), "group covers non-mask tile {tx},{ty}");
                }
            }
        }
        // greedy takes the 4x3 block first
        assert_eq!(groups[0], IRect::new(0, 0, 64, 48));
        assert!(groups.len() <= 3, "too many groups: {groups:?}");
    }

    #[test]
    fn single_tile_mask() {
        let m = masks_from(&[(7, 4)]);
        let groups = group_camera(&m, 0);
        assert_eq!(groups, vec![IRect::new(112, 64, 16, 16)]);
    }

    #[test]
    fn empty_mask_no_groups() {
        let m = masks_from(&[]);
        assert!(group_camera(&m, 0).is_empty());
    }

    #[test]
    fn grouping_reduces_region_count() {
        // a solid 6x4 block of 24 tiles must become exactly 1 group
        let tiles: Vec<(u32, u32)> =
            (2..8).flat_map(|x| (3..7).map(move |y| (x, y))).collect();
        let m = masks_from(&tiles);
        let groups = group_camera(&m, 0);
        assert_eq!(groups.len(), 1);
        assert_eq!(groups[0], IRect::new(32, 48, 96, 64));
    }

    #[test]
    fn checkerboard_worst_case() {
        let tiles: Vec<(u32, u32)> = (0..8)
            .flat_map(|x| (0..6).map(move |y| (x, y)))
            .filter(|(x, y)| (x + y) % 2 == 0)
            .collect();
        let m = masks_from(&tiles);
        let groups = group_camera(&m, 0);
        // no merging possible: one group per tile
        assert_eq!(groups.len(), tiles.len());
    }
}
