//! RBF-kernel soft-margin SVM trained with (simplified) SMO — the second
//! tandem filter (§4.2.3).
//!
//! The paper trains *and applies* the SVM on the same samples: it is a
//! data filter, not a generalizing classifier.  Negative samples that land
//! in the positive region (`f(x) > 0`) are the false negatives to remove;
//! γ controls kernel non-linearity exactly as in Fig. 9 (tiny γ ⇒ nearly
//! linear boundary, many negative outliers; huge γ ⇒ memorizes everything,
//! no outliers).

use crate::util::rng::Rng;

/// SVM hyperparameters.
#[derive(Debug, Clone)]
pub struct SvmParams {
    /// RBF kernel width γ (paper default 1e-4 after the Fig. 9 sweep —
    /// note the paper's bboxes are 1080p-scale while ours are pre-scaled
    /// to O(1) features, so sweeps here cover a γ grid around 1).
    pub gamma: f64,
    /// Soft-margin C (negative class; the positive class is weighted).
    pub c: f64,
    /// Positive-class C multiplier.  `None` ⇒ "balanced": n_neg / n_pos,
    /// the sklearn `class_weight="balanced"` convention — the positive
    /// class is the scarce one (O2) and must not be drowned by the false
    /// negatives contaminating its region.
    pub pos_weight: Option<f64>,
    /// SMO convergence tolerance.
    pub tol: f64,
    /// Max passes without alpha changes before declaring convergence.
    pub max_passes: usize,
    /// Hard cap on SMO iterations.
    pub max_iters: usize,
    pub seed: u64,
}

impl Default for SvmParams {
    fn default() -> Self {
        SvmParams {
            gamma: 1.0,
            c: 4.0,
            pos_weight: None,
            tol: 1e-3,
            max_passes: 4,
            max_iters: 40_000,
            seed: 0x5F4,
        }
    }
}

/// A trained SVM (stores its own training set — it is applied back onto
/// exactly those samples).
pub struct Svm {
    x: Vec<Vec<f64>>,
    y: Vec<f64>,
    alpha: Vec<f64>,
    b: f64,
    gamma: f64,
}

fn rbf(a: &[f64], b: &[f64], gamma: f64) -> f64 {
    let d2: f64 = a.iter().zip(b).map(|(p, q)| (p - q) * (p - q)).sum();
    (-gamma * d2).exp()
}

impl Svm {
    /// Train on `(x, y)` with y ∈ {+1, −1} using simplified SMO.
    pub fn train(x: Vec<Vec<f64>>, y: Vec<f64>, params: &SvmParams) -> Svm {
        assert_eq!(x.len(), y.len());
        assert!(y.iter().all(|&v| v == 1.0 || v == -1.0), "labels must be ±1");
        let n = x.len();
        let mut alpha = vec![0.0f64; n];
        let mut b = 0.0f64;
        if n == 0 {
            return Svm { x, y, alpha, b, gamma: params.gamma };
        }
        let mut rng = Rng::new(params.seed).fork(n as u64);
        // per-class soft-margin bound
        let n_pos = y.iter().filter(|&&v| v > 0.0).count().max(1);
        let n_neg = (n - n_pos).max(1);
        let pos_w = params.pos_weight.unwrap_or(n_neg as f64 / n_pos as f64).max(1.0);
        let c_of = |label: f64| if label > 0.0 { params.c * pos_w } else { params.c };

        // precompute the kernel matrix when it fits (n ≤ ~3000)
        let kmat: Option<Vec<f32>> = if n * n <= 9_000_000 {
            let mut k = vec![0.0f32; n * n];
            for i in 0..n {
                for j in i..n {
                    let v = rbf(&x[i], &x[j], params.gamma) as f32;
                    k[i * n + j] = v;
                    k[j * n + i] = v;
                }
            }
            Some(k)
        } else {
            None
        };
        let kernel = |i: usize, j: usize, x: &[Vec<f64>]| -> f64 {
            match &kmat {
                Some(k) => k[i * n + j] as f64,
                None => rbf(&x[i], &x[j], params.gamma),
            }
        };
        let f = |i: usize, alpha: &[f64], b: f64, x: &[Vec<f64>], y: &[f64]| -> f64 {
            let mut acc = b;
            for j in 0..n {
                if alpha[j] != 0.0 {
                    acc += alpha[j] * y[j] * kernel(j, i, x);
                }
            }
            acc
        };

        let mut passes = 0;
        let mut iters = 0;
        while passes < params.max_passes && iters < params.max_iters {
            let mut changed = 0;
            for i in 0..n {
                iters += 1;
                let ei = f(i, &alpha, b, &x, &y) - y[i];
                let ci = c_of(y[i]);
                let kkt_violated = (y[i] * ei < -params.tol && alpha[i] < ci)
                    || (y[i] * ei > params.tol && alpha[i] > 0.0);
                if !kkt_violated {
                    continue;
                }
                // pick a random j != i
                let mut j = rng.below(n - 1);
                if j >= i {
                    j += 1;
                }
                let cj = c_of(y[j]);
                let ej = f(j, &alpha, b, &x, &y) - y[j];
                let (ai_old, aj_old) = (alpha[i], alpha[j]);
                let (lo, hi) = if y[i] != y[j] {
                    ((aj_old - ai_old).max(0.0), (ci + aj_old - ai_old).min(cj))
                } else {
                    ((ai_old + aj_old - ci).max(0.0), (ai_old + aj_old).min(cj))
                };
                if (hi - lo).abs() < 1e-12 {
                    continue;
                }
                let eta = 2.0 * kernel(i, j, &x) - kernel(i, i, &x) - kernel(j, j, &x);
                if eta >= 0.0 {
                    continue;
                }
                let mut aj = aj_old - y[j] * (ei - ej) / eta;
                aj = aj.clamp(lo, hi);
                if (aj - aj_old).abs() < 1e-6 {
                    continue;
                }
                let ai = ai_old + y[i] * y[j] * (aj_old - aj);
                alpha[i] = ai;
                alpha[j] = aj;
                let b1 = b - ei
                    - y[i] * (ai - ai_old) * kernel(i, i, &x)
                    - y[j] * (aj - aj_old) * kernel(i, j, &x);
                let b2 = b - ej
                    - y[i] * (ai - ai_old) * kernel(i, j, &x)
                    - y[j] * (aj - aj_old) * kernel(j, j, &x);
                b = if ai > 0.0 && ai < ci {
                    b1
                } else if aj > 0.0 && aj < cj {
                    b2
                } else {
                    (b1 + b2) / 2.0
                };
                changed += 1;
            }
            if changed == 0 {
                passes += 1;
            } else {
                passes = 0;
            }
        }
        Svm { x, y, alpha, b, gamma: params.gamma }
    }

    /// Decision value for an arbitrary point.
    pub fn decision(&self, p: &[f64]) -> f64 {
        let mut acc = self.b;
        for j in 0..self.x.len() {
            if self.alpha[j] != 0.0 {
                acc += self.alpha[j] * self.y[j] * rbf(&self.x[j], p, self.gamma);
            }
        }
        acc
    }

    /// Decision values for the training samples themselves (the filter's
    /// application mode).
    pub fn train_decisions(&self) -> Vec<f64> {
        (0..self.x.len()).map(|i| self.decision(&self.x[i])).collect()
    }

    /// Indices of *negative outliers*: training samples labelled −1 that
    /// the model places in the positive region — the paper's false
    /// negatives (§4.2.3).
    pub fn negative_outliers(&self) -> Vec<usize> {
        self.train_decisions()
            .iter()
            .enumerate()
            .filter(|(i, &d)| self.y[*i] < 0.0 && d > 0.0)
            .map(|(i, _)| i)
            .collect()
    }

    pub fn n_support(&self) -> usize {
        self.alpha.iter().filter(|&&a| a > 1e-9).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// 2-D toy set: positives in a disk around the origin, negatives in a
    /// ring — plus some mislabelled negatives *inside* the disk.
    fn toy(n: usize, planted: usize, seed: u64) -> (Vec<Vec<f64>>, Vec<f64>, Vec<usize>) {
        let mut rng = Rng::new(seed);
        let mut x = Vec::new();
        let mut y = Vec::new();
        for _ in 0..n {
            let a = rng.range(0.0, std::f64::consts::TAU);
            let r = rng.range(0.0, 0.8);
            x.push(vec![r * a.cos(), r * a.sin()]);
            y.push(1.0);
        }
        for _ in 0..n {
            let a = rng.range(0.0, std::f64::consts::TAU);
            let r = rng.range(1.6, 2.6);
            x.push(vec![r * a.cos(), r * a.sin()]);
            y.push(-1.0);
        }
        let mut idx = Vec::new();
        for _ in 0..planted {
            let a = rng.range(0.0, std::f64::consts::TAU);
            let r = rng.range(0.0, 0.5);
            x.push(vec![r * a.cos(), r * a.sin()]);
            y.push(-1.0); // mislabelled: negative inside the positive disk
            idx.push(x.len() - 1);
        }
        (x, y, idx)
    }

    #[test]
    fn separable_data_classifies_cleanly() {
        let (x, y, _) = toy(60, 0, 1);
        let svm = Svm::train(x.clone(), y.clone(), &SvmParams::default());
        let correct = svm
            .train_decisions()
            .iter()
            .zip(&y)
            .filter(|(d, &l)| d.signum() == l)
            .count();
        assert!(correct as f64 / y.len() as f64 > 0.95, "{correct}/{}", y.len());
        assert!(svm.n_support() > 0);
    }

    #[test]
    fn finds_planted_negative_outliers() {
        let (x, y, planted) = toy(80, 8, 2);
        let svm = Svm::train(x, y, &SvmParams::default());
        let outliers = svm.negative_outliers();
        let found = planted.iter().filter(|i| outliers.contains(i)).count();
        assert!(found >= 6, "found only {found}/8 planted FNs; outliers={outliers:?}");
    }

    #[test]
    fn huge_gamma_memorizes_no_outliers() {
        // the Fig. 9 right-end behaviour: overfit kernel finds no outliers
        let (x, y, _) = toy(60, 6, 3);
        let svm = Svm::train(
            x,
            y,
            &SvmParams { gamma: 500.0, c: 100.0, ..Default::default() },
        );
        assert!(
            svm.negative_outliers().len() <= 1,
            "overfit SVM still flags {} outliers",
            svm.negative_outliers().len()
        );
    }

    #[test]
    fn tiny_gamma_flags_more_than_huge() {
        let (x, y, _) = toy(60, 6, 4);
        let lo = Svm::train(x.clone(), y.clone(), &SvmParams { gamma: 0.05, ..Default::default() })
            .negative_outliers()
            .len();
        let hi = Svm::train(x, y, &SvmParams { gamma: 500.0, c: 100.0, ..Default::default() })
            .negative_outliers()
            .len();
        assert!(lo >= hi, "gamma sweep not monotone-ish: lo={lo} hi={hi}");
    }

    #[test]
    fn empty_training_set() {
        let svm = Svm::train(Vec::new(), Vec::new(), &SvmParams::default());
        assert!(svm.negative_outliers().is_empty());
        assert_eq!(svm.decision(&[0.0, 0.0]), 0.0);
    }

    #[test]
    fn deterministic() {
        let (x, y, _) = toy(40, 4, 5);
        let a = Svm::train(x.clone(), y.clone(), &SvmParams::default()).train_decisions();
        let b = Svm::train(x, y, &SvmParams::default()).train_decisions();
        assert_eq!(a, b);
    }
}
