//! The tandem filter pipeline (Fig. 4): raw ReID → regression filter
//! (false positives get fresh ids, becoming negative data) → SVM filter
//! (false negatives are removed) → highly-confident stream for the
//! association/optimization stages.
//!
//! Both filters work per ordered camera pair, and the pairwise work is the
//! part of the offline phase that grows O(n²) with fleet size.  The sample
//! sets of **every** pair are built in one indexed pass over the stream
//! (no per-pair rescans), then the pair models are fitted on scoped worker
//! threads and merged back in pair order — rewrites are applied by record
//! index and fresh ids assigned after the merge, so the output stream is
//! byte-identical to a sequential run at any thread count
//! (`rust/tests/offline_determinism.rs`).

use std::collections::{HashMap, HashSet};
use std::sync::Arc;

use crate::filters::features::bbox4;
use crate::filters::ransac::{self, RansacParams};
use crate::filters::svm::{Svm, SvmParams};
use crate::reid::records::ReidStream;
use crate::util::geometry::Rect;
use crate::util::parallel::ordered_map;
use crate::util::rng::Rng;

/// Tandem filter configuration.
#[derive(Debug, Clone)]
pub struct TandemFilters {
    pub ransac: RansacParams,
    pub svm: SvmParams,
    /// Cap on SVM training samples per camera pair (subsampled above).
    pub svm_max_samples: usize,
    /// Frame size, for the interior predicate below.
    pub frame_w: f64,
    pub frame_h: f64,
    /// Bboxes touching an `edge_margin` border are excluded from the
    /// regression filter: a clipped box breaks the bbox↔bbox functional
    /// relation (a vehicle halfway out of one view maps nowhere), so such
    /// pairs can neither train the mapping nor be judged by it.
    pub edge_margin: f64,
}

impl Default for TandemFilters {
    fn default() -> Self {
        TandemFilters {
            ransac: RansacParams::default(),
            svm: SvmParams::default(),
            svm_max_samples: 2200,
            frame_w: crate::sim::FRAME_W as f64,
            frame_h: crate::sim::FRAME_H as f64,
            edge_margin: 4.0,
        }
    }
}

/// What the filters did (diagnostics + Fig. 9/10 sweeps).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FilterReport {
    /// Camera pairs with enough positives to fit a mapping.
    pub pairs_fit: usize,
    /// Positive records decoupled by the regression filter (FP).
    pub fp_rewritten: usize,
    /// Records removed by the SVM filter (FN).
    pub fn_removed: usize,
}

/// Minimum per-class SVM sample count: pairs with fewer of either class
/// are skipped (no region can be learned), and subsampling always
/// reserves this many negative slots so the training set never collapses
/// to one class.
const MIN_CLASS_SAMPLES: usize = 8;

/// Index of an ordered camera pair in the canonical (src-major, dst-minor,
/// src ≠ dst) enumeration — the merge order that keeps parallel fitting
/// byte-identical to the sequential reference.
fn pair_index(src: usize, dst: usize, n: usize) -> usize {
    debug_assert!(src != dst && src < n && dst < n);
    src * (n - 1) + dst - usize::from(dst > src)
}

/// One ordered pair's regression-filter training set: interior positive
/// (src bbox, dst bbox) pairs plus the src record index behind each.
#[derive(Debug, Default)]
struct PairSamples {
    rec_idx: Vec<usize>,
    pairs: Vec<(Rect, Rect)>,
}

/// One ordered pair's SVM training set: every src-camera record labelled
/// ±1 by whether its id appears in dst at the same frame.  Features and
/// record indices depend only on the source camera, so the `n - 1` pairs
/// sharing a source share one allocation.
#[derive(Debug)]
struct SvmSamples {
    rec_idx: Arc<Vec<usize>>,
    feats: Arc<Vec<Vec<f64>>>,
    labels: Vec<f64>,
}

impl TandemFilters {
    /// Run both filters on the caller's thread; returns the cleaned
    /// stream and a report.
    pub fn apply(&self, stream: &ReidStream) -> (ReidStream, FilterReport) {
        self.apply_with_threads(stream, 1)
    }

    /// Like [`Self::apply`], with the per-pair model fitting spread over
    /// `threads` scoped worker threads.  The result is identical to
    /// `apply` for every thread count (deterministic pair-order merge).
    pub fn apply_with_threads(
        &self,
        stream: &ReidStream,
        threads: usize,
    ) -> (ReidStream, FilterReport) {
        let mut report = FilterReport::default();

        // ---- stage 1: regression filter (per ordered camera pair) ----
        // positive pair = src record whose raw id also appears in dst
        let pair_samples = self.build_pair_samples(stream);
        let fits = ordered_map(&pair_samples, threads, |p| ransac::fit(&p.pairs, &self.ransac));
        let mut rewrites: HashMap<usize, u32> = HashMap::new();
        let mut next_fresh = stream.max_raw_id() + 1;
        for (p, fit) in pair_samples.iter().zip(&fits) {
            let Some(fit) = fit else {
                continue;
            };
            report.pairs_fit += 1;
            for oi in fit.outlier_indices() {
                let rec = p.rec_idx[oi];
                // decouple: fresh id turns this into a negative sample
                rewrites.entry(rec).or_insert_with(|| {
                    report.fp_rewritten += 1;
                    next_fresh += 1;
                    next_fresh - 1
                });
            }
        }
        let stage1 = stream.with_rewrites(&rewrites);

        // ---- stage 2: SVM filter (per ordered camera pair) ----
        // label every src record ±1 by whether its id appears in dst;
        // negative outliers (negatives in the positive region) are FNs.
        let svm_samples = build_svm_samples(&stage1);
        let removals = ordered_map(&svm_samples, threads, |s| self.fit_svm_pair(s));
        let mut remove: Vec<bool> = vec![false; stage1.len()];
        for pair_removals in &removals {
            for &rec in pair_removals {
                if !remove[rec] {
                    report.fn_removed += 1;
                }
                remove[rec] = true;
            }
        }
        let mut i = 0;
        let filtered = stage1.filtered(|_| {
            let k = !remove[i];
            i += 1;
            k
        });
        (filtered, report)
    }

    /// One indexed pass over the stream building every ordered pair's
    /// positive sample set: a `(cam, frame, raw_id) → first record` map
    /// replaces the per-pair `find_id` rescans, and each record fans its
    /// matches out to the pairs it belongs to.  Per-pair vectors are
    /// filled in record order — exactly the order the per-pair rescan
    /// produced.
    fn build_pair_samples(&self, stream: &ReidStream) -> Vec<PairSamples> {
        let n = stream.n_cameras;
        let interior = |b: &Rect| {
            b.left > self.edge_margin
                && b.top > self.edge_margin
                && b.right() < self.frame_w - self.edge_margin
                && b.bottom() < self.frame_h - self.edge_margin
        };
        // first record carrying (cam, frame, raw_id) — what find_id returns
        let mut first: HashMap<(usize, usize, u32), usize> = HashMap::new();
        for (i, rec) in stream.all().iter().enumerate() {
            first.entry((rec.cam, rec.frame, rec.raw_id)).or_insert(i);
        }
        let mut out: Vec<PairSamples> =
            (0..n.saturating_sub(1) * n).map(|_| PairSamples::default()).collect();
        for (i, rec) in stream.all().iter().enumerate() {
            if !interior(&rec.bbox) {
                continue;
            }
            for dst in 0..n {
                if dst == rec.cam {
                    continue;
                }
                let Some(&j) = first.get(&(dst, rec.frame, rec.raw_id)) else {
                    continue;
                };
                let m = &stream.all()[j];
                if !interior(&m.bbox) {
                    continue;
                }
                let p = &mut out[pair_index(rec.cam, dst, n)];
                p.rec_idx.push(i);
                p.pairs.push((rec.bbox, m.bbox));
            }
        }
        out
    }

    /// Train one pair's SVM and return the record indices it removes
    /// (negatives the model places in the positive region).
    fn fit_svm_pair(&self, s: &SvmSamples) -> Vec<usize> {
        let n_pos = s.labels.iter().filter(|&&l| l > 0.0).count();
        if n_pos < MIN_CLASS_SAMPLES || s.labels.len() - n_pos < MIN_CLASS_SAMPLES {
            return Vec::new(); // not enough of either class to learn a region
        }
        // subsample for training if oversized (keep all positives)
        let (tx, ty) = subsample(&s.feats, &s.labels, self.svm_max_samples, self.svm.seed);
        let svm = Svm::train(tx, ty, &self.svm);
        let mut out = Vec::new();
        for (k, f) in s.feats.iter().enumerate() {
            if s.labels[k] < 0.0 && svm.decision(f) > 0.0 {
                out.push(s.rec_idx[k]);
            }
        }
        out
    }
}

/// One indexed pass building every ordered pair's SVM sample set: each
/// record contributes one labelled sample to the `n - 1` pairs it is the
/// source of, with the label looked up in a presence set instead of a
/// per-pair `find_id` scan.  The per-source feature matrix and record
/// indices are built once and shared across that source's pairs.
fn build_svm_samples(stream: &ReidStream) -> Vec<SvmSamples> {
    let n = stream.n_cameras;
    let mut present: HashSet<(usize, usize, u32)> = HashSet::new();
    for rec in stream.all() {
        present.insert((rec.cam, rec.frame, rec.raw_id));
    }
    let mut rec_idx: Vec<Vec<usize>> = vec![Vec::new(); n];
    let mut feats: Vec<Vec<Vec<f64>>> = vec![Vec::new(); n];
    let mut labels: Vec<Vec<f64>> =
        (0..n.saturating_sub(1) * n).map(|_| Vec::new()).collect();
    for (i, rec) in stream.all().iter().enumerate() {
        rec_idx[rec.cam].push(i);
        feats[rec.cam].push(bbox4(&rec.bbox).to_vec());
        for dst in 0..n {
            if dst == rec.cam {
                continue;
            }
            let positive = present.contains(&(dst, rec.frame, rec.raw_id));
            labels[pair_index(rec.cam, dst, n)].push(if positive { 1.0 } else { -1.0 });
        }
    }
    let rec_idx: Vec<Arc<Vec<usize>>> = rec_idx.into_iter().map(Arc::new).collect();
    let feats: Vec<Arc<Vec<Vec<f64>>>> = feats.into_iter().map(Arc::new).collect();
    let mut out = Vec::with_capacity(labels.len());
    for src in 0..n {
        for dst in 0..n {
            if dst == src {
                continue;
            }
            out.push(SvmSamples {
                rec_idx: Arc::clone(&rec_idx[src]),
                feats: Arc::clone(&feats[src]),
                labels: std::mem::take(&mut labels[pair_index(src, dst, n)]),
            });
        }
    }
    out
}

/// Deterministically subsample to `max` samples, keeping **all** positives
/// (they are the scarce class, O2) up to the cap less a reserved negative
/// quota; negatives get the budget the positives leave over.  The quota
/// keeps the training set two-class even when positives alone exceed the
/// cap — a one-class SVM would put the whole plane in the positive region
/// and flag every negative as a false negative.
fn subsample(
    feats: &[Vec<f64>],
    labels: &[f64],
    max: usize,
    seed: u64,
) -> (Vec<Vec<f64>>, Vec<f64>) {
    if feats.len() <= max {
        return (feats.to_vec(), labels.to_vec());
    }
    let pos: Vec<usize> = (0..feats.len()).filter(|&i| labels[i] > 0.0).collect();
    let neg: Vec<usize> = (0..feats.len()).filter(|&i| labels[i] < 0.0).collect();
    let mut rng = Rng::new(seed).fork(feats.len() as u64);
    let neg_quota = neg.len().min(MIN_CLASS_SAMPLES);
    let mut chosen: Vec<usize> = pos.into_iter().take(max.saturating_sub(neg_quota)).collect();
    let budget_neg = max - chosen.len();
    if neg.len() <= budget_neg {
        chosen.extend(neg);
    } else {
        let picks = rng.sample_indices(neg.len(), budget_neg);
        chosen.extend(picks.into_iter().map(|i| neg[i]));
    }
    chosen.sort_unstable();
    (
        chosen.iter().map(|&i| feats[i].clone()).collect(),
        chosen.iter().map(|&i| labels[i]).collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::reid::error_model::{ErrorModelParams, RawReid};
    use crate::reid::labels;
    use crate::sim::Scenario;

    #[test]
    fn pair_index_is_a_bijection() {
        for n in [2usize, 3, 5, 16] {
            let mut seen = vec![false; n * (n - 1)];
            let mut expected = 0usize;
            for src in 0..n {
                for dst in 0..n {
                    if src == dst {
                        continue;
                    }
                    let k = pair_index(src, dst, n);
                    // canonical enumeration order: src-major, dst-minor
                    assert_eq!(k, expected, "pair ({src},{dst}) of {n}");
                    assert!(!seen[k]);
                    seen[k] = true;
                    expected += 1;
                }
            }
            assert!(seen.iter().all(|&s| s));
        }
    }

    #[test]
    fn filters_improve_reid_quality() {
        let sc = Scenario::build(&Config::test_small().scenario);
        let raw = RawReid::generate(&sc, 0..sc.n_frames(), &ErrorModelParams::default());
        let before = labels::characterize_all(&raw);
        let (clean, report) = TandemFilters::default().apply(&raw);
        let after = labels::characterize_all(&clean);

        let sum_fp = |m: &Vec<Vec<labels::PairCounts>>| -> usize {
            m.iter().flat_map(|r| r.iter()).map(|c| c.fp).sum()
        };
        let sum_fn = |m: &Vec<Vec<labels::PairCounts>>| -> usize {
            m.iter().flat_map(|r| r.iter()).map(|c| c.fn_).sum()
        };
        assert!(clean.len() <= raw.len());
        // the cleaned stream must have strictly fewer false negatives
        // whenever the SVM removed anything
        if report.fn_removed > 0 {
            assert!(sum_fn(&after) < sum_fn(&before), "FN not reduced");
        }
        // FP should not grow
        assert!(sum_fp(&after) <= sum_fp(&before), "FP grew");
    }

    #[test]
    fn parallel_apply_is_byte_identical_to_sequential() {
        let sc = Scenario::build(&Config::test_small().scenario);
        let raw = RawReid::generate(&sc, 0..sc.n_frames(), &ErrorModelParams::default());
        let filters = TandemFilters::default();
        let (seq, seq_report) = filters.apply_with_threads(&raw, 1);
        for threads in [2usize, 3, 8] {
            let (par, par_report) = filters.apply_with_threads(&raw, threads);
            assert_eq!(seq_report, par_report, "report diverged at {threads} threads");
            assert_eq!(seq.len(), par.len(), "stream length diverged at {threads} threads");
            for (a, b) in seq.all().iter().zip(par.all()) {
                assert_eq!(a.cam, b.cam);
                assert_eq!(a.frame, b.frame);
                assert_eq!(a.raw_id, b.raw_id, "rewritten ids diverged at {threads} threads");
                assert_eq!(a.bbox, b.bbox);
            }
        }
    }

    #[test]
    fn clean_stream_mostly_untouched() {
        let sc = Scenario::build(&Config::test_small().scenario);
        let params = ErrorModelParams {
            p_fn: 0.0,
            p_fp: 0.0,
            p_miss_occluded: 0.0,
            ..Default::default()
        };
        let raw = RawReid::generate(&sc, 0..sc.n_frames(), &params);
        let (clean, report) = TandemFilters::default().apply(&raw);
        // harsh statistical filtering may nip records (§4.2.4: true
        // negatives that sit in the positive region — e.g. vehicles below
        // the partner camera's visibility cutoff — are legitimately
        // removed), but the bulk of a clean stream must survive
        assert!(
            clean.len() as f64 >= 0.75 * raw.len() as f64,
            "lost too much clean data: {} -> {} (report {report:?})",
            raw.len(),
            clean.len()
        );
        // the learned mapping is exact geometry here: at the operating θ
        // almost no positives should be decoupled
        assert!(
            (report.fp_rewritten as f64) < 0.05 * raw.len() as f64,
            "clean data produced too many FP rewrites: {report:?}"
        );
    }

    #[test]
    fn subsample_respects_cap_and_classes() {
        let feats: Vec<Vec<f64>> = (0..100).map(|i| vec![i as f64]).collect();
        let labels: Vec<f64> = (0..100).map(|i| if i < 20 { 1.0 } else { -1.0 }).collect();
        let (tx, ty) = subsample(&feats, &labels, 50, 1);
        assert!(tx.len() <= 50);
        assert!(ty.iter().filter(|&&l| l > 0.0).count() >= 20.min(25));
    }

    #[test]
    fn subsample_keeps_all_positives_when_they_exceed_half_the_cap() {
        // regression: `take(max / 2)` used to silently drop positives as
        // soon as they exceeded half the cap
        let feats: Vec<Vec<f64>> = (0..100).map(|i| vec![i as f64]).collect();
        let labels: Vec<f64> = (0..100).map(|i| if i < 70 { 1.0 } else { -1.0 }).collect();
        let (tx, ty) = subsample(&feats, &labels, 80, 1);
        assert_eq!(tx.len(), 80);
        assert_eq!(ty.iter().filter(|&&l| l > 0.0).count(), 70, "positives dropped");
        assert_eq!(ty.iter().filter(|&&l| l < 0.0).count(), 10);
        // positives beyond the whole cap are still capped
        let all_pos: Vec<f64> = vec![1.0; 100];
        let (tx, ty) = subsample(&feats, &all_pos, 80, 1);
        assert_eq!(tx.len(), 80);
        assert!(ty.iter().all(|&l| l > 0.0));
    }

    #[test]
    fn subsample_always_reserves_negative_slots() {
        // regression: when positives alone exceed the cap, the negative
        // quota must keep the training set two-class (a one-class SVM
        // would flag every negative as FN)
        let feats: Vec<Vec<f64>> = (0..115).map(|i| vec![i as f64]).collect();
        let labels: Vec<f64> = (0..115).map(|i| if i < 95 { 1.0 } else { -1.0 }).collect();
        let (tx, ty) = subsample(&feats, &labels, 50, 1);
        assert_eq!(tx.len(), 50);
        assert_eq!(ty.iter().filter(|&&l| l > 0.0).count(), 42);
        assert_eq!(ty.iter().filter(|&&l| l < 0.0).count(), 8);
    }
}
