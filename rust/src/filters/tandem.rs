//! The tandem filter pipeline (Fig. 4): raw ReID → regression filter
//! (false positives get fresh ids, becoming negative data) → SVM filter
//! (false negatives are removed) → highly-confident stream for the
//! association/optimization stages.
//!
//! Both filters work per ordered camera pair, and the pairwise work is the
//! part of the offline phase that grows O(n²) with fleet size.  The sample
//! sets of **every** pair are built in one indexed pass over the stream
//! (no per-pair rescans), then the pair models are fitted on scoped worker
//! threads and merged back in pair order — rewrites are applied by record
//! index and fresh ids assigned after the merge, so the output stream is
//! byte-identical to a sequential run at any thread count
//! (`rust/tests/offline_determinism.rs`).

use std::collections::{HashMap, HashSet};
use std::sync::Arc;

use crate::filters::features::bbox4;
use crate::filters::ransac::{self, RansacParams};
use crate::filters::svm::{Svm, SvmParams};
use crate::reid::records::ReidStream;
use crate::util::geometry::Rect;
use crate::util::parallel::ordered_map;
use crate::util::rng::Rng;

/// Tandem filter configuration.
#[derive(Debug, Clone)]
pub struct TandemFilters {
    pub ransac: RansacParams,
    pub svm: SvmParams,
    /// Cap on SVM training samples per camera pair (subsampled above).
    pub svm_max_samples: usize,
    /// Frame size, for the interior predicate below.
    pub frame_w: f64,
    pub frame_h: f64,
    /// Bboxes touching an `edge_margin` border are excluded from the
    /// regression filter: a clipped box breaks the bbox↔bbox functional
    /// relation (a vehicle halfway out of one view maps nowhere), so such
    /// pairs can neither train the mapping nor be judged by it.
    pub edge_margin: f64,
}

impl Default for TandemFilters {
    fn default() -> Self {
        TandemFilters {
            ransac: RansacParams::default(),
            svm: SvmParams::default(),
            svm_max_samples: 2200,
            frame_w: crate::sim::FRAME_W as f64,
            frame_h: crate::sim::FRAME_H as f64,
            edge_margin: 4.0,
        }
    }
}

/// What the filters did (diagnostics + Fig. 9/10 sweeps).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FilterReport {
    /// Camera pairs with enough positives to fit a mapping.
    pub pairs_fit: usize,
    /// Positive records decoupled by the regression filter (FP).
    pub fp_rewritten: usize,
    /// Records removed by the SVM filter (FN).
    pub fn_removed: usize,
}

/// Minimum per-class SVM sample count: pairs with fewer of either class
/// are skipped (no region can be learned), and subsampling always
/// reserves this many negative slots so the training set never collapses
/// to one class.
const MIN_CLASS_SAMPLES: usize = 8;

/// The ordered camera pairs one filter run fits, in canonical
/// (src-major, dst-minor, src ≠ dst) enumeration — the merge order that
/// keeps parallel fitting byte-identical to the sequential reference.
///
/// A whole-fleet run enumerates every ordered pair; a camera-scoped run
/// ([`TandemFilters::apply_scoped`], used by the sharded planner in
/// `crate::offline::shard`) enumerates only pairs inside the subset —
/// cross-shard pairs share no observations, so building their (empty)
/// sample sets would only burn the O(n²) the sharding exists to avoid.
#[derive(Debug)]
pub struct PairSet {
    /// (src, dst) per slot, canonical order (global camera indices).
    pairs: Vec<(usize, usize)>,
    /// Destination cameras of each source, ascending (per-record fan-out;
    /// indexed by global camera).
    dsts: Vec<Vec<usize>>,
    /// Global camera → dense member index (`usize::MAX` = not a member).
    /// O(n) per set — a scoped set must not pay O(n²) in the global
    /// camera count, or sharding would reintroduce the cost it removes.
    member: Vec<usize>,
    /// `member(src) * k + member(dst)` → slot (`usize::MAX` = src = dst).
    slot: Vec<usize>,
    /// Member count.
    k: usize,
}

impl PairSet {
    /// Every ordered pair of an `n`-camera fleet.
    pub fn all(n: usize) -> PairSet {
        let cams: Vec<usize> = (0..n).collect();
        PairSet::among(n, &cams)
    }

    /// Only the ordered pairs within `cams` (global indices < `n`,
    /// sorted ascending, deduplicated).
    pub fn among(n: usize, cams: &[usize]) -> PairSet {
        debug_assert!(cams.windows(2).all(|w| w[0] < w[1]), "cameras not sorted/deduped");
        debug_assert!(cams.iter().all(|&c| c < n), "camera index out of range");
        let k = cams.len();
        let mut member = vec![usize::MAX; n];
        for (i, &c) in cams.iter().enumerate() {
            member[c] = i;
        }
        let mut pairs = Vec::with_capacity(k * k.saturating_sub(1));
        let mut dsts: Vec<Vec<usize>> = vec![Vec::new(); n];
        let mut slot = vec![usize::MAX; k * k];
        for (si, &src) in cams.iter().enumerate() {
            for (di, &dst) in cams.iter().enumerate() {
                if src == dst {
                    continue;
                }
                slot[si * k + di] = pairs.len();
                pairs.push((src, dst));
                dsts[src].push(dst);
            }
        }
        PairSet { pairs, dsts, member, slot, k }
    }

    /// Number of enumerated pairs.
    pub fn len(&self) -> usize {
        self.pairs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pairs.is_empty()
    }

    /// Slot of an ordered pair (`usize::MAX` when not enumerated).
    fn slot_of(&self, src: usize, dst: usize) -> usize {
        let (si, di) = (self.member[src], self.member[dst]);
        if si == usize::MAX || di == usize::MAX {
            return usize::MAX;
        }
        self.slot[si * self.k + di]
    }
}

/// One ordered pair's regression-filter training set: interior positive
/// (src bbox, dst bbox) pairs plus the src record index behind each.
#[derive(Debug, Default)]
struct PairSamples {
    rec_idx: Vec<usize>,
    pairs: Vec<(Rect, Rect)>,
}

/// One ordered pair's SVM training set: every src-camera record labelled
/// ±1 by whether its id appears in dst at the same frame.  Features and
/// record indices depend only on the source camera, so the `n - 1` pairs
/// sharing a source share one allocation.
#[derive(Debug)]
struct SvmSamples {
    rec_idx: Arc<Vec<usize>>,
    feats: Arc<Vec<Vec<f64>>>,
    labels: Vec<f64>,
}

impl TandemFilters {
    /// Run both filters on the caller's thread; returns the cleaned
    /// stream and a report.
    pub fn apply(&self, stream: &ReidStream) -> (ReidStream, FilterReport) {
        self.apply_with_threads(stream, 1)
    }

    /// Like [`Self::apply`], with the per-pair model fitting spread over
    /// `threads` scoped worker threads.  The result is identical to
    /// `apply` for every thread count (deterministic pair-order merge).
    pub fn apply_with_threads(
        &self,
        stream: &ReidStream,
        threads: usize,
    ) -> (ReidStream, FilterReport) {
        self.apply_scoped(stream, threads, None)
    }

    /// Like [`Self::apply_with_threads`], restricted to the ordered pairs
    /// within `cameras` (None = the whole fleet).  The sharded planner
    /// passes one overlap component at a time: records of other cameras
    /// are ignored and no cross-component pair is ever enumerated.
    pub fn apply_scoped(
        &self,
        stream: &ReidStream,
        threads: usize,
        cameras: Option<&[usize]>,
    ) -> (ReidStream, FilterReport) {
        let pairset = match cameras {
            None => PairSet::all(stream.n_cameras),
            Some(cams) => PairSet::among(stream.n_cameras, cams),
        };
        let mut report = FilterReport::default();

        // ---- stage 1: regression filter (per ordered camera pair) ----
        // positive pair = src record whose raw id also appears in dst
        let pair_samples = self.build_pair_samples(stream, &pairset);
        let fits = ordered_map(&pair_samples, threads, |p| ransac::fit(&p.pairs, &self.ransac));
        let mut rewrites: HashMap<usize, u32> = HashMap::new();
        let mut next_fresh = stream.max_raw_id() + 1;
        for (p, fit) in pair_samples.iter().zip(&fits) {
            let Some(fit) = fit else {
                continue;
            };
            report.pairs_fit += 1;
            for oi in fit.outlier_indices() {
                let rec = p.rec_idx[oi];
                // decouple: fresh id turns this into a negative sample
                rewrites.entry(rec).or_insert_with(|| {
                    report.fp_rewritten += 1;
                    next_fresh += 1;
                    next_fresh - 1
                });
            }
        }
        let stage1 = stream.with_rewrites(&rewrites);

        // ---- stage 2: SVM filter (per ordered camera pair) ----
        // label every src record ±1 by whether its id appears in dst;
        // negative outliers (negatives in the positive region) are FNs.
        let svm_samples = build_svm_samples(&stage1, &pairset);
        let removals = ordered_map(&svm_samples, threads, |s| self.fit_svm_pair(s));
        let mut remove: Vec<bool> = vec![false; stage1.len()];
        for pair_removals in &removals {
            for &rec in pair_removals {
                if !remove[rec] {
                    report.fn_removed += 1;
                }
                remove[rec] = true;
            }
        }
        let mut i = 0;
        let filtered = stage1.filtered(|_| {
            let k = !remove[i];
            i += 1;
            k
        });
        (filtered, report)
    }

    /// One indexed pass over the stream building every enumerated pair's
    /// positive sample set: a `(cam, frame, raw_id) → first record` map
    /// replaces the per-pair `find_id` rescans, and each record fans its
    /// matches out to the pairs it belongs to.  Per-pair vectors are
    /// filled in record order — exactly the order the per-pair rescan
    /// produced.
    fn build_pair_samples(&self, stream: &ReidStream, ps: &PairSet) -> Vec<PairSamples> {
        let interior = |b: &Rect| {
            b.left > self.edge_margin
                && b.top > self.edge_margin
                && b.right() < self.frame_w - self.edge_margin
                && b.bottom() < self.frame_h - self.edge_margin
        };
        // first record carrying (cam, frame, raw_id) — what find_id returns
        let mut first: HashMap<(usize, usize, u32), usize> = HashMap::new();
        for (i, rec) in stream.all().iter().enumerate() {
            first.entry((rec.cam, rec.frame, rec.raw_id)).or_insert(i);
        }
        let mut out: Vec<PairSamples> =
            (0..ps.len()).map(|_| PairSamples::default()).collect();
        for (i, rec) in stream.all().iter().enumerate() {
            if !interior(&rec.bbox) {
                continue;
            }
            for &dst in &ps.dsts[rec.cam] {
                let Some(&j) = first.get(&(dst, rec.frame, rec.raw_id)) else {
                    continue;
                };
                let m = &stream.all()[j];
                if !interior(&m.bbox) {
                    continue;
                }
                let p = &mut out[ps.slot_of(rec.cam, dst)];
                p.rec_idx.push(i);
                p.pairs.push((rec.bbox, m.bbox));
            }
        }
        out
    }

    /// Train one pair's SVM and return the record indices it removes
    /// (negatives the model places in the positive region).
    fn fit_svm_pair(&self, s: &SvmSamples) -> Vec<usize> {
        let n_pos = s.labels.iter().filter(|&&l| l > 0.0).count();
        if n_pos < MIN_CLASS_SAMPLES || s.labels.len() - n_pos < MIN_CLASS_SAMPLES {
            return Vec::new(); // not enough of either class to learn a region
        }
        // subsample for training if oversized (keep all positives)
        let (tx, ty) = subsample(&s.feats, &s.labels, self.svm_max_samples, self.svm.seed);
        let svm = Svm::train(tx, ty, &self.svm);
        let mut out = Vec::new();
        for (k, f) in s.feats.iter().enumerate() {
            if s.labels[k] < 0.0 && svm.decision(f) > 0.0 {
                out.push(s.rec_idx[k]);
            }
        }
        out
    }
}

/// One indexed pass building every enumerated pair's SVM sample set: each
/// record contributes one labelled sample to the pairs it is the source
/// of, with the label looked up in a presence set instead of a per-pair
/// `find_id` scan.  The per-source feature matrix and record indices are
/// built once and shared across that source's pairs.
fn build_svm_samples(stream: &ReidStream, ps: &PairSet) -> Vec<SvmSamples> {
    let n = stream.n_cameras;
    let mut present: HashSet<(usize, usize, u32)> = HashSet::new();
    for rec in stream.all() {
        present.insert((rec.cam, rec.frame, rec.raw_id));
    }
    let mut rec_idx: Vec<Vec<usize>> = vec![Vec::new(); n];
    let mut feats: Vec<Vec<Vec<f64>>> = vec![Vec::new(); n];
    let mut labels: Vec<Vec<f64>> = (0..ps.len()).map(|_| Vec::new()).collect();
    for (i, rec) in stream.all().iter().enumerate() {
        rec_idx[rec.cam].push(i);
        feats[rec.cam].push(bbox4(&rec.bbox).to_vec());
        for &dst in &ps.dsts[rec.cam] {
            let positive = present.contains(&(dst, rec.frame, rec.raw_id));
            labels[ps.slot_of(rec.cam, dst)].push(if positive { 1.0 } else { -1.0 });
        }
    }
    let rec_idx: Vec<Arc<Vec<usize>>> = rec_idx.into_iter().map(Arc::new).collect();
    let feats: Vec<Arc<Vec<Vec<f64>>>> = feats.into_iter().map(Arc::new).collect();
    let mut out = Vec::with_capacity(labels.len());
    for (k, &(src, _)) in ps.pairs.iter().enumerate() {
        out.push(SvmSamples {
            rec_idx: Arc::clone(&rec_idx[src]),
            feats: Arc::clone(&feats[src]),
            labels: std::mem::take(&mut labels[k]),
        });
    }
    out
}

/// Deterministically subsample to `max` samples, keeping **all** positives
/// (they are the scarce class, O2) up to the cap less a reserved negative
/// quota; negatives get the budget the positives leave over.  The quota
/// keeps the training set two-class even when positives alone exceed the
/// cap — a one-class SVM would put the whole plane in the positive region
/// and flag every negative as a false negative.
fn subsample(
    feats: &[Vec<f64>],
    labels: &[f64],
    max: usize,
    seed: u64,
) -> (Vec<Vec<f64>>, Vec<f64>) {
    if feats.len() <= max {
        return (feats.to_vec(), labels.to_vec());
    }
    let pos: Vec<usize> = (0..feats.len()).filter(|&i| labels[i] > 0.0).collect();
    let neg: Vec<usize> = (0..feats.len()).filter(|&i| labels[i] < 0.0).collect();
    let mut rng = Rng::new(seed).fork(feats.len() as u64);
    let neg_quota = neg.len().min(MIN_CLASS_SAMPLES);
    let mut chosen: Vec<usize> = pos.into_iter().take(max.saturating_sub(neg_quota)).collect();
    let budget_neg = max - chosen.len();
    if neg.len() <= budget_neg {
        chosen.extend(neg);
    } else {
        let picks = rng.sample_indices(neg.len(), budget_neg);
        chosen.extend(picks.into_iter().map(|i| neg[i]));
    }
    chosen.sort_unstable();
    (
        chosen.iter().map(|&i| feats[i].clone()).collect(),
        chosen.iter().map(|&i| labels[i]).collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::reid::error_model::{ErrorModelParams, RawReid};
    use crate::reid::labels;
    use crate::sim::Scenario;

    #[test]
    fn pair_set_enumerates_all_ordered_pairs_canonically() {
        for n in [2usize, 3, 5, 16] {
            let ps = PairSet::all(n);
            assert_eq!(ps.len(), n * (n - 1));
            let mut expected = 0usize;
            for src in 0..n {
                for dst in 0..n {
                    if src == dst {
                        continue;
                    }
                    // canonical enumeration order: src-major, dst-minor
                    assert_eq!(ps.slot_of(src, dst), expected, "pair ({src},{dst}) of {n}");
                    assert_eq!(ps.pairs[expected], (src, dst));
                    expected += 1;
                }
            }
        }
    }

    #[test]
    fn pair_set_among_restricts_to_the_subset() {
        let ps = PairSet::among(6, &[1, 3, 4]);
        assert_eq!(ps.len(), 6);
        assert_eq!(
            ps.pairs,
            vec![(1, 3), (1, 4), (3, 1), (3, 4), (4, 1), (4, 3)],
            "subset pairs not in src-major canonical order"
        );
        // pairs touching cameras outside the subset are not enumerated
        assert_eq!(ps.slot_of(0, 1), usize::MAX);
        assert_eq!(ps.slot_of(1, 2), usize::MAX);
        assert_eq!(ps.slot_of(5, 4), usize::MAX);
        assert!(ps.dsts[0].is_empty() && ps.dsts[2].is_empty() && ps.dsts[5].is_empty());
        assert_eq!(ps.dsts[1], vec![3, 4]);
    }

    #[test]
    fn scoped_apply_on_a_component_matches_whole_fleet_on_its_records() {
        // two disjoint "intersections" in one stream (cameras {0,1} and
        // {2,3} share no ids): filtering the whole fleet must equal
        // filtering each component scoped — the sharded planner's
        // correctness argument in miniature
        let sc = Scenario::build(&Config::test_small().scenario);
        let raw = RawReid::generate(&sc, 0..sc.n_frames(), &ErrorModelParams::default());
        // build the synthetic 2-component stream: copy cameras 0/1 as-is,
        // and duplicate them as cameras 2/3 with an id offset
        let offset = raw.max_raw_id() + 1;
        let mut records = Vec::new();
        for rec in raw.all() {
            if rec.cam > 1 {
                continue;
            }
            records.push(*rec);
            let mut moved = *rec;
            moved.cam += 2;
            moved.raw_id += offset;
            moved.true_id += offset;
            records.push(moved);
        }
        let combined = ReidStream::new(4, raw.n_frames, records);
        let filters = TandemFilters::default();
        let (whole, whole_report) = filters.apply_scoped(&combined, 2, None);
        let (a, a_report) = filters.apply_scoped(&combined, 2, Some(&[0, 1]));
        let (b, b_report) = filters.apply_scoped(&combined, 2, Some(&[2, 3]));
        assert_eq!(
            whole_report.pairs_fit,
            a_report.pairs_fit + b_report.pairs_fit,
            "cross-component pairs must never fit"
        );
        assert_eq!(whole_report.fn_removed, a_report.fn_removed + b_report.fn_removed);
        assert_eq!(whole_report.fp_rewritten, a_report.fp_rewritten + b_report.fp_rewritten);
        // the whole-fleet output restricted to a component matches the
        // scoped run's output on that component (ids may differ only on
        // FP-decoupled records, which get fresh ids from different pools)
        let keep_component = |s: &ReidStream, cams: std::ops::Range<usize>| -> Vec<(usize, usize, Rect)> {
            s.all()
                .iter()
                .filter(|r| cams.contains(&r.cam))
                .map(|r| (r.cam, r.frame, r.bbox))
                .collect()
        };
        assert_eq!(keep_component(&whole, 0..2), keep_component(&a, 0..2));
        assert_eq!(keep_component(&whole, 2..4), keep_component(&b, 2..4));
    }

    #[test]
    fn filters_improve_reid_quality() {
        let sc = Scenario::build(&Config::test_small().scenario);
        let raw = RawReid::generate(&sc, 0..sc.n_frames(), &ErrorModelParams::default());
        let before = labels::characterize_all(&raw);
        let (clean, report) = TandemFilters::default().apply(&raw);
        let after = labels::characterize_all(&clean);

        let sum_fp = |m: &Vec<Vec<labels::PairCounts>>| -> usize {
            m.iter().flat_map(|r| r.iter()).map(|c| c.fp).sum()
        };
        let sum_fn = |m: &Vec<Vec<labels::PairCounts>>| -> usize {
            m.iter().flat_map(|r| r.iter()).map(|c| c.fn_).sum()
        };
        assert!(clean.len() <= raw.len());
        // the cleaned stream must have strictly fewer false negatives
        // whenever the SVM removed anything
        if report.fn_removed > 0 {
            assert!(sum_fn(&after) < sum_fn(&before), "FN not reduced");
        }
        // FP should not grow
        assert!(sum_fp(&after) <= sum_fp(&before), "FP grew");
    }

    #[test]
    fn parallel_apply_is_byte_identical_to_sequential() {
        let sc = Scenario::build(&Config::test_small().scenario);
        let raw = RawReid::generate(&sc, 0..sc.n_frames(), &ErrorModelParams::default());
        let filters = TandemFilters::default();
        let (seq, seq_report) = filters.apply_with_threads(&raw, 1);
        for threads in [2usize, 3, 8] {
            let (par, par_report) = filters.apply_with_threads(&raw, threads);
            assert_eq!(seq_report, par_report, "report diverged at {threads} threads");
            assert_eq!(seq.len(), par.len(), "stream length diverged at {threads} threads");
            for (a, b) in seq.all().iter().zip(par.all()) {
                assert_eq!(a.cam, b.cam);
                assert_eq!(a.frame, b.frame);
                assert_eq!(a.raw_id, b.raw_id, "rewritten ids diverged at {threads} threads");
                assert_eq!(a.bbox, b.bbox);
            }
        }
    }

    #[test]
    fn clean_stream_mostly_untouched() {
        let sc = Scenario::build(&Config::test_small().scenario);
        let params = ErrorModelParams {
            p_fn: 0.0,
            p_fp: 0.0,
            p_miss_occluded: 0.0,
            ..Default::default()
        };
        let raw = RawReid::generate(&sc, 0..sc.n_frames(), &params);
        let (clean, report) = TandemFilters::default().apply(&raw);
        // harsh statistical filtering may nip records (§4.2.4: true
        // negatives that sit in the positive region — e.g. vehicles below
        // the partner camera's visibility cutoff — are legitimately
        // removed), but the bulk of a clean stream must survive
        assert!(
            clean.len() as f64 >= 0.75 * raw.len() as f64,
            "lost too much clean data: {} -> {} (report {report:?})",
            raw.len(),
            clean.len()
        );
        // the learned mapping is exact geometry here: at the operating θ
        // almost no positives should be decoupled
        assert!(
            (report.fp_rewritten as f64) < 0.05 * raw.len() as f64,
            "clean data produced too many FP rewrites: {report:?}"
        );
    }

    #[test]
    fn subsample_respects_cap_and_classes() {
        let feats: Vec<Vec<f64>> = (0..100).map(|i| vec![i as f64]).collect();
        let labels: Vec<f64> = (0..100).map(|i| if i < 20 { 1.0 } else { -1.0 }).collect();
        let (tx, ty) = subsample(&feats, &labels, 50, 1);
        assert!(tx.len() <= 50);
        assert!(ty.iter().filter(|&&l| l > 0.0).count() >= 20.min(25));
    }

    #[test]
    fn subsample_keeps_all_positives_when_they_exceed_half_the_cap() {
        // regression: `take(max / 2)` used to silently drop positives as
        // soon as they exceeded half the cap
        let feats: Vec<Vec<f64>> = (0..100).map(|i| vec![i as f64]).collect();
        let labels: Vec<f64> = (0..100).map(|i| if i < 70 { 1.0 } else { -1.0 }).collect();
        let (tx, ty) = subsample(&feats, &labels, 80, 1);
        assert_eq!(tx.len(), 80);
        assert_eq!(ty.iter().filter(|&&l| l > 0.0).count(), 70, "positives dropped");
        assert_eq!(ty.iter().filter(|&&l| l < 0.0).count(), 10);
        // positives beyond the whole cap are still capped
        let all_pos: Vec<f64> = vec![1.0; 100];
        let (tx, ty) = subsample(&feats, &all_pos, 80, 1);
        assert_eq!(tx.len(), 80);
        assert!(ty.iter().all(|&l| l > 0.0));
    }

    #[test]
    fn subsample_always_reserves_negative_slots() {
        // regression: when positives alone exceed the cap, the negative
        // quota must keep the training set two-class (a one-class SVM
        // would flag every negative as FN)
        let feats: Vec<Vec<f64>> = (0..115).map(|i| vec![i as f64]).collect();
        let labels: Vec<f64> = (0..115).map(|i| if i < 95 { 1.0 } else { -1.0 }).collect();
        let (tx, ty) = subsample(&feats, &labels, 50, 1);
        assert_eq!(tx.len(), 50);
        assert_eq!(ty.iter().filter(|&&l| l > 0.0).count(), 42);
        assert_eq!(ty.iter().filter(|&&l| l < 0.0).count(), 8);
    }
}
