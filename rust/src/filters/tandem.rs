//! The tandem filter pipeline (Fig. 4): raw ReID → regression filter
//! (false positives get fresh ids, becoming negative data) → SVM filter
//! (false negatives are removed) → highly-confident stream for the
//! association/optimization stages.

use std::collections::HashMap;

use crate::filters::features::bbox4;
use crate::filters::ransac::{self, RansacParams};
use crate::filters::svm::{Svm, SvmParams};
use crate::reid::records::ReidStream;
use crate::util::rng::Rng;

/// Tandem filter configuration.
#[derive(Debug, Clone)]
pub struct TandemFilters {
    pub ransac: RansacParams,
    pub svm: SvmParams,
    /// Cap on SVM training samples per camera pair (subsampled above).
    pub svm_max_samples: usize,
    /// Frame size, for the interior predicate below.
    pub frame_w: f64,
    pub frame_h: f64,
    /// Bboxes touching an `edge_margin` border are excluded from the
    /// regression filter: a clipped box breaks the bbox↔bbox functional
    /// relation (a vehicle halfway out of one view maps nowhere), so such
    /// pairs can neither train the mapping nor be judged by it.
    pub edge_margin: f64,
}

impl Default for TandemFilters {
    fn default() -> Self {
        TandemFilters {
            ransac: RansacParams::default(),
            svm: SvmParams::default(),
            svm_max_samples: 2200,
            frame_w: crate::sim::FRAME_W as f64,
            frame_h: crate::sim::FRAME_H as f64,
            edge_margin: 4.0,
        }
    }
}

/// What the filters did (diagnostics + Fig. 9/10 sweeps).
#[derive(Debug, Clone, Default)]
pub struct FilterReport {
    /// Camera pairs with enough positives to fit a mapping.
    pub pairs_fit: usize,
    /// Positive records decoupled by the regression filter (FP).
    pub fp_rewritten: usize,
    /// Records removed by the SVM filter (FN).
    pub fn_removed: usize,
}

impl TandemFilters {
    /// Run both filters; returns the cleaned stream and a report.
    pub fn apply(&self, stream: &ReidStream) -> (ReidStream, FilterReport) {
        let mut report = FilterReport::default();

        // ---- stage 1: regression filter (per ordered camera pair) ----
        // positive pair = src record whose raw id also appears in dst
        let mut rewrites: HashMap<usize, u32> = HashMap::new();
        let mut next_fresh = stream.max_raw_id() + 1;
        let n = stream.n_cameras;
        let interior = |b: &crate::util::geometry::Rect| {
            b.left > self.edge_margin
                && b.top > self.edge_margin
                && b.right() < self.frame_w - self.edge_margin
                && b.bottom() < self.frame_h - self.edge_margin
        };
        for src in 0..n {
            for dst in 0..n {
                if src == dst {
                    continue;
                }
                // record-index + dst bbox for every interior positive pair
                let mut rec_idx = Vec::new();
                let mut pairs = Vec::new();
                for (i, rec) in stream.all().iter().enumerate() {
                    if rec.cam != src || !interior(&rec.bbox) {
                        continue;
                    }
                    if let Some(m) = stream.find_id(dst, rec.frame, rec.raw_id) {
                        if !interior(&m.bbox) {
                            continue;
                        }
                        rec_idx.push(i);
                        pairs.push((rec.bbox, m.bbox));
                    }
                }
                let Some(fit) = ransac::fit(&pairs, &self.ransac) else {
                    continue;
                };
                report.pairs_fit += 1;
                for oi in fit.outlier_indices() {
                    let rec = rec_idx[oi];
                    // decouple: fresh id turns this into a negative sample
                    rewrites.entry(rec).or_insert_with(|| {
                        report.fp_rewritten += 1;
                        next_fresh += 1;
                        next_fresh - 1
                    });
                }
            }
        }
        let stage1 = stream.with_rewrites(&rewrites);

        // ---- stage 2: SVM filter (per ordered camera pair) ----
        // label every src record ±1 by whether its id appears in dst;
        // negative outliers (negatives in the positive region) are FNs.
        let mut remove: Vec<bool> = vec![false; stage1.len()];
        for src in 0..n {
            for dst in 0..n {
                if src == dst {
                    continue;
                }
                let mut feats: Vec<Vec<f64>> = Vec::new();
                let mut labels: Vec<f64> = Vec::new();
                let mut rec_idx: Vec<usize> = Vec::new();
                for (i, rec) in stage1.all().iter().enumerate() {
                    if rec.cam != src {
                        continue;
                    }
                    let positive = stage1.find_id(dst, rec.frame, rec.raw_id).is_some();
                    feats.push(bbox4(&rec.bbox).to_vec());
                    labels.push(if positive { 1.0 } else { -1.0 });
                    rec_idx.push(i);
                }
                let n_pos = labels.iter().filter(|&&l| l > 0.0).count();
                if n_pos < 8 || labels.len() - n_pos < 8 {
                    continue; // not enough of either class to learn a region
                }
                // subsample for training if oversized (keep all positives)
                let (tx, ty) = subsample(&feats, &labels, self.svm_max_samples, self.svm.seed);
                let svm = Svm::train(tx, ty, &self.svm);
                for (k, f) in feats.iter().enumerate() {
                    if labels[k] < 0.0 && svm.decision(f) > 0.0 {
                        if !remove[rec_idx[k]] {
                            report.fn_removed += 1;
                        }
                        remove[rec_idx[k]] = true;
                    }
                }
            }
        }
        let mut i = 0;
        let filtered = stage1.filtered(|_| {
            let k = !remove[i];
            i += 1;
            k
        });
        (filtered, report)
    }
}

/// Deterministically subsample to `max` samples, preferring to keep all
/// positives (they are the scarce class, O2).
fn subsample(
    feats: &[Vec<f64>],
    labels: &[f64],
    max: usize,
    seed: u64,
) -> (Vec<Vec<f64>>, Vec<f64>) {
    if feats.len() <= max {
        return (feats.to_vec(), labels.to_vec());
    }
    let pos: Vec<usize> = (0..feats.len()).filter(|&i| labels[i] > 0.0).collect();
    let neg: Vec<usize> = (0..feats.len()).filter(|&i| labels[i] < 0.0).collect();
    let budget_neg = max.saturating_sub(pos.len().min(max / 2));
    let mut rng = Rng::new(seed).fork(feats.len() as u64);
    let mut chosen: Vec<usize> = pos.into_iter().take(max / 2).collect();
    if neg.len() <= budget_neg {
        chosen.extend(neg);
    } else {
        let picks = rng.sample_indices(neg.len(), budget_neg);
        chosen.extend(picks.into_iter().map(|i| neg[i]));
    }
    chosen.sort_unstable();
    (
        chosen.iter().map(|&i| feats[i].clone()).collect(),
        chosen.iter().map(|&i| labels[i]).collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::reid::error_model::{ErrorModelParams, RawReid};
    use crate::reid::labels;
    use crate::sim::Scenario;

    #[test]
    fn filters_improve_reid_quality() {
        let sc = Scenario::build(&Config::test_small().scenario);
        let raw = RawReid::generate(&sc, 0..sc.n_frames(), &ErrorModelParams::default());
        let before = labels::characterize_all(&raw);
        let (clean, report) = TandemFilters::default().apply(&raw);
        let after = labels::characterize_all(&clean);

        let sum_fp = |m: &Vec<Vec<labels::PairCounts>>| -> usize {
            m.iter().flat_map(|r| r.iter()).map(|c| c.fp).sum()
        };
        let sum_fn = |m: &Vec<Vec<labels::PairCounts>>| -> usize {
            m.iter().flat_map(|r| r.iter()).map(|c| c.fn_).sum()
        };
        assert!(clean.len() <= raw.len());
        // the cleaned stream must have strictly fewer false negatives
        // whenever the SVM removed anything
        if report.fn_removed > 0 {
            assert!(sum_fn(&after) < sum_fn(&before), "FN not reduced");
        }
        // FP should not grow
        assert!(sum_fp(&after) <= sum_fp(&before), "FP grew");
    }

    #[test]
    fn clean_stream_mostly_untouched() {
        let sc = Scenario::build(&Config::test_small().scenario);
        let params = ErrorModelParams {
            p_fn: 0.0,
            p_fp: 0.0,
            p_miss_occluded: 0.0,
            ..Default::default()
        };
        let raw = RawReid::generate(&sc, 0..sc.n_frames(), &params);
        let (clean, report) = TandemFilters::default().apply(&raw);
        // harsh statistical filtering may nip records (§4.2.4: true
        // negatives that sit in the positive region — e.g. vehicles below
        // the partner camera's visibility cutoff — are legitimately
        // removed), but the bulk of a clean stream must survive
        assert!(
            clean.len() as f64 >= 0.75 * raw.len() as f64,
            "lost too much clean data: {} -> {} (report {report:?})",
            raw.len(),
            clean.len()
        );
        // the learned mapping is exact geometry here: at the operating θ
        // almost no positives should be decoupled
        assert!(
            (report.fp_rewritten as f64) < 0.05 * raw.len() as f64,
            "clean data produced too many FP rewrites: {report:?}"
        );
    }

    #[test]
    fn subsample_respects_cap_and_classes() {
        let feats: Vec<Vec<f64>> = (0..100).map(|i| vec![i as f64]).collect();
        let labels: Vec<f64> = (0..100).map(|i| if i < 20 { 1.0 } else { -1.0 }).collect();
        let (tx, ty) = subsample(&feats, &labels, 50, 1);
        assert!(tx.len() <= 50);
        assert!(ty.iter().filter(|&&l| l > 0.0).count() >= 20.min(25));
    }
}
