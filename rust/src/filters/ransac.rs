//! RANSAC regression filter (§4.2.2): learns the cross-camera bbox mapping
//! between a pair of cameras from *positive* ReID pairs and flags outliers
//! as false positives.
//!
//! Mirrors sklearn's `RANSACRegressor`: random minimal samples, a
//! least-squares model on degree-2 polynomial features (one output per
//! bbox coordinate), inlier threshold `θ · MAD(targets)` (the sklearn
//! default residual threshold scaled by the paper's sweep parameter θ,
//! Fig. 10), final refit on the best consensus set.

use crate::filters::features::{poly2, residual_l1, target4, POLY2_DIM};
use crate::util::geometry::Rect;
use crate::util::matrix::{lstsq, Mat};
use crate::util::rng::Rng;
use crate::util::stats;

/// RANSAC hyperparameters.
#[derive(Debug, Clone)]
pub struct RansacParams {
    /// Residual threshold multiplier θ.  The paper sweeps θ and settles on
    /// 0.01 *for the AI-City geometry*; the operating point is
    /// data-dependent.  Our default (0.2) is this repo's Fig.-10 sweep
    /// winner for the simulated rig — the quadratic model's Taylor error
    /// across a 62° FoV is larger relative to MAD than theirs.
    pub theta: f64,
    /// Number of random hypotheses.
    pub iters: usize,
    /// Minimal sample size per hypothesis (≥ feature dimension).
    pub min_samples: usize,
    pub seed: u64,
}

impl Default for RansacParams {
    fn default() -> Self {
        RansacParams { theta: 0.5, iters: 64, min_samples: POLY2_DIM + 5, seed: 0xA45C }
    }
}

/// A fitted mapping: 4 linear models over poly2 features.
#[derive(Debug, Clone)]
pub struct RansacModel {
    /// `weights[out][feat]` — one row per output coordinate.
    weights: Vec<Vec<f64>>,
}

impl RansacModel {
    /// Predict the destination bbox target vector for a source bbox.
    pub fn predict(&self, src: &Rect) -> Vec<f64> {
        let f = poly2(src);
        self.weights
            .iter()
            .map(|w| w.iter().zip(&f).map(|(a, b)| a * b).sum())
            .collect()
    }
}

/// Result of a RANSAC fit over positive pairs.
#[derive(Debug, Clone)]
pub struct RansacFit {
    pub model: RansacModel,
    /// Inlier flag per input pair.
    pub inliers: Vec<bool>,
    /// The residual threshold actually used (θ·MAD).
    pub threshold: f64,
}

impl RansacFit {
    pub fn outlier_indices(&self) -> Vec<usize> {
        self.inliers
            .iter()
            .enumerate()
            .filter(|(_, &inl)| !inl)
            .map(|(i, _)| i)
            .collect()
    }
}

fn fit_lstsq(pairs: &[(Rect, Rect)], idx: &[usize]) -> Option<RansacModel> {
    let a = Mat::from_rows(&idx.iter().map(|&i| poly2(&pairs[i].0)).collect::<Vec<_>>());
    let mut weights = Vec::with_capacity(4);
    for out in 0..4 {
        let b: Vec<f64> = idx.iter().map(|&i| target4(&pairs[i].1)[out]).collect();
        weights.push(lstsq(&a, &b, 1e-8)?);
    }
    Some(RansacModel { weights })
}

fn residuals(model: &RansacModel, pairs: &[(Rect, Rect)]) -> Vec<f64> {
    pairs
        .iter()
        .map(|(s, d)| residual_l1(&model.predict(s), &target4(d)))
        .collect()
}

/// Threshold per sklearn's default: MAD of the target values, scaled by θ.
/// (Computed across all 4 output coordinates jointly.)
fn mad_threshold(pairs: &[(Rect, Rect)], theta: f64) -> f64 {
    let targets: Vec<f64> = pairs.iter().flat_map(|(_, d)| target4(d)).collect();
    let mad = stats::mad(&targets).max(1e-6);
    // residuals are L1 over 4 coordinates -> scale the per-coordinate MAD
    theta * mad * 4.0
}

/// Fit RANSAC over positive pairs `(src bbox, dst bbox)`.
///
/// Returns `None` when there are too few pairs to even form a hypothesis —
/// callers then skip the pair of cameras (no mapping can be learned, so
/// nothing is filtered, matching the conservative behaviour the paper
/// needs: never invent outliers from thin data).
pub fn fit(pairs: &[(Rect, Rect)], params: &RansacParams) -> Option<RansacFit> {
    if pairs.len() < params.min_samples {
        return None;
    }
    let threshold = mad_threshold(pairs, params.theta);
    let mut rng = Rng::new(params.seed).fork(pairs.len() as u64);
    let mut best: Option<(usize, RansacModel)> = None;
    for _ in 0..params.iters {
        let sample = rng.sample_indices(pairs.len(), params.min_samples);
        let Some(model) = fit_lstsq(pairs, &sample) else {
            continue;
        };
        let res = residuals(&model, pairs);
        let n_inliers = res.iter().filter(|&&r| r <= threshold).count();
        if best.as_ref().map_or(true, |(n, _)| n_inliers > *n) {
            best = Some((n_inliers, model));
        }
    }
    let (_, model) = best?;
    // refit on the consensus set
    let res = residuals(&model, pairs);
    let inlier_idx: Vec<usize> = (0..pairs.len()).filter(|&i| res[i] <= threshold).collect();
    let final_model = if inlier_idx.len() >= params.min_samples {
        fit_lstsq(pairs, &inlier_idx).unwrap_or(model)
    } else {
        model
    };
    let res = residuals(&final_model, pairs);
    let inliers: Vec<bool> = res.iter().map(|&r| r <= threshold).collect();
    Some(RansacFit { model: final_model, inliers, threshold })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A smooth synthetic cross-camera mapping (affine + mild curvature).
    fn true_map(src: &Rect) -> Rect {
        Rect::new(
            0.8 * src.left + 0.1 * src.top + 12.0 + 0.0006 * src.left * src.left,
            0.9 * src.top - 0.05 * src.left + 8.0,
            0.85 * src.width + 1.0,
            0.9 * src.height + 0.5,
        )
    }

    fn make_pairs(n: usize, seed: u64) -> Vec<(Rect, Rect)> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| {
                let s = Rect::new(
                    rng.range(0.0, 280.0),
                    rng.range(0.0, 160.0),
                    rng.range(15.0, 60.0),
                    rng.range(10.0, 40.0),
                );
                (s, true_map(&s))
            })
            .collect()
    }

    #[test]
    fn clean_data_all_inliers() {
        let pairs = make_pairs(80, 1);
        let fit = fit(&pairs, &RansacParams { theta: 0.05, ..Default::default() }).unwrap();
        assert!(fit.inliers.iter().all(|&i| i), "clean data produced outliers");
    }

    #[test]
    fn detects_planted_outliers() {
        let mut pairs = make_pairs(100, 2);
        // plant 10 geometry-violating associations (wrong matches)
        let mut rng = Rng::new(99);
        let planted: Vec<usize> = (0..10).map(|i| i * 9).collect();
        for &i in &planted {
            pairs[i].1 = Rect::new(
                rng.range(0.0, 300.0),
                rng.range(0.0, 180.0),
                rng.range(15.0, 60.0),
                rng.range(10.0, 40.0),
            );
        }
        let fit = fit(&pairs, &RansacParams { theta: 0.05, ..Default::default() }).unwrap();
        let outliers = fit.outlier_indices();
        // all planted pairs flagged, few false alarms
        for &i in &planted {
            assert!(outliers.contains(&i), "planted outlier {i} missed");
        }
        assert!(outliers.len() <= planted.len() + 4, "too many false alarms: {outliers:?}");
    }

    #[test]
    fn too_few_pairs_returns_none() {
        let pairs = make_pairs(5, 3);
        assert!(fit(&pairs, &RansacParams::default()).is_none());
    }

    #[test]
    fn tighter_theta_flags_more() {
        let mut pairs = make_pairs(120, 4);
        // mild noise on destinations
        let mut rng = Rng::new(7);
        for p in pairs.iter_mut() {
            p.1.left += rng.normal(0.0, 1.5);
            p.1.top += rng.normal(0.0, 1.5);
        }
        let loose = fit(&pairs, &RansacParams { theta: 1.0, ..Default::default() })
            .unwrap()
            .outlier_indices()
            .len();
        let tight = fit(&pairs, &RansacParams { theta: 0.01, ..Default::default() })
            .unwrap()
            .outlier_indices()
            .len();
        assert!(tight >= loose, "tight {tight} < loose {loose}");
    }

    #[test]
    fn deterministic() {
        let pairs = make_pairs(60, 5);
        let p = RansacParams::default();
        let a = fit(&pairs, &p).unwrap();
        let b = fit(&pairs, &p).unwrap();
        assert_eq!(a.inliers, b.inliers);
    }
}
