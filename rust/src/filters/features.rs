//! Bbox feature maps shared by the two filters: the paper feeds
//! `<left, top, width, height>` 4-vectors, with "higher order features ...
//! to make the filter fit ReID results better" (§4.2.2).

use crate::util::geometry::Rect;

/// Full degree-2 polynomial feature map of a bbox (15 features): constant,
/// the 4 coordinates, and all 10 pairwise products.  The cross-camera bbox
/// mapping is projective (a homography of the ground plane); a full
/// quadratic is its 2nd-order Taylor expansion and fits it to a few pixels
/// across the view.  Coordinates are pre-scaled to O(1) so the normal
/// equations stay well-conditioned.
pub fn poly2(b: &Rect) -> Vec<f64> {
    let s = 0.01; // pixels -> O(1)
    let v = [b.left * s, b.top * s, b.width * s, b.height * s];
    let mut f = Vec::with_capacity(POLY2_DIM);
    f.push(1.0);
    f.extend_from_slice(&v);
    for i in 0..4 {
        for j in i..4 {
            f.push(v[i] * v[j]);
        }
    }
    f
}

/// Number of features produced by [`poly2`].
pub const POLY2_DIM: usize = 15;

/// Plain scaled 4-vector `[l, t, w, h]` (the SVM's input space).
pub fn bbox4(b: &Rect) -> [f64; 4] {
    let s = 0.01;
    [b.left * s, b.top * s, b.width * s, b.height * s]
}

/// Target 4-vector for regression (same scaling as the inputs).
pub fn target4(b: &Rect) -> [f64; 4] {
    bbox4(b)
}

/// L1 residual between a predicted and an actual target vector.
pub fn residual_l1(pred: &[f64], actual: &[f64; 4]) -> f64 {
    pred.iter().zip(actual.iter()).map(|(p, a)| (p - a).abs()).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poly2_shape_and_content() {
        let b = Rect::new(100.0, 50.0, 30.0, 20.0);
        let f = poly2(&b);
        assert_eq!(f.len(), POLY2_DIM);
        assert_eq!(f[0], 1.0);
        assert!((f[1] - 1.0).abs() < 1e-12); // 100 * 0.01
        assert!((f[5] - 1.0).abs() < 1e-12); // l²
        assert!((f[6] - 0.5).abs() < 1e-12); // l·t
        assert!((f[14] - 0.04).abs() < 1e-12); // h²
    }

    #[test]
    fn residual_zero_for_exact() {
        let b = Rect::new(10.0, 20.0, 30.0, 40.0);
        let t = target4(&b);
        assert_eq!(residual_l1(&t.to_vec(), &t), 0.0);
    }
}
