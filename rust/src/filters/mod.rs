//! Statistical ReID filters (§4.2) — the paper's answer to error-prone
//! ReID: a tandem of a RANSAC **regression filter** (removes false
//! positives by learning the physical cross-camera bbox mapping, O1) and an
//! RBF-**SVM filter** (removes false negatives by classifying the
//! positive/negative regions of each camera pair in bbox feature space).
//!
//! Both are reimplementations of the sklearn modules the paper uses
//! (RANSACRegressor with polynomial features; SVC with RBF kernel trained
//! by SMO) — see DESIGN.md §3.

pub mod features;
pub mod ransac;
pub mod svm;
pub mod tandem;

pub use ransac::{RansacFit, RansacParams};
pub use svm::{Svm, SvmParams};
pub use tandem::{FilterReport, TandemFilters};
