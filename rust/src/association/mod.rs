//! Cross-camera region association (§3.2): frame tiling, appearance
//! regions, and the lookup table (Table 1) that feeds the RoI optimizer.

pub mod table;
pub mod tiles;

pub use table::{AssociationTable, Constraint};
pub use tiles::{GlobalTile, Tiling};
