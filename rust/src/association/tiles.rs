//! Frame tiling (§3.1): tiles `G_{i,j}` are the smallest spatial unit of a
//! RoI mask.  Global tile ids flatten (camera, tile) so the optimizer works
//! over one index space.

use crate::util::geometry::{IRect, Rect};

/// A tile identified globally across all cameras.
pub type GlobalTile = u32;

/// Tiling geometry for a fleet of cameras.  `frame_w × frame_h` is the
/// fleet *envelope*: homogeneous fleets use it directly, heterogeneous
/// fleets ([`Tiling::heterogeneous`]) index every camera on the envelope
/// grid (so global tile ids stay a flat `cam × per_camera` space) while
/// [`Tiling::appearance_region`] clamps each camera to its own active
/// frame — tiles outside a camera's frame can never enter a region, so
/// the optimizer never assigns them.
#[derive(Debug, Clone)]
pub struct Tiling {
    pub n_cameras: usize,
    pub frame_w: u32,
    pub frame_h: u32,
    pub tile_px: u32,
    pub tiles_x: u32,
    pub tiles_y: u32,
    /// Per-camera active frame sizes for heterogeneous fleets (`None` =
    /// every camera fills the envelope).
    pub cam_dims: Option<Vec<(u32, u32)>>,
}

impl Tiling {
    pub fn new(n_cameras: usize, frame_w: u32, frame_h: u32, tile_px: u32) -> Tiling {
        assert!(frame_w % tile_px == 0 && frame_h % tile_px == 0,
                "frame {frame_w}x{frame_h} not a multiple of tile {tile_px}");
        Tiling {
            n_cameras,
            frame_w,
            frame_h,
            tile_px,
            tiles_x: frame_w / tile_px,
            tiles_y: frame_h / tile_px,
            cam_dims: None,
        }
    }

    /// Tiling for a mixed-resolution fleet: the envelope is the maximum
    /// width/height over `dims`, and each camera's appearance regions
    /// are clamped to its own `(w, h)`.  Every dimension must divide
    /// into `tile_px` tiles exactly, like [`Tiling::new`].
    pub fn heterogeneous(dims: &[(u32, u32)], tile_px: u32) -> Tiling {
        assert!(!dims.is_empty(), "a fleet needs at least one camera");
        for &(w, h) in dims {
            assert!(w % tile_px == 0 && h % tile_px == 0,
                    "camera frame {w}x{h} not a multiple of tile {tile_px}");
        }
        let frame_w = dims.iter().map(|&(w, _)| w).max().unwrap();
        let frame_h = dims.iter().map(|&(_, h)| h).max().unwrap();
        let mut t = Tiling::new(dims.len(), frame_w, frame_h, tile_px);
        if dims.iter().any(|&d| d != (frame_w, frame_h)) {
            t.cam_dims = Some(dims.to_vec());
        }
        t
    }

    /// One camera's active frame size (the envelope unless the fleet is
    /// heterogeneous).
    pub fn cam_frame(&self, cam: usize) -> (u32, u32) {
        match &self.cam_dims {
            Some(dims) => dims[cam],
            None => (self.frame_w, self.frame_h),
        }
    }

    /// Tiles per camera.
    pub fn per_camera(&self) -> u32 {
        self.tiles_x * self.tiles_y
    }

    /// Total global tiles.
    pub fn total(&self) -> u32 {
        self.per_camera() * self.n_cameras as u32
    }

    /// Global id of tile (tx, ty) in `cam`.
    pub fn tile_id(&self, cam: usize, tx: u32, ty: u32) -> GlobalTile {
        debug_assert!(tx < self.tiles_x && ty < self.tiles_y);
        cam as u32 * self.per_camera() + ty * self.tiles_x + tx
    }

    /// Inverse of [`Self::tile_id`]: (cam, tx, ty).
    pub fn tile_pos(&self, id: GlobalTile) -> (usize, u32, u32) {
        let cam = id / self.per_camera();
        let rem = id % self.per_camera();
        (cam as usize, rem % self.tiles_x, rem / self.tiles_x)
    }

    /// Camera owning a global tile.
    pub fn camera_of(&self, id: GlobalTile) -> usize {
        (id / self.per_camera()) as usize
    }

    /// Pixel rectangle of a tile.
    pub fn tile_rect(&self, id: GlobalTile) -> IRect {
        let (_, tx, ty) = self.tile_pos(id);
        IRect::new(tx * self.tile_px, ty * self.tile_px, self.tile_px, self.tile_px)
    }

    /// Appearance region (§3.2): the least set of tiles covering a bbox.
    /// Returns a sorted list of global tile ids; empty if the bbox is
    /// empty or lies entirely outside the frame.
    pub fn appearance_region(&self, cam: usize, bbox: &Rect) -> Vec<GlobalTile> {
        if bbox.is_empty() {
            return Vec::new();
        }
        // the camera's own active frame, not the fleet envelope: a
        // heterogeneous fleet's smaller camera must never claim tiles
        // past its right/bottom edge
        let (cam_w, cam_h) = self.cam_frame(cam);
        // A bbox entirely outside the frame covers no tile.  Without this
        // check the clamps below cross (tx0 > tx1 / ty0 > ty1), the extent
        // arithmetic underflows u32, and a bbox fully left/above the frame
        // would alias onto tile column/row 0.
        if bbox.right() <= 0.0
            || bbox.bottom() <= 0.0
            || bbox.left >= cam_w as f64
            || bbox.top >= cam_h as f64
        {
            return Vec::new();
        }
        let t = self.tile_px as f64;
        let max_tx = cam_w / self.tile_px - 1;
        let max_ty = cam_h / self.tile_px - 1;
        let tx0 = ((bbox.left / t).floor().max(0.0) as u32).min(max_tx);
        let ty0 = ((bbox.top / t).floor().max(0.0) as u32).min(max_ty);
        let tx1 = (((bbox.right() - 1e-9) / t).floor().max(0.0) as u32).min(max_tx);
        let ty1 = (((bbox.bottom() - 1e-9) / t).floor().max(0.0) as u32).min(max_ty);
        // a box thinner than the boundary epsilon can still cross clamps
        if tx1 < tx0 || ty1 < ty0 {
            return Vec::new();
        }
        let mut out = Vec::with_capacity(((tx1 - tx0 + 1) * (ty1 - ty0 + 1)) as usize);
        for ty in ty0..=ty1 {
            for tx in tx0..=tx1 {
                out.push(self.tile_id(cam, tx, ty));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiling() -> Tiling {
        Tiling::new(5, 320, 192, 16)
    }

    #[test]
    fn geometry() {
        let t = tiling();
        assert_eq!(t.tiles_x, 20);
        assert_eq!(t.tiles_y, 12);
        assert_eq!(t.per_camera(), 240);
        assert_eq!(t.total(), 1200);
    }

    #[test]
    fn id_roundtrip() {
        let t = tiling();
        for cam in 0..5 {
            for ty in [0u32, 5, 11] {
                for tx in [0u32, 7, 19] {
                    let id = t.tile_id(cam, tx, ty);
                    assert_eq!(t.tile_pos(id), (cam, tx, ty));
                    assert_eq!(t.camera_of(id), cam);
                }
            }
        }
    }

    #[test]
    fn tile_rect_pixels() {
        let t = tiling();
        let id = t.tile_id(1, 3, 2);
        let r = t.tile_rect(id);
        assert_eq!((r.x, r.y, r.w, r.h), (48, 32, 16, 16));
    }

    #[test]
    fn appearance_region_covers_bbox() {
        let t = tiling();
        // bbox spanning tiles (1..=3, 0..=1)
        let r = Rect::new(20.0, 5.0, 40.0, 20.0);
        let region = t.appearance_region(0, &r);
        assert_eq!(region.len(), 6);
        assert!(region.contains(&t.tile_id(0, 1, 0)));
        assert!(region.contains(&t.tile_id(0, 3, 1)));
    }

    #[test]
    fn appearance_region_exact_tile() {
        let t = tiling();
        // exactly one tile
        let r = Rect::new(16.0, 16.0, 16.0, 16.0);
        let region = t.appearance_region(2, &r);
        assert_eq!(region, vec![t.tile_id(2, 1, 1)]);
    }

    #[test]
    fn appearance_region_clamps_to_frame() {
        let t = tiling();
        let r = Rect::new(310.0, 180.0, 50.0, 50.0);
        let region = t.appearance_region(0, &r);
        assert_eq!(region, vec![t.tile_id(0, 19, 11)]);
        assert!(t.appearance_region(0, &Rect::new(5.0, 5.0, 0.0, 0.0)).is_empty());
    }

    #[test]
    fn appearance_region_of_off_frame_bboxes_is_empty() {
        let t = tiling();
        // entirely past the right/bottom edge: clamping used to cross the
        // tile extents and underflow `tx1 - tx0 + 1`
        assert!(t.appearance_region(0, &Rect::new(330.0, 10.0, 40.0, 40.0)).is_empty());
        assert!(t.appearance_region(0, &Rect::new(10.0, 200.0, 40.0, 40.0)).is_empty());
        assert!(t.appearance_region(0, &Rect::new(400.0, 300.0, 5.0, 5.0)).is_empty());
        // entirely left/above: the negative-to-u32 cast used to alias
        // these onto tile column/row 0
        assert!(t.appearance_region(0, &Rect::new(-50.0, 20.0, 30.0, 30.0)).is_empty());
        assert!(t.appearance_region(0, &Rect::new(20.0, -80.0, 30.0, 30.0)).is_empty());
        assert!(t.appearance_region(0, &Rect::new(-90.0, -90.0, 30.0, 30.0)).is_empty());
        // degenerate: thinner than the boundary epsilon, sitting exactly on
        // a tile edge (tx1 < tx0 after the epsilon shave)
        assert!(t.appearance_region(0, &Rect::new(32.0, 32.0, 1e-12, 1e-12)).is_empty());
    }

    #[test]
    fn heterogeneous_fleet_clamps_regions_per_camera() {
        // cam 0: the 320x192 envelope; cam 1: a quarter-size 160x96 feed
        let t = Tiling::heterogeneous(&[(320, 192), (160, 96)], 16);
        assert_eq!((t.frame_w, t.frame_h), (320, 192));
        assert_eq!(t.cam_frame(0), (320, 192));
        assert_eq!(t.cam_frame(1), (160, 96));
        // same global id space as the homogeneous layout
        assert_eq!(t.per_camera(), 240);
        // a bbox valid in the envelope but outside cam 1's active frame
        let r = Rect::new(200.0, 100.0, 40.0, 40.0);
        assert!(!t.appearance_region(0, &r).is_empty());
        assert!(t.appearance_region(1, &r).is_empty());
        // a bbox crossing cam 1's edge clamps to its last tile, never
        // the envelope's
        for &id in &t.appearance_region(1, &Rect::new(150.0, 80.0, 40.0, 40.0)) {
            let (cam, tx, ty) = t.tile_pos(id);
            assert_eq!(cam, 1);
            assert!(tx < 160 / 16 && ty < 96 / 16, "tile ({tx},{ty}) outside cam 1's frame");
        }
        // a uniform dims list degrades to the homogeneous layout
        assert!(Tiling::heterogeneous(&[(320, 192), (320, 192)], 16).cam_dims.is_none());
    }

    #[test]
    fn appearance_region_never_underflows_fuzz() {
        // fuzz-style sweep over random (mostly off-frame) bboxes: every
        // call must return without panicking, tiles must be in range, and
        // emptiness must match frame intersection
        let t = tiling();
        let mut rng = crate::util::rng::Rng::new(0xF0F0);
        for _ in 0..2000 {
            let r = Rect::new(
                rng.range(-400.0, 400.0),
                rng.range(-400.0, 400.0),
                rng.range(0.0, 120.0),
                rng.range(0.0, 120.0),
            );
            let region = t.appearance_region(1, &r);
            for &id in &region {
                assert!(id < t.total(), "tile id {id} out of range for {r:?}");
                assert_eq!(t.camera_of(id), 1);
            }
            let overlap = r.clip_to_frame(t.frame_w as f64, t.frame_h as f64);
            if overlap.is_empty() {
                assert!(region.is_empty(), "off-frame {r:?} produced tiles {region:?}");
            } else if overlap.area() > 1e-6 {
                assert!(!region.is_empty(), "in-frame {r:?} produced no tiles");
            }
        }
    }
}
