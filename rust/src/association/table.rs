//! The region-association lookup table (§3.2, Table 1): for every
//! (object, timestamp) occurrence, the collection of its appearance
//! regions across cameras — the constraints of the RoI optimization.
//!
//! Identical constraints repeat heavily over a profile window (the same
//! physical spot produces the same region sets), so constraints are
//! deduplicated with multiplicities; the optimizer only sees unique ones.

use std::collections::HashMap;

use crate::association::tiles::{GlobalTile, Tiling};
use crate::reid::records::ReidStream;

/// One optimization constraint: the appearance regions `R^k_{t_m}` of one
/// object occurrence; at least one region must be fully inside the mask.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct Constraint {
    /// Each region is a sorted list of global tiles.
    pub regions: Vec<Vec<GlobalTile>>,
}

impl Constraint {
    fn canonical(mut regions: Vec<Vec<GlobalTile>>) -> Constraint {
        for r in regions.iter_mut() {
            r.sort_unstable();
            r.dedup();
        }
        regions.sort();
        regions.dedup();
        Constraint { regions }
    }
}

/// The deduplicated association table.
#[derive(Debug, Clone)]
pub struct AssociationTable {
    pub tiling: Tiling,
    pub constraints: Vec<Constraint>,
    /// Occurrence count of each unique constraint.
    pub multiplicity: Vec<usize>,
    /// Total raw (object, timestamp) occurrences before dedup.
    pub total_occurrences: usize,
}

impl AssociationTable {
    /// Build from a (filtered) ReID stream: occurrences are grouped by
    /// `(frame, raw_id)`; each camera where the id appears contributes one
    /// appearance region.
    pub fn build(stream: &ReidStream, tiling: &Tiling) -> AssociationTable {
        Self::build_par(stream, tiling, 1)
    }

    /// [`AssociationTable::build`] with the per-frame grouping fanned out
    /// over up to `threads` scoped workers
    /// ([`crate::util::parallel::ordered_map`]), one contiguous frame
    /// chunk each.
    ///
    /// Byte-identical to the sequential build at every thread count:
    /// frames are independent (grouping never crosses a frame), the
    /// partial dedup maps merge by *adding* multiplicities (addition is
    /// associative and commutative over the chunk partition), and the
    /// final order comes from one total sort on `regions` — a
    /// [`Constraint`]'s only field, so the sort key is unique and the
    /// order cannot depend on which chunk saw a constraint first.
    pub fn build_par(stream: &ReidStream, tiling: &Tiling, threads: usize) -> AssociationTable {
        let threads = threads.clamp(1, stream.n_frames.max(1));
        let chunk = stream.n_frames.div_ceil(threads.max(1)).max(1);
        let starts: Vec<usize> = (0..stream.n_frames).step_by(chunk).collect();
        let partials = crate::util::parallel::ordered_map(&starts, threads, |&start| {
            collect_frames(stream, tiling, start..(start + chunk).min(stream.n_frames))
        });
        let mut unique: HashMap<Constraint, usize> = HashMap::new();
        let mut total = 0usize;
        for (map, sub_total) in partials {
            total += sub_total;
            for (c, m) in map {
                *unique.entry(c).or_insert(0) += m;
            }
        }
        let mut constraints = Vec::with_capacity(unique.len());
        let mut multiplicity = Vec::with_capacity(unique.len());
        let mut entries: Vec<(Constraint, usize)> = unique.into_iter().collect();
        // deterministic order
        entries.sort_by(|a, b| a.0.regions.cmp(&b.0.regions));
        for (c, m) in entries {
            constraints.push(c);
            multiplicity.push(m);
        }
        AssociationTable {
            tiling: tiling.clone(),
            constraints,
            multiplicity,
            total_occurrences: total,
        }
    }

    pub fn n_constraints(&self) -> usize {
        self.constraints.len()
    }

    /// All distinct tiles referenced by any region.
    pub fn candidate_tiles(&self) -> Vec<GlobalTile> {
        let mut tiles: Vec<GlobalTile> = self
            .constraints
            .iter()
            .flat_map(|c| c.regions.iter().flatten().copied())
            .collect();
        tiles.sort_unstable();
        tiles.dedup();
        tiles
    }
}

/// Dedup one frame range of the stream into (constraint → multiplicity)
/// plus its raw occurrence count — one worker's share of
/// [`AssociationTable::build_par`].
fn collect_frames(
    stream: &ReidStream,
    tiling: &Tiling,
    frames: std::ops::Range<usize>,
) -> (HashMap<Constraint, usize>, usize) {
    let mut unique: HashMap<Constraint, usize> = HashMap::new();
    let mut total = 0usize;
    for frame in frames {
        // group this frame's records by raw id
        let mut groups: HashMap<u32, Vec<Vec<GlobalTile>>> = HashMap::new();
        for cam in 0..stream.n_cameras {
            for rec in stream.at(cam, frame) {
                let region = tiling.appearance_region(cam, &rec.bbox);
                if !region.is_empty() {
                    groups.entry(rec.raw_id).or_default().push(region);
                }
            }
        }
        for (_, regions) in groups {
            total += 1;
            let c = Constraint::canonical(regions);
            *unique.entry(c).or_insert(0) += 1;
        }
    }
    (unique, total)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::reid::records::RawDetection;
    use crate::util::geometry::Rect;

    fn tiling() -> Tiling {
        Tiling::new(2, 320, 192, 16)
    }

    fn det(cam: usize, frame: usize, raw_id: u32, x: f64, y: f64) -> RawDetection {
        RawDetection { cam, frame, bbox: Rect::new(x, y, 16.0, 16.0), raw_id, true_id: raw_id }
    }

    #[test]
    fn single_camera_occurrence_single_region() {
        let s = ReidStream::new(2, 1, vec![det(0, 0, 1, 16.0, 16.0)]);
        let t = AssociationTable::build(&s, &tiling());
        assert_eq!(t.n_constraints(), 1);
        assert_eq!(t.constraints[0].regions.len(), 1);
        assert_eq!(t.total_occurrences, 1);
    }

    #[test]
    fn cross_camera_appearance_merges_into_one_constraint() {
        // same raw id in both cameras at the same frame -> one constraint
        // with two alternative regions (the paper's R^1_{t1} example)
        let s = ReidStream::new(2, 1, vec![det(0, 0, 7, 0.0, 0.0), det(1, 0, 7, 160.0, 96.0)]);
        let t = AssociationTable::build(&s, &tiling());
        assert_eq!(t.n_constraints(), 1);
        assert_eq!(t.constraints[0].regions.len(), 2);
    }

    #[test]
    fn repeats_deduplicate_with_multiplicity() {
        let recs: Vec<RawDetection> =
            (0..10).map(|f| det(0, f, 1, 32.0, 32.0)).collect();
        let s = ReidStream::new(2, 10, recs);
        let t = AssociationTable::build(&s, &tiling());
        assert_eq!(t.n_constraints(), 1);
        assert_eq!(t.multiplicity[0], 10);
        assert_eq!(t.total_occurrences, 10);
    }

    #[test]
    fn different_ids_stay_separate() {
        let s = ReidStream::new(2, 1, vec![det(0, 0, 1, 0.0, 0.0), det(0, 0, 2, 160.0, 96.0)]);
        let t = AssociationTable::build(&s, &tiling());
        assert_eq!(t.n_constraints(), 2);
        assert_eq!(t.candidate_tiles().len(), 2);
    }

    #[test]
    fn build_is_deterministic() {
        let recs = vec![
            det(0, 0, 1, 0.0, 0.0),
            det(1, 0, 1, 50.0, 50.0),
            det(0, 1, 2, 100.0, 100.0),
        ];
        let s = ReidStream::new(2, 2, recs);
        let a = AssociationTable::build(&s, &tiling());
        let b = AssociationTable::build(&s, &tiling());
        assert_eq!(a.constraints, b.constraints);
        assert_eq!(a.multiplicity, b.multiplicity);
    }

    #[test]
    fn parallel_build_is_byte_identical() {
        // constraints repeating across chunk boundaries force the
        // multiplicity merge; distinct ones exercise the total sort
        let mut recs = Vec::new();
        for f in 0..23 {
            recs.push(det(0, f, 1, 32.0, 32.0));
            recs.push(det(1, f, 1, 64.0, 64.0));
            recs.push(det(0, f, 2, (f % 5) as f64 * 48.0, 16.0));
        }
        let s = ReidStream::new(2, 23, recs);
        let seq = AssociationTable::build(&s, &tiling());
        for threads in [2, 3, 7, 32] {
            let par = AssociationTable::build_par(&s, &tiling(), threads);
            assert_eq!(seq.constraints, par.constraints, "threads={threads}");
            assert_eq!(seq.multiplicity, par.multiplicity, "threads={threads}");
            assert_eq!(seq.total_occurrences, par.total_occurrences);
        }
    }
}
