//! Per-camera RoI masks: the optimizer's global tile set split by camera,
//! with conversions to pixel rectangles (codec cropping) and to detector
//! block ids (RoI inference).

use std::collections::HashSet;

use crate::association::tiles::{GlobalTile, Tiling};
use crate::util::geometry::IRect;

/// RoI masks for the whole fleet.
#[derive(Debug, Clone)]
pub struct RoiMasks {
    pub tiling: Tiling,
    /// `tiles[cam]` = set of (tx, ty) in that camera's grid.
    pub tiles: Vec<HashSet<(u32, u32)>>,
}

impl RoiMasks {
    /// Split a global solution into per-camera masks.
    pub fn from_solution(tiling: &Tiling, solution: &HashSet<GlobalTile>) -> RoiMasks {
        let mut tiles = vec![HashSet::new(); tiling.n_cameras];
        // lint: order-insensitive — set-to-set split
        for &t in solution {
            let (cam, tx, ty) = tiling.tile_pos(t);
            tiles[cam].insert((tx, ty));
        }
        RoiMasks { tiling: tiling.clone(), tiles }
    }

    /// A full-frame mask (the Baseline methods).
    pub fn full(tiling: &Tiling) -> RoiMasks {
        let mut tiles = vec![HashSet::new(); tiling.n_cameras];
        // lint: order-insensitive — `tiles` is the per-camera Vec of masks
        for mask in tiles.iter_mut() {
            for ty in 0..tiling.tiles_y {
                for tx in 0..tiling.tiles_x {
                    mask.insert((tx, ty));
                }
            }
        }
        RoiMasks { tiling: tiling.clone(), tiles }
    }

    /// Number of mask tiles in one camera.
    pub fn camera_size(&self, cam: usize) -> usize {
        self.tiles[cam].len()
    }

    /// |M| — total tiles across cameras (the optimization objective).
    pub fn total_size(&self) -> usize {
        self.tiles.iter().map(|t| t.len()).sum() // lint: order-insensitive — commutative sum
    }

    /// Fraction of a camera's frame covered by its mask.
    pub fn coverage(&self, cam: usize) -> f64 {
        self.tiles[cam].len() as f64 / self.tiling.per_camera() as f64
    }

    /// Is a pixel inside the camera's mask?
    pub fn contains_pixel(&self, cam: usize, x: u32, y: u32) -> bool {
        let t = self.tiling.tile_px;
        self.tiles[cam].contains(&(x / t, y / t))
    }

    /// Mask tiles of one camera as unit pixel rects (pre-grouping).
    pub fn tile_rects(&self, cam: usize) -> Vec<IRect> {
        let t = self.tiling.tile_px;
        let mut v: Vec<(u32, u32)> = self.tiles[cam].iter().copied().collect();
        v.sort_unstable();
        v.into_iter().map(|(tx, ty)| IRect::new(tx * t, ty * t, t, t)).collect()
    }

    /// Detector block ids (block = `block_px` square, e.g. 32 px = 2×2
    /// tiles) that intersect the camera's mask, sorted ascending.  This is
    /// what the rust runtime feeds the RoI HLO variant.
    pub fn active_blocks(&self, cam: usize, block_px: u32, frame_w: u32) -> Vec<i32> {
        let t = self.tiling.tile_px;
        let per_block = block_px / t;
        let blocks_x = frame_w / block_px;
        let mut out: HashSet<i32> = HashSet::new();
        for &(tx, ty) in &self.tiles[cam] {
            let bx = tx / per_block;
            let by = ty / per_block;
            out.insert((by * blocks_x + bx) as i32);
        }
        let mut v: Vec<i32> = out.into_iter().collect();
        v.sort_unstable();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiling() -> Tiling {
        Tiling::new(2, 320, 192, 16)
    }

    #[test]
    fn from_solution_splits_by_camera() {
        let t = tiling();
        let mut sol = HashSet::new();
        sol.insert(t.tile_id(0, 1, 2));
        sol.insert(t.tile_id(0, 2, 2));
        sol.insert(t.tile_id(1, 5, 5));
        let m = RoiMasks::from_solution(&t, &sol);
        assert_eq!(m.camera_size(0), 2);
        assert_eq!(m.camera_size(1), 1);
        assert_eq!(m.total_size(), 3);
        assert!(m.contains_pixel(0, 16, 32));
        assert!(!m.contains_pixel(0, 0, 0));
        assert!(m.contains_pixel(1, 80, 80));
    }

    #[test]
    fn full_mask_covers_everything() {
        let t = tiling();
        let m = RoiMasks::full(&t);
        assert_eq!(m.camera_size(0), 240);
        assert!((m.coverage(0) - 1.0).abs() < 1e-12);
        assert!(m.contains_pixel(0, 319, 191));
        assert_eq!(m.active_blocks(0, 32, 320).len(), 60);
    }

    #[test]
    fn active_blocks_merge_tiles() {
        let t = tiling();
        let mut sol = HashSet::new();
        // four tiles of the same 32px block (block (0,0))
        sol.insert(t.tile_id(0, 0, 0));
        sol.insert(t.tile_id(0, 1, 0));
        sol.insert(t.tile_id(0, 0, 1));
        sol.insert(t.tile_id(0, 1, 1));
        // one tile in block (5, 3): tiles (10..11, 6..7)
        sol.insert(t.tile_id(0, 10, 6));
        let m = RoiMasks::from_solution(&t, &sol);
        let blocks = m.active_blocks(0, 32, 320);
        assert_eq!(blocks, vec![0, 3 * 10 + 5]);
    }

    #[test]
    fn tile_rects_are_pixel_tiles() {
        let t = tiling();
        let mut sol = HashSet::new();
        sol.insert(t.tile_id(0, 3, 1));
        let m = RoiMasks::from_solution(&t, &sol);
        assert_eq!(m.tile_rects(0), vec![IRect::new(48, 16, 16, 16)]);
        assert!(m.tile_rects(1).is_empty());
    }
}
