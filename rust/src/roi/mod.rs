//! RoI mask optimization (§3.3, Eq. 1–2): choose the minimum set of tiles
//! such that every object occurrence keeps at least one fully-included
//! appearance region.  The paper solves this with Gurobi; we implement the
//! solver ourselves (greedy + pruning, plus exact branch-and-bound for
//! verification) — DESIGN.md §3.

pub mod masks;
pub mod setcover;

pub use masks::RoiMasks;
pub use setcover::{solve, solve_exact, Solution, SolverParams};
