//! The group-cover solvers behind RoI mask generation.
//!
//! Problem (Eq. 1–2): pick a tile set `M` minimizing `|M|` such that every
//! constraint has ≥ 1 region with all tiles in `M`.  (Each region is an
//! AND over its tiles; regions of one constraint are OR-ed — a "minimum
//! union of closed sets" / group Steiner-flavoured cover, NP-hard.)
//!
//! The optimizer is pluggable behind the [`Solver`] trait:
//!
//! * [`GreedySolver`] (and the [`solve`] convenience wrapper) — greedy
//!   density heuristic (best satisfied-per-new-tile ratio) followed by
//!   redundant-tile pruning.  The implementation keeps incremental state —
//!   a bitset mask over dense tile ids, per-region missing-tile counters
//!   maintained as tiles are added, and an inverted tile→region index with
//!   epoch-stamped hit counters for gain evaluation — so each round costs
//!   O(open-region tiles × index fan-out) instead of rescanning every
//!   open constraint × region × tile.  Selection order, scores and
//!   tie-breaking of the greedy phase are identical to the reference
//!   greedy; the prune pass deliberately changed order (rarest tiles
//!   first instead of ascending tile id), so where several tiles are
//!   interchangeably redundant the pruned cover may pick a different —
//!   equally valid, 1-minimal — tile set than pre-refactor builds.
//! * [`ExactSolver`] / [`solve_exact`] — branch-and-bound over
//!   constraint/region choices with a union lower bound; exponential, used
//!   on small instances and in tests to certify the greedy's quality.
//!
//! [`Solver::resolve`] warm-starts from a previous solution — the hook for
//! sliding profile windows (continuous re-profiling): still-useful tiles
//! are reused, newly-open constraints are covered greedily, and the prune
//! pass drops whatever the new window no longer needs.

use std::collections::{HashMap, HashSet};

use crate::association::table::AssociationTable;
use crate::association::tiles::GlobalTile;

/// Solver configuration.
#[derive(Debug, Clone)]
pub struct SolverParams {
    /// Run the pruning pass after the greedy cover.
    pub prune: bool,
}

impl Default for SolverParams {
    fn default() -> Self {
        SolverParams { prune: true }
    }
}

/// A solved mask (global tile set).
#[derive(Debug, Clone)]
pub struct Solution {
    pub tiles: HashSet<GlobalTile>,
    /// Constraints that could not be satisfied (empty region lists only).
    pub unsatisfiable: usize,
}

impl Solution {
    pub fn size(&self) -> usize {
        self.tiles.len()
    }
}

/// A pluggable RoI set-cover optimizer.
///
/// Implementations must be deterministic pure functions of the table (and
/// the warm seed): the planner's byte-identical-across-threads guarantee
/// rests on it.  The two in-tree implementations are [`GreedySolver`]
/// (the default) and [`ExactSolver`] (the branch-and-bound certifier).
pub trait Solver: Send + Sync {
    fn name(&self) -> &'static str;

    /// Solve from scratch.
    fn solve(&self, table: &AssociationTable) -> Solution;

    /// Warm-start from `prev` (e.g. the previous profile window's mask):
    /// tiles still referenced by `table` seed the cover, only newly-open
    /// constraints pay for greedy rounds, and pruning drops tiles the new
    /// window no longer needs.  Must return a valid cover of `table`;
    /// solvers with nothing to reuse may ignore `prev`.
    ///
    /// This is the continuous re-profiling hook (DESIGN.md §7): a window
    /// sliding over drifting traffic keeps most of its constraints, so
    /// re-solving from the previous mask is much cheaper than from
    /// scratch (`benches/offline_scaling.rs` measures the gap).
    ///
    /// ```
    /// use crossroi::association::table::{AssociationTable, Constraint};
    /// use crossroi::association::tiles::Tiling;
    /// use crossroi::roi::setcover::{GreedySolver, Solver};
    ///
    /// let window_a = AssociationTable {
    ///     tiling: Tiling::new(1, 320, 192, 16),
    ///     constraints: vec![
    ///         Constraint { regions: vec![vec![1, 2]] },
    ///         Constraint { regions: vec![vec![40, 41]] },
    ///     ],
    ///     multiplicity: vec![1, 1],
    ///     total_occurrences: 2,
    /// };
    /// // the window slides: one constraint kept, one dropped, one new
    /// let window_b = AssociationTable {
    ///     constraints: vec![
    ///         Constraint { regions: vec![vec![1, 2]] },
    ///         Constraint { regions: vec![vec![50]] },
    ///     ],
    ///     multiplicity: vec![1, 1],
    ///     ..window_a.clone()
    /// };
    ///
    /// let solver = GreedySolver::default();
    /// let prev = solver.solve(&window_a);
    /// let next = solver.resolve(&prev, &window_b);
    /// // still-useful tiles are reused, stale ones pruned, new ones added
    /// assert!(next.tiles.contains(&1) && next.tiles.contains(&2));
    /// assert!(!next.tiles.contains(&40) && !next.tiles.contains(&41));
    /// assert!(next.tiles.contains(&50));
    /// ```
    fn resolve(&self, prev: &Solution, table: &AssociationTable) -> Solution;
}

fn region_satisfied(region: &[GlobalTile], m: &HashSet<GlobalTile>) -> bool {
    region.iter().all(|t| m.contains(t))
}

fn constraint_satisfied(regions: &[Vec<GlobalTile>], m: &HashSet<GlobalTile>) -> bool {
    regions.iter().any(|r| region_satisfied(r, m))
}

/// Greedy + prune solver (see module docs); the default optimizer.
#[derive(Debug, Clone, Default)]
pub struct GreedySolver {
    pub params: SolverParams,
}

impl Solver for GreedySolver {
    fn name(&self) -> &'static str {
        "greedy"
    }

    fn solve(&self, table: &AssociationTable) -> Solution {
        greedy_cover(table, &HashSet::new(), self.params.prune)
    }

    fn resolve(&self, prev: &Solution, table: &AssociationTable) -> Solution {
        greedy_cover(table, &prev.tiles, self.params.prune)
    }
}

/// Exact branch-and-bound solver (small instances only; the certifier).
#[derive(Debug, Clone)]
pub struct ExactSolver {
    /// Refuses (panics on) larger tables — branch-and-bound is exponential.
    pub max_constraints: usize,
}

impl Default for ExactSolver {
    fn default() -> Self {
        ExactSolver { max_constraints: 24 }
    }
}

impl Solver for ExactSolver {
    fn name(&self) -> &'static str {
        "exact"
    }

    fn solve(&self, table: &AssociationTable) -> Solution {
        solve_exact(table, self.max_constraints)
    }

    /// Exact solutions cannot reuse a warm start (the optimum is the
    /// optimum); `prev` is ignored.
    fn resolve(&self, _prev: &Solution, table: &AssociationTable) -> Solution {
        self.solve(table)
    }
}

/// Greedy + prune with default-parameter [`GreedySolver`] semantics.
pub fn solve(table: &AssociationTable, params: &SolverParams) -> Solution {
    GreedySolver { params: params.clone() }.solve(table)
}

// ---- incremental greedy machinery ----

/// Fixed-capacity bitset over dense tile ids.
struct BitSet {
    words: Vec<u64>,
}

impl BitSet {
    fn new(n: usize) -> BitSet {
        BitSet { words: vec![0u64; n.div_ceil(64)] }
    }

    fn contains(&self, i: u32) -> bool {
        (self.words[i as usize / 64] >> (i % 64)) & 1 == 1
    }

    fn insert(&mut self, i: u32) {
        self.words[i as usize / 64] |= 1u64 << (i % 64);
    }
}

/// The association table re-indexed for incremental solving: candidate
/// tiles get dense ids, regions are flat (deduped, dense) tile lists, and
/// an inverted tile→regions index drives gain evaluation and the
/// missing-count updates.
struct DenseTable<'a> {
    table: &'a AssociationTable,
    /// Sorted candidate tiles; position = dense id.
    tiles: Vec<GlobalTile>,
    /// Flat region list: deduped dense tile ids per region.
    region_tiles: Vec<Vec<u32>>,
    /// Owning constraint of each flat region.
    region_constraint: Vec<u32>,
    /// Flat region ids of each constraint, in original region order.
    constraint_regions: Vec<Vec<u32>>,
    /// Inverted index: flat regions containing each dense tile.
    tile_regions: Vec<Vec<u32>>,
}

/// Mutable cover state: the mask plus the incrementally-maintained gain
/// caches (per-region missing counts, per-constraint satisfaction).
struct CoverState {
    mask: BitSet,
    missing: Vec<u32>,
    satisfied: Vec<bool>,
    unsatisfiable: usize,
}

impl<'a> DenseTable<'a> {
    fn build(table: &'a AssociationTable) -> DenseTable<'a> {
        let tiles = table.candidate_tiles();
        let id_of: HashMap<GlobalTile, u32> =
            // lint: order-insensitive — `tiles` is the sorted Vec from candidate_tiles()
            tiles.iter().enumerate().map(|(i, &t)| (t, i as u32)).collect();
        let mut region_tiles = Vec::new();
        let mut region_constraint = Vec::new();
        let mut constraint_regions = Vec::with_capacity(table.constraints.len());
        for (ci, c) in table.constraints.iter().enumerate() {
            let mut rids = Vec::with_capacity(c.regions.len());
            for region in &c.regions {
                let mut dense: Vec<u32> = region.iter().map(|t| id_of[t]).collect();
                dense.sort_unstable();
                dense.dedup();
                rids.push(region_tiles.len() as u32);
                region_constraint.push(ci as u32);
                region_tiles.push(dense);
            }
            constraint_regions.push(rids);
        }
        let mut tile_regions: Vec<Vec<u32>> = vec![Vec::new(); tiles.len()];
        for (q, rt) in region_tiles.iter().enumerate() {
            for &t in rt {
                tile_regions[t as usize].push(q as u32);
            }
        }
        DenseTable { table, tiles, region_tiles, region_constraint, constraint_regions, tile_regions }
    }

    fn initial_state(&self) -> CoverState {
        let mut satisfied = vec![false; self.constraint_regions.len()];
        let mut unsatisfiable = 0usize;
        for (ci, c) in self.table.constraints.iter().enumerate() {
            if c.regions.is_empty() {
                satisfied[ci] = true;
                unsatisfiable += 1;
            }
        }
        let missing: Vec<u32> = self.region_tiles.iter().map(|r| r.len() as u32).collect();
        // a region with no tiles satisfies its constraint for free
        for (q, r) in self.region_tiles.iter().enumerate() {
            if r.is_empty() {
                satisfied[self.region_constraint[q] as usize] = true;
            }
        }
        CoverState {
            mask: BitSet::new(self.tiles.len()),
            missing,
            satisfied,
            unsatisfiable,
        }
    }

    /// Add a tile to the mask, decrementing the missing counters of every
    /// region containing it; regions reaching zero satisfy their
    /// constraint (the incremental form of the reference greedy's
    /// satisfaction-refresh rescan).
    fn add_tile(&self, st: &mut CoverState, t: u32) {
        if st.mask.contains(t) {
            return;
        }
        st.mask.insert(t);
        for &q in &self.tile_regions[t as usize] {
            let qi = q as usize;
            st.missing[qi] -= 1;
            if st.missing[qi] == 0 {
                st.satisfied[self.region_constraint[qi] as usize] = true;
            }
        }
    }

    /// Score of candidate region `r`:
    ///   (Σ multiplicity of open constraints it would close) / (new tiles).
    /// A constraint closes iff one of its regions has all missing tiles
    /// inside `r`'s missing tiles — counted by walking the inverted index
    /// of exactly those tiles with epoch-stamped hit counters (no
    /// clearing between candidates).
    #[allow(clippy::too_many_arguments)]
    fn score(
        &self,
        st: &CoverState,
        r: u32,
        epoch: u64,
        hit: &mut [u32],
        hit_epoch: &mut [u64],
        closed_epoch: &mut [u64],
    ) -> f64 {
        let mut gain = 0usize;
        let mut new_tiles = 0usize;
        for &t in &self.region_tiles[r as usize] {
            if st.mask.contains(t) {
                continue;
            }
            new_tiles += 1;
            for &q in &self.tile_regions[t as usize] {
                let qi = q as usize;
                let ci = self.region_constraint[qi] as usize;
                if st.satisfied[ci] {
                    continue;
                }
                if hit_epoch[qi] != epoch {
                    hit_epoch[qi] = epoch;
                    hit[qi] = 0;
                }
                hit[qi] += 1;
                if hit[qi] == st.missing[qi] && closed_epoch[ci] != epoch {
                    closed_epoch[ci] = epoch;
                    gain += self.table.multiplicity[ci].max(1);
                }
            }
        }
        debug_assert!(new_tiles > 0, "candidate region of an open constraint has no new tiles");
        gain as f64 / new_tiles as f64
    }
}

/// Greedy density cover from a (possibly empty) seed tile set, with
/// optional pruning.  Scores, iteration order and tie-breaking replicate
/// the reference greedy exactly, so the cover is unchanged — only the
/// bookkeeping is incremental.
fn greedy_cover(table: &AssociationTable, seed: &HashSet<GlobalTile>, prune_after: bool) -> Solution {
    let dense = DenseTable::build(table);
    let mut st = dense.initial_state();

    // warm start: reuse seed tiles still referenced by this table (tiles
    // no constraint mentions serve nothing and are dropped here — pruning
    // would remove them anyway)
    let mut seed_dense: Vec<u32> = Vec::new();
    // lint: order-insensitive — `dense.tiles` is the sorted Vec from candidate_tiles()
    for (i, t) in dense.tiles.iter().enumerate() {
        if seed.contains(t) {
            seed_dense.push(i as u32);
        }
    }
    for t in seed_dense {
        dense.add_tile(&mut st, t);
    }

    let n_regions = dense.region_tiles.len();
    let mut hit = vec![0u32; n_regions];
    let mut hit_epoch = vec![0u64; n_regions];
    let mut closed_epoch = vec![0u64; dense.constraint_regions.len()];
    let mut epoch = 0u64;

    loop {
        // candidate regions of open constraints, scored by
        //   (# open constraints fully satisfied by adding it) / (# new tiles)
        let mut best: Option<(f64, u32)> = None;
        for (ci, rids) in dense.constraint_regions.iter().enumerate() {
            if st.satisfied[ci] {
                continue;
            }
            for &r in rids {
                epoch += 1;
                let score = dense.score(&st, r, epoch, &mut hit, &mut hit_epoch, &mut closed_epoch);
                if best.map_or(true, |(s, _)| score > s) {
                    best = Some((score, r));
                }
            }
        }
        // every constraint satisfied (open constraints always offer a
        // region with missing tiles, so `best` is None only when done)
        let Some((_, r)) = best else {
            break;
        };
        let adds: Vec<u32> = dense.region_tiles[r as usize]
            .iter()
            .copied()
            .filter(|&t| !st.mask.contains(t))
            .collect();
        for t in adds {
            dense.add_tile(&mut st, t);
        }
    }

    let mut m: HashSet<GlobalTile> = dense
        .tiles
        .iter()
        .enumerate()
        .filter(|&(i, _)| st.mask.contains(i as u32))
        .map(|(_, &t)| t)
        .collect();
    if prune_after {
        prune(table, &mut m);
    }
    Solution { tiles: m, unsatisfiable: st.unsatisfiable }
}

/// Constraints referencing each tile of `m` (each constraint counted
/// once per tile) — drives both the prune order and the per-tile
/// recheck set.
fn referencing_constraints(
    table: &AssociationTable,
    m: &HashSet<GlobalTile>,
) -> HashMap<GlobalTile, Vec<usize>> {
    let mut referencing: HashMap<GlobalTile, Vec<usize>> = HashMap::new();
    for (ci, c) in table.constraints.iter().enumerate() {
        let mut seen: HashSet<GlobalTile> = HashSet::new();
        for region in &c.regions {
            for &t in region {
                if m.contains(&t) && seen.insert(t) {
                    referencing.entry(t).or_default().push(ci);
                }
            }
        }
    }
    referencing
}

/// Tiles of `m` ordered for pruning: ascending count of constraints that
/// reference them (rare tiles are likelier redundant), ties by tile id.
fn occurrence_order_from(
    referencing: &HashMap<GlobalTile, Vec<usize>>,
    m: &HashSet<GlobalTile>,
) -> Vec<GlobalTile> {
    let mut tiles: Vec<GlobalTile> = m.iter().copied().collect();
    tiles.sort_unstable_by_key(|t| (referencing.get(t).map_or(0, |v| v.len()), *t));
    tiles
}

/// [`occurrence_order_from`] building its own referencing index
/// (the ordering test's hook).
#[cfg(test)]
fn occurrence_order(table: &AssociationTable, m: &HashSet<GlobalTile>) -> Vec<GlobalTile> {
    occurrence_order_from(&referencing_constraints(table, m), m)
}

/// Remove tiles whose removal keeps every constraint satisfied, rare
/// (fewest-referencing-constraints) tiles first.  The referencing index
/// is built once and drives both the order and the per-tile rechecks.
fn prune(table: &AssociationTable, m: &mut HashSet<GlobalTile>) {
    let referencing = referencing_constraints(table, m);
    let order = occurrence_order_from(&referencing, m);
    prune_with(table, m, &order, &referencing);
}

/// The prune pass over an explicit removal order (order-robustness test
/// hook; builds the referencing index itself).
#[cfg(test)]
fn prune_ordered(table: &AssociationTable, m: &mut HashSet<GlobalTile>, order: &[GlobalTile]) {
    let referencing = referencing_constraints(table, m);
    prune_with(table, m, order, &referencing);
}

/// Try removing tiles in `order`.  Only constraints referencing the
/// candidate tile can break, so only they are rechecked.
fn prune_with(
    table: &AssociationTable,
    m: &mut HashSet<GlobalTile>,
    order: &[GlobalTile],
    referencing: &HashMap<GlobalTile, Vec<usize>>,
) {
    for t in order {
        m.remove(t);
        let ok = referencing.get(t).map_or(true, |cs| {
            cs.iter().all(|&ci| constraint_satisfied(&table.constraints[ci].regions, m))
        });
        if !ok {
            m.insert(*t);
        }
    }
}

/// Exact branch-and-bound solver (small instances only).
///
/// Branches on the open constraint with fewest regions; bound = |M| (the
/// union can only grow).  Panics if `table` exceeds `max_constraints`.
pub fn solve_exact(table: &AssociationTable, max_constraints: usize) -> Solution {
    assert!(
        table.constraints.len() <= max_constraints,
        "exact solver limited to {max_constraints} constraints"
    );
    let mut best: Option<HashSet<GlobalTile>> = None;
    let mut m: HashSet<GlobalTile> = HashSet::new();
    let mut unsat = 0usize;
    let solvable: Vec<&crate::association::table::Constraint> = table
        .constraints
        .iter()
        .filter(|c| {
            if c.regions.is_empty() {
                unsat += 1;
                false
            } else {
                true
            }
        })
        .collect();

    fn dfs(
        constraints: &[&crate::association::table::Constraint],
        m: &mut HashSet<GlobalTile>,
        best: &mut Option<HashSet<GlobalTile>>,
    ) {
        if let Some(b) = best {
            if m.len() >= b.len() {
                return; // bound
            }
        }
        // next open constraint (fewest regions first for tighter branching)
        let open = constraints
            .iter()
            .filter(|c| !constraint_satisfied(&c.regions, m))
            .min_by_key(|c| c.regions.len());
        match open {
            None => {
                *best = Some(m.clone());
            }
            Some(c) => {
                let mut regions: Vec<&Vec<GlobalTile>> = c.regions.iter().collect();
                // cheapest additions first
                regions.sort_by_key(|r| r.iter().filter(|t| !m.contains(t)).count());
                for region in regions {
                    let added: Vec<GlobalTile> =
                        region.iter().filter(|t| !m.contains(t)).copied().collect();
                    for &t in &added {
                        m.insert(t);
                    }
                    dfs(constraints, m, best);
                    for &t in &added {
                        m.remove(&t);
                    }
                }
            }
        }
    }

    dfs(&solvable, &mut m, &mut best);
    Solution { tiles: best.unwrap_or_default(), unsatisfiable: unsat }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::association::table::Constraint;
    use crate::association::tiles::Tiling;

    fn table_from(regions: Vec<Vec<Vec<GlobalTile>>>) -> AssociationTable {
        let n = regions.len();
        AssociationTable {
            tiling: Tiling::new(1, 320, 192, 16),
            constraints: regions.into_iter().map(|r| Constraint { regions: r }).collect(),
            multiplicity: vec![1; n],
            total_occurrences: n,
        }
    }

    fn check_valid(table: &AssociationTable, sol: &Solution) {
        for c in &table.constraints {
            if c.regions.is_empty() {
                continue;
            }
            assert!(
                constraint_satisfied(&c.regions, &sol.tiles),
                "constraint {c:?} unsatisfied by {:?}",
                sol.tiles
            );
        }
    }

    /// No single tile of the solution can be removed without breaking a
    /// constraint — the invariant any prune order must establish.
    fn check_one_minimal(table: &AssociationTable, sol: &Solution) {
        for &t in &sol.tiles {
            let mut m = sol.tiles.clone();
            m.remove(&t);
            let still_ok = table
                .constraints
                .iter()
                .all(|c| c.regions.is_empty() || constraint_satisfied(&c.regions, &m));
            assert!(!still_ok, "tile {t} is redundant after pruning: {:?}", sol.tiles);
        }
    }

    #[test]
    fn picks_shared_region_over_two_singles() {
        // the paper's O_1 example: object visible in both cameras — only
        // one of the two regions needs inclusion; here region {1,2} also
        // covers a second constraint, so it should win
        let t = table_from(vec![
            vec![vec![1, 2], vec![10, 11, 12, 13]],
            vec![vec![1, 2]],
        ]);
        let sol = solve(&t, &SolverParams::default());
        check_valid(&t, &sol);
        assert_eq!(sol.size(), 2, "tiles: {:?}", sol.tiles);
    }

    #[test]
    fn greedy_matches_exact_on_small_instances() {
        let cases = vec![
            vec![
                vec![vec![1, 2, 3], vec![7, 8]],
                vec![vec![2, 3], vec![9]],
                vec![vec![7, 8], vec![1]],
            ],
            vec![
                vec![vec![1], vec![2]],
                vec![vec![2], vec![3]],
                vec![vec![3], vec![1]],
            ],
            vec![
                vec![vec![5, 6]],
                vec![vec![6, 7]],
                vec![vec![5, 7], vec![8, 9, 10]],
            ],
        ];
        for regions in cases {
            let t = table_from(regions);
            let g = solve(&t, &SolverParams::default());
            let e = solve_exact(&t, 16);
            check_valid(&t, &g);
            check_valid(&t, &e);
            assert!(
                g.size() <= e.size() + 1,
                "greedy {} far from optimal {}",
                g.size(),
                e.size()
            );
            assert!(e.size() <= g.size());
        }
    }

    #[test]
    fn solver_trait_objects_agree_with_free_functions() {
        let t = table_from(vec![
            vec![vec![1, 2, 3], vec![7, 8]],
            vec![vec![2, 3], vec![9]],
            vec![vec![7, 8], vec![1]],
        ]);
        let greedy: Box<dyn Solver> = Box::new(GreedySolver::default());
        let exact: Box<dyn Solver> = Box::new(ExactSolver::default());
        assert_eq!(greedy.name(), "greedy");
        assert_eq!(exact.name(), "exact");
        let g = greedy.solve(&t);
        assert_eq!(g.tiles, solve(&t, &SolverParams::default()).tiles);
        assert_eq!(exact.solve(&t).size(), solve_exact(&t, 16).size());
    }

    #[test]
    fn pruning_removes_redundant_tiles() {
        // constraint B ⊂ A tiles: greedy may add extra; prune must trim to
        // a minimal solution
        let t = table_from(vec![vec![vec![1, 2, 3, 4]], vec![vec![2, 3]]]);
        let sol = solve(&t, &SolverParams::default());
        check_valid(&t, &sol);
        assert_eq!(sol.size(), 4);
    }

    #[test]
    fn prune_orders_by_ascending_constraint_occurrence() {
        // t2 and t3 are each referenced by two constraints, t1 and t9 by
        // one; the removal order must try the rare tiles first, ties by id
        let t = table_from(vec![
            vec![vec![1, 2, 3]],
            vec![vec![2, 3], vec![9]],
        ]);
        let m: HashSet<GlobalTile> = [1, 2, 3, 9].into_iter().collect();
        assert_eq!(occurrence_order(&t, &m), vec![1, 9, 2, 3]);
    }

    #[test]
    fn pruning_is_order_robust() {
        // whatever order the prune pass walks, the result must stay a
        // valid cover and be 1-minimal (no removable tile left behind)
        let cases = vec![
            vec![vec![vec![1, 2, 3, 4]], vec![vec![2, 3]]],
            vec![vec![vec![1], vec![2, 3]], vec![vec![2], vec![9]], vec![vec![3]]],
            vec![vec![vec![5, 6]], vec![vec![6, 7]], vec![vec![5, 7], vec![8, 9, 10]]],
        ];
        for regions in cases {
            let t = table_from(regions);
            let unpruned = solve(&t, &SolverParams { prune: false });
            for reversed in [false, true] {
                let mut m = unpruned.tiles.clone();
                let mut order = occurrence_order(&t, &m);
                if reversed {
                    order.reverse();
                }
                prune_ordered(&t, &mut m, &order);
                let sol = Solution { tiles: m, unsatisfiable: 0 };
                check_valid(&t, &sol);
                check_one_minimal(&t, &sol);
            }
        }
    }

    #[test]
    fn multiplicity_biases_choice() {
        // two alternative regions for c0: {1,2,3} also closes the heavy
        // repeated constraint, {9} is cheaper alone
        let mut t = table_from(vec![
            vec![vec![1, 2, 3], vec![9]],
            vec![vec![1, 2, 3]],
        ]);
        t.multiplicity = vec![1, 50];
        let sol = solve(&t, &SolverParams::default());
        check_valid(&t, &sol);
        // {1,2,3} is forced by c1 anyway; c0 must not add {9} on top
        assert_eq!(sol.size(), 3, "tiles {:?}", sol.tiles);
    }

    #[test]
    fn empty_table() {
        let t = table_from(vec![]);
        let sol = solve(&t, &SolverParams::default());
        assert_eq!(sol.size(), 0);
        assert_eq!(solve_exact(&t, 8).size(), 0);
    }

    #[test]
    fn unsatisfiable_counted() {
        let t = table_from(vec![vec![], vec![vec![4]]]);
        let sol = solve(&t, &SolverParams::default());
        assert_eq!(sol.unsatisfiable, 1);
        assert_eq!(sol.size(), 1);
    }

    #[test]
    fn exact_is_optimal_on_triangle() {
        // three constraints pairwise sharing tiles; optimum is 2 tiles
        let t = table_from(vec![
            vec![vec![1], vec![2]],
            vec![vec![2], vec![3]],
            vec![vec![3], vec![1]],
        ]);
        let e = solve_exact(&t, 8);
        check_valid(&t, &e);
        assert_eq!(e.size(), 2);
    }

    #[test]
    fn resolve_with_unchanged_table_is_stable() {
        let t = table_from(vec![
            vec![vec![1, 2, 3], vec![7, 8]],
            vec![vec![2, 3], vec![9]],
            vec![vec![7, 8], vec![1]],
        ]);
        let solver = GreedySolver::default();
        let a = solver.solve(&t);
        let b = solver.resolve(&a, &t);
        assert_eq!(a.tiles, b.tiles, "warm restart on the same window must be a fixpoint");
    }

    #[test]
    fn resolve_covers_a_shifted_window() {
        // window A: two constraints; window B drops one, keeps one, adds
        // two new ones (one reusing A's tiles, one over fresh tiles)
        let a = table_from(vec![vec![vec![1, 2]], vec![vec![40, 41]]]);
        let b = table_from(vec![
            vec![vec![1, 2]],
            vec![vec![1, 2], vec![30]],
            vec![vec![50, 51]],
        ]);
        let solver = GreedySolver::default();
        let prev = solver.solve(&a);
        assert_eq!(prev.size(), 4);
        let next = solver.resolve(&prev, &b);
        check_valid(&b, &next);
        check_one_minimal(&b, &next);
        // stale tiles (40, 41 serve no constraint of B) must be gone
        assert!(!next.tiles.contains(&40) && !next.tiles.contains(&41), "{:?}", next.tiles);
        // reused tiles keep the shared constraints covered without adding
        // the {30} alternative
        assert!(next.tiles.contains(&1) && next.tiles.contains(&2));
        assert!(!next.tiles.contains(&30), "{:?}", next.tiles);
        assert_eq!(next.size(), 4, "{:?}", next.tiles);
    }

    #[test]
    fn resolve_matches_fresh_solve_when_prev_is_empty() {
        let t = table_from(vec![
            vec![vec![1], vec![2]],
            vec![vec![2], vec![3]],
        ]);
        let solver = GreedySolver::default();
        let empty = Solution { tiles: HashSet::new(), unsatisfiable: 0 };
        assert_eq!(solver.resolve(&empty, &t).tiles, solver.solve(&t).tiles);
    }
}
