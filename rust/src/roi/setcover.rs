//! The group-cover solver behind RoI mask generation.
//!
//! Problem (Eq. 1–2): pick a tile set `M` minimizing `|M|` such that every
//! constraint has ≥ 1 region with all tiles in `M`.  (Each region is an
//! AND over its tiles; regions of one constraint are OR-ed — a "minimum
//! union of closed sets" / group Steiner-flavoured cover, NP-hard.)
//!
//! * [`solve`] — greedy density heuristic (best satisfied-per-new-tile
//!   ratio) followed by redundant-tile pruning; scales to the full
//!   profile-window instance.
//! * [`solve_exact`] — branch-and-bound over constraint/region choices
//!   with a union lower bound; exponential, used on small instances and in
//!   tests to certify the greedy's quality.

use std::collections::HashSet;

use crate::association::table::AssociationTable;
use crate::association::tiles::GlobalTile;

/// Solver configuration.
#[derive(Debug, Clone)]
pub struct SolverParams {
    /// Run the pruning pass after the greedy cover.
    pub prune: bool,
}

impl Default for SolverParams {
    fn default() -> Self {
        SolverParams { prune: true }
    }
}

/// A solved mask (global tile set).
#[derive(Debug, Clone)]
pub struct Solution {
    pub tiles: HashSet<GlobalTile>,
    /// Constraints that could not be satisfied (empty region lists only).
    pub unsatisfiable: usize,
}

impl Solution {
    pub fn size(&self) -> usize {
        self.tiles.len()
    }
}

fn region_satisfied(region: &[GlobalTile], m: &HashSet<GlobalTile>) -> bool {
    region.iter().all(|t| m.contains(t))
}

fn constraint_satisfied(regions: &[Vec<GlobalTile>], m: &HashSet<GlobalTile>) -> bool {
    regions.iter().any(|r| region_satisfied(r, m))
}

/// Greedy + prune solver.
pub fn solve(table: &AssociationTable, params: &SolverParams) -> Solution {
    let n = table.constraints.len();
    let mut m: HashSet<GlobalTile> = HashSet::new();
    let mut satisfied = vec![false; n];
    let mut unsatisfiable = 0usize;
    for (i, c) in table.constraints.iter().enumerate() {
        if c.regions.is_empty() {
            satisfied[i] = true;
            unsatisfiable += 1;
        }
    }

    loop {
        // refresh satisfaction (a region may have become covered as a side
        // effect of tiles added for other constraints)
        for (i, c) in table.constraints.iter().enumerate() {
            if !satisfied[i] && constraint_satisfied(&c.regions, &m) {
                satisfied[i] = true;
            }
        }
        let open: Vec<usize> = (0..n).filter(|&i| !satisfied[i]).collect();
        if open.is_empty() {
            break;
        }
        // candidate regions of open constraints, scored by
        //   (# open constraints fully satisfied by adding it) / (# new tiles)
        let mut best: Option<(f64, &Vec<GlobalTile>)> = None;
        for &ci in &open {
            for region in &table.constraints[ci].regions {
                let new_tiles = region.iter().filter(|t| !m.contains(t)).count();
                if new_tiles == 0 {
                    continue; // would already have satisfied it
                }
                // count how many open constraints this region closes
                let mut would: HashSet<GlobalTile> = HashSet::new();
                would.extend(region.iter().copied());
                let mut gain = 0usize;
                for &cj in &open {
                    let c = &table.constraints[cj];
                    if c.regions.iter().any(|r| {
                        r.iter().all(|t| m.contains(t) || would.contains(t))
                    }) {
                        gain += table.multiplicity[cj].max(1);
                    }
                }
                let score = gain as f64 / new_tiles as f64;
                if best.as_ref().map_or(true, |(s, _)| score > *s) {
                    best = Some((score, region));
                }
            }
        }
        match best {
            Some((_, region)) => {
                m.extend(region.iter().copied());
            }
            None => {
                // every open constraint has only empty/covered regions —
                // cannot happen with non-empty regions, guard anyway
                unsatisfiable += open.len();
                break;
            }
        }
    }

    if params.prune {
        prune(table, &mut m);
    }
    Solution { tiles: m, unsatisfiable }
}

/// Remove tiles whose removal keeps every constraint satisfied.
fn prune(table: &AssociationTable, m: &mut HashSet<GlobalTile>) {
    let mut tiles: Vec<GlobalTile> = m.iter().copied().collect();
    tiles.sort_unstable();
    // try removing rare tiles first (they are likelier to be redundant)
    for t in tiles {
        m.remove(&t);
        let ok = table
            .constraints
            .iter()
            .all(|c| c.regions.is_empty() || constraint_satisfied(&c.regions, m));
        if !ok {
            m.insert(t);
        }
    }
}

/// Exact branch-and-bound solver (small instances only).
///
/// Branches on the open constraint with fewest regions; bound = |M| (the
/// union can only grow).  Panics if `table` exceeds `max_constraints`.
pub fn solve_exact(table: &AssociationTable, max_constraints: usize) -> Solution {
    assert!(
        table.constraints.len() <= max_constraints,
        "exact solver limited to {max_constraints} constraints"
    );
    let mut best: Option<HashSet<GlobalTile>> = None;
    let mut m: HashSet<GlobalTile> = HashSet::new();
    let mut unsat = 0usize;
    let solvable: Vec<&crate::association::table::Constraint> = table
        .constraints
        .iter()
        .filter(|c| {
            if c.regions.is_empty() {
                unsat += 1;
                false
            } else {
                true
            }
        })
        .collect();

    fn dfs(
        constraints: &[&crate::association::table::Constraint],
        m: &mut HashSet<GlobalTile>,
        best: &mut Option<HashSet<GlobalTile>>,
    ) {
        if let Some(b) = best {
            if m.len() >= b.len() {
                return; // bound
            }
        }
        // next open constraint (fewest regions first for tighter branching)
        let open = constraints
            .iter()
            .filter(|c| !constraint_satisfied(&c.regions, m))
            .min_by_key(|c| c.regions.len());
        match open {
            None => {
                *best = Some(m.clone());
            }
            Some(c) => {
                let mut regions: Vec<&Vec<GlobalTile>> = c.regions.iter().collect();
                // cheapest additions first
                regions.sort_by_key(|r| r.iter().filter(|t| !m.contains(t)).count());
                for region in regions {
                    let added: Vec<GlobalTile> =
                        region.iter().filter(|t| !m.contains(t)).copied().collect();
                    for &t in &added {
                        m.insert(t);
                    }
                    dfs(constraints, m, best);
                    for &t in &added {
                        m.remove(&t);
                    }
                }
            }
        }
    }

    dfs(&solvable, &mut m, &mut best);
    Solution { tiles: best.unwrap_or_default(), unsatisfiable: unsat }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::association::table::Constraint;
    use crate::association::tiles::Tiling;

    fn table_from(regions: Vec<Vec<Vec<GlobalTile>>>) -> AssociationTable {
        let n = regions.len();
        AssociationTable {
            tiling: Tiling::new(1, 320, 192, 16),
            constraints: regions.into_iter().map(|r| Constraint { regions: r }).collect(),
            multiplicity: vec![1; n],
            total_occurrences: n,
        }
    }

    fn check_valid(table: &AssociationTable, sol: &Solution) {
        for c in &table.constraints {
            if c.regions.is_empty() {
                continue;
            }
            assert!(
                constraint_satisfied(&c.regions, &sol.tiles),
                "constraint {c:?} unsatisfied by {:?}",
                sol.tiles
            );
        }
    }

    #[test]
    fn picks_shared_region_over_two_singles() {
        // the paper's O_1 example: object visible in both cameras — only
        // one of the two regions needs inclusion; here region {1,2} also
        // covers a second constraint, so it should win
        let t = table_from(vec![
            vec![vec![1, 2], vec![10, 11, 12, 13]],
            vec![vec![1, 2]],
        ]);
        let sol = solve(&t, &SolverParams::default());
        check_valid(&t, &sol);
        assert_eq!(sol.size(), 2, "tiles: {:?}", sol.tiles);
    }

    #[test]
    fn greedy_matches_exact_on_small_instances() {
        let cases = vec![
            vec![
                vec![vec![1, 2, 3], vec![7, 8]],
                vec![vec![2, 3], vec![9]],
                vec![vec![7, 8], vec![1]],
            ],
            vec![
                vec![vec![1], vec![2]],
                vec![vec![2], vec![3]],
                vec![vec![3], vec![1]],
            ],
            vec![
                vec![vec![5, 6]],
                vec![vec![6, 7]],
                vec![vec![5, 7], vec![8, 9, 10]],
            ],
        ];
        for regions in cases {
            let t = table_from(regions);
            let g = solve(&t, &SolverParams::default());
            let e = solve_exact(&t, 16);
            check_valid(&t, &g);
            check_valid(&t, &e);
            assert!(
                g.size() <= e.size() + 1,
                "greedy {} far from optimal {}",
                g.size(),
                e.size()
            );
            assert!(e.size() <= g.size());
        }
    }

    #[test]
    fn pruning_removes_redundant_tiles() {
        // constraint B ⊂ A tiles: greedy may add extra; prune must trim to
        // a minimal solution
        let t = table_from(vec![vec![vec![1, 2, 3, 4]], vec![vec![2, 3]]]);
        let sol = solve(&t, &SolverParams::default());
        check_valid(&t, &sol);
        assert_eq!(sol.size(), 4);
    }

    #[test]
    fn multiplicity_biases_choice() {
        // two alternative regions for c0: {1,2,3} also closes the heavy
        // repeated constraint, {9} is cheaper alone
        let mut t = table_from(vec![
            vec![vec![1, 2, 3], vec![9]],
            vec![vec![1, 2, 3]],
        ]);
        t.multiplicity = vec![1, 50];
        let sol = solve(&t, &SolverParams::default());
        check_valid(&t, &sol);
        // {1,2,3} is forced by c1 anyway; c0 must not add {9} on top
        assert_eq!(sol.size(), 3, "tiles {:?}", sol.tiles);
    }

    #[test]
    fn empty_table() {
        let t = table_from(vec![]);
        let sol = solve(&t, &SolverParams::default());
        assert_eq!(sol.size(), 0);
        assert_eq!(solve_exact(&t, 8).size(), 0);
    }

    #[test]
    fn unsatisfiable_counted() {
        let t = table_from(vec![vec![], vec![vec![4]]]);
        let sol = solve(&t, &SolverParams::default());
        assert_eq!(sol.unsatisfiable, 1);
        assert_eq!(sol.size(), 1);
    }

    #[test]
    fn exact_is_optimal_on_triangle() {
        // three constraints pairwise sharing tiles; optimum is 2 tiles
        let t = table_from(vec![
            vec![vec![1], vec![2]],
            vec![vec![2], vec![3]],
            vec![vec![3], vec![1]],
        ]);
        let e = solve_exact(&t, 8);
        check_valid(&t, &e);
        assert_eq!(e.size(), 2);
    }
}
