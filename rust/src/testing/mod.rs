//! Property-testing kit (proptest is unavailable offline — DESIGN.md §3).
//!
//! [`check`] runs a property over `n` generated cases with seed reporting
//! and greedy input shrinking via the case index: on failure it reports
//! the failing seed so the case is reproducible.

use crate::util::rng::Rng;

/// Configuration for a property run.
#[derive(Debug, Clone)]
pub struct PropConfig {
    pub cases: usize,
    pub seed: u64,
}

impl Default for PropConfig {
    fn default() -> Self {
        PropConfig { cases: 64, seed: 0x9E37_79B9 }
    }
}

/// Run `prop(rng)` for `cfg.cases` independently-seeded cases; panic with
/// the failing case's seed on the first failure.
pub fn check<F>(cfg: &PropConfig, name: &str, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    for case in 0..cfg.cases {
        let case_seed = cfg.seed ^ (case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = Rng::new(case_seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property {name:?} failed on case {case} (seed {case_seed:#x}): {msg}\n\
                 reproduce with Rng::new({case_seed:#x})"
            );
        }
    }
}

/// Generators for common test inputs.
pub mod gen {
    use crate::util::geometry::Rect;
    use crate::util::rng::Rng;

    /// A bbox fully inside a `w × h` frame.
    pub fn bbox_in_frame(rng: &mut Rng, w: f64, h: f64) -> Rect {
        let bw = rng.range(4.0, w / 2.0);
        let bh = rng.range(4.0, h / 2.0);
        Rect::new(rng.range(0.0, w - bw), rng.range(0.0, h - bh), bw, bh)
    }

    /// A sorted list of distinct values below `n`.
    pub fn distinct_below(rng: &mut Rng, n: usize, k: usize) -> Vec<usize> {
        let mut v = rng.sample_indices(n, k.min(n));
        v.sort_unstable();
        v
    }
}

/// Synthetic multi-intersection fleets — the city-scale fleet shape the
/// overlap-sharded planner targets, which the simulator cannot build as
/// one scenario.  Shared by the sharding determinism tests, the
/// `offline_scaling` bench and the `sharded_fleet` example so the fleet
/// construction (camera offsets, disjoint id spaces) cannot drift
/// between them.
pub mod fleet {
    use crate::association::tiles::Tiling;
    use crate::config::Config;
    use crate::offline::profile;
    use crate::reid::records::{RawDetection, ReidStream};
    use crate::sim::Scenario;
    use crate::util::geometry::Rect;

    /// Profile `n_intersections` disjoint 4-camera intersections (seeds
    /// `base_seed + k`) and concatenate their streams into one fleet:
    /// camera indices are offset by intersection and raw/true id spaces
    /// are kept disjoint, so the co-occurrence graph has (at least) one
    /// component per intersection and none across.  The scenario knobs
    /// (window lengths, arrival rate, tile size) come from `base`; its
    /// `n_cameras`/`seed` are overridden per intersection.
    pub fn disjoint_intersections(
        base: &Config,
        n_intersections: usize,
        base_seed: u64,
    ) -> (ReidStream, Tiling) {
        let mut records: Vec<RawDetection> = Vec::new();
        let mut n_frames = 0usize;
        let mut id_offset = 0u32;
        for k in 0..n_intersections {
            let mut cfg = base.clone();
            cfg.scenario.n_cameras = 4;
            cfg.scenario.seed = base_seed + k as u64;
            let scenario = Scenario::build(&cfg.scenario);
            let stream = profile::run(&scenario).stream;
            n_frames = stream.n_frames; // identical windows per intersection
            let mut max_id = id_offset;
            for rec in stream.all() {
                let mut r = *rec;
                r.cam += 4 * k;
                r.raw_id += id_offset;
                r.true_id += id_offset;
                max_id = max_id.max(r.raw_id).max(r.true_id);
                records.push(r);
            }
            id_offset = max_id + 1;
        }
        let n_cams = 4 * n_intersections;
        let stream = ReidStream::new(n_cams, n_frames, records);
        let tiling = Tiling::new(
            n_cams,
            crate::sim::FRAME_W,
            crate::sim::FRAME_H,
            base.scenario.tile_px,
        );
        (stream, tiling)
    }

    /// [`disjoint_intersections`] (2 intersections) plus one **bridge
    /// camera**: a deterministic subsample of intersection 0's camera-0
    /// records re-appears in the bridge camera's *left* image half, and
    /// of intersection 1's first camera (global camera 4) in its *right*
    /// half, with the middle tile columns left empty.  The co-occurrence
    /// partition therefore fuses the whole fleet into **one** camera
    /// component through the bridge, while the bridge's two views image
    /// into tile-disjoint clusters — exactly the topology the constraint
    /// spill (DESIGN.md §8) splits back apart.  Returns the stream, the
    /// tiling and the bridge camera's global index.
    pub fn bridged_intersections(
        base: &Config,
        base_seed: u64,
    ) -> (ReidStream, Tiling, usize) {
        let (stream, _) = disjoint_intersections(base, 2, base_seed);
        let bridge = 2 * 4;
        let n_cams = bridge + 1;
        let mut records: Vec<RawDetection> = stream.all().to_vec();
        for rec in stream.all() {
            let left = match rec.cam {
                0 => true,
                4 => false,
                _ => continue,
            };
            if rec.frame % 2 != 0 {
                continue; // subsample: the bridge sees the corridor part-time
            }
            // squeeze the source bbox into the bridge's left
            // (intersection 0) or right (intersection 1) image half;
            // x stays under 120+24=144 on the left and starts at 184 on
            // the right, so tile columns 9–10 (x 144..176) never fill
            // and the two clusters stay tile-disjoint
            let w = rec.bbox.width.clamp(8.0, 24.0);
            let h = rec.bbox.height.clamp(8.0, 24.0);
            let x = if left {
                rec.bbox.left * 120.0 / 320.0
            } else {
                184.0 + rec.bbox.left * 120.0 / 320.0
            };
            let y = (rec.bbox.top * 0.8).min(192.0 - h - 1.0);
            records.push(RawDetection {
                cam: bridge,
                frame: rec.frame,
                bbox: Rect::new(x, y, w, h),
                raw_id: rec.raw_id,
                true_id: rec.true_id,
            });
        }
        let stream = ReidStream::new(n_cams, stream.n_frames, records);
        let tiling = Tiling::new(
            n_cams,
            crate::sim::FRAME_W,
            crate::sim::FRAME_H,
            base.scenario.tile_px,
        );
        (stream, tiling, bridge)
    }

    /// A mixed-resolution fleet: [`disjoint_intersections`] (1
    /// intersection, 4 cameras) with the odd cameras downscaled to a
    /// quarter-size active frame — every record's bbox is scaled into
    /// the smaller frame, so the stream geometrically matches the
    /// heterogeneous [`Tiling`] this returns alongside it.
    pub fn heterogeneous_fleet(base: &Config, base_seed: u64) -> (ReidStream, Tiling) {
        let (stream, _) = disjoint_intersections(base, 1, base_seed);
        let full = (crate::sim::FRAME_W, crate::sim::FRAME_H);
        let small = (crate::sim::FRAME_W / 2, crate::sim::FRAME_H / 2);
        let dims: Vec<(u32, u32)> =
            (0..stream.n_cameras).map(|c| if c % 2 == 0 { full } else { small }).collect();
        let records: Vec<RawDetection> = stream
            .all()
            .iter()
            .map(|rec| {
                if rec.cam % 2 == 0 {
                    return *rec;
                }
                let mut r = *rec;
                r.bbox = Rect::new(
                    rec.bbox.left / 2.0,
                    rec.bbox.top / 2.0,
                    (rec.bbox.width / 2.0).max(2.0),
                    (rec.bbox.height / 2.0).max(2.0),
                );
                r
            })
            .collect();
        let tiling = Tiling::heterogeneous(&dims, base.scenario.tile_px);
        (ReidStream::new(stream.n_cameras, stream.n_frames, records), tiling)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check(&PropConfig { cases: 10, seed: 1 }, "count", |_| {
            count += 1;
            Ok(())
        });
        assert_eq!(count, 10);
    }

    #[test]
    #[should_panic(expected = "property \"fails\" failed")]
    fn failing_property_panics_with_seed() {
        check(&PropConfig { cases: 5, seed: 2 }, "fails", |rng| {
            if rng.f64() >= 0.0 {
                Err("always".to_string())
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn generators_produce_valid_inputs() {
        let mut rng = Rng::new(3);
        for _ in 0..100 {
            let b = gen::bbox_in_frame(&mut rng, 320.0, 192.0);
            assert!(b.left >= 0.0 && b.right() <= 320.0);
            assert!(b.top >= 0.0 && b.bottom() <= 192.0);
            let d = gen::distinct_below(&mut rng, 60, 10);
            assert!(d.windows(2).all(|w| w[0] < w[1]));
        }
    }
}
