//! `crossroi` — the CrossRoI leader binary.
//!
//! Subcommands:
//!   offline   run the offline phase and print the mask/grouping summary
//!   run       run one method end-to-end over the eval window
//!   ablation  run the Fig. 8 ablation (all five methods)
//!   info      print the resolved configuration and artifact status
//!
//! Examples:
//!   crossroi offline --seed 7
//!   crossroi offline --seed 7 --shards auto --offline-threads 8
//!   crossroi run --method crossroi --segment-secs 1.0
//!   crossroi run --method crossroi --native --drift-at 70 --replan-every 4
//!   crossroi run --method reducto --reducto-target 0.9
//!   crossroi ablation --eval-secs 30
//!   crossroi info

use anyhow::{bail, Result};

use crossroi::cli::Args;
use crossroi::config::Config;
use crossroi::coordinator::{self, Method, MethodReport, NativeInfer};
use crossroi::sim::Scenario;

const USAGE: &str = "usage: crossroi <offline|run|ablation|info> [flags]
flags:
  --config <path>          TOML config file
  --seed <n>               scenario seed
  --cameras <n>            number of cameras
  --profile-secs <s>       offline window length
  --eval-secs <s>          online window length
  --segment-secs <s>       streaming segment length
  --svm-gamma <g>          SVM filter non-linearity
  --ransac-theta <t>       RANSAC threshold multiplier
  --method <name>          baseline|no-filters|no-merging|no-roiinf|crossroi|reducto|crossroi-reducto
  --reducto-target <a>     frame-filter accuracy target (with reducto methods)
  --offline-threads <n>    worker threads for the offline pair fitting
                           (0 = one per core, the default)
  --solver <name>          greedy|exact RoI set-cover solver (exact is a
                           certifier for small instances only)
  --shards <mode>          auto|off overlap-sharded planning: partition the
                           fleet into co-occurrence components and plan
                           each independently (default: auto)
  --replan-every <n>       continuous re-profiling (run/ablation): re-plan
                           the RoI masks every n streaming segments from a
                           sliding profile window, warm-starting the solver
  --replan-drift <t>       re-plan only when the window's constraint drift
                           reaches t in [0,1] (checked every --replan-every
                           segments, default 4)
  --replan-scope <s>       fleet|component re-planning granularity: component
                           (default) re-solves only drifted co-occurrence
                           components and carries the rest forward
  --planner-threads <n>    worker threads for one re-plan epoch's compute
                           phase (drift profile + fired-component solves;
                           0 = inherit --offline-threads, the default)
  --consolidate <mode>     auto|on|off cross-camera RoI consolidation: pack
                           sparse cameras' kept tile groups into shared
                           dense canvases on the server (auto, the default,
                           consolidates when >= 2 RoI cameras keep <= 25%
                           of their pixels)
  --fail <cam@t[..t2]>     sim: camera `cam` (0-based) goes silent at eval
                           time t; with `..t2` it rejoins at t2. Repeatable,
                           one camera per occurrence
  --scenario <name>        fault/scenario preset: dropout|rejoin|rush-hour|
                           membership-change (applied before other flags'
                           validation; --fail composes with it)
  --drift-at <s>           sim: shift the traffic flow between the two
                           roads at scenario time s (0 = stationary)
  --drift-strength <s>     sim: drift magnitude in [0,1] (default 0.75)
  --intersections <n>      sim: number of intersections in the fleet
                           (default 1; above 1, --cameras counts cameras
                           per intersection)
  --spacing <m>            sim: intersection spacing in meters (default 170)
  --bridge                 sim: add a corridor trio (two watchers + a bridge
                           camera) between adjacent intersections
  --drift-intersection <k> sim: drift only intersection k (default -1 = all)
  --artifacts <dir>        AOT artifact directory (default: artifacts)
  --native                 use the native reference detector (no PJRT)
  --sequential             run the online pipeline single-threaded
                           (uncontended service-time measurement)
";

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        eprintln!("{USAGE}");
        std::process::exit(1);
    }
}

fn build_config(args: &Args) -> Result<Config> {
    let mut cfg = match args.flag("config") {
        Some(path) => Config::from_file(path)?,
        None => Config::paper(),
    };
    if let Some(seed) = args.u64_flag("seed")? {
        cfg.scenario.seed = seed;
    }
    if let Some(n) = args.u64_flag("cameras")? {
        cfg.scenario.n_cameras = n as usize;
    }
    if let Some(v) = args.f64_flag("profile-secs")? {
        cfg.scenario.profile_secs = v;
    }
    if let Some(v) = args.f64_flag("eval-secs")? {
        cfg.scenario.eval_secs = v;
    }
    if let Some(v) = args.f64_flag("segment-secs")? {
        cfg.system.segment_secs = v;
    }
    if let Some(v) = args.f64_flag("svm-gamma")? {
        cfg.system.svm_gamma = v;
    }
    if let Some(v) = args.f64_flag("ransac-theta")? {
        cfg.system.ransac_theta = v;
    }
    if let Some(v) = args.f64_flag("bandwidth-mbps")? {
        cfg.system.bandwidth_mbps = v;
    }
    if let Some(v) = args.f64_flag("qp")? {
        cfg.system.qp = v;
    }
    if let Some(dir) = args.flag("artifacts") {
        cfg.system.artifacts_dir = dir.to_string();
    }
    if let Some(v) = args.f64_flag("drift-at")? {
        cfg.scenario.drift_at_secs = v;
    }
    if let Some(v) = args.f64_flag("drift-strength")? {
        cfg.scenario.drift_strength = v;
    }
    if let Some(n) = args.u64_flag("intersections")? {
        cfg.scenario.n_intersections = n as usize;
    }
    if let Some(v) = args.f64_flag("spacing")? {
        cfg.scenario.intersection_spacing = v;
    }
    if args.switch("bridge") {
        cfg.scenario.bridge_cameras = true;
    }
    if let Some(v) = args.flag("drift-intersection") {
        cfg.scenario.drift_intersection = v
            .parse::<i64>()
            .map_err(|_| anyhow::anyhow!("--drift-intersection {v:?} is not an integer"))?;
    }
    if let Some(name) = args.flag("scenario") {
        apply_scenario_preset(&mut cfg, name)?;
    }
    for spec in args.multi("fail") {
        cfg.scenario.faults.push(crossroi::config::FaultEvent::parse(spec)?);
    }
    cfg.scenario.validate()?;
    cfg.system.validate()?;
    Ok(cfg)
}

/// Named fault/scenario presets; they compose with explicit `--fail`
/// flags and are derived from the (already flag-adjusted) window lengths.
fn apply_scenario_preset(cfg: &mut Config, name: &str) -> Result<()> {
    use crossroi::config::FaultEvent;
    let eval = cfg.scenario.eval_secs;
    match name {
        "dropout" => cfg.scenario.faults.push(FaultEvent {
            cam: 1,
            start_secs: 0.3 * eval,
            end_secs: None,
        }),
        "rejoin" => cfg.scenario.faults.push(FaultEvent {
            cam: 1,
            start_secs: 0.25 * eval,
            end_secs: Some(0.6 * eval),
        }),
        "rush-hour" => cfg.scenario.rush_period_secs = eval / 2.0,
        "membership-change" => {
            cfg.scenario.n_intersections = cfg.scenario.n_intersections.max(2);
            cfg.scenario.n_cameras = cfg.scenario.n_cameras.min(4);
            cfg.scenario.bridge_cameras = true;
            cfg.scenario.corridor_at_secs = cfg.scenario.profile_secs + 0.3 * eval;
        }
        other => bail!("unknown --scenario {other:?} (dropout|rejoin|rush-hour|membership-change)"),
    }
    Ok(())
}

fn parse_method(args: &Args) -> Result<Method> {
    let target = args.f64_flag("reducto-target")?.unwrap_or(0.9);
    Ok(match args.flag("method").unwrap_or("crossroi") {
        "baseline" => Method::Baseline,
        "no-filters" => Method::NoFilters,
        "no-merging" => Method::NoMerging,
        "no-roiinf" => Method::NoRoiInf,
        "crossroi" => Method::CrossRoi,
        "reducto" => Method::Reducto(target),
        "crossroi-reducto" => Method::CrossRoiReducto(target),
        other => bail!("unknown method {other:?}"),
    })
}

fn run() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    args.ensure_known_switches(&["native", "verbose", "sequential", "bridge"])?;
    let cfg = build_config(&args)?;

    match args.subcommand.as_deref() {
        Some("info") => {
            println!("scenario: {:?}", cfg.scenario);
            println!("system:   {:?}", cfg.system);
            println!("artifacts: {}", artifact_status(&cfg));
            Ok(())
        }
        Some("offline") => {
            let scenario = Scenario::build(&cfg.scenario);
            let method = parse_method(&args)?;
            let opts = offline_options(&args)?;
            let plan = coordinator::build_plan_with(
                &scenario, &cfg.scenario, &cfg.system, &method, &opts,
            )?;
            println!(
                "offline phase for {} in {:.2} s ({} threads, {} solver): {} constraints",
                method.name(),
                plan.seconds(),
                plan.report.threads,
                plan.report.solver,
                plan.n_constraints
            );
            for st in &plan.report.stages {
                println!("  stage {:<9} {:8.3} s", st.stage, st.seconds);
            }
            if !plan.report.shards.is_empty() {
                println!("sharded into {} fleets:", plan.report.shards.len());
                for (i, s) in plan.report.shards.iter().enumerate() {
                    let cams: Vec<String> =
                        s.cameras.iter().map(|c| format!("C{}", c + 1)).collect();
                    println!(
                        "  shard {i}: [{}] {} constraints, {} tiles, {} spill groups, solve {:.3} s",
                        cams.join(" "),
                        s.n_constraints,
                        s.mask_tiles,
                        s.spill_groups,
                        s.stage_seconds("solve").unwrap_or(0.0)
                    );
                }
            }
            // only worth a line when the spill split *further* than the
            // camera partition (each shard trivially contributes one group)
            if plan.report.spill_groups > plan.report.shards.len().max(1) {
                let bridges: Vec<String> = plan
                    .report
                    .bridge_cameras
                    .iter()
                    .map(|c| format!("C{}", c + 1))
                    .collect();
                println!(
                    "constraint spill: {} tile-connected groups, bridge cameras [{}]",
                    plan.report.spill_groups,
                    bridges.join(" ")
                );
            }
            if let Some(r) = &plan.filter_report {
                println!(
                    "filters: {} pairs fit, {} FP decoupled, {} FN removed",
                    r.pairs_fit, r.fp_rewritten, r.fn_removed
                );
            }
            for cam in 0..scenario.cameras.len() {
                println!(
                    "  C{}: {:3} mask tiles ({:4.1}% of frame) -> {} regions, {} blocks",
                    cam + 1,
                    plan.masks.camera_size(cam),
                    100.0 * plan.masks.coverage(cam),
                    plan.groups[cam].len(),
                    plan.blocks[cam].len()
                );
            }
            println!("|M| = {} tiles total", plan.masks.total_size());
            Ok(())
        }
        Some("run") => {
            let scenario = Scenario::build(&cfg.scenario);
            let method = parse_method(&args)?;
            let opts = pipeline_options(&args)?;
            let report = if args.switch("native") {
                coordinator::run_method_with(
                    &scenario, &cfg.system, &NativeInfer, &method, None, &opts,
                )?
                .0
            } else {
                run_with_runtime(&scenario, &cfg, &method, &opts)?
            };
            println!("{}", report.row());
            println!(
                "  frames: {} total, {} filtered; mask {} tiles ({:.1}% mean coverage)",
                report.frames_total,
                report.frames_reduced,
                report.mask_tiles,
                100.0 * report.mask_coverage
            );
            println!(
                "  kernels: {} backend; arena: {} frame allocs, {} pixel allocs, \
                 {} pixel reuses, {} grid allocs, {} grid reuses",
                crossroi::codec::backend().name(),
                report.arena_frame_allocs,
                report.arena_pixel_allocs,
                report.arena_pixel_reuses,
                report.arena_grid_allocs,
                report.arena_grid_reuses
            );
            println!(
                "  consolidation: {} mode, {} canvas cams; {} canvases, \
                 {:.2} mean fill, {:.2} jobs/canvas, {} canvas allocs, {} canvas reuses",
                report.consolidate_mode,
                report.canvas_cams,
                report.canvas_count,
                report.canvas_fill_ratio,
                report.canvas_occupancy,
                report.arena_canvas_allocs,
                report.arena_canvas_reuses
            );
            if report.replan_count > 0 || report.replan_carried_components > 0 {
                println!(
                    "  re-profiling: {} component re-solves ({} warm-started), {} carried, \
                     {} migrations, mean mask churn {:.2}, {:.2} s planning",
                    report.replan_count,
                    report.replan_warm_count,
                    report.replan_carried_components,
                    report.replan_migrations,
                    report.replan_mask_churn,
                    report.replan_seconds
                );
                if report.replan_reducto_rederived > 0 {
                    println!(
                        "  frame filter: {} per-epoch threshold re-derivations",
                        report.replan_reducto_rederived
                    );
                }
                if report.planner_epochs_computed > 0 {
                    println!(
                        "  planner pool: {} epochs computed, {} component solves \
                         ({} max concurrent), {:.3} s total queue wait",
                        report.planner_epochs_computed,
                        report.planner_components_solved,
                        report.planner_max_concurrent,
                        report.planner_queue_wait_secs
                    );
                }
            }
            if !report.repair_records.is_empty() {
                let drops =
                    report.repair_records.iter().filter(|r| r.kind == "dropout").count();
                let orphaned: usize =
                    report.repair_records.iter().map(|r| r.orphaned_tiles).sum();
                let recovered: usize =
                    report.repair_records.iter().map(|r| r.recovered_tiles).sum();
                let uncovered: usize =
                    report.repair_records.iter().map(|r| r.uncovered_constraints).sum();
                println!(
                    "  plan repair: {} record(s) ({} dropout, {} rejoin), \
                     {} orphaned tiles, {} re-covered, {} uncovered",
                    report.repair_records.len(),
                    drops,
                    report.repair_records.len() - drops,
                    orphaned,
                    recovered,
                    uncovered
                );
            }
            Ok(())
        }
        Some("ablation") => {
            let scenario = Scenario::build(&cfg.scenario);
            let methods = [
                Method::Baseline,
                Method::NoFilters,
                Method::NoMerging,
                Method::NoRoiInf,
                Method::CrossRoi,
            ];
            let opts = pipeline_options(&args)?;
            let reports = if args.switch("native") {
                coordinator::run_ablation_with(
                    &scenario, &cfg.system, &NativeInfer, &methods, &opts,
                )?
            } else {
                ablation_with_runtime(&scenario, &cfg, &methods, &opts)?
            };
            for r in &reports {
                println!("{}", r.row());
            }
            Ok(())
        }
        Some(other) => bail!("unknown subcommand {other:?}"),
        None => bail!("missing subcommand"),
    }
}

fn offline_options(args: &Args) -> Result<crossroi::offline::OfflineOptions> {
    let mut opts = crossroi::offline::OfflineOptions::default();
    if let Some(n) = args.u64_flag("offline-threads")? {
        opts.threads = n as usize;
    }
    if let Some(name) = args.flag("solver") {
        opts.solver = crossroi::offline::SolverKind::parse(name)?;
    }
    if let Some(name) = args.flag("shards") {
        opts.shards = crossroi::offline::ShardMode::parse(name)?;
    }
    Ok(opts)
}

fn pipeline_options(args: &Args) -> Result<crossroi::pipeline::PipelineOptions> {
    use crossroi::pipeline::ReplanPolicy;
    let mut opts = crossroi::pipeline::PipelineOptions::default();
    if args.switch("sequential") {
        opts.parallelism = crossroi::pipeline::Parallelism::Sequential;
    }
    // run/ablation build their offline plan internally — the planner
    // flags steer it there too
    opts.offline = offline_options(args)?;
    let every = args.u64_flag("replan-every")?.map(|n| (n as usize).max(1));
    let drift = args.f64_flag("replan-drift")?;
    opts.replan = match (every, drift) {
        (None, None) => ReplanPolicy::Never,
        (Some(n), None) => ReplanPolicy::Every(n),
        (every, Some(threshold)) => {
            if !(0.0..=1.0).contains(&threshold) {
                bail!("--replan-drift must be in [0,1], got {threshold}");
            }
            ReplanPolicy::Drift {
                check_every: every.unwrap_or(ReplanPolicy::DEFAULT_CHECK_EVERY),
                threshold,
            }
        }
    };
    if let Some(name) = args.flag("replan-scope") {
        opts.replan_scope = crossroi::pipeline::ReplanScope::parse(name)?;
    }
    if let Some(n) = args.u64_flag("planner-threads")? {
        opts.planner_threads = n as usize;
    }
    if let Some(name) = args.flag("consolidate") {
        opts.consolidate = crossroi::pipeline::ConsolidateMode::parse(name)
            .ok_or_else(|| anyhow::anyhow!("--consolidate must be auto|on|off, got {name:?}"))?;
    }
    Ok(opts)
}

// ---- PJRT-backed entry points (feature `pjrt`); default builds route
// everything through --native and report the runtime as unavailable ----

#[cfg(feature = "pjrt")]
fn artifact_status(cfg: &Config) -> String {
    match crossroi::runtime::Runtime::load(&cfg.system.artifacts_dir) {
        Ok(rt) => format!(
            "OK ({} RoI variants, contract {}x{})",
            rt.contract.roi_capacities.len(),
            rt.contract.frame_w,
            rt.contract.frame_h
        ),
        Err(e) => format!("UNAVAILABLE ({e:#})"),
    }
}

#[cfg(not(feature = "pjrt"))]
fn artifact_status(_cfg: &Config) -> String {
    "UNAVAILABLE (built without the `pjrt` feature; rebuild with --features pjrt)".to_string()
}

#[cfg(feature = "pjrt")]
fn run_with_runtime(
    scenario: &Scenario,
    cfg: &Config,
    method: &Method,
    opts: &crossroi::pipeline::PipelineOptions,
) -> Result<MethodReport> {
    use anyhow::Context as _;
    let rt = crossroi::runtime::Runtime::load(&cfg.system.artifacts_dir)
        .context("loading artifacts (or pass --native)")?;
    let report = coordinator::run_method_with(
        scenario,
        &cfg.system,
        &coordinator::RuntimeInfer(&rt),
        method,
        None,
        opts,
    )?
    .0;
    Ok(report)
}

#[cfg(not(feature = "pjrt"))]
fn run_with_runtime(
    _scenario: &Scenario,
    _cfg: &Config,
    _method: &Method,
    _opts: &crossroi::pipeline::PipelineOptions,
) -> Result<MethodReport> {
    bail!("this binary was built without the `pjrt` feature; pass --native or rebuild with --features pjrt")
}

#[cfg(feature = "pjrt")]
fn ablation_with_runtime(
    scenario: &Scenario,
    cfg: &Config,
    methods: &[Method],
    opts: &crossroi::pipeline::PipelineOptions,
) -> Result<Vec<MethodReport>> {
    use anyhow::Context as _;
    let rt = crossroi::runtime::Runtime::load(&cfg.system.artifacts_dir)
        .context("loading artifacts (or pass --native)")?;
    coordinator::run_ablation_with(
        scenario,
        &cfg.system,
        &coordinator::RuntimeInfer(&rt),
        methods,
        opts,
    )
}

#[cfg(not(feature = "pjrt"))]
fn ablation_with_runtime(
    _scenario: &Scenario,
    _cfg: &Config,
    _methods: &[Method],
    _opts: &crossroi::pipeline::PipelineOptions,
) -> Result<Vec<MethodReport>> {
    bail!("this binary was built without the `pjrt` feature; pass --native or rebuild with --features pjrt")
}
