//! # CrossRoI — cross-camera region-of-interest optimization
//!
//! Reproduction of *"CrossRoI: Cross-camera Region of Interest Optimization
//! for Efficient Real Time Video Analytics at Scale"* (MMSys 2021) as a
//! three-layer rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the coordinator and every substrate the paper
//!   depends on: traffic-world simulator, ReID error model, statistical
//!   filters (RANSAC / SVM), region association, RoI set-cover optimizer,
//!   tile grouping, block video codec, network discrete-event simulator,
//!   the staged [`offline`] planner, the stage-parallel streaming
//!   [`pipeline`], Reducto frame filtering and the query/accuracy
//!   machinery.
//! * **L2 (python/compile/model.py)** — the detector compute graph, AOT
//!   lowered to HLO text in `artifacts/`.
//! * **L1 (python/compile/kernels/sbnet.py)** — the SBNet-style sparse-block
//!   Pallas kernel inside that graph.
//!
//! The [`runtime`] module loads the AOT artifacts via the PJRT CPU client
//! (`xla` crate, behind the non-default `pjrt` feature) and executes them
//! on the request path; Python is build-time only.  Default builds use the
//! pure-rust reference detector instead, so `cargo build && cargo test`
//! work fully offline.  See `DESIGN.md` for the substitution table and
//! experiment index.

// Unsafe discipline (DESIGN.md §11, checked by `cargo xtask analyze`):
// unsafe code is confined to the two modules with a reason to exist —
// the SIMD kernels under `codec` and the PJRT FFI under `runtime` —
// and even there every unsafe operation must sit in an explicit block
// with a `// SAFETY:` justification.  Everything else forbids unsafe
// outright.

#[forbid(unsafe_code)]
pub mod association;
#[forbid(unsafe_code)]
pub mod bench;
#[forbid(unsafe_code)]
pub mod cli;
#[deny(unsafe_op_in_unsafe_fn)]
pub mod codec;
#[forbid(unsafe_code)]
pub mod config;
#[forbid(unsafe_code)]
pub mod coordinator;
#[forbid(unsafe_code)]
pub mod filters;
#[forbid(unsafe_code)]
pub mod net;
#[forbid(unsafe_code)]
pub mod offline;
#[forbid(unsafe_code)]
pub mod pipeline;
#[forbid(unsafe_code)]
pub mod query;
#[forbid(unsafe_code)]
pub mod reducto;
#[forbid(unsafe_code)]
pub mod reid;
#[forbid(unsafe_code)]
pub mod roi;
#[deny(unsafe_op_in_unsafe_fn)]
pub mod runtime;
#[forbid(unsafe_code)]
pub mod sim;
#[forbid(unsafe_code)]
pub mod testing;
#[forbid(unsafe_code)]
pub mod tilegroup;
#[forbid(unsafe_code)]
pub mod util;
