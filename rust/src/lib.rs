//! # CrossRoI — cross-camera region-of-interest optimization
//!
//! Reproduction of *"CrossRoI: Cross-camera Region of Interest Optimization
//! for Efficient Real Time Video Analytics at Scale"* (MMSys 2021) as a
//! three-layer rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the coordinator and every substrate the paper
//!   depends on: traffic-world simulator, ReID error model, statistical
//!   filters (RANSAC / SVM), region association, RoI set-cover optimizer,
//!   tile grouping, block video codec, network discrete-event simulator,
//!   the staged [`offline`] planner, the stage-parallel streaming
//!   [`pipeline`], Reducto frame filtering and the query/accuracy
//!   machinery.
//! * **L2 (python/compile/model.py)** — the detector compute graph, AOT
//!   lowered to HLO text in `artifacts/`.
//! * **L1 (python/compile/kernels/sbnet.py)** — the SBNet-style sparse-block
//!   Pallas kernel inside that graph.
//!
//! The [`runtime`] module loads the AOT artifacts via the PJRT CPU client
//! (`xla` crate, behind the non-default `pjrt` feature) and executes them
//! on the request path; Python is build-time only.  Default builds use the
//! pure-rust reference detector instead, so `cargo build && cargo test`
//! work fully offline.  See `DESIGN.md` for the substitution table and
//! experiment index.

pub mod association;
pub mod bench;
pub mod cli;
pub mod codec;
pub mod config;
pub mod coordinator;
pub mod filters;
pub mod net;
pub mod offline;
pub mod pipeline;
pub mod query;
pub mod reducto;
pub mod reid;
pub mod roi;
pub mod runtime;
pub mod sim;
pub mod testing;
pub mod tilegroup;
pub mod util;
