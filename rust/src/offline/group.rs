//! Stage ⑤-prep — Group: merge each camera's mask tiles into few large
//! codec regions (§4.3.2, Fig. 5; per-tile regions for the No-Merging
//! ablation) and derive the detector's active block lists.

use crate::roi::masks::RoiMasks;
use crate::tilegroup;
use crate::util::geometry::IRect;

/// Detector block size in pixels (2×2 tiles at the working resolution;
/// must match the L2 geometry contract).
pub const BLOCK_PX: u32 = 32;

/// The group stage's artifact: codec regions and detector blocks per
/// camera.
#[derive(Debug, Clone)]
pub struct GroupArtifact {
    pub groups: Vec<Vec<IRect>>,
    pub blocks: Vec<Vec<i32>>,
}

/// Group each camera's mask (or emit per-tile regions when `merging` is
/// off) and compute its active detector blocks.
pub fn run(masks: &RoiMasks, merging: bool) -> GroupArtifact {
    let n_cams = masks.tiling.n_cameras;
    let groups: Vec<Vec<IRect>> = if merging {
        tilegroup::group_all(masks)
    } else {
        (0..n_cams).map(|c| masks.tile_rects(c)).collect()
    };
    let blocks: Vec<Vec<i32>> = (0..n_cams)
        .map(|c| masks.active_blocks(c, BLOCK_PX, masks.tiling.frame_w))
        .collect();
    GroupArtifact { groups, blocks }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::association::tiles::Tiling;
    use std::collections::HashSet;

    fn masks_from(tiles: &[(u32, u32)]) -> RoiMasks {
        let tiling = Tiling::new(1, 320, 192, 16);
        let mut set = HashSet::new();
        set.extend(tiles.iter().copied());
        RoiMasks { tiling, tiles: vec![set] }
    }

    #[test]
    fn merging_produces_fewer_regions_than_tiles() {
        // a 3×2 block of tiles merges into one region
        let tiles: Vec<(u32, u32)> =
            (0..3).flat_map(|x| (0..2).map(move |y| (x, y))).collect();
        let m = masks_from(&tiles);
        let merged = run(&m, true);
        let unmerged = run(&m, false);
        assert_eq!(merged.groups[0].len(), 1);
        assert_eq!(unmerged.groups[0].len(), tiles.len());
        // blocks are identical either way (they depend on the mask only)
        assert_eq!(merged.blocks, unmerged.blocks);
    }

    #[test]
    fn blocks_cover_every_mask_tile() {
        let m = masks_from(&[(0, 0), (5, 3), (10, 6)]);
        let art = run(&m, true);
        let blocks_x = (320 / BLOCK_PX) as i32;
        for &(tx, ty) in m.tiles[0].iter() {
            let bid = (ty / 2) as i32 * blocks_x + (tx / 2) as i32;
            assert!(art.blocks[0].contains(&bid), "tile ({tx},{ty}) missing block {bid}");
        }
    }
}
