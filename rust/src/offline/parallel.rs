//! The offline planner's deterministic fan-out — a re-export of
//! [`crate::util::parallel`] (the helper is fully generic; the filters
//! layer uses it too, so it lives in `util` to keep the planner a pure
//! consumer of the layers below it).

pub use crate::util::parallel::ordered_map;
