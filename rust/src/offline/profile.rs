//! Stage ① — Profile: run the (error-prone) offline ReID over the
//! scenario's profile window (§4.1.1 module ①).

use crate::reid::error_model::{ErrorModelParams, RawReid};
use crate::reid::records::ReidStream;
use crate::sim::Scenario;

/// The profile stage's artifact: the raw ReID stream of the profile
/// window, indexed for the filter and association stages.
#[derive(Debug, Clone)]
pub struct ProfileArtifact {
    pub stream: ReidStream,
}

/// Generate the raw ReID stream for the profile window.
pub fn run(scenario: &Scenario) -> ProfileArtifact {
    let stream =
        RawReid::generate(scenario, scenario.profile_range(), &ErrorModelParams::default());
    ProfileArtifact { stream }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;

    #[test]
    fn profiles_the_profile_window_only() {
        let cfg = Config::test_small();
        let sc = Scenario::build(&cfg.scenario);
        let art = run(&sc);
        assert_eq!(art.stream.n_cameras, cfg.scenario.n_cameras);
        assert_eq!(art.stream.n_frames, sc.profile_range().len());
        assert!(!art.stream.is_empty(), "profile window produced no ReID records");
    }
}
