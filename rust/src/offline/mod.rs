//! The staged offline planner (§4.1.1, modules ①–④ plus grouping):
//! Profile → Filter → Associate → Solve → Group, each stage a typed
//! function producing a named artifact, timed into a [`PlanReport`].
//!
//! This mirrors the online phase's stage decomposition
//! ([`crate::pipeline`], DESIGN.md §4) on the offline side: the planner
//! is the part of CrossRoI that must scale as fleets grow — the pairwise
//! filter fitting is O(n²) in cameras — so the pair models are fitted on
//! scoped worker threads ([`parallel::ordered_map`]) with a deterministic
//! pair-order merge, and the RoI optimizer is pluggable behind
//! [`crate::roi::setcover::Solver`] (greedy default, exact certifier,
//! warm-started `resolve` for sliding profile windows).  Plans are
//! byte-identical at every thread count
//! (`rust/tests/offline_determinism.rs`).

pub mod associate;
pub mod filter;
pub mod group;
pub mod parallel;
pub mod profile;
pub mod solve;

pub use solve::SolverKind;

use std::time::Instant;

use anyhow::Result;

use crate::association::tiles::Tiling;
use crate::config::{ScenarioConfig, SystemConfig};
use crate::coordinator::method::Method;
use crate::filters::FilterReport;
use crate::roi::masks::RoiMasks;
use crate::sim::Scenario;
use crate::util::geometry::IRect;

/// Options steering one offline planning run.
#[derive(Debug, Clone, Copy)]
pub struct OfflineOptions {
    /// Worker threads for the O(n²) camera-pair fitting
    /// (CLI: `--offline-threads`); 0 = one per available core.
    pub threads: usize,
    /// Which set-cover solver optimizes the RoI masks (CLI: `--solver`).
    pub solver: SolverKind,
}

impl Default for OfflineOptions {
    fn default() -> Self {
        OfflineOptions { threads: 0, solver: SolverKind::Greedy }
    }
}

impl OfflineOptions {
    /// Resolve `threads = 0` to the host's core count.
    pub fn effective_threads(&self) -> usize {
        match self.threads {
            0 => std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
            n => n,
        }
    }
}

/// One stage's wall-clock share of a planning run.
#[derive(Debug, Clone, Copy)]
pub struct StageTiming {
    pub stage: &'static str,
    pub seconds: f64,
}

/// Per-stage breakdown of an offline planning run — supersedes the bare
/// `seconds` field the pre-stage `OfflinePlan` carried.  Timings are the
/// one wall-clock (non-deterministic) part of a plan; everything else is
/// a pure function of the scenario seed.
#[derive(Debug, Clone, Default)]
pub struct PlanReport {
    /// Stage timings in execution order.
    pub stages: Vec<StageTiming>,
    pub total_seconds: f64,
    /// Worker threads the pair fitting used.
    pub threads: usize,
    /// Solver that produced the masks.
    pub solver: &'static str,
}

impl PlanReport {
    fn record(&mut self, stage: &'static str, since: Instant) {
        self.stages.push(StageTiming { stage, seconds: since.elapsed().as_secs_f64() });
    }

    /// Seconds one named stage took (`None` if it did not run).
    pub fn stage_seconds(&self, stage: &str) -> Option<f64> {
        self.stages.iter().find(|s| s.stage == stage).map(|s| s.seconds)
    }
}

/// Per-fleet plan handed to the online phase.
#[derive(Debug, Clone)]
pub struct OfflinePlan {
    pub masks: RoiMasks,
    /// Codec regions per camera (grouped rectangles, or per-tile rects for
    /// No-Merging, or the full frame for Baseline).
    pub groups: Vec<Vec<IRect>>,
    /// Active detector blocks per camera (for the RoI HLO variant).
    pub blocks: Vec<Vec<i32>>,
    /// Filter diagnostics (None when filters were off).
    pub filter_report: Option<FilterReport>,
    /// Association table size (diagnostics).
    pub n_constraints: usize,
    /// Per-stage wall-clock breakdown of this plan.
    pub report: PlanReport,
}

impl OfflinePlan {
    /// Total wall-clock seconds the offline phase took.
    pub fn seconds(&self) -> f64 {
        self.report.total_seconds
    }
}

/// Run the offline phase for a method with default options (auto thread
/// count, greedy solver).
///
/// * Baseline / Reducto: full-frame masks, one full-frame region.
/// * No-Filters: raw ReID straight into the optimizer (② off).
/// * No-Merging: optimized masks but per-tile regions (tile grouping off).
/// * No-RoIInf / CrossRoI / CrossRoI-Reducto: the full pipeline.
pub fn build_plan(
    scenario: &Scenario,
    cfg: &ScenarioConfig,
    sys: &SystemConfig,
    method: &Method,
) -> Result<OfflinePlan> {
    build_plan_with(scenario, cfg, sys, method, &OfflineOptions::default())
}

/// [`build_plan`] with explicit [`OfflineOptions`].  Errors when the
/// chosen solver cannot take the instance (`--solver exact` on a real
/// profile window); the default greedy solver never fails.
pub fn build_plan_with(
    scenario: &Scenario,
    cfg: &ScenarioConfig,
    sys: &SystemConfig,
    method: &Method,
    opts: &OfflineOptions,
) -> Result<OfflinePlan> {
    let start = Instant::now();
    let threads = opts.effective_threads();
    let mut report =
        PlanReport { threads, solver: opts.solver.name(), ..Default::default() };
    let tiling = Tiling::new(
        scenario.cameras.len(),
        crate::sim::FRAME_W,
        crate::sim::FRAME_H,
        cfg.tile_px,
    );

    if !method.uses_roi_masks() {
        // Baseline / Reducto stream full frames: only Group has work.
        let t = Instant::now();
        let masks = RoiMasks::full(&tiling);
        let n_cams = scenario.cameras.len();
        let full_rect = vec![IRect::new(0, 0, crate::sim::FRAME_W, crate::sim::FRAME_H)];
        let blocks: Vec<Vec<i32>> = (0..n_cams)
            .map(|c| masks.active_blocks(c, group::BLOCK_PX, crate::sim::FRAME_W))
            .collect();
        report.record("group", t);
        report.total_seconds = start.elapsed().as_secs_f64();
        return Ok(OfflinePlan {
            groups: vec![full_rect; n_cams],
            blocks,
            masks,
            filter_report: None,
            n_constraints: 0,
            report,
        });
    }

    // ① Profile: offline ReID over the profile window
    let t = Instant::now();
    let profiled = profile::run(scenario);
    report.record("profile", t);

    // ② Filter: tandem statistical filters (skipped by No-Filters)
    let t = Instant::now();
    let filtered = filter::run(profiled, sys, method, threads);
    report.record("filter", t);

    // ③ Associate: region association lookup table
    let t = Instant::now();
    let assoc = associate::run(&filtered.stream, &tiling);
    report.record("associate", t);

    // ④ Solve: RoI mask optimization
    let t = Instant::now();
    opts.solver.validate(&assoc.table)?;
    let solved = solve::run(&assoc.table, opts.solver.build().as_ref());
    report.record("solve", t);

    // ⑤-prep Group: tile grouping (per-tile regions for No-Merging)
    let t = Instant::now();
    let grouped = group::run(&solved.masks, method.uses_merging());
    report.record("group", t);

    report.total_seconds = start.elapsed().as_secs_f64();
    Ok(OfflinePlan {
        masks: solved.masks,
        groups: grouped.groups,
        blocks: grouped.blocks,
        filter_report: filtered.report,
        n_constraints: assoc.table.n_constraints(),
        report,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;

    fn setup() -> (Scenario, Config) {
        let cfg = Config::test_small();
        (Scenario::build(&cfg.scenario), cfg)
    }

    #[test]
    fn baseline_plan_is_full_frame() {
        let (sc, cfg) = setup();
        let plan = build_plan(&sc, &cfg.scenario, &cfg.system, &Method::Baseline).unwrap();
        assert_eq!(plan.groups[0], vec![IRect::new(0, 0, 320, 192)]);
        assert_eq!(plan.blocks[0].len(), 60);
        assert!((plan.masks.coverage(0) - 1.0).abs() < 1e-12);
        assert!(plan.filter_report.is_none());
        // only the group stage runs for full-frame methods
        assert!(plan.report.stage_seconds("group").is_some());
        assert!(plan.report.stage_seconds("solve").is_none());
    }

    #[test]
    fn crossroi_plan_reduces_tiles() {
        let (sc, cfg) = setup();
        let plan = build_plan(&sc, &cfg.scenario, &cfg.system, &Method::CrossRoi).unwrap();
        let total: usize = (0..5).map(|c| plan.masks.camera_size(c)).sum();
        assert!(total > 0, "empty masks");
        assert!(
            total < 5 * 240,
            "CrossRoI masks did not shrink below full frames: {total}"
        );
        assert!(plan.filter_report.is_some());
        assert!(plan.n_constraints > 0);
        // grouped regions are fewer than tiles
        for cam in 0..5 {
            assert!(plan.groups[cam].len() <= plan.masks.camera_size(cam));
        }
    }

    #[test]
    fn plan_report_times_every_stage() {
        let (sc, cfg) = setup();
        let plan = build_plan_with(
            &sc,
            &cfg.scenario,
            &cfg.system,
            &Method::CrossRoi,
            &OfflineOptions { threads: 2, solver: SolverKind::Greedy },
        )
        .unwrap();
        let stages: Vec<&str> = plan.report.stages.iter().map(|s| s.stage).collect();
        assert_eq!(stages, vec!["profile", "filter", "associate", "solve", "group"]);
        assert!(plan.report.stages.iter().all(|s| s.seconds >= 0.0));
        // the total covers at least the sum of its stages
        let sum: f64 = plan.report.stages.iter().map(|s| s.seconds).sum();
        assert!(plan.report.total_seconds >= sum * 0.99, "{} < {sum}", plan.report.total_seconds);
        assert_eq!(plan.report.threads, 2);
        assert_eq!(plan.report.solver, "greedy");
        assert!(plan.seconds() > 0.0);
    }

    #[test]
    fn no_merging_uses_per_tile_regions() {
        let (sc, cfg) = setup();
        let merged = build_plan(&sc, &cfg.scenario, &cfg.system, &Method::CrossRoi).unwrap();
        let unmerged =
            build_plan(&sc, &cfg.scenario, &cfg.system, &Method::NoMerging).unwrap();
        // identical masks (same seed/profile), different region granularity
        assert_eq!(merged.masks.total_size(), unmerged.masks.total_size());
        for cam in 0..5 {
            assert_eq!(unmerged.groups[cam].len(), unmerged.masks.camera_size(cam));
            assert!(merged.groups[cam].len() <= unmerged.groups[cam].len());
        }
    }

    #[test]
    fn no_filters_masks_are_larger() {
        let (sc, cfg) = setup();
        let with = build_plan(&sc, &cfg.scenario, &cfg.system, &Method::CrossRoi).unwrap();
        let without =
            build_plan(&sc, &cfg.scenario, &cfg.system, &Method::NoFilters).unwrap();
        // false negatives force both copies of every broken pair into the
        // masks: the unfiltered plan must be at least as large
        assert!(
            without.masks.total_size() >= with.masks.total_size(),
            "no-filters {} < crossroi {}",
            without.masks.total_size(),
            with.masks.total_size()
        );
    }

    #[test]
    fn blocks_cover_mask_tiles() {
        let (sc, cfg) = setup();
        let plan = build_plan(&sc, &cfg.scenario, &cfg.system, &Method::CrossRoi).unwrap();
        for cam in 0..5 {
            for &(tx, ty) in plan.masks.tiles[cam].iter() {
                let bid = ((ty / 2) * 10 + tx / 2) as i32;
                assert!(
                    plan.blocks[cam].contains(&bid),
                    "cam {cam} tile ({tx},{ty}) not covered by block {bid}"
                );
            }
        }
    }
}
