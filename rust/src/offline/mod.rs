// lint: wall-clock-file — every Instant reading in this module lands in a
// PlanReport/MethodReport `*_seconds` stage timing, all of which
// `MethodReport::zero_wall_clock` zeroes before reports are byte-compared
// (rust/tests/report_shape.rs pins the field inventory).

//! The staged offline planner (§4.1.1, modules ①–④ plus grouping):
//! Profile → [Shard] → Filter → Associate → Solve → Group, each stage a
//! typed function producing a named artifact, timed into a [`PlanReport`].
//!
//! This mirrors the online phase's stage decomposition
//! ([`crate::pipeline`], DESIGN.md §4) on the offline side: the planner
//! is the part of CrossRoI that must scale as fleets grow — the pairwise
//! filter fitting is O(n²) in cameras — so the fleet is first partitioned
//! into overlap-connected shards ([`shard`]; city-scale fleets are sparse
//! and cross-shard pairs contribute nothing), each shard is planned
//! independently on scoped worker threads ([`parallel::ordered_map`])
//! with a deterministic shard-order merge, the pair models inside a shard
//! are fitted the same way with a deterministic pair-order merge, and the
//! RoI optimizer is pluggable behind [`crate::roi::setcover::Solver`]
//! (greedy default, exact certifier, warm-started `resolve` for sliding
//! profile windows).  Plans are byte-identical at every thread count and
//! at every shard mode (`rust/tests/offline_determinism.rs`).
//!
//! Planning is no longer one-shot: [`replan`] re-profiles a sliding
//! window during the online phase and warm-starts the solve from the
//! previous masks, swapping plans into the pipeline at segment
//! boundaries (DESIGN.md §7).

pub mod associate;
pub mod filter;
pub mod group;
pub mod parallel;
pub mod profile;
pub mod replan;
pub mod shard;
pub mod solve;

pub use replan::{ComponentRecord, PlannerPoolStats, RepairRecord, ReplanRecord, Replanner};
pub use shard::{spill, ShardMode, SpillGroup, SpillPartition};
pub use solve::SolverKind;

use std::collections::HashSet;
use std::time::Instant;

use anyhow::{Context as _, Result};

use crate::association::tiles::{GlobalTile, Tiling};
use crate::config::{ScenarioConfig, SystemConfig};
use crate::coordinator::method::Method;
use crate::filters::FilterReport;
use crate::reid::records::ReidStream;
use crate::roi::masks::RoiMasks;
use crate::sim::Scenario;
use crate::util::geometry::IRect;

/// Options steering one offline planning run.
#[derive(Debug, Clone, Copy)]
pub struct OfflineOptions {
    /// Worker threads for the per-shard planning and the O(n²)
    /// camera-pair fitting (CLI: `--offline-threads`); 0 = one per
    /// available core.
    pub threads: usize,
    /// Which set-cover solver optimizes the RoI masks (CLI: `--solver`).
    pub solver: SolverKind,
    /// Overlap-sharded planning (CLI: `--shards auto|off`): partition the
    /// fleet into co-occurrence components and plan each independently.
    pub shards: ShardMode,
}

impl Default for OfflineOptions {
    fn default() -> Self {
        OfflineOptions { threads: 0, solver: SolverKind::Greedy, shards: ShardMode::Auto }
    }
}

impl OfflineOptions {
    /// Resolve `threads = 0` to the host's core count.
    pub fn effective_threads(&self) -> usize {
        match self.threads {
            0 => std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
            n => n,
        }
    }
}

/// One stage's wall-clock share of a planning run.
#[derive(Debug, Clone, Copy)]
pub struct StageTiming {
    pub stage: &'static str,
    pub seconds: f64,
}

/// One shard's sub-report inside a sharded planning run: which cameras it
/// covered, its own filter/associate/solve timings, and what it
/// contributed to the merged plan.
#[derive(Debug, Clone)]
pub struct ShardReport {
    /// Global camera indices of this shard, ascending.
    pub cameras: Vec<usize>,
    /// Stage timings of this shard's run, in execution order.
    pub stages: Vec<StageTiming>,
    /// Constraints in this shard's association table.
    pub n_constraints: usize,
    /// Mask tiles this shard contributed to the merged solution.
    pub mask_tiles: usize,
    /// Tile-connected spill groups this shard's solve decomposed into
    /// (1 = nothing to split).
    pub spill_groups: usize,
    /// Cameras whose constraints spanned several spill groups (bridge
    /// cameras), ascending.
    pub bridge_cameras: Vec<usize>,
}

impl ShardReport {
    /// Seconds one named stage of this shard took (`None` if it did not
    /// run).
    pub fn stage_seconds(&self, stage: &str) -> Option<f64> {
        self.stages.iter().find(|s| s.stage == stage).map(|s| s.seconds)
    }
}

/// Per-stage breakdown of an offline planning run — supersedes the bare
/// `seconds` field the pre-stage `OfflinePlan` carried.  Timings are the
/// one wall-clock (non-deterministic) part of a plan; everything else is
/// a pure function of the scenario seed.
///
/// Unsharded (and single-shard `--shards auto`) runs time every stage
/// top-level in [`Self::stages`], keeping [`Self::stage_seconds`]'s
/// historical shape.  Multi-shard runs time the fan-out top-level
/// (profile / shard / plan / merge / group) and keep each shard's
/// filter/associate/solve timings in [`Self::shards`].  Full-frame
/// methods (Baseline / Reducto) only record the `group` stage.
///
/// `crossroi offline` prints this breakdown; continuous re-profiling
/// records its per-epoch costs separately
/// ([`replan::ReplanRecord::seconds`]), since re-plans run during the
/// online phase.
#[derive(Debug, Clone, Default)]
pub struct PlanReport {
    /// Stage timings in execution order.
    pub stages: Vec<StageTiming>,
    /// Per-shard sub-reports, in merge order (empty for unsharded and
    /// single-shard runs).
    pub shards: Vec<ShardReport>,
    pub total_seconds: f64,
    /// Worker threads the pair fitting used.
    pub threads: usize,
    /// Solver that produced the masks.
    pub solver: &'static str,
    /// Tile-connected spill groups the solve(s) decomposed into, summed
    /// across shards (0 for full-frame methods and `--shards off`).
    pub spill_groups: usize,
    /// Bridge cameras — cameras whose constraints spanned several spill
    /// groups — across the fleet, ascending.
    pub bridge_cameras: Vec<usize>,
}

impl PlanReport {
    fn record(&mut self, stage: &'static str, since: Instant) {
        self.stages.push(StageTiming { stage, seconds: since.elapsed().as_secs_f64() });
    }

    /// Seconds one named stage took (`None` if it did not run).
    pub fn stage_seconds(&self, stage: &str) -> Option<f64> {
        self.stages.iter().find(|s| s.stage == stage).map(|s| s.seconds)
    }
}

/// Per-fleet plan handed to the online phase.
#[derive(Debug, Clone)]
pub struct OfflinePlan {
    pub masks: RoiMasks,
    /// Codec regions per camera (grouped rectangles, or per-tile rects for
    /// No-Merging, or the full frame for Baseline).
    pub groups: Vec<Vec<IRect>>,
    /// Active detector blocks per camera (for the RoI HLO variant).
    pub blocks: Vec<Vec<i32>>,
    /// Filter diagnostics (None when filters were off).
    pub filter_report: Option<FilterReport>,
    /// Association table size (diagnostics).
    pub n_constraints: usize,
    /// Per-stage wall-clock breakdown of this plan.
    pub report: PlanReport,
}

impl OfflinePlan {
    /// Total wall-clock seconds the offline phase took.
    pub fn seconds(&self) -> f64 {
        self.report.total_seconds
    }
}

/// Run the offline phase for a method with default options (auto thread
/// count, greedy solver).
///
/// * Baseline / Reducto: full-frame masks, one full-frame region.
/// * No-Filters: raw ReID straight into the optimizer (② off).
/// * No-Merging: optimized masks but per-tile regions (tile grouping off).
/// * No-RoIInf / CrossRoI / CrossRoI-Reducto: the full pipeline.
pub fn build_plan(
    scenario: &Scenario,
    cfg: &ScenarioConfig,
    sys: &SystemConfig,
    method: &Method,
) -> Result<OfflinePlan> {
    build_plan_with(scenario, cfg, sys, method, &OfflineOptions::default())
}

/// [`build_plan`] with explicit [`OfflineOptions`].  Errors when the
/// chosen solver cannot take the instance (`--solver exact` on a real
/// profile window); the default greedy solver never fails.
pub fn build_plan_with(
    scenario: &Scenario,
    cfg: &ScenarioConfig,
    sys: &SystemConfig,
    method: &Method,
    opts: &OfflineOptions,
) -> Result<OfflinePlan> {
    let start = Instant::now();
    let mut report = PlanReport {
        threads: opts.effective_threads(),
        solver: opts.solver.name(),
        ..Default::default()
    };
    let tiling = Tiling::new(
        scenario.cameras.len(),
        crate::sim::FRAME_W,
        crate::sim::FRAME_H,
        cfg.tile_px,
    );

    if !method.uses_roi_masks() {
        return Ok(full_frame_plan(&tiling, report, start));
    }

    // ① Profile: offline ReID over the profile window
    let t = Instant::now();
    let profiled = profile::run(scenario);
    report.record("profile", t);

    plan_stream(profiled.stream, &tiling, sys, method, opts, report, start)
}

/// Plan from an already-profiled ReID stream over an explicit [`Tiling`]
/// — the entry point for fleets the simulator cannot build as one
/// scenario (synthetic multi-intersection worlds in
/// `benches/offline_scaling.rs` and the sharding tests) and for
/// externally profiled streams.  [`build_plan_with`] is this plus the
/// Profile stage.
///
/// Errors when the stream's camera count disagrees with the tiling, or
/// when the chosen solver cannot take the instance (`--solver exact` on
/// an oversized window).
///
/// ```
/// use crossroi::association::tiles::Tiling;
/// use crossroi::config::Config;
/// use crossroi::coordinator::Method;
/// use crossroi::offline::{build_plan_from_stream, OfflineOptions};
/// use crossroi::reid::records::ReidStream;
///
/// // plan a 2-camera fleet from an externally-profiled (here: empty)
/// // stream; Baseline skips straight to full-frame masks
/// let tiling = Tiling::new(2, 320, 192, 16);
/// let stream = ReidStream::new(2, 1, Vec::new());
/// let cfg = Config::test_small();
/// let plan = build_plan_from_stream(
///     &stream, &tiling, &cfg.system, &Method::Baseline, &OfflineOptions::default(),
/// ).unwrap();
/// assert_eq!(plan.masks.coverage(0), 1.0);
/// assert!(plan.report.stage_seconds("group").is_some());
/// ```
pub fn build_plan_from_stream(
    stream: &ReidStream,
    tiling: &Tiling,
    sys: &SystemConfig,
    method: &Method,
    opts: &OfflineOptions,
) -> Result<OfflinePlan> {
    anyhow::ensure!(
        stream.n_cameras == tiling.n_cameras,
        "stream carries {} cameras but the tiling {}",
        stream.n_cameras,
        tiling.n_cameras
    );
    let start = Instant::now();
    let report = PlanReport {
        threads: opts.effective_threads(),
        solver: opts.solver.name(),
        ..Default::default()
    };
    if !method.uses_roi_masks() {
        return Ok(full_frame_plan(tiling, report, start));
    }
    plan_stream(stream.clone(), tiling, sys, method, opts, report, start)
}

/// Full-frame plan (Baseline / Reducto): only Group has work.  Everything
/// — the full rect, the block grid — derives from the `Tiling`, never
/// from the sim's frame constants, so a non-default tiling stays
/// consistent with what [`group::run`] computes for the masked methods.
fn full_frame_plan(tiling: &Tiling, mut report: PlanReport, start: Instant) -> OfflinePlan {
    let t = Instant::now();
    let masks = RoiMasks::full(tiling);
    let n_cams = tiling.n_cameras;
    let full_rect = vec![IRect::new(0, 0, tiling.frame_w, tiling.frame_h)];
    let blocks: Vec<Vec<i32>> = (0..n_cams)
        .map(|c| masks.active_blocks(c, group::BLOCK_PX, tiling.frame_w))
        .collect();
    report.record("group", t);
    report.total_seconds = start.elapsed().as_secs_f64();
    OfflinePlan {
        groups: vec![full_rect; n_cams],
        blocks,
        masks,
        filter_report: None,
        n_constraints: 0,
        report,
    }
}

/// The post-profile stages.  `--shards auto` partitions the fleet first
/// and fans the shards out; one overlap component (or `--shards off`)
/// runs the historical single-instance path.
fn plan_stream(
    stream: ReidStream,
    tiling: &Tiling,
    sys: &SystemConfig,
    method: &Method,
    opts: &OfflineOptions,
    mut report: PlanReport,
    start: Instant,
) -> Result<OfflinePlan> {
    let threads = report.threads;

    if opts.shards == ShardMode::Auto {
        let t = Instant::now();
        let shards = shard::partition(&stream);
        if shards.len() > 1 {
            report.record("shard", t);
            return plan_sharded(stream, tiling, sys, method, opts, report, start, shards);
        }
        // a fully-connected fleet falls through to the unsharded path,
        // keeping the historical stage shape (and byte-identical plans
        // trivially)
    }

    // ② Filter: tandem statistical filters (skipped by No-Filters)
    let t = Instant::now();
    let frame = (tiling.frame_w as f64, tiling.frame_h as f64);
    let filtered = filter::run_scoped(stream, sys, method, threads, None, frame);
    report.record("filter", t);

    // ③ Associate: region association lookup table
    let t = Instant::now();
    let assoc = associate::run(&filtered.stream, tiling);
    report.record("associate", t);

    // ④ Solve: RoI mask optimization.  Under `--shards auto` the
    // instance is first split along the bridge-camera constraint spill
    // (DESIGN.md §8) — a camera bridging two intersections no longer
    // fuses them into one giant solve — which is byte-identical to the
    // fused solve and applies the exact certifier's cap per spill group.
    let t = Instant::now();
    let solved = if opts.shards == ShardMode::Auto {
        let sp = shard::spill(&assoc.table);
        report.spill_groups = sp.groups.len();
        report.bridge_cameras = sp.bridge_cameras();
        solve::run_spilled(&assoc.table, opts.solver, None, &sp)?
    } else {
        opts.solver.validate(&assoc.table)?;
        solve::run(&assoc.table, opts.solver.build().as_ref())
    };
    report.record("solve", t);

    // ⑤-prep Group: tile grouping (per-tile regions for No-Merging)
    let t = Instant::now();
    let grouped = group::run(&solved.masks, method.uses_merging());
    report.record("group", t);

    report.total_seconds = start.elapsed().as_secs_f64();
    Ok(OfflinePlan {
        masks: solved.masks,
        groups: grouped.groups,
        blocks: grouped.blocks,
        filter_report: filtered.report,
        n_constraints: assoc.table.n_constraints(),
        report,
    })
}

/// What one shard's independent run hands back to the merge.
struct ShardOutcome {
    tiles: HashSet<GlobalTile>,
    filter_report: Option<FilterReport>,
    report: ShardReport,
}

/// Fan the overlap components out on [`parallel::ordered_map`] workers
/// and merge in shard order.  Each shard plans its sub-stream with
/// global camera indexing (tile ids never need remapping), so the merge
/// is a plain union of disjoint per-shard solutions followed by one
/// global Group pass — grouping is per-camera, so post-merge grouping is
/// identical to grouping inside each shard.
#[allow(clippy::too_many_arguments)]
fn plan_sharded(
    stream: ReidStream,
    tiling: &Tiling,
    sys: &SystemConfig,
    method: &Method,
    opts: &OfflineOptions,
    mut report: PlanReport,
    start: Instant,
    shards: Vec<shard::Shard>,
) -> Result<OfflinePlan> {
    let threads = report.threads;
    // Split the worker budget by each shard's share of the O(k²) pair
    // fitting, not uniformly: on a skewed fleet (one downtown component
    // plus many singletons) a uniform split would hand the dominant
    // shard one thread and make `--shards auto` slower than unsharded.
    // Tiny shards still get one inline worker; the transient
    // oversubscription while a dominant shard and the fan-out overlap is
    // bounded and strictly better than starving it.
    let pair_count =
        |sh: &shard::Shard| sh.cameras.len() * sh.cameras.len().saturating_sub(1);
    let total_pairs: usize = shards.iter().map(&pair_count).sum();

    let t = Instant::now();
    let outcomes = parallel::ordered_map(&shards, threads, |sh| {
        let inner_threads = (threads * pair_count(sh) / total_pairs.max(1)).max(1);
        plan_one_shard(sh, &stream, tiling, sys, method, opts, inner_threads)
    });
    report.record("plan", t);

    // deterministic shard-order merge back into global camera indexing
    let t = Instant::now();
    let mut tiles: HashSet<GlobalTile> = HashSet::new();
    let mut filter_report = method.uses_filters().then(FilterReport::default);
    let mut n_constraints = 0usize;
    for outcome in outcomes {
        let o = outcome?;
        n_constraints += o.report.n_constraints;
        if let (Some(acc), Some(r)) = (filter_report.as_mut(), o.filter_report.as_ref()) {
            acc.pairs_fit += r.pairs_fit;
            acc.fp_rewritten += r.fp_rewritten;
            acc.fn_removed += r.fn_removed;
        }
        // lint: order-insensitive — set-to-set union
        tiles.extend(o.tiles.iter().copied());
        report.spill_groups += o.report.spill_groups;
        report.bridge_cameras.extend(o.report.bridge_cameras.iter().copied());
        report.shards.push(o.report);
    }
    // shards are camera-disjoint, so their bridge lists never overlap;
    // sorting restores the global ascending order
    report.bridge_cameras.sort_unstable();
    let masks = RoiMasks::from_solution(tiling, &tiles);
    report.record("merge", t);

    let t = Instant::now();
    let grouped = group::run(&masks, method.uses_merging());
    report.record("group", t);

    report.total_seconds = start.elapsed().as_secs_f64();
    Ok(OfflinePlan {
        masks,
        groups: grouped.groups,
        blocks: grouped.blocks,
        filter_report,
        n_constraints,
        report,
    })
}

/// One shard's Filter → Associate → Solve run over its sub-stream,
/// restricted to intra-shard camera pairs.
fn plan_one_shard(
    sh: &shard::Shard,
    stream: &ReidStream,
    tiling: &Tiling,
    sys: &SystemConfig,
    method: &Method,
    opts: &OfflineOptions,
    threads: usize,
) -> Result<ShardOutcome> {
    let mut stages = Vec::new();

    // ② Filter, intra-shard pairs only
    let t = Instant::now();
    let frame = (tiling.frame_w as f64, tiling.frame_h as f64);
    let filtered =
        filter::run_scoped(sh.substream(stream), sys, method, threads, Some(&sh.cameras), frame);
    stages.push(StageTiming { stage: "filter", seconds: t.elapsed().as_secs_f64() });

    // ③ Associate: shard-local constraint table (global tile ids; the
    // solver's dense re-indexing shrinks to this shard's candidate tiles)
    let t = Instant::now();
    let assoc = associate::run(&filtered.stream, tiling);
    stages.push(StageTiming { stage: "associate", seconds: t.elapsed().as_secs_f64() });

    // ④ Solve: shard-local set cover, decomposed along the shard's own
    // spill partition (the certifier's cap applies per spill group)
    let t = Instant::now();
    let sp = shard::spill(&assoc.table);
    let solution = solve::solve_spilled(&assoc.table, opts.solver, None, &sp)
        .with_context(|| format!("shard of cameras {:?}", sh.cameras))?;
    stages.push(StageTiming { stage: "solve", seconds: t.elapsed().as_secs_f64() });

    Ok(ShardOutcome {
        report: ShardReport {
            cameras: sh.cameras.clone(),
            stages,
            n_constraints: assoc.table.n_constraints(),
            mask_tiles: solution.size(),
            spill_groups: sp.groups.len(),
            bridge_cameras: sp.bridge_cameras(),
        },
        tiles: solution.tiles,
        filter_report: filtered.report,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;

    fn setup() -> (Scenario, Config) {
        let cfg = Config::test_small();
        (Scenario::build(&cfg.scenario), cfg)
    }

    #[test]
    fn baseline_plan_is_full_frame() {
        let (sc, cfg) = setup();
        let plan = build_plan(&sc, &cfg.scenario, &cfg.system, &Method::Baseline).unwrap();
        assert_eq!(plan.groups[0], vec![IRect::new(0, 0, 320, 192)]);
        assert_eq!(plan.blocks[0].len(), 60);
        assert!((plan.masks.coverage(0) - 1.0).abs() < 1e-12);
        assert!(plan.filter_report.is_none());
        // only the group stage runs for full-frame methods
        assert!(plan.report.stage_seconds("group").is_some());
        assert!(plan.report.stage_seconds("solve").is_none());
    }

    #[test]
    fn crossroi_plan_reduces_tiles() {
        let (sc, cfg) = setup();
        let plan = build_plan(&sc, &cfg.scenario, &cfg.system, &Method::CrossRoi).unwrap();
        let total: usize = (0..5).map(|c| plan.masks.camera_size(c)).sum();
        assert!(total > 0, "empty masks");
        assert!(
            total < 5 * 240,
            "CrossRoI masks did not shrink below full frames: {total}"
        );
        assert!(plan.filter_report.is_some());
        assert!(plan.n_constraints > 0);
        // grouped regions are fewer than tiles
        for cam in 0..5 {
            assert!(plan.groups[cam].len() <= plan.masks.camera_size(cam));
        }
    }

    #[test]
    fn plan_report_times_every_stage() {
        let (sc, cfg) = setup();
        let plan = build_plan_with(
            &sc,
            &cfg.scenario,
            &cfg.system,
            &Method::CrossRoi,
            &OfflineOptions { threads: 2, ..Default::default() },
        )
        .unwrap();
        let stages: Vec<&str> = plan.report.stages.iter().map(|s| s.stage).collect();
        assert_eq!(stages, vec!["profile", "filter", "associate", "solve", "group"]);
        assert!(plan.report.stages.iter().all(|s| s.seconds >= 0.0));
        // the total covers at least the sum of its stages
        let sum: f64 = plan.report.stages.iter().map(|s| s.seconds).sum();
        assert!(plan.report.total_seconds >= sum * 0.99, "{} < {sum}", plan.report.total_seconds);
        assert_eq!(plan.report.threads, 2);
        assert_eq!(plan.report.solver, "greedy");
        assert!(plan.seconds() > 0.0);
    }

    #[test]
    fn no_merging_uses_per_tile_regions() {
        let (sc, cfg) = setup();
        let merged = build_plan(&sc, &cfg.scenario, &cfg.system, &Method::CrossRoi).unwrap();
        let unmerged =
            build_plan(&sc, &cfg.scenario, &cfg.system, &Method::NoMerging).unwrap();
        // identical masks (same seed/profile), different region granularity
        assert_eq!(merged.masks.total_size(), unmerged.masks.total_size());
        for cam in 0..5 {
            assert_eq!(unmerged.groups[cam].len(), unmerged.masks.camera_size(cam));
            assert!(merged.groups[cam].len() <= unmerged.groups[cam].len());
        }
    }

    #[test]
    fn no_filters_masks_are_larger() {
        let (sc, cfg) = setup();
        let with = build_plan(&sc, &cfg.scenario, &cfg.system, &Method::CrossRoi).unwrap();
        let without =
            build_plan(&sc, &cfg.scenario, &cfg.system, &Method::NoFilters).unwrap();
        // false negatives force both copies of every broken pair into the
        // masks: the unfiltered plan must be at least as large
        assert!(
            without.masks.total_size() >= with.masks.total_size(),
            "no-filters {} < crossroi {}",
            without.masks.total_size(),
            with.masks.total_size()
        );
    }

    #[test]
    fn full_frame_plan_derives_from_the_tiling() {
        // regression: the full-frame path used to hardcode the sim's
        // FRAME_W/FRAME_H for the rect and block grid, drifting from
        // `group::run` (which derives from `masks.tiling`) for any
        // non-sim tiling
        let tiling = Tiling::new(2, 160, 96, 16);
        let stream = ReidStream::new(2, 1, Vec::new());
        let cfg = Config::test_small();
        let plan = build_plan_from_stream(
            &stream,
            &tiling,
            &cfg.system,
            &Method::Baseline,
            &OfflineOptions::default(),
        )
        .unwrap();
        for cam in 0..2 {
            assert_eq!(plan.groups[cam], vec![IRect::new(0, 0, 160, 96)]);
            // 160x96 at 32-px blocks: 5 x 3 grid
            assert_eq!(plan.blocks[cam], (0..15).collect::<Vec<i32>>());
            assert!((plan.masks.coverage(cam) - 1.0).abs() < 1e-12);
        }
        // the blocks must agree with what group::run derives from the
        // same tiling
        let grouped = group::run(&plan.masks, true);
        assert_eq!(plan.blocks, grouped.blocks);
    }

    #[test]
    fn sharded_exact_solver_validates_per_shard() {
        // the exact certifier's constraint cap applies per shard: a toy
        // two-component fleet plans end-to-end with --solver exact, and
        // the report carries one sub-report per component
        use crate::reid::records::RawDetection;
        use crate::util::geometry::Rect;
        let det = |cam: usize, frame: usize, raw_id: u32, x: f64| RawDetection {
            cam,
            frame,
            bbox: Rect::new(x, 32.0, 16.0, 16.0),
            raw_id,
            true_id: raw_id,
        };
        // components {0,1} and {2,3}: one shared object each, every frame
        let mut records = Vec::new();
        for f in 0..4 {
            records.push(det(0, f, 1, 32.0));
            records.push(det(1, f, 1, 48.0));
            records.push(det(2, f, 100, 64.0));
            records.push(det(3, f, 100, 80.0));
        }
        let stream = ReidStream::new(4, 4, records);
        let tiling = Tiling::new(4, 320, 192, 16);
        let cfg = Config::test_small();
        let opts = OfflineOptions { solver: SolverKind::Exact, ..Default::default() };
        let plan =
            build_plan_from_stream(&stream, &tiling, &cfg.system, &Method::CrossRoi, &opts)
                .unwrap();
        assert_eq!(plan.report.solver, "exact");
        assert_eq!(plan.report.shards.len(), 2);
        assert_eq!(plan.report.shards[0].cameras, vec![0, 1]);
        assert_eq!(plan.report.shards[1].cameras, vec![2, 3]);
        // each component's constraint has two single-tile regions; the
        // optimum keeps one tile per component
        assert_eq!(plan.n_constraints, 2);
        assert_eq!(plan.masks.total_size(), 2);
        for s in &plan.report.shards {
            assert_eq!(s.n_constraints, 1);
            assert_eq!(s.mask_tiles, 1);
            assert!(s.stage_seconds("solve").is_some());
        }
    }

    #[test]
    fn plan_from_stream_rejects_mismatched_tiling() {
        let tiling = Tiling::new(3, 160, 96, 16);
        let stream = ReidStream::new(2, 1, Vec::new());
        let cfg = Config::test_small();
        let err = build_plan_from_stream(
            &stream,
            &tiling,
            &cfg.system,
            &Method::CrossRoi,
            &OfflineOptions::default(),
        )
        .unwrap_err();
        assert!(err.to_string().contains("cameras"), "{err}");
    }

    #[test]
    fn blocks_cover_mask_tiles() {
        let (sc, cfg) = setup();
        let plan = build_plan(&sc, &cfg.scenario, &cfg.system, &Method::CrossRoi).unwrap();
        for cam in 0..5 {
            for &(tx, ty) in plan.masks.tiles[cam].iter() {
                let bid = ((ty / 2) * 10 + tx / 2) as i32;
                assert!(
                    plan.blocks[cam].contains(&bid),
                    "cam {cam} tile ({tx},{ty}) not covered by block {bid}"
                );
            }
        }
    }
}
