//! Continuous re-profiling — the offline planner's side of the loop
//! (DESIGN.md §7): turn sliding profile windows into warm-started plans.
//!
//! The paper's offline/online split assumes the cross-camera correlation
//! profile stays valid, but §3.1 concedes traffic patterns drift and the
//! RoI masks must be periodically re-derived (ReXCam adapts its learned
//! correlation model online the same way).  [`Replanner`] implements
//! [`EpochPlanner`] for the pipeline runner: at each epoch boundary it
//! re-profiles a **sliding window** of the most recent
//! `profile_secs`-worth of detection records, rebuilds the association
//! table, and — when the policy fires — re-solves the RoI cover,
//! **warm-starting** from the previous solution
//! ([`crate::roi::setcover::Solver::resolve`] via
//! [`solve::run_incremental`]) unless the table drifted so far that the
//! seed would mostly drag stale tiles through the prune pass
//! ([`FRESH_SOLVE_DRIFT`]).
//!
//! The drift signal is the **constraint drift**: the fraction of the new
//! window's (deduplicated) association constraints absent from the table
//! the current plan was solved on.  It is a pure function of the window —
//! never of pipeline timing — so re-plan decisions, and with them the
//! whole run, stay byte-identical across thread counts
//! (`rust/tests/replan.rs`).

use std::collections::HashSet;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::Result;

use crate::association::table::{AssociationTable, Constraint};
use crate::association::tiles::{GlobalTile, Tiling};
use crate::config::SystemConfig;
use crate::coordinator::method::Method;
use crate::offline::solve::SolverKind;
use crate::offline::{associate, filter, group, solve, OfflineOptions, OfflinePlan};
use crate::pipeline::infer::use_roi_path;
use crate::pipeline::replan::{EpochPlanner, PlanEpoch, ReplanPolicy};
use crate::reid::error_model::{ErrorModelParams, RawReid};
use crate::roi::masks::RoiMasks;
use crate::roi::setcover::{Solution, Solver as _};
use crate::sim::Scenario;

/// Above this constraint drift a warm seed reuses too little to pay for
/// itself (most seeded tiles are stale and only burden the prune pass);
/// the re-plan falls back to a from-scratch solve.
pub const FRESH_SOLVE_DRIFT: f64 = 0.6;

/// One epoch boundary's outcome — a check that may or may not have fired.
#[derive(Debug, Clone)]
pub struct ReplanRecord {
    /// Planning epoch (≥ 1; epoch 0 is the initial offline plan).
    pub epoch: usize,
    /// First segment index the epoch's plan applies to.
    pub start_seg: usize,
    /// Virtual time of the epoch boundary (seconds, eval-window origin —
    /// the DES clock).
    pub trigger_time: f64,
    /// Measured wall seconds of this check: window ReID + raw associate
    /// for the drift signal, plus filter + associate + solve + group when
    /// the policy fired.  The *first* check additionally carries the
    /// one-time drift-baseline derivation (a profile-window ReID +
    /// associate pass) — the first re-plan genuinely completes that much
    /// later, so its DES timestamp includes it.
    pub seconds: f64,
    /// Whether the policy fired (false = drift below threshold; the
    /// previous plan was carried forward untouched).
    pub replanned: bool,
    /// Whether the executed solve warm-started from the previous solution
    /// (vs a from-scratch re-solve).
    pub warm: bool,
    /// Fraction of the window's constraints absent from the table the
    /// current plan was solved on.
    pub constraint_drift: f64,
    /// Jaccard distance between the previous and new global tile sets
    /// (0.0 when not replanned).
    pub mask_churn: f64,
    /// Solver that produced this epoch's masks ("carried" when not
    /// replanned).  May be "greedy" under a `--solver exact` run: re-plan
    /// windows are solved unsharded, and when the exact certifier's cap
    /// refuses the global table the epoch degrades to greedy rather than
    /// failing the run mid-flight.
    pub solver: &'static str,
    /// Constraints in the window's *raw* (unfiltered) association table —
    /// the same series the drift signal is computed on, for carried and
    /// fired checks alike (the tandem-filtered table the solver covers is
    /// smaller).
    pub n_constraints: usize,
    /// |M| after this boundary.
    pub mask_tiles: usize,
}

/// Chained re-plan state: everything epoch `k` inherits from `k - 1`.
struct ReplanState {
    prev_solution: Solution,
    /// *Raw* (unfiltered) constraint set of the window the current masks
    /// were solved on — the drift baseline.  Raw-vs-raw keeps the signal
    /// comparable across checks and free of the O(n²) pair fitting.
    /// `None` until the first check derives the initial profile window's
    /// baseline — lazily, on the planner thread, so the extra linear
    /// ReID + associate pass overlaps the pipeline instead of delaying
    /// its start (the offline plan does not retain its profile stream).
    prev_constraints: Option<HashSet<Constraint>>,
    records: Vec<ReplanRecord>,
}

/// The coordinator's [`EpochPlanner`]: sliding-window re-profiling with
/// warm-started solves.  Construct once per run from the initial
/// [`OfflinePlan`], hand to
/// [`crate::pipeline::run_pipeline_with_replan`], then collect
/// [`Replanner::records`] for the report.
pub struct Replanner<'a> {
    scenario: &'a Scenario,
    sys: &'a SystemConfig,
    method: Method,
    opts: OfflineOptions,
    policy: ReplanPolicy,
    tiling: Tiling,
    /// Sliding window length in frames (= the initial profile window's).
    window_frames: usize,
    frames_per_segment: usize,
    /// Absolute frame index of the evaluation window's first frame.
    eval_start: usize,
    fps: f64,
    /// Detector block count of the inference backend (dense-fallback
    /// policy, same rule as the static plan's).
    n_infer_blocks: usize,
    state: Mutex<ReplanState>,
}

impl<'a> Replanner<'a> {
    /// Seed the re-planner from the initial offline plan.  The drift
    /// baseline (the initial profile window's raw association table) is
    /// derived lazily at the first check, on the planner thread, so
    /// constructing a `Replanner` never delays the pipeline's start.
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        scenario: &'a Scenario,
        sys: &'a SystemConfig,
        method: &Method,
        opts: OfflineOptions,
        policy: ReplanPolicy,
        frames_per_segment: usize,
        initial: &OfflinePlan,
        n_infer_blocks: usize,
    ) -> Replanner<'a> {
        Replanner {
            scenario,
            sys,
            method: method.clone(),
            opts,
            policy,
            window_frames: scenario.profile_range().len().max(1),
            frames_per_segment: frames_per_segment.max(1),
            eval_start: scenario.eval_range().start,
            fps: scenario.cfg.fps,
            n_infer_blocks,
            state: Mutex::new(ReplanState {
                prev_solution: solution_of(&initial.masks),
                prev_constraints: None,
                records: Vec::new(),
            }),
            tiling: initial.masks.tiling.clone(),
        }
    }

    /// Every boundary's outcome so far, in epoch order.
    pub fn records(&self) -> Vec<ReplanRecord> {
        self.state.lock().unwrap().records.clone()
    }
}

impl EpochPlanner for Replanner<'_> {
    fn plan_epoch(
        &self,
        k: usize,
        start_seg: usize,
        prev: &Arc<PlanEpoch>,
    ) -> Result<Arc<PlanEpoch>> {
        let t0 = Instant::now();
        let trigger_time = (start_seg * self.frames_per_segment) as f64 / self.fps;

        // the sliding window: the last `window_frames` frames of detection
        // records before the boundary (absolute frame indexing; early
        // boundaries reach back into the original profile window)
        let end_abs = (self.eval_start + start_seg * self.frames_per_segment)
            .min(self.scenario.n_frames());
        let window = end_abs.saturating_sub(self.window_frames)..end_abs;
        let stream = RawReid::generate(self.scenario, window, &ErrorModelParams::default());

        // drift signal on the *raw* (unfiltered) association table — one
        // linear pass, comparable with the raw baseline, and it keeps
        // skipped checks from paying the O(n²) pair fitting
        let raw_table = associate::run(&stream, &self.tiling).table;
        let mut st = self.state.lock().unwrap();
        if st.prev_constraints.is_none() {
            // first check: derive the drift baseline from the initial
            // profile window (the plan the epoch-0 masks were solved on)
            let baseline = RawReid::generate(
                self.scenario,
                self.scenario.profile_range(),
                &ErrorModelParams::default(),
            );
            st.prev_constraints =
                Some(constraint_set(&associate::run(&baseline, &self.tiling).table));
        }
        let drift =
            constraint_drift(&raw_table, st.prev_constraints.as_ref().expect("just seeded"));
        let fire = match self.policy {
            ReplanPolicy::Never => false,
            ReplanPolicy::Every(_) => true,
            ReplanPolicy::Drift { threshold, .. } => drift >= threshold,
        };
        if !fire {
            // carried forward: the drift baseline intentionally stays the
            // window the *current masks* were solved on, so slow cumulative
            // drift accumulates until it crosses the threshold
            st.records.push(ReplanRecord {
                epoch: k,
                start_seg,
                trigger_time,
                seconds: t0.elapsed().as_secs_f64(),
                replanned: false,
                warm: false,
                constraint_drift: drift,
                mask_churn: 0.0,
                solver: "carried",
                n_constraints: raw_table.n_constraints(),
                mask_tiles: prev.mask_tiles,
            });
            return Ok(prev.clone());
        }

        // full quality path for the fired re-plan: tandem filters, then
        // the association table the solver actually covers
        let frame = (self.tiling.frame_w as f64, self.tiling.frame_h as f64);
        let filtered = filter::run_scoped(
            stream,
            self.sys,
            &self.method,
            self.opts.effective_threads(),
            None,
            frame,
        );
        let assoc = associate::run(&filtered.stream, &self.tiling);
        // Re-plan windows are solved as one unsharded instance, so the
        // exact certifier's per-shard cap that admitted the *initial* plan
        // may refuse the global window table here.  A run that planned
        // successfully offline must not die mid-flight on that: degrade
        // the epoch to the (never-failing) greedy solver and record which
        // solver actually produced the masks.
        let solver = match self.opts.solver.validate(&assoc.table) {
            Ok(()) => self.opts.solver.build(),
            Err(_) => SolverKind::Greedy.build(),
        };
        let warm = drift <= FRESH_SOLVE_DRIFT;
        let solved = if warm {
            solve::run_incremental(&assoc.table, solver.as_ref(), &st.prev_solution)
        } else {
            solve::run(&assoc.table, solver.as_ref())
        };
        let churn = mask_churn(&st.prev_solution.tiles, &solved.solution.tiles);
        let grouped = group::run(&solved.masks, self.method.uses_merging());
        let use_roi: Vec<bool> = (0..self.tiling.n_cameras)
            .map(|c| use_roi_path(&self.method, grouped.blocks[c].len(), self.n_infer_blocks))
            .collect();
        let mask_tiles = solved.masks.total_size();
        let epoch = Arc::new(PlanEpoch {
            groups: grouped.groups,
            blocks: grouped.blocks,
            use_roi,
            mask_tiles,
        });
        st.prev_constraints = Some(constraint_set(&raw_table));
        st.prev_solution = solved.solution;
        st.records.push(ReplanRecord {
            epoch: k,
            start_seg,
            trigger_time,
            seconds: t0.elapsed().as_secs_f64(),
            replanned: true,
            warm,
            constraint_drift: drift,
            mask_churn: churn,
            solver: solver.name(),
            n_constraints: raw_table.n_constraints(),
            mask_tiles,
        });
        Ok(epoch)
    }
}

/// The global tile set of per-camera masks, as a warm-start seed.
fn solution_of(masks: &RoiMasks) -> Solution {
    let mut tiles: HashSet<GlobalTile> = HashSet::new();
    for cam in 0..masks.tiling.n_cameras {
        for &(tx, ty) in &masks.tiles[cam] {
            tiles.insert(masks.tiling.tile_id(cam, tx, ty));
        }
    }
    Solution { tiles, unsatisfiable: 0 }
}

fn constraint_set(table: &AssociationTable) -> HashSet<Constraint> {
    table.constraints.iter().cloned().collect()
}

/// Fraction of `table`'s constraints absent from `prev` (0.0 for an empty
/// table — nothing to cover means nothing drifted).
fn constraint_drift(table: &AssociationTable, prev: &HashSet<Constraint>) -> f64 {
    if table.constraints.is_empty() {
        return 0.0;
    }
    let novel = table.constraints.iter().filter(|c| !prev.contains(*c)).count();
    novel as f64 / table.constraints.len() as f64
}

/// Jaccard distance between two global tile sets (0.0 = identical masks).
fn mask_churn(a: &HashSet<GlobalTile>, b: &HashSet<GlobalTile>) -> f64 {
    if a.is_empty() && b.is_empty() {
        return 0.0;
    }
    let inter = a.intersection(b).count();
    let union = a.len() + b.len() - inter;
    1.0 - inter as f64 / union as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::offline::build_plan;

    fn table_from(regions: Vec<Vec<Vec<GlobalTile>>>) -> AssociationTable {
        let n = regions.len();
        AssociationTable {
            tiling: Tiling::new(1, 320, 192, 16),
            constraints: regions.into_iter().map(|r| Constraint { regions: r }).collect(),
            multiplicity: vec![1; n],
            total_occurrences: n,
        }
    }

    #[test]
    fn constraint_drift_counts_novel_constraints() {
        let a = table_from(vec![vec![vec![1, 2]], vec![vec![3]]]);
        let prev = constraint_set(&a);
        // same table: no drift
        assert_eq!(constraint_drift(&a, &prev), 0.0);
        // one kept, one new: half the window is novel
        let b = table_from(vec![vec![vec![1, 2]], vec![vec![9]]]);
        assert!((constraint_drift(&b, &prev) - 0.5).abs() < 1e-12);
        // empty window: nothing to cover, nothing drifted
        let empty = table_from(vec![]);
        assert_eq!(constraint_drift(&empty, &prev), 0.0);
        // empty baseline: everything is novel
        assert_eq!(constraint_drift(&a, &HashSet::new()), 1.0);
    }

    #[test]
    fn mask_churn_is_jaccard_distance() {
        let a: HashSet<GlobalTile> = [1, 2, 3].into_iter().collect();
        let b: HashSet<GlobalTile> = [2, 3, 4].into_iter().collect();
        assert_eq!(mask_churn(&a, &a), 0.0);
        assert!((mask_churn(&a, &b) - 0.5).abs() < 1e-12);
        assert_eq!(mask_churn(&HashSet::new(), &HashSet::new()), 0.0);
        assert_eq!(mask_churn(&a, &HashSet::new()), 1.0);
    }

    #[test]
    fn replanner_epoch_on_a_static_window_keeps_the_plan_small() {
        // no drift scenario: the re-planner must still produce a valid
        // epoch whose masks stay in the same ballpark as the initial plan,
        // via the warm-started path
        let cfg = Config::test_small();
        let scenario = Scenario::build(&cfg.scenario);
        let method = Method::CrossRoi;
        let plan = build_plan(&scenario, &cfg.scenario, &cfg.system, &method).unwrap();
        let rp = Replanner::new(
            &scenario,
            &cfg.system,
            &method,
            OfflineOptions::default(),
            ReplanPolicy::Every(2),
            5,
            &plan,
            60,
        );
        let epoch0 = Arc::new(PlanEpoch {
            groups: plan.groups.clone(),
            blocks: plan.blocks.clone(),
            use_roi: vec![true; scenario.cameras.len()],
            mask_tiles: plan.masks.total_size(),
        });
        let next = rp.plan_epoch(1, 2, &epoch0).unwrap();
        assert_eq!(next.groups.len(), scenario.cameras.len());
        assert!(next.mask_tiles > 0);
        let records = rp.records();
        assert_eq!(records.len(), 1);
        assert!(records[0].replanned);
        assert!(records[0].warm, "low-drift window must warm-start");
        assert!(records[0].seconds >= 0.0);
        assert_eq!(records[0].start_seg, 2);
        assert_eq!(records[0].solver, "greedy");
    }

    #[test]
    fn drift_policy_below_threshold_carries_the_plan_forward() {
        let cfg = Config::test_small();
        let scenario = Scenario::build(&cfg.scenario);
        let method = Method::CrossRoi;
        let plan = build_plan(&scenario, &cfg.scenario, &cfg.system, &method).unwrap();
        let rp = Replanner::new(
            &scenario,
            &cfg.system,
            &method,
            OfflineOptions::default(),
            // threshold above 1.0 can never fire
            ReplanPolicy::Drift { check_every: 2, threshold: 1.1 },
            5,
            &plan,
            60,
        );
        let epoch0 = Arc::new(PlanEpoch {
            groups: plan.groups.clone(),
            blocks: plan.blocks.clone(),
            use_roi: vec![true; scenario.cameras.len()],
            mask_tiles: plan.masks.total_size(),
        });
        let next = rp.plan_epoch(1, 2, &epoch0).unwrap();
        assert!(Arc::ptr_eq(&next, &epoch0), "plan must be carried forward by pointer");
        let records = rp.records();
        assert_eq!(records.len(), 1);
        assert!(!records[0].replanned);
        assert_eq!(records[0].mask_churn, 0.0);
        assert_eq!(records[0].solver, "carried");
    }
}
