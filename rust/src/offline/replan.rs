// lint: wall-clock-file — Instant readings here feed ReplanRecord /
// ComponentRecord timing fields (`seconds`, `queue_wait`, `done_at`) and
// the planner-pool stats, all zeroed by `MethodReport::zero_wall_clock`
// before byte-comparison (rust/tests/report_shape.rs pins the inventory).

//! Continuous re-profiling — the offline planner's side of the loop
//! (DESIGN.md §7–§8): turn sliding profile windows into warm-started,
//! **component-incremental** plans.
//!
//! The paper's offline/online split assumes the cross-camera correlation
//! profile stays valid, but §3.1 concedes traffic patterns drift and the
//! RoI masks must be periodically re-derived (ReXCam adapts its learned
//! correlation model online the same way).  [`Replanner`] implements
//! [`EpochPlanner`] for the pipeline runner: at each epoch boundary it
//! re-profiles a **sliding window** of the most recent
//! `profile_secs`-worth of detection records and rebuilds the raw
//! association table.
//!
//! Under the default [`ReplanScope::Component`], the window is first
//! partitioned into **co-occurrence components** (the same union-find as
//! [`crate::offline::shard`]; cross-camera correlations are spatially
//! local — ReXCam, arXiv:1811.01268) and every decision is made *per
//! component*: constraint drift, the fire/carry choice, the tandem
//! filters (intra-component pairs only), and the solve — decomposed
//! further along the bridge-camera constraint spill
//! ([`crate::offline::shard::spill`]) and **warm-started** from the
//! previous solution ([`crate::roi::setcover::Solver::resolve`] via
//! [`solve::solve_spilled`]) unless the component drifted past
//! [`FRESH_SOLVE_DRIFT`].  Quiescent components carry their cameras'
//! previous tiles forward untouched; if *no* component fires, the whole
//! previous epoch is carried forward by `Arc` pointer.  A camera
//! *moving* between components mid-run (the **component diff**) forces a
//! fresh solve of both its donor and its recipient component.
//! [`ReplanScope::Fleet`] degenerates to one fleet-wide pseudo-component
//! — the historical all-or-nothing behaviour.
//!
//! The drift signal is the **constraint drift**: the fraction of a
//! window's (deduplicated, raw) association constraints absent from the
//! table the current masks were solved on.  It is a pure function of the
//! window — never of pipeline timing — so re-plan decisions, and with
//! them the whole run, stay byte-identical across thread counts
//! (`rust/tests/replan.rs`, `rust/tests/component_replan.rs`).

use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;
use once_cell::sync::OnceCell;

use crate::association::table::{AssociationTable, Constraint};
use crate::association::tiles::{GlobalTile, Tiling};
use crate::config::SystemConfig;
use crate::coordinator::method::Method;
use crate::offline::solve::SolverKind;
use crate::offline::{associate, filter, group, shard, solve, OfflineOptions, OfflinePlan};
use crate::pipeline::infer::use_roi_path;
use crate::pipeline::replan::{EpochPlanner, FaultTimeline, PlanEpoch, ReplanPolicy, ReplanScope};
use crate::reid::error_model::{ErrorModelParams, RawReid};
use crate::roi::masks::RoiMasks;
use crate::roi::setcover::Solution;
use crate::sim::Scenario;
use crate::util::geometry::IRect;
use crate::util::json::Json;
use crate::util::parallel::{ordered_map, PoolGauge};
use crate::util::sync::StateCell;

/// Above this constraint drift a warm seed reuses too little to pay for
/// itself (most seeded tiles are stale and only burden the prune pass);
/// the re-plan falls back to a from-scratch solve.  Applied per
/// component under [`ReplanScope::Component`].
pub const FRESH_SOLVE_DRIFT: f64 = 0.6;

/// One re-plan component's outcome at one epoch boundary.
#[derive(Debug, Clone)]
pub struct ComponentRecord {
    /// Cameras of this co-occurrence component, ascending.  Under
    /// [`ReplanScope::Fleet`] there is exactly one component covering
    /// every camera.
    pub cameras: Vec<usize>,
    /// Fraction of the component's window constraints absent from the
    /// drift baseline.
    pub drift: f64,
    /// Whether this component was re-solved (false = its cameras'
    /// previous tiles were carried forward).
    pub fired: bool,
    /// Whether the executed solve warm-started from the previous
    /// solution (always false when not fired).
    pub warm: bool,
    /// Whether a camera moved into or out of this component since the
    /// last check — migration always fires and always solves fresh.
    pub migrated: bool,
    /// Tile-connected spill groups the component's solve decomposed into
    /// (0 when carried).
    pub spill_groups: usize,
    /// The component's constraints in the raw window table.
    pub n_constraints: usize,
    /// Solver that produced the component's masks ("carried" when not
    /// fired; may be "greedy" under `--solver exact` when the window
    /// instance exceeded the certifier's per-group cap).
    pub solver: &'static str,
    /// Measured wall seconds of this component's filter → associate →
    /// spill → solve, on whichever pool worker ran it (0.0 when carried).
    /// Wall-clock: zeroed by `MethodReport::zero_wall_clock` before
    /// byte-comparison.
    pub seconds: f64,
    /// Wall seconds this component's solve waited between the epoch
    /// fan-out and a pool worker picking it up (0.0 when carried).
    pub queue_wait: f64,
}

/// One epoch boundary's outcome — a check that may or may not have fired
/// for some (or all) of its components.
#[derive(Debug, Clone)]
pub struct ReplanRecord {
    /// Planning epoch (≥ 1; epoch 0 is the initial offline plan).
    pub epoch: usize,
    /// First segment index the epoch's plan applies to.
    pub start_seg: usize,
    /// Virtual time of the epoch boundary (seconds, eval-window origin —
    /// the DES clock).
    pub trigger_time: f64,
    /// Measured wall seconds of this check: window ReID + raw associate
    /// for the drift signal, plus filter + associate + solve + group for
    /// every fired component.  The *first* check additionally carries the
    /// one-time drift-baseline derivation (a profile-window ReID +
    /// associate pass) — the first re-plan genuinely completes that much
    /// later, so its DES timestamp includes it.
    pub seconds: f64,
    /// Whether any component fired (false = every component — and the
    /// whole plan, by pointer — was carried forward untouched).
    pub replanned: bool,
    /// Whether every executed component solve warm-started from the
    /// previous solution (false when none fired).
    pub warm: bool,
    /// Fleet-wide constraint drift: the fraction of the window's
    /// constraints absent from the drift baseline.
    pub constraint_drift: f64,
    /// Jaccard distance between the previous and new global tile sets
    /// (0.0 when not replanned).
    pub mask_churn: f64,
    /// Solver that produced this epoch's masks ("carried" when nothing
    /// fired; "greedy" when any `--solver exact` component degraded).
    pub solver: &'static str,
    /// Constraints in the window's *raw* (unfiltered) association table —
    /// the same series the drift signal is computed on, for carried and
    /// fired checks alike (the tandem-filtered tables the solver covers
    /// are smaller).
    pub n_constraints: usize,
    /// |M| after this boundary.
    pub mask_tiles: usize,
    /// Scope the check ran under ("fleet" | "component").
    pub scope: &'static str,
    /// Per-component outcomes, in component order (one pseudo-component
    /// under [`ReplanScope::Fleet`]).
    pub components: Vec<ComponentRecord>,
    /// Cameras whose Reducto frame-filter threshold was re-derived from
    /// the sliding window because this re-plan changed their regions
    /// (0 for methods without frame filtering).
    pub reducto_rederived: usize,
}

impl ReplanRecord {
    /// Components re-solved at this boundary.
    pub fn fired_components(&self) -> usize {
        self.components.iter().filter(|c| c.fired).count()
    }

    /// Components checked but carried forward at this boundary.
    pub fn carried_components(&self) -> usize {
        self.components.iter().filter(|c| !c.fired).count()
    }

    /// Components whose camera membership changed at this boundary.
    pub fn migrated_components(&self) -> usize {
        self.components.iter().filter(|c| c.migrated).count()
    }

    /// Full record as JSON — nested under `replan_records` in the
    /// `MethodReport` dump.  `seconds` is wall-clock; determinism tests
    /// zero it via `MethodReport::zero_wall_clock` before byte-comparing.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("epoch", Json::Num(self.epoch as f64)),
            ("start_seg", Json::Num(self.start_seg as f64)),
            ("trigger_time", Json::Num(self.trigger_time)),
            ("seconds", Json::Num(self.seconds)),
            ("replanned", Json::Bool(self.replanned)),
            ("warm", Json::Bool(self.warm)),
            ("constraint_drift", Json::Num(self.constraint_drift)),
            ("mask_churn", Json::Num(self.mask_churn)),
            ("solver", Json::Str(self.solver.to_string())),
            ("n_constraints", Json::Num(self.n_constraints as f64)),
            ("mask_tiles", Json::Num(self.mask_tiles as f64)),
            ("scope", Json::Str(self.scope.to_string())),
            (
                "components",
                Json::Arr(self.components.iter().map(ComponentRecord::to_json).collect()),
            ),
            ("reducto_rederived", Json::Num(self.reducto_rederived as f64)),
        ])
    }
}

impl ComponentRecord {
    /// One component's disposition as JSON (see [`ReplanRecord::to_json`]).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            (
                "cameras",
                Json::Arr(self.cameras.iter().map(|&c| Json::Num(c as f64)).collect()),
            ),
            ("drift", Json::Num(self.drift)),
            ("fired", Json::Bool(self.fired)),
            ("warm", Json::Bool(self.warm)),
            ("migrated", Json::Bool(self.migrated)),
            ("spill_groups", Json::Num(self.spill_groups as f64)),
            ("n_constraints", Json::Num(self.n_constraints as f64)),
            ("solver", Json::Str(self.solver.to_string())),
            ("seconds", Json::Num(self.seconds)),
            ("queue_wait", Json::Num(self.queue_wait)),
        ])
    }
}

/// One fault obligation's outcome: what the repair (or rejoin) epoch's
/// re-solve did about a dead camera's orphaned coverage.  Serialized
/// under `repair_records` in the `MethodReport` dump; `seconds` is
/// wall-clock and zeroed by `MethodReport::zero_wall_clock`.
#[derive(Debug, Clone)]
pub struct RepairRecord {
    /// The failed (or rejoining) camera.
    pub cam: usize,
    /// "dropout" (coverage repair after a silence) or "rejoin"
    /// (re-admission with a re-derived frame-filter threshold).
    pub kind: &'static str,
    /// Fault onset (eval-window seconds, from the config).
    pub fail_secs: f64,
    /// When the segment-deadline liveness monitor could first know: the
    /// first missed segment's deadline.
    pub detect_secs: f64,
    /// `detect_secs - fail_secs`.
    pub detect_latency: f64,
    /// Planning epoch this record's re-solve ran at.
    pub epoch: usize,
    /// Epochs between the boundary current at detection (re-admission
    /// for rejoins) and this re-solve — 1 for every repair that lands.
    pub repair_latency_epochs: usize,
    /// Tiles the dead camera owned in the previous solution (what the
    /// failure orphaned).  0 for rejoins.
    pub orphaned_tiles: usize,
    /// Dropout: tiles the re-solve newly placed on surviving cameras.
    /// Rejoin: tiles the re-admitted camera owns again.
    pub recovered_tiles: usize,
    /// Appearance groups in the (unfiltered) window visible *only* to
    /// currently-dead cameras — coverage no live camera can take over,
    /// recorded rather than silently lost.
    pub uncovered_constraints: usize,
    /// Wall seconds of the epoch that executed this repair (zeroed by
    /// `zero_wall_clock`).
    pub seconds: f64,
}

impl RepairRecord {
    /// Full record as JSON — nested under `repair_records` in the
    /// `MethodReport` dump.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("cam", Json::Num(self.cam as f64)),
            ("kind", Json::Str(self.kind.to_string())),
            ("fail_secs", Json::Num(self.fail_secs)),
            ("detect_secs", Json::Num(self.detect_secs)),
            ("detect_latency", Json::Num(self.detect_latency)),
            ("epoch", Json::Num(self.epoch as f64)),
            ("repair_latency_epochs", Json::Num(self.repair_latency_epochs as f64)),
            ("orphaned_tiles", Json::Num(self.orphaned_tiles as f64)),
            ("recovered_tiles", Json::Num(self.recovered_tiles as f64)),
            ("uncovered_constraints", Json::Num(self.uncovered_constraints as f64)),
            ("seconds", Json::Num(self.seconds)),
        ])
    }
}

/// Chained re-plan state: everything epoch `k` inherits from `k - 1`.
struct ReplanState {
    prev_solution: Solution,
    /// *Raw* (unfiltered) constraint set of the window(s) the current
    /// masks were solved on — the drift baseline.  Raw-vs-raw keeps the
    /// signal comparable across checks and free of the O(n²) pair
    /// fitting.  `None` until the first check derives the initial
    /// profile window's baseline — lazily, on the planner thread, so the
    /// extra linear ReID + associate pass overlaps the pipeline instead
    /// of delaying its start (the offline plan does not retain its
    /// profile stream).  Fired components replace their share of the
    /// baseline; quiescent ones keep accumulating drift against theirs.
    /// Behind an `Arc` so an epoch's compute phase can snapshot it by
    /// pointer under a brief lock instead of cloning the set (or holding
    /// the lock across the solves); the commit phase mutates it in place
    /// via `Arc::make_mut` after the compute phase drops its handle.
    prev_constraints: Option<Arc<HashSet<Constraint>>>,
    /// Camera partition of the baseline window — the component-diff
    /// reference a migration is detected against.  Seeded with the
    /// baseline, replaced whenever an epoch fires.
    prev_components: Vec<Vec<usize>>,
    records: Vec<ReplanRecord>,
    repair_records: Vec<RepairRecord>,
}

/// The coordinator's [`EpochPlanner`]: sliding-window, warm-started,
/// component-incremental re-profiling.  Construct once per run from the
/// initial [`OfflinePlan`], hand to
/// [`crate::pipeline::run_pipeline_with_replan`], then collect
/// [`Replanner::records`] for the report.
pub struct Replanner<'a> {
    scenario: &'a Scenario,
    sys: &'a SystemConfig,
    method: Method,
    opts: OfflineOptions,
    policy: ReplanPolicy,
    scope: ReplanScope,
    tiling: Tiling,
    /// Sliding window length in frames (= the initial profile window's).
    window_frames: usize,
    frames_per_segment: usize,
    /// Absolute frame index of the evaluation window's first frame.
    eval_start: usize,
    fps: f64,
    /// Detector block count of the inference backend (dense-fallback
    /// policy, same rule as the static plan's).
    n_infer_blocks: usize,
    /// Frame-filter accuracy target when the method runs Reducto
    /// (threshold re-derivation is skipped at target ≥ 1.0 — a disabled
    /// filter stays disabled).
    reducto_target: Option<f64>,
    /// Lazily-built renderer for threshold re-derivation, cached across
    /// epochs — construction rasterizes every camera's static
    /// background, which must not be paid per fired epoch.
    renderer: OnceCell<crate::sim::Renderer<'a>>,
    /// Worker budget for one epoch's compute phase (drift-signal profile
    /// + fired-component fan-out).  `0` falls back to the offline
    /// planner's `effective_threads`.
    planner_threads: usize,
    /// Concurrency gauge over the fired-component fan-out — feeds the
    /// planner-pool counters beside (never inside) byte-compared output.
    pool: PoolGauge,
    /// Fault schedule resolved onto the segment grid (`None` = no
    /// faults).  Repair and rejoin epochs force the affected component
    /// to fire; a currently-dead camera's window records are filtered
    /// out of the re-solve so surviving cameras re-cover its tiles.
    faults: Option<Arc<FaultTimeline>>,
    /// Epoch boundaries whose compute phase ran (carried or fired).
    epochs_computed: AtomicUsize,
    /// Chained state behind the snapshot → compute → commit protocol
    /// (`util::sync`, loom-modeled in `rust/tests/loom_epoch.rs`).
    state: StateCell<ReplanState>,
}

/// Aggregate planner-pool counters for one run — surfaced on
/// `MethodReport` and printed by `crossroi run`.  Schedule-dependent
/// diagnostics: excluded from the byte-compared JSON.
#[derive(Debug, Clone, Copy, Default)]
pub struct PlannerPoolStats {
    /// Epoch boundaries whose compute phase ran.
    pub epochs_computed: usize,
    /// Component solves dispatched to the pool (fired components only).
    pub components_solved: usize,
    /// High-water mark of component solves running simultaneously.
    pub max_concurrent: usize,
    /// Total seconds component solves waited between the epoch fan-out
    /// and a pool worker picking them up.
    pub queue_wait_secs: f64,
}

impl<'a> Replanner<'a> {
    /// Seed the re-planner from the initial offline plan.  The drift
    /// baseline (the initial profile window's raw association table and
    /// camera partition) is derived lazily at the first check, on the
    /// planner thread, so constructing a `Replanner` never delays the
    /// pipeline's start.
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        scenario: &'a Scenario,
        sys: &'a SystemConfig,
        method: &Method,
        opts: OfflineOptions,
        policy: ReplanPolicy,
        scope: ReplanScope,
        frames_per_segment: usize,
        initial: &OfflinePlan,
        n_infer_blocks: usize,
    ) -> Replanner<'a> {
        Replanner {
            scenario,
            sys,
            method: method.clone(),
            opts,
            policy,
            scope,
            window_frames: scenario.profile_range().len().max(1),
            frames_per_segment: frames_per_segment.max(1),
            eval_start: scenario.eval_range().start,
            fps: scenario.cfg.fps,
            n_infer_blocks,
            reducto_target: method.reducto_target(),
            renderer: OnceCell::new(),
            planner_threads: 0,
            pool: PoolGauge::new(),
            faults: None,
            epochs_computed: AtomicUsize::new(0),
            state: StateCell::new(ReplanState {
                prev_solution: solution_of(&initial.masks),
                prev_constraints: None,
                prev_components: Vec::new(),
                records: Vec::new(),
                repair_records: Vec::new(),
            }),
            tiling: initial.masks.tiling.clone(),
        }
    }

    /// Override the epoch compute phase's worker budget (`0` = inherit
    /// the offline planner's `effective_threads`; the default).
    pub fn with_planner_threads(mut self, threads: usize) -> Replanner<'a> {
        self.planner_threads = threads;
        self
    }

    /// Attach a resolved fault schedule: repair and rejoin epochs fire
    /// the affected component out of band (even under
    /// [`ReplanPolicy::Never`]) and dead cameras' records and tiles are
    /// excluded from the re-solve until they rejoin.
    pub fn with_faults(mut self, timeline: Arc<FaultTimeline>) -> Replanner<'a> {
        self.faults = if timeline.is_empty() { None } else { Some(timeline) };
        self
    }

    /// The compute phase's resolved worker budget.
    fn effective_planner_threads(&self) -> usize {
        if self.planner_threads == 0 {
            self.opts.effective_threads()
        } else {
            self.planner_threads
        }
    }

    /// Aggregate planner-pool counters across every epoch so far.
    pub fn pool_stats(&self) -> PlannerPoolStats {
        let s = self.pool.stats();
        PlannerPoolStats {
            epochs_computed: self.epochs_computed.load(Ordering::Relaxed),
            components_solved: s.tasks,
            max_concurrent: s.max_concurrent,
            queue_wait_secs: s.queue_wait_secs,
        }
    }

    /// Every boundary's outcome so far, in epoch order.
    pub fn records(&self) -> Vec<ReplanRecord> {
        self.state.snapshot(|st| st.records.clone())
    }

    /// Every fault obligation's repair outcome so far, in epoch order.
    pub fn repair_records(&self) -> Vec<RepairRecord> {
        self.state.snapshot(|st| st.repair_records.clone())
    }

    /// The window's camera partition under this re-planner's scope.
    fn partition_scoped(&self, stream: &crate::reid::records::ReidStream) -> Vec<Vec<usize>> {
        match self.scope {
            ReplanScope::Fleet => vec![(0..self.tiling.n_cameras).collect()],
            ReplanScope::Component => {
                shard::partition(stream).into_iter().map(|s| s.cameras).collect()
            }
        }
    }

    /// Carry previous thresholds, re-deriving each camera whose regions
    /// changed this epoch (`cam_epoch[c] == k`) from the sliding window
    /// against its **new** regions.  Methods without frame filtering (or
    /// with a disabled target ≥ 1.0) carry unchanged.
    fn rederive_thresholds(
        &self,
        prev: &PlanEpoch,
        groups: &[Vec<IRect>],
        cam_epoch: &[usize],
        k: usize,
        window: std::ops::Range<usize>,
    ) -> (Option<Vec<f64>>, usize) {
        let (prev_th, target) = match (prev.thresholds.as_ref(), self.reducto_target) {
            (Some(t), Some(target)) if target < 1.0 => (t, target),
            _ => return (prev.thresholds.clone(), 0),
        };
        let changed: Vec<usize> =
            (0..prev_th.len()).filter(|&cam| cam_epoch[cam] == k).collect();
        if changed.is_empty() {
            return (Some(prev_th.clone()), 0);
        }
        let renderer = self.renderer.get_or_init(|| self.scenario.renderer());
        let mut th = prev_th.clone();
        for &cam in &changed {
            th[cam] = crate::reducto::ReductoFilter::profile_one(
                self.scenario,
                renderer,
                cam,
                &groups[cam],
                window.clone(),
                self.frames_per_segment,
                target,
            );
        }
        (Some(th), changed.len())
    }
}

impl EpochPlanner for Replanner<'_> {
    fn plan_epoch(
        &self,
        k: usize,
        start_seg: usize,
        prev: &Arc<PlanEpoch>,
    ) -> Result<Arc<PlanEpoch>> {
        let t0 = Instant::now();
        // fault obligations landing at this boundary (a repair or rejoin
        // epoch forces its component to fire below, regardless of drift)
        let event = self.faults.as_deref().is_some_and(|t| t.has_event_at(k));
        if matches!(self.policy, ReplanPolicy::Never) && !event {
            // repair-only mode: boundaries with no fault obligation carry
            // by pointer without paying a window profile (and without
            // counting as a computed epoch or a boundary record)
            return Ok(prev.clone());
        }
        self.epochs_computed.fetch_add(1, Ordering::Relaxed);
        let threads = self.effective_planner_threads();
        let trigger_time = (start_seg * self.frames_per_segment) as f64 / self.fps;
        let n_cams = self.tiling.n_cameras;
        // cameras currently down: their window records must not anchor
        // the re-solve, and their tiles are orphaned rather than carried
        let dead_now: Vec<bool> = (0..n_cams)
            .map(|c| self.faults.as_deref().is_some_and(|t| t.down_seg(c, start_seg)))
            .collect();

        // ---- compute phase (no state lock held anywhere below until the
        // commit): snapshot → decide → solve in parallel → merge ----

        // the sliding window: the last `window_frames` frames of detection
        // records before the boundary (absolute frame indexing; early
        // boundaries reach back into the original profile window).  The
        // drift-signal profile (linear ReID + raw associate over the full
        // window) runs on the same worker budget as the component solves.
        let end_abs = (self.eval_start + start_seg * self.frames_per_segment)
            .min(self.scenario.n_frames());
        let window = end_abs.saturating_sub(self.window_frames)..end_abs;
        let stream = RawReid::generate_par(
            self.scenario,
            window.clone(),
            &ErrorModelParams::default(),
            threads,
        );
        // coverage no live camera can take over — counted on the raw
        // window before dead cameras' records are filtered out, so the
        // loss is recorded instead of silently vanishing with the filter
        let uncovered_now = if event { uncovered_groups(&stream, &dead_now) } else { 0 };
        // the sliding window reaches back across the fault onset: a dead
        // camera's pre-fault records (and a rejoined camera's records
        // from inside its own outage) would hand the solver coverage
        // that no longer exists, so both are filtered out before the
        // partition and the solves
        let stream = match self.faults.as_deref() {
            Some(t) => stream
                .filtered(|d| !dead_now[d.cam] && !t.down_frame(d.cam, window.start + d.frame)),
            None => stream,
        };

        // drift signal on the *raw* (unfiltered) association table — one
        // linear pass, comparable with the raw baseline, and it keeps
        // carried components (and skipped checks) from paying the O(n²)
        // pair fitting
        let raw_table = associate::run_par(&stream, &self.tiling, threads).table;
        let comps = self.partition_scoped(&stream);
        let mut comp_of_cam = vec![0usize; n_cams];
        for (i, comp) in comps.iter().enumerate() {
            for &c in comp {
                comp_of_cam[c] = i;
            }
        }
        // a raw constraint's cameras all co-occur, so they lie inside one
        // component — route it by any of its tiles
        let mut comp_constraints: Vec<Vec<usize>> = vec![Vec::new(); comps.len()];
        for (ci, c) in raw_table.constraints.iter().enumerate() {
            if let Some(cam) = first_camera(c, &self.tiling) {
                comp_constraints[comp_of_cam[cam]].push(ci);
            }
        }

        // first check: derive the drift baseline (constraints + camera
        // partition) from the initial profile window — the window the
        // epoch-0 masks were solved on.  Derived *outside* the lock (the
        // pass is a full profile-window ReID + associate) and installed
        // under it.
        let needs_baseline = self.state.snapshot(|st| st.prev_constraints.is_none());
        let seeded = if needs_baseline {
            let baseline_stream = RawReid::generate_par(
                self.scenario,
                self.scenario.profile_range(),
                &ErrorModelParams::default(),
                threads,
            );
            let parts = self.partition_scoped(&baseline_stream);
            let set = constraint_set(
                &associate::run_par(&baseline_stream, &self.tiling, threads).table,
            );
            Some((parts, Arc::new(set)))
        } else {
            None
        };

        // snapshot under a brief lock: the baseline by `Arc` pointer, the
        // previous solution and partition by value.  The sequential loop
        // never mutated any of these mid-epoch, so decisions and solves
        // made against the snapshot are byte-identical to its output.
        let (prev_solution, baseline, prev_components) = self.state.snapshot(|st| {
            if let Some((parts, set)) = seeded {
                st.prev_components = parts;
                st.prev_constraints = Some(set);
            }
            (
                st.prev_solution.clone(),
                Arc::clone(st.prev_constraints.as_ref().expect("seeded above")),
                st.prev_components.clone(),
            )
        });
        let drift = constraint_drift(&raw_table, &baseline);
        let comp_drift: Vec<f64> = comp_constraints
            .iter()
            .map(|idxs| {
                if idxs.is_empty() {
                    return 0.0;
                }
                let novel = idxs
                    .iter()
                    .filter(|&&ci| !baseline.contains(&raw_table.constraints[ci]))
                    .count();
                novel as f64 / idxs.len() as f64
            })
            .collect();
        let migrated: Vec<bool> = comps
            .iter()
            .map(|comp| component_migrated(&prev_components, comp))
            .collect();
        // whether a component's cameras still hold any mask tiles — an
        // *empty* window component only needs a (trivial) re-solve when
        // there are stale tiles to clear; otherwise firing it would be a
        // pure no-op and would inflate the re-solve count
        let mut comp_has_tiles = vec![false; comps.len()];
        // lint: order-insensitive — only sets idempotent flags
        for &t in &prev_solution.tiles {
            comp_has_tiles[comp_of_cam[self.tiling.camera_of(t)]] = true;
        }
        // repair / rejoin obligations: the affected camera's component
        // must fire at this boundary regardless of drift
        let mut force_cam = vec![false; n_cams];
        if let Some(t) = self.faults.as_deref() {
            for &c in t.force_fire_cams(k) {
                if c < n_cams {
                    force_cam[c] = true;
                }
            }
        }
        let fired: Vec<bool> = (0..comps.len())
            .map(|i| {
                fire_decision(
                    self.policy,
                    migrated[i],
                    comp_drift[i],
                    !comp_constraints[i].is_empty(),
                    comp_has_tiles[i],
                ) || comps[i].iter().any(|&c| force_cam[c])
            })
            .collect();

        if !fired.iter().any(|&f| f) && !event {
            // fully carried: the drift baseline intentionally stays the
            // window(s) the *current masks* were solved on, so slow
            // cumulative drift accumulates until it crosses the threshold
            let components = comps
                .iter()
                .enumerate()
                .map(|(i, comp)| ComponentRecord {
                    cameras: comp.clone(),
                    drift: comp_drift[i],
                    fired: false,
                    warm: false,
                    migrated: migrated[i],
                    spill_groups: 0,
                    n_constraints: comp_constraints[i].len(),
                    solver: "carried",
                    seconds: 0.0,
                    queue_wait: 0.0,
                })
                .collect();
            self.state.commit(|st| st.records.push(ReplanRecord {
                epoch: k,
                start_seg,
                trigger_time,
                seconds: t0.elapsed().as_secs_f64(),
                replanned: false,
                warm: false,
                constraint_drift: drift,
                mask_churn: 0.0,
                solver: "carried",
                n_constraints: raw_table.n_constraints(),
                mask_tiles: prev.mask_tiles,
                scope: self.scope.name(),
                components,
                reducto_rederived: 0,
            }));
            return Ok(prev.clone());
        }

        // ---- fired path: full quality pipeline per fired component,
        // fanned out over the shared worker pool ----
        let mut fired_cam = vec![false; n_cams];
        for (i, comp) in comps.iter().enumerate() {
            if fired[i] {
                for &c in comp {
                    fired_cam[c] = true;
                }
            }
        }
        // quiescent components carry their cameras' previous tiles
        // forward untouched (tiles are camera-owned, components are
        // camera-disjoint — the carry is exact)
        let mut tiles: HashSet<GlobalTile> = prev_solution
            .tiles
            .iter()
            .copied()
            .filter(|&t| {
                let cam = self.tiling.camera_of(t);
                !fired_cam[cam] && !dead_now[cam]
            })
            .collect();
        let frame = (self.tiling.frame_w as f64, self.tiling.frame_h as f64);

        // one pool task per fired component.  The worker budget inside a
        // component (its pair fitting) is split by pair count — the same
        // weighting as the static plan's shard split — so a lone big
        // component still saturates the pool.  `ordered_map` returns the
        // solves in `fired_idx` order, so the merge below is a plain
        // sequential fold in component order, byte-identical to the old
        // in-loop solve at every thread count.
        let fired_idx: Vec<usize> = (0..comps.len()).filter(|&i| fired[i]).collect();
        let pair_count = |i: usize| comps[i].len() * comps[i].len().saturating_sub(1);
        let total_pairs: usize = fired_idx.iter().map(|&i| pair_count(i)).sum();
        let queued_at = Instant::now();
        let solves = ordered_map(&fired_idx, threads, |&i| {
            let queue_wait = queued_at.elapsed().as_secs_f64();
            self.pool.track(queued_at, || {
                let t_comp = Instant::now();
                let comp = &comps[i];
                let inner = (threads * pair_count(i) / total_pairs.max(1)).max(1);
                // tandem filters over this component's substream only
                // (intra-component pairs — identical to the fleet-wide
                // filter restricted to these cameras), then association
                // and the spilled, warm-started solve
                let sub = shard::Shard { cameras: comp.clone() }.substream(&stream);
                let filtered =
                    filter::run_scoped(sub, self.sys, &self.method, inner, Some(comp), frame);
                let assoc = associate::run(&filtered.stream, &self.tiling);
                let sp = shard::spill(&assoc.table);
                let warm = warm_decision(migrated[i], comp_drift[i]);
                let seed = if warm { Some(&prev_solution) } else { None };
                // A run that planned successfully offline must not die
                // mid-flight because `--solver exact` meets an oversized
                // window instance: degrade the component to the
                // (never-failing) greedy solver and record it.
                let (solution, solver, degraded) =
                    match solve::solve_spilled(&assoc.table, self.opts.solver, seed, &sp) {
                        Ok(s) => (s, self.opts.solver.name(), false),
                        Err(_) => match solve::solve_spilled(
                            &assoc.table,
                            SolverKind::Greedy,
                            seed,
                            &sp,
                        ) {
                            Ok(s) => (s, SolverKind::Greedy.name(), true),
                            // no solver could take the window (however it
                            // got malformed): carry the component's
                            // previous tiles forward and record it — a
                            // planner-thread panic here would kill every
                            // subsequent epoch of the run
                            Err(_) => {
                                let mut s =
                                    component_carry(&prev_solution, comp, &self.tiling);
                                s.tiles.retain(|&t| !dead_now[self.tiling.camera_of(t)]);
                                (s, "degraded-carry", true)
                            }
                        },
                    };
                ComponentSolve {
                    tiles: solution.tiles,
                    spill_groups: sp.groups.len(),
                    warm,
                    solver,
                    degraded,
                    seconds: t_comp.elapsed().as_secs_f64(),
                    queue_wait,
                }
            })
        });

        // merge in deterministic component order (carried components
        // interleave with fired ones exactly as the sequential loop did)
        let mut solves = solves.into_iter();
        let mut components: Vec<ComponentRecord> = Vec::with_capacity(comps.len());
        // a fault event with nothing to fire (e.g. a dead camera whose
        // whole component vanished from the window) still rebuilds masks
        // — the dead tiles must clear — but records itself as carried
        let any_fired = !fired_idx.is_empty();
        let mut all_warm = true;
        let mut degraded = false;
        for (i, comp) in comps.iter().enumerate() {
            if !fired[i] {
                components.push(ComponentRecord {
                    cameras: comp.clone(),
                    drift: comp_drift[i],
                    fired: false,
                    warm: false,
                    migrated: migrated[i],
                    spill_groups: 0,
                    n_constraints: comp_constraints[i].len(),
                    solver: "carried",
                    seconds: 0.0,
                    queue_wait: 0.0,
                });
                continue;
            }
            let s = solves.next().expect("one solve per fired component");
            all_warm &= s.warm;
            degraded |= s.degraded;
            // lint: order-insensitive — set-to-set union
            tiles.extend(s.tiles.iter().copied());
            components.push(ComponentRecord {
                cameras: comp.clone(),
                drift: comp_drift[i],
                fired: true,
                warm: s.warm,
                migrated: migrated[i],
                spill_groups: s.spill_groups,
                n_constraints: comp_constraints[i].len(),
                solver: s.solver,
                seconds: s.seconds,
                queue_wait: s.queue_wait,
            });
        }

        let masks = RoiMasks::from_solution(&self.tiling, &tiles);
        let churn = mask_churn(&prev_solution.tiles, &tiles);
        let grouped = group::run(&masks, self.method.uses_merging());
        let use_roi: Vec<bool> = (0..n_cams)
            .map(|c| use_roi_path(&self.method, grouped.blocks[c].len(), self.n_infer_blocks))
            .collect();
        // content-compared epoch stamps: only cameras whose regions
        // actually changed swap codec/filter state downstream — cameras
        // of carried components keep their encoder motion reference
        let cam_epoch: Vec<usize> = (0..n_cams)
            .map(|c| if grouped.groups[c] == prev.groups[c] { prev.cam_epoch[c] } else { k })
            .collect();
        let (thresholds, rederived) =
            self.rederive_thresholds(prev, &grouped.groups, &cam_epoch, k, window);

        let mask_tiles = masks.total_size();
        let epoch = Arc::new(PlanEpoch {
            groups: grouped.groups,
            blocks: grouped.blocks,
            use_roi,
            cam_epoch,
            thresholds,
            mask_tiles,
        });

        // repair bookkeeping: each fault obligation landing at this
        // boundary gets a record of what the re-solve did about it —
        // pure functions of the solutions on either side of the solve,
        // so the records are byte-identical across thread counts
        let mut repairs: Vec<RepairRecord> = Vec::new();
        if let Some(t) = self.faults.as_deref() {
            let ce = t.check_every().max(1);
            for s in t.repairs_at(k) {
                let orphaned = prev_solution
                    .tiles
                    .iter()
                    .filter(|&&g| self.tiling.camera_of(g) == s.cam)
                    .count();
                // tiles the re-solve newly placed on surviving cameras —
                // the orphaned coverage live peers took over
                let recovered = tiles
                    .iter()
                    .filter(|&&g| {
                        self.tiling.camera_of(g) != s.cam && !prev_solution.tiles.contains(&g)
                    })
                    .count();
                repairs.push(RepairRecord {
                    cam: s.cam,
                    kind: "dropout",
                    fail_secs: s.fail_secs,
                    detect_secs: s.detect_secs,
                    detect_latency: s.detect_latency,
                    epoch: k,
                    repair_latency_epochs: s.repair_latency_epochs(ce),
                    orphaned_tiles: orphaned,
                    recovered_tiles: recovered,
                    uncovered_constraints: uncovered_now,
                    seconds: t0.elapsed().as_secs_f64(),
                });
            }
            for s in t.rejoins_at(k) {
                let readmitted =
                    tiles.iter().filter(|&&g| self.tiling.camera_of(g) == s.cam).count();
                repairs.push(RepairRecord {
                    cam: s.cam,
                    kind: "rejoin",
                    fail_secs: s.fail_secs,
                    detect_secs: s.detect_secs,
                    detect_latency: s.detect_latency,
                    epoch: k,
                    repair_latency_epochs: s.up_from.map_or(0, |u| k.saturating_sub(u / ce)),
                    orphaned_tiles: 0,
                    recovered_tiles: readmitted,
                    uncovered_constraints: uncovered_now,
                    seconds: t0.elapsed().as_secs_f64(),
                });
            }
        }

        // ---- commit phase, one atomic `StateCell::commit`: baseline
        // update (fired components adopt their window constraints and
        // the new partition becomes the component-diff reference;
        // quiescent components keep accumulating drift), solution, and
        // record — all inside one closure, so a concurrent `records()`
        // snapshot can never observe the record without its baseline
        // update (the invariant the loom model checks).  The compute
        // snapshot's `Arc` is dropped first so `Arc::make_mut` mutates
        // the shared set in place.
        drop(baseline);
        self.state.commit(|st| {
            let base = Arc::make_mut(st.prev_constraints.as_mut().expect("seeded above"));
            base.retain(|c| baseline_keeps(c, &self.tiling, &fired_cam));
            for (i, idxs) in comp_constraints.iter().enumerate() {
                if fired[i] {
                    for &ci in idxs {
                        base.insert(raw_table.constraints[ci].clone());
                    }
                }
            }
            st.prev_components = comps;
            st.prev_solution = Solution { tiles, unsatisfiable: 0 };
            st.repair_records.extend(repairs);
            st.records.push(ReplanRecord {
                epoch: k,
                start_seg,
                trigger_time,
                seconds: t0.elapsed().as_secs_f64(),
                replanned: any_fired,
                warm: any_fired && all_warm,
                constraint_drift: drift,
                mask_churn: churn,
                solver: if !any_fired {
                    "carried"
                } else if degraded {
                    SolverKind::Greedy.name()
                } else {
                    self.opts.solver.name()
                },
                n_constraints: raw_table.n_constraints(),
                mask_tiles,
                scope: self.scope.name(),
                components,
                reducto_rederived: rederived,
            });
        });
        Ok(epoch)
    }
}

/// One fired component's solve output, produced on a pool worker and
/// merged sequentially in component order by the epoch's commit.
struct ComponentSolve {
    tiles: HashSet<GlobalTile>,
    spill_groups: usize,
    warm: bool,
    solver: &'static str,
    degraded: bool,
    seconds: f64,
    queue_wait: f64,
}

/// Whether the baseline keeps a constraint after the components over
/// `fired_cam` re-solved: fired components' constraints are replaced
/// wholesale by their window's.  Tile-less rows are dropped too — they
/// route to no component, so the old `map_or(true, ..)` rule kept them
/// forever; they can never be covered or drift, and only grew the
/// baseline without bound.
fn baseline_keeps(c: &Constraint, tiling: &Tiling, fired_cam: &[bool]) -> bool {
    first_camera(c, tiling).is_some_and(|cam| !fired_cam[cam])
}

/// Last-resort fallback when every solver rejected a fired component's
/// window: the previous solution restricted to the component's cameras.
/// Exact for the same reason the quiescent carry is — tiles are
/// camera-owned and components are camera-disjoint — so the component
/// keeps streaming its stale (but valid) RoIs instead of killing the
/// planner thread.
fn component_carry(prev: &Solution, comp: &[usize], tiling: &Tiling) -> Solution {
    let tiles = prev
        .tiles
        .iter()
        .copied()
        .filter(|&t| comp.contains(&tiling.camera_of(t)))
        .collect();
    Solution { tiles, unsatisfiable: 0 }
}

/// Distinct appearance groups (same frame, same raw identity) in the raw
/// window whose every record sits on a currently-dead camera — query
/// opportunities no live camera can re-cover.  Recorded on the repair
/// record (graceful degradation) instead of aborting the solve.
fn uncovered_groups(stream: &crate::reid::records::ReidStream, dead: &[bool]) -> usize {
    if !dead.iter().any(|&d| d) {
        return 0;
    }
    let mut groups: HashMap<(usize, u32), bool> = HashMap::new();
    for d in stream.all() {
        let all_dead = groups.entry((d.frame, d.raw_id)).or_insert(true);
        *all_dead &= dead[d.cam];
    }
    // lint: order-insensitive — counts a predicate over the map
    groups.values().filter(|&&all_dead| all_dead).count()
}

/// The global tile set of per-camera masks, as a warm-start seed.
fn solution_of(masks: &RoiMasks) -> Solution {
    let mut tiles: HashSet<GlobalTile> = HashSet::new();
    for cam in 0..masks.tiling.n_cameras {
        // lint: order-insensitive — set-to-set rebuild
        for &(tx, ty) in &masks.tiles[cam] {
            tiles.insert(masks.tiling.tile_id(cam, tx, ty));
        }
    }
    Solution { tiles, unsatisfiable: 0 }
}

fn constraint_set(table: &AssociationTable) -> HashSet<Constraint> {
    table.constraints.iter().cloned().collect()
}

/// Fraction of `table`'s constraints absent from `prev` (0.0 for an empty
/// table — nothing to cover means nothing drifted).
fn constraint_drift(table: &AssociationTable, prev: &HashSet<Constraint>) -> f64 {
    if table.constraints.is_empty() {
        return 0.0;
    }
    let novel = table.constraints.iter().filter(|c| !prev.contains(*c)).count();
    novel as f64 / table.constraints.len() as f64
}

/// Camera owning a constraint (the camera of its first tile; a raw
/// constraint's cameras always lie inside one co-occurrence component,
/// so any tile identifies the component).  `None` for tile-less rows.
fn first_camera(c: &Constraint, tiling: &Tiling) -> Option<usize> {
    c.regions.iter().flat_map(|r| r.iter()).next().map(|&t| tiling.camera_of(t))
}

/// The per-component fire decision — the pure, unit-testable core of an
/// epoch check:
///
/// * `Never` never fires;
/// * `Every` fires any component with work — constraints to cover, or
///   stale tiles to clear (an empty, untiled component would be a pure
///   no-op and only inflate the re-solve count);
/// * `Drift` fires on migration (the component diff — the instance
///   changed *shape*, not just content, so the threshold does not
///   apply), on the drift signal itself, or when a tiled component's
///   window went **empty** — its drift is 0 by definition, so without
///   this case its stale tiles would stream empty-road RoIs forever.
fn fire_decision(
    policy: ReplanPolicy,
    migrated: bool,
    drift: f64,
    has_constraints: bool,
    has_tiles: bool,
) -> bool {
    // a component with neither constraints to cover nor tiles to clear
    // is a pure no-op whatever happened to its membership — solving it
    // would only inflate the re-solve count
    if !has_constraints && !has_tiles {
        return false;
    }
    match policy {
        ReplanPolicy::Never => false,
        ReplanPolicy::Every(_) => true,
        ReplanPolicy::Drift { threshold, .. } => {
            migrated || drift >= threshold || !has_constraints
        }
    }
}

/// Whether a fired component's solve warm-starts: never after a
/// migration (the donor/recipient instances changed shape, the old
/// seed describes a different decomposition), and only while the drift
/// stays under [`FRESH_SOLVE_DRIFT`].
fn warm_decision(migrated: bool, drift: f64) -> bool {
    !migrated && drift <= FRESH_SOLVE_DRIFT
}

/// The component diff: whether any camera of `comp` belonged to a
/// differently-shaped component at the previous check.  A camera moving
/// between components makes *both* its donor and its recipient report a
/// changed membership, so both re-solve fresh.
fn component_migrated(prev: &[Vec<usize>], comp: &[usize]) -> bool {
    comp.iter().any(|c| {
        // lint: order-insensitive — `prev` is a slice of sorted partitions
        prev.iter()
            .find(|p| p.contains(c))
            .map_or(true, |p| p.as_slice() != comp)
    })
}

/// Jaccard distance between two global tile sets (0.0 = identical masks).
fn mask_churn(a: &HashSet<GlobalTile>, b: &HashSet<GlobalTile>) -> f64 {
    if a.is_empty() && b.is_empty() {
        return 0.0;
    }
    let inter = a.intersection(b).count();
    let union = a.len() + b.len() - inter;
    1.0 - inter as f64 / union as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::offline::build_plan;
    use crate::reid::records::{RawDetection, ReidStream};

    fn table_from(regions: Vec<Vec<Vec<GlobalTile>>>) -> AssociationTable {
        let n = regions.len();
        AssociationTable {
            tiling: Tiling::new(1, 320, 192, 16),
            constraints: regions.into_iter().map(|r| Constraint { regions: r }).collect(),
            multiplicity: vec![1; n],
            total_occurrences: n,
        }
    }

    fn epoch_of_plan(plan: &OfflinePlan, n_cams: usize) -> Arc<PlanEpoch> {
        Arc::new(PlanEpoch::initial(
            plan.groups.clone(),
            plan.blocks.clone(),
            vec![true; n_cams],
            None,
            plan.masks.total_size(),
        ))
    }

    #[test]
    fn component_carry_restricts_to_the_component() {
        // tiling: 2 cameras × (20×12) tiles each
        let tiling = Tiling::new(2, 320, 192, 16);
        let per_cam = tiling.per_camera();
        let prev = Solution {
            tiles: [0, 1, per_cam, per_cam + 3].into_iter().collect(),
            unsatisfiable: 2,
        };
        let carry = component_carry(&prev, &[1], &tiling);
        assert_eq!(carry.tiles, [per_cam, per_cam + 3].into_iter().collect::<HashSet<_>>());
        assert_eq!(carry.unsatisfiable, 0, "the carry asserts nothing about coverage");
        assert!(component_carry(&prev, &[], &tiling).tiles.is_empty());
    }

    #[test]
    fn uncovered_groups_counts_dead_only_appearances() {
        let det = |cam: usize, frame: usize, raw_id: u32| RawDetection {
            cam,
            frame,
            bbox: crate::util::geometry::Rect::new(0.0, 0.0, 16.0, 16.0),
            raw_id,
            true_id: raw_id,
        };
        // id 1 @ frame 0 seen by cams 1+2 (one dead, one live: covered);
        // id 2 @ frame 1 seen only by dead cam 1 (uncovered);
        // id 2 @ frame 2 seen only by live cam 0 (covered)
        let s = ReidStream::new(
            3,
            3,
            vec![det(1, 0, 1), det(2, 0, 1), det(1, 1, 2), det(0, 2, 2)],
        );
        assert_eq!(uncovered_groups(&s, &[false, true, false]), 1);
        assert_eq!(uncovered_groups(&s, &[false, false, false]), 0);
        assert_eq!(uncovered_groups(&s, &[true, true, true]), 3);
    }

    #[test]
    fn constraint_drift_counts_novel_constraints() {
        let a = table_from(vec![vec![vec![1, 2]], vec![vec![3]]]);
        let prev = constraint_set(&a);
        // same table: no drift
        assert_eq!(constraint_drift(&a, &prev), 0.0);
        // one kept, one new: half the window is novel
        let b = table_from(vec![vec![vec![1, 2]], vec![vec![9]]]);
        assert!((constraint_drift(&b, &prev) - 0.5).abs() < 1e-12);
        // empty window: nothing to cover, nothing drifted
        let empty = table_from(vec![]);
        assert_eq!(constraint_drift(&empty, &prev), 0.0);
        // empty baseline: everything is novel
        assert_eq!(constraint_drift(&a, &HashSet::new()), 1.0);
    }

    #[test]
    fn mask_churn_is_jaccard_distance() {
        let a: HashSet<GlobalTile> = [1, 2, 3].into_iter().collect();
        let b: HashSet<GlobalTile> = [2, 3, 4].into_iter().collect();
        assert_eq!(mask_churn(&a, &a), 0.0);
        assert!((mask_churn(&a, &b) - 0.5).abs() < 1e-12);
        assert_eq!(mask_churn(&HashSet::new(), &HashSet::new()), 0.0);
        assert_eq!(mask_churn(&a, &HashSet::new()), 1.0);
    }

    #[test]
    fn migration_fires_fresh_for_donor_and_recipient() {
        // a camera moving between components: both the recipient
        // ({0,1,2}) and the donor's remainder ({3}) report a changed
        // membership, fire even under an unreachable drift threshold,
        // and must solve fresh
        let policy = ReplanPolicy::Drift { check_every: 2, threshold: 1.1 };
        let prev: Vec<Vec<usize>> = vec![vec![0, 1], vec![2, 3]];
        for comp in [vec![0usize, 1, 2], vec![3]] {
            let migrated = component_migrated(&prev, &comp);
            assert!(migrated, "{comp:?} must report migration");
            assert!(
                fire_decision(policy, migrated, 0.0, true, true),
                "{comp:?} must fire below the threshold"
            );
        }
        // unaffected components stay gated on the threshold alone
        assert!(!fire_decision(policy, false, 0.3, true, true));
        // a migrated component with nothing to solve and nothing to
        // clear is a no-op and must not fire at all
        assert!(!fire_decision(policy, true, 0.0, false, false));
        assert!(!warm_decision(true, 0.0), "migrated components must solve fresh");
        assert!(warm_decision(false, 0.3));
        assert!(!warm_decision(false, 0.7), "past FRESH_SOLVE_DRIFT solves fresh");
    }

    #[test]
    fn empty_window_components_fire_only_to_clear_stale_tiles() {
        let drift = ReplanPolicy::Drift { check_every: 2, threshold: 0.5 };
        // a tiled component whose window went empty has drift 0 — it
        // must still fire once to clear the stale tiles...
        assert!(fire_decision(drift, false, 0.0, false, true));
        // ...and stop firing once nothing is left to clear
        assert!(!fire_decision(drift, false, 0.0, false, false));
        let every = ReplanPolicy::Every(2);
        assert!(fire_decision(every, false, 0.0, true, false));
        assert!(fire_decision(every, false, 0.0, false, true));
        assert!(!fire_decision(every, false, 0.0, false, false));
        assert!(!fire_decision(ReplanPolicy::Never, true, 1.0, true, true));
    }

    #[test]
    fn component_diff_detects_splits_merges_and_moves() {
        let prev: Vec<Vec<usize>> = vec![vec![0, 1], vec![2, 3], vec![4]];
        // unchanged membership: no migration
        assert!(!component_migrated(&prev, &[0, 1]));
        assert!(!component_migrated(&prev, &[4]));
        // camera 2 moved to {0,1}: recipient {0,1,2} and donor {3} both
        // report migration
        assert!(component_migrated(&prev, &[0, 1, 2]));
        assert!(component_migrated(&prev, &[3]));
        // a split fires both halves
        assert!(component_migrated(&prev, &[2]));
        // a merge fires the union
        assert!(component_migrated(&prev, &[2, 3, 4]));
        // a camera never seen before is a migration too
        assert!(component_migrated(&[], &[0]));
    }

    #[test]
    fn baseline_retention_drops_fired_and_tile_less_constraints() {
        let tiling = Tiling::new(2, 320, 192, 16);
        let cam0 = Constraint { regions: vec![vec![3]] };
        let cam1 = Constraint { regions: vec![vec![300]] };
        let fired_cam = vec![true, false];
        // fired camera's constraints are replaced wholesale
        assert!(!baseline_keeps(&cam0, &tiling, &fired_cam));
        // quiescent camera's keep accumulating drift
        assert!(baseline_keeps(&cam1, &tiling, &fired_cam));
        // regression: tile-less rows used to survive every retain
        // (`map_or(true, ..)`) and grow the baseline forever — they
        // route to no component and must be dropped
        for orphan in [
            Constraint { regions: vec![] },
            Constraint { regions: vec![vec![]] },
        ] {
            assert!(!baseline_keeps(&orphan, &tiling, &fired_cam));
            assert!(!baseline_keeps(&orphan, &tiling, &[false, false]));
        }
    }

    #[test]
    fn first_camera_routes_by_any_tile() {
        let tiling = Tiling::new(3, 320, 192, 16);
        let c = Constraint { regions: vec![vec![300], vec![481]] };
        assert_eq!(first_camera(&c, &tiling), Some(1));
        let empty = Constraint { regions: vec![] };
        assert_eq!(first_camera(&empty, &tiling), None);
        let all_empty = Constraint { regions: vec![vec![]] };
        assert_eq!(first_camera(&all_empty, &tiling), None);
    }

    #[test]
    fn replanner_epoch_on_a_static_window_keeps_the_plan_small() {
        // no drift scenario: the re-planner must still produce a valid
        // epoch whose masks stay in the same ballpark as the initial plan,
        // via the warm-started path
        let cfg = Config::test_small();
        let scenario = Scenario::build(&cfg.scenario);
        let method = Method::CrossRoi;
        let plan = build_plan(&scenario, &cfg.scenario, &cfg.system, &method).unwrap();
        let rp = Replanner::new(
            &scenario,
            &cfg.system,
            &method,
            OfflineOptions::default(),
            ReplanPolicy::Every(2),
            ReplanScope::Component,
            5,
            &plan,
            60,
        );
        let epoch0 = epoch_of_plan(&plan, scenario.cameras.len());
        let next = rp.plan_epoch(1, 2, &epoch0).unwrap();
        assert_eq!(next.groups.len(), scenario.cameras.len());
        assert!(next.mask_tiles > 0);
        let records = rp.records();
        assert_eq!(records.len(), 1);
        assert!(records[0].replanned);
        assert!(records[0].warm, "low-drift window must warm-start");
        assert!(records[0].seconds >= 0.0);
        assert_eq!(records[0].start_seg, 2);
        assert_eq!(records[0].solver, "greedy");
        assert_eq!(records[0].scope, "component");
        // the 5-camera rig overlaps at the crossing: one component, fired
        assert!(records[0].fired_components() >= 1);
        assert_eq!(records[0].carried_components() + records[0].fired_components(),
                   records[0].components.len());
        for c in &records[0].components {
            if c.fired {
                assert!(c.spill_groups >= 1);
                assert_eq!(c.solver, "greedy");
            }
        }
        // content-compared stamps: every stamp is 0 (unchanged) or 1
        assert!(next.cam_epoch.iter().all(|&e| e == 0 || e == 1));
        assert!(next.thresholds.is_none(), "CrossRoI runs without a frame filter");
    }

    #[test]
    fn drift_policy_below_threshold_carries_the_plan_forward() {
        let cfg = Config::test_small();
        let scenario = Scenario::build(&cfg.scenario);
        let method = Method::CrossRoi;
        let plan = build_plan(&scenario, &cfg.scenario, &cfg.system, &method).unwrap();
        let rp = Replanner::new(
            &scenario,
            &cfg.system,
            &method,
            OfflineOptions::default(),
            // threshold above 1.0 can never fire
            ReplanPolicy::Drift { check_every: 2, threshold: 1.1 },
            ReplanScope::Component,
            5,
            &plan,
            60,
        );
        let epoch0 = epoch_of_plan(&plan, scenario.cameras.len());
        let next = rp.plan_epoch(1, 2, &epoch0).unwrap();
        assert!(Arc::ptr_eq(&next, &epoch0), "plan must be carried forward by pointer");
        let records = rp.records();
        assert_eq!(records.len(), 1);
        assert!(!records[0].replanned);
        assert_eq!(records[0].mask_churn, 0.0);
        assert_eq!(records[0].solver, "carried");
        assert_eq!(records[0].fired_components(), 0);
        assert!(records[0].carried_components() >= 1);
        assert!(records[0].components.iter().all(|c| !c.migrated),
                "a static window must not report migrations");
    }

    #[test]
    fn fleet_scope_uses_one_pseudo_component() {
        let cfg = Config::test_small();
        let scenario = Scenario::build(&cfg.scenario);
        let method = Method::CrossRoi;
        let plan = build_plan(&scenario, &cfg.scenario, &cfg.system, &method).unwrap();
        let rp = Replanner::new(
            &scenario,
            &cfg.system,
            &method,
            OfflineOptions::default(),
            ReplanPolicy::Every(2),
            ReplanScope::Fleet,
            5,
            &plan,
            60,
        );
        let epoch0 = epoch_of_plan(&plan, scenario.cameras.len());
        rp.plan_epoch(1, 2, &epoch0).unwrap();
        let records = rp.records();
        assert_eq!(records[0].scope, "fleet");
        assert_eq!(records[0].components.len(), 1);
        assert_eq!(
            records[0].components[0].cameras,
            (0..scenario.cameras.len()).collect::<Vec<_>>()
        );
        assert!(!records[0].components[0].migrated, "the fleet pseudo-component never migrates");
    }
}
