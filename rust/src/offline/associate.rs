//! Stage ③ — Associate: build the region-association lookup table (§3.2,
//! §4.1.1 module ③) from the cleaned stream — the constraint set of the
//! RoI optimization.

use crate::association::table::AssociationTable;
use crate::association::tiles::Tiling;
use crate::reid::records::ReidStream;

/// The associate stage's artifact: the deduplicated constraint table.
#[derive(Debug, Clone)]
pub struct AssociateArtifact {
    pub table: AssociationTable,
}

/// Build the association table over the given tiling.
pub fn run(stream: &ReidStream, tiling: &Tiling) -> AssociateArtifact {
    AssociateArtifact { table: AssociationTable::build(stream, tiling) }
}

/// [`run`] with the per-frame grouping fanned out over up to `threads`
/// scoped workers — byte-identical at every thread count (see
/// [`AssociationTable::build_par`]).
pub fn run_par(stream: &ReidStream, tiling: &Tiling, threads: usize) -> AssociateArtifact {
    AssociateArtifact { table: AssociationTable::build_par(stream, tiling, threads) }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::offline::profile;
    use crate::sim::Scenario;

    #[test]
    fn builds_constraints_from_the_profile_stream() {
        let cfg = Config::test_small();
        let sc = Scenario::build(&cfg.scenario);
        let profiled = profile::run(&sc);
        let tiling = Tiling::new(
            cfg.scenario.n_cameras,
            crate::sim::FRAME_W,
            crate::sim::FRAME_H,
            cfg.scenario.tile_px,
        );
        let art = run(&profiled.stream, &tiling);
        assert!(art.table.n_constraints() > 0);
        assert!(art.table.total_occurrences >= art.table.n_constraints());
    }
}
