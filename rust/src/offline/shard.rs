//! Stage ②-prep — Shard: partition the fleet into overlap-connected
//! camera clusters so the rest of the planner runs per cluster, and
//! split each cluster's *solve instance* along its articulation
//! structure (bridge-camera constraint spill, DESIGN.md §8).
//!
//! City-scale deployments are sparse (ReXCam, arXiv:1811.01268): cameras
//! cluster around intersections, and a camera pair whose viewing fields
//! never overlap contributes nothing to the association table — fitting
//! its tandem filters or carrying its tiles through one global set-cover
//! only burns the O(n²) that keeps the offline phase from scaling.  The
//! shard stage builds the camera overlap graph from the profile stream —
//! an edge wherever two cameras ever report the same raw id at the same
//! frame, a superset of the pairs the tandem filters could ever fit (a
//! pair with no co-occurrence has no positive samples) and far cheaper
//! than fitting them first — and partitions it into connected components
//! with a union-find.
//!
//! Determinism: the partition is a pure function of the stream (no
//! iteration-order dependence — unions commute), shards are ordered by
//! their smallest camera index and cameras ascend inside each shard, so
//! the downstream shard-order merge is byte-identical across runs and
//! thread counts (`rust/tests/offline_determinism.rs`).

use std::collections::hash_map::Entry;
use std::collections::HashMap;

use anyhow::{bail, Result};

use crate::association::table::AssociationTable;
use crate::association::tiles::GlobalTile;
use crate::reid::records::ReidStream;

/// Whether the planner partitions the fleet (CLI: `--shards auto|off`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ShardMode {
    /// Partition into overlap components; a fully-connected fleet (one
    /// component) falls through to the unsharded path.
    #[default]
    Auto,
    /// Always plan the fleet as one instance.
    Off,
}

impl ShardMode {
    pub fn parse(name: &str) -> Result<ShardMode> {
        Ok(match name {
            "auto" => ShardMode::Auto,
            "off" => ShardMode::Off,
            other => bail!("unknown shard mode {other:?} (expected auto|off)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            ShardMode::Auto => "auto",
            ShardMode::Off => "off",
        }
    }
}

/// One overlap-connected camera cluster (global camera indices, ascending).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Shard {
    pub cameras: Vec<usize>,
}

impl Shard {
    /// The shard's records only, global camera indexing preserved (the
    /// association table keeps producing global tile ids, so the merge
    /// is a plain union).
    pub fn substream(&self, stream: &ReidStream) -> ReidStream {
        let mut member = vec![false; stream.n_cameras];
        for &c in &self.cameras {
            member[c] = true;
        }
        stream.filtered(|r| member[r.cam])
    }
}

/// Partition the fleet into overlap components of the profile stream.
/// Cameras with no co-occurrence at all become singleton shards.
pub fn partition(stream: &ReidStream) -> Vec<Shard> {
    let mut uf = UnionFind::new(stream.n_cameras);
    // (frame, raw_id) → first camera seen carrying it; later carriers
    // union into that representative (transitively joining each other)
    let mut first_cam: HashMap<(usize, u32), usize> = HashMap::new();
    for rec in stream.all() {
        match first_cam.entry((rec.frame, rec.raw_id)) {
            Entry::Occupied(e) => uf.union(*e.get(), rec.cam),
            Entry::Vacant(v) => {
                v.insert(rec.cam);
            }
        }
    }
    let mut by_root: HashMap<usize, Vec<usize>> = HashMap::new();
    for cam in 0..stream.n_cameras {
        by_root.entry(uf.find(cam)).or_default().push(cam);
    }
    // cameras were pushed in ascending order; order shards the same way
    let mut shards: Vec<Shard> =
        by_root.into_values().map(|cameras| Shard { cameras }).collect();
    shards.sort_by_key(|s| s.cameras[0]);
    shards
}

/// One spill sub-instance of a solve: a **tile-connected** group of
/// constraints.  All tiles any of its constraints mention belong to this
/// group and to no other, so solving each group independently and
/// unioning the (disjoint) tile sets is byte-identical to solving the
/// whole table at once — the greedy's scores and the prune's removal
/// checks never cross tile-connectivity boundaries.
#[derive(Debug, Clone)]
pub struct SpillGroup {
    /// Cameras owning this group's tiles, ascending.  A bridge camera
    /// appears in several groups; [`SpillPartition::owner_of`] breaks the
    /// tie.
    pub cameras: Vec<usize>,
    /// Indices into the source table's constraint list, ascending.
    pub constraints: Vec<usize>,
    /// Candidate tiles owned by this group.
    pub n_tiles: usize,
}

impl SpillGroup {
    /// This group's constraints as a standalone instance (order and
    /// multiplicities preserved, so per-group solves replicate the global
    /// solve's scoring exactly).
    pub fn subtable(&self, table: &AssociationTable) -> AssociationTable {
        AssociationTable {
            tiling: table.tiling.clone(),
            constraints: self
                .constraints
                .iter()
                .map(|&ci| table.constraints[ci].clone())
                .collect(),
            multiplicity: self.constraints.iter().map(|&ci| table.multiplicity[ci]).collect(),
            total_occurrences: self.constraints.iter().map(|&ci| table.multiplicity[ci]).sum(),
        }
    }
}

/// The bridge-camera constraint spill (DESIGN.md §8): a camera whose
/// constraints span two otherwise-disjoint sub-fleets no longer fuses
/// them into one giant solve instance.  Constraints are partitioned along
/// the overlap graph's articulation structure, *refined to
/// tile-connectivity*: two constraints share a group iff they are linked
/// by a chain of shared candidate tiles.  Where a bridge camera's views
/// of its two sides image into disjoint tile clusters, its constraint
/// rows split between the sides; where traffic genuinely entangles the
/// tiles, the groups fuse — exactly when splitting would change the
/// solution.
#[derive(Debug, Clone)]
pub struct SpillPartition {
    /// Tile-connected groups, ordered by their smallest tile id (tile
    /// ownership is unique by construction, so the order is total).
    pub groups: Vec<SpillGroup>,
    /// Constraints mentioning no candidate tile at all (empty or
    /// all-empty region lists); they join no group and contribute only
    /// their unsatisfiable count.
    pub residual: Vec<usize>,
}

impl SpillPartition {
    /// Cameras whose tiles span more than one group — the articulation
    /// (bridge) cameras of this instance, ascending.
    pub fn bridge_cameras(&self) -> Vec<usize> {
        let mut count: HashMap<usize, usize> = HashMap::new();
        for g in &self.groups {
            for &c in &g.cameras {
                *count.entry(c).or_insert(0) += 1;
            }
        }
        let mut out: Vec<usize> =
            count.into_iter().filter(|&(_, n)| n >= 2).map(|(c, _)| c).collect();
        out.sort_unstable();
        out
    }

    /// The group that owns camera `cam` for attribution purposes: the
    /// lowest group id containing it (lowest shard id wins ties — the
    /// deterministic ownership rule for bridge cameras).
    pub fn owner_of(&self, cam: usize) -> Option<usize> {
        self.groups.iter().position(|g| g.cameras.contains(&cam))
    }
}

/// Split a solve instance into tile-connected constraint groups.
///
/// Determinism: groups are keyed by union-find roots but *ordered* by
/// their smallest tile id, constraints ascend inside each group, and the
/// partition is a pure function of the table (unions commute) — so the
/// downstream group-order merge is byte-identical across runs and thread
/// counts.
pub fn spill(table: &AssociationTable) -> SpillPartition {
    let tiles = table.candidate_tiles(); // sorted ascending
    let id_of: HashMap<GlobalTile, usize> =
        // lint: order-insensitive — `tiles` is the sorted Vec from candidate_tiles()
        tiles.iter().enumerate().map(|(i, &t)| (t, i)).collect();
    let mut uf = UnionFind::new(tiles.len());
    let mut anchors: Vec<Option<usize>> = Vec::with_capacity(table.constraints.len());
    let mut residual = Vec::new();
    for (ci, c) in table.constraints.iter().enumerate() {
        // every tile a constraint mentions — across all its alternative
        // regions — must live in one group: the solve picks one region,
        // and which one depends on every alternative's score
        let mut first: Option<usize> = None;
        for region in &c.regions {
            for t in region {
                let d = id_of[t];
                match first {
                    None => first = Some(d),
                    Some(f) => uf.union(f, d),
                }
            }
        }
        if first.is_none() {
            residual.push(ci);
        }
        anchors.push(first);
    }
    // dense ids ascend with tile id, so the first tile to reach a root is
    // the group's smallest — walking tiles in order yields the group
    // order and (camera-major tile ids) each group's cameras ascending
    let mut group_of_root: HashMap<usize, usize> = HashMap::new();
    let mut groups: Vec<SpillGroup> = Vec::new();
    // lint: order-insensitive — `tiles` is the sorted Vec from candidate_tiles()
    for (d, &tile) in tiles.iter().enumerate() {
        let root = uf.find(d);
        let gi = *group_of_root.entry(root).or_insert_with(|| {
            groups.push(SpillGroup {
                cameras: Vec::new(),
                constraints: Vec::new(),
                n_tiles: 0,
            });
            groups.len() - 1
        });
        let g = &mut groups[gi];
        g.n_tiles += 1;
        let cam = table.tiling.camera_of(tile);
        if g.cameras.last() != Some(&cam) {
            g.cameras.push(cam);
        }
    }
    for (ci, a) in anchors.iter().enumerate() {
        if let Some(d) = a {
            let gi = group_of_root[&uf.find(*d)];
            groups[gi].constraints.push(ci);
        }
    }
    SpillPartition { groups, residual }
}

/// Union-find with path halving + union by size.
struct UnionFind {
    parent: Vec<usize>,
    size: Vec<usize>,
}

impl UnionFind {
    fn new(n: usize) -> UnionFind {
        UnionFind { parent: (0..n).collect(), size: vec![1; n] }
    }

    fn find(&mut self, mut x: usize) -> usize {
        while self.parent[x] != x {
            self.parent[x] = self.parent[self.parent[x]];
            x = self.parent[x];
        }
        x
    }

    fn union(&mut self, a: usize, b: usize) {
        let (ra, rb) = (self.find(a), self.find(b));
        if ra == rb {
            return;
        }
        let (big, small) = if self.size[ra] >= self.size[rb] { (ra, rb) } else { (rb, ra) };
        self.parent[small] = big;
        self.size[big] += self.size[small];
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::reid::records::RawDetection;
    use crate::util::geometry::Rect;

    fn det(cam: usize, frame: usize, raw_id: u32) -> RawDetection {
        RawDetection { cam, frame, bbox: Rect::new(10.0, 10.0, 20.0, 20.0), raw_id, true_id: raw_id }
    }

    fn cams(shards: &[Shard]) -> Vec<Vec<usize>> {
        shards.iter().map(|s| s.cameras.clone()).collect()
    }

    #[test]
    fn disjoint_components_split() {
        // cams {0,1} share id 1; cams {2,3} share id 9; cam 4 sees only
        // its own id
        let s = ReidStream::new(
            5,
            2,
            vec![
                det(0, 0, 1),
                det(1, 0, 1),
                det(2, 0, 9),
                det(3, 1, 9),
                det(2, 1, 9),
                det(4, 0, 50),
            ],
        );
        assert_eq!(cams(&partition(&s)), vec![vec![0, 1], vec![2, 3], vec![4]]);
    }

    #[test]
    fn transitive_overlap_joins() {
        // 0-1 co-occur and 1-2 co-occur: one component even though 0 and 2
        // never share a frame id directly
        let s = ReidStream::new(
            3,
            2,
            vec![det(0, 0, 1), det(1, 0, 1), det(1, 1, 2), det(2, 1, 2)],
        );
        assert_eq!(cams(&partition(&s)), vec![vec![0, 1, 2]]);
    }

    #[test]
    fn same_id_on_different_frames_does_not_join() {
        let s = ReidStream::new(2, 2, vec![det(0, 0, 1), det(1, 1, 1)]);
        assert_eq!(cams(&partition(&s)), vec![vec![0], vec![1]]);
    }

    #[test]
    fn empty_stream_yields_singletons() {
        let s = ReidStream::new(3, 1, vec![]);
        assert_eq!(cams(&partition(&s)), vec![vec![0], vec![1], vec![2]]);
    }

    #[test]
    fn substream_keeps_only_member_records() {
        let s = ReidStream::new(
            4,
            1,
            vec![det(0, 0, 1), det(1, 0, 1), det(2, 0, 9), det(3, 0, 9)],
        );
        let sh = Shard { cameras: vec![2, 3] };
        let sub = sh.substream(&s);
        assert_eq!(sub.n_cameras, 4, "global indexing must be preserved");
        assert_eq!(sub.len(), 2);
        assert!(sub.all().iter().all(|r| r.cam >= 2));
    }

    use crate::association::table::Constraint;
    use crate::association::tiles::Tiling;

    /// Table over `n_cams` cameras (240 tiles each: cam c owns ids
    /// `c*240 .. (c+1)*240`).
    fn spill_table(n_cams: usize, regions: Vec<Vec<Vec<GlobalTile>>>) -> AssociationTable {
        let n = regions.len();
        AssociationTable {
            tiling: Tiling::new(n_cams, 320, 192, 16),
            constraints: regions.into_iter().map(|r| Constraint { regions: r }).collect(),
            multiplicity: vec![1; n],
            total_occurrences: n,
        }
    }

    #[test]
    fn spill_splits_a_bridge_cameras_constraints() {
        // cam 1 bridges cams 0 and 2: its left-half tile (240) shares a
        // constraint with cam 0, its right-half tile (300) with cam 2 —
        // tile-disjoint, so the instance splits at the articulation
        let t = spill_table(
            3,
            vec![
                vec![vec![1, 2], vec![240]],   // side A (cams 0 + bridge-left)
                vec![vec![300], vec![481]],    // side B (bridge-right + cam 2)
            ],
        );
        let sp = spill(&t);
        assert_eq!(sp.groups.len(), 2);
        assert_eq!(sp.groups[0].cameras, vec![0, 1]);
        assert_eq!(sp.groups[0].constraints, vec![0]);
        assert_eq!(sp.groups[0].n_tiles, 3);
        assert_eq!(sp.groups[1].cameras, vec![1, 2]);
        assert_eq!(sp.groups[1].constraints, vec![1]);
        assert!(sp.residual.is_empty());
        assert_eq!(sp.bridge_cameras(), vec![1]);
        // ownership tie-break: the bridge camera belongs to the lowest
        // group id containing it
        assert_eq!(sp.owner_of(1), Some(0));
        assert_eq!(sp.owner_of(0), Some(0));
        assert_eq!(sp.owner_of(2), Some(1));
        assert_eq!(sp.owner_of(9), None);
    }

    #[test]
    fn spill_fuses_groups_that_share_tiles() {
        // genuinely entangled constraints (shared tile 2) must stay one
        // instance — splitting them would change the greedy's choices
        let t = spill_table(1, vec![vec![vec![1, 2]], vec![vec![2, 3]], vec![vec![9]]]);
        let sp = spill(&t);
        assert_eq!(sp.groups.len(), 2);
        assert_eq!(sp.groups[0].constraints, vec![0, 1]);
        assert_eq!(sp.groups[1].constraints, vec![2]);
        assert!(sp.bridge_cameras().is_empty());
    }

    #[test]
    fn spill_connects_alternative_regions_of_one_constraint() {
        // a constraint's alternative regions are one choice — their tiles
        // must land in one group even across cameras
        let t = spill_table(3, vec![vec![vec![1], vec![500]], vec![vec![600]]]);
        let sp = spill(&t);
        assert_eq!(sp.groups.len(), 2);
        assert_eq!(sp.groups[0].cameras, vec![0, 2]);
        assert_eq!(sp.groups[1].cameras, vec![2]);
        assert_eq!(sp.owner_of(2), Some(0), "lowest group id wins the tie");
    }

    #[test]
    fn spill_routes_tile_less_constraints_to_the_residual() {
        let t = spill_table(1, vec![vec![], vec![vec![4]]]);
        let sp = spill(&t);
        assert_eq!(sp.groups.len(), 1);
        assert_eq!(sp.residual, vec![0]);
    }

    #[test]
    fn spill_subtable_preserves_order_and_multiplicity() {
        let mut t = spill_table(1, vec![vec![vec![1]], vec![vec![50]], vec![vec![1, 2]]]);
        t.multiplicity = vec![3, 7, 2];
        let sp = spill(&t);
        assert_eq!(sp.groups.len(), 2);
        let sub = sp.groups[0].subtable(&t);
        assert_eq!(sub.n_constraints(), 2);
        assert_eq!(sub.constraints[0], t.constraints[0]);
        assert_eq!(sub.constraints[1], t.constraints[2]);
        assert_eq!(sub.multiplicity, vec![3, 2]);
        assert_eq!(sub.total_occurrences, 5);
        let sub1 = sp.groups[1].subtable(&t);
        assert_eq!(sub1.multiplicity, vec![7]);
    }

    #[test]
    fn mode_parses_and_names() {
        assert_eq!(ShardMode::parse("auto").unwrap(), ShardMode::Auto);
        assert_eq!(ShardMode::parse("off").unwrap(), ShardMode::Off);
        assert!(ShardMode::parse("on").is_err());
        assert_eq!(ShardMode::Auto.name(), "auto");
        assert_eq!(ShardMode::Off.name(), "off");
        assert_eq!(ShardMode::default(), ShardMode::Auto);
    }
}
