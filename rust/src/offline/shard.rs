//! Stage ②-prep — Shard: partition the fleet into overlap-connected
//! camera clusters so the rest of the planner runs per cluster.
//!
//! City-scale deployments are sparse (ReXCam, arXiv:1811.01268): cameras
//! cluster around intersections, and a camera pair whose viewing fields
//! never overlap contributes nothing to the association table — fitting
//! its tandem filters or carrying its tiles through one global set-cover
//! only burns the O(n²) that keeps the offline phase from scaling.  The
//! shard stage builds the camera overlap graph from the profile stream —
//! an edge wherever two cameras ever report the same raw id at the same
//! frame, a superset of the pairs the tandem filters could ever fit (a
//! pair with no co-occurrence has no positive samples) and far cheaper
//! than fitting them first — and partitions it into connected components
//! with a union-find.
//!
//! Determinism: the partition is a pure function of the stream (no
//! iteration-order dependence — unions commute), shards are ordered by
//! their smallest camera index and cameras ascend inside each shard, so
//! the downstream shard-order merge is byte-identical across runs and
//! thread counts (`rust/tests/offline_determinism.rs`).

use std::collections::hash_map::Entry;
use std::collections::HashMap;

use anyhow::{bail, Result};

use crate::reid::records::ReidStream;

/// Whether the planner partitions the fleet (CLI: `--shards auto|off`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ShardMode {
    /// Partition into overlap components; a fully-connected fleet (one
    /// component) falls through to the unsharded path.
    #[default]
    Auto,
    /// Always plan the fleet as one instance.
    Off,
}

impl ShardMode {
    pub fn parse(name: &str) -> Result<ShardMode> {
        Ok(match name {
            "auto" => ShardMode::Auto,
            "off" => ShardMode::Off,
            other => bail!("unknown shard mode {other:?} (expected auto|off)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            ShardMode::Auto => "auto",
            ShardMode::Off => "off",
        }
    }
}

/// One overlap-connected camera cluster (global camera indices, ascending).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Shard {
    pub cameras: Vec<usize>,
}

impl Shard {
    /// The shard's records only, global camera indexing preserved (the
    /// association table keeps producing global tile ids, so the merge
    /// is a plain union).
    pub fn substream(&self, stream: &ReidStream) -> ReidStream {
        let mut member = vec![false; stream.n_cameras];
        for &c in &self.cameras {
            member[c] = true;
        }
        stream.filtered(|r| member[r.cam])
    }
}

/// Partition the fleet into overlap components of the profile stream.
/// Cameras with no co-occurrence at all become singleton shards.
pub fn partition(stream: &ReidStream) -> Vec<Shard> {
    let mut uf = UnionFind::new(stream.n_cameras);
    // (frame, raw_id) → first camera seen carrying it; later carriers
    // union into that representative (transitively joining each other)
    let mut first_cam: HashMap<(usize, u32), usize> = HashMap::new();
    for rec in stream.all() {
        match first_cam.entry((rec.frame, rec.raw_id)) {
            Entry::Occupied(e) => uf.union(*e.get(), rec.cam),
            Entry::Vacant(v) => {
                v.insert(rec.cam);
            }
        }
    }
    let mut by_root: HashMap<usize, Vec<usize>> = HashMap::new();
    for cam in 0..stream.n_cameras {
        by_root.entry(uf.find(cam)).or_default().push(cam);
    }
    // cameras were pushed in ascending order; order shards the same way
    let mut shards: Vec<Shard> =
        by_root.into_values().map(|cameras| Shard { cameras }).collect();
    shards.sort_by_key(|s| s.cameras[0]);
    shards
}

/// Union-find with path halving + union by size.
struct UnionFind {
    parent: Vec<usize>,
    size: Vec<usize>,
}

impl UnionFind {
    fn new(n: usize) -> UnionFind {
        UnionFind { parent: (0..n).collect(), size: vec![1; n] }
    }

    fn find(&mut self, mut x: usize) -> usize {
        while self.parent[x] != x {
            self.parent[x] = self.parent[self.parent[x]];
            x = self.parent[x];
        }
        x
    }

    fn union(&mut self, a: usize, b: usize) {
        let (ra, rb) = (self.find(a), self.find(b));
        if ra == rb {
            return;
        }
        let (big, small) = if self.size[ra] >= self.size[rb] { (ra, rb) } else { (rb, ra) };
        self.parent[small] = big;
        self.size[big] += self.size[small];
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::reid::records::RawDetection;
    use crate::util::geometry::Rect;

    fn det(cam: usize, frame: usize, raw_id: u32) -> RawDetection {
        RawDetection { cam, frame, bbox: Rect::new(10.0, 10.0, 20.0, 20.0), raw_id, true_id: raw_id }
    }

    fn cams(shards: &[Shard]) -> Vec<Vec<usize>> {
        shards.iter().map(|s| s.cameras.clone()).collect()
    }

    #[test]
    fn disjoint_components_split() {
        // cams {0,1} share id 1; cams {2,3} share id 9; cam 4 sees only
        // its own id
        let s = ReidStream::new(
            5,
            2,
            vec![
                det(0, 0, 1),
                det(1, 0, 1),
                det(2, 0, 9),
                det(3, 1, 9),
                det(2, 1, 9),
                det(4, 0, 50),
            ],
        );
        assert_eq!(cams(&partition(&s)), vec![vec![0, 1], vec![2, 3], vec![4]]);
    }

    #[test]
    fn transitive_overlap_joins() {
        // 0-1 co-occur and 1-2 co-occur: one component even though 0 and 2
        // never share a frame id directly
        let s = ReidStream::new(
            3,
            2,
            vec![det(0, 0, 1), det(1, 0, 1), det(1, 1, 2), det(2, 1, 2)],
        );
        assert_eq!(cams(&partition(&s)), vec![vec![0, 1, 2]]);
    }

    #[test]
    fn same_id_on_different_frames_does_not_join() {
        let s = ReidStream::new(2, 2, vec![det(0, 0, 1), det(1, 1, 1)]);
        assert_eq!(cams(&partition(&s)), vec![vec![0], vec![1]]);
    }

    #[test]
    fn empty_stream_yields_singletons() {
        let s = ReidStream::new(3, 1, vec![]);
        assert_eq!(cams(&partition(&s)), vec![vec![0], vec![1], vec![2]]);
    }

    #[test]
    fn substream_keeps_only_member_records() {
        let s = ReidStream::new(
            4,
            1,
            vec![det(0, 0, 1), det(1, 0, 1), det(2, 0, 9), det(3, 0, 9)],
        );
        let sh = Shard { cameras: vec![2, 3] };
        let sub = sh.substream(&s);
        assert_eq!(sub.n_cameras, 4, "global indexing must be preserved");
        assert_eq!(sub.len(), 2);
        assert!(sub.all().iter().all(|r| r.cam >= 2));
    }

    #[test]
    fn mode_parses_and_names() {
        assert_eq!(ShardMode::parse("auto").unwrap(), ShardMode::Auto);
        assert_eq!(ShardMode::parse("off").unwrap(), ShardMode::Off);
        assert!(ShardMode::parse("on").is_err());
        assert_eq!(ShardMode::Auto.name(), "auto");
        assert_eq!(ShardMode::Off.name(), "off");
        assert_eq!(ShardMode::default(), ShardMode::Auto);
    }
}
