//! Stage ② — Filter: the tandem statistical filters (RANSAC regression +
//! RBF-SVM) over the profiled ReID stream (§4.1.1 module ②; skipped by
//! the No-Filters ablation).  The O(n²) per-pair model fitting runs on
//! `threads` scoped workers — see [`crate::filters::TandemFilters`].

use crate::config::SystemConfig;
use crate::coordinator::method::Method;
use crate::filters::ransac::RansacParams;
use crate::filters::svm::SvmParams;
use crate::filters::{FilterReport, TandemFilters};
use crate::offline::profile::ProfileArtifact;
use crate::reid::records::ReidStream;

/// The filter stage's artifact: the cleaned stream plus the filter
/// diagnostics (`None` when the method runs with filters off).
#[derive(Debug, Clone)]
pub struct FilterArtifact {
    pub stream: ReidStream,
    pub report: Option<FilterReport>,
}

/// Clean the profiled stream (or pass it through for No-Filters).
pub fn run(
    profiled: ProfileArtifact,
    sys: &SystemConfig,
    method: &Method,
    threads: usize,
) -> FilterArtifact {
    if !method.uses_filters() {
        return FilterArtifact { stream: profiled.stream, report: None };
    }
    let filters = TandemFilters {
        ransac: RansacParams { theta: sys.ransac_theta, ..Default::default() },
        svm: SvmParams { gamma: sys.svm_gamma, ..Default::default() },
        ..Default::default()
    };
    let (stream, report) = filters.apply_with_threads(&profiled.stream, threads);
    FilterArtifact { stream, report: Some(report) }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::offline::profile;
    use crate::sim::Scenario;

    #[test]
    fn no_filters_method_passes_the_stream_through() {
        let cfg = Config::test_small();
        let sc = Scenario::build(&cfg.scenario);
        let profiled = profile::run(&sc);
        let before = profiled.stream.len();
        let art = run(profiled, &cfg.system, &Method::NoFilters, 2);
        assert!(art.report.is_none());
        assert_eq!(art.stream.len(), before);
    }

    #[test]
    fn crossroi_method_filters_and_reports() {
        let cfg = Config::test_small();
        let sc = Scenario::build(&cfg.scenario);
        let profiled = profile::run(&sc);
        let before = profiled.stream.len();
        let art = run(profiled, &cfg.system, &Method::CrossRoi, 2);
        let report = art.report.expect("filters ran");
        assert!(report.pairs_fit > 0, "no camera pair could be fit");
        assert!(art.stream.len() <= before);
    }
}
