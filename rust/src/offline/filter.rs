//! Stage ② — Filter: the tandem statistical filters (RANSAC regression +
//! RBF-SVM) over the profiled ReID stream (§4.1.1 module ②; skipped by
//! the No-Filters ablation).  The O(n²) per-pair model fitting runs on
//! `threads` scoped workers — see [`crate::filters::TandemFilters`].

use crate::config::SystemConfig;
use crate::coordinator::method::Method;
use crate::filters::ransac::RansacParams;
use crate::filters::svm::SvmParams;
use crate::filters::{FilterReport, TandemFilters};
use crate::reid::records::ReidStream;

/// The filter stage's artifact: the cleaned stream plus the filter
/// diagnostics (`None` when the method runs with filters off).
#[derive(Debug, Clone)]
pub struct FilterArtifact {
    pub stream: ReidStream,
    pub report: Option<FilterReport>,
}

/// Clean the stream (or pass it through for No-Filters), restricted to
/// the ordered camera pairs within `cameras` (None = whole fleet) — the
/// sharded planner passes one overlap component at a time, so
/// cross-shard pairs are never enumerated.  `frame` is the
/// (width, height) the streams were captured at (the planner passes its
/// `Tiling`'s geometry): the filters' interior predicate must match the
/// caller's frames, never a hardcoded sim constant.
pub fn run_scoped(
    stream: ReidStream,
    sys: &SystemConfig,
    method: &Method,
    threads: usize,
    cameras: Option<&[usize]>,
    frame: (f64, f64),
) -> FilterArtifact {
    if !method.uses_filters() {
        return FilterArtifact { stream, report: None };
    }
    let filters = TandemFilters {
        ransac: RansacParams { theta: sys.ransac_theta, ..Default::default() },
        svm: SvmParams { gamma: sys.svm_gamma, ..Default::default() },
        frame_w: frame.0,
        frame_h: frame.1,
        ..Default::default()
    };
    let (stream, report) = filters.apply_scoped(&stream, threads, cameras);
    FilterArtifact { stream, report: Some(report) }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::offline::profile;
    use crate::sim::Scenario;

    const SIM_FRAME: (f64, f64) = (crate::sim::FRAME_W as f64, crate::sim::FRAME_H as f64);

    #[test]
    fn no_filters_method_passes_the_stream_through() {
        let cfg = Config::test_small();
        let sc = Scenario::build(&cfg.scenario);
        let profiled = profile::run(&sc);
        let before = profiled.stream.len();
        let art =
            run_scoped(profiled.stream, &cfg.system, &Method::NoFilters, 2, None, SIM_FRAME);
        assert!(art.report.is_none());
        assert_eq!(art.stream.len(), before);
    }

    #[test]
    fn crossroi_method_filters_and_reports() {
        let cfg = Config::test_small();
        let sc = Scenario::build(&cfg.scenario);
        let profiled = profile::run(&sc);
        let before = profiled.stream.len();
        let art =
            run_scoped(profiled.stream, &cfg.system, &Method::CrossRoi, 2, None, SIM_FRAME);
        let report = art.report.expect("filters ran");
        assert!(report.pairs_fit > 0, "no camera pair could be fit");
        assert!(art.stream.len() <= before);
    }
}
