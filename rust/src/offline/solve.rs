//! Stage ④ — Solve: optimize the RoI masks over the association table
//! (§4.1.1 module ④, Eq. 1–2) with a pluggable [`Solver`] — either as one
//! instance ([`run`] / [`run_incremental`]) or decomposed along the
//! bridge-camera constraint spill ([`run_spilled`], DESIGN.md §8).

use anyhow::{bail, Context as _, Result};

use crate::association::table::AssociationTable;
use crate::offline::shard::SpillPartition;
use crate::roi::masks::RoiMasks;
use crate::roi::setcover::{ExactSolver, GreedySolver, Solution, Solver};

/// Which set-cover implementation optimizes the RoI masks
/// (CLI: `--solver greedy|exact`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SolverKind {
    /// Incremental greedy density heuristic + prune (the default; scales
    /// to full profile-window instances).
    #[default]
    Greedy,
    /// Branch-and-bound certifier — exponential, refuses instances above
    /// its constraint cap; only meaningful on small/toy scenarios.
    Exact,
}

impl SolverKind {
    pub fn parse(name: &str) -> Result<SolverKind> {
        Ok(match name {
            "greedy" => SolverKind::Greedy,
            "exact" => SolverKind::Exact,
            other => bail!("unknown solver {other:?} (expected greedy|exact)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            SolverKind::Greedy => "greedy",
            SolverKind::Exact => "exact",
        }
    }

    /// Instantiate the solver behind this kind.
    pub fn build(&self) -> Box<dyn Solver> {
        match self {
            SolverKind::Greedy => Box::new(GreedySolver::default()),
            SolverKind::Exact => Box::new(ExactSolver::default()),
        }
    }

    /// Reject instances the chosen solver cannot take — the exact
    /// certifier is exponential and capped, and must fail cleanly (not
    /// panic) when `--solver exact` meets a real profile window.
    pub fn validate(&self, table: &AssociationTable) -> Result<()> {
        if let SolverKind::Exact = self {
            let cap = ExactSolver::default().max_constraints;
            if table.n_constraints() > cap {
                bail!(
                    "the exact solver is a certifier for small instances \
                     (<= {cap} constraints); this profile window produced {} — \
                     use --solver greedy",
                    table.n_constraints()
                );
            }
        }
        Ok(())
    }
}

/// The solve stage's artifact: the global tile solution and its
/// per-camera mask split.
#[derive(Debug, Clone)]
pub struct SolveArtifact {
    pub solution: Solution,
    pub masks: RoiMasks,
}

/// Solve from scratch.
pub fn run(table: &AssociationTable, solver: &dyn Solver) -> SolveArtifact {
    finish(table, solver.solve(table))
}

/// Warm-start from a previous window's solution ([`Solver::resolve`]) —
/// the entry point for sliding-window re-profiling.
pub fn run_incremental(
    table: &AssociationTable,
    solver: &dyn Solver,
    prev: &Solution,
) -> SolveArtifact {
    finish(table, solver.resolve(prev, table))
}

/// Solve an instance decomposed along its [`SpillPartition`]: each
/// tile-connected constraint group is solved (or, with `prev`,
/// warm-started via [`Solver::resolve`] — the seed restricts itself to
/// the group's candidate tiles) independently and the disjoint tile sets
/// are unioned in group order.  Because groups share no tiles, the union
/// is **byte-identical** to solving the whole table at once with the same
/// warm seed; the decomposition only shrinks each solve's universe.
///
/// The exact certifier's constraint cap applies **per group** here (the
/// finest instance the certifier actually branches over), so `--solver
/// exact` admits bridged fleets whose individual sides fit the cap even
/// when the fused table would not.
pub fn run_spilled(
    table: &AssociationTable,
    kind: SolverKind,
    prev: Option<&Solution>,
    sp: &SpillPartition,
) -> Result<SolveArtifact> {
    Ok(finish(table, solve_spilled(table, kind, prev, sp)?))
}

/// [`run_spilled`] without the per-camera mask split — for callers (the
/// sharded planner's merge) that union solutions before building masks.
pub fn solve_spilled(
    table: &AssociationTable,
    kind: SolverKind,
    prev: Option<&Solution>,
    sp: &SpillPartition,
) -> Result<Solution> {
    let solver = kind.build();
    let mut tiles = std::collections::HashSet::new();
    let mut unsatisfiable = 0usize;
    for (gi, group) in sp.groups.iter().enumerate() {
        let sub = group.subtable(table);
        kind.validate(&sub)
            .with_context(|| format!("spill group {gi} (cameras {:?})", group.cameras))?;
        let solution = match prev {
            Some(p) => solver.resolve(p, &sub),
            None => solver.solve(&sub),
        };
        unsatisfiable += solution.unsatisfiable;
        tiles.extend(solution.tiles);
    }
    for &ci in &sp.residual {
        if table.constraints[ci].regions.is_empty() {
            unsatisfiable += 1;
        }
    }
    Ok(Solution { tiles, unsatisfiable })
}

fn finish(table: &AssociationTable, solution: Solution) -> SolveArtifact {
    let masks = RoiMasks::from_solution(&table.tiling, &solution.tiles);
    SolveArtifact { solution, masks }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::association::table::Constraint;
    use crate::association::tiles::Tiling;

    fn toy_table() -> AssociationTable {
        AssociationTable {
            tiling: Tiling::new(1, 320, 192, 16),
            constraints: vec![
                Constraint { regions: vec![vec![1, 2], vec![10, 11, 12]] },
                Constraint { regions: vec![vec![1, 2]] },
            ],
            multiplicity: vec![1, 1],
            total_occurrences: 2,
        }
    }

    #[test]
    fn validate_rejects_large_instances_for_exact_only() {
        let small = toy_table();
        assert!(SolverKind::Greedy.validate(&small).is_ok());
        assert!(SolverKind::Exact.validate(&small).is_ok());
        let big = AssociationTable {
            tiling: Tiling::new(1, 320, 192, 16),
            constraints: (0..30)
                .map(|i| Constraint { regions: vec![vec![i]] })
                .collect(),
            multiplicity: vec![1; 30],
            total_occurrences: 30,
        };
        assert!(SolverKind::Greedy.validate(&big).is_ok());
        let err = SolverKind::Exact.validate(&big).unwrap_err();
        assert!(err.to_string().contains("--solver greedy"), "{err}");
    }

    #[test]
    fn kind_parses_and_names() {
        assert_eq!(SolverKind::parse("greedy").unwrap(), SolverKind::Greedy);
        assert_eq!(SolverKind::parse("exact").unwrap(), SolverKind::Exact);
        assert!(SolverKind::parse("simplex").is_err());
        assert_eq!(SolverKind::Greedy.name(), "greedy");
        assert_eq!(SolverKind::Exact.build().name(), "exact");
        assert_eq!(SolverKind::default(), SolverKind::Greedy);
    }

    #[test]
    fn greedy_and_exact_agree_on_the_toy_table() {
        let table = toy_table();
        let g = run(&table, SolverKind::Greedy.build().as_ref());
        let e = run(&table, SolverKind::Exact.build().as_ref());
        assert_eq!(g.solution.size(), 2);
        assert_eq!(e.solution.size(), 2, "greedy not certified by exact");
        assert_eq!(g.masks.total_size(), 2);
    }

    #[test]
    fn incremental_solve_reuses_the_previous_mask() {
        let table = toy_table();
        let solver = SolverKind::Greedy.build();
        let first = run(&table, solver.as_ref());
        let second = run_incremental(&table, solver.as_ref(), &first.solution);
        assert_eq!(first.solution.tiles, second.solution.tiles);
    }

    fn bridge_table() -> AssociationTable {
        // two tile-disjoint sides joined only through camera 1's frame
        // (left tile 240 vs right tile 300) — the spill splits them
        AssociationTable {
            tiling: Tiling::new(3, 320, 192, 16),
            constraints: vec![
                Constraint { regions: vec![vec![1, 2], vec![240]] },
                Constraint { regions: vec![vec![300], vec![481, 482]] },
                Constraint { regions: vec![vec![1, 2]] },
            ],
            multiplicity: vec![1, 1, 1],
            total_occurrences: 3,
        }
    }

    #[test]
    fn spilled_solve_matches_the_fused_solve() {
        let table = bridge_table();
        let sp = crate::offline::shard::spill(&table);
        assert_eq!(sp.groups.len(), 2);
        let fused = run(&table, SolverKind::Greedy.build().as_ref());
        let spilled = run_spilled(&table, SolverKind::Greedy, None, &sp).unwrap();
        assert_eq!(fused.solution.tiles, spilled.solution.tiles);
        assert_eq!(fused.solution.unsatisfiable, spilled.solution.unsatisfiable);
        for cam in 0..3 {
            assert_eq!(fused.masks.tiles[cam], spilled.masks.tiles[cam]);
        }
    }

    #[test]
    fn spilled_warm_start_matches_the_fused_warm_start() {
        let table = bridge_table();
        let sp = crate::offline::shard::spill(&table);
        let solver = SolverKind::Greedy.build();
        let prev = run(&table, solver.as_ref()).solution;
        let fused = run_incremental(&table, solver.as_ref(), &prev);
        let spilled = run_spilled(&table, SolverKind::Greedy, Some(&prev), &sp).unwrap();
        assert_eq!(fused.solution.tiles, spilled.solution.tiles);
    }

    #[test]
    fn spilled_exact_cap_applies_per_group() {
        // 30 tile-disjoint single-constraint groups: the fused table
        // exceeds the exact certifier's cap, the per-group instances all
        // fit it
        let table = AssociationTable {
            tiling: Tiling::new(1, 320, 192, 16),
            constraints: (0..30).map(|i| Constraint { regions: vec![vec![i]] }).collect(),
            multiplicity: vec![1; 30],
            total_occurrences: 30,
        };
        let sp = crate::offline::shard::spill(&table);
        assert_eq!(sp.groups.len(), 30);
        assert!(SolverKind::Exact.validate(&table).is_err());
        let solved = run_spilled(&table, SolverKind::Exact, None, &sp).unwrap();
        assert_eq!(solved.solution.size(), 30);
    }

    #[test]
    fn spilled_residual_counts_unsatisfiable_constraints() {
        let table = AssociationTable {
            tiling: Tiling::new(1, 320, 192, 16),
            constraints: vec![
                Constraint { regions: vec![] },
                Constraint { regions: vec![vec![4]] },
            ],
            multiplicity: vec![1, 1],
            total_occurrences: 2,
        };
        let sp = crate::offline::shard::spill(&table);
        let spilled = run_spilled(&table, SolverKind::Greedy, None, &sp).unwrap();
        let fused = run(&table, SolverKind::Greedy.build().as_ref());
        assert_eq!(spilled.solution.unsatisfiable, 1);
        assert_eq!(spilled.solution.unsatisfiable, fused.solution.unsatisfiable);
        assert_eq!(spilled.solution.tiles, fused.solution.tiles);
    }
}
