//! Configuration system: typed scenario + system configs, a TOML-subset
//! loader (`parser`), and presets matching the paper's evaluation setup
//! (§5.1: 5 cameras, 10 fps, 60 s profile + 120 s eval, 30 Mbps / 10 ms,
//! 64 px tiles ≙ 16 px at our 320x192 working resolution).

pub mod parser;

use anyhow::{bail, Context, Result};

/// World/scenario configuration (the "dataset" knobs).
#[derive(Debug, Clone)]
pub struct ScenarioConfig {
    /// Master seed; every stochastic component forks from it.
    pub seed: u64,
    /// Number of cameras (paper scene: 5).
    pub n_cameras: usize,
    /// Frame rate.  The paper runs 10 fps at 1080p on 2× RTX 2080; this
    /// testbed runs 320×192 on a CPU PJRT client, so the rate is scaled
    /// to 5 fps to keep the Baseline *just above* the real-time line the
    /// way the paper's was (52 Hz vs a 50 Hz requirement) — see
    /// EXPERIMENTS.md §Scaling.
    pub fps: f64,
    /// Offline profiling window length in seconds (paper: first 60 s).
    pub profile_secs: f64,
    /// Online evaluation window length in seconds (paper: last 120 s).
    pub eval_secs: f64,
    /// Poisson vehicle arrival rate per approach arm (vehicles/s).
    pub arrival_rate: f64,
    /// Vehicle speed range (m/s).
    pub speed_min: f64,
    pub speed_max: f64,
    /// Fraction of trucks (larger boxes).
    pub truck_fraction: f64,
    /// RoI mask tile size in pixels (§5.1.3; 16 px ≙ paper's 64 px @1080p).
    pub tile_px: u32,
    /// Sensor noise std (u8 scale / 255).
    pub sensor_noise: f64,
    /// Traffic drift (the continuous re-profiling scenario, DESIGN.md §7):
    /// absolute scenario time in seconds at which the per-arm arrival mix
    /// flips between the two roads; `0.0` disables drift (the default —
    /// stationary traffic, byte-identical to pre-drift builds).
    pub drift_at_secs: f64,
    /// Drift magnitude in `[0, 1]`: before `drift_at_secs` the EW arms
    /// spawn at `(1 + s) ×` the base rate and the NS arms at `(1 − s) ×`;
    /// after, the roles swap — shifting object flow between the camera
    /// overlaps mid-run.  `1.0` silences the disfavoured road entirely.
    pub drift_strength: f64,
    /// Number of intersections laid out along the EW axis (fleet
    /// scenarios, CLI `--intersections`).  `1` (the default) is the
    /// single-intersection world, bit-identical to pre-fleet builds;
    /// above 1, `n_cameras` counts cameras *per intersection* and each
    /// intersection runs its own independent traffic world (seed
    /// `seed + k`) shifted `intersection_spacing` meters east.
    pub n_intersections: usize,
    /// Center-to-center spacing between adjacent intersections (m).  Must
    /// exceed twice the approach-arm length so neither the vehicles nor
    /// the per-intersection rigs of adjacent intersections ever share a
    /// view — the co-occurrence partition then recovers one component per
    /// intersection.
    pub intersection_spacing: f64,
    /// Fleet scenarios only: add a corridor-watching trio per adjacent
    /// intersection pair (an east-facing camera at the west crossing, a
    /// west-facing one at the east crossing, and a **bridge camera**
    /// midway whose view overlaps both) — the bridge-camera topology the
    /// constraint spill (DESIGN.md §8) is tested on.
    pub bridge_cameras: bool,
    /// Which intersection the traffic drift perturbs: `-1` (default)
    /// drifts every intersection; `k ≥ 0` drifts only intersection `k`,
    /// leaving the others stationary — the single-intersection-drift
    /// scenario component-incremental re-planning re-solves selectively.
    pub drift_intersection: i64,
    /// Camera fault schedule (CLI `--fail cam@t[..t2]`, repeatable):
    /// each event silences one camera from `start_secs` of the **eval
    /// window** until `end_secs` (or the end of the run).  Empty (the
    /// default) disables fault injection entirely.
    pub faults: Vec<FaultEvent>,
    /// Rush-hour arrival waves (`--scenario rush-hour`): when positive,
    /// every arm's arrival rate oscillates with this period — the first
    /// half of each period runs hot, the second half cold.  `0` (the
    /// default) keeps arrivals stationary, bit-identical to pre-wave
    /// builds.
    pub rush_period_secs: f64,
    /// Membership-change scenario (`--scenario membership-change`): the
    /// EW arms of every intersection stay silent until this absolute
    /// scenario time, then activate — a corridor coming alive mid-run,
    /// fusing the bridge camera into the intersections' co-occurrence
    /// components.  `0` (the default) disables the gate.
    pub corridor_at_secs: f64,
}

/// One camera outage: the camera stops producing segments at
/// `start_secs` (eval-window clock) and, if `end_secs` is set, rejoins
/// there; `None` means it never comes back.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultEvent {
    pub cam: usize,
    pub start_secs: f64,
    pub end_secs: Option<f64>,
}

impl FaultEvent {
    /// Parse the CLI form `cam@t` (dropout) or `cam@t..t2` (dropout +
    /// rejoin), times in seconds into the eval window.
    pub fn parse(spec: &str) -> Result<FaultEvent> {
        let (cam, times) = spec
            .split_once('@')
            .with_context(|| format!("fault {spec:?}: expected cam@t or cam@t..t2"))?;
        let cam: usize =
            cam.parse().with_context(|| format!("fault {spec:?}: bad camera index"))?;
        let (start, end) = match times.split_once("..") {
            None => (times, None),
            Some((a, b)) => (a, Some(b)),
        };
        let start_secs: f64 =
            start.parse().with_context(|| format!("fault {spec:?}: bad start time"))?;
        let end_secs: Option<f64> = match end {
            None => None,
            Some(b) => {
                Some(b.parse().with_context(|| format!("fault {spec:?}: bad end time"))?)
            }
        };
        Ok(FaultEvent { cam, start_secs, end_secs })
    }
}

impl Default for ScenarioConfig {
    fn default() -> Self {
        ScenarioConfig {
            seed: 2021,
            n_cameras: 5,
            fps: 5.0,
            profile_secs: 60.0,
            eval_secs: 120.0,
            arrival_rate: 0.12,
            speed_min: 7.0,
            speed_max: 13.0,
            truck_fraction: 0.12,
            tile_px: 16,
            sensor_noise: 0.015,
            drift_at_secs: 0.0,
            drift_strength: 0.75,
            n_intersections: 1,
            intersection_spacing: 170.0,
            bridge_cameras: false,
            drift_intersection: -1,
            faults: Vec::new(),
            rush_period_secs: 0.0,
            corridor_at_secs: 0.0,
        }
    }
}

impl ScenarioConfig {
    pub fn total_secs(&self) -> f64 {
        self.profile_secs + self.eval_secs
    }

    pub fn total_frames(&self) -> usize {
        (self.total_secs() * self.fps).round() as usize
    }

    pub fn profile_frames(&self) -> usize {
        (self.profile_secs * self.fps).round() as usize
    }

    pub fn validate(&self) -> Result<()> {
        if self.n_cameras == 0 || self.n_cameras > 16 {
            bail!("n_cameras must be in 1..=16, got {}", self.n_cameras);
        }
        if self.fps <= 0.0 {
            bail!("fps must be positive");
        }
        if self.speed_min <= 0.0 || self.speed_max < self.speed_min {
            bail!("invalid speed range");
        }
        if !(0.0..=1.0).contains(&self.truck_fraction) {
            bail!("truck_fraction must be in [0,1]");
        }
        if self.tile_px == 0 {
            bail!("tile_px must be positive");
        }
        if self.drift_at_secs < 0.0 {
            bail!("drift_at_secs must be non-negative (0 disables drift)");
        }
        if !(0.0..=1.0).contains(&self.drift_strength) {
            bail!("drift_strength must be in [0,1]");
        }
        if self.n_intersections == 0 || self.n_intersections > 6 {
            bail!("n_intersections must be in 1..=6, got {}", self.n_intersections);
        }
        if self.n_intersections > 1 {
            // arms are ARM_LENGTH long on both sides; closer spacing would
            // let adjacent intersections' vehicles share camera views and
            // fuse the co-occurrence components
            let min_spacing = 2.0 * crate::sim::world::ARM_LENGTH + 8.0;
            if self.intersection_spacing < min_spacing {
                bail!(
                    "intersection_spacing must be at least {min_spacing} m \
                     (2 x arm length + margin), got {}",
                    self.intersection_spacing
                );
            }
            if self.total_cameras() > 24 {
                bail!(
                    "fleet of {} cameras too large (max 24): {} intersections x {} cameras{}",
                    self.total_cameras(),
                    self.n_intersections,
                    self.n_cameras,
                    if self.bridge_cameras { " + corridor trios" } else { "" }
                );
            }
        } else if self.bridge_cameras {
            bail!("bridge_cameras needs n_intersections > 1");
        }
        if self.drift_intersection < -1 || self.drift_intersection >= self.n_intersections as i64
        {
            bail!(
                "drift_intersection {} out of range (fleet has {} intersections; -1 = all)",
                self.drift_intersection,
                self.n_intersections
            );
        }
        for f in &self.faults {
            if f.cam >= self.total_cameras() {
                bail!(
                    "fault camera {} out of range (fleet has {} cameras)",
                    f.cam,
                    self.total_cameras()
                );
            }
            if !f.start_secs.is_finite() || f.start_secs < 0.0 {
                bail!("fault start time {} must be finite and non-negative", f.start_secs);
            }
            if let Some(end) = f.end_secs {
                if !end.is_finite() || end <= f.start_secs {
                    bail!("fault end time {end} must be finite and after start {}", f.start_secs);
                }
            }
        }
        if !self.rush_period_secs.is_finite() || self.rush_period_secs < 0.0 {
            bail!("rush_period_secs must be finite and non-negative (0 disables waves)");
        }
        if !self.corridor_at_secs.is_finite() || self.corridor_at_secs < 0.0 {
            bail!("corridor_at_secs must be finite and non-negative (0 disables the gate)");
        }
        Ok(())
    }

    /// Total cameras in the scenario: `n_cameras` per intersection, plus
    /// a corridor trio (east-watcher, west-watcher, bridge) per adjacent
    /// intersection pair when `bridge_cameras` is on.
    pub fn total_cameras(&self) -> usize {
        let gaps = self.n_intersections.saturating_sub(1);
        self.n_cameras * self.n_intersections
            + if self.bridge_cameras { 3 * gaps } else { 0 }
    }

    /// Set a field by dotted key (used by the TOML loader and CLI overrides).
    pub fn set(&mut self, key: &str, value: &parser::Value) -> Result<()> {
        match key {
            "seed" => self.seed = value.as_u64().context("seed")?,
            "n_cameras" => self.n_cameras = value.as_u64().context("n_cameras")? as usize,
            "fps" => self.fps = value.as_f64().context("fps")?,
            "profile_secs" => self.profile_secs = value.as_f64().context("profile_secs")?,
            "eval_secs" => self.eval_secs = value.as_f64().context("eval_secs")?,
            "arrival_rate" => self.arrival_rate = value.as_f64().context("arrival_rate")?,
            "speed_min" => self.speed_min = value.as_f64().context("speed_min")?,
            "speed_max" => self.speed_max = value.as_f64().context("speed_max")?,
            "truck_fraction" => self.truck_fraction = value.as_f64().context("truck_fraction")?,
            "tile_px" => self.tile_px = value.as_u64().context("tile_px")? as u32,
            "sensor_noise" => self.sensor_noise = value.as_f64().context("sensor_noise")?,
            "drift_at_secs" => self.drift_at_secs = value.as_f64().context("drift_at_secs")?,
            "drift_strength" => {
                self.drift_strength = value.as_f64().context("drift_strength")?
            }
            "n_intersections" => {
                self.n_intersections = value.as_u64().context("n_intersections")? as usize
            }
            "intersection_spacing" => {
                self.intersection_spacing = value.as_f64().context("intersection_spacing")?
            }
            "bridge_cameras" => {
                self.bridge_cameras = value.as_bool().context("bridge_cameras")?
            }
            "drift_intersection" => {
                let v = value.as_f64().context("drift_intersection")?;
                if v.fract() != 0.0 {
                    bail!("drift_intersection must be an integer, got {v}");
                }
                self.drift_intersection = v as i64;
            }
            "rush_period_secs" => {
                self.rush_period_secs = value.as_f64().context("rush_period_secs")?
            }
            "corridor_at_secs" => {
                self.corridor_at_secs = value.as_f64().context("corridor_at_secs")?
            }
            other => bail!("unknown scenario key {other:?}"),
        }
        Ok(())
    }
}

/// System configuration (the pipeline knobs the paper sweeps).
#[derive(Debug, Clone)]
pub struct SystemConfig {
    /// Shared camera→server bandwidth in Mbps.  The paper emulates a
    /// 30 Mbps WiFi for 1080p streams; our 320×192 streams carry ~1/17
    /// the bitrate, so the default link is scaled to 1.8 Mbps to preserve
    /// the paper's link utilization (≈0.85 for Baseline) and therefore
    /// its queueing behaviour — see EXPERIMENTS.md §Scaling.
    pub bandwidth_mbps: f64,
    /// Round-trip time in ms (paper: 10).
    pub rtt_ms: f64,
    /// Streaming segment length in seconds (paper default: 1 s, Fig. 11).
    pub segment_secs: f64,
    /// Codec quantization parameter (higher ⇒ smaller/worse).
    pub qp: f64,
    /// SVM filter kernel non-linearity γ (Fig. 9 sweep).  The paper's
    /// operating point is 1e-4 on 1080p-pixel features; ours is ~1 because
    /// features are pre-scaled to O(1) (γ scales with 1/feature-scale²).
    pub svm_gamma: f64,
    /// RANSAC residual threshold multiplier θ (θ·MAD; Fig. 10 sweep; this
    /// repo's operating point — see filters::ransac::RansacParams).
    pub ransac_theta: f64,
    /// Objectness threshold for the detector post-processor.
    pub objectness_threshold: f64,
    /// Directory with AOT HLO artifacts + meta.json.
    pub artifacts_dir: String,
    /// Reducto accuracy target; `None` disables frame filtering.
    pub reducto_target: Option<f64>,
}

impl Default for SystemConfig {
    fn default() -> Self {
        SystemConfig {
            bandwidth_mbps: 1.8,
            rtt_ms: 10.0,
            segment_secs: 1.0,
            qp: 6.0,
            svm_gamma: 1.0,
            ransac_theta: 0.5,
            objectness_threshold: 0.25,
            artifacts_dir: "artifacts".to_string(),
            reducto_target: None,
        }
    }
}

impl SystemConfig {
    pub fn validate(&self) -> Result<()> {
        if self.bandwidth_mbps <= 0.0 {
            bail!("bandwidth must be positive");
        }
        if self.segment_secs <= 0.0 {
            bail!("segment length must be positive");
        }
        if self.qp < 1.0 || self.qp > 50.0 {
            bail!("qp out of range [1, 50]");
        }
        if let Some(t) = self.reducto_target {
            if !(0.0..=1.0).contains(&t) {
                bail!("reducto target must be in [0,1]");
            }
        }
        Ok(())
    }

    pub fn set(&mut self, key: &str, value: &parser::Value) -> Result<()> {
        match key {
            "bandwidth_mbps" => self.bandwidth_mbps = value.as_f64().context("bandwidth_mbps")?,
            "rtt_ms" => self.rtt_ms = value.as_f64().context("rtt_ms")?,
            "segment_secs" => self.segment_secs = value.as_f64().context("segment_secs")?,
            "qp" => self.qp = value.as_f64().context("qp")?,
            "svm_gamma" => self.svm_gamma = value.as_f64().context("svm_gamma")?,
            "ransac_theta" => self.ransac_theta = value.as_f64().context("ransac_theta")?,
            "objectness_threshold" => {
                self.objectness_threshold = value.as_f64().context("objectness_threshold")?
            }
            "artifacts_dir" => {
                self.artifacts_dir = value.as_str().context("artifacts_dir")?.to_string()
            }
            "reducto_target" => self.reducto_target = Some(value.as_f64().context("reducto_target")?),
            other => bail!("unknown system key {other:?}"),
        }
        Ok(())
    }
}

/// Full configuration = scenario + system.
#[derive(Debug, Clone, Default)]
pub struct Config {
    pub scenario: ScenarioConfig,
    pub system: SystemConfig,
}

impl Config {
    /// Paper evaluation preset (§5.1).
    pub fn paper() -> Self {
        Config::default()
    }

    /// Small, fast preset for unit/integration tests.
    pub fn test_small() -> Self {
        let mut c = Config::default();
        c.scenario.profile_secs = 12.0;
        c.scenario.eval_secs = 8.0;
        c.scenario.arrival_rate = 0.25;
        c
    }

    /// Parse a TOML-subset document into a config.
    pub fn from_toml(text: &str) -> Result<Self> {
        let doc = parser::parse(text)?;
        let mut cfg = Config::default();
        for (section, key, value) in doc.entries() {
            match section {
                "scenario" => cfg.scenario.set(key, value)?,
                "system" => cfg.system.set(key, value)?,
                "" => bail!("top-level key {key:?} outside a section"),
                other => bail!("unknown section {other:?}"),
            }
        }
        cfg.scenario.validate()?;
        cfg.system.validate()?;
        Ok(cfg)
    }

    pub fn from_file(path: &str) -> Result<Self> {
        let text =
            std::fs::read_to_string(path).with_context(|| format!("reading config {path}"))?;
        Config::from_toml(&text)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_valid() {
        let c = Config::paper();
        c.scenario.validate().unwrap();
        c.system.validate().unwrap();
        assert_eq!(c.scenario.total_frames(), 900); // 180 s at 5 fps
        assert_eq!(c.scenario.profile_frames(), 300);
    }

    #[test]
    fn toml_roundtrip() {
        let cfg = Config::from_toml(
            r#"
            # paper-like scenario
            [scenario]
            seed = 7
            n_cameras = 3
            fps = 5.0

            [system]
            segment_secs = 2.0
            svm_gamma = 1e-3
            artifacts_dir = "artifacts"
            "#,
        )
        .unwrap();
        assert_eq!(cfg.scenario.seed, 7);
        assert_eq!(cfg.scenario.n_cameras, 3);
        assert_eq!(cfg.system.segment_secs, 2.0);
        assert!((cfg.system.svm_gamma - 1e-3).abs() < 1e-12);
    }

    #[test]
    fn rejects_unknown_keys_and_bad_values() {
        assert!(Config::from_toml("[scenario]\nbogus = 1").is_err());
        assert!(Config::from_toml("[nope]\nx = 1").is_err());
        assert!(Config::from_toml("[scenario]\nn_cameras = 0").is_err());
        assert!(Config::from_toml("[system]\nqp = 99").is_err());
    }

    #[test]
    fn fault_event_parsing() {
        assert_eq!(
            FaultEvent::parse("2@4.5").unwrap(),
            FaultEvent { cam: 2, start_secs: 4.5, end_secs: None }
        );
        assert_eq!(
            FaultEvent::parse("0@1..6").unwrap(),
            FaultEvent { cam: 0, start_secs: 1.0, end_secs: Some(6.0) }
        );
        assert!(FaultEvent::parse("nope").is_err());
        assert!(FaultEvent::parse("x@1").is_err());
        assert!(FaultEvent::parse("1@x").is_err());
        assert!(FaultEvent::parse("1@2..y").is_err());
    }

    #[test]
    fn fault_schedule_validation() {
        let mut c = ScenarioConfig::default();
        c.faults = vec![FaultEvent { cam: 1, start_secs: 3.0, end_secs: Some(9.0) }];
        c.validate().unwrap();
        c.faults[0].cam = 99;
        assert!(c.validate().is_err());
        c.faults[0] = FaultEvent { cam: 0, start_secs: -1.0, end_secs: None };
        assert!(c.validate().is_err());
        c.faults[0] = FaultEvent { cam: 0, start_secs: 5.0, end_secs: Some(4.0) };
        assert!(c.validate().is_err());
        c.faults.clear();
        c.rush_period_secs = -2.0;
        assert!(c.validate().is_err());
        c.rush_period_secs = 20.0;
        c.corridor_at_secs = f64::NAN;
        assert!(c.validate().is_err());
        c.corridor_at_secs = 30.0;
        c.validate().unwrap();
    }
}
