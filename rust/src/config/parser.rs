//! TOML-subset parser (the `toml` crate is unavailable offline).
//!
//! Supported: `[section]` headers, `key = value` with integers, floats
//! (including scientific notation), booleans, quoted strings, arrays of
//! scalars, `#` comments.  That covers every config this project reads;
//! unsupported TOML (dotted keys, tables-in-arrays, multiline strings)
//! fails loudly rather than misparsing.

use anyhow::{bail, Result};

/// A parsed scalar or array value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Int(i64),
    Float(f64),
    Bool(bool),
    Str(String),
    Arr(Vec<Value>),
}

impl Value {
    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Value::Int(i) => Ok(*i as f64),
            Value::Float(f) => Ok(*f),
            other => bail!("expected number, got {other:?}"),
        }
    }

    pub fn as_u64(&self) -> Result<u64> {
        match self {
            Value::Int(i) if *i >= 0 => Ok(*i as u64),
            other => bail!("expected non-negative integer, got {other:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Value::Bool(b) => Ok(*b),
            other => bail!("expected bool, got {other:?}"),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            other => bail!("expected string, got {other:?}"),
        }
    }
}

/// A parsed document: ordered `(section, key, value)` triples.
#[derive(Debug, Clone, Default)]
pub struct Document {
    entries: Vec<(String, String, Value)>,
}

impl Document {
    pub fn entries(&self) -> impl Iterator<Item = (&str, &str, &Value)> {
        self.entries.iter().map(|(s, k, v)| (s.as_str(), k.as_str(), v))
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.entries
            .iter()
            .find(|(s, k, _)| s == section && k == key)
            .map(|(_, _, v)| v)
    }
}

/// Parse a TOML-subset document.
pub fn parse(text: &str) -> Result<Document> {
    let mut doc = Document::default();
    let mut section = String::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let Some(name) = rest.strip_suffix(']') else {
                bail!("line {}: malformed section header {line:?}", lineno + 1);
            };
            section = name.trim().to_string();
            if section.is_empty() {
                bail!("line {}: empty section name", lineno + 1);
            }
            continue;
        }
        let Some(eq) = line.find('=') else {
            bail!("line {}: expected key = value, got {line:?}", lineno + 1);
        };
        let key = line[..eq].trim();
        if key.is_empty() {
            bail!("line {}: empty key", lineno + 1);
        }
        let value = parse_value(line[eq + 1..].trim())
            .map_err(|e| anyhow::anyhow!("line {}: {e}", lineno + 1))?;
        doc.entries.push((section.clone(), key.to_string(), value));
    }
    Ok(doc)
}

fn strip_comment(line: &str) -> &str {
    // a '#' inside a quoted string does not start a comment
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value> {
    let s = s.trim();
    if s.is_empty() {
        bail!("empty value");
    }
    if let Some(body) = s.strip_prefix('[') {
        let Some(body) = body.strip_suffix(']') else {
            bail!("unterminated array {s:?}");
        };
        let mut items = Vec::new();
        for part in split_top_level(body) {
            let part = part.trim();
            if !part.is_empty() {
                items.push(parse_value(part)?);
            }
        }
        return Ok(Value::Arr(items));
    }
    if let Some(body) = s.strip_prefix('"') {
        let Some(body) = body.strip_suffix('"') else {
            bail!("unterminated string {s:?}");
        };
        return Ok(Value::Str(body.replace("\\\"", "\"").replace("\\\\", "\\")));
    }
    match s {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    if !s.contains(['.', 'e', 'E']) {
        if let Ok(i) = s.replace('_', "").parse::<i64>() {
            return Ok(Value::Int(i));
        }
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    bail!("cannot parse value {s:?}")
}

/// Split on commas that are not nested in quotes (arrays are flat here).
fn split_top_level(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut start = 0;
    let mut in_str = false;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            ',' if !in_str => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let doc = parse(
            r#"
            top = 1          # comment
            [a]
            x = 42
            y = -1.5e2
            flag = true
            name = "hello # not a comment"
            arr = [1, 2.5, "three"]
            [b]
            x = 0
            "#,
        )
        .unwrap();
        assert_eq!(doc.get("", "top"), Some(&Value::Int(1)));
        assert_eq!(doc.get("a", "x"), Some(&Value::Int(42)));
        assert_eq!(doc.get("a", "y"), Some(&Value::Float(-150.0)));
        assert_eq!(doc.get("a", "flag"), Some(&Value::Bool(true)));
        assert_eq!(
            doc.get("a", "name").unwrap().as_str().unwrap(),
            "hello # not a comment"
        );
        match doc.get("a", "arr").unwrap() {
            Value::Arr(items) => assert_eq!(items.len(), 3),
            other => panic!("expected array, got {other:?}"),
        }
        assert_eq!(doc.get("b", "x"), Some(&Value::Int(0)));
    }

    #[test]
    fn errors_are_loud() {
        assert!(parse("[unclosed").is_err());
        assert!(parse("key").is_err());
        assert!(parse("k = ").is_err());
        assert!(parse("k = \"unterminated").is_err());
        assert!(parse("k = [1, 2").is_err());
    }

    #[test]
    fn value_coercions() {
        assert_eq!(Value::Int(3).as_f64().unwrap(), 3.0);
        assert!(Value::Str("x".into()).as_f64().is_err());
        assert!(Value::Int(-1).as_u64().is_err());
    }
}
