//! The stage-parallel online streaming pipeline (§4.1.2).
//!
//! The online phase is an explicit staged pipeline with typed
//! inter-stage records:
//!
//! ```text
//!   per camera (worker thread)          server side (caller thread)
//!   ┌─────────┐ ┌────────┐ ┌────────┐   ┌───────────┐ ┌───────────┐ ┌──────┐
//!   │ Capture │→│ Filter │→│ Encode │→ →│   Infer   │→│ Transport │→│ Query│
//!   └─────────┘ └────────┘ └────────┘ ↗ │ (batched) │ │ (DES)     │ └──────┘
//!      camera 2 ────────────────────── ↗└───────────┘ └───────────┘
//!      camera N ──────────────────────
//! ```
//!
//! * **[`CaptureStage`]** renders a camera's frames into reusable buffers
//!   ([`SimCapture`]).
//! * **[`FilterStage`]** owns the per-camera keep/drop state
//!   ([`ReductoFilterStage`] / [`PassThroughFilter`]).
//! * **[`EncodeStage`]** drives the block codec over the kept frames —
//!   borrowed, never cloned ([`CodecEncodeStage`]).
//! * **[`InferStage`]** consumes the merged queue of all cameras'
//!   segments and batches kept frames per [`Infer::infer_batch`] call
//!   ([`BatchedInfer`]).
//! * **[`TransportStage`]** replays the measured service times on the
//!   discrete-event engine ([`DesTransport`]).
//! * **[`QueryStage`]** fuses per-camera results, carrying inference
//!   results over filtered frames ([`CarryOverQuery`]).
//!
//! Scheduling lives in [`run_pipeline`]: camera chains run on scoped
//! worker threads ([`Parallelism::PerCamera`] by default) and results are
//! re-canonicalized so `MethodReport`s are bit-identical across thread
//! counts.  New stages (codecs, filters, schedulers) plug in here without
//! touching the coordinator.
//!
//! **Continuous re-profiling** ([`replan`], DESIGN.md §7): with a
//! [`ReplanPolicy`] other than `Never`, [`run_pipeline_with_replan`] runs
//! an [`EpochPlanner`] beside the stage workers; workers swap codec
//! regions and RoI masks at fixed segment-indexed epoch boundaries from
//! the shared [`PlanSchedule`], so masks follow traffic drift without
//! stalling the pipeline or breaking schedule determinism.

pub mod arena;
pub mod canvas;
pub mod capture;
pub mod encode;
pub mod filter;
pub mod infer;
pub mod query;
pub mod replan;
pub mod runner;
pub mod stage;
pub mod transport;

pub use arena::{Arena, ArenaStats, FramePool};
pub use canvas::{consolidation_active, CanvasTally, ConsolidateMode};
pub use capture::SimCapture;
pub use encode::{CodecEncodeStage, EncodeCost};
pub use filter::{PassThroughFilter, ReductoFilterStage};
#[cfg(feature = "pjrt")]
pub use infer::RuntimeInfer;
pub use infer::{
    infer_route, use_roi_path, BatchedInfer, Infer, InferOutcome, InferRequest, InferRoute,
    InferStage, NativeInfer, DENSE_FALLBACK_FRACTION,
};
pub use query::{CarryOverQuery, QueryStage};
pub use replan::{
    EpochPlanner, FaultContext, FaultSchedule, FaultTimeline, LivenessMonitor, PlanEpoch,
    PlanSchedule, ReplanPolicy, ReplanScope, Silence,
};
pub use runner::{
    run_pipeline, run_pipeline_faulted, run_pipeline_in, run_pipeline_with_replan, CameraStages,
    Parallelism, PipelineOptions, PipelineOutput, ReplanContext,
};
pub use stage::{
    CameraSegment, CaptureStage, EncodeStage, FilterStage, InferJob, SegmentLayout,
    SegmentRecord,
};
pub use transport::{DesTransport, LatencySamples, TransportStage};
