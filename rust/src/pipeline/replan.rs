//! Continuous re-profiling: the pipeline-side plumbing that lets the
//! online phase swap RoI plans at segment boundaries without stalling the
//! stage workers (§3.1's concession that traffic patterns drift and the
//! masks must be re-derived; ReXCam adapts its correlation model online
//! the same way).
//!
//! The run is divided into fixed **planning epochs** of
//! [`PlanSchedule::check_every`] segments.  Epoch 0 is the initial
//! offline plan; every later epoch's plan is produced by an
//! [`EpochPlanner`] (the coordinator installs
//! `offline::replan::Replanner`) and published into the shared
//! [`PlanSchedule`].  Camera workers look their epoch up at each segment
//! boundary and swap the encode regions / RoI mask only when the plan
//! actually changed; the server-side inference stage resolves each
//! incoming segment's epoch the same way.  Because epoch boundaries are
//! fixed segment indices and every epoch plan is a pure function of the
//! scenario and the policy — never of worker timing — a run with
//! re-profiling on is byte-identical across thread counts
//! (`rust/tests/replan.rs`).
//!
//! The planner runs **concurrently** with the stage workers (a dedicated
//! scoped thread under parallel schedules, inline pre-computation under
//! [`crate::pipeline::Parallelism::Sequential`]); a worker only blocks on
//! [`PlanSchedule::wait`] in the degenerate case where it reaches a
//! boundary before the planner has published that epoch.

use std::collections::BTreeSet;
use std::sync::Arc;

use anyhow::Result;

use crate::config::FaultEvent;
use crate::net::Des;
use crate::util::geometry::IRect;
use crate::util::sync::EpochTable;

/// When to re-derive the RoI plan during the online phase
/// (CLI: `--replan-every` / `--replan-drift`).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub enum ReplanPolicy {
    /// Plan once offline and keep the masks for the whole run (the
    /// historical behaviour; the default).
    #[default]
    Never,
    /// Re-plan at every epoch boundary, i.e. every `n` segments.
    Every(usize),
    /// Check the sliding window every `check_every` segments but only
    /// re-solve when the constraint drift — the fraction of the new
    /// window's association constraints absent from the previous window —
    /// reaches `threshold`.
    Drift { check_every: usize, threshold: f64 },
}

impl ReplanPolicy {
    /// Default check cadence (segments) when `--replan-drift` is given
    /// without `--replan-every`.
    pub const DEFAULT_CHECK_EVERY: usize = 4;

    /// Segments per planning epoch (`None` for [`ReplanPolicy::Never`]).
    pub fn check_every(&self) -> Option<usize> {
        match self {
            ReplanPolicy::Never => None,
            ReplanPolicy::Every(n) => Some((*n).max(1)),
            ReplanPolicy::Drift { check_every, .. } => Some((*check_every).max(1)),
        }
    }
}

/// What one re-plan instance covers (CLI: `--replan-scope`, DESIGN.md
/// §8): the whole fleet as one window, or — the default — each
/// co-occurrence component independently, so only drifted components pay
/// a re-solve and quiescent ones carry their sub-plan forward.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ReplanScope {
    /// Per-component drift, filtering and warm-started solves; quiescent
    /// components carry forward untouched.
    #[default]
    Component,
    /// One fleet-wide window and one fleet-wide fire/carry decision per
    /// epoch (the historical behaviour).
    Fleet,
}

impl ReplanScope {
    pub fn parse(name: &str) -> anyhow::Result<ReplanScope> {
        Ok(match name {
            "component" => ReplanScope::Component,
            "fleet" => ReplanScope::Fleet,
            other => anyhow::bail!("unknown replan scope {other:?} (expected fleet|component)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            ReplanScope::Component => "component",
            ReplanScope::Fleet => "fleet",
        }
    }
}

/// One planning epoch's per-camera artifacts — everything the online
/// stages need from a plan (the `RoiMask` derivatives: codec regions,
/// detector blocks, the RoI-vs-dense policy).
#[derive(Debug, Clone)]
pub struct PlanEpoch {
    /// Codec regions per camera (what the encode stage crops and the
    /// capture mask keeps).
    pub groups: Vec<Vec<IRect>>,
    /// Active detector blocks per camera (the RoI HLO variant's input).
    pub blocks: Vec<Vec<i32>>,
    /// Whether each camera takes the SBNet RoI inference path this epoch.
    pub use_roi: Vec<bool>,
    /// Planning epoch at which each camera's regions last **changed**
    /// (content-compared, so a component-scoped re-plan that left a
    /// camera's plan intact keeps its stamp).  Workers swap codec
    /// regions — and reset the codec's motion reference — only when this
    /// stamp moves, so cameras of carried components keep their encoder
    /// state across other components' re-plans.
    pub cam_epoch: Vec<usize>,
    /// Per-camera Reducto frame-filter thresholds for this epoch (`None`
    /// when the method runs without frame filtering).  Re-derived from
    /// the sliding window whenever a re-plan changes a camera's regions.
    pub thresholds: Option<Vec<f64>>,
    /// |M| of this epoch's masks (diagnostics).
    pub mask_tiles: usize,
}

impl PlanEpoch {
    /// Epoch 0: the initial offline plan's artifacts with every camera's
    /// change stamp at 0 — the one construction the coordinator, tests
    /// and benches share.
    pub fn initial(
        groups: Vec<Vec<IRect>>,
        blocks: Vec<Vec<i32>>,
        use_roi: Vec<bool>,
        thresholds: Option<Vec<f64>>,
        mask_tiles: usize,
    ) -> PlanEpoch {
        let n_cams = groups.len();
        PlanEpoch { groups, blocks, use_roi, cam_epoch: vec![0; n_cams], thresholds, mask_tiles }
    }
}

/// Produces the plan of each epoch `k ≥ 1`, in order, given the previous
/// epoch's plan.  Implementations may return `prev` unchanged (an
/// `Arc` clone) when their policy decides the window has not drifted —
/// workers detect the pointer identity and skip the swap.
///
/// `start_seg` is the epoch's first segment **as the runner's
/// [`PlanSchedule`] defines it** — the schedule is the single source of
/// truth for boundaries, so a planner must derive its profile window and
/// trigger timestamps from this argument, never from its own cadence
/// copy.
pub trait EpochPlanner: Sync {
    fn plan_epoch(
        &self,
        k: usize,
        start_seg: usize,
        prev: &Arc<PlanEpoch>,
    ) -> Result<Arc<PlanEpoch>>;
}

/// The shared epoch → plan table: fixed boundaries, plans filled in as
/// the planner publishes them.  Epoch boundaries are segment indices
/// (`epoch = seg / check_every`), so pickup is atomic *between* segments
/// by construction — a worker never changes plan mid-segment.
///
/// Storage and blocking live in [`EpochTable`] (`util::sync`), the
/// loom-modeled write-once slot table; this type adds the segment ↔
/// epoch arithmetic and the epoch-0 bootstrap.
pub struct PlanSchedule {
    check_every: usize,
    epochs: EpochTable<PlanEpoch>,
}

impl PlanSchedule {
    /// Schedule for a run of `n_segments` per camera with epoch length
    /// `check_every`; epoch 0 is published immediately with the initial
    /// offline plan.
    pub fn new(n_segments: usize, check_every: usize, initial: PlanEpoch) -> PlanSchedule {
        let check_every = check_every.max(1);
        let n_epochs = n_segments.div_ceil(check_every).max(1);
        let sched = PlanSchedule { check_every, epochs: EpochTable::new(n_epochs) };
        sched.publish(0, Arc::new(initial));
        sched
    }

    /// Segments per epoch.
    pub fn check_every(&self) -> usize {
        self.check_every
    }

    pub fn n_epochs(&self) -> usize {
        self.epochs.len()
    }

    /// Epoch owning segment `seg`.
    pub fn epoch_of(&self, seg: usize) -> usize {
        (seg / self.check_every).min(self.epochs.len() - 1)
    }

    /// First segment of epoch `k`.
    pub fn start_seg(&self, k: usize) -> usize {
        k * self.check_every
    }

    /// Publish epoch `k`'s plan, waking every worker blocked on it.
    /// Re-publishing an epoch is a no-op (first write wins), so an error
    /// path may flood the remaining epochs with the last good plan
    /// without racing the planner.
    pub fn publish(&self, k: usize, plan: Arc<PlanEpoch>) {
        self.epochs.publish(k, plan);
    }

    /// Epoch `k`'s plan, blocking until published.
    pub fn wait(&self, k: usize) -> Arc<PlanEpoch> {
        self.epochs.wait(k)
    }

    /// Epoch `k`'s plan if already published (the server side only sees
    /// segments whose epoch the camera worker already picked up).
    pub fn get(&self, k: usize) -> Option<Arc<PlanEpoch>> {
        self.epochs.get(k)
    }
}

// ---- fault injection & liveness ----------------------------------------
//
// A `--fail cam@t[..t2]` schedule is resolved once, up front, onto the
// run's segment grid: which segments each camera fails to deliver, when
// the coordinator can first *know* (the first missed segment deadline),
// which planning epoch repairs the coverage hole, and which epoch
// re-admits a rejoining camera.  Everything below is a pure function of
// the config and the grid — never of worker timing — which is what keeps
// fault handling inside the byte-identity contract: the planner, the
// camera workers and the server-side inference all consult the same
// timeline instead of reacting to live arrivals.  The DES-driven
// `LivenessMonitor` closes the loop after the run by replaying the
// recorded arrivals against the same deadlines and confirming the
// timeline's predicted silences are exactly the ones the transport saw.

/// One fault's resolved schedule on the segment grid.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultSchedule {
    /// Camera index.
    pub cam: usize,
    /// Fault onset (eval-window seconds, straight from the config).
    pub fail_secs: f64,
    /// First segment the camera fails to deliver.
    pub down_from: usize,
    /// First segment delivered again (`None`: down for the rest of the
    /// run, or the configured rejoin lands past the last segment).
    pub up_from: Option<usize>,
    /// When the liveness monitor detects the silence: the deadline of
    /// the first missed segment, `(down_from + 1) * segment_secs`.
    pub detect_secs: f64,
    /// `detect_secs - fail_secs`.
    pub detect_latency: f64,
    /// Epoch whose plan re-covers the orphaned tiles (`None`: the run
    /// ends before another epoch boundary; surviving peers degrade to
    /// full-frame for the remainder instead).
    pub repair_epoch: Option<usize>,
    /// Epoch that re-admits the camera after `up_from` (`None`: no
    /// rejoin, or no boundary left).
    pub rejoin_epoch: Option<usize>,
}

impl FaultSchedule {
    /// Repair latency in epochs from the epoch that was current at
    /// detection (always 1 when a repair epoch exists: the next
    /// boundary).
    pub fn repair_latency_epochs(&self, check_every: usize) -> usize {
        match self.repair_epoch {
            Some(k) => k.saturating_sub(self.down_from / check_every.max(1)),
            None => 0,
        }
    }
}

/// The full fault schedule resolved onto one run's segment grid: per
///-(camera, segment) down/degraded flags plus the per-epoch repair and
/// rejoin obligations the planner must honour.
#[derive(Debug, Clone, Default)]
pub struct FaultTimeline {
    n_segments: usize,
    frames_per_segment: usize,
    eval_start: usize,
    segment_secs: f64,
    check_every: usize,
    /// `down[cam][seg]`: the camera delivers nothing for this segment.
    down: Vec<Vec<bool>>,
    /// `degraded[cam][seg]`: the camera streams full-frame (capture mask
    /// and frame filter off) while waiting for a repair plan.
    degraded: Vec<Vec<bool>>,
    schedules: Vec<FaultSchedule>,
    /// Cameras whose component must fire at each epoch (sorted, deduped).
    force_fire: Vec<Vec<usize>>,
}

impl FaultTimeline {
    /// Resolve `faults` onto a grid of `n_segments` segments of
    /// `frames_per_segment` frames at `fps`, with planning epochs of
    /// `check_every` segments.  `eval_start` is the absolute frame the
    /// eval window (and fault clock) starts at; `components` is the
    /// initial co-occurrence partition (a dead camera's peers — the
    /// cameras that can re-cover its tiles — are its component members).
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        faults: &[FaultEvent],
        n_cams: usize,
        n_segments: usize,
        frames_per_segment: usize,
        fps: f64,
        check_every: usize,
        eval_start: usize,
        components: &[Vec<usize>],
    ) -> FaultTimeline {
        let check_every = check_every.max(1);
        let n_epochs = n_segments.div_ceil(check_every).max(1);
        let segment_secs = frames_per_segment as f64 / fps;
        let mut down = vec![vec![false; n_segments]; n_cams];
        let mut degraded = vec![vec![false; n_segments]; n_cams];
        let mut schedules = Vec::new();
        let mut force: Vec<BTreeSet<usize>> = vec![BTreeSet::new(); n_epochs];
        for f in faults {
            // a segment is lost iff the outage covers its start
            let down_from = (f.start_secs / segment_secs).ceil() as usize;
            let up_raw = f.end_secs.map(|e| (e / segment_secs).ceil() as usize);
            let down_until = up_raw.unwrap_or(n_segments).min(n_segments);
            if down_from >= down_until {
                continue; // the outage falls between segment boundaries
            }
            for s in down_from..down_until {
                down[f.cam][s] = true;
            }
            let epoch_at_detection = (down_from / check_every).min(n_epochs - 1);
            let repair_epoch = (epoch_at_detection + 1 < n_epochs).then_some(epoch_at_detection + 1);
            let up_from = up_raw.filter(|&u| u < n_segments);
            let rejoin_epoch = up_from.and_then(|u| {
                let k = u.div_ceil(check_every).max(1);
                (k < n_epochs).then_some(k)
            });
            // Surviving peers stream full-frame from the segment after
            // detection until the repair plan lands (or the run ends).
            let component = components.iter().find(|c| c.contains(&f.cam));
            let repair_start =
                repair_epoch.map_or(n_segments, |k| (k * check_every).min(n_segments));
            if let Some(comp) = component {
                for &p in comp.iter().filter(|&&p| p != f.cam) {
                    for s in (down_from + 1).min(n_segments)..repair_start {
                        degraded[p][s] = true;
                    }
                }
            }
            // A re-admitted camera streams full-frame until its
            // re-derived plan (and Reducto threshold) lands.
            if let Some(u) = up_from {
                let rejoin_start =
                    rejoin_epoch.map_or(n_segments, |k| (k * check_every).min(n_segments));
                for s in u..rejoin_start {
                    degraded[f.cam][s] = true;
                }
            }
            let members: Vec<usize> = component.cloned().unwrap_or_else(|| vec![f.cam]);
            if let Some(k) = repair_epoch {
                force[k].extend(members.iter().copied());
            }
            if let Some(k) = rejoin_epoch {
                force[k].extend(members.iter().copied());
            }
            schedules.push(FaultSchedule {
                cam: f.cam,
                fail_secs: f.start_secs,
                down_from,
                up_from,
                detect_secs: (down_from + 1) as f64 * segment_secs,
                detect_latency: (down_from + 1) as f64 * segment_secs - f.start_secs,
                repair_epoch,
                rejoin_epoch,
            });
        }
        FaultTimeline {
            n_segments,
            frames_per_segment,
            eval_start,
            segment_secs,
            check_every,
            down,
            degraded,
            schedules,
            force_fire: force.into_iter().map(|s| s.into_iter().collect()).collect(),
        }
    }

    /// No fault ever materialises on this grid.
    pub fn is_empty(&self) -> bool {
        self.schedules.is_empty()
    }

    /// The camera delivers nothing for this segment.
    pub fn down_seg(&self, cam: usize, seg: usize) -> bool {
        self.down.get(cam).and_then(|v| v.get(seg)).copied().unwrap_or(false)
    }

    /// The camera streams full-frame (capture mask and frame filter off)
    /// for this segment, waiting for a repair or re-admission plan.
    pub fn degraded_seg(&self, cam: usize, seg: usize) -> bool {
        self.degraded.get(cam).and_then(|v| v.get(seg)).copied().unwrap_or(false)
    }

    /// Whether an absolute scenario frame falls in one of the camera's
    /// down segments, for profile-window filtering: a dead camera's
    /// frames contribute no constraints.  Frames before the eval window
    /// (the fault clock's origin) are never down.
    pub fn down_frame(&self, cam: usize, abs_frame: usize) -> bool {
        if abs_frame < self.eval_start || self.frames_per_segment == 0 {
            return false;
        }
        self.down_seg(cam, (abs_frame - self.eval_start) / self.frames_per_segment)
    }

    /// Cameras whose current component must fire at epoch `k` (sorted,
    /// deduped): the members of every component owing a repair or a
    /// rejoin at this boundary.
    pub fn force_fire_cams(&self, k: usize) -> &[usize] {
        self.force_fire.get(k).map(Vec::as_slice).unwrap_or_default()
    }

    /// Does any repair or rejoin land at epoch `k`?
    pub fn has_event_at(&self, k: usize) -> bool {
        self.force_fire.get(k).is_some_and(|v| !v.is_empty())
    }

    /// Dropout repairs landing at epoch `k`.
    pub fn repairs_at(&self, k: usize) -> impl Iterator<Item = &FaultSchedule> {
        self.schedules.iter().filter(move |s| s.repair_epoch == Some(k))
    }

    /// Rejoin re-admissions landing at epoch `k`.
    pub fn rejoins_at(&self, k: usize) -> impl Iterator<Item = &FaultSchedule> {
        self.schedules.iter().filter(move |s| s.rejoin_epoch == Some(k))
    }

    /// Every materialised fault, in config order.
    pub fn schedules(&self) -> &[FaultSchedule] {
        &self.schedules
    }

    pub fn segment_secs(&self) -> f64 {
        self.segment_secs
    }

    pub fn check_every(&self) -> usize {
        self.check_every
    }

    pub fn n_segments(&self) -> usize {
        self.n_segments
    }
}

/// What the camera workers need to act the faults out: the resolved
/// timeline plus the full-frame rect degraded cameras fall back to.
#[derive(Debug, Clone)]
pub struct FaultContext {
    pub timeline: Arc<FaultTimeline>,
    /// The whole frame, as a codec region (degraded cameras encode it).
    pub full_frame: IRect,
}

/// One detected silence: camera `cam` missed segment `seg`'s deadline.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Silence {
    pub cam: usize,
    pub seg: usize,
    /// Virtual time the deadline fired.
    pub deadline: f64,
}

/// Segment-deadline liveness monitor, DES-driven: every camera owes one
/// segment per `segment_secs` window, and a deadline that fires before
/// that segment was seen is a silence.  The coordinator replays the
/// recorded arrivals through this after the run, as an end-to-end check
/// that the config-derived [`FaultTimeline`] matches what the DES replay
/// actually delivered (and unit tests drive it directly).
pub struct LivenessMonitor {
    des: Des<LivenessEvent>,
    n_cams: usize,
    n_segments: usize,
    segment_secs: f64,
    delivered: Vec<Vec<bool>>,
}

#[derive(Debug)]
enum LivenessEvent {
    Seen { cam: usize, seg: usize },
    Deadline { cam: usize, seg: usize },
}

impl LivenessMonitor {
    pub fn new(n_cams: usize, n_segments: usize, segment_secs: f64) -> LivenessMonitor {
        LivenessMonitor {
            des: Des::new(),
            n_cams,
            n_segments,
            segment_secs,
            delivered: vec![vec![false; n_segments]; n_cams],
        }
    }

    /// Record a delivered segment at its `capture_end` timestamp.
    pub fn observe(&mut self, cam: usize, seg: usize, capture_end: f64) {
        if cam < self.n_cams && seg < self.n_segments {
            self.des.at(capture_end, LivenessEvent::Seen { cam, seg });
        }
    }

    /// Run the deadlines and return every silence in event-time order
    /// (per-camera runs of consecutive silent segments; the first entry
    /// of a run is the detection).  Deadlines are scheduled *after* the
    /// observations so a segment whose `capture_end` lands exactly on
    /// its deadline counts as delivered — the DES breaks time ties by
    /// insertion sequence.
    pub fn silences(mut self) -> Vec<Silence> {
        for cam in 0..self.n_cams {
            for seg in 0..self.n_segments {
                self.des
                    .at((seg + 1) as f64 * self.segment_secs, LivenessEvent::Deadline { cam, seg });
            }
        }
        let mut out = Vec::new();
        while let Some((t, ev)) = self.des.pop() {
            match ev {
                LivenessEvent::Seen { cam, seg } => self.delivered[cam][seg] = true,
                LivenessEvent::Deadline { cam, seg } => {
                    if !self.delivered[cam][seg] {
                        out.push(Silence { cam, seg, deadline: t });
                    }
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn epoch(tiles: usize) -> PlanEpoch {
        PlanEpoch {
            groups: vec![vec![IRect::new(0, 0, 16, 16)]],
            blocks: vec![vec![0]],
            use_roi: vec![true],
            cam_epoch: vec![0],
            thresholds: None,
            mask_tiles: tiles,
        }
    }

    #[test]
    fn policy_cadence() {
        assert_eq!(ReplanPolicy::Never.check_every(), None);
        assert_eq!(ReplanPolicy::Every(3).check_every(), Some(3));
        assert_eq!(ReplanPolicy::Every(0).check_every(), Some(1));
        assert_eq!(
            ReplanPolicy::Drift { check_every: 5, threshold: 0.2 }.check_every(),
            Some(5)
        );
        assert_eq!(ReplanPolicy::default(), ReplanPolicy::Never);
    }

    #[test]
    fn scope_parses_and_names() {
        assert_eq!(ReplanScope::parse("fleet").unwrap(), ReplanScope::Fleet);
        assert_eq!(ReplanScope::parse("component").unwrap(), ReplanScope::Component);
        assert!(ReplanScope::parse("shard").is_err());
        assert_eq!(ReplanScope::Fleet.name(), "fleet");
        assert_eq!(ReplanScope::Component.name(), "component");
        assert_eq!(ReplanScope::default(), ReplanScope::Component);
    }

    #[test]
    fn epoch_boundaries_are_segment_indexed() {
        let s = PlanSchedule::new(10, 4, epoch(1));
        assert_eq!(s.n_epochs(), 3);
        assert_eq!(s.epoch_of(0), 0);
        assert_eq!(s.epoch_of(3), 0);
        assert_eq!(s.epoch_of(4), 1);
        assert_eq!(s.epoch_of(9), 2);
        // segments past the last boundary stay in the last epoch
        assert_eq!(s.epoch_of(40), 2);
        assert_eq!(s.start_seg(2), 8);
    }

    #[test]
    fn initial_epoch_is_published() {
        let s = PlanSchedule::new(4, 2, epoch(7));
        assert_eq!(s.wait(0).mask_tiles, 7);
        assert!(s.get(1).is_none());
    }

    #[test]
    fn publish_is_first_write_wins() {
        let s = PlanSchedule::new(4, 2, epoch(1));
        s.publish(1, Arc::new(epoch(2)));
        s.publish(1, Arc::new(epoch(3)));
        assert_eq!(s.get(1).unwrap().mask_tiles, 2);
    }

    #[test]
    fn wait_blocks_until_published() {
        let s = PlanSchedule::new(6, 3, epoch(1));
        std::thread::scope(|scope| {
            let waiter = scope.spawn(|| s.wait(1).mask_tiles);
            std::thread::sleep(std::time::Duration::from_millis(10));
            s.publish(1, Arc::new(epoch(9)));
            assert_eq!(waiter.join().unwrap(), 9);
        });
    }

    #[test]
    fn one_segment_run_has_one_epoch() {
        let s = PlanSchedule::new(1, 8, epoch(1));
        assert_eq!(s.n_epochs(), 1);
        assert_eq!(s.epoch_of(0), 0);
    }

    fn fault(cam: usize, start: f64, end: Option<f64>) -> FaultEvent {
        FaultEvent { cam, start_secs: start, end_secs: end }
    }

    // 3 cams in one component, 12 one-second segments, epochs of 4,
    // eval window starting at absolute frame 900 (30 fps).
    fn timeline(faults: &[FaultEvent]) -> FaultTimeline {
        FaultTimeline::new(faults, 3, 12, 30, 30.0, 4, 900, &[vec![0, 1, 2]])
    }

    #[test]
    fn dropout_schedule_on_the_segment_grid() {
        let t = timeline(&[fault(1, 4.5, None)]);
        let s = &t.schedules()[0];
        // first lost segment is the first starting at/after the onset
        assert_eq!(s.down_from, 5);
        assert_eq!(s.up_from, None);
        // detection = the lost segment's deadline
        assert_eq!(s.detect_secs, 6.0);
        assert!((s.detect_latency - 1.5).abs() < 1e-12);
        // repair = next epoch boundary after detection
        assert_eq!(s.repair_epoch, Some(2));
        assert_eq!(s.repair_latency_epochs(4), 1);
        assert_eq!(s.rejoin_epoch, None);
        assert!(!t.down_seg(1, 4) && t.down_seg(1, 5) && t.down_seg(1, 11));
        assert!(!t.down_seg(0, 5));
        // peers degrade from the segment after detection to the repair
        assert!(!t.degraded_seg(0, 5));
        assert!(t.degraded_seg(0, 6) && t.degraded_seg(2, 7));
        assert!(!t.degraded_seg(0, 8));
        // the dead camera itself is down, not degraded
        assert!(!t.degraded_seg(1, 6));
        assert_eq!(t.force_fire_cams(2), &[0, 1, 2]);
        assert!(t.has_event_at(2) && !t.has_event_at(1));
        assert_eq!(t.repairs_at(2).count(), 1);
        assert_eq!(t.rejoins_at(2).count(), 0);
    }

    #[test]
    fn rejoin_is_symmetric() {
        let t = timeline(&[fault(1, 1.2, Some(5.5))]);
        let s = &t.schedules()[0];
        assert_eq!(s.down_from, 2);
        assert_eq!(s.up_from, Some(6));
        assert_eq!(s.repair_epoch, Some(1));
        assert_eq!(s.rejoin_epoch, Some(2));
        assert!(t.down_seg(1, 2) && t.down_seg(1, 5) && !t.down_seg(1, 6));
        // still down when the repair epoch starts (seg 4)
        assert!(t.down_seg(1, 4));
        // the rejoined camera streams full-frame until its plan lands
        assert!(t.degraded_seg(1, 6) && t.degraded_seg(1, 7) && !t.degraded_seg(1, 8));
        // peers degrade between detection and repair
        assert!(t.degraded_seg(0, 3) && !t.degraded_seg(0, 4));
        assert!(t.has_event_at(1) && t.has_event_at(2));
        assert_eq!(t.rejoins_at(2).count(), 1);
    }

    #[test]
    fn fault_frame_lookup_is_eval_anchored() {
        let t = timeline(&[fault(1, 1.2, Some(5.5))]);
        assert!(!t.down_frame(1, 899)); // profile frames are never down
        assert!(!t.down_frame(1, 900 + 59)); // seg 1 delivered
        assert!(t.down_frame(1, 900 + 2 * 30)); // seg 2 lost
        assert!(t.down_frame(1, 900 + 5 * 30 + 29)); // seg 5 lost
        assert!(!t.down_frame(1, 900 + 6 * 30)); // rejoined
        assert!(!t.down_frame(0, 900 + 2 * 30)); // other cameras live
    }

    #[test]
    fn sub_segment_outage_never_materialises() {
        // entirely between two segment starts: no segment is lost
        let t = timeline(&[fault(0, 1.2, Some(1.8))]);
        assert!(t.is_empty());
        // and one starting after the run ends
        let t = timeline(&[fault(0, 99.0, None)]);
        assert!(t.is_empty());
        assert!(!t.down_seg(0, 11));
    }

    #[test]
    fn late_dropout_has_no_repair_epoch() {
        // lost segments begin inside the last epoch: peers degrade to
        // the end of the run instead of repairing
        let t = timeline(&[fault(2, 8.5, None)]);
        let s = &t.schedules()[0];
        assert_eq!(s.down_from, 9);
        assert_eq!(s.repair_epoch, None);
        assert_eq!(s.repair_latency_epochs(4), 0);
        assert!(t.degraded_seg(0, 10) && t.degraded_seg(1, 11));
    }

    #[test]
    fn liveness_monitor_detects_silence_runs() {
        let mut m = LivenessMonitor::new(2, 4, 1.0);
        // cam 0 delivers everything, exactly at each deadline (the tie
        // must resolve Seen-before-Deadline)
        for seg in 0..4 {
            m.observe(0, seg, (seg + 1) as f64);
        }
        // cam 1 misses segments 1 and 2
        m.observe(1, 0, 1.0);
        m.observe(1, 3, 4.0);
        let silences = m.silences();
        assert_eq!(
            silences,
            vec![
                Silence { cam: 1, seg: 1, deadline: 2.0 },
                Silence { cam: 1, seg: 2, deadline: 3.0 },
            ]
        );
    }

    #[test]
    fn liveness_monitor_agrees_with_the_timeline() {
        let t = timeline(&[fault(1, 1.2, Some(5.5))]);
        let mut m = LivenessMonitor::new(3, 12, t.segment_secs());
        for cam in 0..3 {
            for seg in 0..12 {
                if !t.down_seg(cam, seg) {
                    m.observe(cam, seg, (seg + 1) as f64 * t.segment_secs());
                }
            }
        }
        let silences = m.silences();
        let first = silences.iter().find(|s| s.cam == 1).unwrap();
        let sched = &t.schedules()[0];
        assert_eq!(first.seg, sched.down_from);
        assert!((first.deadline - sched.detect_secs).abs() < 1e-9);
        assert!(silences.iter().all(|s| s.cam == 1));
        assert_eq!(silences.len(), 4); // segments 2..6
    }
}
