//! Continuous re-profiling: the pipeline-side plumbing that lets the
//! online phase swap RoI plans at segment boundaries without stalling the
//! stage workers (§3.1's concession that traffic patterns drift and the
//! masks must be re-derived; ReXCam adapts its correlation model online
//! the same way).
//!
//! The run is divided into fixed **planning epochs** of
//! [`PlanSchedule::check_every`] segments.  Epoch 0 is the initial
//! offline plan; every later epoch's plan is produced by an
//! [`EpochPlanner`] (the coordinator installs
//! `offline::replan::Replanner`) and published into the shared
//! [`PlanSchedule`].  Camera workers look their epoch up at each segment
//! boundary and swap the encode regions / RoI mask only when the plan
//! actually changed; the server-side inference stage resolves each
//! incoming segment's epoch the same way.  Because epoch boundaries are
//! fixed segment indices and every epoch plan is a pure function of the
//! scenario and the policy — never of worker timing — a run with
//! re-profiling on is byte-identical across thread counts
//! (`rust/tests/replan.rs`).
//!
//! The planner runs **concurrently** with the stage workers (a dedicated
//! scoped thread under parallel schedules, inline pre-computation under
//! [`crate::pipeline::Parallelism::Sequential`]); a worker only blocks on
//! [`PlanSchedule::wait`] in the degenerate case where it reaches a
//! boundary before the planner has published that epoch.

use std::sync::Arc;

use anyhow::Result;

use crate::util::geometry::IRect;
use crate::util::sync::EpochTable;

/// When to re-derive the RoI plan during the online phase
/// (CLI: `--replan-every` / `--replan-drift`).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub enum ReplanPolicy {
    /// Plan once offline and keep the masks for the whole run (the
    /// historical behaviour; the default).
    #[default]
    Never,
    /// Re-plan at every epoch boundary, i.e. every `n` segments.
    Every(usize),
    /// Check the sliding window every `check_every` segments but only
    /// re-solve when the constraint drift — the fraction of the new
    /// window's association constraints absent from the previous window —
    /// reaches `threshold`.
    Drift { check_every: usize, threshold: f64 },
}

impl ReplanPolicy {
    /// Default check cadence (segments) when `--replan-drift` is given
    /// without `--replan-every`.
    pub const DEFAULT_CHECK_EVERY: usize = 4;

    /// Segments per planning epoch (`None` for [`ReplanPolicy::Never`]).
    pub fn check_every(&self) -> Option<usize> {
        match self {
            ReplanPolicy::Never => None,
            ReplanPolicy::Every(n) => Some((*n).max(1)),
            ReplanPolicy::Drift { check_every, .. } => Some((*check_every).max(1)),
        }
    }
}

/// What one re-plan instance covers (CLI: `--replan-scope`, DESIGN.md
/// §8): the whole fleet as one window, or — the default — each
/// co-occurrence component independently, so only drifted components pay
/// a re-solve and quiescent ones carry their sub-plan forward.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ReplanScope {
    /// Per-component drift, filtering and warm-started solves; quiescent
    /// components carry forward untouched.
    #[default]
    Component,
    /// One fleet-wide window and one fleet-wide fire/carry decision per
    /// epoch (the historical behaviour).
    Fleet,
}

impl ReplanScope {
    pub fn parse(name: &str) -> anyhow::Result<ReplanScope> {
        Ok(match name {
            "component" => ReplanScope::Component,
            "fleet" => ReplanScope::Fleet,
            other => anyhow::bail!("unknown replan scope {other:?} (expected fleet|component)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            ReplanScope::Component => "component",
            ReplanScope::Fleet => "fleet",
        }
    }
}

/// One planning epoch's per-camera artifacts — everything the online
/// stages need from a plan (the `RoiMask` derivatives: codec regions,
/// detector blocks, the RoI-vs-dense policy).
#[derive(Debug, Clone)]
pub struct PlanEpoch {
    /// Codec regions per camera (what the encode stage crops and the
    /// capture mask keeps).
    pub groups: Vec<Vec<IRect>>,
    /// Active detector blocks per camera (the RoI HLO variant's input).
    pub blocks: Vec<Vec<i32>>,
    /// Whether each camera takes the SBNet RoI inference path this epoch.
    pub use_roi: Vec<bool>,
    /// Planning epoch at which each camera's regions last **changed**
    /// (content-compared, so a component-scoped re-plan that left a
    /// camera's plan intact keeps its stamp).  Workers swap codec
    /// regions — and reset the codec's motion reference — only when this
    /// stamp moves, so cameras of carried components keep their encoder
    /// state across other components' re-plans.
    pub cam_epoch: Vec<usize>,
    /// Per-camera Reducto frame-filter thresholds for this epoch (`None`
    /// when the method runs without frame filtering).  Re-derived from
    /// the sliding window whenever a re-plan changes a camera's regions.
    pub thresholds: Option<Vec<f64>>,
    /// |M| of this epoch's masks (diagnostics).
    pub mask_tiles: usize,
}

impl PlanEpoch {
    /// Epoch 0: the initial offline plan's artifacts with every camera's
    /// change stamp at 0 — the one construction the coordinator, tests
    /// and benches share.
    pub fn initial(
        groups: Vec<Vec<IRect>>,
        blocks: Vec<Vec<i32>>,
        use_roi: Vec<bool>,
        thresholds: Option<Vec<f64>>,
        mask_tiles: usize,
    ) -> PlanEpoch {
        let n_cams = groups.len();
        PlanEpoch { groups, blocks, use_roi, cam_epoch: vec![0; n_cams], thresholds, mask_tiles }
    }
}

/// Produces the plan of each epoch `k ≥ 1`, in order, given the previous
/// epoch's plan.  Implementations may return `prev` unchanged (an
/// `Arc` clone) when their policy decides the window has not drifted —
/// workers detect the pointer identity and skip the swap.
///
/// `start_seg` is the epoch's first segment **as the runner's
/// [`PlanSchedule`] defines it** — the schedule is the single source of
/// truth for boundaries, so a planner must derive its profile window and
/// trigger timestamps from this argument, never from its own cadence
/// copy.
pub trait EpochPlanner: Sync {
    fn plan_epoch(
        &self,
        k: usize,
        start_seg: usize,
        prev: &Arc<PlanEpoch>,
    ) -> Result<Arc<PlanEpoch>>;
}

/// The shared epoch → plan table: fixed boundaries, plans filled in as
/// the planner publishes them.  Epoch boundaries are segment indices
/// (`epoch = seg / check_every`), so pickup is atomic *between* segments
/// by construction — a worker never changes plan mid-segment.
///
/// Storage and blocking live in [`EpochTable`] (`util::sync`), the
/// loom-modeled write-once slot table; this type adds the segment ↔
/// epoch arithmetic and the epoch-0 bootstrap.
pub struct PlanSchedule {
    check_every: usize,
    epochs: EpochTable<PlanEpoch>,
}

impl PlanSchedule {
    /// Schedule for a run of `n_segments` per camera with epoch length
    /// `check_every`; epoch 0 is published immediately with the initial
    /// offline plan.
    pub fn new(n_segments: usize, check_every: usize, initial: PlanEpoch) -> PlanSchedule {
        let check_every = check_every.max(1);
        let n_epochs = n_segments.div_ceil(check_every).max(1);
        let sched = PlanSchedule { check_every, epochs: EpochTable::new(n_epochs) };
        sched.publish(0, Arc::new(initial));
        sched
    }

    /// Segments per epoch.
    pub fn check_every(&self) -> usize {
        self.check_every
    }

    pub fn n_epochs(&self) -> usize {
        self.epochs.len()
    }

    /// Epoch owning segment `seg`.
    pub fn epoch_of(&self, seg: usize) -> usize {
        (seg / self.check_every).min(self.epochs.len() - 1)
    }

    /// First segment of epoch `k`.
    pub fn start_seg(&self, k: usize) -> usize {
        k * self.check_every
    }

    /// Publish epoch `k`'s plan, waking every worker blocked on it.
    /// Re-publishing an epoch is a no-op (first write wins), so an error
    /// path may flood the remaining epochs with the last good plan
    /// without racing the planner.
    pub fn publish(&self, k: usize, plan: Arc<PlanEpoch>) {
        self.epochs.publish(k, plan);
    }

    /// Epoch `k`'s plan, blocking until published.
    pub fn wait(&self, k: usize) -> Arc<PlanEpoch> {
        self.epochs.wait(k)
    }

    /// Epoch `k`'s plan if already published (the server side only sees
    /// segments whose epoch the camera worker already picked up).
    pub fn get(&self, k: usize) -> Option<Arc<PlanEpoch>> {
        self.epochs.get(k)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn epoch(tiles: usize) -> PlanEpoch {
        PlanEpoch {
            groups: vec![vec![IRect::new(0, 0, 16, 16)]],
            blocks: vec![vec![0]],
            use_roi: vec![true],
            cam_epoch: vec![0],
            thresholds: None,
            mask_tiles: tiles,
        }
    }

    #[test]
    fn policy_cadence() {
        assert_eq!(ReplanPolicy::Never.check_every(), None);
        assert_eq!(ReplanPolicy::Every(3).check_every(), Some(3));
        assert_eq!(ReplanPolicy::Every(0).check_every(), Some(1));
        assert_eq!(
            ReplanPolicy::Drift { check_every: 5, threshold: 0.2 }.check_every(),
            Some(5)
        );
        assert_eq!(ReplanPolicy::default(), ReplanPolicy::Never);
    }

    #[test]
    fn scope_parses_and_names() {
        assert_eq!(ReplanScope::parse("fleet").unwrap(), ReplanScope::Fleet);
        assert_eq!(ReplanScope::parse("component").unwrap(), ReplanScope::Component);
        assert!(ReplanScope::parse("shard").is_err());
        assert_eq!(ReplanScope::Fleet.name(), "fleet");
        assert_eq!(ReplanScope::Component.name(), "component");
        assert_eq!(ReplanScope::default(), ReplanScope::Component);
    }

    #[test]
    fn epoch_boundaries_are_segment_indexed() {
        let s = PlanSchedule::new(10, 4, epoch(1));
        assert_eq!(s.n_epochs(), 3);
        assert_eq!(s.epoch_of(0), 0);
        assert_eq!(s.epoch_of(3), 0);
        assert_eq!(s.epoch_of(4), 1);
        assert_eq!(s.epoch_of(9), 2);
        // segments past the last boundary stay in the last epoch
        assert_eq!(s.epoch_of(40), 2);
        assert_eq!(s.start_seg(2), 8);
    }

    #[test]
    fn initial_epoch_is_published() {
        let s = PlanSchedule::new(4, 2, epoch(7));
        assert_eq!(s.wait(0).mask_tiles, 7);
        assert!(s.get(1).is_none());
    }

    #[test]
    fn publish_is_first_write_wins() {
        let s = PlanSchedule::new(4, 2, epoch(1));
        s.publish(1, Arc::new(epoch(2)));
        s.publish(1, Arc::new(epoch(3)));
        assert_eq!(s.get(1).unwrap().mask_tiles, 2);
    }

    #[test]
    fn wait_blocks_until_published() {
        let s = PlanSchedule::new(6, 3, epoch(1));
        std::thread::scope(|scope| {
            let waiter = scope.spawn(|| s.wait(1).mask_tiles);
            std::thread::sleep(std::time::Duration::from_millis(10));
            s.publish(1, Arc::new(epoch(9)));
            assert_eq!(waiter.join().unwrap(), 9);
        });
    }

    #[test]
    fn one_segment_run_has_one_epoch() {
        let s = PlanSchedule::new(1, 8, epoch(1));
        assert_eq!(s.n_epochs(), 1);
        assert_eq!(s.epoch_of(0), 0);
    }
}
