//! Cross-camera RoI consolidation: gather every camera's kept tile
//! groups into a few dense canvases, infer those, scatter the grids back
//! (object-level consolidation, arXiv 2111.15451, on CrossRoI's groups).
//!
//! ## Byte-identity construction (DESIGN.md §13)
//!
//! The native detector's objectness cell (cy, cx) depends only on the
//! frame pixels of its 16×16 cell rect inflated by 1 px (conv radius).
//! The canvas path exploits that locality:
//!
//! * **gather**: each group rect is inflated by [`GATHER_INFLATE_CELLS`]
//!   cells (clipped to the frame) and copied from the job's masked
//!   pixels into a zero-filled canvas — zeros elsewhere match both the
//!   detector's pad zeros and the masked-out background;
//! * **scatter**: the group rect inflated by [`SCATTER_INFLATE_CELLS`]
//!   cells, intersected with the plan's active-block cells, is copied
//!   from the canvas grid into a zeroed per-camera grid.  Every active
//!   cell is within one cell of some mask tile (blocks are 2×2 cells,
//!   active iff a tile is masked), so the scatter regions of a camera's
//!   groups cover all its active cells; inactive cells stay zero,
//!   exactly like `detect_roi_into`'s restriction;
//! * **gutter**: placements sit ≥ [`GUTTER_PX`] apart, so one
//!   placement's 1-px receptive ring never reads another's pixels, and
//!   connected-component decoding (the NMS analogue) cannot bleed
//!   across groups.
//!
//! Scatter cells sit inside gather rects (1 cell + 1 px ≤ 2 cells), the
//! 16-px alignment of groups, gutter and canvas keeps the pooling grid
//! phase-aligned, and the detector is translation-invariant — so every
//! reconstructed cell is bit-identical to the per-camera RoI path
//! (`round_trip_matches_roi_path` below proves it on real masks).
//!
//! ## Routing determinism
//!
//! Whether a camera takes the canvas route is a pure function of the
//! epoch plan ([`consolidation_active`]) — never of batch composition —
//! so reports stay byte-identical across worker counts.  Packing still
//! happens per merged batch (that is the cross-camera pooling), but it
//! only affects the wall-clock-free diagnostics in [`CanvasTally`].

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

use crate::util::geometry::IRect;

/// Detector cell edge in pixels (objectness grid granularity).
pub const CELL_PX: u32 = 16;
/// Gather inflation: the copied rect is the group rect grown by this
/// many cells per side (2 cells ⊇ scatter ring + conv radius).
pub const GATHER_INFLATE_CELLS: u32 = 2;
/// Scatter inflation: cells owed to a group (covers the 1-cell ring a
/// mask tile can activate in its 2×2 block).
pub const SCATTER_INFLATE_CELLS: u32 = 1;
/// Minimum pixel separation between canvas placements (≥ 1 px required
/// by the conv radius; one full cell keeps placements grid-aligned).
pub const GUTTER_PX: u32 = 16;
/// Auto mode consolidates only when the fleet's RoI cameras keep at
/// most this fraction of their pixels — above it, canvases stop winning
/// over per-camera sparse inference (see `BENCH_canvas.json`).
pub const CONSOLIDATE_COVERAGE_FRACTION: f64 = 0.25;

/// The `--consolidate` policy (CLI → `PipelineOptions`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ConsolidateMode {
    /// Consolidate when ≥ 2 RoI cameras keep ≤ 25 % of their pixels.
    #[default]
    Auto,
    /// Always consolidate RoI cameras.
    On,
    /// Never consolidate (per-camera dense/sbnet routing only).
    Off,
}

impl ConsolidateMode {
    pub fn parse(s: &str) -> Option<ConsolidateMode> {
        match s {
            "auto" => Some(ConsolidateMode::Auto),
            "on" => Some(ConsolidateMode::On),
            "off" => Some(ConsolidateMode::Off),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            ConsolidateMode::Auto => "auto",
            ConsolidateMode::On => "on",
            ConsolidateMode::Off => "off",
        }
    }
}

/// Does this plan route its RoI cameras through canvases?  A pure
/// function of the plan (groups + RoI policy), deliberately independent
/// of queue state so the route — and with it every report byte — cannot
/// depend on worker scheduling.  `frame_px` is one camera's pixel count.
pub fn consolidation_active(
    mode: ConsolidateMode,
    use_roi: &[bool],
    groups: &[Vec<IRect>],
    frame_px: u64,
) -> bool {
    let eligible: Vec<usize> =
        (0..use_roi.len()).filter(|&c| use_roi[c]).collect();
    match mode {
        ConsolidateMode::Off => false,
        ConsolidateMode::On => !eligible.is_empty(),
        ConsolidateMode::Auto => {
            if eligible.len() < 2 {
                return false;
            }
            // groups partition the mask, so their areas sum to the kept
            // pixel count — aggregate coverage needs no extra bookkeeping
            let kept: u64 =
                eligible.iter().map(|&c| groups[c].iter().map(|g| g.area()).sum::<u64>()).sum();
            kept as f64 / (eligible.len() as u64 * frame_px) as f64
                <= CONSOLIDATE_COVERAGE_FRACTION
        }
    }
}

/// Inflate `r` by `cells` detector cells per side, clipped to the
/// `fw × fh` frame.  Tile-aligned input stays tile-aligned.
pub fn inflate_clip(r: IRect, cells: u32, fw: u32, fh: u32) -> IRect {
    let d = cells * CELL_PX;
    let x0 = r.x.saturating_sub(d);
    let y0 = r.y.saturating_sub(d);
    let x1 = (r.x + r.w + d).min(fw);
    let y1 = (r.y + r.h + d).min(fh);
    IRect::new(x0, y0, x1 - x0, y1 - y0)
}

/// Copy the HWC pixels of `src` (frame coordinates) into the canvas at
/// (`dst_x`, `dst_y`).  Row-wise `copy_from_slice` — no per-pixel math.
pub fn gather_into(
    canvas: &mut [f32],
    canvas_w: usize,
    frame: &[f32],
    frame_w: usize,
    src: IRect,
    dst_x: u32,
    dst_y: u32,
) {
    let (w, h) = (src.w as usize, src.h as usize);
    let (sx, sy) = (src.x as usize, src.y as usize);
    let (dx, dy) = (dst_x as usize, dst_y as usize);
    for y in 0..h {
        let from = ((sy + y) * frame_w + sx) * 3;
        let to = ((dy + y) * canvas_w + dx) * 3;
        canvas[to..to + w * 3].copy_from_slice(&frame[from..from + w * 3]);
    }
}

/// Copy the cells of `scatter` (frame coordinates, restricted to
/// `active` cells) from the canvas grid back into the camera grid.  The
/// placement maps frame cell (cy, cx) to canvas cell
/// `(cy − gather.y/16 + dst_y/16, cx − gather.x/16 + dst_x/16)`.
/// Overlapping scatter regions write bit-identical values (each canvas
/// reproduces the dense grid over its gather rect), so write order
/// never matters.
#[allow(clippy::too_many_arguments)]
pub fn scatter_into(
    cam_grid: &mut [f32],
    canvas_grid: &[f32],
    grid_w: usize,
    scatter: IRect,
    gather: IRect,
    dst_x: u32,
    dst_y: u32,
    active: &[bool],
) {
    let c = CELL_PX;
    debug_assert!(
        scatter.x % c == 0
            && scatter.y % c == 0
            && scatter.w % c == 0
            && scatter.h % c == 0
            && gather.x % c == 0
            && gather.y % c == 0
            && dst_x % c == 0
            && dst_y % c == 0,
        "consolidation rects must stay cell-aligned"
    );
    let (cy0, cx0) = ((scatter.y / c) as usize, (scatter.x / c) as usize);
    let (cy1, cx1) = (((scatter.y + scatter.h) / c) as usize, ((scatter.x + scatter.w) / c) as usize);
    // frame cell → canvas cell offset (signed: dst may sit left of src)
    let oy = (dst_y / c) as isize - (gather.y / c) as isize;
    let ox = (dst_x / c) as isize - (gather.x / c) as isize;
    for cy in cy0..cy1 {
        for cx in cx0..cx1 {
            if active[cy * grid_w + cx] {
                let ccy = (cy as isize + oy) as usize;
                let ccx = (cx as isize + ox) as usize;
                cam_grid[cy * grid_w + cx] = canvas_grid[ccy * grid_w + ccx];
            }
        }
    }
}

/// Expand a plan's active block ids into a per-cell bitmap (`out` is
/// cleared and refilled — reusable, allocation-free once warm).
pub fn active_cells(
    blocks: &[i32],
    grid_w: usize,
    grid_h: usize,
    cells_per_block: usize,
    block_grid_w: usize,
    out: &mut Vec<bool>,
) {
    out.clear();
    out.resize(grid_w * grid_h, false);
    for &b in blocks {
        if b < 0 {
            continue;
        }
        let by = b as usize / block_grid_w;
        let bx = b as usize % block_grid_w;
        for cy in 0..cells_per_block {
            for cx in 0..cells_per_block {
                let (gy, gx) = (by * cells_per_block + cy, bx * cells_per_block + cx);
                if gy < grid_h && gx < grid_w {
                    out[gy * grid_w + gx] = true;
                }
            }
        }
    }
}

/// Wall-clock-free consolidation diagnostics, accumulated across merged
/// batches with relaxed atomics (exact values depend on batch
/// composition, hence on scheduling — surfaced in `MethodReport` but
/// excluded from its byte-compared JSON, like `ArenaStats`).
#[derive(Debug, Default)]
pub struct CanvasTally {
    canvases: AtomicUsize,
    batches: AtomicUsize,
    jobs: AtomicUsize,
    placed_px: AtomicU64,
}

impl CanvasTally {
    pub fn record(&self, canvases: usize, jobs: usize, placed_px: u64) {
        if canvases == 0 {
            return;
        }
        self.canvases.fetch_add(canvases, Ordering::Relaxed);
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.jobs.fetch_add(jobs, Ordering::Relaxed);
        self.placed_px.fetch_add(placed_px, Ordering::Relaxed);
    }

    /// Total canvases inferred across the run.
    pub fn canvases(&self) -> usize {
        self.canvases.load(Ordering::Relaxed)
    }

    /// Mean fraction of canvas pixels carrying gathered content.
    pub fn mean_fill(&self, frame_px: u64) -> f64 {
        let n = self.canvases() as u64;
        if n == 0 {
            return 0.0;
        }
        self.placed_px.load(Ordering::Relaxed) as f64 / (n * frame_px) as f64
    }

    /// Mean camera-jobs folded into each canvas (batch occupancy).
    pub fn occupancy(&self) -> f64 {
        let n = self.canvases();
        if n == 0 {
            return 0.0;
        }
        self.jobs.load(Ordering::Relaxed) as f64 / n as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::association::tiles::Tiling;
    use crate::roi::masks::RoiMasks;
    use crate::runtime::native::{detect_full_into, detect_roi_into, DetectScratch};
    use crate::tilegroup::pack::{PackItem, Packer, Placement};
    use crate::util::rng::Rng;
    use std::collections::HashSet;

    const W: usize = 320;
    const H: usize = 192;

    #[test]
    fn inflate_clip_aligns_and_clips() {
        let r = IRect::new(32, 16, 64, 32);
        assert_eq!(inflate_clip(r, 2, 320, 192), IRect::new(0, 0, 128, 80));
        assert_eq!(inflate_clip(r, 1, 320, 192), IRect::new(16, 0, 96, 64));
        let edge = IRect::new(288, 160, 32, 32);
        assert_eq!(inflate_clip(edge, 2, 320, 192), IRect::new(256, 128, 64, 64));
    }

    #[test]
    fn auto_mode_needs_two_sparse_roi_cameras() {
        let px = (W * H) as u64;
        let small = vec![IRect::new(0, 0, 64, 48)]; // 3072 px ≈ 5 %
        let big = vec![IRect::new(0, 0, 320, 96)]; // 50 %
        let g2 = vec![small.clone(), small.clone()];
        assert!(consolidation_active(ConsolidateMode::Auto, &[true, true], &g2, px));
        assert!(!consolidation_active(ConsolidateMode::Auto, &[true, false], &g2, px));
        let gb = vec![big.clone(), big];
        assert!(!consolidation_active(ConsolidateMode::Auto, &[true, true], &gb, px));
        assert!(!consolidation_active(ConsolidateMode::Off, &[true, true], &g2, px));
        let g1 = [small];
        assert!(consolidation_active(ConsolidateMode::On, &[true], &g1, px));
        assert!(!consolidation_active(ConsolidateMode::On, &[false], &g1, px));
    }

    fn masks_from(tile_sets: Vec<Vec<(u32, u32)>>) -> RoiMasks {
        let tiling = Tiling::new(tile_sets.len(), W as u32, H as u32, 16);
        let tiles = tile_sets
            .into_iter()
            .map(|v| v.into_iter().collect::<HashSet<_>>())
            .collect();
        RoiMasks { tiling, tiles }
    }

    /// A frame whose mask tiles carry pseudo-random content and whose
    /// background is zero — exactly what `masked_f32_into` produces.
    fn masked_frame(masks: &RoiMasks, cam: usize, rng: &mut Rng) -> Vec<f32> {
        let mut f = vec![0.0f32; W * H * 3];
        let mut tiles: Vec<(u32, u32)> = masks.tiles[cam].iter().copied().collect();
        tiles.sort_unstable();
        for (tx, ty) in tiles {
            for y in ty * 16..(ty + 1) * 16 {
                for x in tx * 16..(tx + 1) * 16 {
                    let i = (y as usize * W + x as usize) * 3;
                    for c in 0..3 {
                        f[i + c] = (rng.next_u64() % 1000) as f32 / 1000.0;
                    }
                }
            }
        }
        f
    }

    /// The tentpole's correctness core: pack the groups of two cameras
    /// into shared canvases, infer the canvases dense, scatter back —
    /// every camera grid must be bit-identical to its per-camera RoI
    /// inference, including groups flush against the frame border.
    #[test]
    fn round_trip_matches_roi_path() {
        let masks = masks_from(vec![
            // camera 0: a corner block (exercises frame-edge clipping),
            // a mid-frame blob and an isolated tile
            (0..3)
                .flat_map(|x| (0..2).map(move |y| (x, y)))
                .chain((8..12).flat_map(|x| (5..9).map(move |y| (x, y))))
                .chain([(17, 10)])
                .collect(),
            // camera 1: a right-edge strip and a bottom-edge blob
            (18..20)
                .flat_map(|x| (2..8).map(move |y| (x, y)))
                .chain((4..9).flat_map(|x| (9..12).map(move |y| (x, y))))
                .collect(),
        ]);
        let mut rng = Rng::new(7);
        let frames: Vec<Vec<f32>> =
            (0..2).map(|c| masked_frame(&masks, c, &mut rng)).collect();
        let groups: Vec<Vec<IRect>> =
            (0..2).map(|c| crate::tilegroup::group_camera(&masks, c)).collect();
        let blocks: Vec<Vec<i32>> =
            (0..2).map(|c| masks.active_blocks(c, 32, W as u32)).collect();

        // reference: the per-camera RoI path
        let mut scratch = DetectScratch::new();
        let mut want = Vec::new();
        for c in 0..2 {
            let mut g = Vec::new();
            detect_roi_into(&frames[c], H, W, &blocks[c], 32, 10, &mut scratch, &mut g);
            want.push(g);
        }

        // canvas path: one shared packing across both cameras
        let mut items = Vec::new();
        let mut info = Vec::new(); // (cam, gather, scatter)
        for c in 0..2 {
            for g in &groups[c] {
                let gather = inflate_clip(*g, GATHER_INFLATE_CELLS, W as u32, H as u32);
                let scatter = inflate_clip(*g, SCATTER_INFLATE_CELLS, W as u32, H as u32);
                items.push(PackItem { id: info.len(), w: gather.w, h: gather.h });
                info.push((c, gather, scatter));
            }
        }
        let mut packer = Packer::new(W as u32, H as u32, GUTTER_PX);
        let mut placements: Vec<Placement> = Vec::new();
        let n_canvases = packer.pack(&items, &mut placements);
        assert!(n_canvases >= 1);
        let mut canvases = vec![vec![0.0f32; W * H * 3]; n_canvases];
        for p in &placements {
            let (cam, gather, _) = info[p.id];
            gather_into(&mut canvases[p.canvas], W, &frames[cam], W, gather, p.x, p.y);
        }
        let mut canvas_grids = Vec::new();
        for cv in &canvases {
            let mut g = Vec::new();
            detect_full_into(cv, H, W, &mut scratch, &mut g);
            canvas_grids.push(g);
        }
        let mut active = Vec::new();
        for c in 0..2 {
            active_cells(&blocks[c], 20, 12, 2, 10, &mut active);
            let mut got = vec![0.0f32; 12 * 20];
            for p in &placements {
                let (cam, gather, scatter) = info[p.id];
                if cam != c {
                    continue;
                }
                scatter_into(
                    &mut got,
                    &canvas_grids[p.canvas],
                    20,
                    scatter,
                    gather,
                    p.x,
                    p.y,
                    &active,
                );
            }
            let want_bits: Vec<u32> = want[c].iter().map(|v| v.to_bits()).collect();
            let got_bits: Vec<u32> = got.iter().map(|v| v.to_bits()).collect();
            assert_eq!(want_bits, got_bits, "camera {c} grid diverged from the RoI path");
        }
    }

    #[test]
    fn tally_ratios() {
        let t = CanvasTally::default();
        assert_eq!(t.canvases(), 0);
        assert_eq!(t.mean_fill(100), 0.0);
        assert_eq!(t.occupancy(), 0.0);
        t.record(2, 6, 50);
        t.record(0, 9, 999); // canvas-free batch: ignored
        assert_eq!(t.canvases(), 2);
        assert!((t.mean_fill(100) - 0.25).abs() < 1e-12);
        assert!((t.occupancy() - 3.0).abs() < 1e-12);
    }
}
