//! Pipeline runner: schedules the camera-side stages onto worker threads
//! and drives the server-side inference stage off the merged queue.
//!
//! Each camera's `capture → filter → encode` chain runs independently
//! (one scoped worker per camera by default); finished segments flow over
//! an mpsc channel to the caller's thread, where everything currently
//! queued is packed into one merged [`InferStage`] batch.  Results are
//! re-canonicalized to (camera, segment) order afterwards, so reports are
//! bit-identical across thread counts (see the determinism test in
//! `rust/tests/pipeline_determinism.rs`).

use std::collections::HashSet;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::pipeline::arena::{Arena, ArenaStats};
use crate::pipeline::canvas::ConsolidateMode;
use crate::pipeline::infer::{InferOutcome, InferStage};
use crate::pipeline::replan::{
    EpochPlanner, FaultContext, PlanEpoch, PlanSchedule, ReplanPolicy, ReplanScope,
};
use crate::pipeline::stage::{
    CameraSegment, CaptureStage, EncodeStage, FilterStage, InferJob, SegmentLayout,
    SegmentRecord,
};
use crate::sim::render::Frame;
use crate::util::geometry::IRect;

/// How the camera-side stages are scheduled.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Parallelism {
    /// Everything on the caller's thread, camera-major (the reference
    /// execution order; what the pre-pipeline coordinator did).
    Sequential,
    /// One scoped worker thread per camera (the default).
    PerCamera,
    /// Cameras distributed round-robin over `n` worker threads.
    Workers(usize),
}

/// Options steering one end-to-end run: the online schedule/cost model
/// plus the offline planner's options (the coordinator builds the plan
/// before wiring the pipeline, so they travel together).
///
/// Note on methodology: with `EncodeCost::Measured` under a parallel
/// schedule, per-camera encode times are measured while up to `n_cams`
/// workers share this host's cores.  That matches a deployment where
/// cameras contend for one box, but on a core-starved host it inflates
/// the service times the DES replays versus the uncontended per-device
/// encoders of the paper's testbed — pin `Parallelism::Sequential` when
/// measuring paper-figure numbers on small machines.
#[derive(Debug, Clone, Copy)]
pub struct PipelineOptions {
    pub parallelism: Parallelism,
    pub encode_cost: crate::pipeline::encode::EncodeCost,
    /// Offline planner options (`--offline-threads`, `--solver`).
    pub offline: crate::offline::OfflineOptions,
    /// Continuous re-profiling policy (`--replan-every`, `--replan-drift`);
    /// [`ReplanPolicy::Never`] keeps the one-shot plan.
    pub replan: ReplanPolicy,
    /// What each re-plan instance covers (`--replan-scope`): the whole
    /// fleet, or (default) each co-occurrence component independently so
    /// only drifted components re-solve.
    pub replan_scope: ReplanScope,
    /// Worker budget for one re-plan epoch's compute phase
    /// (`--planner-threads`): the drift-signal profile and the fired
    /// components fan out over this many shared pool workers.  `0`
    /// (default) inherits the offline planner's `effective_threads`.
    pub planner_threads: usize,
    /// Cross-camera canvas consolidation (`--consolidate`): pack sparse
    /// RoI cameras' kept tile groups into shared dense canvases on the
    /// server side ([`crate::pipeline::canvas`], DESIGN.md §13).
    pub consolidate: ConsolidateMode,
}

impl Default for PipelineOptions {
    /// Per-camera workers with measured costs.  Setting
    /// `CROSSROI_SEQUENTIAL=1` flips the default to
    /// [`Parallelism::Sequential`] — the uncontended-measurement escape
    /// hatch for benches and other callers of the default-option entry
    /// points (same pattern as the benches' `CROSSROI_FULL`).
    fn default() -> Self {
        let parallelism = if std::env::var("CROSSROI_SEQUENTIAL").ok().as_deref() == Some("1") {
            Parallelism::Sequential
        } else {
            Parallelism::PerCamera
        };
        PipelineOptions {
            parallelism,
            encode_cost: crate::pipeline::encode::EncodeCost::Measured,
            offline: crate::offline::OfflineOptions::default(),
            replan: ReplanPolicy::Never,
            replan_scope: ReplanScope::default(),
            planner_threads: 0,
            consolidate: ConsolidateMode::default(),
        }
    }
}

/// Everything [`run_pipeline_with_replan`] needs for continuous
/// re-profiling: the shared epoch schedule plus the planner that fills
/// it.  Plans are published into the schedule as the planner finishes
/// them; workers swap at the fixed epoch boundaries.
#[derive(Clone, Copy)]
pub struct ReplanContext<'a> {
    pub schedule: &'a PlanSchedule,
    pub planner: &'a dyn EpochPlanner,
}

/// One camera's stage chain plus the RoI crop it streams.
pub struct CameraStages<'a> {
    pub capture: Box<dyn CaptureStage + 'a>,
    pub filter: Box<dyn FilterStage + 'a>,
    pub encode: Box<dyn EncodeStage + 'a>,
    /// Pixel rectangles streamed to the server (inference input masking).
    pub mask: &'a [IRect],
}

/// Everything the compute pass produces, in canonical order.
pub struct PipelineOutput {
    /// Measured segments sorted by (camera, segment index).
    pub segments: Vec<SegmentRecord>,
    /// `frame_sets[cam][local]` is `Some(vehicles)` for inferred frames.
    pub frame_sets: Vec<Vec<Option<HashSet<u32>>>>,
    /// Frames discarded by the filter stage.
    pub frames_reduced: usize,
    /// Buffer-arena counters: proof the steady state recycles instead of
    /// allocating (schedule-dependent — diagnostics, not byte-compared).
    pub arena: ArenaStats,
}

/// Drive one camera's stages over every segment of the window, handing
/// each finished [`CameraSegment`] to `emit`.  A `false` from `emit`
/// (downstream gone or failed) aborts the remaining segments.
///
/// With a re-profiling `schedule`, the worker resolves its epoch at each
/// segment boundary and — only when **this camera's** plan actually
/// changed, per the epoch's content-compared [`PlanEpoch::cam_epoch`]
/// stamp — swaps the encode regions (resetting the codec's motion
/// reference), the frame-filter regions/threshold and the streamed RoI
/// mask before touching the segment's first frame.  A component-scoped
/// re-plan that left this camera's component untouched therefore keeps
/// its encoder state; a plan is never mixed within one segment.
///
/// With a fault context, a segment the timeline marks **down** produces
/// nothing at all — no capture, no emit; the server only learns from the
/// missed deadline.  A **degraded** segment (a surviving peer between
/// detection and repair, or a just-rejoined camera waiting for its plan)
/// streams the full frame with the frame filter bypassed, so coverage
/// never silently shrinks below the dense baseline while a repair is in
/// flight.  Both flags are pure functions of `(cam, seg)` from the
/// config-resolved timeline, so the byte-identity contract holds.
fn run_camera(
    cam: usize,
    stages: &mut CameraStages<'_>,
    layout: &SegmentLayout,
    schedule: Option<&PlanSchedule>,
    faults: Option<&FaultContext>,
    arena: &Arena,
    emit: &mut dyn FnMut(CameraSegment) -> bool,
) {
    // free-list of frame buffers: capture renders into a recycled buffer,
    // kept frames hold theirs until the segment is encoded and masked
    let mut pool = arena.frame_pool();
    let mut local = 0usize;
    let mut seg = 0usize;
    let mut cur_epoch = 0usize;
    // epoch 0's plan is what the stages were constructed with
    let mut applied_cam_epoch = 0usize;
    // whether the encoder currently holds the full-frame fallback region
    let mut full_applied = false;
    let mut cur_plan: Option<Arc<PlanEpoch>> = schedule.map(|s| s.wait(0));
    while local < layout.n_frames {
        let down = faults.is_some_and(|f| f.timeline.down_seg(cam, seg));
        let degraded = faults.is_some_and(|f| f.timeline.degraded_seg(cam, seg));
        if let Some(sched) = schedule {
            let epoch = sched.epoch_of(seg);
            if epoch != cur_epoch {
                cur_plan = Some(sched.wait(epoch));
                cur_epoch = epoch;
            }
        }
        let end = (local + layout.frames_per_segment).min(layout.n_frames);
        if down {
            // dead camera: the segment is simply never produced
            local = end;
            seg += 1;
            continue;
        }
        if degraded {
            if !full_applied {
                stages
                    .encode
                    .set_regions(std::slice::from_ref(&faults.expect("degraded").full_frame));
                full_applied = true;
            }
        } else {
            // apply the epoch plan when this camera's stamp moved — or
            // when leaving the full-frame fallback (the codec's motion
            // reference resets either way)
            let stamp = cur_plan.as_ref().map_or(applied_cam_epoch, |p| p.cam_epoch[cam]);
            if full_applied || stamp != applied_cam_epoch {
                match &cur_plan {
                    Some(plan) => {
                        stages.encode.set_regions(&plan.groups[cam]);
                        if let Some(th) = &plan.thresholds {
                            stages.filter.replan(&plan.groups[cam], th[cam]);
                        }
                    }
                    None => stages.encode.set_regions(stages.mask),
                }
                applied_cam_epoch = stamp;
                full_applied = false;
            }
        }
        let mask: &[IRect] = if degraded {
            std::slice::from_ref(&faults.expect("degraded").full_frame)
        } else {
            match &cur_plan {
                Some(plan) => &plan.groups[cam],
                None => stages.mask,
            }
        };
        let mut kept: Vec<(usize, Frame)> = Vec::new();
        let mut dropped = 0usize;
        for (k, lf) in (local..end).enumerate() {
            let mut buf = pool.take();
            stages.capture.capture(lf, &mut buf);
            // degraded segments bypass the frame filter: full coverage
            // until the repair plan lands
            if degraded || stages.filter.keep(&buf, k == 0) {
                kept.push((lf, buf));
            } else {
                dropped += 1;
                pool.put(buf);
            }
        }
        let refs: Vec<&Frame> = kept.iter().map(|(_, f)| f).collect();
        let (encoded, encode_secs) = stages.encode.encode(&refs);
        drop(refs);
        let jobs: Vec<InferJob> = kept
            .iter()
            .map(|(lf, f)| {
                // detector-input buffers travel to the server stage and
                // come back through the arena once the segment is inferred
                let mut pixels = arena.take_pixels();
                f.masked_f32_into(mask, &mut pixels);
                InferJob {
                    local: *lf,
                    capture_time: (*lf as f64 + 1.0) / layout.fps,
                    pixels,
                }
            })
            .collect();
        for (_, f) in kept {
            pool.put(f);
        }
        let keep_going = emit(CameraSegment {
            cam,
            seg,
            capture_end: end as f64 / layout.fps,
            bytes: encoded.bytes,
            encode_secs,
            dropped,
            jobs,
        });
        if !keep_going {
            return;
        }
        local = end;
        seg += 1;
    }
}

/// Fold one inferred segment into the output accumulators and return its
/// consumed detector-input buffers to the arena.
fn finish_segment(
    cs: CameraSegment,
    outcomes: Vec<InferOutcome>,
    frame_sets: &mut [Vec<Option<HashSet<u32>>>],
    segments: &mut Vec<SegmentRecord>,
    frames_reduced: &mut usize,
    arena: &Arena,
) {
    debug_assert_eq!(cs.jobs.len(), outcomes.len());
    let mut frames = Vec::with_capacity(outcomes.len());
    for o in outcomes {
        frame_sets[cs.cam][o.local] = Some(o.matched);
        frames.push((o.local, o.capture_time, o.secs));
    }
    *frames_reduced += cs.dropped;
    segments.push(SegmentRecord {
        cam: cs.cam,
        seg: cs.seg,
        capture_end: cs.capture_end,
        bytes: cs.bytes,
        encode_secs: cs.encode_secs,
        frames,
    });
    for job in cs.jobs {
        arena.put_pixels(job.pixels);
    }
}

/// Run the full compute pass: camera-side stages (scheduled per
/// `parallelism`) into the merged, batched inference stage.
pub fn run_pipeline(
    cams: Vec<CameraStages<'_>>,
    infer: &dyn InferStage,
    layout: &SegmentLayout,
    parallelism: Parallelism,
) -> Result<PipelineOutput> {
    run_pipeline_with_replan(cams, infer, layout, parallelism, None)
}

/// [`run_pipeline`] with a fault schedule: down segments are never
/// produced, degraded cameras stream full-frame (see [`run_camera`]).
pub fn run_pipeline_faulted(
    cams: Vec<CameraStages<'_>>,
    infer: &dyn InferStage,
    layout: &SegmentLayout,
    parallelism: Parallelism,
    faults: Option<&FaultContext>,
) -> Result<PipelineOutput> {
    let arena = Arena::new();
    run_pipeline_in(cams, infer, layout, parallelism, None, faults, &arena)
}

/// [`run_pipeline`] with optional continuous re-profiling: the planner
/// fills the epoch schedule while the stage workers stream (a dedicated
/// scoped thread under parallel schedules; pre-computed inline under
/// [`Parallelism::Sequential`], whose single thread would otherwise
/// interleave anyway), and workers pick new plans up at the fixed
/// segment-indexed epoch boundaries — so nothing ever stalls mid-segment
/// and the output is byte-identical across thread counts.
///
/// If the planner fails, the last good plan is flooded into the
/// remaining epochs so every blocked worker finishes its window, and the
/// planner's error is returned after the join.
pub fn run_pipeline_with_replan(
    cams: Vec<CameraStages<'_>>,
    infer: &dyn InferStage,
    layout: &SegmentLayout,
    parallelism: Parallelism,
    replan: Option<ReplanContext<'_>>,
) -> Result<PipelineOutput> {
    let arena = Arena::new();
    run_pipeline_in(cams, infer, layout, parallelism, replan, None, &arena)
}

/// [`run_pipeline_with_replan`] against a caller-owned [`Arena`], so the
/// server-side inference stage (which the caller builds around the same
/// arena) can recycle its grid buffers through the run's free lists too,
/// and an optional fault schedule for the camera workers to act out.
pub fn run_pipeline_in(
    cams: Vec<CameraStages<'_>>,
    infer: &dyn InferStage,
    layout: &SegmentLayout,
    parallelism: Parallelism,
    replan: Option<ReplanContext<'_>>,
    faults: Option<&FaultContext>,
    arena: &Arena,
) -> Result<PipelineOutput> {
    let n_cams = cams.len();
    let mut frame_sets: Vec<Vec<Option<HashSet<u32>>>> =
        vec![vec![None; layout.n_frames]; n_cams];
    let mut segments: Vec<SegmentRecord> = Vec::new();
    let mut frames_reduced = 0usize;
    let schedule = replan.map(|ctx| ctx.schedule);

    match parallelism {
        Parallelism::Sequential => {
            // epoch plans first: the single thread would compute them at
            // each boundary anyway, and camera 0 crosses every boundary
            // before camera 1 starts
            if let Some(ctx) = replan {
                let mut prev = ctx.schedule.wait(0);
                for k in 1..ctx.schedule.n_epochs() {
                    let plan = ctx.planner.plan_epoch(k, ctx.schedule.start_seg(k), &prev)?;
                    ctx.schedule.publish(k, plan.clone());
                    prev = plan;
                }
            }
            // stream each segment straight into inference — never more
            // than one segment's pixel payloads in flight
            let mut cams = cams;
            let mut first_err: Option<anyhow::Error> = None;
            for (ci, stages) in cams.iter_mut().enumerate() {
                run_camera(ci, stages, layout, schedule, faults, arena, &mut |cs| {
                    match infer.infer_merged(std::slice::from_ref(&cs)) {
                        Ok(mut outcomes) => {
                            let outcome = outcomes.pop().expect("one segment in, one out");
                            finish_segment(
                                cs,
                                outcome,
                                &mut frame_sets,
                                &mut segments,
                                &mut frames_reduced,
                                arena,
                            );
                            true
                        }
                        Err(e) => {
                            first_err = Some(e);
                            false
                        }
                    }
                });
                if let Some(e) = first_err.take() {
                    return Err(e);
                }
            }
        }
        _ => {
            let workers = match parallelism {
                Parallelism::Workers(n) => n.clamp(1, n_cams.max(1)),
                _ => n_cams.max(1),
            };
            let mut buckets: Vec<Vec<(usize, CameraStages<'_>)>> =
                (0..workers).map(|_| Vec::new()).collect();
            for (ci, stages) in cams.into_iter().enumerate() {
                buckets[ci % workers].push((ci, stages));
            }
            let layout = *layout;
            let replan_err: Mutex<Option<anyhow::Error>> = Mutex::new(None);
            std::thread::scope(|scope| -> Result<()> {
                // the re-planner runs beside the stage workers, publishing
                // epochs in order; workers only block at a boundary if the
                // planner has not caught up yet
                if let Some(ctx) = replan {
                    let err_slot = &replan_err;
                    scope.spawn(move || {
                        let mut prev = ctx.schedule.wait(0);
                        for k in 1..ctx.schedule.n_epochs() {
                            // a panicking planner must not strand workers
                            // parked in `PlanSchedule::wait` (the scope
                            // would then never join); catch it and take
                            // the same flood-and-surface path as an Err
                            let outcome = std::panic::catch_unwind(
                                std::panic::AssertUnwindSafe(|| {
                                    ctx.planner.plan_epoch(k, ctx.schedule.start_seg(k), &prev)
                                }),
                            )
                            .unwrap_or_else(|_| {
                                Err(anyhow::anyhow!("re-planner panicked at epoch {k}"))
                            });
                            match outcome {
                                Ok(plan) => {
                                    ctx.schedule.publish(k, plan.clone());
                                    prev = plan;
                                }
                                Err(e) => {
                                    // unblock every waiting worker with the
                                    // last good plan, then surface the error
                                    for kk in k..ctx.schedule.n_epochs() {
                                        ctx.schedule.publish(kk, prev.clone());
                                    }
                                    *err_slot.lock().unwrap() = Some(e);
                                    return;
                                }
                            }
                        }
                    });
                }
                // bounded: each queued segment carries full f32 pixel
                // payloads for its kept frames, so backpressure (not
                // buffering) absorbs any camera-side lead over the
                // inference consumer.  Created inside the scope closure so
                // `rx` drops on an inference error and blocked senders
                // unblock before the scope joins its workers.
                let (tx, rx) = mpsc::sync_channel::<CameraSegment>(2 * n_cams.max(1));
                let arena_ref = arena;
                for bucket in buckets {
                    let tx = tx.clone();
                    scope.spawn(move || {
                        for (ci, mut stages) in bucket {
                            // a dead receiver means the inference stage
                            // failed: stop burning compute on this camera
                            run_camera(
                                ci,
                                &mut stages,
                                &layout,
                                schedule,
                                faults,
                                arena_ref,
                                &mut |cs| tx.send(cs).is_ok(),
                            );
                        }
                    });
                }
                drop(tx);
                // merged server queue: drain whatever is ready into one
                // batched inference call
                while let Ok(first) = rx.recv() {
                    let mut batch = vec![first];
                    while let Ok(next) = rx.try_recv() {
                        batch.push(next);
                    }
                    let outcomes = infer.infer_merged(&batch)?;
                    for (cs, outcome) in batch.into_iter().zip(outcomes) {
                        finish_segment(
                            cs,
                            outcome,
                            &mut frame_sets,
                            &mut segments,
                            &mut frames_reduced,
                            arena,
                        );
                    }
                }
                Ok(())
            })?;
            if let Some(e) = replan_err.into_inner().unwrap() {
                return Err(e);
            }
            // canonical order: reports must not depend on worker timing
            segments.sort_by_key(|s| (s.cam, s.seg));
        }
    }

    Ok(PipelineOutput { segments, frame_sets, frames_reduced, arena: arena.stats() })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Deterministic toy stages: capture paints the frame index, the
    /// filter drops odd non-head frames, encode counts bytes.
    struct TestCapture;
    impl CaptureStage for TestCapture {
        fn capture(&mut self, local: usize, out: &mut Frame) {
            out.w = 16;
            out.h = 16;
            out.data.clear();
            out.data.resize(16 * 16 * 3, (local % 251) as u8);
        }
    }

    struct OddDropFilter;
    impl FilterStage for OddDropFilter {
        fn keep(&mut self, frame: &Frame, segment_head: bool) -> bool {
            segment_head || frame.data[0] % 2 == 0
        }
    }

    struct ByteCountEncode;
    impl EncodeStage for ByteCountEncode {
        fn encode(&mut self, kept: &[&Frame]) -> (crate::codec::EncodedSegment, f64) {
            let bytes: usize = kept.iter().map(|f| f.data.len()).sum();
            (
                crate::codec::EncodedSegment {
                    bytes,
                    n_frames: kept.len(),
                    region_bits: vec![bytes as u64 * 8],
                },
                0.001 * kept.len() as f64,
            )
        }
    }

    struct NullInfer;
    impl InferStage for NullInfer {
        fn infer_merged(&self, segments: &[CameraSegment]) -> Result<Vec<Vec<InferOutcome>>> {
            Ok(segments
                .iter()
                .map(|s| {
                    s.jobs
                        .iter()
                        .map(|j| InferOutcome {
                            local: j.local,
                            capture_time: j.capture_time,
                            secs: 0.002,
                            matched: [j.local as u32].into_iter().collect(),
                        })
                        .collect()
                })
                .collect())
        }
    }

    fn stages<'a>(mask: &'a [IRect]) -> CameraStages<'a> {
        CameraStages {
            capture: Box::new(TestCapture),
            filter: Box::new(OddDropFilter),
            encode: Box::new(ByteCountEncode),
            mask,
        }
    }

    fn run(par: Parallelism, n_cams: usize) -> PipelineOutput {
        let mask = vec![IRect::new(0, 0, 16, 16)];
        let layout = SegmentLayout { n_frames: 10, frames_per_segment: 4, fps: 5.0 };
        let cams: Vec<CameraStages<'_>> = (0..n_cams).map(|_| stages(&mask)).collect();
        run_pipeline(cams, &NullInfer, &layout, par).unwrap()
    }

    #[test]
    fn sequential_output_shape() {
        let out = run(Parallelism::Sequential, 3);
        // 10 frames / 4 per segment = 3 segments per camera
        assert_eq!(out.segments.len(), 9);
        // per camera: heads 0, 4, 8 kept; evens 2, 6 kept; odds dropped
        assert_eq!(out.frames_reduced, 3 * 5);
        for cam in 0..3 {
            let inferred: Vec<usize> = (0..10)
                .filter(|&lf| out.frame_sets[cam][lf].is_some())
                .collect();
            assert_eq!(inferred, vec![0, 2, 4, 6, 8]);
        }
    }

    #[test]
    fn parallel_matches_sequential() {
        let a = run(Parallelism::Sequential, 4);
        for par in [Parallelism::PerCamera, Parallelism::Workers(2), Parallelism::Workers(7)] {
            let b = run(par, 4);
            assert_eq!(a.frames_reduced, b.frames_reduced);
            assert_eq!(a.frame_sets, b.frame_sets);
            assert_eq!(a.segments.len(), b.segments.len());
            for (x, y) in a.segments.iter().zip(&b.segments) {
                assert_eq!((x.cam, x.seg), (y.cam, y.seg));
                assert_eq!(x.bytes, y.bytes);
                assert_eq!(x.capture_end, y.capture_end);
                assert_eq!(x.encode_secs, y.encode_secs);
                assert_eq!(x.frames, y.frames);
            }
        }
    }

    #[test]
    fn arena_recycles_buffers_across_segments() {
        // 3 segments per camera stream through sequentially, so segment 2+
        // must reuse the detector-input buffers segment 1 released
        let out = run(Parallelism::Sequential, 2);
        assert!(out.arena.pixel_allocs > 0);
        assert!(
            out.arena.pixel_reuses > 0,
            "later segments must recycle released pixel buffers: {:?}",
            out.arena
        );
        // frame buffers never exceed one segment's worth per camera
        assert!(out.arena.frame_allocs <= 2 * 4, "frame pool leaked: {:?}", out.arena);
    }

    #[test]
    fn segment_geometry() {
        let out = run(Parallelism::Sequential, 1);
        assert_eq!(out.segments.len(), 3);
        assert_eq!(out.segments[0].frames.len(), 2); // lf 0 (head) + 2 (even)
        assert_eq!(out.segments[1].frames.len(), 2); // lf 4 (head) + 6 (even)
        assert_eq!(out.segments[2].frames.len(), 1); // lf 8 (head); 9 dropped
        assert!((out.segments[0].capture_end - 0.8).abs() < 1e-12);
        assert!((out.segments[2].capture_end - 2.0).abs() < 1e-12);
        assert_eq!(out.segments[0].bytes, 2 * 16 * 16 * 3);
        // frame metadata: (local, capture time = (local+1)/fps, secs)
        assert_eq!(out.segments[0].frames[0].0, 0);
        assert!((out.segments[0].frames[1].1 - 0.6).abs() < 1e-12);
    }
}
