//! Query stage: fuses per-camera inference results into the fleet's
//! per-frame unique-vehicle reports (§5.1.2).
//!
//! Frames the filter stage discarded have no inference result; the server
//! reuses the camera's last inferred result for them (the standard
//! Reducto carry-over behaviour), then unions across cameras.

use std::collections::HashSet;

/// Fuses per-camera per-frame vehicle sets into per-frame fleet reports.
pub trait QueryStage {
    /// `frame_sets[cam][local]` is `Some(vehicles)` for inferred frames
    /// and `None` for filtered ones.
    fn fuse(
        &self,
        frame_sets: &[Vec<Option<HashSet<u32>>>],
        n_frames: usize,
    ) -> Vec<HashSet<u32>>;
}

/// The carry-over fusion described above.
pub struct CarryOverQuery;

impl QueryStage for CarryOverQuery {
    fn fuse(
        &self,
        frame_sets: &[Vec<Option<HashSet<u32>>>],
        n_frames: usize,
    ) -> Vec<HashSet<u32>> {
        let mut reported: Vec<HashSet<u32>> = vec![HashSet::new(); n_frames];
        // lint: order-insensitive — `frame_sets` is a camera-ordered slice,
        // and the union below is commutative anyway
        for cam_sets in frame_sets {
            let mut last: HashSet<u32> = HashSet::new();
            for lf in 0..n_frames {
                if let Some(s) = &cam_sets[lf] {
                    last = s.clone();
                }
                // lint: order-insensitive — set-to-set union
                for &v in &last {
                    reported[lf].insert(v);
                }
            }
        }
        reported
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn set(ids: &[u32]) -> HashSet<u32> {
        ids.iter().copied().collect()
    }

    #[test]
    fn inferred_frames_pass_through() {
        let sets = vec![vec![Some(set(&[1])), Some(set(&[2])), Some(set(&[]))]];
        let fused = CarryOverQuery.fuse(&sets, 3);
        assert_eq!(fused, vec![set(&[1]), set(&[2]), set(&[])]);
    }

    #[test]
    fn filtered_frames_carry_the_last_inferred_result() {
        let sets = vec![vec![Some(set(&[1, 2])), None, None, Some(set(&[3])), None]];
        let fused = CarryOverQuery.fuse(&sets, 5);
        assert_eq!(
            fused,
            vec![set(&[1, 2]), set(&[1, 2]), set(&[1, 2]), set(&[3]), set(&[3])]
        );
    }

    #[test]
    fn empty_inferred_result_clears_the_carry() {
        let sets = vec![vec![Some(set(&[7])), Some(set(&[])), None]];
        let fused = CarryOverQuery.fuse(&sets, 3);
        assert_eq!(fused, vec![set(&[7]), set(&[]), set(&[])]);
    }

    #[test]
    fn leading_filtered_frames_report_nothing() {
        let sets = vec![vec![None, None, Some(set(&[5]))]];
        let fused = CarryOverQuery.fuse(&sets, 3);
        assert_eq!(fused, vec![set(&[]), set(&[]), set(&[5])]);
    }

    #[test]
    fn cameras_union_per_frame() {
        let sets = vec![
            vec![Some(set(&[1])), None],
            vec![Some(set(&[2])), Some(set(&[3]))],
        ];
        let fused = CarryOverQuery.fuse(&sets, 2);
        assert_eq!(fused, vec![set(&[1, 2]), set(&[1, 3])]);
    }

    #[test]
    fn no_cameras_reports_empty_frames() {
        let fused = CarryOverQuery.fuse(&[], 2);
        assert_eq!(fused, vec![set(&[]), set(&[])]);
    }
}
