//! Transport stage: replays the measured segment records on the
//! discrete-event engine — shared-link transport plus camera/server
//! queueing — and produces the latency samples behind Fig. 8f.
//!
//! Compute costs (encode, inference) are **measured** by the earlier
//! stages; this stage replays the transport and queueing behaviour
//! (shared 30 Mbps link, segment queueing, FIFO server) with those
//! measured service times — see DESIGN.md §3 on the testbed substitution.

use crate::net::{Des, SharedLink};
use crate::pipeline::stage::SegmentRecord;

/// DES events of the online pipeline replay.
enum Ev {
    Captured(usize),
    EncodeDone(usize),
    Arrived(usize),
    /// A continuous-re-profiling solve finishing (timestamping only — the
    /// planner runs beside the pipeline and contends with nothing the DES
    /// models).
    ReplanDone(usize),
}

/// Per-frame latency samples from one replay.
#[derive(Debug, Clone, Default)]
pub struct LatencySamples {
    /// Capture-to-encode-done (includes segment queueing).
    pub camera: Vec<f64>,
    /// Encode-done to server arrival (link queueing + tx + propagation).
    pub network: Vec<f64>,
    /// Arrival to inference completion (server queue + inference).
    pub server: Vec<f64>,
    /// Capture to inference completion.
    pub total: Vec<f64>,
}

/// Replays measured segment records into end-to-end latency samples.
pub trait TransportStage {
    fn replay(&self, n_cams: usize, segments: &[SegmentRecord]) -> LatencySamples;
}

/// The discrete-event replay: per-camera FIFO encoders feeding one shared
/// FIFO uplink feeding one FIFO inference server.
pub struct DesTransport {
    pub bandwidth_mbps: f64,
    pub rtt_ms: f64,
}

impl DesTransport {
    pub fn new(bandwidth_mbps: f64, rtt_ms: f64) -> DesTransport {
        DesTransport { bandwidth_mbps, rtt_ms }
    }
}

impl TransportStage for DesTransport {
    fn replay(&self, n_cams: usize, segments: &[SegmentRecord]) -> LatencySamples {
        self.replay_with_replans(n_cams, segments, &[]).0
    }
}

impl DesTransport {
    /// [`TransportStage::replay`] that additionally timestamps continuous
    /// re-profiling on the same virtual clock: each `(trigger, secs)` pair
    /// — the epoch boundary that triggered a re-plan and its measured
    /// planning cost — completes at `trigger + secs` on the DES, and the
    /// completion times are returned in input order (they land in
    /// `MethodReport::replan_done_at`).  Re-planning runs beside the
    /// pipeline and contends with neither the link nor the server, so the
    /// latency samples are identical to a replay without re-plan events.
    pub fn replay_with_replans(
        &self,
        n_cams: usize,
        segments: &[SegmentRecord],
        replans: &[(f64, f64)],
    ) -> (LatencySamples, Vec<f64>) {
        // capture order; the sort is stable, so same-time segments keep
        // their canonical (camera-major) order and the replay is
        // bit-reproducible
        let mut order: Vec<usize> = (0..segments.len()).collect();
        order.sort_by(|&a, &b| {
            segments[a].capture_end.partial_cmp(&segments[b].capture_end).unwrap()
        });
        let mut des: Des<Ev> = Des::new();
        for &si in &order {
            des.at(segments[si].capture_end, Ev::Captured(si));
        }
        for (ri, &(trigger, secs)) in replans.iter().enumerate() {
            des.at(trigger + secs, Ev::ReplanDone(ri));
        }
        let mut link = SharedLink::new(self.bandwidth_mbps, self.rtt_ms);
        let mut cam_free = vec![0.0f64; n_cams];
        let mut enc_done_at = vec![0.0f64; segments.len()];
        let mut arrived_at = vec![0.0f64; segments.len()];
        let mut replan_done_at = vec![0.0f64; replans.len()];
        let mut server_free = 0.0f64;
        let mut out = LatencySamples::default();
        while let Some((now, ev)) = des.pop() {
            match ev {
                Ev::Captured(si) => {
                    let s = &segments[si];
                    let start = now.max(cam_free[s.cam]);
                    let done = start + s.encode_secs;
                    cam_free[s.cam] = done;
                    enc_done_at[si] = done;
                    des.at(done, Ev::EncodeDone(si));
                }
                Ev::EncodeDone(si) => {
                    let arrival = link.transfer(now, segments[si].bytes);
                    arrived_at[si] = arrival;
                    des.at(arrival, Ev::Arrived(si));
                }
                Ev::Arrived(si) => {
                    let s = &segments[si];
                    for &(_, capture, secs) in &s.frames {
                        let start = server_free.max(now);
                        let done = start + secs;
                        server_free = done;
                        out.camera.push(enc_done_at[si] - capture);
                        out.network.push(arrived_at[si] - enc_done_at[si]);
                        out.server.push(done - arrived_at[si]);
                        out.total.push(done - capture);
                    }
                }
                Ev::ReplanDone(ri) => {
                    replan_done_at[ri] = now;
                }
            }
        }
        (out, replan_done_at)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn seg(cam: usize, seg_idx: usize, capture_end: f64, bytes: usize) -> SegmentRecord {
        SegmentRecord {
            cam,
            seg: seg_idx,
            capture_end,
            bytes,
            encode_secs: 0.1,
            frames: vec![(0, capture_end - 0.5, 0.02)],
        }
    }

    #[test]
    fn replay_produces_one_sample_per_frame() {
        let t = DesTransport::new(1.8, 10.0);
        let segs = vec![seg(0, 0, 1.0, 4000), seg(1, 0, 1.0, 4000), seg(0, 1, 2.0, 4000)];
        let lat = t.replay(2, &segs);
        assert_eq!(lat.total.len(), 3);
        for i in 0..3 {
            assert!(lat.camera[i] > 0.0);
            assert!(lat.network[i] > 0.0);
            assert!(lat.server[i] > 0.0);
            let sum = lat.camera[i] + lat.network[i] + lat.server[i];
            assert!((sum - lat.total[i]).abs() < 1e-12);
        }
    }

    #[test]
    fn shared_link_serializes_simultaneous_segments() {
        let t = DesTransport::new(1.8, 0.0);
        // two same-time segments from different cameras: the second must
        // queue behind the first on the shared link
        let segs = vec![seg(0, 0, 1.0, 45_000), seg(1, 0, 1.0, 45_000)];
        let lat = t.replay(2, &segs);
        let tx = 45_000.0 * 8.0 / 1.8e6;
        assert!(lat.network[1] > lat.network[0] + 0.9 * tx, "{:?}", lat.network);
    }

    #[test]
    fn replan_events_are_timestamped_without_perturbing_latencies() {
        let t = DesTransport::new(1.8, 10.0);
        let segs = vec![seg(0, 0, 1.0, 4000), seg(1, 0, 1.0, 4000), seg(0, 1, 2.0, 4000)];
        let plain = t.replay(2, &segs);
        let (with, done_at) =
            t.replay_with_replans(2, &segs, &[(1.0, 0.25), (2.0, 0.5)]);
        assert_eq!(done_at.len(), 2);
        assert!((done_at[0] - 1.25).abs() < 1e-12, "{done_at:?}");
        assert!((done_at[1] - 2.5).abs() < 1e-12, "{done_at:?}");
        // re-planning contends with nothing the DES models
        assert_eq!(plain.total, with.total);
        assert_eq!(plain.camera, with.camera);
        assert_eq!(plain.network, with.network);
    }

    #[test]
    fn replay_is_deterministic() {
        let t = DesTransport::new(1.8, 10.0);
        let segs: Vec<SegmentRecord> =
            (0..20).map(|i| seg(i % 4, i / 4, 1.0 + (i / 4) as f64, 3000 + 100 * i)).collect();
        let a = t.replay(4, &segs);
        let b = t.replay(4, &segs);
        assert_eq!(a.total, b.total);
        assert_eq!(a.camera, b.camera);
    }
}
