//! Inference stage: the server side of the pipeline.
//!
//! Kept frames from all cameras arrive on a merged queue; the stage packs
//! everything currently queued into one [`Infer::infer_batch`] call, then
//! decodes the objectness grids and matches detections to ground-truth
//! identities.  Backends implement [`Infer`]: the real PJRT runtime in
//! benches and examples (feature `pjrt`), the native reference in fast
//! tests.

use std::collections::HashSet;
use std::time::Instant;

use anyhow::Result;

use crate::pipeline::stage::CameraSegment;
use crate::query;
use crate::runtime::postproc::decode_objectness_into;
use crate::sim::Scenario;

/// When the RoI covers at least this fraction of blocks, fall back to the
/// dense detector (§4.4: "we load both RoI-YOLO and normal YOLO into GPU
/// and push large RoI-area videos to normal YOLO").  The threshold sits at
/// the measured crossover of the compiled variants: a mask needing the
/// K=60 capacity runs slower than dense, so only masks that fit K≤32
/// (≤ 32/60 ≈ 53 % coverage) take the SBNet path (see the
/// `sbnet_crossover` bench).
pub const DENSE_FALLBACK_FRACTION: f64 = 0.55;

/// The RoI-vs-dense policy for one camera under one plan: take the SBNet
/// RoI path only when the method wants RoI inference *and* the plan's
/// active blocks sit under the measured crossover
/// ([`DENSE_FALLBACK_FRACTION`] of the backend's block count).  The one
/// rule for both the initial plan and every re-profiled epoch — a policy
/// change here applies to the whole run, never to half of it.
pub fn use_roi_path(
    method: &crate::coordinator::method::Method,
    active_blocks: usize,
    n_infer_blocks: usize,
) -> bool {
    method.uses_roi_inference()
        && (active_blocks as f64) < DENSE_FALLBACK_FRACTION * n_infer_blocks as f64
}

/// One detector invocation's inputs (borrowed from the pending jobs).
#[derive(Debug, Clone, Copy)]
pub struct InferRequest<'a> {
    /// HWC f32 pixels in [0, 1].
    pub frame: &'a [f32],
    /// Active block ids for the RoI variant; `None` means dense.
    pub blocks: Option<&'a [i32]>,
}

/// Inference backend abstraction: the real PJRT runtime in benches and
/// examples, the native reference in fast tests.  `Sync` so the server
/// stage can be shared across pipeline threads.
pub trait Infer: Sync {
    /// Run the detector; `blocks = None` means the dense variant.
    /// Returns the objectness grid and the measured inference seconds.
    fn infer(&self, frame: &[f32], blocks: Option<&[i32]>) -> Result<(Vec<f32>, f64)>;

    /// Run a merged batch of requests (kept frames from all cameras).
    /// The default forwards to [`Infer::infer`] per request; backends
    /// with a real batch dimension override this.
    fn infer_batch(&self, requests: &[InferRequest<'_>]) -> Result<Vec<(Vec<f32>, f64)>> {
        requests.iter().map(|r| self.infer(r.frame, r.blocks)).collect()
    }

    /// Run the detector writing the grid into `out` (cleared and
    /// overwritten), returning the measured inference seconds.  The
    /// default forwards to [`Infer::infer`] and copies; allocation-free
    /// backends override it to fill `out`'s recycled capacity directly.
    fn infer_into(&self, frame: &[f32], blocks: Option<&[i32]>, out: &mut Vec<f32>) -> Result<f64> {
        let (grid, secs) = self.infer(frame, blocks)?;
        out.clear();
        out.extend_from_slice(&grid);
        Ok(secs)
    }

    /// Run a merged batch writing each request's grid into the matching
    /// `grids` slot (the server stage passes recycled arena buffers).
    /// The default forwards to [`Infer::infer_into`] per request;
    /// backends with a real batch dimension override this.
    fn infer_batch_into(
        &self,
        requests: &[InferRequest<'_>],
        grids: &mut [Vec<f32>],
    ) -> Result<Vec<f64>> {
        anyhow::ensure!(
            grids.len() == requests.len(),
            "infer_batch_into got {} grids for {} requests",
            grids.len(),
            requests.len()
        );
        requests
            .iter()
            .zip(grids.iter_mut())
            .map(|(r, g)| self.infer_into(r.frame, r.blocks, g))
            .collect()
    }

    /// Total detector blocks (for the dense-fallback policy).
    fn n_blocks(&self) -> usize {
        60
    }
}

/// Real PJRT-backed inference.
#[cfg(feature = "pjrt")]
pub struct RuntimeInfer<'a>(pub &'a crate::runtime::Runtime);

#[cfg(feature = "pjrt")]
impl Infer for RuntimeInfer<'_> {
    fn infer(&self, frame: &[f32], blocks: Option<&[i32]>) -> Result<(Vec<f32>, f64)> {
        // lint: wall-clock — measured cost feeds latency fields zeroed by
        // zero_wall_clock; determinism tests use FixedCostInfer instead
        let t0 = Instant::now();
        let grid = match blocks {
            None => self.0.infer_full(frame)?,
            Some(b) => self.0.infer_roi(frame, b)?.0,
        };
        Ok((grid, t0.elapsed().as_secs_f64()))
    }

    fn n_blocks(&self) -> usize {
        self.0.contract.n_blocks
    }
}

/// Native reference inference (tests / fast sweeps; never used for
/// reported throughput numbers).
pub struct NativeInfer;

impl Infer for NativeInfer {
    fn infer(&self, frame: &[f32], blocks: Option<&[i32]>) -> Result<(Vec<f32>, f64)> {
        let mut out = Vec::new();
        let secs = self.infer_into(frame, blocks, &mut out)?;
        Ok((out, secs))
    }

    /// Allocation-free steady state: the detector's intermediates live in
    /// a thread-local [`crate::runtime::native::DetectScratch`] and the
    /// grid fills the caller's recycled buffer.
    fn infer_into(&self, frame: &[f32], blocks: Option<&[i32]>, out: &mut Vec<f32>) -> Result<f64> {
        thread_local! {
            static SCRATCH: std::cell::RefCell<crate::runtime::native::DetectScratch> =
                std::cell::RefCell::new(crate::runtime::native::DetectScratch::new());
        }
        SCRATCH.with(|s| {
            let mut guard = s.borrow_mut();
            let scratch = &mut *guard;
            // lint: wall-clock — measured cost feeds latency fields zeroed by
            // zero_wall_clock; determinism tests use FixedCostInfer instead
            let t0 = Instant::now();
            match blocks {
                None => crate::runtime::native::detect_full_into(frame, 192, 320, scratch, out),
                Some(b) => crate::runtime::native::detect_roi_into(
                    frame, 192, 320, b, 32, 10, scratch, out,
                ),
            }
            Ok(t0.elapsed().as_secs_f64())
        })
    }
}

/// One kept frame's inference result, ready for the DES replay and the
/// query stage.
#[derive(Debug, Clone)]
pub struct InferOutcome {
    pub local: usize,
    pub capture_time: f64,
    /// Inference service time in seconds.
    pub secs: f64,
    /// Ground-truth vehicle ids the detections cover.
    pub matched: HashSet<u32>,
}

/// The server-side inference stage: consumes merged camera segments and
/// produces per-frame outcomes.
pub trait InferStage {
    /// Run one merged batch — all pending jobs of `segments` in a single
    /// [`Infer::infer_batch`] call — returning outcomes per segment, in
    /// the same order.
    fn infer_merged(&self, segments: &[CameraSegment]) -> Result<Vec<Vec<InferOutcome>>>;
}

/// [`InferStage`] over any [`Infer`] backend, with per-camera RoI policy
/// and ground-truth matching for the unique-vehicle query.
///
/// Under continuous re-profiling, `schedule` maps each incoming segment
/// to its planning epoch, whose blocks / RoI policy override the static
/// per-camera fields — a segment is always inferred against the same plan
/// it was captured and encoded under.
pub struct BatchedInfer<'a> {
    pub infer: &'a dyn Infer,
    pub scenario: &'a Scenario,
    /// Active detector blocks per camera (the whole run's plan, or epoch 0
    /// when a `schedule` is installed).
    pub blocks: &'a [Vec<i32>],
    /// Whether each camera takes the SBNet RoI path.
    pub use_roi: &'a [bool],
    /// Re-profiling epoch schedule (`None` = static plan).
    pub schedule: Option<&'a crate::pipeline::replan::PlanSchedule>,
    /// Fault timeline (`None` = no faults): a degraded segment streamed
    /// full-frame is inferred on the dense path regardless of its epoch's
    /// RoI policy — its pixels cover the whole frame, not the mask.
    pub fault: Option<&'a crate::pipeline::replan::FaultTimeline>,
    pub objectness_threshold: f64,
    /// Absolute frame index of the evaluation window's first frame.
    pub eval_start: usize,
    /// Buffer arena to recycle grid outputs through (`None` = allocate
    /// per batch — tests and benches that don't thread an arena in).
    pub arena: Option<&'a crate::pipeline::arena::Arena>,
}

impl InferStage for BatchedInfer<'_> {
    fn infer_merged(&self, segments: &[CameraSegment]) -> Result<Vec<Vec<InferOutcome>>> {
        // resolve each segment's epoch plan first so the borrowed block
        // slices below live as long as the request batch; a segment only
        // reaches the server after its camera worker picked the epoch up,
        // so the plan is always published by now
        let epoch_plans: Vec<Option<std::sync::Arc<crate::pipeline::replan::PlanEpoch>>> =
            segments
                .iter()
                .map(|s| {
                    self.schedule.map(|sched| {
                        sched
                            .get(sched.epoch_of(s.seg))
                            .expect("segment arrived before its epoch plan was published")
                    })
                })
                .collect();
        let mut requests = Vec::new();
        for (s, epoch) in segments.iter().zip(&epoch_plans) {
            let (blocks, mut use_roi): (&[i32], bool) = match epoch {
                Some(p) => (p.blocks[s.cam].as_slice(), p.use_roi[s.cam]),
                None => (self.blocks[s.cam].as_slice(), self.use_roi[s.cam]),
            };
            if self.fault.is_some_and(|t| t.degraded_seg(s.cam, s.seg)) {
                use_roi = false;
            }
            for job in &s.jobs {
                requests.push(InferRequest {
                    frame: &job.pixels,
                    blocks: if use_roi { Some(blocks) } else { None },
                });
            }
        }
        // grid outputs come from the arena's free list when one is
        // installed, so the steady-state server loop allocates nothing
        let mut grids: Vec<Vec<f32>> = match self.arena {
            Some(a) => (0..requests.len()).map(|_| a.take_grid()).collect(),
            None => vec![Vec::new(); requests.len()],
        };
        let times = self.infer.infer_batch_into(&requests, &mut grids)?;
        anyhow::ensure!(
            times.len() == requests.len(),
            "infer_batch_into returned {} results for {} requests",
            times.len(),
            requests.len()
        );
        // decode through thread-local reusable traversal buffers — the
        // same allocation-free contract as the backend's scratch
        thread_local! {
            static DECODE: std::cell::RefCell<(
                crate::runtime::postproc::DecodeScratch,
                Vec<crate::runtime::postproc::Detection>,
            )> = std::cell::RefCell::new((
                crate::runtime::postproc::DecodeScratch::new(),
                Vec::new(),
            ));
        }
        let out = DECODE.with(|cell| {
            let mut guard = cell.borrow_mut();
            let (scratch, dets) = &mut *guard;
            let mut idx = 0;
            let mut out = Vec::with_capacity(segments.len());
            for s in segments {
                let mut frames = Vec::with_capacity(s.jobs.len());
                for job in &s.jobs {
                    decode_objectness_into(
                        &grids[idx],
                        12,
                        20,
                        16,
                        self.objectness_threshold,
                        scratch,
                        dets,
                    );
                    let abs = self.eval_start + job.local;
                    let matched =
                        query::match_detections(dets, self.scenario.detections(s.cam, abs));
                    frames.push(InferOutcome {
                        local: job.local,
                        capture_time: job.capture_time,
                        secs: times[idx],
                        matched,
                    });
                    idx += 1;
                }
                out.push(frames);
            }
            out
        });
        if let Some(a) = self.arena {
            for g in grids {
                a.put_grid(g);
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A backend that records batch sizes and returns a fixed grid.
    struct CountingInfer(std::sync::Mutex<Vec<usize>>);

    impl Infer for CountingInfer {
        fn infer(&self, _frame: &[f32], _blocks: Option<&[i32]>) -> Result<(Vec<f32>, f64)> {
            Ok((vec![0.0; 12 * 20], 0.001))
        }

        fn infer_batch_into(
            &self,
            requests: &[InferRequest<'_>],
            grids: &mut [Vec<f32>],
        ) -> Result<Vec<f64>> {
            self.0.lock().unwrap().push(requests.len());
            requests
                .iter()
                .zip(grids.iter_mut())
                .map(|(r, g)| self.infer_into(r.frame, r.blocks, g))
                .collect()
        }
    }

    #[test]
    fn merged_segments_become_one_batch() {
        use crate::config::Config;
        use crate::pipeline::stage::InferJob;

        let cfg = Config::test_small();
        let sc = Scenario::build(&cfg.scenario);
        let backend = CountingInfer(std::sync::Mutex::new(Vec::new()));
        let arena = crate::pipeline::arena::Arena::new();
        let blocks: Vec<Vec<i32>> = vec![Vec::new(); sc.cameras.len()];
        let use_roi = vec![false; sc.cameras.len()];
        let stage = BatchedInfer {
            infer: &backend,
            scenario: &sc,
            blocks: &blocks,
            use_roi: &use_roi,
            schedule: None,
            fault: None,
            objectness_threshold: 0.25,
            eval_start: sc.eval_range().start,
            arena: Some(&arena),
        };
        let job = |local: usize| InferJob {
            local,
            capture_time: (local as f64 + 1.0) / 5.0,
            pixels: vec![0.0f32; 320 * 192 * 3],
        };
        let segs = vec![
            CameraSegment {
                cam: 0,
                seg: 0,
                capture_end: 1.0,
                bytes: 10,
                encode_secs: 0.0,
                dropped: 0,
                jobs: vec![job(0), job(1)],
            },
            CameraSegment {
                cam: 1,
                seg: 0,
                capture_end: 1.0,
                bytes: 10,
                encode_secs: 0.0,
                dropped: 0,
                jobs: vec![job(0)],
            },
        ];
        let out = stage.infer_merged(&segs).unwrap();
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].len(), 2);
        assert_eq!(out[1].len(), 1);
        // both segments' jobs were merged into a single batch call
        assert_eq!(*backend.0.lock().unwrap(), vec![3]);
        assert!((out[0][1].capture_time - 0.4).abs() < 1e-12);
        // the batch's grid buffers came fresh from, and returned to, the
        // arena: a second merged batch recycles instead of allocating
        assert_eq!(arena.stats().grid_allocs, 3);
        stage.infer_merged(&segs).unwrap();
        let s = arena.stats();
        assert_eq!(s.grid_allocs, 3, "second batch must reuse the free list");
        assert_eq!(s.grid_reuses, 3);
    }
}
