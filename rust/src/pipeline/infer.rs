//! Inference stage: the server side of the pipeline.
//!
//! Kept frames from all cameras arrive on a merged queue; the stage packs
//! everything currently queued into one [`Infer::infer_batch`] call, then
//! decodes the objectness grids and matches detections to ground-truth
//! identities.  Backends implement [`Infer`]: the real PJRT runtime in
//! benches and examples (feature `pjrt`), the native reference in fast
//! tests.

use std::collections::HashSet;
use std::time::Instant;

use anyhow::Result;

use crate::pipeline::canvas::{
    self, consolidation_active, CanvasTally, ConsolidateMode, GATHER_INFLATE_CELLS, GUTTER_PX,
    SCATTER_INFLATE_CELLS,
};
use crate::pipeline::stage::CameraSegment;
use crate::query;
use crate::runtime::postproc::decode_objectness_into;
use crate::sim::Scenario;
use crate::tilegroup::pack::{PackItem, Packer, Placement};
use crate::util::geometry::IRect;

/// When the RoI covers at least this fraction of blocks, fall back to the
/// dense detector (§4.4: "we load both RoI-YOLO and normal YOLO into GPU
/// and push large RoI-area videos to normal YOLO").  The threshold sits at
/// the measured crossover of the compiled variants: a mask needing the
/// K=60 capacity runs slower than dense, so only masks that fit K≤32
/// (≤ 32/60 ≈ 53 % coverage) take the SBNet path (see the
/// `sbnet_crossover` bench).
pub const DENSE_FALLBACK_FRACTION: f64 = 0.55;

/// The RoI-vs-dense policy for one camera under one plan: take the SBNet
/// RoI path only when the method wants RoI inference *and* the plan's
/// active blocks sit under the measured crossover
/// ([`DENSE_FALLBACK_FRACTION`] of the backend's block count).  The one
/// rule for both the initial plan and every re-profiled epoch — a policy
/// change here applies to the whole run, never to half of it.
pub fn use_roi_path(
    method: &crate::coordinator::method::Method,
    active_blocks: usize,
    n_infer_blocks: usize,
) -> bool {
    method.uses_roi_inference()
        && (active_blocks as f64) < DENSE_FALLBACK_FRACTION * n_infer_blocks as f64
}

/// The three-way per-camera inference route under one plan.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InferRoute {
    /// Full-frame inference (RoI off, coverage over the sbnet crossover,
    /// or a fault-degraded segment streaming the whole frame).
    Dense,
    /// Per-camera sparse-block (sbnet) inference.
    Blocks,
    /// Cross-camera canvas consolidation ([`crate::pipeline::canvas`]).
    Canvas,
}

/// Extend [`use_roi_path`] into the dense / blocks / canvas router:
/// `use_roi` is the per-camera sbnet decision, `consolidated` the
/// fleet-wide [`consolidation_active`] predicate of the same plan.
pub fn infer_route(use_roi: bool, consolidated: bool) -> InferRoute {
    match (use_roi, consolidated) {
        (false, _) => InferRoute::Dense,
        (true, true) => InferRoute::Canvas,
        (true, false) => InferRoute::Blocks,
    }
}

/// One detector invocation's inputs (borrowed from the pending jobs).
#[derive(Debug, Clone, Copy)]
pub struct InferRequest<'a> {
    /// HWC f32 pixels in [0, 1].
    pub frame: &'a [f32],
    /// Active block ids for the RoI variant; `None` means dense.
    pub blocks: Option<&'a [i32]>,
}

/// Inference backend abstraction: the real PJRT runtime in benches and
/// examples, the native reference in fast tests.  `Sync` so the server
/// stage can be shared across pipeline threads.
pub trait Infer: Sync {
    /// Run the detector; `blocks = None` means the dense variant.
    /// Returns the objectness grid and the measured inference seconds.
    fn infer(&self, frame: &[f32], blocks: Option<&[i32]>) -> Result<(Vec<f32>, f64)>;

    /// Run a merged batch of requests (kept frames from all cameras).
    /// The default forwards to [`Infer::infer`] per request; backends
    /// with a real batch dimension override this.
    fn infer_batch(&self, requests: &[InferRequest<'_>]) -> Result<Vec<(Vec<f32>, f64)>> {
        requests.iter().map(|r| self.infer(r.frame, r.blocks)).collect()
    }

    /// Run the detector writing the grid into `out` (cleared and
    /// overwritten), returning the measured inference seconds.  The
    /// default forwards to [`Infer::infer`] and copies; allocation-free
    /// backends override it to fill `out`'s recycled capacity directly.
    fn infer_into(&self, frame: &[f32], blocks: Option<&[i32]>, out: &mut Vec<f32>) -> Result<f64> {
        let (grid, secs) = self.infer(frame, blocks)?;
        out.clear();
        out.extend_from_slice(&grid);
        Ok(secs)
    }

    /// Run a merged batch writing each request's grid into the matching
    /// `grids` slot (the server stage passes recycled arena buffers).
    /// The default forwards to [`Infer::infer_into`] per request;
    /// backends with a real batch dimension override this.
    fn infer_batch_into(
        &self,
        requests: &[InferRequest<'_>],
        grids: &mut [Vec<f32>],
    ) -> Result<Vec<f64>> {
        anyhow::ensure!(
            grids.len() == requests.len(),
            "infer_batch_into got {} grids for {} requests",
            grids.len(),
            requests.len()
        );
        requests
            .iter()
            .zip(grids.iter_mut())
            .map(|(r, g)| self.infer_into(r.frame, r.blocks, g))
            .collect()
    }

    /// Total detector blocks (for the dense-fallback policy).
    fn n_blocks(&self) -> usize {
        60
    }
}

/// Real PJRT-backed inference.
#[cfg(feature = "pjrt")]
pub struct RuntimeInfer<'a>(pub &'a crate::runtime::Runtime);

#[cfg(feature = "pjrt")]
impl Infer for RuntimeInfer<'_> {
    fn infer(&self, frame: &[f32], blocks: Option<&[i32]>) -> Result<(Vec<f32>, f64)> {
        // lint: wall-clock — measured cost feeds latency fields zeroed by
        // zero_wall_clock; determinism tests use FixedCostInfer instead
        let t0 = Instant::now();
        let grid = match blocks {
            None => self.0.infer_full(frame)?,
            Some(b) => self.0.infer_roi(frame, b)?.0,
        };
        Ok((grid, t0.elapsed().as_secs_f64()))
    }

    fn n_blocks(&self) -> usize {
        self.0.contract.n_blocks
    }
}

/// Native reference inference (tests / fast sweeps; never used for
/// reported throughput numbers).
pub struct NativeInfer;

impl Infer for NativeInfer {
    fn infer(&self, frame: &[f32], blocks: Option<&[i32]>) -> Result<(Vec<f32>, f64)> {
        let mut out = Vec::new();
        let secs = self.infer_into(frame, blocks, &mut out)?;
        Ok((out, secs))
    }

    /// Allocation-free steady state: the detector's intermediates live in
    /// a thread-local [`crate::runtime::native::DetectScratch`] and the
    /// grid fills the caller's recycled buffer.
    fn infer_into(&self, frame: &[f32], blocks: Option<&[i32]>, out: &mut Vec<f32>) -> Result<f64> {
        thread_local! {
            static SCRATCH: std::cell::RefCell<crate::runtime::native::DetectScratch> =
                std::cell::RefCell::new(crate::runtime::native::DetectScratch::new());
        }
        SCRATCH.with(|s| {
            let mut guard = s.borrow_mut();
            let scratch = &mut *guard;
            // lint: wall-clock — measured cost feeds latency fields zeroed by
            // zero_wall_clock; determinism tests use FixedCostInfer instead
            let t0 = Instant::now();
            match blocks {
                None => crate::runtime::native::detect_full_into(frame, 192, 320, scratch, out),
                Some(b) => crate::runtime::native::detect_roi_into(
                    frame, 192, 320, b, 32, 10, scratch, out,
                ),
            }
            Ok(t0.elapsed().as_secs_f64())
        })
    }
}

/// One kept frame's inference result, ready for the DES replay and the
/// query stage.
#[derive(Debug, Clone)]
pub struct InferOutcome {
    pub local: usize,
    pub capture_time: f64,
    /// Inference service time in seconds.
    pub secs: f64,
    /// Ground-truth vehicle ids the detections cover.
    pub matched: HashSet<u32>,
}

/// The server-side inference stage: consumes merged camera segments and
/// produces per-frame outcomes.
pub trait InferStage {
    /// Run one merged batch — all pending jobs of `segments` in a single
    /// [`Infer::infer_batch`] call — returning outcomes per segment, in
    /// the same order.
    fn infer_merged(&self, segments: &[CameraSegment]) -> Result<Vec<Vec<InferOutcome>>>;
}

/// [`InferStage`] over any [`Infer`] backend, with per-camera RoI policy
/// and ground-truth matching for the unique-vehicle query.
///
/// Under continuous re-profiling, `schedule` maps each incoming segment
/// to its planning epoch, whose blocks / RoI policy override the static
/// per-camera fields — a segment is always inferred against the same plan
/// it was captured and encoded under.
pub struct BatchedInfer<'a> {
    pub infer: &'a dyn Infer,
    pub scenario: &'a Scenario,
    /// Active detector blocks per camera (the whole run's plan, or epoch 0
    /// when a `schedule` is installed).
    pub blocks: &'a [Vec<i32>],
    /// Whether each camera takes the SBNet RoI path.
    pub use_roi: &'a [bool],
    /// Tile groups per camera (same epoch-0 convention as `blocks`) —
    /// the rects the canvas route gathers and scatters through.
    pub groups: &'a [Vec<IRect>],
    /// Cross-camera consolidation policy; with `Auto`/`On` active, RoI
    /// cameras route through packed canvases instead of per-camera
    /// sparse-block inference ([`crate::pipeline::canvas`]).
    pub consolidate: ConsolidateMode,
    /// Consolidation diagnostics sink (`None` = don't tally).
    pub canvas_tally: Option<&'a CanvasTally>,
    /// Re-profiling epoch schedule (`None` = static plan).
    pub schedule: Option<&'a crate::pipeline::replan::PlanSchedule>,
    /// Fault timeline (`None` = no faults): a degraded segment streamed
    /// full-frame is inferred on the dense path regardless of its epoch's
    /// RoI policy — its pixels cover the whole frame, not the mask.
    pub fault: Option<&'a crate::pipeline::replan::FaultTimeline>,
    pub objectness_threshold: f64,
    /// Absolute frame index of the evaluation window's first frame.
    pub eval_start: usize,
    /// Buffer arena to recycle grid outputs through (`None` = allocate
    /// per batch — tests and benches that don't thread an arena in).
    pub arena: Option<&'a crate::pipeline::arena::Arena>,
}

impl InferStage for BatchedInfer<'_> {
    fn infer_merged(&self, segments: &[CameraSegment]) -> Result<Vec<Vec<InferOutcome>>> {
        const FRAME_H: usize = 192;
        const FRAME_W: usize = 320;
        const GRID_H: usize = 12;
        const GRID_W: usize = 20;
        let frame_px = (FRAME_W * FRAME_H) as u64;
        // resolve each segment's epoch plan first so the borrowed block
        // slices below live as long as the request batch; a segment only
        // reaches the server after its camera worker picked the epoch up,
        // so the plan is always published by now
        let epoch_plans: Vec<Option<std::sync::Arc<crate::pipeline::replan::PlanEpoch>>> =
            segments
                .iter()
                .map(|s| {
                    self.schedule.map(|sched| {
                        sched
                            .get(sched.epoch_of(s.seg))
                            .expect("segment arrived before its epoch plan was published")
                    })
                })
                .collect();
        // per-segment route: a pure function of the segment's plan —
        // blocks, RoI policy and the fleet-wide consolidation predicate
        // all come from the epoch (or static plan), never from what
        // happens to be queued, so reports stay schedule-invariant
        let mut seg_plan: Vec<(&[i32], &[IRect], InferRoute)> =
            Vec::with_capacity(segments.len());
        for (s, epoch) in segments.iter().zip(&epoch_plans) {
            let (blocks, groups, mut use_roi, consolidated): (&[i32], &[IRect], bool, bool) =
                match epoch {
                    Some(p) => (
                        p.blocks[s.cam].as_slice(),
                        p.groups[s.cam].as_slice(),
                        p.use_roi[s.cam],
                        consolidation_active(self.consolidate, &p.use_roi, &p.groups, frame_px),
                    ),
                    None => (
                        self.blocks[s.cam].as_slice(),
                        self.groups[s.cam].as_slice(),
                        self.use_roi[s.cam],
                        consolidation_active(self.consolidate, self.use_roi, self.groups, frame_px),
                    ),
                };
            if self.fault.is_some_and(|t| t.degraded_seg(s.cam, s.seg)) {
                // degraded segments stream the full frame: dense, never packed
                use_roi = false;
            }
            seg_plan.push((blocks, groups, infer_route(use_roi, consolidated)));
        }
        // flatten jobs; each canvas-routed job contributes one pack item
        // per tile group (gather = group + 2 cells, scatter = group + 1
        // cell — the byte-identity construction of pipeline/canvas.rs)
        let mut flat: Vec<(usize, &crate::pipeline::stage::InferJob)> = Vec::new();
        let mut items: Vec<PackItem> = Vec::new();
        let mut item_info: Vec<(usize, IRect, IRect)> = Vec::new(); // (flat job, gather, scatter)
        for (si, s) in segments.iter().enumerate() {
            let (_, groups, route) = seg_plan[si];
            for job in &s.jobs {
                let fj = flat.len();
                flat.push((si, job));
                if route == InferRoute::Canvas {
                    for g in groups {
                        let gather = canvas::inflate_clip(
                            *g,
                            GATHER_INFLATE_CELLS,
                            FRAME_W as u32,
                            FRAME_H as u32,
                        );
                        let scatter = canvas::inflate_clip(
                            *g,
                            SCATTER_INFLATE_CELLS,
                            FRAME_W as u32,
                            FRAME_H as u32,
                        );
                        items.push(PackItem { id: item_info.len(), w: gather.w, h: gather.h });
                        item_info.push((fj, gather, scatter));
                    }
                }
            }
        }
        let mut packer = Packer::new(FRAME_W as u32, FRAME_H as u32, GUTTER_PX);
        let mut placements: Vec<Placement> = Vec::new();
        let n_canvases = packer.pack(&items, &mut placements);
        // canvas pixel buffers recycle through the arena like the grids
        let mut canvases: Vec<Vec<f32>> = (0..n_canvases)
            .map(|_| match self.arena {
                Some(a) => a.take_canvas(),
                None => Vec::new(),
            })
            .collect();
        for cv in canvases.iter_mut() {
            cv.clear();
            cv.resize(FRAME_W * FRAME_H * 3, 0.0);
        }
        let mut job_pl: Vec<Vec<usize>> = vec![Vec::new(); flat.len()];
        for (pi, p) in placements.iter().enumerate() {
            let (fj, gather, _) = item_info[p.id];
            canvas::gather_into(
                &mut canvases[p.canvas],
                FRAME_W,
                &flat[fj].1.pixels,
                FRAME_W,
                gather,
                p.x,
                p.y,
            );
            job_pl[fj].push(pi);
        }
        // one merged request batch: direct jobs first (in job order),
        // then the packed canvases (always dense)
        let mut requests = Vec::new();
        let mut direct_idx: Vec<Option<usize>> = Vec::with_capacity(flat.len());
        for &(si, job) in &flat {
            let (blocks, _, route) = seg_plan[si];
            match route {
                InferRoute::Canvas => direct_idx.push(None),
                InferRoute::Blocks => {
                    direct_idx.push(Some(requests.len()));
                    requests.push(InferRequest { frame: &job.pixels, blocks: Some(blocks) });
                }
                InferRoute::Dense => {
                    direct_idx.push(Some(requests.len()));
                    requests.push(InferRequest { frame: &job.pixels, blocks: None });
                }
            }
        }
        let n_direct = requests.len();
        for cv in &canvases {
            requests.push(InferRequest { frame: cv, blocks: None });
        }
        // grid outputs come from the arena's free list when one is
        // installed, so the steady-state server loop allocates nothing
        let mut grids: Vec<Vec<f32>> = match self.arena {
            Some(a) => (0..requests.len()).map(|_| a.take_grid()).collect(),
            None => vec![Vec::new(); requests.len()],
        };
        let times = self.infer.infer_batch_into(&requests, &mut grids)?;
        anyhow::ensure!(
            times.len() == requests.len(),
            "infer_batch_into returned {} results for {} requests",
            times.len(),
            requests.len()
        );
        // decode through thread-local reusable traversal buffers — the
        // same allocation-free contract as the backend's scratch
        thread_local! {
            static DECODE: std::cell::RefCell<(
                crate::runtime::postproc::DecodeScratch,
                Vec<crate::runtime::postproc::Detection>,
                Vec<f32>,  // reconstructed per-camera grid (canvas route)
                Vec<bool>, // active-cell bitmap of the current segment
            )> = std::cell::RefCell::new((
                crate::runtime::postproc::DecodeScratch::new(),
                Vec::new(),
                Vec::new(),
                Vec::new(),
            ));
        }
        let out = DECODE.with(|cell| {
            let mut guard = cell.borrow_mut();
            let (scratch, dets, recon, active) = &mut *guard;
            let mut fj = 0;
            let mut out = Vec::with_capacity(segments.len());
            for (si, s) in segments.iter().enumerate() {
                let (blocks, _, route) = seg_plan[si];
                if route == InferRoute::Canvas && !s.jobs.is_empty() {
                    canvas::active_cells(blocks, GRID_W, GRID_H, 2, 10, active);
                }
                let mut frames = Vec::with_capacity(s.jobs.len());
                for job in &s.jobs {
                    let secs = match direct_idx[fj] {
                        Some(ri) => {
                            decode_objectness_into(
                                &grids[ri],
                                GRID_H,
                                GRID_W,
                                16,
                                self.objectness_threshold,
                                scratch,
                                dets,
                            );
                            times[ri]
                        }
                        None => {
                            recon.clear();
                            recon.resize(GRID_H * GRID_W, 0.0);
                            let mut t = 0.0;
                            for &pi in &job_pl[fj] {
                                let p = placements[pi];
                                let (_, gather, scatter) = item_info[p.id];
                                canvas::scatter_into(
                                    recon,
                                    &grids[n_direct + p.canvas],
                                    GRID_W,
                                    scatter,
                                    gather,
                                    p.x,
                                    p.y,
                                    active,
                                );
                                // apportion the canvas's measured time by the
                                // placement's pixel share — a pure function of
                                // the plan under a fixed-cost backend, so
                                // reports stay schedule-invariant
                                t += times[n_direct + p.canvas]
                                    * (gather.area() as f64 / frame_px as f64);
                            }
                            decode_objectness_into(
                                recon,
                                GRID_H,
                                GRID_W,
                                16,
                                self.objectness_threshold,
                                scratch,
                                dets,
                            );
                            t
                        }
                    };
                    let abs = self.eval_start + job.local;
                    let matched =
                        query::match_detections(dets, self.scenario.detections(s.cam, abs));
                    frames.push(InferOutcome {
                        local: job.local,
                        capture_time: job.capture_time,
                        secs,
                        matched,
                    });
                    fj += 1;
                }
                out.push(frames);
            }
            out
        });
        if let Some(t) = self.canvas_tally {
            let jobs = direct_idx.iter().filter(|d| d.is_none()).count();
            let placed: u64 = item_info.iter().map(|(_, g, _)| g.area()).sum();
            t.record(n_canvases, jobs, placed);
        }
        if let Some(a) = self.arena {
            for g in grids {
                a.put_grid(g);
            }
            for cv in canvases {
                a.put_canvas(cv);
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A backend that records batch sizes and returns a fixed grid.
    struct CountingInfer(std::sync::Mutex<Vec<usize>>);

    impl Infer for CountingInfer {
        fn infer(&self, _frame: &[f32], _blocks: Option<&[i32]>) -> Result<(Vec<f32>, f64)> {
            Ok((vec![0.0; 12 * 20], 0.001))
        }

        fn infer_batch_into(
            &self,
            requests: &[InferRequest<'_>],
            grids: &mut [Vec<f32>],
        ) -> Result<Vec<f64>> {
            self.0.lock().unwrap().push(requests.len());
            requests
                .iter()
                .zip(grids.iter_mut())
                .map(|(r, g)| self.infer_into(r.frame, r.blocks, g))
                .collect()
        }
    }

    #[test]
    fn merged_segments_become_one_batch() {
        use crate::config::Config;
        use crate::pipeline::stage::InferJob;

        let cfg = Config::test_small();
        let sc = Scenario::build(&cfg.scenario);
        let backend = CountingInfer(std::sync::Mutex::new(Vec::new()));
        let arena = crate::pipeline::arena::Arena::new();
        let blocks: Vec<Vec<i32>> = vec![Vec::new(); sc.cameras.len()];
        let use_roi = vec![false; sc.cameras.len()];
        let groups: Vec<Vec<IRect>> = vec![Vec::new(); sc.cameras.len()];
        let stage = BatchedInfer {
            infer: &backend,
            scenario: &sc,
            blocks: &blocks,
            use_roi: &use_roi,
            groups: &groups,
            consolidate: ConsolidateMode::Off,
            canvas_tally: None,
            schedule: None,
            fault: None,
            objectness_threshold: 0.25,
            eval_start: sc.eval_range().start,
            arena: Some(&arena),
        };
        let job = |local: usize| InferJob {
            local,
            capture_time: (local as f64 + 1.0) / 5.0,
            pixels: vec![0.0f32; 320 * 192 * 3],
        };
        let segs = vec![
            CameraSegment {
                cam: 0,
                seg: 0,
                capture_end: 1.0,
                bytes: 10,
                encode_secs: 0.0,
                dropped: 0,
                jobs: vec![job(0), job(1)],
            },
            CameraSegment {
                cam: 1,
                seg: 0,
                capture_end: 1.0,
                bytes: 10,
                encode_secs: 0.0,
                dropped: 0,
                jobs: vec![job(0)],
            },
        ];
        let out = stage.infer_merged(&segs).unwrap();
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].len(), 2);
        assert_eq!(out[1].len(), 1);
        // both segments' jobs were merged into a single batch call
        assert_eq!(*backend.0.lock().unwrap(), vec![3]);
        assert!((out[0][1].capture_time - 0.4).abs() < 1e-12);
        // the batch's grid buffers came fresh from, and returned to, the
        // arena: a second merged batch recycles instead of allocating
        assert_eq!(arena.stats().grid_allocs, 3);
        stage.infer_merged(&segs).unwrap();
        let s = arena.stats();
        assert_eq!(s.grid_allocs, 3, "second batch must reuse the free list");
        assert_eq!(s.grid_reuses, 3);
    }

    #[test]
    fn canvas_route_folds_sparse_jobs_into_one_request() {
        use crate::config::Config;
        use crate::pipeline::stage::InferJob;

        let cfg = Config::test_small();
        let sc = Scenario::build(&cfg.scenario);
        let backend = CountingInfer(std::sync::Mutex::new(Vec::new()));
        let arena = crate::pipeline::arena::Arena::new();
        let n = sc.cameras.len();
        // every camera keeps one 32×32 group in its top-left block
        let blocks: Vec<Vec<i32>> = vec![vec![0]; n];
        let use_roi = vec![true; n];
        let groups: Vec<Vec<IRect>> = vec![vec![IRect::new(0, 0, 32, 32)]; n];
        let tally = CanvasTally::default();
        let stage = BatchedInfer {
            infer: &backend,
            scenario: &sc,
            blocks: &blocks,
            use_roi: &use_roi,
            groups: &groups,
            consolidate: ConsolidateMode::On,
            canvas_tally: Some(&tally),
            schedule: None,
            fault: None,
            objectness_threshold: 0.25,
            eval_start: sc.eval_range().start,
            arena: Some(&arena),
        };
        let job = |local: usize| InferJob {
            local,
            capture_time: (local as f64 + 1.0) / 5.0,
            pixels: vec![0.0f32; 320 * 192 * 3],
        };
        let seg = |cam: usize, jobs: Vec<InferJob>| CameraSegment {
            cam,
            seg: 0,
            capture_end: 1.0,
            bytes: 10,
            encode_secs: 0.0,
            dropped: 0,
            jobs,
        };
        let segs = vec![seg(0, vec![job(0), job(1)]), seg(1, vec![job(0)])];
        let out = stage.infer_merged(&segs).unwrap();
        assert_eq!(out.len(), 2);
        // three sparse jobs (one 64×64 gather each) pack into a single
        // canvas, so the backend sees exactly one dense request
        assert_eq!(*backend.0.lock().unwrap(), vec![1]);
        assert_eq!(tally.canvases(), 1);
        assert!((tally.occupancy() - 3.0).abs() < 1e-12);
        // each job's service time is its pixel share of the one canvas
        let share = 0.001 * (64.0 * 64.0) / (320.0 * 192.0);
        assert!((out[0][0].secs - share).abs() < 1e-15);
        // canvas buffers recycle like grids
        assert_eq!(arena.stats().canvas_allocs, 1);
        stage.infer_merged(&segs).unwrap();
        let s = arena.stats();
        assert_eq!(s.canvas_allocs, 1, "second batch must reuse the canvas");
        assert_eq!(s.canvas_reuses, 1);
    }
}
