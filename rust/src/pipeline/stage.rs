//! Typed inter-stage records and the camera-side stage traits.
//!
//! A camera worker drives `CaptureStage → FilterStage → EncodeStage` over
//! its streaming segments and emits one [`CameraSegment`] per segment into
//! the merged server queue.  The server side turns each into a
//! [`SegmentRecord`] once the inference stage has measured its per-frame
//! service times; the transport stage then replays the records on the DES
//! (see DESIGN.md §4).

use crate::codec::EncodedSegment;
use crate::sim::render::Frame;

/// Segmenting geometry of one online run (shared by every stage).
#[derive(Debug, Clone, Copy)]
pub struct SegmentLayout {
    /// Evaluation-window length in frames.
    pub n_frames: usize,
    /// Frames per streaming segment (= GOP length).
    pub frames_per_segment: usize,
    /// Capture frame rate.
    pub fps: f64,
}

impl SegmentLayout {
    /// Number of segments each camera produces.
    pub fn n_segments(&self) -> usize {
        self.n_frames.div_ceil(self.frames_per_segment)
    }
}

/// Produces the camera's pixels: renders local frame `local` of the
/// evaluation window into `out`, reusing its allocation.
pub trait CaptureStage: Send {
    fn capture(&mut self, local: usize, out: &mut Frame);
}

/// Keep/drop decision for a freshly captured frame.  `segment_head` marks
/// the first frame of a streaming segment, which is always sent (it seeds
/// the GOP and the server's carry-over state).
pub trait FilterStage: Send {
    fn keep(&mut self, frame: &Frame, segment_head: bool) -> bool;

    /// Re-profiling swap: adopt the new plan's RoI regions and the
    /// threshold re-derived for them — called by the runner at an epoch
    /// boundary when this camera's plan actually changed, always between
    /// segments.  Stages without region/threshold state ignore it (the
    /// default).
    fn replan(&mut self, _regions: &[crate::util::geometry::IRect], _threshold: f64) {}
}

/// Encodes one segment's kept frames (borrowed — the worker keeps
/// ownership and recycles the buffers afterwards).  Returns the encoded
/// segment and the encode service time in seconds.
pub trait EncodeStage: Send {
    fn encode(&mut self, kept: &[&Frame]) -> (EncodedSegment, f64);

    /// Swap the codec regions this stage crops — called by the runner at
    /// an epoch boundary when continuous re-profiling published a changed
    /// plan, always *between* segments (never mid-segment).  Stages whose
    /// output does not depend on regions may ignore it (the default).
    fn set_regions(&mut self, _regions: &[crate::util::geometry::IRect]) {}
}

/// One kept frame's pending inference work: the RoI-masked detector input
/// plus the metadata the DES replay needs.
#[derive(Debug, Clone)]
pub struct InferJob {
    /// Local frame index within the evaluation window.
    pub local: usize,
    /// Virtual capture time (s, eval-window origin).
    pub capture_time: f64,
    /// Masked HWC f32 pixels in [0, 1] — the detector input.
    pub pixels: Vec<f32>,
}

/// A camera worker's per-segment output, sent over the merged server
/// queue: everything measured camera-side plus the pending inference jobs.
#[derive(Debug, Clone)]
pub struct CameraSegment {
    pub cam: usize,
    /// Segment index within the camera (capture order).
    pub seg: usize,
    /// Virtual time (s, eval-window origin) when the segment's last frame
    /// was captured.
    pub capture_end: f64,
    /// Encoded size in bytes.
    pub bytes: usize,
    /// Measured (or modelled) encode service time in seconds.
    pub encode_secs: f64,
    /// Frames the filter stage discarded in this segment.
    pub dropped: usize,
    /// Pending inference inputs for the kept frames, in capture order.
    pub jobs: Vec<InferJob>,
}

/// A fully-measured segment, ready for the DES transport replay.
#[derive(Debug, Clone)]
pub struct SegmentRecord {
    pub cam: usize,
    /// Segment index within the camera (capture order).
    pub seg: usize,
    /// Virtual time (s, eval-window origin) when the segment's last frame
    /// was captured.
    pub capture_end: f64,
    pub bytes: usize,
    pub encode_secs: f64,
    /// (local frame index, capture time, inference seconds) per kept frame.
    pub frames: Vec<(usize, f64, f64)>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layout_segment_count() {
        let l = SegmentLayout { n_frames: 40, frames_per_segment: 5, fps: 5.0 };
        assert_eq!(l.n_segments(), 8);
        let l = SegmentLayout { n_frames: 41, frames_per_segment: 5, fps: 5.0 };
        assert_eq!(l.n_segments(), 9);
        let l = SegmentLayout { n_frames: 4, frames_per_segment: 5, fps: 5.0 };
        assert_eq!(l.n_segments(), 1);
    }
}
