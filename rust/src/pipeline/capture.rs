//! Capture stage: the simulator-backed frame source.

use crate::pipeline::stage::CaptureStage;
use crate::sim::render::{Frame, Renderer};

/// Renders one camera's evaluation-window frames into caller-owned
/// buffers via [`Renderer::render_into`] (no per-frame allocation).
pub struct SimCapture<'a> {
    renderer: &'a Renderer<'a>,
    cam: usize,
    /// Absolute frame index of the evaluation window's first frame.
    eval_start: usize,
}

impl<'a> SimCapture<'a> {
    pub fn new(renderer: &'a Renderer<'a>, cam: usize, eval_start: usize) -> Self {
        SimCapture { renderer, cam, eval_start }
    }
}

impl CaptureStage for SimCapture<'_> {
    fn capture(&mut self, local: usize, out: &mut Frame) {
        self.renderer.render_into(self.cam, self.eval_start + local, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::sim::Scenario;

    #[test]
    fn capture_matches_direct_render() {
        let cfg = Config::test_small();
        let sc = Scenario::build(&cfg.scenario);
        let renderer = sc.renderer();
        let eval = sc.eval_range();
        let mut stage = SimCapture::new(&renderer, 1, eval.start);
        let mut buf = Frame::new(1, 1);
        stage.capture(3, &mut buf);
        assert_eq!(buf.data, renderer.render(1, eval.start + 3).data);
        // the buffer is reused across captures
        stage.capture(4, &mut buf);
        assert_eq!(buf.data, renderer.render(1, eval.start + 4).data);
    }
}
