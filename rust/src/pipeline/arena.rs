//! Buffer arena for the streaming pipeline's steady state.
//!
//! The per-frame hot loop needs two kinds of heap buffers: the RGB
//! [`Frame`]s a camera worker renders into, and the f32 detector-input
//! vectors ([`crate::sim::render::Frame::masked_f32_into`]) that travel
//! with [`crate::pipeline::InferJob`]s to the server stage.  Frames never
//! leave their camera worker, so each worker recycles them through a
//! local [`FramePool`].  Pixel vectors cross threads (camera → server),
//! so they return to a shared mutex-guarded free list once the server
//! has consumed the segment, from which any worker may take them back.
//! Both buffer kinds are fully overwritten before reuse, so recycling
//! cannot change pipeline output.
//!
//! After warm-up the loop allocates nothing: buffers circulate, and the
//! [`ArenaStats`] counters prove it (`pixel_reuses` grows, the alloc
//! counters plateau).  The counters use relaxed atomics — they are
//! diagnostics whose exact values depend on thread interleaving, which
//! is why they are surfaced in `MethodReport` but excluded from its
//! byte-compared JSON (DESIGN.md §9).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::sim::render::Frame;

/// Shared buffer recycler (one per pipeline run).
#[derive(Debug, Default)]
pub struct Arena {
    pixels: Mutex<Vec<Vec<f32>>>,
    /// Server-side objectness-grid buffers ([`crate::pipeline::infer::Infer::infer_batch_into`]
    /// outputs) — taken per merged batch, returned after decode.
    grids: Mutex<Vec<Vec<f32>>>,
    /// Consolidation canvas buffers ([`crate::pipeline::canvas`]) —
    /// taken per merged batch on the canvas route, returned after
    /// inference.  Zero-filled by the taker before gathering.
    canvases: Mutex<Vec<Vec<f32>>>,
    frame_allocs: AtomicUsize,
    pixel_allocs: AtomicUsize,
    pixel_reuses: AtomicUsize,
    grid_allocs: AtomicUsize,
    grid_reuses: AtomicUsize,
    canvas_allocs: AtomicUsize,
    canvas_reuses: AtomicUsize,
}

/// Snapshot of the arena's allocation counters.
#[derive(Debug, Clone, Copy, Default)]
pub struct ArenaStats {
    /// Fresh `Frame` buffers created by camera workers.
    pub frame_allocs: usize,
    /// Fresh detector-input vectors created (free list was empty).
    pub pixel_allocs: usize,
    /// Detector-input vectors recycled from the free list.
    pub pixel_reuses: usize,
    /// Fresh inference-grid vectors created on the server side.
    pub grid_allocs: usize,
    /// Inference-grid vectors recycled from the free list.
    pub grid_reuses: usize,
    /// Fresh consolidation-canvas buffers created on the server side.
    pub canvas_allocs: usize,
    /// Consolidation-canvas buffers recycled from the free list.
    pub canvas_reuses: usize,
}

impl Arena {
    pub fn new() -> Arena {
        Arena::default()
    }

    /// Take a pixel buffer from the free list (or a fresh empty one).
    /// The caller overwrites it completely (`masked_f32_into`).
    pub fn take_pixels(&self) -> Vec<f32> {
        let recycled = self.pixels.lock().expect("arena lock poisoned").pop();
        match recycled {
            Some(buf) => {
                self.pixel_reuses.fetch_add(1, Ordering::Relaxed);
                buf
            }
            None => {
                self.pixel_allocs.fetch_add(1, Ordering::Relaxed);
                Vec::new()
            }
        }
    }

    /// Return a consumed pixel buffer to the free list.
    pub fn put_pixels(&self, buf: Vec<f32>) {
        self.pixels.lock().expect("arena lock poisoned").push(buf);
    }

    /// Take an inference-grid buffer from the free list (or a fresh empty
    /// one).  The caller overwrites it completely (`infer_batch_into`).
    pub fn take_grid(&self) -> Vec<f32> {
        let recycled = self.grids.lock().expect("arena lock poisoned").pop();
        match recycled {
            Some(buf) => {
                self.grid_reuses.fetch_add(1, Ordering::Relaxed);
                buf
            }
            None => {
                self.grid_allocs.fetch_add(1, Ordering::Relaxed);
                Vec::new()
            }
        }
    }

    /// Return a decoded grid buffer to the free list.
    pub fn put_grid(&self, buf: Vec<f32>) {
        self.grids.lock().expect("arena lock poisoned").push(buf);
    }

    /// Take a consolidation-canvas buffer from the free list (or a fresh
    /// empty one).  The caller zero-fills it before gathering.
    pub fn take_canvas(&self) -> Vec<f32> {
        let recycled = self.canvases.lock().expect("arena lock poisoned").pop();
        match recycled {
            Some(buf) => {
                self.canvas_reuses.fetch_add(1, Ordering::Relaxed);
                buf
            }
            None => {
                self.canvas_allocs.fetch_add(1, Ordering::Relaxed);
                Vec::new()
            }
        }
    }

    /// Return an inferred canvas buffer to the free list.
    pub fn put_canvas(&self, buf: Vec<f32>) {
        self.canvases.lock().expect("arena lock poisoned").push(buf);
    }

    /// A worker-local frame recycler that counts its fresh allocations
    /// against this arena.
    pub fn frame_pool(&self) -> FramePool<'_> {
        FramePool { arena: self, pool: Vec::new() }
    }

    pub fn stats(&self) -> ArenaStats {
        ArenaStats {
            frame_allocs: self.frame_allocs.load(Ordering::Relaxed),
            pixel_allocs: self.pixel_allocs.load(Ordering::Relaxed),
            pixel_reuses: self.pixel_reuses.load(Ordering::Relaxed),
            grid_allocs: self.grid_allocs.load(Ordering::Relaxed),
            grid_reuses: self.grid_reuses.load(Ordering::Relaxed),
            canvas_allocs: self.canvas_allocs.load(Ordering::Relaxed),
            canvas_reuses: self.canvas_reuses.load(Ordering::Relaxed),
        }
    }
}

/// Per-worker `Frame` free list (frames never cross threads, so no lock).
pub struct FramePool<'a> {
    arena: &'a Arena,
    pool: Vec<Frame>,
}

impl<'a> FramePool<'a> {
    /// Take a recycled frame, or a minimal fresh one (`render_into` and
    /// `copy_from` resize it to the camera's true dimensions).
    pub fn take(&mut self) -> Frame {
        self.pool.pop().unwrap_or_else(|| {
            self.arena.frame_allocs.fetch_add(1, Ordering::Relaxed);
            Frame::new(1, 1)
        })
    }

    /// Return a frame for reuse.
    pub fn put(&mut self, frame: Frame) {
        self.pool.push(frame);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pixel_buffers_recycle() {
        let arena = Arena::new();
        let a = arena.take_pixels();
        assert_eq!(arena.stats().pixel_allocs, 1);
        arena.put_pixels(a);
        let b = arena.take_pixels();
        drop(b);
        let s = arena.stats();
        assert_eq!(s.pixel_allocs, 1);
        assert_eq!(s.pixel_reuses, 1);
    }

    #[test]
    fn grid_buffers_recycle() {
        let arena = Arena::new();
        let a = arena.take_grid();
        let b = arena.take_grid();
        assert_eq!(arena.stats().grid_allocs, 2);
        arena.put_grid(a);
        arena.put_grid(b);
        let _c = arena.take_grid();
        let s = arena.stats();
        assert_eq!(s.grid_allocs, 2);
        assert_eq!(s.grid_reuses, 1);
        // grid and pixel free lists are independent
        assert_eq!(s.pixel_allocs, 0);
    }

    #[test]
    fn canvas_buffers_recycle_independently() {
        let arena = Arena::new();
        let a = arena.take_canvas();
        assert_eq!(arena.stats().canvas_allocs, 1);
        arena.put_canvas(a);
        let _b = arena.take_canvas();
        let s = arena.stats();
        assert_eq!(s.canvas_allocs, 1);
        assert_eq!(s.canvas_reuses, 1);
        assert_eq!(s.grid_allocs, 0);
        assert_eq!(s.pixel_allocs, 0);
    }

    #[test]
    fn frame_pool_counts_fresh_allocations_only() {
        let arena = Arena::new();
        let mut pool = arena.frame_pool();
        let f1 = pool.take();
        let f2 = pool.take();
        assert_eq!(arena.stats().frame_allocs, 2);
        pool.put(f1);
        pool.put(f2);
        let _f3 = pool.take();
        assert_eq!(arena.stats().frame_allocs, 2, "recycled take must not count");
    }
}
