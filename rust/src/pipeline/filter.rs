//! Filter stage: per-camera frame-filtering state (Reducto §5.4).
//!
//! The keep/drop state that used to live inline in the coordinator's
//! camera loop: the previous *rendered* frame is the diff reference (the
//! threshold was profiled against exactly that sequence offline).

use crate::pipeline::stage::FilterStage;
use crate::reducto;
use crate::sim::render::Frame;
use crate::util::geometry::IRect;

/// Keeps every frame — methods without frame filtering.
pub struct PassThroughFilter;

impl FilterStage for PassThroughFilter {
    fn keep(&mut self, _frame: &Frame, _segment_head: bool) -> bool {
        true
    }
}

/// Reducto keep/drop state for one camera, with the threshold learned
/// offline ([`crate::reducto::ReductoFilter`]).  A negative threshold
/// (the disabled filter) keeps even pixel-identical frames.
///
/// Owns its region list so a re-plan can swap both the regions the diff
/// feature is restricted to and the threshold re-derived for them
/// ([`FilterStage::replan`]) without borrowing from the plan epoch.
pub struct ReductoFilterStage {
    /// RoI regions the diff feature is restricted to (Fig. 12).
    regions: Vec<IRect>,
    threshold: f64,
    /// Previous rendered frame (diff reference), reused across frames.
    prev: Option<Frame>,
}

impl ReductoFilterStage {
    pub fn new(regions: &[IRect], threshold: f64) -> Self {
        ReductoFilterStage { regions: regions.to_vec(), threshold, prev: None }
    }
}

impl FilterStage for ReductoFilterStage {
    fn keep(&mut self, frame: &Frame, segment_head: bool) -> bool {
        let keep = match &self.prev {
            // the very first frame has no reference and is always sent
            None => true,
            Some(prev) => {
                segment_head
                    || reducto::frame_diff(prev, frame, &self.regions) > self.threshold
            }
        };
        // update the diff reference in place, reusing its allocation
        match &mut self.prev {
            Some(p) => p.copy_from(frame),
            None => self.prev = Some(frame.clone()),
        }
        keep
    }

    /// Adopt a re-plan's regions and re-derived threshold.  The diff
    /// reference (the previous *rendered* frame) survives the swap — it
    /// is a property of the camera's pixel stream, not of the plan.
    fn replan(&mut self, regions: &[IRect], threshold: f64) {
        self.regions.clear();
        self.regions.extend_from_slice(regions);
        self.threshold = threshold;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn flat(level: u8) -> Frame {
        let mut f = Frame::new(32, 32);
        for y in 0..32 {
            for x in 0..32 {
                f.set(x, y, [level, level, level]);
            }
        }
        f
    }

    #[test]
    fn pass_through_keeps_everything() {
        let mut f = PassThroughFilter;
        assert!(f.keep(&flat(0), true));
        assert!(f.keep(&flat(0), false));
    }

    #[test]
    fn first_frame_and_segment_heads_always_kept() {
        let regions = [IRect::new(0, 0, 32, 32)];
        let mut f = ReductoFilterStage::new(&regions, 0.5);
        let frame = flat(100);
        assert!(f.keep(&frame, false), "first frame must be kept");
        assert!(!f.keep(&frame, false), "identical frame below threshold");
        assert!(f.keep(&frame, true), "segment head must be kept");
    }

    #[test]
    fn large_diff_is_kept() {
        let regions = [IRect::new(0, 0, 32, 32)];
        let mut f = ReductoFilterStage::new(&regions, 0.5);
        assert!(f.keep(&flat(0), true));
        assert!(f.keep(&flat(100), false), "every pixel changed: must be kept");
    }

    #[test]
    fn diff_reference_is_previous_rendered_frame_not_last_kept() {
        let regions = [IRect::new(0, 0, 32, 32)];
        let mut f = ReductoFilterStage::new(&regions, 0.5);
        assert!(f.keep(&flat(0), true));
        // +8 luma: below the per-pixel delta, dropped — but it still
        // becomes the diff reference
        assert!(!f.keep(&flat(8), false));
        // +16 vs the last *kept* frame would trip the per-pixel delta;
        // vs the previous *rendered* frame it is another +8 -> dropped
        assert!(!f.keep(&flat(16), false));
    }

    #[test]
    fn replan_swaps_regions_and_threshold_but_keeps_the_diff_reference() {
        let regions = [IRect::new(0, 0, 32, 32)];
        // threshold 10: nothing but heads would ever be kept
        let mut f = ReductoFilterStage::new(&regions, 10.0);
        assert!(f.keep(&flat(0), true));
        assert!(!f.keep(&flat(100), false), "all-pixel diff still under a huge threshold");
        // a re-plan lowers the threshold; the diff reference (last
        // rendered frame, luma 100) must survive the swap
        f.replan(&[IRect::new(0, 0, 32, 32)], 0.5);
        assert!(f.keep(&flat(0), false), "diff vs the surviving reference trips the new threshold");
    }

    #[test]
    fn negative_threshold_keeps_identical_frames() {
        let regions = [IRect::new(0, 0, 32, 32)];
        let mut f = ReductoFilterStage::new(&regions, -1.0);
        let frame = flat(7);
        assert!(f.keep(&frame, true));
        assert!(f.keep(&frame, false), "disabled filter keeps zero-diff frames");
    }
}
