//! Encode stage: drives the block codec over one camera's segments and
//! measures (or models) the encode service time the DES replays.

use std::time::Instant;

use crate::codec::{EncodedSegment, SegmentEncoder};
use crate::pipeline::stage::EncodeStage;
use crate::sim::render::Frame;
use crate::util::geometry::IRect;

/// How camera-side encode service times are obtained.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum EncodeCost {
    /// Wall-clock measurement on this host (the default; feeds the DES
    /// replay — DESIGN.md §3 on the testbed substitution).
    Measured,
    /// Deterministic model: fixed seconds per encoded frame.  Used by the
    /// determinism tests, where reports must be byte-identical across
    /// runs and thread counts.
    PerFrame(f64),
}

/// [`SegmentEncoder`]-backed encode stage for one camera.
pub struct CodecEncodeStage {
    enc: SegmentEncoder,
    qp: f64,
    cost: EncodeCost,
}

impl CodecEncodeStage {
    pub fn new(regions: &[IRect], qp: f64, cost: EncodeCost) -> Self {
        CodecEncodeStage { enc: SegmentEncoder::new(regions, qp), qp, cost }
    }
}

impl EncodeStage for CodecEncodeStage {
    fn encode(&mut self, kept: &[&Frame]) -> (EncodedSegment, f64) {
        // lint: wall-clock — measured cost feeds latency fields zeroed by
        // zero_wall_clock; determinism tests inject EncodeCost::PerFrame
        let t0 = Instant::now();
        let encoded = self.enc.encode_segment_refs(kept);
        let secs = match self.cost {
            EncodeCost::Measured => t0.elapsed().as_secs_f64(),
            EncodeCost::PerFrame(per_frame) => per_frame * kept.len() as f64,
        };
        (encoded, secs)
    }

    /// Re-profiling mask swap: rebuild the per-region encoder streams for
    /// the new plan.  Dropping the old encoder also drops its motion
    /// reference state, which is exactly right — the first segment under
    /// a new plan starts a fresh GOP, the same way segment heads do.
    fn set_regions(&mut self, regions: &[IRect]) {
        self.enc = SegmentEncoder::new(regions, self.qp);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::sim::Scenario;

    #[test]
    fn per_frame_cost_is_deterministic() {
        let cfg = Config::test_small();
        let sc = Scenario::build(&cfg.scenario);
        let renderer = sc.renderer();
        let frames: Vec<Frame> = (0..3).map(|i| renderer.render(0, i)).collect();
        let refs: Vec<&Frame> = frames.iter().collect();
        let regions = [IRect::new(0, 0, 320, 192)];
        let mut stage = CodecEncodeStage::new(&regions, 6.0, EncodeCost::PerFrame(0.01));
        let (seg, secs) = stage.encode(&refs);
        assert_eq!(seg.n_frames, 3);
        assert!((secs - 0.03).abs() < 1e-12);
    }

    #[test]
    fn measured_cost_is_positive() {
        let cfg = Config::test_small();
        let sc = Scenario::build(&cfg.scenario);
        let renderer = sc.renderer();
        let frame = renderer.render(0, 0);
        let regions = [IRect::new(0, 0, 320, 192)];
        let mut stage = CodecEncodeStage::new(&regions, 6.0, EncodeCost::Measured);
        let (seg, secs) = stage.encode(&[&frame]);
        assert!(seg.bytes > 0);
        assert!(secs > 0.0);
    }
}
