//! The evaluation query: *unique vehicle detection* (§5.1.2) — detect
//! every unique vehicle across all cameras at every timestamp; any one
//! bbox of a vehicle fulfills the query for that vehicle.
//!
//! Matching system detections to identities uses the simulator's ground
//! truth (the paper does the same against its fused GT reference);
//! accuracy is `1 − mean |C − R| / C` with the Baseline method's detection
//! results as the correct reference `C` (§5.2.1), so Baseline is 100 % by
//! construction.

use std::collections::HashSet;

use crate::runtime::postproc::Detection;
use crate::sim::scene::GtDetection;

/// Minimum IoU for a system detection to claim a ground-truth vehicle.
pub const MATCH_IOU: f64 = 0.1;

/// Map one camera frame's detections to the ground-truth vehicle ids they
/// cover.  A GT vehicle counts as detected when some detection overlaps it
/// (IoU ≥ [`MATCH_IOU`]) or contains its center — detections are
/// cell-resolution boxes, so containment matters for small vehicles.
pub fn match_detections(dets: &[Detection], gt: &[GtDetection]) -> HashSet<u32> {
    let mut out = HashSet::new();
    for g in gt {
        let (cx, cy) = g.bbox.center();
        for d in dets {
            if d.bbox.iou(&g.bbox) >= MATCH_IOU || d.bbox.contains_point(cx, cy) {
                out.insert(g.vehicle_id);
                break;
            }
        }
    }
    out
}

/// Per-frame query outcome across all cameras.
#[derive(Debug, Clone, Default)]
pub struct FrameResult {
    /// Unique vehicles the system reported this frame.
    pub reported: HashSet<u32>,
}

/// Accuracy of a method's per-frame reports against a reference.
///
/// Returns `(accuracy, missed_per_frame)`; the histogram feeds Fig. 8b.
pub fn accuracy(
    reference: &[HashSet<u32>],
    reported: &[HashSet<u32>],
) -> (f64, Vec<usize>) {
    assert_eq!(reference.len(), reported.len());
    let mut err_sum = 0.0;
    let mut n = 0usize;
    let mut missed = Vec::with_capacity(reference.len());
    // lint: order-insensitive — frame-indexed slices; per-frame math uses
    // only counts (difference().count(), len()), never element order
    for (c, r) in reference.iter().zip(reported) {
        let miss = c.difference(r).count();
        missed.push(miss);
        if c.is_empty() {
            continue;
        }
        // |C - R| / C on the *counts*, per §5.1.2
        let err = (c.len() as f64 - r.len() as f64).abs() / c.len() as f64;
        err_sum += err;
        n += 1;
    }
    let acc = if n == 0 { 1.0 } else { 1.0 - err_sum / n as f64 };
    (acc, missed)
}

/// Total vehicle appearances in the reference (the paper quotes "8 missed
/// of 15424 appearances").
pub fn total_appearances(reference: &[HashSet<u32>]) -> usize {
    reference.iter().map(|s| s.len()).sum() // lint: order-insensitive — commutative sum
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::geometry::Rect;

    fn gt(id: u32, x: f64, y: f64) -> GtDetection {
        GtDetection {
            vehicle_id: id,
            bbox: Rect::new(x, y, 30.0, 20.0),
            depth: 10.0,
            occluded: false,
        }
    }

    fn det(x: f64, y: f64, w: f64, h: f64) -> Detection {
        Detection { bbox: Rect::new(x, y, w, h), score: 1.0 }
    }

    #[test]
    fn matching_by_iou_and_center() {
        let gts = [gt(1, 100.0, 100.0), gt(2, 200.0, 50.0)];
        // box overlapping vehicle 1 well
        let dets = [det(96.0, 96.0, 32.0, 32.0)];
        let m = match_detections(&dets, &gts);
        assert!(m.contains(&1));
        assert!(!m.contains(&2));
        // large box containing vehicle 2's center but low IoU
        let dets2 = [det(160.0, 0.0, 120.0, 120.0)];
        let m2 = match_detections(&dets2, &gts);
        assert!(m2.contains(&2));
    }

    #[test]
    fn accuracy_perfect_when_equal() {
        let reference: Vec<HashSet<u32>> =
            vec![[1u32, 2].into_iter().collect(), [3u32].into_iter().collect()];
        let (acc, missed) = accuracy(&reference, &reference.clone());
        assert_eq!(acc, 1.0);
        assert_eq!(missed, vec![0, 0]);
    }

    #[test]
    fn accuracy_counts_percentile_error() {
        let reference: Vec<HashSet<u32>> = vec![[1u32, 2, 3, 4].into_iter().collect()];
        let reported: Vec<HashSet<u32>> = vec![[1u32, 2, 3].into_iter().collect()];
        let (acc, missed) = accuracy(&reference, &reported);
        assert!((acc - 0.75).abs() < 1e-12);
        assert_eq!(missed, vec![1]);
    }

    #[test]
    fn empty_reference_frames_are_skipped() {
        let reference: Vec<HashSet<u32>> = vec![HashSet::new(), [1u32].into_iter().collect()];
        let reported: Vec<HashSet<u32>> = vec![HashSet::new(), [1u32].into_iter().collect()];
        let (acc, _) = accuracy(&reference, &reported);
        assert_eq!(acc, 1.0);
    }

    #[test]
    fn appearances_total() {
        let reference: Vec<HashSet<u32>> =
            vec![[1u32, 2].into_iter().collect(), [1u32].into_iter().collect()];
        assert_eq!(total_appearances(&reference), 3);
    }
}
