//! World generation: intersection geometry, Poisson arrivals, routes.
//!
//! Two perpendicular roads (NS along y, EW along x) cross at the origin;
//! vehicles spawn on the four approach arms with exponential headways and
//! drive straight, turn right or turn left through the crossing — the kind
//! of scene the paper's Fig. 1 cameras watch.

use crate::config::ScenarioConfig;
use crate::sim::path::Path;
use crate::sim::vehicle::{Vehicle, VehicleClass, VehicleState, PALETTE};
use crate::util::geometry::Vec2;
use crate::util::rng::Rng;

/// Half-width of each road (two 3.5 m lanes per direction).
pub const ROAD_HALF_WIDTH: f64 = 7.0;
/// Lane-center offset from the road axis.
pub const LANE_OFFSET: f64 = 1.75;
/// Approach arm length in meters.
pub const ARM_LENGTH: f64 = 80.0;
/// Minimum same-lane spawn headway in seconds.
const MIN_HEADWAY: f64 = 2.8;

/// Route action at the intersection.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Turn {
    Straight,
    Right,
    Left,
}

/// The generated world: every vehicle that will ever exist.
#[derive(Debug, Clone)]
pub struct World {
    pub vehicles: Vec<Vehicle>,
    pub duration: f64,
    /// Vehicle-id range of each intersection's traffic (ids are assigned
    /// intersection-major before the spawn-time sort, so each range is
    /// contiguous).  One range for the legacy single-intersection world.
    pub intersection_ids: Vec<std::ops::Range<u32>>,
}

/// Right-pointing unit vector relative to heading `d` (y-up world).
fn right_of(d: Vec2) -> Vec2 {
    Vec2::new(d.y, -d.x)
}

/// Build the route polyline for an approach direction and a turn choice.
///
/// `d` is the inbound unit heading (pointing *toward* the intersection).
pub fn make_route(d: Vec2, turn: Turn) -> Path {
    let r = right_of(d);
    let start = d.scale(-ARM_LENGTH).add(r.scale(LANE_OFFSET));
    let entry = d.scale(-ROAD_HALF_WIDTH).add(r.scale(LANE_OFFSET));
    match turn {
        Turn::Straight => {
            let end = d.scale(ARM_LENGTH).add(r.scale(LANE_OFFSET));
            Path::new(vec![start, end])
        }
        Turn::Right => {
            let e = r; // exit heading
            let re = right_of(e);
            let exit = e.scale(ROAD_HALF_WIDTH).add(re.scale(LANE_OFFSET));
            let end = e.scale(ARM_LENGTH).add(re.scale(LANE_OFFSET));
            let center = d.scale(-ROAD_HALF_WIDTH).add(r.scale(ROAD_HALF_WIDTH));
            let mut pts = vec![start];
            pts.extend(Path::arc(center, entry, exit, 8));
            pts.push(end);
            Path::new(pts)
        }
        Turn::Left => {
            let e = r.scale(-1.0); // exit heading
            let re = right_of(e);
            let exit = e.scale(ROAD_HALF_WIDTH).add(re.scale(LANE_OFFSET));
            let end = e.scale(ARM_LENGTH).add(re.scale(LANE_OFFSET));
            let center = d.scale(-ROAD_HALF_WIDTH).sub(r.scale(ROAD_HALF_WIDTH));
            let mut pts = vec![start];
            pts.extend(Path::arc(center, entry, exit, 10));
            pts.push(end);
            Path::new(pts)
        }
    }
}

/// Per-arm arrival-rate weight under traffic drift (`cfg.drift_at_secs`
/// / `cfg.drift_strength`): before the drift time the EW arms (indices
/// 2, 3) are favoured at `1 + s` and the NS arms (0, 1) starved at
/// `1 − s`; after it, the roles swap — the object flow shifts between
/// the camera overlaps mid-run, which is what continuous re-profiling
/// (DESIGN.md §7) has to chase.  With drift disabled the weight is
/// exactly 1, so the generated world is bit-identical to pre-drift
/// builds.
fn arm_weight(cfg: &ScenarioConfig, drifts: bool, arm_idx: usize, t: f64) -> f64 {
    if !drifts {
        return 1.0;
    }
    let ns_arm = arm_idx < 2;
    let ns_favoured = t >= cfg.drift_at_secs;
    if ns_arm == ns_favoured {
        1.0 + cfg.drift_strength
    } else {
        1.0 - cfg.drift_strength
    }
}

/// Rush-hour wave amplitudes: the first half of each
/// `rush_period_secs` period runs hot, the second half cold.
const RUSH_HOT: f64 = 1.75;
const RUSH_COLD: f64 = 0.25;

/// Arrival-rate gate for the fault/churn scenarios (rush-hour waves and
/// the membership-change corridor): exactly 1 when both knobs are off,
/// so stationary worlds stay bit-identical.
fn rate_gate(cfg: &ScenarioConfig, arm_idx: usize, t: f64) -> f64 {
    let mut gate = 1.0;
    // corridor gate: the EW arms (indices 2, 3) are silent until the
    // corridor activates
    if cfg.corridor_at_secs > 0.0 && arm_idx >= 2 && t < cfg.corridor_at_secs {
        return 0.0;
    }
    if cfg.rush_period_secs > 0.0 {
        let phase = t.rem_euclid(cfg.rush_period_secs);
        gate *= if phase < cfg.rush_period_secs / 2.0 { RUSH_HOT } else { RUSH_COLD };
    }
    gate
}

/// The next time strictly after `t` at which any arm's arrival rate can
/// change (drift flip, corridor activation, rush half-period boundary);
/// `+∞` when the rate is constant from `t` on.  The generation loop
/// restarts any headway gap that would cross such a boundary — see the
/// piecewise-Poisson comment in [`World::generate`].
fn next_rate_boundary(cfg: &ScenarioConfig, drifts: bool, t: f64) -> f64 {
    let mut b = f64::INFINITY;
    if drifts && t < cfg.drift_at_secs {
        b = b.min(cfg.drift_at_secs);
    }
    if cfg.corridor_at_secs > 0.0 && t < cfg.corridor_at_secs {
        b = b.min(cfg.corridor_at_secs);
    }
    if cfg.rush_period_secs > 0.0 {
        let half = cfg.rush_period_secs / 2.0;
        b = b.min(((t / half).floor() + 1.0) * half);
    }
    b
}

impl World {
    /// Generate all vehicles for `cfg.total_secs()` seconds (plus a lead-in
    /// so the scene is already populated at t = 0).
    ///
    /// With `cfg.n_intersections > 1` each intersection runs its own
    /// independent traffic world — seed `cfg.seed + k`, routes shifted
    /// `k * intersection_spacing` m east, ids in disjoint contiguous
    /// ranges ([`World::intersection_ids`]) — and the drift knobs perturb
    /// only the intersection `cfg.drift_intersection` selects (`-1` =
    /// all).  Intersection 0 of a fleet is bit-identical to the
    /// single-intersection world of the same seed.
    pub fn generate(cfg: &ScenarioConfig) -> World {
        let duration = cfg.total_secs();
        let arms = [
            Vec2::new(0.0, -1.0), // from north, heading south
            Vec2::new(0.0, 1.0),  // from south, heading north
            Vec2::new(-1.0, 0.0), // from east, heading west
            Vec2::new(1.0, 0.0),  // from west, heading east
        ];
        let lead_in = ARM_LENGTH / cfg.speed_min; // populate the scene at t=0
        let mut vehicles = Vec::new();
        let mut intersection_ids = Vec::with_capacity(cfg.n_intersections);
        let mut id = 0u32;
        for k in 0..cfg.n_intersections {
            let first_id = id;
            let rng = Rng::new(cfg.seed + k as u64).fork(0x77_6F72_6C64); // "world"
            let offset = Vec2::new(k as f64 * cfg.intersection_spacing, 0.0);
            let drifts = cfg.drift_at_secs > 0.0
                && (cfg.drift_intersection < 0 || cfg.drift_intersection == k as i64);
            for (arm_idx, &d) in arms.iter().enumerate() {
                let mut arm_rng = rng.fork(arm_idx as u64 + 1);
                let mut t = -lead_in;
                loop {
                    // piecewise-Poisson arrivals: headways are drawn at the
                    // rate in force when the gap opens; a gap that would
                    // cross a rate boundary (drift flip, corridor
                    // activation, rush half-period) is restarted there at
                    // the new rate — statistically exact (exponentials are
                    // memoryless) and it keeps a fully-starved arm from
                    // sleeping through its own revival on one infinite gap
                    let rate = cfg.arrival_rate
                        * arm_weight(cfg, drifts, arm_idx, t)
                        * rate_gate(cfg, arm_idx, t);
                    let boundary = next_rate_boundary(cfg, drifts, t);
                    if rate <= 0.0 {
                        // silent arm: no hazard to draw; jump straight to
                        // the next rate change (if any) or stop
                        if boundary > duration {
                            break;
                        }
                        t = boundary;
                        continue;
                    }
                    let gap = arm_rng.exponential(rate).max(MIN_HEADWAY);
                    if t + gap >= boundary {
                        t = boundary;
                        continue;
                    }
                    t += gap;
                    if t > duration {
                        break;
                    }
                    let turn = match arm_rng.f64() {
                        x if x < 0.6 => Turn::Straight,
                        x if x < 0.8 => Turn::Right,
                        _ => Turn::Left,
                    };
                    let class = if arm_rng.chance(cfg.truck_fraction) {
                        VehicleClass::Truck
                    } else {
                        VehicleClass::Car
                    };
                    vehicles.push(Vehicle {
                        id,
                        spawn_time: t,
                        path: make_route(d, turn).translated(offset),
                        speed: arm_rng.range(cfg.speed_min, cfg.speed_max),
                        class,
                        color: arm_rng.below(PALETTE.len()),
                    });
                    id += 1;
                }
            }
            intersection_ids.push(first_id..id);
        }
        vehicles.sort_by(|a, b| a.spawn_time.partial_cmp(&b.spawn_time).unwrap());
        World { vehicles, duration, intersection_ids }
    }

    /// Intersection whose traffic world spawned vehicle `id` (0 for the
    /// legacy single-intersection world).
    pub fn intersection_of(&self, id: u32) -> usize {
        self.intersection_ids
            .iter()
            .position(|r| r.contains(&id))
            .unwrap_or(0)
    }

    /// Poses of every vehicle present at time `t`, ordered by id.
    pub fn states_at(&self, t: f64) -> Vec<VehicleState> {
        let mut out: Vec<VehicleState> =
            self.vehicles.iter().filter_map(|v| v.state_at(t)).collect();
        out.sort_by_key(|s| s.id);
        out
    }

    /// Look a vehicle up by id.
    pub fn vehicle(&self, id: u32) -> Option<&Vehicle> {
        self.vehicles.iter().find(|v| v.id == id)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ScenarioConfig;

    #[test]
    fn routes_start_and_end_on_arms() {
        for d in [
            Vec2::new(0.0, -1.0),
            Vec2::new(0.0, 1.0),
            Vec2::new(-1.0, 0.0),
            Vec2::new(1.0, 0.0),
        ] {
            for turn in [Turn::Straight, Turn::Right, Turn::Left] {
                let p = make_route(d, turn);
                let a = p.point_at(0.0);
                let b = p.point_at(p.length());
                // both endpoints are ARM_LENGTH-ish from the origin
                assert!(a.norm() > ARM_LENGTH * 0.9, "{d:?} {turn:?} start {a:?}");
                assert!(b.norm() > ARM_LENGTH * 0.9, "{d:?} {turn:?} end {b:?}");
                // the route passes near the intersection
                let mid = p.point_at(p.length() / 2.0);
                assert!(mid.norm() < 2.0 * ROAD_HALF_WIDTH, "{d:?} {turn:?} mid {mid:?}");
            }
        }
    }

    #[test]
    fn routes_stay_on_roads() {
        // every point of every route is on the NS or EW road surface
        for d in [Vec2::new(0.0, -1.0), Vec2::new(1.0, 0.0)] {
            for turn in [Turn::Straight, Turn::Right, Turn::Left] {
                let p = make_route(d, turn);
                let n = 200;
                for i in 0..=n {
                    let pt = p.point_at(p.length() * i as f64 / n as f64);
                    let on_ns = pt.x.abs() <= ROAD_HALF_WIDTH + 2.0;
                    let on_ew = pt.y.abs() <= ROAD_HALF_WIDTH + 2.0;
                    assert!(on_ns || on_ew, "{turn:?} point off road: {pt:?}");
                }
            }
        }
    }

    #[test]
    fn generation_is_deterministic_and_populated() {
        let cfg = ScenarioConfig::default();
        let w1 = World::generate(&cfg);
        let w2 = World::generate(&cfg);
        assert_eq!(w1.vehicles.len(), w2.vehicles.len());
        assert!(w1.vehicles.len() > 40, "only {} vehicles", w1.vehicles.len());
        for (a, b) in w1.vehicles.iter().zip(&w2.vehicles) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.spawn_time, b.spawn_time);
            assert_eq!(a.color, b.color);
        }
    }

    #[test]
    fn scene_is_populated_at_t0() {
        let cfg = ScenarioConfig::default();
        let w = World::generate(&cfg);
        // thanks to the lead-in, some vehicles are already mid-route
        assert!(!w.states_at(0.0).is_empty());
        assert!(!w.states_at(30.0).is_empty());
    }

    #[test]
    fn ids_are_unique() {
        let w = World::generate(&ScenarioConfig::default());
        let mut ids: Vec<u32> = w.vehicles.iter().map(|v| v.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), w.vehicles.len());
    }

    #[test]
    fn drift_shifts_flow_between_roads() {
        let mut cfg = ScenarioConfig::default();
        cfg.drift_at_secs = cfg.total_secs() / 2.0;
        cfg.drift_strength = 0.9;
        let w = World::generate(&cfg);
        // classify spawns by road (heading x≈0 → NS road) and by phase
        let mut counts = [[0usize; 2]; 2]; // [phase][is_ns]
        for v in &w.vehicles {
            if v.spawn_time < 0.0 {
                continue; // lead-in
            }
            let start = v.path.point_at(0.0);
            let is_ns = start.x.abs() < 2.0 * ROAD_HALF_WIDTH;
            let phase = usize::from(v.spawn_time >= cfg.drift_at_secs);
            counts[phase][usize::from(is_ns)] += 1;
        }
        // pre-drift the EW road dominates, post-drift the NS road
        assert!(
            counts[0][0] > 2 * counts[0][1].max(1),
            "pre-drift EW {} vs NS {}",
            counts[0][0],
            counts[0][1]
        );
        assert!(
            counts[1][1] > 2 * counts[1][0].max(1),
            "post-drift NS {} vs EW {}",
            counts[1][1],
            counts[1][0]
        );
    }

    #[test]
    fn fully_starved_arm_revives_after_the_drift_boundary() {
        let mut cfg = ScenarioConfig::default();
        cfg.drift_at_secs = cfg.total_secs() / 2.0;
        cfg.drift_strength = 1.0; // NS arms completely silent pre-drift
        let w = World::generate(&cfg);
        let ns_post = w
            .vehicles
            .iter()
            .filter(|v| {
                v.spawn_time >= cfg.drift_at_secs
                    && v.path.point_at(0.0).x.abs() < 2.0 * ROAD_HALF_WIDTH
            })
            .count();
        assert!(ns_post > 0, "starved NS arms never revived after the drift boundary");
    }

    #[test]
    fn disabled_drift_reproduces_the_stationary_world() {
        let cfg = ScenarioConfig::default();
        assert_eq!(cfg.drift_at_secs, 0.0);
        let mut drifting = cfg.clone();
        drifting.drift_at_secs = 0.0;
        drifting.drift_strength = 1.0; // ignored while drift is off
        let a = World::generate(&cfg);
        let b = World::generate(&drifting);
        assert_eq!(a.vehicles.len(), b.vehicles.len());
        for (x, y) in a.vehicles.iter().zip(&b.vehicles) {
            assert_eq!(x.spawn_time, y.spawn_time);
            assert_eq!(x.id, y.id);
        }
    }

    #[test]
    fn disabled_waves_and_corridor_reproduce_the_stationary_world() {
        let cfg = ScenarioConfig::default();
        let mut gated = cfg.clone();
        gated.rush_period_secs = 0.0;
        gated.corridor_at_secs = 0.0;
        let a = World::generate(&cfg);
        let b = World::generate(&gated);
        assert_eq!(a.vehicles.len(), b.vehicles.len());
        for (x, y) in a.vehicles.iter().zip(&b.vehicles) {
            assert_eq!(x.spawn_time, y.spawn_time);
            assert_eq!(x.id, y.id);
        }
    }

    #[test]
    fn rush_waves_modulate_arrivals() {
        let mut cfg = ScenarioConfig::default();
        cfg.rush_period_secs = cfg.total_secs(); // one hot half, one cold half
        let w = World::generate(&cfg);
        let half = cfg.rush_period_secs / 2.0;
        let hot = w.vehicles.iter().filter(|v| (0.0..half).contains(&v.spawn_time)).count();
        let cold = w.vehicles.iter().filter(|v| v.spawn_time >= half).count();
        assert!(
            hot > cold,
            "rush wave had no effect: {hot} hot-half vs {cold} cold-half spawns"
        );
    }

    #[test]
    fn corridor_gate_silences_ew_arms_until_activation() {
        let mut cfg = ScenarioConfig::default();
        cfg.corridor_at_secs = cfg.total_secs() / 2.0;
        let w = World::generate(&cfg);
        let is_ew = |v: &Vehicle| v.path.point_at(0.0).y.abs() < 2.0 * ROAD_HALF_WIDTH;
        let ew_pre = w
            .vehicles
            .iter()
            .filter(|v| is_ew(v) && v.spawn_time < cfg.corridor_at_secs)
            .count();
        let ew_post = w
            .vehicles
            .iter()
            .filter(|v| is_ew(v) && v.spawn_time >= cfg.corridor_at_secs)
            .count();
        assert_eq!(ew_pre, 0, "EW arms spawned before the corridor activated");
        assert!(ew_post > 0, "EW arms never activated");
        // the NS arms draw from independent RNG forks, so gating the EW
        // arms leaves their traffic bit-identical to the ungated world
        let ungated = World::generate(&ScenarioConfig::default());
        let ns = |w: &World| -> Vec<(f64, f64)> {
            w.vehicles
                .iter()
                .filter(|v| !is_ew(v))
                .map(|v| (v.spawn_time, v.speed))
                .collect()
        };
        assert_eq!(ns(&w), ns(&ungated));
    }

    #[test]
    fn different_seed_different_world() {
        let mut cfg = ScenarioConfig::default();
        let w1 = World::generate(&cfg);
        cfg.seed = 9999;
        let w2 = World::generate(&cfg);
        let same = w1
            .vehicles
            .iter()
            .zip(&w2.vehicles)
            .all(|(a, b)| a.spawn_time == b.spawn_time);
        assert!(!same);
    }
}
