//! Vehicles: spawn specs, classes and the saturated color palette the L2
//! detector's matched filter is tuned to (model.py docstring).

use crate::sim::path::Path;
use crate::util::geometry::Vec2;

/// Vehicle body classes (paper scene: cars with occasional trucks).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum VehicleClass {
    Car,
    Truck,
}

impl VehicleClass {
    /// (length, width, height) in meters.
    pub fn dims(self) -> (f64, f64, f64) {
        match self {
            VehicleClass::Car => (4.5, 1.8, 1.5),
            VehicleClass::Truck => (8.0, 2.5, 3.2),
        }
    }
}

/// Saturated palette (RGB in [0,1]).  Gray/white/black are deliberately
/// absent: road, lane markings and shadows must stay below the detector's
/// color-opponency threshold while every vehicle is detectable.
pub const PALETTE: [[f64; 3]; 8] = [
    [0.85, 0.12, 0.10], // red
    [0.10, 0.25, 0.85], // blue
    [0.10, 0.70, 0.20], // green
    [0.90, 0.75, 0.05], // yellow
    [0.90, 0.45, 0.05], // orange
    [0.55, 0.10, 0.70], // purple
    [0.05, 0.65, 0.75], // teal
    [0.80, 0.10, 0.50], // magenta
];

/// One simulated vehicle: a route, a constant cruise speed and a body.
#[derive(Debug, Clone)]
pub struct Vehicle {
    /// Globally unique ground-truth identity.
    pub id: u32,
    /// Simulation time at which the vehicle enters the scene.
    pub spawn_time: f64,
    /// Route through the intersection.
    pub path: Path,
    /// Cruise speed in m/s.
    pub speed: f64,
    pub class: VehicleClass,
    /// Index into [`PALETTE`].
    pub color: usize,
}

/// Pose of a vehicle at a queried time.
#[derive(Debug, Clone, Copy)]
pub struct VehicleState {
    pub id: u32,
    pub pos: Vec2,
    pub heading: Vec2,
    pub class: VehicleClass,
    pub color: usize,
}

impl Vehicle {
    /// Distance traveled at time `t` (None before spawn / after exit).
    pub fn progress(&self, t: f64) -> Option<f64> {
        if t < self.spawn_time {
            return None;
        }
        let s = (t - self.spawn_time) * self.speed;
        if s > self.path.length() {
            None
        } else {
            Some(s)
        }
    }

    /// Pose at time `t`, if the vehicle is in the scene.
    pub fn state_at(&self, t: f64) -> Option<VehicleState> {
        let s = self.progress(t)?;
        Some(VehicleState {
            id: self.id,
            pos: self.path.point_at(s),
            heading: self.path.dir_at(s),
            class: self.class,
            color: self.color,
        })
    }

    /// Time the vehicle leaves the scene.
    pub fn exit_time(&self) -> f64 {
        self.spawn_time + self.path.length() / self.speed
    }

    /// Footprint corners (4 ground points) at a given state.
    pub fn footprint(state: &VehicleState) -> [Vec2; 4] {
        let (l, w, _h) = state.class.dims();
        let f = state.heading.scale(l / 2.0);
        let r = state.heading.perp().scale(w / 2.0);
        [
            state.pos.add(f).add(r),
            state.pos.add(f).sub(r),
            state.pos.sub(f).sub(r),
            state.pos.sub(f).add(r),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mkvehicle() -> Vehicle {
        Vehicle {
            id: 1,
            spawn_time: 10.0,
            path: Path::new(vec![Vec2::new(0.0, 0.0), Vec2::new(100.0, 0.0)]),
            speed: 10.0,
            class: VehicleClass::Car,
            color: 0,
        }
    }

    #[test]
    fn lifecycle() {
        let v = mkvehicle();
        assert!(v.state_at(9.9).is_none());
        assert!(v.state_at(10.0).is_some());
        let s = v.state_at(15.0).unwrap();
        assert!((s.pos.x - 50.0).abs() < 1e-9);
        assert!(v.state_at(20.0).is_some()); // exactly at end
        assert!(v.state_at(20.1).is_none());
        assert!((v.exit_time() - 20.0).abs() < 1e-12);
    }

    #[test]
    fn footprint_dims() {
        let v = mkvehicle();
        let s = v.state_at(12.0).unwrap();
        let fp = Vehicle::footprint(&s);
        let len = fp[0].sub(fp[3]).norm();
        let wid = fp[0].sub(fp[1]).norm();
        assert!((len - 4.5).abs() < 1e-9);
        assert!((wid - 1.8).abs() < 1e-9);
    }

    #[test]
    fn palette_is_saturated() {
        // every palette color must trip the detector's opponency filter:
        // sum of |channel differences| well above the conv3 bias (0.15/1.5)
        for c in PALETTE {
            let sat = (c[0] - c[1]).abs() + (c[1] - c[2]).abs() + (c[2] - c[0]).abs();
            assert!(sat > 0.5, "palette color {c:?} not saturated enough");
        }
    }
}
