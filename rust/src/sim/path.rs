//! Arc-length parameterized polyline paths.
//!
//! Vehicle routes through the intersection (straight / left / right) are
//! piecewise linear with turns discretized into short chords; position and
//! heading are queried by traveled distance.

use crate::util::geometry::Vec2;

/// A polyline with cumulative arc-length index.
#[derive(Debug, Clone)]
pub struct Path {
    points: Vec<Vec2>,
    cumlen: Vec<f64>,
}

impl Path {
    /// Build from waypoints (at least 2, consecutive duplicates dropped).
    pub fn new(points: Vec<Vec2>) -> Self {
        let mut pts: Vec<Vec2> = Vec::with_capacity(points.len());
        for p in points {
            if pts.last().map_or(true, |q: &Vec2| q.sub(p).norm() > 1e-9) {
                pts.push(p);
            }
        }
        assert!(pts.len() >= 2, "path needs at least 2 distinct points");
        let mut cumlen = Vec::with_capacity(pts.len());
        let mut acc = 0.0;
        cumlen.push(0.0);
        for i in 1..pts.len() {
            acc += pts[i].sub(pts[i - 1]).norm();
            cumlen.push(acc);
        }
        Path { points: pts, cumlen }
    }

    /// Total length in meters.
    pub fn length(&self) -> f64 {
        *self.cumlen.last().unwrap()
    }

    /// The same polyline shifted by `d` (lengths unchanged) — fleet
    /// scenarios place each intersection's routes at its own offset.
    pub fn translated(&self, d: Vec2) -> Path {
        Path {
            points: self.points.iter().map(|p| p.add(d)).collect(),
            cumlen: self.cumlen.clone(),
        }
    }

    /// Position at distance `s` (clamped to the ends).
    pub fn point_at(&self, s: f64) -> Vec2 {
        let s = s.clamp(0.0, self.length());
        let i = match self
            .cumlen
            .binary_search_by(|c| c.partial_cmp(&s).unwrap())
        {
            Ok(i) => i,
            Err(i) => i.saturating_sub(1),
        };
        let i = i.min(self.points.len() - 2);
        let seg = self.cumlen[i + 1] - self.cumlen[i];
        let t = if seg <= 0.0 { 0.0 } else { (s - self.cumlen[i]) / seg };
        let a = self.points[i];
        let b = self.points[i + 1];
        a.add(b.sub(a).scale(t))
    }

    /// Unit heading at distance `s`.
    pub fn dir_at(&self, s: f64) -> Vec2 {
        let s = s.clamp(0.0, self.length());
        let i = match self
            .cumlen
            .binary_search_by(|c| c.partial_cmp(&s).unwrap())
        {
            Ok(i) => i,
            Err(i) => i.saturating_sub(1),
        };
        let i = i.min(self.points.len() - 2);
        self.points[i + 1].sub(self.points[i]).normalized()
    }

    /// Discretize a circular arc from `from` to `to` around `center`
    /// (shorter direction), as `n` chords.  Helper for turn geometry.
    pub fn arc(center: Vec2, from: Vec2, to: Vec2, n: usize) -> Vec<Vec2> {
        let r0 = from.sub(center);
        let r1 = to.sub(center);
        let a0 = r0.y.atan2(r0.x);
        let mut a1 = r1.y.atan2(r1.x);
        // take the shorter way around
        while a1 - a0 > std::f64::consts::PI {
            a1 -= 2.0 * std::f64::consts::PI;
        }
        while a0 - a1 > std::f64::consts::PI {
            a1 += 2.0 * std::f64::consts::PI;
        }
        let radius = r0.norm();
        (0..=n)
            .map(|i| {
                let a = a0 + (a1 - a0) * i as f64 / n as f64;
                Vec2::new(center.x + radius * a.cos(), center.y + radius * a.sin())
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn straight_line_param() {
        let p = Path::new(vec![Vec2::new(0.0, 0.0), Vec2::new(10.0, 0.0)]);
        assert_eq!(p.length(), 10.0);
        let mid = p.point_at(5.0);
        assert!((mid.x - 5.0).abs() < 1e-12 && mid.y.abs() < 1e-12);
        let d = p.dir_at(3.0);
        assert!((d.x - 1.0).abs() < 1e-12);
    }

    #[test]
    fn clamps_out_of_range() {
        let p = Path::new(vec![Vec2::new(0.0, 0.0), Vec2::new(10.0, 0.0)]);
        assert_eq!(p.point_at(-5.0).x, 0.0);
        assert_eq!(p.point_at(99.0).x, 10.0);
    }

    #[test]
    fn multi_segment_lengths() {
        let p = Path::new(vec![
            Vec2::new(0.0, 0.0),
            Vec2::new(3.0, 0.0),
            Vec2::new(3.0, 4.0),
        ]);
        assert!((p.length() - 7.0).abs() < 1e-12);
        let pt = p.point_at(5.0);
        assert!((pt.x - 3.0).abs() < 1e-12 && (pt.y - 2.0).abs() < 1e-12);
        let d = p.dir_at(5.0);
        assert!((d.y - 1.0).abs() < 1e-12);
    }

    #[test]
    fn duplicate_points_dropped() {
        let p = Path::new(vec![
            Vec2::new(0.0, 0.0),
            Vec2::new(0.0, 0.0),
            Vec2::new(1.0, 0.0),
        ]);
        assert_eq!(p.length(), 1.0);
    }

    #[test]
    fn arc_quarter_circle() {
        let pts = Path::arc(
            Vec2::new(0.0, 0.0),
            Vec2::new(5.0, 0.0),
            Vec2::new(0.0, 5.0),
            8,
        );
        assert_eq!(pts.len(), 9);
        for p in &pts {
            assert!((p.norm() - 5.0).abs() < 1e-9);
        }
        let path = Path::new(pts);
        // chord-length ≈ quarter circumference
        let expect = 2.5 * std::f64::consts::PI;
        assert!((path.length() - expect).abs() < 0.1);
    }
}
