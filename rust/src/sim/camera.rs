//! Pinhole cameras: placement per the paper's Fig. 1 and 3-D → image
//! projection of vehicle boxes into `<left, top, width, height>` bboxes.

use crate::sim::vehicle::{Vehicle, VehicleState};
use crate::sim::{FRAME_H, FRAME_W};
use crate::util::geometry::{Rect, Vec2};

/// Minimum projected bbox area (px²) to count as visible.
pub const MIN_BBOX_AREA: f64 = 60.0;
/// Maximum detection distance in meters.
pub const MAX_RANGE: f64 = 75.0;
/// Near plane in meters.
const NEAR: f64 = 1.0;

/// A static pinhole camera.
#[derive(Debug, Clone)]
pub struct Camera {
    pub id: usize,
    /// Position in world meters (z up).
    pub pos: [f64; 3],
    /// Yaw (radians, world x-axis = 0, CCW) and downward pitch (radians).
    pub yaw: f64,
    pub pitch: f64,
    /// Horizontal field of view (radians).
    pub hfov: f64,
    pub width: u32,
    pub height: u32,
    // cached axes
    fwd: [f64; 3],
    right: [f64; 3],
    down: [f64; 3],
    fx: f64,
    fy: f64,
}

impl Camera {
    pub fn new(id: usize, pos: [f64; 3], yaw: f64, pitch: f64, hfov: f64) -> Self {
        let (sy, cy) = yaw.sin_cos();
        let (sp, cp) = pitch.sin_cos();
        let fwd = [cp * cy, cp * sy, -sp];
        // right = fwd × up, with up = (0,0,1)
        let right_raw = [fwd[1], -fwd[0], 0.0];
        let rn = (right_raw[0] * right_raw[0] + right_raw[1] * right_raw[1]).sqrt();
        let right = [right_raw[0] / rn, right_raw[1] / rn, 0.0];
        // down = fwd × right
        let down = [
            fwd[1] * right[2] - fwd[2] * right[1],
            fwd[2] * right[0] - fwd[0] * right[2],
            fwd[0] * right[1] - fwd[1] * right[0],
        ];
        let fx = (FRAME_W as f64 / 2.0) / (hfov / 2.0).tan();
        Camera {
            id,
            pos,
            yaw,
            pitch,
            hfov,
            width: FRAME_W,
            height: FRAME_H,
            fwd,
            right,
            down,
            fx,
            fy: fx,
        }
    }

    /// The five-camera rig around the intersection (paper Fig. 1): four
    /// corner cameras looking at the center plus a fifth down-road camera.
    /// For `n != 5` the first `n` of a ring of corner cameras are used.
    pub fn ring(n: usize) -> Vec<Camera> {
        let mut cams = Vec::with_capacity(n);
        // Corner cameras aimed past the intersection center toward one
        // approach arm each (paper Fig. 1): all overlap at the crossing,
        // but each is the sole observer of most of "its" arm — that is
        // what makes true negatives dominate Table 2.
        let corner: [([f64; 3], (f64, f64)); 4] = [
            ([32.0, 32.0, 8.0], (0.0, -18.0)),  // C1: crossing + south arm
            ([-32.0, 32.0, 8.0], (18.0, 0.0)),  // C2: crossing + east arm
            ([-32.0, -32.0, 8.0], (0.0, 18.0)), // C3: crossing + north arm
            ([32.0, -32.0, 8.0], (-18.0, 0.0)), // C4: crossing + west arm
        ];
        for i in 0..n.min(4) {
            let (pos, (tx, ty)) = corner[i];
            let yaw = f64::atan2(ty - pos[1], tx - pos[0]);
            let dist = ((tx - pos[0]).powi(2) + (ty - pos[1]).powi(2)).sqrt();
            let pitch = f64::atan(pos[2] / dist);
            cams.push(Camera::new(i, pos, yaw, pitch, 62f64.to_radians()));
        }
        if n >= 5 {
            // C5: down the EW road from the east, slightly narrower view
            cams.push(Camera::new(
                4,
                [48.0, 6.0, 10.0],
                std::f64::consts::PI, // looking west
                (10.0f64 / 45.0).atan(),
                52f64.to_radians(),
            ));
        }
        for (extra, cam) in (5..n).enumerate() {
            // additional cameras (scale experiments): a wider ring
            let ang = extra as f64 * std::f64::consts::PI / 4.0 + 0.4;
            let pos = [50.0 * ang.cos(), 50.0 * ang.sin(), 9.0];
            let yaw = f64::atan2(-pos[1], -pos[0]);
            cams.push(Camera::new(cam, pos, yaw, (9.0f64 / 50.0).atan(), 60f64.to_radians()));
        }
        cams
    }

    /// The whole scenario's rig: the single-intersection [`Camera::ring`]
    /// for legacy configs, or one ring per intersection (ids
    /// intersection-major, positions shifted east by the spacing) for
    /// fleet configs.  With `bridge_cameras`, each adjacent pair
    /// additionally gets a corridor trio:
    ///
    /// * an **east-watcher** at the west crossing looking east down the
    ///   connecting road (coverage ends mid-corridor at its 75 m range,
    ///   short of the next intersection's traffic),
    /// * a **west-watcher** at the east crossing looking west (mirror),
    /// * a **bridge camera** south of the corridor midpoint looking
    ///   north, wide enough that its view overlaps *both* watchers'.
    ///
    /// The bridge camera co-occurs with cameras of both intersections and
    /// is the only camera that does — the overlap graph's articulation
    /// camera the constraint spill (DESIGN.md §8) splits on.  Because the
    /// two intersections' arms end short of each other (spacing >
    /// 2 × arm length), the corridor's middle stretch carries no traffic,
    /// so the bridge's two views image into disjoint tile clusters.
    pub fn fleet(cfg: &crate::config::ScenarioConfig) -> Vec<Camera> {
        if cfg.n_intersections <= 1 {
            return Camera::ring(cfg.n_cameras);
        }
        let mut cams: Vec<Camera> = Vec::new();
        for k in 0..cfg.n_intersections {
            let dx = k as f64 * cfg.intersection_spacing;
            for c in Camera::ring(cfg.n_cameras) {
                let id = cams.len();
                cams.push(Camera::new(
                    id,
                    [c.pos[0] + dx, c.pos[1], c.pos[2]],
                    c.yaw,
                    c.pitch,
                    c.hfov,
                ));
            }
        }
        if cfg.bridge_cameras {
            let watcher_pitch = (10.0f64 / 45.0).atan();
            for g in 0..cfg.n_intersections - 1 {
                let west = g as f64 * cfg.intersection_spacing;
                let east = (g + 1) as f64 * cfg.intersection_spacing;
                let id = cams.len();
                cams.push(Camera::new(
                    id,
                    [west, 6.0, 10.0],
                    0.0, // looking east
                    watcher_pitch,
                    52f64.to_radians(),
                ));
                let id = cams.len();
                cams.push(Camera::new(
                    id,
                    [east, 6.0, 10.0],
                    std::f64::consts::PI, // looking west
                    watcher_pitch,
                    52f64.to_radians(),
                ));
                let id = cams.len();
                cams.push(Camera::new(
                    id,
                    [(west + east) / 2.0, -38.0, 10.0],
                    std::f64::consts::FRAC_PI_2, // looking north at the corridor
                    (10.0f64 / 38.0).atan(),
                    80f64.to_radians(),
                ));
            }
        }
        cams
    }

    /// Project a world point; returns (u, v, depth) with depth along fwd.
    pub fn project(&self, p: [f64; 3]) -> Option<(f64, f64, f64)> {
        let v = [p[0] - self.pos[0], p[1] - self.pos[1], p[2] - self.pos[2]];
        let z = v[0] * self.fwd[0] + v[1] * self.fwd[1] + v[2] * self.fwd[2];
        if z < NEAR {
            return None;
        }
        let x = v[0] * self.right[0] + v[1] * self.right[1] + v[2] * self.right[2];
        let y = v[0] * self.down[0] + v[1] * self.down[1] + v[2] * self.down[2];
        let u = self.width as f64 / 2.0 + self.fx * x / z;
        let w = self.height as f64 / 2.0 + self.fy * y / z;
        Some((u, w, z))
    }

    /// Project a vehicle's 3-D box into an image bbox (clipped to frame).
    /// None when behind the camera, out of range, or too small.
    pub fn project_vehicle(&self, state: &VehicleState) -> Option<(Rect, f64)> {
        let (_, _, h) = state.class.dims();
        let fp = Vehicle::footprint(state);
        let dist = Vec2::new(self.pos[0], self.pos[1]).sub(state.pos).norm();
        if dist > MAX_RANGE {
            return None;
        }
        let mut min_u = f64::INFINITY;
        let mut max_u = f64::NEG_INFINITY;
        let mut min_v = f64::INFINITY;
        let mut max_v = f64::NEG_INFINITY;
        let mut depth_acc = 0.0;
        for corner in fp.iter() {
            for z in [0.0, h] {
                let (u, v, d) = self.project([corner.x, corner.y, z])?;
                min_u = min_u.min(u);
                max_u = max_u.max(u);
                min_v = min_v.min(v);
                max_v = max_v.max(v);
                depth_acc += d;
            }
        }
        let raw = Rect::from_corners(min_u, min_v, max_u, max_v);
        let clipped = raw.clip_to_frame(self.width as f64, self.height as f64);
        if clipped.area() < MIN_BBOX_AREA {
            return None;
        }
        // require that a meaningful part of the vehicle is inside the frame
        if clipped.area() < 0.25 * raw.area() {
            return None;
        }
        Some((clipped, depth_acc / 8.0))
    }

    /// Ray-cast a pixel onto the ground plane (z = 0); None if sky.
    pub fn pixel_to_ground(&self, u: f64, v: f64) -> Option<Vec2> {
        let dx = (u - self.width as f64 / 2.0) / self.fx;
        let dy = (v - self.height as f64 / 2.0) / self.fy;
        // ray direction in world coords
        let dir = [
            self.fwd[0] + dx * self.right[0] + dy * self.down[0],
            self.fwd[1] + dx * self.right[1] + dy * self.down[1],
            self.fwd[2] + dx * self.right[2] + dy * self.down[2],
        ];
        if dir[2] >= -1e-9 {
            return None; // looking up
        }
        let t = -self.pos[2] / dir[2];
        Some(Vec2::new(self.pos[0] + t * dir[0], self.pos[1] + t * dir[1]))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::vehicle::VehicleClass;

    fn center_cam() -> Camera {
        // at (30, 0, 8) looking toward the origin
        Camera::new(0, [30.0, 0.0, 8.0], std::f64::consts::PI, (8.0f64 / 30.0).atan(), 1.1)
    }

    #[test]
    fn intersection_center_projects_near_frame_center() {
        let cam = center_cam();
        let (u, v, z) = cam.project([0.0, 0.0, 0.0]).unwrap();
        assert!((u - FRAME_W as f64 / 2.0).abs() < 1.0, "u={u}");
        assert!((v - FRAME_H as f64 / 2.0).abs() < 15.0, "v={v}");
        assert!(z > 25.0 && z < 35.0);
    }

    #[test]
    fn behind_camera_is_rejected() {
        let cam = center_cam();
        assert!(cam.project([60.0, 0.0, 0.0]).is_none());
    }

    #[test]
    fn vehicle_at_center_is_visible() {
        let cam = center_cam();
        let state = VehicleState {
            id: 0,
            pos: Vec2::new(0.0, 0.0),
            heading: Vec2::new(0.0, 1.0),
            class: VehicleClass::Car,
            color: 0,
        };
        let (bbox, depth) = cam.project_vehicle(&state).unwrap();
        assert!(bbox.area() > MIN_BBOX_AREA);
        assert!(depth > 20.0 && depth < 40.0);
        // nearer vehicle must appear larger
        let near = VehicleState { pos: Vec2::new(15.0, 0.0), ..state };
        let (bbox2, _) = cam.project_vehicle(&near).unwrap();
        assert!(bbox2.area() > bbox.area());
    }

    #[test]
    fn out_of_range_rejected() {
        let cam = center_cam();
        let state = VehicleState {
            id: 0,
            pos: Vec2::new(-80.0, 0.0),
            heading: Vec2::new(0.0, 1.0),
            class: VehicleClass::Car,
            color: 0,
        };
        assert!(cam.project_vehicle(&state).is_none());
    }

    #[test]
    fn ground_raycast_roundtrip() {
        let cam = center_cam();
        for &(x, y) in &[(0.0, 0.0), (5.0, 3.0), (-4.0, -6.0)] {
            let (u, v, _) = cam.project([x, y, 0.0]).unwrap();
            let g = cam.pixel_to_ground(u, v).unwrap();
            assert!((g.x - x).abs() < 1e-6 && (g.y - y).abs() < 1e-6, "({x},{y}) -> {g:?}");
        }
    }

    #[test]
    fn ring_has_overlapping_views_of_center() {
        let cams = Camera::ring(5);
        assert_eq!(cams.len(), 5);
        let state = VehicleState {
            id: 0,
            pos: Vec2::new(0.0, 0.0),
            heading: Vec2::new(1.0, 0.0),
            class: VehicleClass::Car,
            color: 0,
        };
        let visible = cams
            .iter()
            .filter(|c| c.project_vehicle(&state).is_some())
            .count();
        assert!(visible >= 4, "only {visible} cameras see the center");
    }

    #[test]
    fn sky_pixels_have_no_ground() {
        let cam = center_cam();
        assert!(cam.pixel_to_ground(160.0, 0.0).is_none());
    }
}
