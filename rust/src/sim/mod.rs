//! Traffic-world simulator — the substitute for the NVIDIA AI-City dataset
//! (DESIGN.md §3).
//!
//! A synthetic intersection world generates (a) metric ground truth —
//! vehicle trajectories, per-camera bounding boxes and occlusion flags —
//! and (b) rendered pixel frames the codec and detector operate on.  Five
//! cameras with overlapping fields of view are placed around the crossing
//! per the paper's Fig. 1.

pub mod camera;
pub mod path;
pub mod render;
pub mod scene;
pub mod vehicle;
pub mod world;

pub use camera::Camera;
pub use render::{Frame, Renderer};
pub use scene::{GtDetection, Scenario};
pub use vehicle::{Vehicle, VehicleClass};
pub use world::World;

/// Working frame geometry — must match the L2 geometry contract
/// (`python/compile/model.py`, `artifacts/meta.json`; asserted by
/// `runtime::contract`).
pub const FRAME_W: u32 = 320;
pub const FRAME_H: u32 = 192;
