//! Pixel renderer: synthesizes the camera frames the codec compresses and
//! the detector analyzes.
//!
//! Content model (kept deliberately gray-vs-saturated, matching the L2
//! detector's analytic weights — see python/compile/model.py):
//! * asphalt, lane markings, concrete surroundings and sky are gray-scale
//!   (zero color opponency), with luminance-only texture + sensor noise;
//! * vehicles are saturated palette rectangles with a darker windshield
//!   band and skirt (multiplicative shading preserves hue).
//!
//! Static backgrounds are ray-cast once per camera (the cameras never
//! move); per-frame work is a copy + temporal noise + painter-ordered
//! vehicle fills, which keeps long renders fast.

use crate::sim::scene::Scenario;
use crate::sim::world::ROAD_HALF_WIDTH;
use crate::sim::vehicle::PALETTE;
use crate::util::rng::hash_noise;

/// An RGB8 frame (row-major, interleaved).
#[derive(Debug, Clone)]
pub struct Frame {
    pub w: u32,
    pub h: u32,
    pub data: Vec<u8>,
}

impl Frame {
    pub fn new(w: u32, h: u32) -> Frame {
        Frame { w, h, data: vec![0; (w * h * 3) as usize] }
    }

    #[inline]
    pub fn idx(&self, x: u32, y: u32) -> usize {
        ((y * self.w + x) * 3) as usize
    }

    #[inline]
    pub fn set(&mut self, x: u32, y: u32, rgb: [u8; 3]) {
        let i = self.idx(x, y);
        self.data[i..i + 3].copy_from_slice(&rgb);
    }

    #[inline]
    pub fn get(&self, x: u32, y: u32) -> [u8; 3] {
        let i = self.idx(x, y);
        [self.data[i], self.data[i + 1], self.data[i + 2]]
    }

    /// Overwrite this frame with `src`, reusing the existing allocation
    /// (the pipeline's buffer-recycling hot path).
    pub fn copy_from(&mut self, src: &Frame) {
        self.w = src.w;
        self.h = src.h;
        self.data.clear();
        self.data.extend_from_slice(&src.data);
    }

    /// Luma (BT.601-ish) of a pixel in [0, 255].
    #[inline]
    pub fn luma(&self, x: u32, y: u32) -> f32 {
        let [r, g, b] = self.get(x, y);
        0.299 * r as f32 + 0.587 * g as f32 + 0.114 * b as f32
    }

    /// Frame as HWC f32 in [0, 1] — the L2 detector's input layout.
    pub fn to_f32(&self) -> Vec<f32> {
        let mut out = Vec::new();
        self.to_f32_into(&mut out);
        out
    }

    /// [`Frame::to_f32`] writing through a reusable buffer (cleared and
    /// resized in place; allocation-free once warm).
    pub fn to_f32_into(&self, out: &mut Vec<f32>) {
        out.clear();
        out.resize(self.data.len(), 0.0);
        crate::codec::kernels::convert_u8_to_f32(&self.data, out);
    }

    /// RoI-masked detector input: like `masked_keep(keep).to_f32()` but
    /// without materializing the intermediate frame — the streaming
    /// pipeline calls this once per kept frame on the hot path.
    pub fn masked_f32(&self, keep: &[crate::util::geometry::IRect]) -> Vec<f32> {
        let mut out = Vec::new();
        self.masked_f32_into(keep, &mut out);
        out
    }

    /// [`Frame::masked_f32`] writing through a reusable buffer: the mask
    /// and the u8→f32 conversion are fused into one pass per kept row
    /// (the conversion dispatches to the SIMD kernel when selected).
    pub fn masked_f32_into(&self, keep: &[crate::util::geometry::IRect], out: &mut Vec<f32>) {
        out.clear();
        out.resize(self.data.len(), 0.0);
        for r in keep {
            if r.x >= self.w || r.y >= self.h {
                continue;
            }
            let x1 = (r.x + r.w).min(self.w);
            let y1 = (r.y + r.h).min(self.h);
            for y in r.y..y1 {
                let start = self.idx(r.x, y);
                let len = ((x1 - r.x) * 3) as usize;
                crate::codec::kernels::convert_u8_to_f32(
                    &self.data[start..start + len],
                    &mut out[start..start + len],
                );
            }
        }
    }

    /// Zero out everything except the given pixel rectangles (RoI crop:
    /// non-RoI tiles are never streamed, the server sees black there).
    pub fn masked_keep(&self, keep: &[crate::util::geometry::IRect]) -> Frame {
        let mut out = Frame::new(self.w, self.h);
        for r in keep {
            if r.x >= self.w || r.y >= self.h {
                continue;
            }
            let x1 = (r.x + r.w).min(self.w);
            let y1 = (r.y + r.h).min(self.h);
            for y in r.y..y1 {
                let src = self.idx(r.x, y);
                let len = ((x1 - r.x) * 3) as usize;
                let dst = out.idx(r.x, y);
                out.data[dst..dst + len].copy_from_slice(&self.data[src..src + len]);
            }
        }
        out
    }
}

fn to_u8(v: f64) -> u8 {
    (v.clamp(0.0, 1.0) * 255.0).round() as u8
}

/// Renders frames for a scenario.
pub struct Renderer<'a> {
    scenario: &'a Scenario,
    backgrounds: Vec<Frame>,
    noise: f64,
}

impl<'a> Renderer<'a> {
    pub fn new(scenario: &'a Scenario) -> Renderer<'a> {
        // one NS road per intersection (fleet scenarios lay them out
        // along the EW axis; the EW road is shared)
        let ns_roads: Vec<f64> = (0..scenario.world.intersection_ids.len())
            .map(|k| k as f64 * scenario.cfg.intersection_spacing)
            .collect();
        let backgrounds = scenario
            .cameras
            .iter()
            .map(|cam| {
                let mut f = Frame::new(cam.width, cam.height);
                for y in 0..cam.height {
                    for x in 0..cam.width {
                        let base = match cam.pixel_to_ground(x as f64 + 0.5, y as f64 + 0.5) {
                            None => [0.72, 0.72, 0.74], // overcast sky
                            Some(g) => ground_color_at(g.x, g.y, &ns_roads),
                        };
                        // luminance-only static texture
                        let n = (hash_noise(cam.id as u64, x as u64, y as u64, 1) - 0.5) * 0.05;
                        f.set(x, y, [to_u8(base[0] + n), to_u8(base[1] + n), to_u8(base[2] + n)]);
                    }
                }
                f
            })
            .collect();
        Renderer { scenario, backgrounds, noise: scenario.cfg.sensor_noise }
    }

    /// Render camera `cam` at frame index `frame` into a fresh buffer.
    pub fn render(&self, cam: usize, frame: usize) -> Frame {
        let mut out = Frame { w: 0, h: 0, data: Vec::new() };
        self.render_into(cam, frame, &mut out);
        out
    }

    /// Render camera `cam` at frame index `frame` into `out`, reusing the
    /// buffer's allocation — the per-camera pipeline workers render
    /// thousands of frames, so the hot path stays allocation-free.
    pub fn render_into(&self, cam: usize, frame: usize, out: &mut Frame) {
        let camera = &self.scenario.cameras[cam];
        out.copy_from(&self.backgrounds[cam]);
        let f = out;
        // painter's algorithm: scenario detections are already far -> near
        for det in self.scenario.detections(cam, frame) {
            let color = self
                .scenario
                .world
                .vehicle(det.vehicle_id)
                .map(|v| PALETTE[v.color])
                .unwrap_or([0.5, 0.5, 0.5]);
            let x0 = det.bbox.left.max(0.0) as u32;
            let y0 = det.bbox.top.max(0.0) as u32;
            let x1 = (det.bbox.right().ceil() as u32).min(camera.width);
            let y1 = (det.bbox.bottom().ceil() as u32).min(camera.height);
            let hh = (y1 - y0).max(1) as f64;
            for y in y0..y1 {
                let fy = (y - y0) as f64 / hh;
                // windshield band + dark skirt, multiplicative (keeps hue)
                let shade = if (0.18..0.38).contains(&fy) {
                    0.45
                } else if fy > 0.88 {
                    0.55
                } else {
                    1.0
                };
                for x in x0..x1 {
                    let n = 1.0
                        + (hash_noise(det.vehicle_id as u64, x as u64, y as u64, 2) - 0.5) * 0.12;
                    f.set(
                        x,
                        y,
                        [
                            to_u8(color[0] * shade * n),
                            to_u8(color[1] * shade * n),
                            to_u8(color[2] * shade * n),
                        ],
                    );
                }
            }
        }
        // temporal sensor noise (luminance-only, so it cannot excite the
        // detector's color-opponency channels; it *does* cost the codec)
        if self.noise > 0.0 {
            let amp = self.noise * 255.0;
            for y in 0..f.h {
                for x in 0..f.w {
                    let n = ((hash_noise(cam as u64, x as u64 + 7, y as u64, frame as u64)
                        - 0.5)
                        * 2.0
                        * amp) as i32;
                    let i = f.idx(x, y);
                    for c in 0..3 {
                        f.data[i + c] = (f.data[i + c] as i32 + n).clamp(0, 255) as u8;
                    }
                }
            }
        }
    }
}

/// Static ground color at world position (x, y): roads, markings,
/// concrete — the single-intersection world (NS road at x = 0; the
/// legacy-background regression tests pin this form).
#[cfg(test)]
fn ground_color(x: f64, y: f64) -> [f64; 3] {
    ground_color_at(x, y, &[0.0])
}

/// [`ground_color`] for a fleet: one NS road per intersection center in
/// `ns_roads`, sharing the one EW road.  With `ns_roads == [0.0]` this
/// is exactly the legacy single-intersection background.
fn ground_color_at(x: f64, y: f64, ns_roads: &[f64]) -> [f64; 3] {
    // relative x to the nearest intersection's NS road
    let x = ns_roads
        .iter()
        .map(|&ox| x - ox)
        .min_by(|a, b| a.abs().partial_cmp(&b.abs()).unwrap())
        .unwrap_or(x);
    let on_ns = x.abs() <= ROAD_HALF_WIDTH;
    let on_ew = y.abs() <= ROAD_HALF_WIDTH;
    if on_ns && on_ew {
        return [0.42, 0.42, 0.42]; // intersection box, no markings
    }
    if on_ns || on_ew {
        // (along, across) relative to the road direction
        let (along, across) = if on_ns { (y, x) } else { (x, y) };
        // center double line
        if across.abs() < 0.15 {
            return [0.88, 0.88, 0.88];
        }
        // dashed lane separators
        if (across.abs() - 3.5).abs() < 0.12 && along.rem_euclid(6.0) < 3.0 {
            return [0.88, 0.88, 0.88];
        }
        // solid edge lines
        if (across.abs() - 6.8).abs() < 0.12 {
            return [0.88, 0.88, 0.88];
        }
        return [0.42, 0.42, 0.42]; // asphalt
    }
    [0.50, 0.49, 0.48] // concrete surroundings (near-gray)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::sim::scene::Scenario;
    use crate::util::geometry::IRect;

    fn scenario() -> Scenario {
        Scenario::build(&Config::test_small().scenario)
    }

    #[test]
    fn frames_have_expected_shape() {
        let sc = scenario();
        let r = sc.renderer();
        let f = r.render(0, 0);
        assert_eq!(f.w, 320);
        assert_eq!(f.h, 192);
        assert_eq!(f.data.len(), 320 * 192 * 3);
    }

    #[test]
    fn rendering_is_deterministic() {
        let sc = scenario();
        let r = sc.renderer();
        assert_eq!(r.render(1, 5).data, r.render(1, 5).data);
    }

    #[test]
    fn background_is_grayscale_only() {
        // color opponency of every background pixel must be ~0 so the
        // detector stays silent off-vehicle (noise-free check)
        let mut cfg = Config::test_small().scenario;
        cfg.sensor_noise = 0.0;
        let sc = Scenario::build(&cfg);
        let r = sc.renderer();
        // find a frame with no vehicles in camera 0
        let empty = (0..sc.n_frames()).find(|&f| sc.detections(0, f).is_empty());
        if let Some(frame) = empty {
            let f = r.render(0, frame);
            for y in 0..f.h {
                for x in 0..f.w {
                    let [r8, g8, b8] = f.get(x, y);
                    let sat = (r8 as i32 - g8 as i32).abs().max((g8 as i32 - b8 as i32).abs());
                    assert!(sat <= 8, "background pixel ({x},{y}) is colored: {r8},{g8},{b8}");
                }
            }
        }
    }

    #[test]
    fn vehicles_paint_saturated_pixels() {
        let sc = scenario();
        let r = sc.renderer();
        // find a frame with a vehicle
        'outer: for frame in 0..sc.n_frames() {
            for cam in 0..sc.cameras.len() {
                if let Some(det) = sc.detections(cam, frame).iter().find(|d| !d.occluded) {
                    let f = r.render(cam, frame);
                    let (cx, cy) = det.bbox.center();
                    // sample a body pixel (below the windshield band)
                    let y = (det.bbox.top + det.bbox.height * 0.6) as u32;
                    let [r8, g8, b8] = f.get(cx as u32, y.min(f.h - 1));
                    let sat = (r8 as i32 - g8 as i32).abs()
                        + (g8 as i32 - b8 as i32).abs()
                        + (b8 as i32 - r8 as i32).abs();
                    assert!(sat > 60, "vehicle pixel not saturated: {r8},{g8},{b8} at {cx},{cy}");
                    break 'outer;
                }
            }
        }
    }

    #[test]
    fn masked_keep_zeroes_outside() {
        let sc = scenario();
        let r = sc.renderer();
        let f = r.render(0, 0);
        let keep = vec![IRect::new(32, 32, 64, 32)];
        let m = f.masked_keep(&keep);
        assert_eq!(m.get(0, 0), [0, 0, 0]);
        assert_eq!(m.get(33, 33), f.get(33, 33));
        assert_eq!(m.get(95, 63), f.get(95, 63));
        assert_eq!(m.get(96, 63), [0, 0, 0]);
        assert_eq!(m.get(200, 100), [0, 0, 0]);
    }

    #[test]
    fn render_into_reuses_buffer_and_matches_render() {
        let sc = scenario();
        let r = sc.renderer();
        let mut buf = Frame::new(1, 1);
        r.render_into(0, 3, &mut buf);
        assert_eq!(buf.data, r.render(0, 3).data);
        // stale contents from a previous frame must not leak through
        r.render_into(0, 4, &mut buf);
        assert_eq!(buf.data, r.render(0, 4).data);
        assert_eq!((buf.w, buf.h), (320, 192));
    }

    #[test]
    fn masked_f32_matches_masked_keep_to_f32() {
        let sc = scenario();
        let r = sc.renderer();
        let f = r.render(0, 0);
        let keep = vec![IRect::new(32, 32, 64, 32), IRect::new(200, 100, 50, 40)];
        assert_eq!(f.masked_f32(&keep), f.masked_keep(&keep).to_f32());
    }

    #[test]
    fn masked_f32_into_reuses_buffer_with_odd_offsets() {
        let sc = scenario();
        let r = sc.renderer();
        let f = r.render(0, 2);
        let mut buf = Vec::new();
        let cases: Vec<Vec<IRect>> = vec![
            vec![IRect::new(63, 47, 161, 97)], // odd offsets, non-lane-multiple width
            vec![IRect::new(32, 32, 64, 32), IRect::new(200, 100, 50, 40)],
            vec![IRect::new(300, 180, 100, 100)], // clamped at the frame edge
            vec![],                               // all-black
        ];
        for keep in cases {
            f.masked_f32_into(&keep, &mut buf);
            assert_eq!(buf, f.masked_keep(&keep).to_f32(), "{keep:?}");
        }
        // stale contents from the previous mask must not leak through
        f.masked_f32_into(&[], &mut buf);
        assert!(buf.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn temporal_noise_varies_frames() {
        let sc = scenario();
        let r = sc.renderer();
        let a = r.render(0, 0);
        let b = r.render(0, 1);
        assert_ne!(a.data, b.data, "consecutive frames identical — no sensor noise?");
    }

    #[test]
    fn ground_colors() {
        // intersection
        assert_eq!(ground_color(0.0, 0.0), [0.42, 0.42, 0.42]);
        // road asphalt away from lines
        assert_eq!(ground_color(2.0, 40.0), [0.42, 0.42, 0.42]);
        // center line
        assert_eq!(ground_color(0.0, 40.0), [0.88, 0.88, 0.88]);
        // concrete
        assert_eq!(ground_color(50.0, 50.0), [0.50, 0.49, 0.48]);
    }
}
