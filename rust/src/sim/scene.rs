//! Scenario = world + camera rig + frame clock, and the ground-truth
//! detection streams (per camera, per frame) everything downstream consumes:
//! the ReID error model, the RoI optimizer constraints and the query scorer.

use crate::config::ScenarioConfig;
use crate::sim::camera::Camera;
use crate::sim::render::Renderer;
use crate::sim::world::World;
use crate::util::geometry::Rect;

/// A ground-truth detection of one vehicle in one camera frame.
#[derive(Debug, Clone)]
pub struct GtDetection {
    pub vehicle_id: u32,
    pub bbox: Rect,
    /// Camera-to-vehicle depth (m) — used for painter-order rendering and
    /// occlusion reasoning.
    pub depth: f64,
    /// True when mostly covered by a closer vehicle: the dataset's ReID
    /// ground truth misses these (§5.1.1), ours flags them instead.
    pub occluded: bool,
}

/// Fraction of a bbox that must be covered by a closer one to be occluded.
pub const OCCLUSION_COVER: f64 = 0.65;

/// The full evaluation scenario.
pub struct Scenario {
    pub cfg: ScenarioConfig,
    pub world: World,
    pub cameras: Vec<Camera>,
    /// `gt[cam][frame]` — detections ordered far → near.
    gt: Vec<Vec<Vec<GtDetection>>>,
}

impl Scenario {
    /// Build the world, rig and ground truth for a configuration.
    pub fn build(cfg: &ScenarioConfig) -> Scenario {
        let world = World::generate(cfg);
        let cameras = Camera::fleet(cfg);
        let n_frames = cfg.total_frames();
        let mut gt = vec![Vec::with_capacity(n_frames); cameras.len()];
        for frame in 0..n_frames {
            let t = frame as f64 / cfg.fps;
            let states = world.states_at(t);
            for (ci, cam) in cameras.iter().enumerate() {
                let mut dets: Vec<GtDetection> = states
                    .iter()
                    .filter_map(|s| {
                        cam.project_vehicle(s).map(|(bbox, depth)| GtDetection {
                            vehicle_id: s.id,
                            bbox,
                            depth,
                            occluded: false,
                        })
                    })
                    .collect();
                // far -> near so the renderer can paint in order
                dets.sort_by(|a, b| b.depth.partial_cmp(&a.depth).unwrap());
                mark_occlusions(&mut dets);
                gt[ci].push(dets);
            }
        }
        Scenario { cfg: cfg.clone(), world, cameras, gt }
    }

    pub fn n_frames(&self) -> usize {
        self.gt.first().map_or(0, |c| c.len())
    }

    /// Ground-truth detections for a camera frame (far → near order).
    pub fn detections(&self, cam: usize, frame: usize) -> &[GtDetection] {
        &self.gt[cam][frame]
    }

    /// Unique vehicle ids visible anywhere in the scene at a frame
    /// (the denominator of the paper's unique-vehicle-detection query).
    pub fn unique_visible(&self, frame: usize) -> Vec<u32> {
        let mut ids: Vec<u32> = (0..self.cameras.len())
            .flat_map(|c| self.gt[c][frame].iter().map(|d| d.vehicle_id))
            .collect();
        ids.sort_unstable();
        ids.dedup();
        ids
    }

    /// Intersection whose traffic world spawned vehicle `id` (always 0 in
    /// the legacy single-intersection world).
    pub fn intersection_of_vehicle(&self, id: u32) -> usize {
        self.world.intersection_of(id)
    }

    /// A renderer bound to this scenario's cameras and world.
    pub fn renderer(&self) -> Renderer<'_> {
        Renderer::new(self)
    }

    /// Frame index range of the offline profile window.
    pub fn profile_range(&self) -> std::ops::Range<usize> {
        0..self.cfg.profile_frames().min(self.n_frames())
    }

    /// Frame index range of the online evaluation window.
    pub fn eval_range(&self) -> std::ops::Range<usize> {
        self.cfg.profile_frames().min(self.n_frames())..self.n_frames()
    }

    /// Total ground-truth bbox count (sanity/scale metric; the paper's
    /// scene has ~30 K boxes over 3 minutes).
    pub fn total_boxes(&self) -> usize {
        self.gt.iter().flat_map(|c| c.iter()).map(|f| f.len()).sum()
    }
}

/// Flag detections mostly covered by a closer vehicle.
/// `dets` must be sorted far → near.
fn mark_occlusions(dets: &mut [GtDetection]) {
    for i in 0..dets.len() {
        let mut covered = 0.0;
        for j in i + 1..dets.len() {
            // j is nearer (sorted far->near)
            covered += dets[i].bbox.coverage_by(&dets[j].bbox);
        }
        if covered >= OCCLUSION_COVER {
            dets[i].occluded = true;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;

    fn small_scenario() -> Scenario {
        Scenario::build(&Config::test_small().scenario)
    }

    #[test]
    fn ground_truth_shape() {
        let sc = small_scenario();
        assert_eq!(sc.cameras.len(), 5);
        assert_eq!(sc.n_frames(), sc.cfg.total_frames()); // 20 s at cfg fps
        assert!(sc.total_boxes() > 100, "too few boxes: {}", sc.total_boxes());
    }

    #[test]
    fn bboxes_are_inside_frames() {
        let sc = small_scenario();
        for cam in 0..sc.cameras.len() {
            for frame in 0..sc.n_frames() {
                for det in sc.detections(cam, frame) {
                    assert!(det.bbox.left >= 0.0 && det.bbox.top >= 0.0);
                    assert!(det.bbox.right() <= sc.cameras[cam].width as f64 + 1e-9);
                    assert!(det.bbox.bottom() <= sc.cameras[cam].height as f64 + 1e-9);
                    assert!(det.bbox.area() > 0.0);
                }
            }
        }
    }

    #[test]
    fn some_vehicles_are_multi_camera() {
        let sc = small_scenario();
        let mut multi = 0;
        for frame in 0..sc.n_frames() {
            let mut seen = std::collections::HashMap::new();
            for cam in 0..sc.cameras.len() {
                for det in sc.detections(cam, frame) {
                    *seen.entry(det.vehicle_id).or_insert(0usize) += 1;
                }
            }
            multi += seen.values().filter(|&&c| c >= 2).count();
        }
        assert!(multi > 20, "cross-camera overlap too rare: {multi}");
    }

    #[test]
    fn detections_sorted_far_to_near() {
        let sc = small_scenario();
        for frame in 0..sc.n_frames() {
            for cam in 0..sc.cameras.len() {
                let dets = sc.detections(cam, frame);
                for pair in dets.windows(2) {
                    assert!(pair[0].depth >= pair[1].depth);
                }
            }
        }
    }

    #[test]
    fn unique_visible_counts() {
        let sc = small_scenario();
        let mut any = false;
        for frame in 0..sc.n_frames() {
            let uniq = sc.unique_visible(frame);
            let mut sorted = uniq.clone();
            sorted.dedup();
            assert_eq!(sorted.len(), uniq.len());
            if uniq.len() >= 2 {
                any = true;
            }
        }
        assert!(any, "scene never has 2+ vehicles visible");
    }

    #[test]
    fn occlusion_marks_covered_boxes() {
        let mut dets = vec![
            GtDetection {
                vehicle_id: 0,
                bbox: Rect::new(10.0, 10.0, 20.0, 20.0),
                depth: 50.0,
                occluded: false,
            },
            GtDetection {
                vehicle_id: 1,
                bbox: Rect::new(8.0, 8.0, 30.0, 30.0),
                depth: 20.0,
                occluded: false,
            },
        ];
        mark_occlusions(&mut dets);
        assert!(dets[0].occluded);
        assert!(!dets[1].occluded);
    }

    #[test]
    fn profile_and_eval_ranges_partition_frames() {
        let sc = small_scenario();
        let p = sc.profile_range();
        let e = sc.eval_range();
        assert_eq!(p.end, e.start);
        assert_eq!(e.end, sc.n_frames());
        assert_eq!(p.start, 0);
    }
}
