//! Concurrency facade for the planner's epoch-publication machinery
//! (DESIGN.md §11).
//!
//! The re-planning path has exactly two shared-state protocols:
//!
//! * **epoch publication** — the planner thread publishes one immutable
//!   [`std::sync::Arc`]'d plan per epoch into a fixed-size table; stage
//!   workers block until their epoch's slot fills ([`EpochTable`]);
//! * **snapshot → compute → commit** — the component re-planner copies
//!   its baseline under a brief lock, solves outside the lock, then
//!   merges the result back under a second brief lock ([`StateCell`]).
//!
//! Both are built here on a `Mutex`/`Condvar` pair that swaps to the
//! in-tree `loom` model checker under `--cfg loom`, so
//! `rust/tests/loom_epoch.rs` can exhaustively enumerate every
//! interleaving of publish/wait/commit.  Production builds re-export
//! `std::sync` and compile to exactly the code the pipeline ran before
//! the facade existed.

#[cfg(loom)]
pub use loom::sync::{Condvar, Mutex, MutexGuard};
#[cfg(not(loom))]
pub use std::sync::{Condvar, Mutex, MutexGuard};

use std::sync::Arc;

/// A fixed-size table of write-once epoch slots.
///
/// `publish` fills a slot (first write wins — late duplicate plans from a
/// racing planner are dropped, so every reader of epoch `k` observes the
/// same `Arc`), `wait` blocks until a slot fills, `get` peeks without
/// blocking.  The value behind the `Arc` is immutable once published:
/// readers can never observe a torn epoch (fields from two different
/// plans) because the only shared mutation is the single
/// `None → Some(arc)` slot transition under the slot's mutex.
pub struct EpochTable<T> {
    cells: Vec<EpochCell<T>>,
}

struct EpochCell<T> {
    slot: Mutex<Option<Arc<T>>>,
    ready: Condvar,
}

impl<T> EpochTable<T> {
    /// A table with `n_epochs` empty slots (at least one).
    pub fn new(n_epochs: usize) -> EpochTable<T> {
        let cells = (0..n_epochs.max(1))
            .map(|_| EpochCell {
                slot: Mutex::new(None),
                ready: Condvar::new(),
            })
            .collect();
        EpochTable { cells }
    }

    /// Number of epoch slots.
    pub fn len(&self) -> usize {
        self.cells.len()
    }

    /// Tables always hold at least one slot.
    pub fn is_empty(&self) -> bool {
        false
    }

    /// Publish `value` into slot `k`; first write wins.  Returns whether
    /// this call installed the value (`false` = an earlier publish won
    /// and `value` was dropped).  Waiters on `k` are woken either way.
    pub fn publish(&self, k: usize, value: Arc<T>) -> bool {
        let cell = &self.cells[k];
        let mut slot = cell.slot.lock().unwrap();
        let installed = if slot.is_none() {
            *slot = Some(value);
            true
        } else {
            false
        };
        drop(slot);
        cell.ready.notify_all();
        installed
    }

    /// Block until slot `k` is published, then return the shared plan.
    pub fn wait(&self, k: usize) -> Arc<T> {
        let cell = &self.cells[k];
        let mut slot = cell.slot.lock().unwrap();
        loop {
            if let Some(v) = slot.as_ref() {
                return Arc::clone(v);
            }
            slot = cell.ready.wait(slot).unwrap();
        }
    }

    /// Non-blocking peek at slot `k`.
    pub fn get(&self, k: usize) -> Option<Arc<T>> {
        self.cells[k].slot.lock().unwrap().clone()
    }
}

/// Mutex-held state driven through the snapshot → compute → commit
/// protocol (DESIGN.md §8).
///
/// Both methods take the lock only for the duration of the closure; the
/// expensive solve happens between a `snapshot` and its `commit`, off the
/// lock, so stage workers reading records never block behind the solver.
/// The protocol invariant the loom model checks: a commit closure runs
/// atomically, so an observer snapshotting between commits sees either
/// none or all of a commit's writes — a pushed record can never be
/// observed without the baseline update committed alongside it.
pub struct StateCell<S> {
    inner: Mutex<S>,
}

impl<S> StateCell<S> {
    pub fn new(state: S) -> StateCell<S> {
        StateCell {
            inner: Mutex::new(state),
        }
    }

    /// Read (or lazily seed) the state under a brief lock.
    ///
    /// Snapshot closures may write — the re-planner seeds its baseline on
    /// first use — but must copy out anything the compute phase needs:
    /// nothing borrowed from the state survives the call.
    pub fn snapshot<R>(&self, read: impl FnOnce(&mut S) -> R) -> R {
        read(&mut self.inner.lock().unwrap())
    }

    /// Merge a computed result back under a brief lock.
    ///
    /// All writes belonging to one logical commit must happen inside a
    /// single closure call; splitting them across two `commit` calls
    /// would let observers see the torn intermediate state.
    pub fn commit<R>(&self, write: impl FnOnce(&mut S) -> R) -> R {
        write(&mut self.inner.lock().unwrap())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn publish_then_wait_roundtrips() {
        let table: EpochTable<u32> = EpochTable::new(3);
        assert_eq!(table.len(), 3);
        assert!(!table.is_empty());
        assert!(table.get(1).is_none());
        assert!(table.publish(1, Arc::new(7)));
        assert_eq!(*table.wait(1), 7);
        assert_eq!(table.get(1).as_deref(), Some(&7));
    }

    #[test]
    fn publish_is_first_write_wins() {
        let table: EpochTable<u32> = EpochTable::new(1);
        assert!(table.publish(0, Arc::new(1)));
        assert!(!table.publish(0, Arc::new(2)));
        assert_eq!(*table.wait(0), 1);
    }

    #[test]
    fn zero_slot_table_rounds_up_to_one() {
        let table: EpochTable<u32> = EpochTable::new(0);
        assert_eq!(table.len(), 1);
    }

    #[test]
    fn wait_blocks_until_published() {
        let table: Arc<EpochTable<u32>> = Arc::new(EpochTable::new(2));
        let t2 = Arc::clone(&table);
        let waiter = std::thread::spawn(move || *t2.wait(1));
        std::thread::sleep(std::time::Duration::from_millis(20));
        table.publish(1, Arc::new(42));
        assert_eq!(waiter.join().unwrap(), 42);
    }

    #[test]
    fn state_cell_snapshot_and_commit() {
        let cell = StateCell::new(Vec::<u32>::new());
        cell.commit(|v| v.push(1));
        let copy = cell.snapshot(|v| v.clone());
        assert_eq!(copy, vec![1]);
        // snapshot may seed lazily
        cell.snapshot(|v| {
            if v.len() == 1 {
                v.push(2);
            }
        });
        assert_eq!(cell.snapshot(|v| v.len()), 2);
    }
}
