//! 2-D geometry primitives shared by the simulator, ReID records, filters
//! and the query matcher.  Bounding boxes use the paper's
//! `<left, top, width, height>` convention (§4.1.1), pixels, y-down.

/// Axis-aligned rectangle `<left, top, width, height>` in f64 pixels.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Rect {
    pub left: f64,
    pub top: f64,
    pub width: f64,
    pub height: f64,
}

impl Rect {
    pub fn new(left: f64, top: f64, width: f64, height: f64) -> Self {
        Rect { left, top, width, height }
    }

    /// From corner coordinates; empty if inverted.
    pub fn from_corners(x0: f64, y0: f64, x1: f64, y1: f64) -> Self {
        Rect::new(x0, y0, (x1 - x0).max(0.0), (y1 - y0).max(0.0))
    }

    pub fn right(&self) -> f64 {
        self.left + self.width
    }

    pub fn bottom(&self) -> f64 {
        self.top + self.height
    }

    pub fn area(&self) -> f64 {
        self.width * self.height
    }

    pub fn is_empty(&self) -> bool {
        self.width <= 0.0 || self.height <= 0.0
    }

    pub fn center(&self) -> (f64, f64) {
        (self.left + self.width / 2.0, self.top + self.height / 2.0)
    }

    pub fn contains_point(&self, x: f64, y: f64) -> bool {
        x >= self.left && x < self.right() && y >= self.top && y < self.bottom()
    }

    /// Intersection rectangle (possibly empty).
    pub fn intersect(&self, other: &Rect) -> Rect {
        Rect::from_corners(
            self.left.max(other.left),
            self.top.max(other.top),
            self.right().min(other.right()),
            self.bottom().min(other.bottom()),
        )
    }

    /// Intersection-over-union.
    pub fn iou(&self, other: &Rect) -> f64 {
        let inter = self.intersect(other).area();
        let union = self.area() + other.area() - inter;
        if union <= 0.0 {
            0.0
        } else {
            inter / union
        }
    }

    /// Fraction of `self` covered by `other`.
    pub fn coverage_by(&self, other: &Rect) -> f64 {
        if self.area() <= 0.0 {
            0.0
        } else {
            self.intersect(other).area() / self.area()
        }
    }

    /// Clip to a `width x height` frame; may become empty.
    pub fn clip_to_frame(&self, width: f64, height: f64) -> Rect {
        self.intersect(&Rect::new(0.0, 0.0, width, height))
    }

    /// Smallest rectangle containing both.
    pub fn union_bounds(&self, other: &Rect) -> Rect {
        if self.is_empty() {
            return *other;
        }
        if other.is_empty() {
            return *self;
        }
        Rect::from_corners(
            self.left.min(other.left),
            self.top.min(other.top),
            self.right().max(other.right()),
            self.bottom().max(other.bottom()),
        )
    }
}

/// Integer pixel rectangle (used by the codec and tile grouping).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct IRect {
    pub x: u32,
    pub y: u32,
    pub w: u32,
    pub h: u32,
}

impl IRect {
    pub fn new(x: u32, y: u32, w: u32, h: u32) -> Self {
        IRect { x, y, w, h }
    }

    pub fn area(&self) -> u64 {
        self.w as u64 * self.h as u64
    }

    pub fn to_rect(&self) -> Rect {
        Rect::new(self.x as f64, self.y as f64, self.w as f64, self.h as f64)
    }

    pub fn contains(&self, px: u32, py: u32) -> bool {
        px >= self.x && px < self.x + self.w && py >= self.y && py < self.y + self.h
    }
}

/// A 2-D point / vector in world meters.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Vec2 {
    pub x: f64,
    pub y: f64,
}

impl Vec2 {
    pub fn new(x: f64, y: f64) -> Self {
        Vec2 { x, y }
    }

    pub fn add(self, o: Vec2) -> Vec2 {
        Vec2::new(self.x + o.x, self.y + o.y)
    }

    pub fn sub(self, o: Vec2) -> Vec2 {
        Vec2::new(self.x - o.x, self.y - o.y)
    }

    pub fn scale(self, s: f64) -> Vec2 {
        Vec2::new(self.x * s, self.y * s)
    }

    pub fn norm(self) -> f64 {
        (self.x * self.x + self.y * self.y).sqrt()
    }

    pub fn normalized(self) -> Vec2 {
        let n = self.norm();
        if n == 0.0 {
            Vec2::new(0.0, 0.0)
        } else {
            self.scale(1.0 / n)
        }
    }

    /// Perpendicular (rotated +90°).
    pub fn perp(self) -> Vec2 {
        Vec2::new(-self.y, self.x)
    }

    pub fn dot(self, o: Vec2) -> f64 {
        self.x * o.x + self.y * o.y
    }

    pub fn rotate(self, angle: f64) -> Vec2 {
        let (s, c) = angle.sin_cos();
        Vec2::new(c * self.x - s * self.y, s * self.x + c * self.y)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn iou_identity_and_disjoint() {
        let a = Rect::new(0.0, 0.0, 10.0, 10.0);
        assert!((a.iou(&a) - 1.0).abs() < 1e-12);
        let b = Rect::new(20.0, 20.0, 5.0, 5.0);
        assert_eq!(a.iou(&b), 0.0);
    }

    #[test]
    fn iou_half_overlap() {
        let a = Rect::new(0.0, 0.0, 10.0, 10.0);
        let b = Rect::new(5.0, 0.0, 10.0, 10.0);
        // inter 50, union 150
        assert!((a.iou(&b) - 1.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn intersect_empty_when_disjoint() {
        let a = Rect::new(0.0, 0.0, 4.0, 4.0);
        let b = Rect::new(10.0, 10.0, 4.0, 4.0);
        assert!(a.intersect(&b).is_empty());
    }

    #[test]
    fn clip_to_frame() {
        let r = Rect::new(-5.0, -5.0, 20.0, 20.0).clip_to_frame(10.0, 8.0);
        assert_eq!(r, Rect::new(0.0, 0.0, 10.0, 8.0));
    }

    #[test]
    fn coverage() {
        let a = Rect::new(0.0, 0.0, 10.0, 10.0);
        let b = Rect::new(0.0, 0.0, 5.0, 10.0);
        assert!((b.coverage_by(&a) - 1.0).abs() < 1e-12);
        assert!((a.coverage_by(&b) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn union_bounds() {
        let a = Rect::new(0.0, 0.0, 2.0, 2.0);
        let b = Rect::new(4.0, 4.0, 2.0, 2.0);
        let u = a.union_bounds(&b);
        assert_eq!(u, Rect::new(0.0, 0.0, 6.0, 6.0));
    }

    #[test]
    fn vec2_ops() {
        let v = Vec2::new(3.0, 4.0);
        assert!((v.norm() - 5.0).abs() < 1e-12);
        assert!((v.normalized().norm() - 1.0).abs() < 1e-12);
        let r = Vec2::new(1.0, 0.0).rotate(std::f64::consts::FRAC_PI_2);
        assert!((r.x).abs() < 1e-12 && (r.y - 1.0).abs() < 1e-12);
        assert_eq!(v.perp().dot(v), 0.0);
    }
}
