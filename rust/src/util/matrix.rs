//! Small dense linear algebra: just enough for the statistical filters —
//! least-squares solves for the RANSAC regression filter (§4.2.2) and the
//! DCT basis products in the codec.  Row-major `Mat` with Gaussian
//! elimination; dimensions here are tiny (≤ ~30), so simplicity wins.

/// Row-major dense matrix of f64.
#[derive(Debug, Clone, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn identity(n: usize) -> Self {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_rows(rows: &[Vec<f64>]) -> Self {
        let r = rows.len();
        let c = if r == 0 { 0 } else { rows[0].len() };
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c, "ragged rows");
            data.extend_from_slice(row);
        }
        Mat { rows: r, cols: c, data }
    }

    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t[(j, i)] = self[(i, j)];
            }
        }
        t
    }

    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "shape mismatch");
        let mut out = Mat::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self[(i, k)];
                if a == 0.0 {
                    continue;
                }
                for j in 0..other.cols {
                    out[(i, j)] += a * other[(k, j)];
                }
            }
        }
        out
    }

    pub fn matvec(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(self.cols, v.len());
        (0..self.rows)
            .map(|i| self.row(i).iter().zip(v).map(|(a, b)| a * b).sum())
            .collect()
    }

    /// Solve `self * x = b` by Gaussian elimination with partial pivoting.
    /// Returns None when singular (pivot below 1e-12).
    pub fn solve(&self, b: &[f64]) -> Option<Vec<f64>> {
        assert_eq!(self.rows, self.cols);
        assert_eq!(self.rows, b.len());
        let n = self.rows;
        let mut a = self.clone();
        let mut x = b.to_vec();
        for col in 0..n {
            // pivot
            let mut piv = col;
            for r in col + 1..n {
                if a[(r, col)].abs() > a[(piv, col)].abs() {
                    piv = r;
                }
            }
            if a[(piv, col)].abs() < 1e-12 {
                return None;
            }
            if piv != col {
                for j in 0..n {
                    let tmp = a[(col, j)];
                    a[(col, j)] = a[(piv, j)];
                    a[(piv, j)] = tmp;
                }
                x.swap(col, piv);
            }
            // eliminate
            for r in col + 1..n {
                let f = a[(r, col)] / a[(col, col)];
                if f == 0.0 {
                    continue;
                }
                for j in col..n {
                    a[(r, j)] -= f * a[(col, j)];
                }
                x[r] -= f * x[col];
            }
        }
        // back substitution
        for col in (0..n).rev() {
            let mut s = x[col];
            for j in col + 1..n {
                s -= a[(col, j)] * x[j];
            }
            x[col] = s / a[(col, col)];
        }
        Some(x)
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[i * self.cols + j]
    }
}

/// Least-squares solve of `A x ≈ b` via ridge-regularized normal equations
/// `(AᵀA + λI) x = Aᵀ b`.  λ defaults tiny — only there to keep nearly
/// collinear polynomial features solvable.
pub fn lstsq(a: &Mat, b: &[f64], ridge: f64) -> Option<Vec<f64>> {
    assert_eq!(a.rows, b.len());
    let at = a.transpose();
    let mut ata = at.matmul(a);
    for i in 0..ata.rows {
        ata[(i, i)] += ridge;
    }
    let atb = at.matvec(b);
    ata.solve(&atb)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn solve_simple() {
        let a = Mat::from_rows(&[vec![2.0, 1.0], vec![1.0, 3.0]]);
        let x = a.solve(&[5.0, 10.0]).unwrap();
        assert!((x[0] - 1.0).abs() < 1e-10);
        assert!((x[1] - 3.0).abs() < 1e-10);
    }

    #[test]
    fn solve_requires_pivoting() {
        let a = Mat::from_rows(&[vec![0.0, 1.0], vec![1.0, 0.0]]);
        let x = a.solve(&[2.0, 3.0]).unwrap();
        assert_eq!(x, vec![3.0, 2.0]);
    }

    #[test]
    fn solve_singular_returns_none() {
        let a = Mat::from_rows(&[vec![1.0, 2.0], vec![2.0, 4.0]]);
        assert!(a.solve(&[1.0, 2.0]).is_none());
    }

    #[test]
    fn matmul_identity() {
        let a = Mat::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        let i = Mat::identity(2);
        assert_eq!(a.matmul(&i), a);
        assert_eq!(i.matmul(&a), a);
    }

    #[test]
    fn transpose_involution() {
        let a = Mat::from_rows(&[vec![1.0, 2.0, 3.0], vec![4.0, 5.0, 6.0]]);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn lstsq_recovers_line() {
        // y = 3 + 2x with exact data
        let xs = [0.0, 1.0, 2.0, 3.0, 4.0];
        let a = Mat::from_rows(&xs.iter().map(|&x| vec![1.0, x]).collect::<Vec<_>>());
        let b: Vec<f64> = xs.iter().map(|&x| 3.0 + 2.0 * x).collect();
        let w = lstsq(&a, &b, 1e-9).unwrap();
        assert!((w[0] - 3.0).abs() < 1e-5);
        assert!((w[1] - 2.0).abs() < 1e-5);
    }

    #[test]
    fn lstsq_overdetermined_noisy() {
        // best fit of constant signal: mean
        let a = Mat::from_rows(&(0..10).map(|_| vec![1.0]).collect::<Vec<_>>());
        let b: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let w = lstsq(&a, &b, 0.0).unwrap();
        assert!((w[0] - 4.5).abs() < 1e-9);
    }
}
