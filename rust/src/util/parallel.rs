//! Deterministic fan-out: map a slice over scoped worker threads and
//! return results **in input order**, so every merge downstream is
//! byte-identical to the sequential execution regardless of thread count
//! or scheduling (the same re-canonicalization rule as the online
//! pipeline, DESIGN.md §4; used by the offline planner's pair fitting,
//! DESIGN.md §5).

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::time::Instant;

/// Map `f` over `items` on up to `threads` scoped worker threads.
///
/// Items are strided over the workers (worker `w` takes items `w`,
/// `w + threads`, …); each worker returns `(index, result)` pairs and the
/// caller reassembles them by index, so the output order — and therefore
/// any order-sensitive fold over it — never depends on scheduling.
/// `threads <= 1` (or a single item) runs inline on the caller's thread.
pub fn ordered_map<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let threads = threads.clamp(1, items.len().max(1));
    if threads <= 1 || items.len() <= 1 {
        return items.iter().map(f).collect();
    }
    let mut slots: Vec<Option<R>> = (0..items.len()).map(|_| None).collect();
    std::thread::scope(|scope| {
        let f = &f;
        let handles: Vec<_> = (0..threads)
            .map(|w| {
                scope.spawn(move || {
                    let mut out = Vec::new();
                    let mut i = w;
                    while i < items.len() {
                        out.push((i, f(&items[i])));
                        i += threads;
                    }
                    out
                })
            })
            .collect();
        for h in handles {
            for (i, r) in h.join().expect("worker panicked") {
                slots[i] = Some(r);
            }
        }
    });
    slots.into_iter().map(|s| s.expect("every item mapped exactly once")).collect()
}

/// Concurrency gauge for a shared worker pool: counts tasks, tracks the
/// high-water mark of simultaneously running tasks, and accumulates
/// queue-wait (time between a task being enqueued and starting to run).
///
/// The counters are relaxed atomics — diagnostics whose exact values
/// depend on scheduling, so consumers surface them beside (never inside)
/// byte-compared output, the same contract as the buffer-arena counters.
#[derive(Debug, Default)]
pub struct PoolGauge {
    tasks: AtomicUsize,
    active: AtomicUsize,
    max_concurrent: AtomicUsize,
    queue_wait_ns: AtomicU64,
}

/// Snapshot of a [`PoolGauge`]'s counters.
#[derive(Debug, Clone, Copy, Default)]
pub struct PoolStats {
    /// Tasks run through the pool.
    pub tasks: usize,
    /// High-water mark of tasks running simultaneously.
    pub max_concurrent: usize,
    /// Total seconds tasks spent waiting between enqueue and start.
    pub queue_wait_secs: f64,
}

impl PoolGauge {
    pub fn new() -> PoolGauge {
        PoolGauge::default()
    }

    /// Run `f` as one tracked task: `queued_at` is when the task was
    /// handed to the pool, so `now - queued_at` at entry is its queue
    /// wait.  Returns `f`'s result unchanged.
    pub fn track<R>(&self, queued_at: Instant, f: impl FnOnce() -> R) -> R {
        let wait = queued_at.elapsed().as_nanos() as u64;
        self.queue_wait_ns.fetch_add(wait, Ordering::Relaxed);
        let running = self.active.fetch_add(1, Ordering::Relaxed) + 1;
        self.max_concurrent.fetch_max(running, Ordering::Relaxed);
        let out = f();
        self.active.fetch_sub(1, Ordering::Relaxed);
        self.tasks.fetch_add(1, Ordering::Relaxed);
        out
    }

    pub fn stats(&self) -> PoolStats {
        PoolStats {
            tasks: self.tasks.load(Ordering::Relaxed),
            max_concurrent: self.max_concurrent.load(Ordering::Relaxed),
            queue_wait_secs: self.queue_wait_ns.load(Ordering::Relaxed) as f64 * 1e-9,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_input_order() {
        let items: Vec<usize> = (0..37).collect();
        let expect: Vec<usize> = items.iter().map(|i| i * i).collect();
        for threads in [1, 2, 3, 8, 64] {
            assert_eq!(ordered_map(&items, threads, |&i| i * i), expect, "threads={threads}");
        }
    }

    #[test]
    fn empty_and_single() {
        let none: Vec<u32> = Vec::new();
        assert!(ordered_map(&none, 4, |&x| x).is_empty());
        assert_eq!(ordered_map(&[7u32], 4, |&x| x + 1), vec![8]);
    }

    #[test]
    fn matches_sequential_for_stateless_work() {
        let items: Vec<u64> = (0..100).map(|i| i * 31 + 7).collect();
        let seq = ordered_map(&items, 1, |&x| x.wrapping_mul(x) ^ 0xABCD);
        let par = ordered_map(&items, 7, |&x| x.wrapping_mul(x) ^ 0xABCD);
        assert_eq!(seq, par);
    }

    #[test]
    fn gauge_counts_tasks_and_high_water_mark() {
        let gauge = PoolGauge::new();
        let queued = Instant::now();
        let items: Vec<usize> = (0..16).collect();
        let out = ordered_map(&items, 4, |&i| gauge.track(queued, || i * 2));
        assert_eq!(out, (0..16).map(|i| i * 2).collect::<Vec<_>>());
        let s = gauge.stats();
        assert_eq!(s.tasks, 16);
        assert!(s.max_concurrent >= 1 && s.max_concurrent <= 4);
        assert!(s.queue_wait_secs >= 0.0);
    }

    #[test]
    fn gauge_track_passes_results_through() {
        let gauge = PoolGauge::new();
        assert_eq!(gauge.track(Instant::now(), || 41 + 1), 42);
        assert_eq!(gauge.stats().tasks, 1);
        assert_eq!(gauge.stats().max_concurrent, 1);
    }
}
