//! Deterministic fan-out: map a slice over scoped worker threads and
//! return results **in input order**, so every merge downstream is
//! byte-identical to the sequential execution regardless of thread count
//! or scheduling (the same re-canonicalization rule as the online
//! pipeline, DESIGN.md §4; used by the offline planner's pair fitting,
//! DESIGN.md §5).

/// Map `f` over `items` on up to `threads` scoped worker threads.
///
/// Items are strided over the workers (worker `w` takes items `w`,
/// `w + threads`, …); each worker returns `(index, result)` pairs and the
/// caller reassembles them by index, so the output order — and therefore
/// any order-sensitive fold over it — never depends on scheduling.
/// `threads <= 1` (or a single item) runs inline on the caller's thread.
pub fn ordered_map<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let threads = threads.clamp(1, items.len().max(1));
    if threads <= 1 || items.len() <= 1 {
        return items.iter().map(f).collect();
    }
    let mut slots: Vec<Option<R>> = (0..items.len()).map(|_| None).collect();
    std::thread::scope(|scope| {
        let f = &f;
        let handles: Vec<_> = (0..threads)
            .map(|w| {
                scope.spawn(move || {
                    let mut out = Vec::new();
                    let mut i = w;
                    while i < items.len() {
                        out.push((i, f(&items[i])));
                        i += threads;
                    }
                    out
                })
            })
            .collect();
        for h in handles {
            for (i, r) in h.join().expect("worker panicked") {
                slots[i] = Some(r);
            }
        }
    });
    slots.into_iter().map(|s| s.expect("every item mapped exactly once")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_input_order() {
        let items: Vec<usize> = (0..37).collect();
        let expect: Vec<usize> = items.iter().map(|i| i * i).collect();
        for threads in [1, 2, 3, 8, 64] {
            assert_eq!(ordered_map(&items, threads, |&i| i * i), expect, "threads={threads}");
        }
    }

    #[test]
    fn empty_and_single() {
        let none: Vec<u32> = Vec::new();
        assert!(ordered_map(&none, 4, |&x| x).is_empty());
        assert_eq!(ordered_map(&[7u32], 4, |&x| x + 1), vec![8]);
    }

    #[test]
    fn matches_sequential_for_stateless_work() {
        let items: Vec<u64> = (0..100).map(|i| i * 31 + 7).collect();
        let seq = ordered_map(&items, 1, |&x| x.wrapping_mul(x) ^ 0xABCD);
        let par = ordered_map(&items, 7, |&x| x.wrapping_mul(x) ^ 0xABCD);
        assert_eq!(seq, par);
    }
}
