//! Deterministic pseudo-random number generation (SplitMix64).
//!
//! Every stochastic component of the system (world simulator, ReID error
//! model, RANSAC sampling, SMO shuffling, …) draws from seeded, forkable
//! streams so that every experiment in EXPERIMENTS.md is reproducible
//! bit-for-bit.  SplitMix64 passes BigCrush, is 3 instructions per draw and
//! — unlike `rand` — is available offline.

/// A 64-bit SplitMix64 generator.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// Create a generator from a seed.
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9E37_79B9_7F4A_7C15) }
    }

    /// Derive an independent substream for component `tag`.
    ///
    /// Forked streams are stable under insertion/removal of other draws,
    /// which keeps experiments comparable when unrelated code changes.
    pub fn fork(&self, tag: u64) -> Rng {
        let mut r = Rng::new(self.state ^ tag.wrapping_mul(0xA24B_AED4_963E_E407));
        r.next_u64();
        r
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in `[0, n)` (n > 0), Lemire-style rejection-free.
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Bernoulli draw.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn gauss(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with mean/std.
    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.gauss()
    }

    /// Exponential inter-arrival with rate `lambda` (events/unit time).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        -self.f64().max(1e-300).ln() / lambda
    }

    /// Poisson draw (Knuth for small lambda, normal approx above 30).
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        if lambda > 30.0 {
            return self.normal(lambda, lambda.sqrt()).round().max(0.0) as u64;
        }
        let limit = (-lambda).exp();
        let mut k = 0u64;
        let mut p = 1.0;
        loop {
            p *= self.f64();
            if p <= limit {
                return k;
            }
            k += 1;
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (k <= n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        debug_assert!(k <= n);
        // Floyd's algorithm: O(k) expected, no O(n) allocation.
        let mut chosen: Vec<usize> = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.below(j + 1);
            if chosen.contains(&t) {
                chosen.push(j);
            } else {
                chosen.push(t);
            }
        }
        chosen
    }
}

/// Stateless hash noise: deterministic pseudo-random f64 in [0,1) keyed by
/// up to four integers.  Used by the renderer for per-pixel texture and
/// per-frame sensor noise without carrying RNG state across pixels.
pub fn hash_noise(a: u64, b: u64, c: u64, d: u64) -> f64 {
    let mut z = a
        .wrapping_mul(0x9E37_79B9_7F4A_7C15)
        .wrapping_add(b.wrapping_mul(0xC2B2_AE3D_27D4_EB4F))
        .wrapping_add(c.wrapping_mul(0x1656_67B1_9E37_79F9))
        .wrapping_add(d.wrapping_mul(0x2545_F491_4F6C_DD1D));
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^= z >> 31;
    (z >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn fork_independence() {
        let base = Rng::new(7);
        let mut a = base.fork(1);
        let mut b = base.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
        // forks are stable regardless of parent draws
        let mut parent = Rng::new(7);
        parent.next_u64();
        let mut a2 = Rng::new(7).fork(1);
        let _ = parent;
        assert_eq!(Rng::new(7).fork(1).next_u64(), a2.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(3);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            seen[r.below(7)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn gauss_moments() {
        let mut r = Rng::new(5);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gauss()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn poisson_mean() {
        let mut r = Rng::new(6);
        let lambda = 4.0;
        let n = 20_000;
        let total: u64 = (0..n).map(|_| r.poisson(lambda)).sum();
        let mean = total as f64 / n as f64;
        assert!((mean - lambda).abs() < 0.1, "mean {mean}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(8);
        let n = 20_000;
        let mean = (0..n).map(|_| r.exponential(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(9);
        for _ in 0..100 {
            let mut s = r.sample_indices(20, 8);
            s.sort_unstable();
            s.dedup();
            assert_eq!(s.len(), 8);
            assert!(s.iter().all(|&i| i < 20));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(10);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn hash_noise_deterministic_and_uniformish() {
        assert_eq!(hash_noise(1, 2, 3, 4), hash_noise(1, 2, 3, 4));
        assert_ne!(hash_noise(1, 2, 3, 4), hash_noise(1, 2, 3, 5));
        let mean: f64 =
            (0..10_000).map(|i| hash_noise(i, 0, 0, 0)).sum::<f64>() / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02);
    }
}
