//! Minimal JSON reader/writer (serde is unavailable offline — DESIGN.md §3).
//!
//! Used to read `artifacts/meta.json` (the geometry contract emitted by the
//! python AOT step) and to write experiment reports.  Supports the full JSON
//! value model minus `\u` surrogate pairs (not needed by our artifacts).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Build an object from pairs (convenience for report writers).
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    /// Serialize; `indent` of 0 means compact.
    pub fn to_string_pretty(&self, indent: usize) -> String {
        let mut out = String::new();
        self.write(&mut out, indent, 0);
        out
    }

    fn write(&self, out: &mut String, indent: usize, level: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, level + 1);
                    item.write(out, indent, level + 1);
                }
                if !items.is_empty() {
                    newline(out, indent, level);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, level + 1);
                    write_escaped(out, k);
                    out.push_str(": ");
                    v.write(out, indent, level + 1);
                }
                if !map.is_empty() {
                    newline(out, indent, level);
                }
                out.push('}');
            }
        }
    }
}

fn newline(out: &mut String, indent: usize, level: usize) {
    if indent > 0 {
        out.push('\n');
        for _ in 0..indent * level {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document.
pub fn parse(input: &str) -> Result<Json, String> {
    let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(format!("trailing input at byte {}", p.pos));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", b as char, self.pos))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|c| c as char), self.pos)),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        while matches!(
            self.peek(),
            Some(b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        ) {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>().map(Json::Num).map_err(|e| format!("bad number {s:?}: {e}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or("bad \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|_| "bad \\u")?,
                                16,
                            )
                            .map_err(|_| "bad \\u digits")?;
                            out.push(char::from_u32(code).ok_or("bad codepoint")?);
                            self.pos += 4;
                        }
                        other => return Err(format!("bad escape {other:?}")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // take a full UTF-8 sequence
                    let s = &self.bytes[self.pos..];
                    let text = std::str::from_utf8(s).map_err(|e| e.to_string())?;
                    let ch = text.chars().next().unwrap();
                    out.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                other => return Err(format!("expected , or ] got {other:?}")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                other => return Err(format!("expected , or }} got {other:?}")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_object() {
        let src = r#"{"a": 1, "b": [1.5, true, null, "x\ny"], "c": {"d": -2e3}}"#;
        let v = parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().as_i64(), Some(1));
        assert_eq!(v.get("c").unwrap().get("d").unwrap().as_f64(), Some(-2000.0));
        let arr = v.get("b").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_f64(), Some(1.5));
        assert_eq!(arr[1], Json::Bool(true));
        assert_eq!(arr[3].as_str(), Some("x\ny"));
        // serialize -> parse -> equal
        let re = parse(&v.to_string_pretty(2)).unwrap();
        assert_eq!(re, v);
        let compact = parse(&v.to_string_pretty(0)).unwrap();
        assert_eq!(compact, v);
    }

    #[test]
    fn parses_meta_like_document() {
        let src = r#"{"frame_h": 192, "roi_capacities": [8, 16, 32, 60],
                      "objectness_threshold": 0.25}"#;
        let v = parse(src).unwrap();
        assert_eq!(v.get("frame_h").unwrap().as_usize(), Some(192));
        let caps: Vec<usize> = v
            .get("roi_capacities")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|j| j.as_usize().unwrap())
            .collect();
        assert_eq!(caps, vec![8, 16, 32, 60]);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("nul").is_err());
        assert!(parse("{} extra").is_err());
    }

    #[test]
    fn escapes_in_output() {
        let v = Json::Str("a\"b\\c\nd".to_string());
        let s = v.to_string_pretty(0);
        assert_eq!(parse(&s).unwrap(), v);
    }

    #[test]
    fn unicode_escape() {
        let v = parse(r#""é""#).unwrap();
        assert_eq!(v.as_str(), Some("é"));
    }
}
