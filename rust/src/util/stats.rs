//! Descriptive statistics used by the filters (MAD for the RANSAC threshold,
//! §5.3), the metrics aggregation and the bench harness.

/// Arithmetic mean; 0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population variance; 0 for < 2 samples.
pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64
}

pub fn std_dev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// Median (by sorting a copy); 0 for empty input.
pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        (v[n / 2 - 1] + v[n / 2]) / 2.0
    }
}

/// Median absolute deviation — the paper's RANSAC `residual_threshold`
/// default is `θ · mad` (§5.3.2).
pub fn mad(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let med = median(xs);
    let devs: Vec<f64> = xs.iter().map(|x| (x - med).abs()).collect();
    median(&devs)
}

/// Percentile in `[0, 100]` with linear interpolation; 0 for empty input.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

/// Streaming mean/min/max/variance accumulator (Welford).
#[derive(Debug, Clone, Default)]
pub struct Accumulator {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
    sum: f64,
}

impl Accumulator {
    pub fn new() -> Self {
        Accumulator { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY, sum: 0.0 }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        self.sum += x;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.mean
        }
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    pub fn std_dev(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.max
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_moments() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert!((variance(&xs) - 1.25).abs() < 1e-12);
        assert_eq!(median(&xs), 2.5);
        assert_eq!(median(&[1.0, 2.0, 9.0]), 2.0);
    }

    #[test]
    fn mad_robust_to_outlier() {
        let xs = [1.0, 2.0, 3.0, 4.0, 1000.0];
        assert_eq!(median(&xs), 3.0);
        assert_eq!(mad(&xs), 1.0);
    }

    #[test]
    fn percentiles() {
        let xs: Vec<f64> = (0..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 0.0), 0.0);
        assert_eq!(percentile(&xs, 50.0), 50.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
        assert!((percentile(&xs, 95.0) - 95.0).abs() < 1e-9);
    }

    #[test]
    fn empty_inputs() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(median(&[]), 0.0);
        assert_eq!(mad(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }

    #[test]
    fn accumulator_matches_batch() {
        let xs = [3.0, 1.0, 4.0, 1.0, 5.0, 9.0, 2.0, 6.0];
        let mut acc = Accumulator::new();
        for &x in &xs {
            acc.push(x);
        }
        assert!((acc.mean() - mean(&xs)).abs() < 1e-12);
        assert!((acc.variance() - variance(&xs)).abs() < 1e-12);
        assert_eq!(acc.min(), 1.0);
        assert_eq!(acc.max(), 9.0);
        assert_eq!(acc.count(), 8);
    }
}
