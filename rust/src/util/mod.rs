//! Shared utilities: deterministic RNG, small linear algebra, geometry,
//! statistics, JSON — the pieces `rand`/`serde`/`nalgebra` would normally
//! provide, reimplemented because this build is fully offline (DESIGN.md §3).

pub mod geometry;
pub mod json;
pub mod matrix;
pub mod parallel;
pub mod rng;
pub mod stats;
pub mod sync;
