//! Re-identification substrate.
//!
//! The paper consumes an *error-prone* ReID stream (DiDi-MTMC) and never
//! tries to improve it — CrossRoI's contribution is to clean it
//! statistically.  We therefore substitute the ReID algorithm with a
//! calibrated error-injection model over the simulator's ground truth
//! (DESIGN.md §3): identity breaks (false negatives) dominate, wrong
//! matches (false positives) are rarer, true negatives dwarf both —
//! the Table 2 structure.
//!
//! Also here: the ground-truth augmentation of §5.1.1 (Kalman gap filling
//! for occlusion dropouts) and the pairwise TP/FP/FN/TN characterization
//! that regenerates Table 2.

pub mod error_model;
pub mod kalman;
pub mod labels;
pub mod records;

pub use error_model::{ErrorModelParams, RawReid};
pub use records::{RawDetection, ReidStream};
