//! ReID error injection — the substitute for running DiDi-MTMC on real
//! frames (DESIGN.md §3).
//!
//! Real multi-camera ReID errors are *temporally correlated*: an algorithm
//! that fails to match a car across two views fails for a stretch of
//! frames, not per-frame i.i.d.  We therefore chunk each (vehicle, camera)
//! track into short runs and draw one identity decision per chunk:
//!
//! * **identity break** (prob `p_fn`): the chunk gets a fresh local id —
//!   the detections stay, but cross-camera identity is lost (→ the FN mass
//!   of Table 2, which dominates);
//! * **wrong match** (prob `p_fp`): the chunk steals the id of another
//!   concurrently-visible vehicle (→ Table 2's FP mass; geometry-violating
//!   associations the regression filter must catch);
//! * otherwise the ground-truth global id is kept.
//!
//! Occluded detections are additionally dropped with `p_miss_occluded`
//! (the detector under the ReID algorithm misses them, §5.1.1); the
//! ground-truth side repairs its own copy with Kalman gap filling.

use crate::reid::records::{RawDetection, ReidStream};
use crate::sim::Scenario;
use crate::util::rng::Rng;

/// Error injection parameters (calibrated so the pairwise label counts
/// have Table 2's structure: FN ≫ TP ≳ FP, TN dominant).
#[derive(Debug, Clone)]
pub struct ErrorModelParams {
    /// Chunk length in frames over which one identity decision holds.
    pub chunk_frames: usize,
    /// Probability a chunk loses cross-camera identity.
    pub p_fn: f64,
    /// Probability a chunk is matched to a wrong vehicle.
    pub p_fp: f64,
    /// Probability an occluded detection is missed entirely.
    pub p_miss_occluded: f64,
    pub seed: u64,
}

impl Default for ErrorModelParams {
    fn default() -> Self {
        // Calibrated against Table 2's per-pair ratios: a cross-camera
        // match requires both sides' chunks intact, so the FN fraction of
        // overlap-region records is 1 − (1 − p_fn)² ≈ 0.44 at p_fn = 0.25
        // (paper C1→C2: 263 FN vs 335 TP → 0.44), plus occlusion misses.
        ErrorModelParams {
            chunk_frames: 15,
            p_fn: 0.25,
            p_fp: 0.05,
            p_miss_occluded: 0.8,
            seed: 0xE1D,
        }
    }
}

/// Raw ReID generation over a scenario window.
pub struct RawReid;

impl RawReid {
    /// Produce the raw ReID stream for frames `range` of a scenario.
    ///
    /// Fresh local ids for broken chunks are allocated above the largest
    /// ground-truth id so they can never collide with a real identity.
    pub fn generate(
        scenario: &Scenario,
        range: std::ops::Range<usize>,
        params: &ErrorModelParams,
    ) -> ReidStream {
        Self::generate_par(scenario, range, params, 1)
    }

    /// [`RawReid::generate`] with each camera's records produced on up to
    /// `threads` scoped workers ([`crate::util::parallel::ordered_map`]).
    ///
    /// Byte-identical to the sequential generation at every thread count:
    /// every identity decision is a pure function of
    /// `(seed, camera, chunk, vehicle)` — the memo only avoids re-rolling,
    /// it never couples cameras — and the per-camera record vectors are
    /// concatenated in camera order, exactly the order the sequential
    /// camera-major loop appends in.
    pub fn generate_par(
        scenario: &Scenario,
        range: std::ops::Range<usize>,
        params: &ErrorModelParams,
        threads: usize,
    ) -> ReidStream {
        let n_cams = scenario.cameras.len();
        let max_true = scenario.world.vehicles.iter().map(|v| v.id).max().unwrap_or(0);
        let cams: Vec<usize> = (0..n_cams).collect();
        let per_cam = crate::util::parallel::ordered_map(&cams, threads, |&cam| {
            camera_records(scenario, cam, range.clone(), params, max_true)
        });
        let mut records = Vec::with_capacity(per_cam.iter().map(Vec::len).sum());
        for v in per_cam {
            records.extend(v);
        }
        ReidStream::new(n_cams, range.len(), records)
    }
}

/// One camera's raw records over the window — the sequential generation's
/// inner loop, extracted so cameras can run on separate workers.
fn camera_records(
    scenario: &Scenario,
    cam: usize,
    range: std::ops::Range<usize>,
    params: &ErrorModelParams,
    max_true: u32,
) -> Vec<RawDetection> {
    let rng = Rng::new(params.seed).fork(0x7265_6964);
    let mut records = Vec::new();
    // id decision memo: one identity per (chunk, vehicle) of this camera
    let mut assigned: std::collections::HashMap<(usize, u32), u32> =
        std::collections::HashMap::new();

    for frame in range.clone() {
        for det in scenario.detections(cam, frame) {
            if det.occluded {
                let mut r = rng.fork(hash3(cam, frame, det.vehicle_id));
                if r.chance(params.p_miss_occluded) {
                    continue;
                }
            }
            // one decision per (vehicle, camera, chunk), made when
            // the chunk is first seen and memoized for coherence
            let chunk = frame / params.chunk_frames;
            let key = (chunk, det.vehicle_id);
            let raw_id = *assigned.entry(key).or_insert_with(|| {
                let mut chunk_rng =
                    Rng::new(params.seed).fork(hash3(cam, chunk, det.vehicle_id));
                let roll = chunk_rng.f64();
                if roll < params.p_fn {
                    // identity break: deterministic fresh id
                    fresh_id(max_true, cam, chunk, det.vehicle_id)
                } else if roll < params.p_fn + params.p_fp {
                    // wrong match: steal another visible vehicle's
                    // id.  Confusion is local — the ReID gallery a
                    // detection can be mismatched against is the
                    // traffic of its own intersection — so a fleet
                    // scenario's wrong matches never fabricate a
                    // cross-intersection co-occurrence edge (which
                    // would spuriously fuse overlap components).
                    let home = scenario.intersection_of_vehicle(det.vehicle_id);
                    let others: Vec<u32> = scenario
                        .unique_visible(frame)
                        .into_iter()
                        .filter(|&v| {
                            v != det.vehicle_id
                                && scenario.intersection_of_vehicle(v) == home
                        })
                        .collect();
                    if others.is_empty() {
                        det.vehicle_id
                    } else {
                        others[chunk_rng.below(others.len())]
                    }
                } else {
                    det.vehicle_id
                }
            });
            records.push(RawDetection {
                cam,
                frame: frame - range.start,
                bbox: det.bbox,
                raw_id,
                true_id: det.vehicle_id,
            });
        }
    }
    records
}

fn hash3(a: usize, b: usize, c: u32) -> u64 {
    (a as u64)
        .wrapping_mul(0x9E37_79B9_7F4A_7C15)
        .wrapping_add((b as u64).wrapping_mul(0xC2B2_AE3D_27D4_EB4F))
        .wrapping_add((c as u64).wrapping_mul(0x1656_67B1_9E37_79F9))
}

/// Deterministic fresh id for a broken chunk: unique per (cam, chunk,
/// vehicle), strictly above every ground-truth id, and drawn from a
/// **per-camera** id space — a broken chunk means cross-camera identity
/// was *lost*, so two cameras' fresh ids must never collide (a collision
/// would fabricate a co-occurrence the overlap partition trusts).
fn fresh_id(max_true: u32, cam: usize, chunk: usize, vehicle: u32) -> u32 {
    let h = hash3(cam, chunk, vehicle);
    max_true + 1 + cam as u32 * 1_000_000 + (h % 1_000_000) as u32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;

    fn scenario() -> Scenario {
        Scenario::build(&Config::test_small().scenario)
    }

    #[test]
    fn generates_records_with_errors() {
        let sc = scenario();
        let params = ErrorModelParams::default();
        let stream = RawReid::generate(&sc, 0..sc.n_frames(), &params);
        assert!(!stream.is_empty());
        // some identity breaks must exist
        let broken = stream.all().iter().filter(|d| d.raw_id != d.true_id).count();
        assert!(broken > 0, "error model injected nothing");
        // but not everything is broken
        assert!(broken < stream.len());
    }

    #[test]
    fn deterministic() {
        let sc = scenario();
        let params = ErrorModelParams::default();
        let a = RawReid::generate(&sc, 0..50, &params);
        let b = RawReid::generate(&sc, 0..50, &params);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.all().iter().zip(b.all()) {
            assert_eq!(x.raw_id, y.raw_id);
        }
    }

    #[test]
    fn parallel_generation_is_byte_identical() {
        let sc = scenario();
        let params = ErrorModelParams::default();
        let seq = RawReid::generate(&sc, 0..60, &params);
        for threads in [2, 3, 8] {
            let par = RawReid::generate_par(&sc, 0..60, &params, threads);
            assert_eq!(seq.len(), par.len(), "threads={threads}");
            for (x, y) in seq.all().iter().zip(par.all()) {
                assert_eq!((x.cam, x.frame, x.raw_id), (y.cam, y.frame, y.raw_id));
            }
        }
    }

    #[test]
    fn zero_error_params_reproduce_ground_truth() {
        let sc = scenario();
        let params = ErrorModelParams {
            p_fn: 0.0,
            p_fp: 0.0,
            p_miss_occluded: 0.0,
            ..Default::default()
        };
        let stream = RawReid::generate(&sc, 0..sc.n_frames(), &params);
        assert!(stream.all().iter().all(|d| d.raw_id == d.true_id));
    }

    #[test]
    fn identity_breaks_are_chunk_coherent() {
        // within one chunk, a (vehicle, camera) keeps a single raw id
        let sc = scenario();
        let params = ErrorModelParams::default();
        let stream = RawReid::generate(&sc, 0..sc.n_frames(), &params);
        use std::collections::HashMap;
        let mut per_chunk: HashMap<(usize, usize, u32), u32> = HashMap::new();
        for d in stream.all() {
            let key = (d.cam, d.frame / params.chunk_frames, d.true_id);
            if let Some(&prev) = per_chunk.get(&key) {
                assert_eq!(prev, d.raw_id, "chunk id flipped mid-chunk");
            } else {
                per_chunk.insert(key, d.raw_id);
            }
        }
    }

    #[test]
    fn frame_indices_are_rebased() {
        let sc = scenario();
        let stream = RawReid::generate(&sc, 50..100, &ErrorModelParams::default());
        assert_eq!(stream.n_frames, 50);
        assert!(stream.all().iter().all(|d| d.frame < 50));
    }
}
