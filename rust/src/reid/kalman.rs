//! Constant-velocity Kalman filtering of bbox tracks, used to fill
//! occlusion dropouts in the ReID ground truth (§5.1.1: "we apply Kalman
//! filter to fill the disappearance gaps in vehicles consecutive
//! appearance").
//!
//! Each bbox is tracked as four independent `[value, velocity]` states
//! (cx, cy, w, h); gaps are filled by pure prediction.

use crate::util::geometry::Rect;

/// 1-D constant-velocity Kalman filter.
#[derive(Debug, Clone)]
struct Kf1 {
    x: f64,  // value
    v: f64,  // velocity
    p: [[f64; 2]; 2],
    q: f64, // process noise
    r: f64, // measurement noise
}

impl Kf1 {
    fn new(x0: f64, q: f64, r: f64) -> Kf1 {
        Kf1 { x: x0, v: 0.0, p: [[10.0, 0.0], [0.0, 10.0]], q, r }
    }

    /// Predict `dt` ahead.
    fn predict(&mut self, dt: f64) {
        self.x += self.v * dt;
        // P = F P Fᵀ + Q
        let [[p00, p01], [p10, p11]] = self.p;
        self.p = [
            [p00 + dt * (p10 + p01) + dt * dt * p11 + self.q * dt, p01 + dt * p11],
            [p10 + dt * p11, p11 + self.q * dt],
        ];
    }

    /// Measurement update.
    fn update(&mut self, z: f64) {
        let s = self.p[0][0] + self.r;
        let k0 = self.p[0][0] / s;
        let k1 = self.p[1][0] / s;
        let innov = z - self.x;
        self.x += k0 * innov;
        self.v += k1 * innov;
        let [[p00, p01], [p10, p11]] = self.p;
        self.p = [
            [(1.0 - k0) * p00, (1.0 - k0) * p01],
            [p10 - k1 * p00, p11 - k1 * p01],
        ];
    }
}

/// A bbox observation at a frame index.
#[derive(Debug, Clone, Copy)]
pub struct Obs {
    pub frame: usize,
    pub bbox: Rect,
}

/// Fill missing frames inside a track with Kalman predictions.
///
/// `obs` must be sorted by frame and contain no duplicates.  Returns one
/// bbox per frame in `[first, last]`; observed frames keep their (smoothed
/// toward measurement) bbox, gap frames get the prediction.
pub fn fill_gaps(obs: &[Obs]) -> Vec<Obs> {
    if obs.is_empty() {
        return Vec::new();
    }
    let b0 = obs[0].bbox;
    let (cx0, cy0) = b0.center();
    let mut ks = [
        Kf1::new(cx0, 1.0, 4.0),
        Kf1::new(cy0, 1.0, 4.0),
        Kf1::new(b0.width, 0.5, 4.0),
        Kf1::new(b0.height, 0.5, 4.0),
    ];
    let mut out = Vec::new();
    let mut next_obs = 0usize;
    for frame in obs[0].frame..=obs[obs.len() - 1].frame {
        if frame > obs[0].frame {
            for k in ks.iter_mut() {
                k.predict(1.0);
            }
        }
        if next_obs < obs.len() && obs[next_obs].frame == frame {
            let b = obs[next_obs].bbox;
            let (cx, cy) = b.center();
            ks[0].update(cx);
            ks[1].update(cy);
            ks[2].update(b.width);
            ks[3].update(b.height);
            // keep the true measurement on observed frames
            out.push(Obs { frame, bbox: b });
            next_obs += 1;
        } else {
            let (cx, cy, w, h) = (ks[0].x, ks[1].x, ks[2].x.max(1.0), ks[3].x.max(1.0));
            out.push(Obs { frame, bbox: Rect::new(cx - w / 2.0, cy - h / 2.0, w, h) });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn moving_track(frames: &[usize]) -> Vec<Obs> {
        // bbox moving right at 5 px/frame, constant size
        frames
            .iter()
            .map(|&f| Obs { frame: f, bbox: Rect::new(10.0 + 5.0 * f as f64, 20.0, 30.0, 18.0) })
            .collect()
    }

    #[test]
    fn no_gaps_passthrough() {
        let track = moving_track(&[0, 1, 2, 3]);
        let filled = fill_gaps(&track);
        assert_eq!(filled.len(), 4);
        for (a, b) in filled.iter().zip(&track) {
            assert_eq!(a.frame, b.frame);
            assert_eq!(a.bbox, b.bbox);
        }
    }

    #[test]
    fn fills_gap_with_plausible_prediction() {
        // frames 0..6 with 3 and 4 missing
        let track = moving_track(&[0, 1, 2, 5, 6]);
        let filled = fill_gaps(&track);
        assert_eq!(filled.len(), 7);
        let f3 = &filled[3];
        let expect = 10.0 + 5.0 * 3.0;
        assert!(
            (f3.bbox.left - expect).abs() < 4.0,
            "gap prediction off: {} vs {expect}",
            f3.bbox.left
        );
        let f4 = &filled[4];
        assert!((f4.bbox.left - (10.0 + 20.0)).abs() < 5.0);
        // sizes stay near constant
        assert!((f3.bbox.width - 30.0).abs() < 2.0);
    }

    #[test]
    fn stationary_gap() {
        let track: Vec<Obs> = [0usize, 1, 2, 6, 7]
            .iter()
            .map(|&f| Obs { frame: f, bbox: Rect::new(50.0, 50.0, 20.0, 20.0) })
            .collect();
        let filled = fill_gaps(&track);
        assert_eq!(filled.len(), 8);
        for o in &filled {
            assert!((o.bbox.left - 50.0).abs() < 2.0);
        }
    }

    #[test]
    fn empty_track() {
        assert!(fill_gaps(&[]).is_empty());
    }

    #[test]
    fn single_observation() {
        let track = moving_track(&[4]);
        let filled = fill_gaps(&track);
        assert_eq!(filled.len(), 1);
        assert_eq!(filled[0].frame, 4);
    }
}
