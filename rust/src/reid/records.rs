//! ReID record types: the `<left, top, width, height, id>` tuples of
//! §4.1.1, indexed for the per-frame / per-camera access patterns of the
//! filters and the association builder.

use crate::util::geometry::Rect;

/// One raw ReID detection.
///
/// `raw_id` is what the (error-prone) ReID algorithm assigned; `true_id`
/// is the simulator's ground-truth identity, carried along *only* for
/// evaluation (Table 2, accuracy scoring) — the filters and optimizer
/// never read it.
#[derive(Debug, Clone, Copy)]
pub struct RawDetection {
    pub cam: usize,
    pub frame: usize,
    pub bbox: Rect,
    pub raw_id: u32,
    pub true_id: u32,
}

/// An indexed collection of raw detections over a profile window.
#[derive(Debug, Clone)]
pub struct ReidStream {
    pub n_cameras: usize,
    pub n_frames: usize,
    records: Vec<RawDetection>,
    /// `index[cam][frame]` → indices into `records`.
    index: Vec<Vec<Vec<usize>>>,
}

impl ReidStream {
    pub fn new(n_cameras: usize, n_frames: usize, records: Vec<RawDetection>) -> ReidStream {
        let mut index = vec![vec![Vec::new(); n_frames]; n_cameras];
        for (i, r) in records.iter().enumerate() {
            assert!(r.cam < n_cameras && r.frame < n_frames, "record out of range");
            index[r.cam][r.frame].push(i);
        }
        ReidStream { n_cameras, n_frames, records, index }
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    pub fn all(&self) -> &[RawDetection] {
        &self.records
    }

    /// Detections of one camera at one frame.
    pub fn at(&self, cam: usize, frame: usize) -> impl Iterator<Item = &RawDetection> {
        self.index[cam][frame].iter().map(move |&i| &self.records[i])
    }

    /// Find a raw id in a camera at a frame (first match).
    pub fn find_id(&self, cam: usize, frame: usize, raw_id: u32) -> Option<&RawDetection> {
        self.at(cam, frame).find(|d| d.raw_id == raw_id)
    }

    /// Retain a subset (used by the SVM filter to drop false negatives).
    /// The predicate sees records in their original insertion order.
    pub fn filtered(&self, mut keep: impl FnMut(&RawDetection) -> bool) -> ReidStream {
        let records: Vec<RawDetection> =
            self.records.iter().copied().filter(|d| keep(d)).collect();
        ReidStream::new(self.n_cameras, self.n_frames, records)
    }

    /// Apply an id-rewrite map (used by the regression filter to decouple
    /// false-positive associations by assigning fresh ids).
    pub fn with_rewrites(&self, rewrite: &std::collections::HashMap<usize, u32>) -> ReidStream {
        let records: Vec<RawDetection> = self
            .records
            .iter()
            .enumerate()
            .map(|(i, d)| {
                let mut d = *d;
                if let Some(&new_id) = rewrite.get(&i) {
                    d.raw_id = new_id;
                }
                d
            })
            .collect();
        ReidStream::new(self.n_cameras, self.n_frames, records)
    }

    /// Largest raw id present (for allocating fresh ids).
    pub fn max_raw_id(&self) -> u32 {
        self.records.iter().map(|r| r.raw_id).max().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn det(cam: usize, frame: usize, raw_id: u32, true_id: u32) -> RawDetection {
        RawDetection {
            cam,
            frame,
            bbox: Rect::new(10.0 * raw_id as f64, 5.0, 20.0, 15.0),
            raw_id,
            true_id,
        }
    }

    #[test]
    fn indexing() {
        let s = ReidStream::new(
            2,
            3,
            vec![det(0, 0, 1, 1), det(0, 0, 2, 2), det(1, 0, 1, 1), det(0, 2, 3, 3)],
        );
        assert_eq!(s.len(), 4);
        assert_eq!(s.at(0, 0).count(), 2);
        assert_eq!(s.at(1, 0).count(), 1);
        assert_eq!(s.at(1, 2).count(), 0);
        assert!(s.find_id(0, 0, 2).is_some());
        assert!(s.find_id(0, 0, 9).is_none());
    }

    #[test]
    fn filtered_keeps_subset() {
        let s = ReidStream::new(1, 2, vec![det(0, 0, 1, 1), det(0, 1, 2, 2)]);
        let f = s.filtered(|d| d.raw_id == 2);
        assert_eq!(f.len(), 1);
        assert_eq!(f.at(0, 1).count(), 1);
        assert_eq!(f.at(0, 0).count(), 0);
    }

    #[test]
    fn rewrites_change_ids() {
        let s = ReidStream::new(1, 1, vec![det(0, 0, 1, 1), det(0, 0, 2, 2)]);
        let mut map = std::collections::HashMap::new();
        map.insert(0usize, 99u32);
        let r = s.with_rewrites(&map);
        assert!(r.find_id(0, 0, 99).is_some());
        assert!(r.find_id(0, 0, 1).is_none());
        assert!(r.find_id(0, 0, 2).is_some());
        assert_eq!(r.max_raw_id(), 99);
    }
}
